//! Validate Chrome trace-event JSON exported by `gsplit::obs::chrome`
//! (`gsplit train --trace`, `GSPLIT_TRACE`). CI's `trace-smoke` job runs
//! this over the traces of a serial, a pipelined, and an out-of-core run
//! and fails the build on any violation of the export contract:
//!
//! * the file parses and `traceEvents` is a non-empty array of `"M"`
//!   (metadata) and `"X"` (complete) events with well-formed fields;
//! * every `X` event's `cat` is a known [`Phase`] wire name and its track
//!   (`pid`) is one of the two the exporter writes;
//! * `X` events are globally `ts`-sorted, and within each `(pid, tid)`
//!   track spans nest properly (a span never half-overlaps an enclosing
//!   one) — the invariant Perfetto's flame layout relies on;
//! * the phases named by `--expect` (default: the serial core set) each
//!   appear at least once, and the trace carries at least
//!   `--min-worker-tracks` / `--min-device-tracks` distinct tracks;
//! * the `metrics` snapshot blob rides along with a `counters` object.
//!
//! Usage:
//!   cargo run --release --bin check_trace_json -- trace.json
//!   cargo run --release --bin check_trace_json -- \
//!       --expect sample,load,compute_fwd,loss,shuffle_fwd_send \
//!       --min-worker-tracks 2 --min-device-tracks 4 trace.json

use std::collections::BTreeSet;

use anyhow::{anyhow, bail, ensure, Context, Result};
use gsplit::obs::chrome::{PID_DEVICES, PID_THREADS};
use gsplit::obs::Phase;
use gsplit::util::JsonValue;

/// Slack for float timestamp comparisons: 1 ns in the µs-denominated
/// `ts`/`dur` fields (the exporter divides exact integer nanoseconds by
/// 1000, so errors are pure f64 rounding, far below this).
const EPS_US: f64 = 1e-3;

fn main() -> Result<()> {
    let mut expect: Vec<Phase> = vec![Phase::Sample, Phase::Load, Phase::ComputeFwd, Phase::Loss];
    let mut min_worker_tracks = 1usize;
    let mut min_device_tracks = 1usize;
    let mut files: Vec<String> = Vec::new();
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--expect" => {
                let list = args.next().ok_or_else(|| anyhow!("--expect needs a phase list"))?;
                expect = list
                    .split(',')
                    .map(|s| {
                        Phase::parse(s.trim())
                            .ok_or_else(|| anyhow!("--expect: unknown phase `{s}`"))
                    })
                    .collect::<Result<_>>()?;
            }
            "--min-worker-tracks" => {
                min_worker_tracks = parse_count(args.next(), "--min-worker-tracks")?
            }
            "--min-device-tracks" => {
                min_device_tracks = parse_count(args.next(), "--min-device-tracks")?
            }
            _ => files.push(a),
        }
    }
    ensure!(
        !files.is_empty(),
        "usage: check_trace_json [--expect <phases>] [--min-worker-tracks N] \
         [--min-device-tracks N] <trace.json>..."
    );
    for f in &files {
        let report = check_file(f, &expect, min_worker_tracks, min_device_tracks)
            .with_context(|| format!("{f}: invalid trace"))?;
        println!(
            "{f}: OK ({} events, {} worker track(s), {} device track(s))",
            report.events, report.worker_tracks, report.device_tracks
        );
    }
    println!("{} trace file(s): all valid", files.len());
    Ok(())
}

fn parse_count(arg: Option<String>, flag: &str) -> Result<usize> {
    arg.ok_or_else(|| anyhow!("{flag} needs a count"))?
        .parse::<usize>()
        .map_err(|e| anyhow!("{flag}: {e}"))
}

struct Report {
    events: usize,
    worker_tracks: usize,
    device_tracks: usize,
}

fn num_field(v: &JsonValue, key: &str) -> Result<f64> {
    let x = v.get(key)?.as_f64().ok_or_else(|| anyhow!("`{key}` must be a number"))?;
    ensure!(x.is_finite(), "`{key}` must be finite, got {x}");
    Ok(x)
}

fn check_file(
    path: &str,
    expect: &[Phase],
    min_worker_tracks: usize,
    min_device_tracks: usize,
) -> Result<Report> {
    let text = std::fs::read_to_string(path).context("cannot read file")?;
    let v = JsonValue::parse(&text).context("not valid JSON")?;
    let events =
        v.get("traceEvents")?.as_arr().ok_or_else(|| anyhow!("`traceEvents` must be an array"))?;
    ensure!(!events.is_empty(), "`traceEvents` must be non-empty");

    let mut last_ts = f64::NEG_INFINITY;
    let mut seen_phases: BTreeSet<&'static str> = BTreeSet::new();
    let mut worker_tids: BTreeSet<u64> = BTreeSet::new();
    let mut device_tids: BTreeSet<u64> = BTreeSet::new();
    // Per-(pid, tid) stack of open-span end times, for the nesting check.
    // File order is the exporter's global (t0 asc, t1 desc) order, so an
    // enclosing span always precedes its children.
    let mut stacks: std::collections::BTreeMap<(u64, u64), Vec<f64>> = Default::default();
    let mut n_complete = 0usize;

    for (i, ev) in events.iter().enumerate() {
        let ph = ev
            .get("ph")
            .and_then(|p| p.as_str().ok_or_else(|| anyhow!("`ph` must be a string")))
            .with_context(|| format!("event #{i}"))?;
        match ph {
            "M" => check_metadata(ev).with_context(|| format!("event #{i} (metadata)"))?,
            "X" => {
                check_complete(
                    ev,
                    &mut last_ts,
                    &mut seen_phases,
                    &mut worker_tids,
                    &mut device_tids,
                    &mut stacks,
                )
                .with_context(|| format!("event #{i} (complete)"))?;
                n_complete += 1;
            }
            other => bail!("event #{i}: unexpected ph {other:?} (exporter only writes M and X)"),
        }
    }
    ensure!(n_complete > 0, "trace has metadata but no complete (`X`) events");
    for p in expect {
        ensure!(
            seen_phases.contains(p.name()),
            "expected phase `{}` never appears (saw: {:?})",
            p.name(),
            seen_phases
        );
    }
    ensure!(
        worker_tids.len() >= min_worker_tracks,
        "only {} worker track(s), expected >= {min_worker_tracks}",
        worker_tids.len()
    );
    ensure!(
        device_tids.len() >= min_device_tracks,
        "only {} device track(s), expected >= {min_device_tracks}",
        device_tids.len()
    );
    let metrics = v.get("metrics").context("`metrics` snapshot missing")?;
    ensure!(
        metrics.get("counters").map(|c| c.as_obj().is_some()).unwrap_or(false),
        "`metrics.counters` must be an object"
    );
    Ok(Report {
        events: n_complete,
        worker_tracks: worker_tids.len(),
        device_tracks: device_tids.len(),
    })
}

fn check_metadata(ev: &JsonValue) -> Result<()> {
    let name = ev.get("name")?.as_str().ok_or_else(|| anyhow!("`name` must be a string"))?;
    ensure!(
        name == "process_name" || name == "thread_name",
        "unexpected metadata record `{name}`"
    );
    num_field(ev, "pid")?;
    num_field(ev, "tid")?;
    let label = ev.get("args")?.get("name")?.as_str().unwrap_or("");
    ensure!(!label.is_empty(), "metadata `args.name` must be a non-empty string");
    Ok(())
}

fn check_complete(
    ev: &JsonValue,
    last_ts: &mut f64,
    seen_phases: &mut BTreeSet<&'static str>,
    worker_tids: &mut BTreeSet<u64>,
    device_tids: &mut BTreeSet<u64>,
    stacks: &mut std::collections::BTreeMap<(u64, u64), Vec<f64>>,
) -> Result<()> {
    let name = ev.get("name")?.as_str().ok_or_else(|| anyhow!("`name` must be a string"))?;
    ensure!(!name.is_empty(), "`name` must be non-empty");
    let cat = ev.get("cat")?.as_str().ok_or_else(|| anyhow!("`cat` must be a string"))?;
    let phase = Phase::parse(cat).ok_or_else(|| anyhow!("unknown phase `{cat}`"))?;
    seen_phases.insert(phase.name());
    let ts = num_field(ev, "ts")?;
    let dur = num_field(ev, "dur")?;
    ensure!(ts >= 0.0 && dur >= 0.0, "`ts`/`dur` must be >= 0 (ts={ts}, dur={dur})");
    ensure!(
        ts >= *last_ts,
        "X events must be globally ts-sorted ({ts} after {last_ts})"
    );
    *last_ts = ts;
    let pid = num_field(ev, "pid")? as u64;
    let tid = num_field(ev, "tid")? as u64;
    match pid {
        PID_THREADS => {
            worker_tids.insert(tid);
        }
        PID_DEVICES => {
            device_tids.insert(tid);
        }
        other => bail!("unexpected pid {other} (exporter writes pid 1 and 2 only)"),
    }
    // Nesting: drop spans that ended before this one starts; whatever is
    // still open must fully contain it.
    let stack = stacks.entry((pid, tid)).or_default();
    while stack.last().is_some_and(|&end| end <= ts + EPS_US) {
        stack.pop();
    }
    let end = ts + dur;
    if let Some(&open_end) = stack.last() {
        ensure!(
            end <= open_end + EPS_US,
            "span `{name}` [{ts}, {end}] half-overlaps an open span ending at {open_end} \
             on track ({pid}, {tid})"
        );
    }
    stack.push(end);
    Ok(())
}
