//! Validate machine-readable bench reports (`BENCH_<suite>.json`) against
//! the contract in `bench_harness` (see its module docs): a `suite` name,
//! a `git_rev`, and a non-empty `cases` array whose entries carry finite,
//! non-negative statistics. CI's `bench-smoke` job runs this over every
//! JSON artifact the benches emitted and fails the build on any violation.
//!
//! Usage: `cargo run --release --bin check_bench_json -- BENCH_*.json`

use anyhow::{anyhow, bail, ensure, Context, Result};
use gsplit::util::JsonValue;

fn main() -> Result<()> {
    let files: Vec<String> = std::env::args().skip(1).collect();
    ensure!(!files.is_empty(), "usage: check_bench_json <BENCH_*.json>...");
    let mut total_cases = 0usize;
    for f in &files {
        let n = check_file(f).with_context(|| format!("{f}: invalid bench report"))?;
        println!("{f}: OK ({n} cases)");
        total_cases += n;
    }
    println!("{} file(s), {total_cases} case(s): all valid", files.len());
    Ok(())
}

fn str_field<'a>(v: &'a JsonValue, key: &str) -> Result<&'a str> {
    v.get(key)?.as_str().ok_or_else(|| anyhow!("`{key}` must be a string"))
}

fn num_field(v: &JsonValue, key: &str) -> Result<f64> {
    v.get(key)?.as_f64().ok_or_else(|| anyhow!("`{key}` must be a number"))
}

/// Validate one report; returns its case count.
fn check_file(path: &str) -> Result<usize> {
    let text = std::fs::read_to_string(path).context("cannot read file")?;
    let v = JsonValue::parse(&text).context("not valid JSON")?;
    ensure!(!str_field(&v, "suite")?.is_empty(), "`suite` must be non-empty");
    ensure!(!str_field(&v, "git_rev")?.is_empty(), "`git_rev` must be non-empty");
    let cases =
        v.get("cases")?.as_arr().ok_or_else(|| anyhow!("`cases` must be an array"))?;
    ensure!(!cases.is_empty(), "`cases` must be non-empty");
    for (i, case) in cases.iter().enumerate() {
        check_case(case).with_context(|| format!("case #{i}"))?;
    }
    Ok(cases.len())
}

fn check_case(case: &JsonValue) -> Result<()> {
    ensure!(!str_field(case, "name")?.is_empty(), "`name` must be non-empty");
    let iters = num_field(case, "iters")?;
    ensure!(iters.fract() == 0.0 && iters >= 1.0, "`iters` must be a positive integer: {iters}");
    let stat = |key: &str| -> Result<f64> {
        let x = num_field(case, key)?;
        ensure!(x.is_finite() && x >= 0.0, "`{key}` must be finite and >= 0, got {x}");
        Ok(x)
    };
    let mean = stat("mean_s")?;
    let median = stat("median_s")?;
    let p95 = stat("p95_s")?;
    let min = stat("min_s")?;
    ensure!(min <= mean && min <= median && min <= p95, "`min_s` must be the smallest statistic");
    match case.get("throughput_per_s")? {
        JsonValue::Null => {}
        JsonValue::Num(t) => {
            ensure!(t.is_finite() && *t >= 0.0, "`throughput_per_s` must be finite and >= 0")
        }
        other => bail!("`throughput_per_s` must be a number or null, got {other}"),
    }
    Ok(())
}
