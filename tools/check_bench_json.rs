//! Validate machine-readable bench reports (`BENCH_<suite>.json`) against
//! the contract in `bench_harness` (see its module docs): a `suite` name,
//! a `git_rev`, and a non-empty `cases` array whose entries carry finite,
//! non-negative statistics. CI's `bench-smoke` job runs this over every
//! JSON artifact the benches emitted and fails the build on any violation.
//!
//! With `--baseline <dir>` each report is additionally diffed against the
//! committed baseline of the same filename (`bench_baseline/` in-repo):
//! cases present in both are compared on `mean_s`, and a change worse than
//! 20% is flagged as a regression — on wall-clock-style metrics a higher
//! mean is worse, on `speedup` metrics a lower one is. Metrics missing on
//! either side are reported but never fatal (suites grow and shrink).
//! Regressions are warnings by default — smoke-mode timings on shared CI
//! runners are noisy — and only fail the run under `--strict`.
//!
//! Usage:
//!   cargo run --release --bin check_bench_json -- BENCH_*.json
//!   cargo run --release --bin check_bench_json -- \
//!       --baseline bench_baseline [--strict] BENCH_*.json

use anyhow::{anyhow, bail, ensure, Context, Result};
use gsplit::util::JsonValue;

/// Relative change beyond which a metric counts as regressed.
const REGRESSION_TOL: f64 = 0.20;

fn main() -> Result<()> {
    let mut baseline_dir: Option<String> = None;
    let mut strict = false;
    let mut files: Vec<String> = Vec::new();
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--baseline" => {
                baseline_dir =
                    Some(args.next().ok_or_else(|| anyhow!("--baseline needs a directory"))?)
            }
            "--strict" => strict = true,
            _ => files.push(a),
        }
    }
    ensure!(
        !files.is_empty(),
        "usage: check_bench_json [--baseline <dir>] [--strict] <BENCH_*.json>..."
    );
    let mut total_cases = 0usize;
    let mut regressions = 0usize;
    for f in &files {
        let report = check_file(f).with_context(|| format!("{f}: invalid bench report"))?;
        println!("{f}: OK ({} cases)", report.1);
        total_cases += report.1;
        if let Some(dir) = &baseline_dir {
            regressions += diff_against_baseline(f, &report.0, dir)?;
        }
    }
    println!("{} file(s), {total_cases} case(s): all valid", files.len());
    if regressions > 0 {
        let msg = format!(
            "{regressions} metric(s) regressed >{:.0}% vs baseline",
            REGRESSION_TOL * 100.0
        );
        if strict {
            bail!("{msg}");
        }
        println!("WARNING: {msg} (non-strict mode: not failing)");
    }
    Ok(())
}

fn str_field<'a>(v: &'a JsonValue, key: &str) -> Result<&'a str> {
    v.get(key)?.as_str().ok_or_else(|| anyhow!("`{key}` must be a string"))
}

fn num_field(v: &JsonValue, key: &str) -> Result<f64> {
    v.get(key)?.as_f64().ok_or_else(|| anyhow!("`{key}` must be a number"))
}

/// Validate one report; returns the parsed value and its case count.
fn check_file(path: &str) -> Result<(JsonValue, usize)> {
    let text = std::fs::read_to_string(path).context("cannot read file")?;
    let v = JsonValue::parse(&text).context("not valid JSON")?;
    ensure!(!str_field(&v, "suite")?.is_empty(), "`suite` must be non-empty");
    ensure!(!str_field(&v, "git_rev")?.is_empty(), "`git_rev` must be non-empty");
    let n = {
        let cases =
            v.get("cases")?.as_arr().ok_or_else(|| anyhow!("`cases` must be an array"))?;
        ensure!(!cases.is_empty(), "`cases` must be non-empty");
        for (i, case) in cases.iter().enumerate() {
            check_case(case).with_context(|| format!("case #{i}"))?;
        }
        cases.len()
    };
    Ok((v, n))
}

fn check_case(case: &JsonValue) -> Result<()> {
    ensure!(!str_field(case, "name")?.is_empty(), "`name` must be non-empty");
    let iters = num_field(case, "iters")?;
    ensure!(iters.fract() == 0.0 && iters >= 1.0, "`iters` must be a positive integer: {iters}");
    let stat = |key: &str| -> Result<f64> {
        let x = num_field(case, key)?;
        ensure!(x.is_finite() && x >= 0.0, "`{key}` must be finite and >= 0, got {x}");
        Ok(x)
    };
    let mean = stat("mean_s")?;
    let median = stat("median_s")?;
    let p95 = stat("p95_s")?;
    let min = stat("min_s")?;
    ensure!(min <= mean && min <= median && min <= p95, "`min_s` must be the smallest statistic");
    match case.get("throughput_per_s")? {
        JsonValue::Null => {}
        JsonValue::Num(t) => {
            ensure!(t.is_finite() && *t >= 0.0, "`throughput_per_s` must be finite and >= 0")
        }
        other => bail!("`throughput_per_s` must be a number or null, got {other}"),
    }
    Ok(())
}

/// `(name, mean_s)` for every case of a validated report.
fn case_means(v: &JsonValue) -> Vec<(String, f64)> {
    v.get("cases")
        .ok()
        .and_then(|c| c.as_arr())
        .map(|cases| {
            cases
                .iter()
                .filter_map(|c| {
                    let name = c.get("name").ok()?.as_str()?.to_string();
                    Some((name, c.get("mean_s").ok()?.as_f64()?))
                })
                .collect()
        })
        .unwrap_or_default()
}

/// On most metrics a larger value is worse (wall-clock seconds, bytes
/// moved); `speedup` and rate metrics (`rps`, `throughput`) invert that.
fn higher_is_better(name: &str) -> bool {
    name.contains("speedup") || name.ends_with("/rps") || name.contains("throughput")
}

/// Diff `new` against `<dir>/<basename of path>`. Returns the number of
/// regressed metrics; missing baselines and mismatched case sets only warn.
fn diff_against_baseline(path: &str, new: &JsonValue, dir: &str) -> Result<usize> {
    let base_name = std::path::Path::new(path)
        .file_name()
        .ok_or_else(|| anyhow!("{path}: no file name"))?;
    let base_path = std::path::Path::new(dir).join(base_name);
    let text = match std::fs::read_to_string(&base_path) {
        Ok(t) => t,
        Err(_) => {
            println!("  baseline: {} not found, skipping diff", base_path.display());
            return Ok(0);
        }
    };
    let base = JsonValue::parse(&text)
        .with_context(|| format!("{}: baseline is not valid JSON", base_path.display()))?;
    let base_means = case_means(&base);
    let new_means = case_means(new);
    let mut regressed = 0usize;
    for (name, old) in &base_means {
        let Some((_, cur)) = new_means.iter().find(|(n, _)| n == name) else {
            println!("  baseline: metric `{name}` missing from new run");
            continue;
        };
        if *old <= 0.0 {
            // Zero baselines carry no information to diff against.
            continue;
        }
        let ratio = cur / old;
        let worse = if higher_is_better(name) {
            ratio < 1.0 - REGRESSION_TOL
        } else {
            ratio > 1.0 + REGRESSION_TOL
        };
        if worse {
            regressed += 1;
            println!(
                "  REGRESSION `{name}`: baseline {old:.6} -> {cur:.6} ({:+.1}%)",
                (ratio - 1.0) * 100.0
            );
        }
    }
    for (name, _) in &new_means {
        if !base_means.iter().any(|(n, _)| n == name) {
            println!("  baseline: new metric `{name}` not in baseline (add on next refresh)");
        }
    }
    if regressed == 0 {
        println!(
            "  baseline: {} metrics compared against {}, none regressed >{:.0}%",
            base_means.len(),
            base_path.display(),
            REGRESSION_TOL * 100.0
        );
    }
    Ok(regressed)
}
