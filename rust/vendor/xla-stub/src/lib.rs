//! Compile-time stub of the `xla-rs` PJRT API surface that
//! `gsplit::runtime::pjrt` programs against.
//!
//! The real bridge needs `libxla_extension` (a multi-GB C++ build) plus the
//! AOT HLO artifacts from `python/compile/aot.py` — neither of which a
//! fresh clone has. This stub keeps `--features pjrt` *compiling* anywhere:
//!
//! * [`Literal`] is fully functional (an f32/i32 host buffer with dims) —
//!   the `runtime::tensors` helpers and their tests work against it;
//! * [`PjRtClient`], [`PjRtLoadedExecutable`], and
//!   [`HloModuleProto::from_text_file`] return a descriptive [`Error`] at
//!   runtime, so `Runtime::load` fails cleanly with instructions instead of
//!   breaking the build.
//!
//! To execute real artifacts, point Cargo at the actual `xla` crate (e.g. a
//! `[patch]` entry or editing `rust/Cargo.toml`) — the API here is
//! signature-compatible with the subset gsplit calls.

use std::fmt;

const STUB_MSG: &str = "xla stub: this build links the in-tree PJRT API stub; \
     swap in the real xla-rs crate and libxla_extension to execute AOT \
     artifacts (see README.md \"PJRT backend\")";

/// Error type mirroring `xla-rs`'s displayable error.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Result alias matching the real crate.
pub type Result<T> = std::result::Result<T, Error>;

fn stub_err<T>() -> Result<T> {
    Err(Error(STUB_MSG.to_string()))
}

// ---------------------------------------------------------------------------
// Literals: functional host-side buffers.
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }
}

/// A typed host buffer with dimensions — the stub's (functional) version of
/// `xla::Literal`.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy {
    fn wrap(v: &[Self]) -> Data;
    fn unwrap(data: &Data) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(v: &[Self]) -> Data {
        Data::F32(v.to_vec())
    }

    fn unwrap(data: &Data) -> Result<Vec<Self>> {
        match data {
            Data::F32(v) => Ok(v.clone()),
            Data::I32(_) => Err(Error("literal holds i32, asked for f32".into())),
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: &[Self]) -> Data {
        Data::I32(v.to_vec())
    }

    fn unwrap(data: &Data) -> Result<Vec<Self>> {
        match data {
            Data::I32(v) => Ok(v.clone()),
            Data::F32(_) => Err(Error("literal holds f32, asked for i32".into())),
        }
    }
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { dims: vec![v.len() as i64], data: T::wrap(v) }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let expect: i64 = dims.iter().product();
        if expect as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: {dims:?} has {expect} elements, literal has {}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Copy out as a flat host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
    }

    /// Dimensions of this literal.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Decompose a tuple literal. The stub never produces tuples (only the
    /// real runtime returns tuple outputs), so this always errors.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        stub_err()
    }
}

// ---------------------------------------------------------------------------
// PJRT client / executable / HLO: stubs that fail at runtime, not build time.
// ---------------------------------------------------------------------------

/// Parsed HLO module handle (stub).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parsing HLO text requires the real xla_extension parser.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        stub_err()
    }
}

/// Computation handle built from a parsed HLO module (stub).
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer returned by an execution (stub).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        stub_err()
    }
}

/// Compiled executable (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub_err()
    }
}

/// PJRT client (stub): creation fails with pointers to the real setup.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        stub_err()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        stub_err()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(l.dims(), &[6]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.dims(), &[2, 3]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[7]).is_err());
        assert!(r.to_vec::<i32>().is_err());
    }

    #[test]
    fn runtime_entry_points_fail_with_guidance() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("xla stub"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
