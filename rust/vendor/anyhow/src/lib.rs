//! Offline shim for the [`anyhow`](https://docs.rs/anyhow) error-handling
//! crate, carrying exactly the API subset this workspace uses:
//!
//! * [`Error`] — a boxed dynamic error with a human-readable context chain,
//! * [`Result<T>`] — `Result<T, Error>` with a defaulted error parameter,
//! * [`anyhow!`], [`bail!`], [`ensure!`] — formatted construction macros,
//! * [`Context`] — `.context(..)` / `.with_context(..)` adapters on
//!   `Result`s whose error is either a `std` error or an [`Error`].
//!
//! The build environment's crate registry is offline (see the note in
//! `gsplit::util`), so this shim is vendored in-tree as a path dependency.
//! It is intentionally tiny: no backtraces, no downcasting — errors here
//! terminate CLIs and tests, they are not matched on.

use std::fmt;

/// A dynamic error: an outermost message plus the chain of causes that
/// context wrapping accumulated (most recent first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from anything displayable (what [`anyhow!`] expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context/cause messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.chain.first() {
            Some(top) => f.write_str(top),
            None => f.write_str("unknown error"),
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.chain.split_first() {
            Some((top, rest)) => {
                f.write_str(top)?;
                if !rest.is_empty() {
                    f.write_str("\n\nCaused by:")?;
                    for cause in rest {
                        write!(f, "\n    {cause}")?;
                    }
                }
                Ok(())
            }
            None => f.write_str("unknown error"),
        }
    }
}

// Mirrors anyhow's blanket conversion; coherence with `impl From<T> for T`
// holds because `Error` itself does not implement `std::error::Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` with a defaulted boxed error, like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context adapters on fallible values.
pub trait Context<T, E> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.context(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/path")
            .with_context(|| "reading config".to_string())?;
        Ok(s)
    }

    #[test]
    fn context_chains_and_formats() {
        let err = io_fail().unwrap_err();
        assert_eq!(err.to_string(), "reading config");
        let dbg = format!("{err:?}");
        assert!(dbg.contains("reading config"));
        assert!(dbg.contains("Caused by:"));
        assert!(err.chain().count() >= 2);
    }

    #[test]
    fn macros_build_errors() {
        let e = anyhow!("value {} too large", 7);
        assert_eq!(e.to_string(), "value 7 too large");

        fn bails(x: u32) -> Result<u32> {
            ensure!(x < 10, "x={x} out of range");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(bails(2).unwrap(), 2);
        assert_eq!(bails(3).unwrap_err().to_string(), "three is right out");
        assert_eq!(bails(11).unwrap_err().to_string(), "x=11 out of range");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i64> {
            Ok(s.parse::<i64>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").unwrap_err().to_string().contains("invalid digit"));
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("inner")).context("outer");
        let e = r.unwrap_err();
        assert_eq!(e.to_string(), "outer");
        assert_eq!(e.root_cause(), "inner");
    }
}
