//! Online splitting and cooperative split-parallel sampling (paper §4–§5)
//! plus the shuffle-index construction that the training phase reuses
//! (paper §6, Algorithms 1 & 2).
//!
//! One `SplitPlan` describes one mini-batch iteration executed
//! cooperatively by `k` devices:
//!
//! * every device owns the **local dst** vertices of each layer (assigned
//!   by the constant-time global partitioning function `f_G`),
//! * sampling produces per-device **mixed frontiers** (sources that may be
//!   owned by other devices),
//! * a per-layer [`ShuffleIndex`] records exactly which owned rows each
//!   device must send to each other device so that every mixed frontier
//!   can be materialized with a single all-to-all per layer — during both
//!   sampling (vertex ids) and training (hidden features, reused in the
//!   backward direction for gradients).

use crate::partition::Partitioning;
use crate::rng::{derive_seed, sample_without_replacement, Pcg32};
use crate::sampling::{VertexMap, NO_NEIGHBOR};
use crate::graph::CsrGraph;
use crate::Vid;

/// Per-device slice of one sampled GNN layer.
#[derive(Debug, Clone, Default)]
pub struct DevLayer {
    /// Destination vertices owned by this device at this layer.
    pub dst: Vec<Vid>,
    /// Mixed frontier: `mixed_src[..dst.len()] == dst`, followed by sampled
    /// neighbors (local or remote). Neighbor table indices point here.
    pub mixed_src: Vec<Vid>,
    /// `[dst.len() × fanout]` indices into `mixed_src` (NO_NEIGHBOR pads).
    pub neigh: Vec<u32>,
    pub neigh_len: Vec<u32>,
    pub fanout: usize,
}

impl DevLayer {
    pub fn num_dst(&self) -> usize {
        self.dst.len()
    }

    pub fn num_edges(&self) -> u64 {
        self.neigh_len.iter().map(|&c| c as u64).sum()
    }

    pub fn neighbors_of(&self, i: usize) -> &[u32] {
        &self.neigh[i * self.fanout..i * self.fanout + self.neigh_len[i] as usize]
    }
}

/// All-to-all exchange plan for one layer.
///
/// `send[from][to]` — indices into `from`'s *owned row buffer* of the layer
/// below (its `dst` list there, or the input frontier for the bottom
/// layer). `recv[to][from]` — the positions in `to`'s `mixed_src` where the
/// corresponding rows land. `send[d][d]`/`recv[d][d]` describe local copies
/// (free of communication cost).
#[derive(Debug, Clone, Default)]
pub struct ShuffleIndex {
    pub send: Vec<Vec<Vec<u32>>>,
    pub recv: Vec<Vec<Vec<u32>>>,
}

impl ShuffleIndex {
    fn new(k: usize) -> Self {
        ShuffleIndex {
            send: vec![vec![Vec::new(); k]; k],
            recv: vec![vec![Vec::new(); k]; k],
        }
    }

    /// Number of rows crossing between distinct devices.
    pub fn remote_rows(&self) -> u64 {
        let k = self.send.len();
        let mut n = 0u64;
        for from in 0..k {
            for to in 0..k {
                if from != to {
                    n += self.send[from][to].len() as u64;
                }
            }
        }
        n
    }

    /// Rows received by `to` from remote devices.
    pub fn remote_rows_into(&self, to: usize) -> u64 {
        self.recv[to]
            .iter()
            .enumerate()
            .filter(|(from, _)| *from != to)
            .map(|(_, v)| v.len() as u64)
            .sum()
    }
}

/// One split layer: per-device slices plus the shuffle wiring that fills
/// every device's mixed frontier from owned rows of the layer below.
#[derive(Debug, Clone, Default)]
pub struct SplitLayer {
    pub per_dev: Vec<DevLayer>,
    pub shuffle: ShuffleIndex,
}

impl SplitLayer {
    pub fn total_edges(&self) -> u64 {
        self.per_dev.iter().map(DevLayer::num_edges).sum()
    }

    pub fn edges_per_dev(&self) -> Vec<u64> {
        self.per_dev.iter().map(DevLayer::num_edges).collect()
    }
}

/// The full cooperative plan of one mini-batch iteration.
///
/// # Example
///
/// ```
/// use gsplit::graph::{rmat, GenParams};
/// use gsplit::partition::Partitioning;
/// use gsplit::split::SplitSampler;
///
/// let g = rmat(&GenParams { num_vertices: 256, num_edges: 1024, seed: 1 });
/// let part = Partitioning { assignment: (0..256u32).map(|v| (v % 2) as u16).collect(), k: 2 };
/// let targets: Vec<u32> = (0..32).collect();
/// let mut sampler = SplitSampler::new(part.k);
/// let plan = sampler.sample(&g, &targets, &[3, 3], &part, 7);
/// assert_eq!(plan.k, 2);
/// assert_eq!(plan.layers.len(), 2);
/// // Input features are loaded exactly once across all devices — the
/// // paper's headline no-redundancy property.
/// assert!(plan.total_inputs() > 0);
/// ```
#[derive(Debug, Clone, Default)]
pub struct SplitPlan {
    pub k: usize,
    /// `layers[0]` = top (targets), `layers.last()` = bottom.
    pub layers: Vec<SplitLayer>,
    /// Input frontier per device: the vertices whose **input features** the
    /// device owns and must provide (load or cache-hit) for the bottom
    /// layer. Orders match the bottom layer's shuffle `send` indices.
    pub input_frontier: Vec<Vec<Vid>>,
}

impl SplitPlan {
    /// Total input feature vectors loaded across devices — non-overlapping
    /// by construction (the paper's headline property).
    pub fn total_inputs(&self) -> u64 {
        self.input_frontier.iter().map(|f| f.len() as u64).sum()
    }

    pub fn total_edges(&self) -> u64 {
        self.layers.iter().map(SplitLayer::total_edges).sum()
    }

    /// Owned hidden rows produced by `dev` at layer `l` (its dst there).
    pub fn owned_rows(&self, l: usize, dev: usize) -> &[Vid] {
        if l + 1 < self.layers.len() {
            &self.layers[l + 1].per_dev[dev].dst
        } else {
            &self.input_frontier[dev]
        }
    }

    /// Whether `dev` contributes a backward pass (and therefore reverse
    /// shuffle traffic) at sampled layer `layer` (0 = top).
    ///
    /// Derivable from the plan alone, which lets every participant of the
    /// threaded executor compute the expected reverse-shuffle message
    /// counts without extra coordination (DESIGN.md §Executor). This is
    /// exactly the serial trainer's skip condition: its extra "upstream
    /// gradient non-empty" check can never differ from `num_dst() > 0`,
    /// because a device's upstream gradient rows at `layer` are its dst
    /// rows there (`owned_rows(layer - 1, dev)` is the same list) — see
    /// the `bwd_active_mirrors_plan_shapes` test, which pins the
    /// equivalence.
    pub fn bwd_active(&self, layer: usize, dev: usize) -> bool {
        self.layers[layer].per_dev[dev].num_dst() > 0
    }
}

/// RNG discipline of one cooperative sampling pass.
///
/// Training uses one advancing stream per device: cheap, and deterministic
/// for a fixed (seed, batch) pair — but a vertex's sampled neighborhood
/// then depends on every vertex sampled before it on the same device, i.e.
/// on the batch composition. Serving needs the opposite property: the
/// neighborhood of `v` must be a pure function of `(seed, layer, v)` so
/// that micro-batch boundaries cannot move a single output bit
/// (DESIGN.md §Serving). [`SplitSampler::sample_stateless`] selects the
/// per-vertex mode; the training path is untouched.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum RngMode {
    /// One advancing [`Pcg32`] stream per device (training).
    PerDevice,
    /// A fresh stream `derive_seed(seed, [layer, v])` per frontier vertex
    /// (serving): batch-composition independent by construction.
    PerVertex,
}

/// Per-layer view of the RNG mode handed to [`sample_dev_layer`].
enum LayerRng<'r> {
    Shared(&'r mut Pcg32),
    PerVertex { seed: u64, layer: u64 },
}

/// Split-parallel cooperative sampler (Algorithm 1). Owns reusable scratch.
pub struct SplitSampler {
    vmaps: Vec<VertexMap>,
    owner_pos: Vec<VertexMap>,
    scratch: Vec<u32>,
}

impl SplitSampler {
    pub fn new(k: usize) -> Self {
        SplitSampler {
            vmaps: (0..k).map(|_| VertexMap::new()).collect(),
            owner_pos: (0..k).map(|_| VertexMap::new()).collect(),
            scratch: Vec::with_capacity(64),
        }
    }

    /// Cooperatively sample and split one mini-batch.
    ///
    /// `seed` must be unique per iteration; per-device RNG streams are
    /// derived from it, so the result is independent of execution order.
    pub fn sample(
        &mut self,
        g: &CsrGraph,
        targets: &[Vid],
        fanouts: &[usize],
        part: &Partitioning,
        seed: u64,
    ) -> SplitPlan {
        self.sample_impl(g, targets, fanouts, part, seed, RngMode::PerDevice)
    }

    /// [`SplitSampler::sample`] with **per-vertex RNG streams**: every
    /// frontier vertex at every layer samples from a fresh
    /// `Pcg32::new(derive_seed(seed, &[layer, v]))` stream, so its sampled
    /// neighborhood is a pure function of `(seed, layer, v)` — independent
    /// of which other vertices share the batch. This is the serving-path
    /// sampler: it makes the served forward pass bit-identical across any
    /// micro-batch grouping of the same request set (DESIGN.md §Serving).
    pub fn sample_stateless(
        &mut self,
        g: &CsrGraph,
        targets: &[Vid],
        fanouts: &[usize],
        part: &Partitioning,
        seed: u64,
    ) -> SplitPlan {
        self.sample_impl(g, targets, fanouts, part, seed, RngMode::PerVertex)
    }

    fn sample_impl(
        &mut self,
        g: &CsrGraph,
        targets: &[Vid],
        fanouts: &[usize],
        part: &Partitioning,
        seed: u64,
        mode: RngMode,
    ) -> SplitPlan {
        let k = part.k;
        assert_eq!(self.vmaps.len(), k, "SplitSampler built for different k");
        let num_layers = fanouts.len();
        let mut plan = SplitPlan {
            k,
            layers: Vec::with_capacity(num_layers),
            input_frontier: vec![Vec::new(); k],
        };

        // Split the targets by owner (constant-time lookups — this is the
        // "embarrassingly parallel" online step).
        let mut frontier: Vec<Vec<Vid>> = vec![Vec::new(); k];
        for &t in targets {
            frontier[part.device_of(t) as usize].push(t);
        }

        let mut rngs: Vec<Pcg32> = match mode {
            RngMode::PerDevice => {
                (0..k).map(|d| Pcg32::new(derive_seed(seed, &[d as u64]))).collect()
            }
            RngMode::PerVertex => Vec::new(),
        };

        for (li, &fanout) in fanouts.iter().enumerate() {
            let mut layer = SplitLayer {
                per_dev: Vec::with_capacity(k),
                shuffle: ShuffleIndex::new(k),
            };
            // --- per-device neighbor sampling into mixed frontiers ---
            for d in 0..k {
                let rng = match mode {
                    RngMode::PerDevice => LayerRng::Shared(&mut rngs[d]),
                    RngMode::PerVertex => LayerRng::PerVertex { seed, layer: li as u64 },
                };
                let dl = sample_dev_layer(
                    g,
                    &frontier[d],
                    fanout,
                    rng,
                    &mut self.vmaps[d],
                    &mut self.scratch,
                );
                layer.per_dev.push(dl);
            }
            // --- build the next frontier: vertices of each owner appearing
            // in any mixed frontier, deduplicated in deterministic order ---
            let mut next: Vec<Vec<Vid>> = vec![Vec::new(); k];
            for (o, pos) in self.owner_pos.iter_mut().enumerate() {
                let expected: usize =
                    layer.per_dev.iter().map(|dl| dl.mixed_src.len()).sum::<usize>() / k + 8;
                pos.reset(expected.max(16));
                let _ = o;
            }
            for dl in &layer.per_dev {
                for &v in &dl.mixed_src {
                    let o = part.device_of(v) as usize;
                    let (idx, fresh) = self.owner_pos[o].get_or_insert(v);
                    debug_assert_eq!(!fresh || idx as usize == next[o].len(), true);
                    if fresh {
                        next[o].push(v);
                    }
                }
            }
            // --- shuffle index: owned row position -> mixed_src position ---
            for (d, dl) in layer.per_dev.iter().enumerate() {
                for (row, &v) in dl.mixed_src.iter().enumerate() {
                    let o = part.device_of(v) as usize;
                    let pos = self.owner_pos[o].get(v).expect("owner map populated above");
                    layer.shuffle.send[o][d].push(pos);
                    layer.shuffle.recv[d][o].push(row as u32);
                }
            }
            plan.layers.push(layer);
            frontier = next;
        }
        plan.input_frontier = frontier;
        plan
    }
}

fn sample_dev_layer(
    g: &CsrGraph,
    frontier: &[Vid],
    fanout: usize,
    mut rng: LayerRng<'_>,
    vmap: &mut VertexMap,
    scratch: &mut Vec<u32>,
) -> DevLayer {
    // Neighbor rows are written exactly once below (sampled prefix +
    // padded tail), so the table starts uninitialized (§Perf: it is the
    // largest per-iteration buffer).
    let mut neigh = Vec::with_capacity(frontier.len() * fanout);
    unsafe { neigh.set_len(frontier.len() * fanout) };
    let mut dl = DevLayer {
        dst: frontier.to_vec(),
        mixed_src: Vec::with_capacity(frontier.len() * (fanout + 1)),
        neigh,
        neigh_len: vec![0; frontier.len()],
        fanout,
    };
    vmap.reset(frontier.len() * (fanout + 1));
    for &v in frontier {
        let (idx, fresh) = vmap.get_or_insert(v);
        debug_assert!(fresh);
        debug_assert_eq!(idx as usize, dl.mixed_src.len());
        dl.mixed_src.push(v);
    }
    for (i, &v) in frontier.iter().enumerate() {
        let nbrs = g.neighbors(v);
        match &mut rng {
            LayerRng::Shared(r) => {
                sample_without_replacement(r, nbrs.len() as u32, fanout as u32, scratch)
            }
            LayerRng::PerVertex { seed, layer } => {
                let mut r = Pcg32::new(derive_seed(*seed, &[*layer, v as u64]));
                sample_without_replacement(&mut r, nbrs.len() as u32, fanout as u32, scratch)
            }
        }
        let row = &mut dl.neigh[i * fanout..(i + 1) * fanout];
        for (j, &slot) in scratch.iter().enumerate() {
            let u = nbrs[slot as usize];
            let (idx, fresh) = vmap.get_or_insert(u);
            if fresh {
                dl.mixed_src.push(u);
            }
            row[j] = idx;
        }
        row[scratch.len()..].fill(NO_NEIGHBOR);
        dl.neigh_len[i] = scratch.len() as u32;
    }
    dl
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{rmat, GenParams};
    use crate::partition::{partition_graph, Strategy};
    use crate::presample::PresampleWeights;

    fn setup(k: usize) -> (CsrGraph, Partitioning) {
        let g = rmat(&GenParams { num_vertices: 2048, num_edges: 16384, seed: 13 });
        let w = PresampleWeights::uniform(&g);
        let mask = vec![false; g.num_vertices()];
        let p = partition_graph(&g, &w, &mask, Strategy::Edge, k, 0.1, 7);
        (g, p)
    }

    fn plan_for(g: &CsrGraph, p: &Partitioning, seed: u64) -> SplitPlan {
        let targets: Vec<Vid> = (0..256).collect();
        let mut s = SplitSampler::new(p.k);
        s.sample(g, &targets, &[5, 5, 5], p, seed)
    }

    #[test]
    fn splits_are_disjoint_and_cover_targets() {
        let (g, p) = setup(4);
        let plan = plan_for(&g, &p, 1);
        // Top-layer dst sets partition the targets.
        let mut seen: Vec<Vid> =
            plan.layers[0].per_dev.iter().flat_map(|dl| dl.dst.iter().copied()).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..256).collect::<Vec<_>>());
        // Ownership consistency: every dst is owned by its device.
        for (l, layer) in plan.layers.iter().enumerate() {
            for (d, dl) in layer.per_dev.iter().enumerate() {
                for &v in &dl.dst {
                    assert_eq!(p.device_of(v) as usize, d, "layer {l} dev {d} vertex {v}");
                }
            }
        }
        // Input frontiers are disjoint (no redundant loads — the paper's
        // key property).
        let mut inputs: Vec<Vid> =
            plan.input_frontier.iter().flat_map(|f| f.iter().copied()).collect();
        let before = inputs.len();
        inputs.sort_unstable();
        inputs.dedup();
        assert_eq!(before, inputs.len(), "redundant input features");
        assert_eq!(plan.total_inputs(), before as u64);
    }

    #[test]
    fn shuffle_index_is_a_bijection_onto_mixed_frontiers() {
        let (g, p) = setup(4);
        let plan = plan_for(&g, &p, 2);
        for (l, layer) in plan.layers.iter().enumerate() {
            for (d, dl) in layer.per_dev.iter().enumerate() {
                // Every mixed_src row is received exactly once.
                let mut filled = vec![false; dl.mixed_src.len()];
                for from in 0..plan.k {
                    let send = &layer.shuffle.send[from][d];
                    let recv = &layer.shuffle.recv[d][from];
                    assert_eq!(send.len(), recv.len());
                    for (&s_idx, &r_idx) in send.iter().zip(recv) {
                        // The row sent is the row that lands.
                        let owned = plan.owned_rows(l, from);
                        assert_eq!(
                            owned[s_idx as usize], dl.mixed_src[r_idx as usize],
                            "layer {l} {from}->{d}"
                        );
                        assert!(!filled[r_idx as usize], "double fill");
                        filled[r_idx as usize] = true;
                    }
                }
                assert!(filled.iter().all(|&b| b), "unfilled mixed row (layer {l} dev {d})");
            }
        }
    }

    #[test]
    fn frontier_chaining_matches_owned_rows() {
        let (g, p) = setup(3);
        let plan = plan_for(&g, &p, 3);
        // Every vertex in a mixed frontier at layer l appears in its
        // owner's dst at layer l+1 (or input frontier at the bottom).
        for l in 0..plan.layers.len() {
            for dl in &plan.layers[l].per_dev {
                for &v in &dl.mixed_src {
                    let o = p.device_of(v) as usize;
                    assert!(
                        plan.owned_rows(l, o).contains(&v),
                        "layer {l}: {v} missing from owner {o}'s rows"
                    );
                }
            }
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let (g, p) = setup(4);
        let a = plan_for(&g, &p, 9);
        let b = plan_for(&g, &p, 9);
        assert_eq!(a.total_edges(), b.total_edges());
        assert_eq!(a.input_frontier, b.input_frontier);
        let c = plan_for(&g, &p, 10);
        assert_ne!(
            a.layers[2].per_dev[0].mixed_src, c.layers[2].per_dev[0].mixed_src,
            "different seeds should differ"
        );
    }

    #[test]
    fn k1_plan_has_no_remote_traffic() {
        let (g, p) = setup(1);
        let plan = plan_for(&g, &p, 4);
        for layer in &plan.layers {
            assert_eq!(layer.shuffle.remote_rows(), 0);
        }
        assert!(plan.total_inputs() > 0);
    }

    #[test]
    fn split_edges_match_sampled_edges() {
        let (g, p) = setup(4);
        let plan = plan_for(&g, &p, 5);
        for layer in &plan.layers {
            for dl in &layer.per_dev {
                for i in 0..dl.num_dst() {
                    for &j in dl.neighbors_of(i) {
                        let (d, s) = (dl.dst[i], dl.mixed_src[j as usize]);
                        assert!(g.neighbors(d).contains(&s), "sampled non-edge {d}->{s}");
                    }
                }
            }
        }
    }

    #[test]
    fn bwd_active_mirrors_plan_shapes() {
        let (g, p) = setup(4);
        let plan = plan_for(&g, &p, 8);
        for (l, layer) in plan.layers.iter().enumerate() {
            for d in 0..plan.k {
                let expect = layer.per_dev[d].num_dst() > 0
                    && (l == 0 || !plan.owned_rows(l - 1, d).is_empty());
                assert_eq!(plan.bwd_active(l, d), expect, "layer {l} dev {d}");
            }
        }
    }

    /// The sampled neighbor vertices of top-layer target `t`, in sampling
    /// order (indices resolved through `mixed_src`).
    fn top_neighbors(plan: &SplitPlan, p: &Partitioning, t: Vid) -> Vec<Vid> {
        let dl = &plan.layers[0].per_dev[p.device_of(t) as usize];
        let i = dl.dst.iter().position(|&v| v == t).expect("target in its owner's dst");
        dl.neighbors_of(i).iter().map(|&j| dl.mixed_src[j as usize]).collect()
    }

    #[test]
    fn stateless_sampling_is_independent_of_batch_composition() {
        let (g, p) = setup(4);
        let targets: Vec<Vid> = (0..256).collect();
        let mut s = SplitSampler::new(p.k);
        let full = s.sample_stateless(&g, &targets, &[5, 5, 5], &p, 11);
        // Each target sampled alone must see the exact same neighborhood,
        // in the same order, as it did inside the full batch.
        for &t in &[0u32, 17, 99, 255] {
            let solo = s.sample_stateless(&g, &[t], &[5, 5, 5], &p, 11);
            assert_eq!(
                top_neighbors(&solo, &p, t),
                top_neighbors(&full, &p, t),
                "vertex {t}: stateless neighborhood depends on batch composition"
            );
        }
        // Any split of the batch reproduces the full batch's neighborhoods.
        let (a, b) = targets.split_at(100);
        let pa = s.sample_stateless(&g, a, &[5, 5, 5], &p, 11);
        let pb = s.sample_stateless(&g, b, &[5, 5, 5], &p, 11);
        for &t in a {
            assert_eq!(top_neighbors(&pa, &p, t), top_neighbors(&full, &p, t));
        }
        for &t in b {
            assert_eq!(top_neighbors(&pb, &p, t), top_neighbors(&full, &p, t));
        }
    }

    #[test]
    fn stateless_sampling_is_deterministic_and_seed_sensitive() {
        let (g, p) = setup(3);
        let targets: Vec<Vid> = (0..128).collect();
        let mut s = SplitSampler::new(p.k);
        let a = s.sample_stateless(&g, &targets, &[5, 5], &p, 21);
        let b = s.sample_stateless(&g, &targets, &[5, 5], &p, 21);
        assert_eq!(a.input_frontier, b.input_frontier);
        assert_eq!(a.total_edges(), b.total_edges());
        let c = s.sample_stateless(&g, &targets, &[5, 5], &p, 22);
        assert_ne!(
            a.layers[1].per_dev[0].mixed_src, c.layers[1].per_dev[0].mixed_src,
            "different seeds should differ"
        );
    }

    #[test]
    fn stateless_plans_keep_the_split_invariants() {
        let (g, p) = setup(4);
        let targets: Vec<Vid> = (0..256).collect();
        let mut s = SplitSampler::new(p.k);
        let plan = s.sample_stateless(&g, &targets, &[5, 5, 5], &p, 12);
        // Disjoint input frontiers + the shuffle bijection both hold in
        // per-vertex mode: the RNG discipline only changes which neighbors
        // are drawn, not any of the plan wiring.
        let mut inputs: Vec<Vid> =
            plan.input_frontier.iter().flat_map(|f| f.iter().copied()).collect();
        let before = inputs.len();
        inputs.sort_unstable();
        inputs.dedup();
        assert_eq!(before, inputs.len(), "redundant input features");
        for (l, layer) in plan.layers.iter().enumerate() {
            for (d, dl) in layer.per_dev.iter().enumerate() {
                let mut filled = vec![false; dl.mixed_src.len()];
                for from in 0..plan.k {
                    let send = &layer.shuffle.send[from][d];
                    let recv = &layer.shuffle.recv[d][from];
                    assert_eq!(send.len(), recv.len());
                    for (&s_idx, &r_idx) in send.iter().zip(recv) {
                        let owned = plan.owned_rows(l, from);
                        assert_eq!(owned[s_idx as usize], dl.mixed_src[r_idx as usize]);
                        assert!(!filled[r_idx as usize], "double fill (layer {l})");
                        filled[r_idx as usize] = true;
                    }
                }
                assert!(filled.iter().all(|&b| b), "unfilled mixed row (layer {l} dev {d})");
            }
        }
    }

    #[test]
    fn remote_rows_counts_match_recv() {
        let (g, p) = setup(4);
        let plan = plan_for(&g, &p, 6);
        for layer in &plan.layers {
            let total: u64 = (0..plan.k).map(|d| layer.shuffle.remote_rows_into(d)).sum();
            assert_eq!(total, layer.shuffle.remote_rows());
        }
    }
}
