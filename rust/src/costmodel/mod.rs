//! Calibrated cost model: converts measured per-iteration *counts* (edges
//! sampled, bytes loaded, bytes shuffled, FLOPs) into the paper's S / L /
//! FB second breakdown on the simulated V100 topology.
//!
//! The engines run the real sampling / splitting / caching / shuffle logic
//! and record exact counts; only the conversion constants come from the
//! hardware spec (see `devices::HardwareModel` and DESIGN.md §3). This is
//! the substitution that replaces the paper's physical testbed.

use crate::devices::Topology;
use crate::DeviceId;

/// A `k × k` byte matrix of device-to-device transfers (row = sender).
#[derive(Debug, Clone, PartialEq)]
pub struct CommMatrix {
    pub k: usize,
    bytes: Vec<u64>,
}

impl CommMatrix {
    pub fn new(k: usize) -> Self {
        CommMatrix { k, bytes: vec![0; k * k] }
    }

    #[inline]
    pub fn add(&mut self, from: DeviceId, to: DeviceId, bytes: u64) {
        self.bytes[from as usize * self.k + to as usize] += bytes;
    }

    #[inline]
    pub fn get(&self, from: DeviceId, to: DeviceId) -> u64 {
        self.bytes[from as usize * self.k + to as usize]
    }

    pub fn total_remote(&self) -> u64 {
        let mut t = 0;
        for f in 0..self.k {
            for to in 0..self.k {
                if f != to {
                    t += self.bytes[f * self.k + to];
                }
            }
        }
        t
    }

    pub fn merge(&mut self, other: &CommMatrix) {
        assert_eq!(self.k, other.k);
        for (a, b) in self.bytes.iter_mut().zip(&other.bytes) {
            *a += b;
        }
    }

    /// Seconds for the all-to-all described by this matrix: transfers from
    /// different senders overlap, so the phase takes as long as the
    /// busiest sender's sequential sends (NCCL-style ring/p2p behaviour
    /// approximated at the fidelity the paper's comparison needs).
    pub fn all_to_all_time(&self, topo: &Topology) -> f64 {
        let mut worst = 0.0f64;
        for from in 0..self.k {
            let mut t = 0.0;
            for to in 0..self.k {
                if from != to {
                    let b = self.bytes[from * self.k + to];
                    if b > 0 {
                        t += topo.transfer_time(from as DeviceId, to as DeviceId, b);
                    }
                }
            }
            worst = worst.max(t);
        }
        worst
    }
}

/// Per-iteration counters recorded by an engine. All compute counters are
/// forward-pass only; the conversion applies the standard fwd+bwd factor.
#[derive(Debug, Clone)]
pub struct IterCounters {
    pub k: usize,
    /// Sampled edges per device (sampling-phase work).
    pub sampled_edges: Vec<u64>,
    /// Vertex-id shuffle during cooperative sampling (GSplit only).
    pub sample_comm: CommMatrix,
    /// Input-feature bytes each device loads from host RAM over PCIe.
    pub host_load_bytes: Vec<u64>,
    /// Input-feature bytes that fell through host RAM to disk (out-of-core
    /// chunk-buffer miss) before crossing PCIe — the fourth tier of the
    /// loading split (DESIGN.md §Loading).
    pub disk_load_bytes: Vec<u64>,
    /// Input-feature bytes served from the device's own cache (free on the
    /// timeline, but part of the Local/Peer/Host/Disk loading split).
    pub local_load_bytes: Vec<u64>,
    /// Input-feature bytes fetched from NVLink peers (distributed caches).
    pub peer_load: CommMatrix,
    /// Dense FLOPs per device (forward).
    pub fwd_flops: Vec<u64>,
    /// Irregular gather/aggregation bytes per device (forward).
    pub agg_bytes: Vec<u64>,
    /// Hidden-feature shuffle bytes during forward (backward mirrors it).
    pub train_comm: CommMatrix,
}

impl IterCounters {
    pub fn new(k: usize) -> Self {
        IterCounters {
            k,
            sampled_edges: vec![0; k],
            sample_comm: CommMatrix::new(k),
            host_load_bytes: vec![0; k],
            disk_load_bytes: vec![0; k],
            local_load_bytes: vec![0; k],
            peer_load: CommMatrix::new(k),
            fwd_flops: vec![0; k],
            agg_bytes: vec![0; k],
            train_comm: CommMatrix::new(k),
        }
    }

    pub fn merge(&mut self, other: &IterCounters) {
        assert_eq!(self.k, other.k);
        for i in 0..self.k {
            self.sampled_edges[i] += other.sampled_edges[i];
            self.host_load_bytes[i] += other.host_load_bytes[i];
            self.disk_load_bytes[i] += other.disk_load_bytes[i];
            self.local_load_bytes[i] += other.local_load_bytes[i];
            self.fwd_flops[i] += other.fwd_flops[i];
            self.agg_bytes[i] += other.agg_bytes[i];
        }
        self.sample_comm.merge(&other.sample_comm);
        self.peer_load.merge(&other.peer_load);
        self.train_comm.merge(&other.train_comm);
    }

    /// Total input feature vectors loaded (any non-local source), in
    /// bytes: host RAM + disk fall-through + NVLink peer fetches.
    pub fn total_load_bytes(&self) -> u64 {
        self.host_load_bytes.iter().sum::<u64>()
            + self.disk_load_bytes.iter().sum::<u64>()
            + self.peer_load.total_remote()
    }

    /// Total input bytes *materialized* per iteration — cache hits plus
    /// NVLink peer fetches plus host RAM and disk loads. Constant across
    /// cache policies *and* feature sources for the same plan (caching and
    /// out-of-core buffering re-route bytes between tiers, they never
    /// change how many rows a device needs).
    pub fn total_input_bytes(&self) -> u64 {
        self.local_load_bytes.iter().sum::<u64>() + self.total_load_bytes()
    }

    /// Publish these counters into the global metrics registry, labeled by
    /// engine name, so counting runs are snapshot-able next to traces
    /// (DESIGN.md §Observability). Called once per epoch by
    /// `exec::run_epoch` — overhead is a handful of map lookups.
    pub fn record_metrics(&self, engine: &str) {
        let reg = crate::obs::metrics::registry();
        let eng = [("engine", engine)];
        reg.counter("sampled_edges", &eng).add(self.sampled_edges.iter().sum());
        reg.counter("sample_comm_bytes", &eng).add(self.sample_comm.total_remote());
        reg.counter("train_comm_bytes", &eng).add(self.train_comm.total_remote());
        reg.counter("fwd_flops", &eng).add(self.fwd_flops.iter().sum());
        reg.counter("agg_bytes", &eng).add(self.agg_bytes.iter().sum());
        let tiers: [(&str, u64); 4] = [
            ("local", self.local_load_bytes.iter().sum()),
            ("peer", self.peer_load.total_remote()),
            ("host", self.host_load_bytes.iter().sum()),
            ("disk", self.disk_load_bytes.iter().sum()),
        ];
        for (tier, bytes) in tiers {
            reg.counter("load_bytes", &[("engine", engine), ("tier", tier)]).add(bytes);
        }
    }
}

/// Backward ≈ 2× forward compute (standard for dense layers), so FB = 3×
/// forward FLOPs / aggregation traffic.
const FWD_BWD_FACTOR: f64 = 3.0;
/// Training shuffle happens forward (activations) and backward (gradients)
/// along the same shuffle index.
const SHUFFLE_FWD_BWD_FACTOR: f64 = 2.0;

/// The paper's S / L / FB epoch-time decomposition (Table 3 columns).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseBreakdown {
    pub sampling: f64,
    pub loading: f64,
    pub fb: f64,
}

impl PhaseBreakdown {
    pub fn total(&self) -> f64 {
        self.sampling + self.loading + self.fb
    }

    pub fn add(&mut self, o: PhaseBreakdown) {
        self.sampling += o.sampling;
        self.loading += o.loading;
        self.fb += o.fb;
    }
}

/// Convert counters to seconds on `topo`. Devices execute each phase in
/// parallel; each phase lasts as long as its slowest device (synchronous
/// training, §7.1 — all baselines are synchronous).
pub fn iter_time(c: &IterCounters, topo: &Topology) -> PhaseBreakdown {
    let hw = &topo.hw;
    // --- Sampling: per-device edge work, plus the cooperative sampler's
    // vertex-id all-to-all.
    let sample_work = c
        .sampled_edges
        .iter()
        .map(|&e| e as f64 * hw.sample_edge_cost)
        .fold(0.0f64, f64::max);
    let sampling = sample_work + c.sample_comm.all_to_all_time(topo);

    // --- Loading: per-device host PCIe loads plus disk fall-through
    // (sequential per device: a disk row crosses both the SSD and PCIe;
    // parallel across devices, the bus is per-GPU on p3) + NVLink peer
    // fetches.
    let host = (0..c.k)
        .map(|d| {
            let mut t = 0.0;
            if c.host_load_bytes[d] > 0 {
                t += topo.host_load_time(c.host_load_bytes[d]);
            }
            if c.disk_load_bytes[d] > 0 {
                t += topo.disk_load_time(c.disk_load_bytes[d]);
            }
            t
        })
        .fold(0.0f64, f64::max);
    let loading = host + c.peer_load.all_to_all_time(topo);

    // --- Forward/backward: dense compute + irregular aggregation traffic,
    // overlapped across devices, plus per-layer shuffles (fwd + bwd).
    let compute = (0..c.k)
        .map(|d| {
            c.fwd_flops[d] as f64 / hw.gpu_flops + c.agg_bytes[d] as f64 / hw.gpu_membw
        })
        .fold(0.0f64, f64::max)
        * FWD_BWD_FACTOR;
    let fb = compute + c.train_comm.all_to_all_time(topo) * SHUFFLE_FWD_BWD_FACTOR;

    PhaseBreakdown { sampling, loading, fb }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo() -> Topology {
        Topology::p3_8xlarge(32.0)
    }

    #[test]
    fn zero_counters_zero_time() {
        let c = IterCounters::new(4);
        let t = iter_time(&c, &topo());
        assert_eq!(t.total(), 0.0);
    }

    #[test]
    fn phases_scale_with_counts() {
        let mut c = IterCounters::new(4);
        c.sampled_edges[0] = 1_000_000;
        c.host_load_bytes[1] = 100 << 20;
        c.fwd_flops[2] = 10_u64.pow(12);
        let t1 = iter_time(&c, &topo());
        c.sampled_edges[0] *= 2;
        c.host_load_bytes[1] *= 2;
        c.fwd_flops[2] *= 2;
        let t2 = iter_time(&c, &topo());
        assert!(t2.sampling > 1.9 * t1.sampling);
        assert!(t2.loading > 1.9 * t1.loading);
        assert!(t2.fb > 1.9 * t1.fb);
    }

    #[test]
    fn max_over_devices_not_sum() {
        let mut a = IterCounters::new(4);
        a.sampled_edges = vec![100, 100, 100, 100];
        let mut b = IterCounters::new(4);
        b.sampled_edges = vec![400, 0, 0, 0];
        let (ta, tb) = (iter_time(&a, &topo()), iter_time(&b, &topo()));
        // Balanced work is 4× faster than the same total put on one device.
        assert!((tb.sampling / ta.sampling - 4.0).abs() < 1e-9);
    }

    #[test]
    fn shuffles_prefer_nvlink() {
        let t_nv = topo();
        let t_net = Topology::multi_host(2, 32.0);
        let mut c = IterCounters::new(8);
        // device 0 sends to device 4: NVLink-less in multihost.
        c.train_comm.add(0, 4, 64 << 20);
        let t8 = Topology::p3_16xlarge(32.0);
        let time_same_host = iter_time(&c, &t8).fb;
        let time_cross_host = iter_time(&c, &t_net).fb;
        assert!(time_cross_host > time_same_host);
        let _ = t_nv;
    }

    #[test]
    fn merge_and_totals_cover_all_four_tiers() {
        let mut a = IterCounters::new(2);
        a.local_load_bytes = vec![100, 0];
        a.host_load_bytes = vec![10, 20];
        a.disk_load_bytes = vec![0, 7];
        a.peer_load.add(0, 1, 5);
        let mut b = IterCounters::new(2);
        b.local_load_bytes = vec![1, 1];
        b.host_load_bytes = vec![2, 2];
        b.disk_load_bytes = vec![3, 3];
        b.peer_load.add(1, 0, 4);
        a.merge(&b);
        assert_eq!(a.local_load_bytes, vec![101, 1]);
        assert_eq!(a.host_load_bytes, vec![12, 22]);
        assert_eq!(a.disk_load_bytes, vec![3, 10]);
        // total_load = host + disk + peer; total_input adds local.
        assert_eq!(a.total_load_bytes(), 34 + 13 + 9);
        assert_eq!(a.total_input_bytes(), 102 + 34 + 13 + 9);
        // The four tiers sum to the total an uncached run would report as
        // pure host+disk loads: re-routing never changes the total.
        let tiers = a.local_load_bytes.iter().sum::<u64>()
            + a.host_load_bytes.iter().sum::<u64>()
            + a.disk_load_bytes.iter().sum::<u64>()
            + a.peer_load.total_remote();
        assert_eq!(a.total_input_bytes(), tiers);
    }

    #[test]
    fn disk_loads_cost_more_than_host_loads() {
        let mut ram = IterCounters::new(4);
        ram.host_load_bytes[0] = 100 << 20;
        let mut disk = IterCounters::new(4);
        disk.disk_load_bytes[0] = 100 << 20;
        let t = topo();
        let (t_ram, t_disk) = (iter_time(&ram, &t), iter_time(&disk, &t));
        assert!(t_disk.loading > t_ram.loading, "disk tier must be slower than PCIe alone");
        // A disk row still crosses PCIe: its time includes the host time.
        assert!(t_disk.loading > t_ram.loading * 1.5);
        // Both tiers on one device are sequential, not max().
        let mut both = IterCounters::new(4);
        both.host_load_bytes[0] = 100 << 20;
        both.disk_load_bytes[0] = 100 << 20;
        let t_both = iter_time(&both, &t);
        assert!((t_both.loading - (t_ram.loading + t_disk.loading)).abs() < 1e-12);
    }

    #[test]
    fn record_metrics_publishes_all_four_tiers() {
        let mut c = IterCounters::new(2);
        c.sampled_edges = vec![3, 4];
        c.local_load_bytes = vec![100, 0];
        c.host_load_bytes = vec![10, 20];
        c.disk_load_bytes = vec![0, 7];
        c.peer_load.add(0, 1, 5);
        c.record_metrics("obs_test_engine");
        let snap = crate::obs::metrics::registry().snapshot();
        assert_eq!(snap.counter("sampled_edges{engine=obs_test_engine}"), 7);
        assert_eq!(snap.counter("load_bytes{engine=obs_test_engine,tier=local}"), 100);
        assert_eq!(snap.counter("load_bytes{engine=obs_test_engine,tier=peer}"), 5);
        assert_eq!(snap.counter("load_bytes{engine=obs_test_engine,tier=host}"), 30);
        assert_eq!(snap.counter("load_bytes{engine=obs_test_engine,tier=disk}"), 7);
    }

    #[test]
    fn comm_matrix_accounting() {
        let mut m = CommMatrix::new(3);
        m.add(0, 1, 10);
        m.add(1, 0, 20);
        m.add(2, 2, 99); // local — excluded from remote total
        assert_eq!(m.total_remote(), 30);
        assert_eq!(m.get(1, 0), 20);
        let mut m2 = CommMatrix::new(3);
        m2.add(0, 1, 5);
        m.merge(&m2);
        assert_eq!(m.get(0, 1), 15);
    }
}
