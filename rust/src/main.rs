//! `gsplit` — leader entrypoint / CLI.
//!
//! Subcommands:
//!   train      end-to-end split-parallel training (native backend by
//!              default; `--backend pjrt` with the `pjrt` feature)
//!   serve      online inference service: train briefly, then answer
//!              Zipf-distributed per-vertex requests in micro-batches
//!   epoch      run one counted epoch of any engine and print S/L/FB
//!   partition  run the offline splitting pipeline (presample + partition)
//!   gen        generate and cache a stand-in dataset graph
//!   info       print dataset/topology/manifest information

#![deny(deprecated)]

use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};
use gsplit::bench_harness::BenchSuite;
use gsplit::cache::{CachePolicy, LoadStats, ResidentCache};
use gsplit::cli::Args;
use gsplit::config::{parse_dataset, parse_model};
use gsplit::costmodel::PhaseBreakdown;
use gsplit::devices::Topology;
use gsplit::exec::{run_epoch, DataParallel, Engine, EngineCtx, FullGraph, PushPull, SplitParallel};
use gsplit::graph::{Dataset, FeatureSource};
use gsplit::model::ModelConfig;
use gsplit::opts;
use gsplit::partition::{partition_graph, Strategy};
use gsplit::presample::{presample, PresampleConfig};
use gsplit::rng::derive_seed;
use gsplit::runtime::{Backend, NativeBackend};
use gsplit::serving::{self, traffic};
use gsplit::train::{train_epoch, ExecMode, TrainConfig, Trainer};
use gsplit::util::{fmt_secs, Table};

fn main() -> Result<()> {
    let mut argv = std::env::args().skip(1);
    let sub = argv.next().unwrap_or_else(|| "help".to_string());
    match sub.as_str() {
        "train" => cmd_train(argv),
        "serve" => cmd_serve(argv),
        "epoch" => cmd_epoch(argv),
        "partition" => cmd_partition(argv),
        "gen" => cmd_gen(argv),
        "info" => cmd_info(argv),
        "version" => {
            println!("gsplit {}", env!("CARGO_PKG_VERSION"));
            Ok(())
        }
        "help" | "--help" | "-h" => {
            println!(
                "gsplit — split-parallel GNN training (GSplit reproduction)\n\n\
                 Subcommands:\n  \
                 train      end-to-end split-parallel training (real compute)\n  \
                 serve      online inference: Zipf traffic through the micro-batching service\n  \
                 epoch      counted epoch of one engine; prints the S/L/FB breakdown\n  \
                 partition  offline pipeline: presample + partition, prints quality\n  \
                 gen        generate and cache a stand-in dataset graph\n  \
                 info       dataset / topology / artifact info\n\n\
                 Run `gsplit <subcommand> --help` for options."
            );
            Ok(())
        }
        other => bail!("unknown subcommand `{other}` (try `gsplit help`)"),
    }
}

/// Resolve `--backend` into a boxed [`Backend`] plus the model config and
/// fanout to train with. The native backend takes its shape from the CLI;
/// the PJRT backend takes it from the artifact manifest.
fn resolve_backend(a: &Args) -> Result<(Box<dyn Backend>, ModelConfig, usize)> {
    let kind = parse_model(&a.get_str("model", "sage"))?;
    match a.get_str("backend", "native").as_str() {
        "native" => {
            let cfg = ModelConfig {
                kind,
                feat_dim: a.get_usize("feat", 32)?,
                hidden: a.get_usize("hidden", 64)?,
                num_classes: a.get_usize("classes", 8)?,
                num_layers: a.get_usize("layers", 3)?,
            };
            let fanout = a.get_usize("fanout", 5)?;
            Ok((Box::new(NativeBackend::new()), cfg, fanout))
        }
        #[cfg(feature = "pjrt")]
        "pjrt" => {
            let rt = gsplit::runtime::Runtime::load(a.get_str("artifacts", "artifacts"))?;
            let cfg = rt.model_config(kind);
            let fanout = rt.manifest.kernel_fanout;
            Ok((Box::new(rt), cfg, fanout))
        }
        #[cfg(not(feature = "pjrt"))]
        "pjrt" => bail!(
            "this binary was built without PJRT support; rebuild with \
             `cargo build --features pjrt` (see README.md \"PJRT backend\")"
        ),
        other => bail!("unknown backend `{other}` (native|pjrt)"),
    }
}

fn cmd_train(argv: impl Iterator<Item = String>) -> Result<()> {
    let spec = opts![
        ("iters", true, "training iterations (default 200)"),
        ("batch", true, "mini-batch size (default 256)"),
        ("gpus", true, "simulated GPUs (default 4)"),
        ("lr", true, "learning rate (default 0.2)"),
        ("vertices", true, "SBM graph size (default 16384)"),
        ("seed", true, "random seed (default 42)"),
        ("model", true, "sage|gat (default sage)"),
        ("feat", true, "input feature dim, native backend (default 32)"),
        ("hidden", true, "hidden dim, native backend (default 64)"),
        ("classes", true, "SBM communities = classes, native backend (default 8)"),
        ("layers", true, "GNN layers, native backend (default 3)"),
        ("fanout", true, "neighbor fanout, native backend (default 5)"),
        ("backend", true, "native|pjrt (default native)"),
        ("artifacts", true, "artifacts dir for --backend pjrt (default artifacts)"),
        ("parallel-workers", true, "worker threads for the pipelined executor (0 = serial, default 0)"),
        ("cache-policy", true, "feature cache: none|distributed|partitioned (default none)"),
        ("cache-budget", true, "cached feature rows per simulated GPU (default 4096)"),
        ("graph", true, "train out-of-core from a v2 .gsg (features stay on disk; overrides shape flags)"),
        ("trace", true, "write a Chrome trace-event JSON of the run to this path (see README \"Tracing a run\")"),
    ];
    let a = Args::parse(argv, spec, "end-to-end split-parallel training on a learnable SBM graph")?;
    // `--trace <path>` wins over the `GSPLIT_TRACE` env var; either enables
    // the span recorder for the whole run.
    let trace_path: Option<String> = a
        .get("trace")
        .map(String::from)
        .or_else(|| gsplit::obs::tracer().env_path().map(String::from));
    if trace_path.is_some() {
        gsplit::obs::set_enabled(true);
    }
    let (backend, mut cfg, fanout) = resolve_backend(&a)?;
    let seed = a.get_u64("seed", 42)?;
    let ds = match a.get("graph") {
        Some(path) => {
            // Out-of-core path: topology + labels in RAM, features served
            // from disk through the chunk buffer. Adopt the file's shapes
            // so the model matches whatever was generated.
            let ds = Dataset::open_ooc(std::path::Path::new(path), 0.25, seed ^ 0x5717)?;
            cfg.feat_dim = ds.features.dim();
            cfg.num_classes = ds.labels.num_classes;
            println!(
                "# out-of-core: {path} | {} vertices | {} edges | feat {} on disk",
                ds.graph.num_vertices(),
                ds.graph.num_edges(),
                cfg.feat_dim
            );
            ds
        }
        None => Dataset::sbm_learnable(
            a.get_usize("vertices", 16384)?,
            cfg.num_classes,
            cfg.feat_dim,
            0.6,
            seed,
        ),
    };
    let k = a.get_usize("gpus", 4)?;
    let batch = a.get_usize("batch", 256)?;
    let iters = a.get_usize("iters", 200)?;

    // Offline stage: presample + weighted min-cut partition.
    let pw = presample(
        &ds.graph,
        &ds.labels.train_set,
        &PresampleConfig {
            epochs: 3,
            batch_size: batch,
            fanouts: vec![fanout; cfg.num_layers],
            seed,
        },
    );
    let mask = train_mask(&ds);
    let part = partition_graph(&ds.graph, &pw, &mask, Strategy::GSplit, k, 0.05, seed);
    let workers = a.get_usize("parallel-workers", 0)?;
    let mut trainer =
        Trainer::new(backend.as_ref(), &cfg, fanout, part, a.get_f64("lr", 0.2)? as f32, seed)?;

    // Cache-aware loading stage (DESIGN.md §Loading): serve input rows
    // from per-GPU resident caches, ranked by pre-sampling frequency.
    let policy = CachePolicy::parse(&a.get_str("cache-policy", "none"))?;
    let mut resident = None;
    if policy != CachePolicy::None {
        let budget = a.get_u64("cache-budget", 4096)?;
        let topo = Topology::for_gpus(k, 1.0)?;
        let cache = Arc::new(ResidentCache::build(
            policy,
            &pw.vertex,
            budget,
            trainer.partitioning(),
            &topo,
            &ds.features,
        ));
        let placement = cache.placement();
        println!(
            "# cache {} | budget {budget} rows/GPU | coverage {:.1}% | resident {}",
            policy.name(),
            placement.coverage() * 100.0,
            gsplit::util::fmt_bytes((0..k as u16).map(|d| cache.store().bytes_on(d)).sum::<u64>()),
        );
        resident = Some(cache);
    }
    trainer
        .apply_config(TrainConfig::new().parallel_workers(workers).cache(resident))?;

    let exec = match trainer.exec_mode() {
        ExecMode::Serial => "serial".to_string(),
        ExecMode::Pipelined(p) => format!("pipelined({} workers)", p.workers),
    };
    println!(
        "# backend {} | {}-layer {} {}->{}->{} | k={k} | exec {exec}",
        backend.name(),
        cfg.num_layers,
        cfg.kind.name(),
        cfg.feat_dim,
        cfg.hidden,
        cfg.num_classes
    );
    println!("step,loss,acc");
    let mut done = 0usize;
    let mut epoch = 0u64;
    while done < iters {
        for s in train_epoch(&mut trainer, &ds, batch, epoch)? {
            done += 1;
            println!("{done},{:.4},{:.4}", s.loss, s.accuracy());
            if done >= iters {
                break;
            }
        }
        epoch += 1;
    }
    let val = trainer.evaluate(&ds, &ds.labels.val_set[..batch.min(ds.labels.val_set.len())], 9999)?;
    println!("# final val accuracy {:.4} (random = {:.4})", val.accuracy(), 1.0 / cfg.num_classes as f32);
    let split = LoadStats::sum(trainer.load_stats());
    println!(
        "# loading: local {} | peer(nvlink) {} | host(pcie) {} | disk {} | total {}",
        gsplit::util::fmt_bytes(split.local_bytes),
        gsplit::util::fmt_bytes(split.peer_bytes),
        gsplit::util::fmt_bytes(split.host_bytes),
        gsplit::util::fmt_bytes(split.disk_bytes),
        gsplit::util::fmt_bytes(split.total()),
    );
    if let Some(path) = trace_path {
        let summary = gsplit::obs::chrome::export(std::path::Path::new(&path))?;
        println!(
            "# trace: {path} | {} events | {} worker track(s) | {} device track(s) | {} dropped",
            summary.events, summary.threads, summary.devices, summary.dropped
        );
    }
    Ok(())
}

/// `gsplit serve`: warm the model up with a short training run, then
/// stand up the online inference service and drive a seeded Zipf request
/// stream through it from closed-loop clients. Prints latency percentiles
/// and throughput; `--bench-json` writes them as `BENCH_serving.json` in
/// the repo bench contract.
fn cmd_serve(argv: impl Iterator<Item = String>) -> Result<()> {
    let spec = opts![
        ("requests", true, "inference requests to serve (default 1000)"),
        ("concurrency", true, "closed-loop client threads (default 4)"),
        ("skew", true, "Zipf popularity exponent of the request stream (default 1.0)"),
        ("max-batch", true, "micro-batch flush size (default 32)"),
        ("max-wait-us", true, "micro-batch flush age in microseconds; 0 = per-request batches (default 2000)"),
        ("queue-cap", true, "admission queue bound; submits beyond it are rejected (default 1024)"),
        ("train-iters", true, "warm-up training iterations before serving (default 20)"),
        ("batch", true, "warm-up mini-batch size (default 256)"),
        ("gpus", true, "simulated GPUs (default 4)"),
        ("lr", true, "warm-up learning rate (default 0.2)"),
        ("vertices", true, "SBM graph size (default 16384)"),
        ("seed", true, "random seed (default 42)"),
        ("model", true, "sage|gat (default sage)"),
        ("feat", true, "input feature dim, native backend (default 32)"),
        ("hidden", true, "hidden dim, native backend (default 64)"),
        ("classes", true, "SBM communities = classes, native backend (default 8)"),
        ("layers", true, "GNN layers, native backend (default 3)"),
        ("fanout", true, "neighbor fanout, native backend (default 5)"),
        ("backend", true, "native|pjrt (default native)"),
        ("artifacts", true, "artifacts dir for --backend pjrt (default artifacts)"),
        ("parallel-workers", true, "worker threads for the pipelined executor (0 = serial, default 0)"),
        ("cache-policy", true, "feature cache: none|distributed|partitioned (default none)"),
        ("cache-budget", true, "cached feature rows per simulated GPU (default 4096)"),
        ("graph", true, "serve out-of-core from a v2 .gsg (features stay on disk; overrides shape flags)"),
        ("bench-json", false, "write BENCH_serving.json (to GSPLIT_BENCH_JSON_DIR, default cwd)"),
        ("trace", true, "write a Chrome trace-event JSON of the run to this path"),
    ];
    let a = Args::parse(argv, spec, "online split-parallel inference with micro-batching + Zipf traffic")?;
    let trace_path: Option<String> = a
        .get("trace")
        .map(String::from)
        .or_else(|| gsplit::obs::tracer().env_path().map(String::from));
    if trace_path.is_some() {
        gsplit::obs::set_enabled(true);
    }
    let (backend, mut cfg, fanout) = resolve_backend(&a)?;
    let seed = a.get_u64("seed", 42)?;
    let ds = match a.get("graph") {
        Some(path) => {
            let ds = Dataset::open_ooc(std::path::Path::new(path), 0.25, seed ^ 0x5717)?;
            cfg.feat_dim = ds.features.dim();
            cfg.num_classes = ds.labels.num_classes;
            println!(
                "# out-of-core: {path} | {} vertices | {} edges | feat {} on disk",
                ds.graph.num_vertices(),
                ds.graph.num_edges(),
                cfg.feat_dim
            );
            ds
        }
        None => Dataset::sbm_learnable(
            a.get_usize("vertices", 16384)?,
            cfg.num_classes,
            cfg.feat_dim,
            0.6,
            seed,
        ),
    };
    let k = a.get_usize("gpus", 4)?;
    let batch = a.get_usize("batch", 256)?;

    // Offline stage, same as `train`: presample + weighted min-cut
    // partition. Serving reuses the hotness orders for its caches.
    let pw = presample(
        &ds.graph,
        &ds.labels.train_set,
        &PresampleConfig {
            epochs: 3,
            batch_size: batch,
            fanouts: vec![fanout; cfg.num_layers],
            seed,
        },
    );
    let mask = train_mask(&ds);
    let part = partition_graph(&ds.graph, &pw, &mask, Strategy::GSplit, k, 0.05, seed);
    let workers = a.get_usize("parallel-workers", 0)?;
    let mut trainer =
        Trainer::new(backend.as_ref(), &cfg, fanout, part, a.get_f64("lr", 0.2)? as f32, seed)?;

    let policy = CachePolicy::parse(&a.get_str("cache-policy", "none"))?;
    let mut resident = None;
    if policy != CachePolicy::None {
        let budget = a.get_u64("cache-budget", 4096)?;
        let topo = Topology::for_gpus(k, 1.0)?;
        let cache = Arc::new(ResidentCache::build(
            policy,
            &pw.vertex,
            budget,
            trainer.partitioning(),
            &topo,
            &ds.features,
        ));
        println!(
            "# cache {} | budget {budget} rows/GPU | coverage {:.1}%",
            policy.name(),
            cache.placement().coverage() * 100.0,
        );
        resident = Some(cache);
    }
    trainer
        .apply_config(TrainConfig::new().parallel_workers(workers).cache(resident))?;

    let exec = match trainer.exec_mode() {
        ExecMode::Serial => "serial".to_string(),
        ExecMode::Pipelined(p) => format!("pipelined({} workers)", p.workers),
    };
    println!(
        "# backend {} | {}-layer {} {}->{}->{} | k={k} | exec {exec}",
        backend.name(),
        cfg.num_layers,
        cfg.kind.name(),
        cfg.feat_dim,
        cfg.hidden,
        cfg.num_classes
    );

    // Warm-up: a short training run so served logits come from a real
    // model, not random init. Serving itself never updates parameters.
    let train_iters = a.get_usize("train-iters", 20)?;
    let mut done = 0usize;
    let mut epoch = 0u64;
    while done < train_iters {
        for s in train_epoch(&mut trainer, &ds, batch, epoch)? {
            done += 1;
            if done >= train_iters {
                println!("# warm-up: {done} iters | loss {:.4} | acc {:.4}", s.loss, s.accuracy());
                break;
            }
        }
        epoch += 1;
    }

    let serve_cfg = serving::ServeConfig {
        max_batch: a.get_usize("max-batch", 32)?,
        max_wait: Duration::from_micros(a.get_u64("max-wait-us", 2000)?),
        queue_cap: a.get_usize("queue-cap", 1024)?,
        // Decorrelated from the training seed so eval-time neighborhoods
        // are not the warm-up's; fixed per run for reproducible logits.
        seed: derive_seed(seed, &[0x1F5E]),
    };
    let traffic_cfg = traffic::TrafficConfig {
        requests: a.get_usize("requests", 1000)?,
        concurrency: a.get_usize("concurrency", 4)?,
        skew: a.get_f64("skew", 1.0)?,
        seed,
        vertices: ds.graph.num_vertices(),
    };
    println!(
        "# serving {} requests | zipf s={} | {} clients | max-batch {} | max-wait {}us | queue {}",
        traffic_cfg.requests,
        traffic_cfg.skew,
        traffic_cfg.concurrency,
        serve_cfg.max_batch,
        serve_cfg.max_wait.as_micros(),
        serve_cfg.queue_cap,
    );
    let (traffic_res, report) = serving::run(&mut trainer, &ds, serve_cfg, |client| {
        traffic::run_closed_loop(client, &traffic_cfg)
    })?;
    let traffic_report = traffic_res?;

    let (p50, p95, p99) =
        (report.percentile(50.0), report.percentile(95.0), report.percentile(99.0));
    println!(
        "# served {} | batches {} | mean batch {:.1} | rejected(retried) {}",
        report.served,
        report.batches,
        report.served as f64 / (report.batches.max(1)) as f64,
        traffic_report.rejected,
    );
    println!(
        "# latency p50 {:.3}ms | p95 {:.3}ms | p99 {:.3}ms | throughput {:.0} req/s",
        p50 * 1e3,
        p95 * 1e3,
        p99 * 1e3,
        report.rps(),
    );
    let split = LoadStats::sum(trainer.load_stats());
    println!(
        "# loading: local {} | peer(nvlink) {} | host(pcie) {} | disk {} | total {}",
        gsplit::util::fmt_bytes(split.local_bytes),
        gsplit::util::fmt_bytes(split.peer_bytes),
        gsplit::util::fmt_bytes(split.host_bytes),
        gsplit::util::fmt_bytes(split.disk_bytes),
        gsplit::util::fmt_bytes(split.total()),
    );
    if a.flag("bench-json") {
        let mut suite = BenchSuite::new("serving");
        suite.metric("serve/p50_s", p50);
        suite.metric("serve/p95_s", p95);
        suite.metric("serve/p99_s", p99);
        suite.metric("serve/rps", report.rps());
        suite.finish();
    }
    if let Some(path) = trace_path {
        let summary = gsplit::obs::chrome::export(std::path::Path::new(&path))?;
        println!(
            "# trace: {path} | {} events | {} worker track(s) | {} device track(s) | {} dropped",
            summary.events, summary.threads, summary.devices, summary.dropped
        );
    }
    Ok(())
}

fn cmd_epoch(argv: impl Iterator<Item = String>) -> Result<()> {
    let spec = opts![
        ("dataset", true, "orkut-s|papers-s|friendster-s|tiny (default tiny)"),
        ("system", true, "dgl|quiver|p3|fullgraph|gsplit (default gsplit)"),
        ("model", true, "sage|gat (default sage)"),
        ("gpus", true, "GPUs (default 4)"),
        ("hosts", true, "hosts of 4 GPUs each (default 1; overrides --gpus)"),
        ("batch", true, "batch size (default 1024)"),
        ("fanout", true, "per-layer fanout (default 15)"),
        ("layers", true, "GNN layers (default 3)"),
        ("hidden", true, "hidden size (default 256)"),
        ("seed", true, "seed (default 42)"),
    ];
    let a = Args::parse(argv, spec, "run one counted epoch and print the S/L/FB breakdown")?;
    let ds = parse_dataset(&a.get_str("dataset", "tiny"))?.load()?;
    let kind = parse_model(&a.get_str("model", "sage"))?;
    let hosts = a.get_usize("hosts", 1)?;
    let topo = if hosts > 1 {
        Topology::multi_host(hosts, ds.spec.scale_divisor)
    } else {
        let gpus = a.get_usize("gpus", 4)?;
        Topology::for_gpus(gpus, ds.spec.scale_divisor)
            .map_err(|e| anyhow::anyhow!("--gpus {gpus}: {e} (or pass --hosts for more GPUs)"))?
    };
    let batch = a.get_usize("batch", 1024)?;
    let seed = a.get_u64("seed", 42)?;
    let ctx = EngineCtx::new(
        &ds,
        topo,
        kind,
        a.get_usize("hidden", 256)?,
        a.get_usize("layers", 3)?,
        a.get_usize("fanout", 15)?,
    );
    let pw = presample(
        &ds.graph,
        &ds.labels.train_set,
        &PresampleConfig { epochs: 2, batch_size: batch, fanouts: ctx.fanouts.clone(), seed },
    );
    let mask = train_mask(&ds);
    let sys = a.get_str("system", "gsplit");
    let mut engine: Box<dyn Engine> = match sys.as_str() {
        "dgl" => Box::new(DataParallel::dgl(&ctx)),
        "quiver" => Box::new(DataParallel::quiver(&ctx, &pw, batch)),
        "p3" | "p3*" => Box::new(PushPull::new(&ctx, batch)),
        "full" | "fullgraph" | "cagnet" => Box::new(FullGraph::new(&ctx)),
        "gsplit" => {
            let part =
                partition_graph(&ds.graph, &pw, &mask, Strategy::GSplit, ctx.k(), 0.05, seed);
            Box::new(SplitParallel::new(&ctx, part, &pw.vertex, batch))
        }
        other => bail!("unknown system `{other}`"),
    };
    // Full-graph training has no mini-batches: one iteration is the epoch.
    let eff_batch = if engine.name() == "FullGraph" { usize::MAX } else { batch };
    let (counters, time) = run_epoch(engine.as_mut(), &ctx, eff_batch, seed);
    print_breakdown(engine.name(), &ds.spec.name, &time);
    println!(
        "loads: host {} | peer {} | shuffle {}",
        gsplit::util::fmt_bytes(counters.host_load_bytes.iter().sum()),
        gsplit::util::fmt_bytes(counters.peer_load.total_remote()),
        gsplit::util::fmt_bytes(counters.train_comm.total_remote()),
    );
    Ok(())
}

fn cmd_partition(argv: impl Iterator<Item = String>) -> Result<()> {
    let spec = opts![
        ("dataset", true, "dataset (default tiny)"),
        ("strategy", true, "gsplit|node|edge|rand (default gsplit)"),
        ("parts", true, "number of partitions (default 4)"),
        ("presample-epochs", true, "pre-sampling epochs (default 10)"),
        ("batch", true, "pre-sampling batch size (default 1024)"),
        ("fanout", true, "fanout (default 15)"),
        ("layers", true, "layers (default 3)"),
        ("seed", true, "seed (default 42)"),
    ];
    let a = Args::parse(argv, spec, "offline splitting pipeline: presample + partition")?;
    let ds = parse_dataset(&a.get_str("dataset", "tiny"))?.load()?;
    let strategy = Strategy::parse(&a.get_str("strategy", "gsplit"))?;
    let seed = a.get_u64("seed", 42)?;
    let (t_pre, pw) = gsplit::util::timer::timed(|| {
        presample(
            &ds.graph,
            &ds.labels.train_set,
            &PresampleConfig {
                epochs: a.get_usize("presample-epochs", 10).unwrap(),
                batch_size: a.get_usize("batch", 1024).unwrap(),
                fanouts: vec![a.get_usize("fanout", 15).unwrap(); a.get_usize("layers", 3).unwrap()],
                seed,
            },
        )
    });
    let mask = train_mask(&ds);
    let k = a.get_usize("parts", 4)?;
    let (t_part, part) =
        gsplit::util::timer::timed(|| partition_graph(&ds.graph, &pw, &mask, strategy, k, 0.05, seed));
    let q = gsplit::partition::evaluate_partitioning(&ds.graph, &pw, &part);
    println!(
        "dataset={} strategy={strategy:?} k={k}\npresample {:.1}s, partition {:.1}s",
        ds.spec.name, t_pre, t_part
    );
    println!(
        "expected cut fraction {:.3}, imbalance {:.3}, loads {:?}",
        q.cut_fraction(),
        q.imbalance,
        q.loads
    );
    Ok(())
}

fn cmd_gen(argv: impl Iterator<Item = String>) -> Result<()> {
    let spec = opts![
        ("dataset", true, "dataset to generate (default all paper stand-ins)"),
        ("out", true, "write a v2 .gsg (topology+labels+features) to this path instead of caching"),
        ("vertices", true, "with --out and no --dataset: RMAT vertices (default 100000)"),
        ("edges", true, "with --out and no --dataset: RMAT edges (default 10x vertices)"),
        ("feat", true, "with --out and no --dataset: feature dim (default 64)"),
        ("communities", true, "with --out and no --dataset: RMAT communities (default 64)"),
        ("inter-frac", true, "with --out, no --dataset: cross-community edge fraction (default 0.1)"),
        ("seed", true, "with --out and no --dataset: generator seed (default 42)"),
    ];
    let a = Args::parse(argv, spec, "generate and cache stand-in graphs under target/graphs/")?;
    if let Some(out) = a.get("out") {
        return gen_gsg(&a, std::path::Path::new(out));
    }
    let list = match a.get("dataset") {
        Some(d) => vec![parse_dataset(d)?],
        None => gsplit::graph::StandIn::all_paper().to_vec(),
    };
    for s in list {
        let (t, ds) = gsplit::util::timer::timed(|| s.load());
        let ds = ds?;
        println!(
            "{}: {} vertices, {} edges ({:.1}s)",
            ds.spec.name,
            ds.graph.num_vertices(),
            ds.graph.num_edges(),
            t
        );
    }
    Ok(())
}

/// `gsplit gen --out <path>`: build an out-of-core training input. With
/// `--dataset` the stand-in is materialized and re-written as v2; without
/// it a community-RMAT graph of the requested size is generated and its
/// lazy (procedural) features are **streamed** to disk in chunks — a
/// 10⁷-vertex graph never holds its feature matrix in RAM, here or later
/// during presample → partition → train.
fn gen_gsg(a: &Args, out: &std::path::Path) -> Result<()> {
    if let Some(parent) = out.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let (t, res) = gsplit::util::timer::timed(|| -> Result<(gsplit::graph::CsrGraph, usize)> {
        match a.get("dataset") {
            Some(d) => {
                let ds = parse_dataset(d)?.load()?;
                ds.write_gsg(out)?;
                Ok((ds.graph, ds.features.dim()))
            }
            None => {
                let n = a.get_usize("vertices", 100_000)?;
                let edges = a.get_usize("edges", 10 * n)?;
                let feat = a.get_usize("feat", 64)?;
                let seed = a.get_u64("seed", 42)?;
                let graph = gsplit::graph::community_rmat(
                    &gsplit::graph::GenParams { num_vertices: n, num_edges: edges, seed },
                    a.get_usize("communities", 64)?,
                    a.get_f64("inter-frac", 0.1)?,
                );
                // Same lazy-feature and degree-label derivation as the
                // stand-ins: the file is bit-identical to what the in-RAM
                // reference would serve.
                let features = gsplit::graph::FeatureStore::lazy(n, feat, seed ^ 0xFEA7);
                let labels: Vec<u32> =
                    (0..n as gsplit::Vid).map(|v| graph.degree(v) % 16).collect();
                gsplit::graph::save_dataset(out, &graph, Some(&labels), &features)?;
                Ok((graph, feat))
            }
        }
    });
    let (graph, feat_dim) = res?;
    let size = std::fs::metadata(out)?.len();
    println!(
        "{}: {} vertices, {} edges, feat {} | {} ({:.1}s)",
        out.display(),
        graph.num_vertices(),
        graph.num_edges(),
        feat_dim,
        gsplit::util::fmt_bytes(size),
        t
    );
    Ok(())
}

fn cmd_info(argv: impl Iterator<Item = String>) -> Result<()> {
    let spec = opts![("artifacts", true, "artifacts dir (default artifacts)")];
    let a = Args::parse(argv, spec, "print dataset specs and AOT artifact info")?;
    let mut t = Table::new(&["Dataset", "Vertices", "Und. edges", "Feat", "Train frac"]).left(0);
    for s in gsplit::graph::StandIn::all_paper() {
        let sp = s.spec();
        t.row(vec![
            sp.name.into(),
            sp.num_vertices.to_string(),
            sp.num_und_edges.to_string(),
            sp.feat_dim.to_string(),
            format!("{:.3}", sp.train_frac),
        ]);
    }
    t.print();
    print_artifact_info(&a);
    Ok(())
}

#[cfg(feature = "pjrt")]
fn print_artifact_info(a: &Args) {
    match gsplit::runtime::Runtime::load(a.get_str("artifacts", "artifacts")) {
        Ok(rt) => println!(
            "artifacts: {} entries, fanout {}, dims feat={} hidden={} classes={}",
            rt.manifest.artifacts.len(),
            rt.manifest.kernel_fanout,
            rt.manifest.feat_dim,
            rt.manifest.hidden,
            rt.manifest.num_classes
        ),
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
}

#[cfg(not(feature = "pjrt"))]
fn print_artifact_info(_a: &Args) {
    println!("artifacts: n/a — built without the `pjrt` feature (native backend only)");
}

fn train_mask(ds: &Dataset) -> Vec<bool> {
    let mut m = vec![false; ds.graph.num_vertices()];
    for &t in &ds.labels.train_set {
        m[t as usize] = true;
    }
    m
}

fn print_breakdown(system: &str, dataset: &str, t: &PhaseBreakdown) {
    let mut tab = Table::new(&["System", "Dataset", "S", "L", "FB", "Total(s)"]).left(0).left(1);
    tab.row(vec![
        system.to_string(),
        dataset.to_string(),
        fmt_secs(t.sampling),
        fmt_secs(t.loading),
        fmt_secs(t.fb),
        fmt_secs(t.total()),
    ]);
    tab.print();
}
