//! Resident cache storage for the real-compute trainer (paper §2.2, §6).
//!
//! [`FeatureCache`](super::FeatureCache) is a pure *placement* — a bitmask
//! answering "which devices hold vertex `v`". The trainer's loading stage
//! additionally needs the cached rows' **actual feature data** resident
//! per simulated device, so a Local hit can be served without touching
//! host memory and a Peer hit can be served by the owning device over the
//! executor's channel fabric. [`CacheStore`] holds that data;
//! [`ResidentCache`] bundles placement + store + topology into the one
//! object the trainer consults on the hot path.
//!
//! Determinism: a cached row is a bit-exact copy of the host row (built
//! once from the [`FeatureSource`]), so serving a row from Local, Peer, or
//! Host yields identical f32 bits — caching can change *where bytes move*,
//! never *what the model computes* (DESIGN.md §Loading).

use anyhow::{bail, Result};

use crate::devices::Topology;
use crate::graph::FeatureSource;
use crate::obs::{metrics, Phase};
use crate::partition::Partitioning;
use crate::span;
use crate::{DeviceId, Vid};

use super::{FeatureCache, FetchSource};

/// Which placement policy the trainer's feature cache uses (the three
/// systems of `cache/mod.rs`, selectable from the CLI via
/// `--cache-policy`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CachePolicy {
    /// DGL-style: nothing cached, every row loads from host memory.
    None,
    /// Quiver-style: hottest rows partitioned within NVLink cliques and
    /// replicated across cliques.
    Distributed,
    /// GSplit-style: each device caches its hottest *owned* rows, keeping
    /// the cache consistent with the splits.
    Partitioned,
}

impl CachePolicy {
    pub fn parse(s: &str) -> Result<CachePolicy> {
        match s {
            "none" => Ok(CachePolicy::None),
            "distributed" => Ok(CachePolicy::Distributed),
            "partitioned" => Ok(CachePolicy::Partitioned),
            other => bail!("unknown cache policy `{other}` (none|distributed|partitioned)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            CachePolicy::None => "none",
            CachePolicy::Distributed => "distributed",
            CachePolicy::Partitioned => "partitioned",
        }
    }

    /// Build the placement for this policy. `budget_rows` is the per-GPU
    /// row budget; `ranking` orders vertices hottest-first (pre-sampling
    /// frequency in the paper, §7.1).
    pub fn build_placement(
        self,
        ranking: &[u64],
        budget_rows: u64,
        part: &Partitioning,
        topo: &Topology,
    ) -> FeatureCache {
        assert_eq!(
            part.k,
            topo.num_gpus(),
            "partitioning and topology must agree on the device count"
        );
        match self {
            CachePolicy::None => FeatureCache::none(ranking.len(), part.k),
            CachePolicy::Distributed => FeatureCache::distributed(ranking, budget_rows, topo),
            CachePolicy::Partitioned => FeatureCache::partitioned(ranking, budget_rows, part),
        }
    }
}

/// Per-device byte accounting of one (or many) loading stages: where did
/// each input-feature row come from?
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LoadStats {
    /// Bytes served from the device's own resident cache (free).
    pub local_bytes: u64,
    /// Bytes pulled from an NVLink peer's resident cache.
    pub peer_bytes: u64,
    /// Bytes loaded from host RAM over PCIe (the feature source served
    /// them from memory: an in-RAM store, or a chunk-buffer hit).
    pub host_bytes: u64,
    /// Bytes that fell through host RAM to disk (out-of-core chunk-buffer
    /// miss) before crossing PCIe — the fourth tier of DESIGN.md §Loading.
    pub disk_bytes: u64,
}

impl LoadStats {
    /// All input bytes this device materialized, regardless of source.
    /// Invariant: equal to the uncached total for the same plan — caching
    /// re-routes bytes between sources, it never changes how many rows a
    /// device needs.
    pub fn total(&self) -> u64 {
        self.local_bytes + self.peer_bytes + self.host_bytes + self.disk_bytes
    }

    pub fn merge(&mut self, other: &LoadStats) {
        self.local_bytes += other.local_bytes;
        self.peer_bytes += other.peer_bytes;
        self.host_bytes += other.host_bytes;
        self.disk_bytes += other.disk_bytes;
    }

    /// Sum many per-device stats (e.g. `Trainer::load_stats()`) into one.
    pub fn sum<'a>(stats: impl IntoIterator<Item = &'a LoadStats>) -> LoadStats {
        let mut acc = LoadStats::default();
        for s in stats {
            acc.merge(s);
        }
        acc
    }

    /// Publish this accounting into the metrics registry (`crate::obs`):
    /// one `load_bytes` counter per tier, plus the cache hit/miss byte
    /// split (hit = served resident, Local or Peer; miss = fell through to
    /// Host or Disk). `scope` distinguishes producers (e.g. `train` for
    /// the real-compute trainer, an engine name for the counting engines).
    pub fn record_metrics(&self, scope: &str) {
        let reg = metrics::registry();
        let tiers = [
            ("local", self.local_bytes),
            ("peer", self.peer_bytes),
            ("host", self.host_bytes),
            ("disk", self.disk_bytes),
        ];
        for (tier, bytes) in tiers {
            if bytes > 0 {
                reg.counter("load_bytes", &[("scope", scope), ("tier", tier)]).add(bytes);
            }
        }
        reg.counter("cache_hit_bytes", &[("scope", scope)])
            .add(self.local_bytes + self.peer_bytes);
        reg.counter("cache_miss_bytes", &[("scope", scope)])
            .add(self.host_bytes + self.disk_bytes);
    }
}

/// Resident feature rows per simulated device: the actual f32 data of
/// every row the placement assigns to each device, copied once from the
/// [`FeatureSource`] at build time.
#[derive(Debug, Clone)]
pub struct CacheStore {
    dim: usize,
    /// Cached vertex ids per device, ascending (lookup = binary search).
    vids: Vec<Vec<Vid>>,
    /// Row-major resident rows per device, aligned with `vids`.
    data: Vec<Vec<f32>>,
}

impl CacheStore {
    /// Materialize the rows the placement assigns to each device.
    ///
    /// This is an *offline* bulk read: afterwards the source's host-tier
    /// state is reset (`reset_host_tiers`), so the online Host/Disk
    /// accounting starts cold and does not depend on which rows the cache
    /// build happened to pull through an out-of-core chunk buffer.
    pub fn build(placement: &FeatureCache, features: &dyn FeatureSource) -> CacheStore {
        let _s = span!(Phase::CacheBuild);
        let k = placement.k();
        let dim = features.dim();
        let mut vids: Vec<Vec<Vid>> = vec![Vec::new(); k];
        let mut data: Vec<Vec<f32>> = vec![Vec::new(); k];
        for v in 0..placement.num_vertices() as Vid {
            for d in 0..k {
                if placement.is_cached_on(v, d as DeviceId) {
                    vids[d].push(v);
                    let start = data[d].len();
                    data[d].resize(start + dim, 0.0);
                    features.copy_row(v, &mut data[d][start..start + dim]);
                }
            }
        }
        features.reset_host_tiers();
        let store = CacheStore { dim, vids, data };
        // Resident footprint per device, snapshot-able alongside the byte
        // tiers the loading stage publishes.
        let reg = metrics::registry();
        for d in 0..k {
            let dev = d.to_string();
            let labels = [("device", dev.as_str())];
            reg.gauge("cache_resident_rows", &labels).set(store.rows_on(d as DeviceId) as f64);
            reg.gauge("cache_resident_bytes", &labels).set(store.bytes_on(d as DeviceId) as f64);
        }
        store
    }

    /// The resident row of `v` on device `d`, if cached there.
    #[inline]
    pub fn row(&self, d: DeviceId, v: Vid) -> Option<&[f32]> {
        let i = self.vids[d as usize].binary_search(&v).ok()?;
        Some(&self.data[d as usize][i * self.dim..(i + 1) * self.dim])
    }

    pub fn rows_on(&self, d: DeviceId) -> usize {
        self.vids[d as usize].len()
    }

    pub fn bytes_on(&self, d: DeviceId) -> u64 {
        (self.data[d as usize].len() * 4) as u64
    }

    pub fn dim(&self) -> usize {
        self.dim
    }
}

/// Everything the trainer's loading stage consults: the placement, the
/// resident row data, and the topology that decides which cached copies
/// are actually reachable over NVLink.
#[derive(Debug, Clone)]
pub struct ResidentCache {
    policy: CachePolicy,
    placement: FeatureCache,
    store: CacheStore,
    topo: Topology,
}

impl ResidentCache {
    /// Build placement + resident store for `policy` under a per-GPU
    /// `budget_rows`.
    pub fn build(
        policy: CachePolicy,
        ranking: &[u64],
        budget_rows: u64,
        part: &Partitioning,
        topo: &Topology,
        features: &dyn FeatureSource,
    ) -> ResidentCache {
        assert_eq!(ranking.len(), features.len(), "ranking must cover all vertices");
        let placement = policy.build_placement(ranking, budget_rows, part, topo);
        let store = CacheStore::build(&placement, features);
        ResidentCache { policy, placement, store, topo: topo.clone() }
    }

    /// Where device `d` obtains the input features of `v` (topology-aware:
    /// a copy on a linkless peer reports `Host`, never `Peer`).
    #[inline]
    pub fn fetch_source(&self, v: Vid, d: DeviceId) -> FetchSource {
        self.placement.fetch_source(v, d, &self.topo)
    }

    /// The resident row of `v` on device `d`, if cached there.
    #[inline]
    pub fn resident_row(&self, d: DeviceId, v: Vid) -> Option<&[f32]> {
        self.store.row(d, v)
    }

    pub fn policy(&self) -> CachePolicy {
        self.policy
    }

    pub fn placement(&self) -> &FeatureCache {
        &self.placement
    }

    pub fn store(&self) -> &CacheStore {
        &self.store
    }

    pub fn topo(&self) -> &Topology {
        &self.topo
    }

    pub fn k(&self) -> usize {
        self.placement.k()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::FeatureStore;

    fn toy_features(n: usize, dim: usize) -> FeatureStore {
        let data: Vec<f32> = (0..n * dim).map(|i| i as f32).collect();
        FeatureStore::dense(n, dim, data)
    }

    fn modulo_part(n: usize, k: usize) -> Partitioning {
        Partitioning {
            assignment: (0..n as Vid).map(|v| (v % k as Vid) as DeviceId).collect(),
            k,
        }
    }

    #[test]
    fn store_holds_exactly_the_placement_rows_bit_identically() {
        let n = 64;
        let dim = 4;
        let feats = toy_features(n, dim);
        let part = modulo_part(n, 4);
        let topo = Topology::p3_8xlarge(1.0);
        let ranking: Vec<u64> = (0..n as u64).map(|v| n as u64 - v).collect();
        let placement = FeatureCache::partitioned(&ranking, 8, &part);
        let store = CacheStore::build(&placement, &feats);
        let mut host_row = vec![0f32; dim];
        for v in 0..n as Vid {
            for d in 0..4u16 {
                match store.row(d, v) {
                    Some(row) => {
                        assert!(placement.is_cached_on(v, d), "spurious resident row {v}@{d}");
                        feats.copy_row(v, &mut host_row);
                        assert_eq!(row, &host_row[..], "cached row must be a bit-exact copy");
                    }
                    None => assert!(!placement.is_cached_on(v, d), "missing resident row {v}@{d}"),
                }
            }
        }
        for d in 0..4u16 {
            assert_eq!(store.rows_on(d) as u64, placement.rows_on(d));
            assert_eq!(store.bytes_on(d), placement.rows_on(d) * (dim as u64 * 4));
        }
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in [CachePolicy::None, CachePolicy::Distributed, CachePolicy::Partitioned] {
            assert_eq!(CachePolicy::parse(p.name()).unwrap(), p);
        }
        assert!(CachePolicy::parse("quiver").is_err());
    }

    #[test]
    fn resident_cache_serves_local_and_classifies() {
        let n = 32;
        let feats = toy_features(n, 2);
        let part = modulo_part(n, 4);
        let topo = Topology::p3_8xlarge(1.0);
        let ranking: Vec<u64> = vec![1; n];
        let rc =
            ResidentCache::build(CachePolicy::Partitioned, &ranking, 4, &part, &topo, &feats);
        let mut local = 0;
        for v in 0..n as Vid {
            let owner = part.device_of(v);
            match rc.fetch_source(v, owner) {
                FetchSource::Local => {
                    assert!(rc.resident_row(owner, v).is_some());
                    local += 1;
                }
                FetchSource::Host => assert!(rc.resident_row(owner, v).is_none()),
                FetchSource::Peer(_) => {
                    panic!("partitioned cache never serves the owner from a peer")
                }
            }
        }
        assert_eq!(local, 16, "4 devices × 4-row budget");
    }

    #[test]
    fn load_stats_merge_and_total() {
        let mut a = LoadStats { local_bytes: 1, peer_bytes: 2, host_bytes: 3, disk_bytes: 4 };
        let b = LoadStats { local_bytes: 10, peer_bytes: 20, host_bytes: 30, disk_bytes: 40 };
        a.merge(&b);
        assert_eq!(
            a,
            LoadStats { local_bytes: 11, peer_bytes: 22, host_bytes: 33, disk_bytes: 44 }
        );
        assert_eq!(a.total(), 110);
    }

    #[test]
    fn load_stats_sum_covers_all_four_tiers() {
        // sum() over per-device stats must equal the element-wise totals —
        // the invariant the four-tier loading split rests on: re-routing
        // bytes between tiers never changes the total.
        let per_device = [
            LoadStats { local_bytes: 5, peer_bytes: 0, host_bytes: 9, disk_bytes: 2 },
            LoadStats { local_bytes: 0, peer_bytes: 7, host_bytes: 0, disk_bytes: 11 },
            LoadStats::default(),
            LoadStats { local_bytes: 1, peer_bytes: 1, host_bytes: 1, disk_bytes: 1 },
        ];
        let s = LoadStats::sum(per_device.iter());
        assert_eq!(
            s,
            LoadStats { local_bytes: 6, peer_bytes: 8, host_bytes: 10, disk_bytes: 14 }
        );
        let tier_sum = s.local_bytes + s.peer_bytes + s.host_bytes + s.disk_bytes;
        assert_eq!(s.total(), tier_sum);
        assert_eq!(s.total(), per_device.iter().map(LoadStats::total).sum::<u64>());
    }
}
