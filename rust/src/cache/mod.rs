//! Static GPU feature caches (paper §2.2, §3, §7.1).
//!
//! Three placement policies, matching the systems compared in the paper:
//!
//! * **None** — DGL: no distributed cache (DGL only caches when everything
//!   fits on a single GPU, which never holds for the evaluated graphs).
//! * **Distributed** — Quiver/GNNLab: the hottest vertices (ranked by
//!   pre-sampling frequency, the criterion of [41] used by both Quiver and
//!   GSplit in §7.1) are *partitioned* across GPUs that share NVLink, and
//!   *replicated* across GPU groups with no direct link (§7.4).
//! * **Partitioned** — GSplit: vertex `v` may be cached **only on the
//!   device `f_G(v)` that owns it**, keeping the cache consistent with the
//!   splits; each device caches its hottest owned vertices.
//!
//! The cache answers one question on the hot path: *from where does device
//! `d` obtain the input features of vertex `v`?* — locally, from an NVLink
//! peer, or from host memory over PCIe.

mod store;

pub use store::{CachePolicy, CacheStore, LoadStats, ResidentCache};

use crate::devices::Topology;
use crate::partition::Partitioning;
use crate::{DeviceId, Vid};

/// Where a feature row is served from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FetchSource {
    /// Cached on the requesting GPU.
    Local,
    /// Cached on an NVLink-connected peer GPU.
    Peer(DeviceId),
    /// Not cached anywhere reachable: host memory over PCIe.
    Host,
}

/// Immutable cache placement: a per-vertex bitmask of devices holding the
/// row (supports replication; `k ≤ 32`).
#[derive(Debug, Clone)]
pub struct FeatureCache {
    mask: Vec<u32>,
    k: usize,
    /// Rows cached per device (for reporting / capacity assertions).
    per_dev_rows: Vec<u64>,
}

impl FeatureCache {
    /// DGL-style: nothing cached.
    pub fn none(num_vertices: usize, k: usize) -> Self {
        FeatureCache { mask: vec![0; num_vertices], k, per_dev_rows: vec![0; k] }
    }

    /// Quiver-style distributed cache. `capacity_rows` is the per-GPU
    /// budget. Hot vertices (by `ranking` weight, descending) are
    /// partitioned round-robin within each NVLink clique and replicated
    /// across cliques.
    pub fn distributed(
        ranking: &[u64],
        capacity_rows: u64,
        topo: &Topology,
    ) -> Self {
        let k = topo.num_gpus();
        assert!(k <= 32);
        let n = ranking.len();
        let mut cache = FeatureCache { mask: vec![0; n], k, per_dev_rows: vec![0; k] };
        let order = ranked_order(ranking);
        let cliques = nvlink_cliques(topo);
        for clique in &cliques {
            // Partition the hottest clique.len()×capacity rows round-robin.
            let mut budget: Vec<u64> = clique.iter().map(|_| capacity_rows).collect();
            let mut slot = 0usize;
            for &v in &order {
                if budget.iter().all(|&b| b == 0) {
                    break;
                }
                // advance to a clique member with remaining budget
                let mut placed = false;
                for _ in 0..clique.len() {
                    let d = clique[slot % clique.len()];
                    let b = &mut budget[slot % clique.len()];
                    slot += 1;
                    if *b > 0 {
                        cache.mask[v as usize] |= 1 << d;
                        cache.per_dev_rows[d as usize] += 1;
                        *b -= 1;
                        placed = true;
                        break;
                    }
                }
                if !placed {
                    break;
                }
            }
        }
        cache
    }

    /// GSplit-style partitioned cache: device `f_G(v)` caches its hottest
    /// owned vertices up to `capacity_rows`.
    pub fn partitioned(
        ranking: &[u64],
        capacity_rows: u64,
        part: &Partitioning,
    ) -> Self {
        let k = part.k;
        assert!(k <= 32);
        let n = ranking.len();
        let mut cache = FeatureCache { mask: vec![0; n], k, per_dev_rows: vec![0; k] };
        let order = ranked_order(ranking);
        let mut budget = vec![capacity_rows; k];
        for &v in &order {
            let d = part.device_of(v) as usize;
            if budget[d] > 0 {
                cache.mask[v as usize] |= 1 << d;
                cache.per_dev_rows[d] += 1;
                budget[d] -= 1;
            }
        }
        cache
    }

    #[inline]
    pub fn is_cached_on(&self, v: Vid, d: DeviceId) -> bool {
        self.mask[v as usize] & (1 << d) != 0
    }

    /// Resolve where device `d` fetches `v` from. Peer fetches require a
    /// direct NVLink (Quiver's constraint, §7.4): a copy held only by a
    /// linkless peer — e.g. across the cube mesh's missing links on the
    /// truncated 5–8 GPU topologies — reports `Host`, never `Peer`, so
    /// this classification always agrees with [`Topology::link`]. Copies
    /// on devices the (possibly truncated) topology doesn't model at all
    /// are ignored for the same reason ([`Topology::has_nvlink`] is total
    /// and never links an unmodeled device).
    #[inline]
    pub fn fetch_source(&self, v: Vid, d: DeviceId, topo: &Topology) -> FetchSource {
        let m = self.mask[v as usize];
        if m == 0 {
            return FetchSource::Host;
        }
        if m & (1 << d) != 0 {
            return FetchSource::Local;
        }
        let mut bits = m;
        while bits != 0 {
            let o = bits.trailing_zeros() as DeviceId;
            bits &= bits - 1;
            if topo.has_nvlink(d, o) {
                return FetchSource::Peer(o);
            }
        }
        FetchSource::Host
    }

    /// Multi-host variant of [`Self::fetch_source`] under the §7.4
    /// replication rule: every host caches the same rows, so a placement
    /// bit for global device `o` means the row is resident on device
    /// `o % gpus_per_host` of **every** host. The replica inside `d`'s
    /// host block is then classified against the topology exactly like
    /// [`Self::fetch_source`] — Local, NVLink peer, or (no direct link)
    /// host memory. With a single host this is identical to
    /// [`Self::fetch_source`].
    pub fn fetch_source_replicated(
        &self,
        v: Vid,
        d: DeviceId,
        topo: &Topology,
        gpus_per_host: usize,
    ) -> FetchSource {
        let g0 = (topo.host_of(d) * gpus_per_host) as DeviceId;
        let mut peer: Option<DeviceId> = None;
        let mut bits = self.mask[v as usize];
        while bits != 0 {
            let o = bits.trailing_zeros() as DeviceId;
            bits &= bits - 1;
            let replica = g0 + o % gpus_per_host as DeviceId;
            if replica == d {
                return FetchSource::Local;
            }
            if peer.is_none() && topo.has_nvlink(d, replica) {
                peer = Some(replica);
            }
        }
        match peer {
            Some(o) => FetchSource::Peer(o),
            None => FetchSource::Host,
        }
    }

    /// Fraction of all vertices cached on ≥1 device.
    pub fn coverage(&self) -> f64 {
        let cached = self.mask.iter().filter(|&&m| m != 0).count();
        cached as f64 / self.mask.len().max(1) as f64
    }

    pub fn rows_on(&self, d: DeviceId) -> u64 {
        self.per_dev_rows[d as usize]
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn num_vertices(&self) -> usize {
        self.mask.len()
    }
}

/// Vertices in descending ranking order (stable: ties by vertex id).
fn ranked_order(ranking: &[u64]) -> Vec<Vid> {
    let mut order: Vec<Vid> = (0..ranking.len() as Vid).collect();
    order.sort_by_key(|&v| std::cmp::Reverse((ranking[v as usize], std::cmp::Reverse(v))));
    order
}

/// Greedy NVLink clique cover: groups of GPUs that are pairwise
/// NVLink-connected. On p3.8xlarge this is one clique of 4; on the
/// p3.16xlarge cube mesh it yields two cliques of 4 (matching Quiver's
/// replication behaviour described in §7.4).
pub fn nvlink_cliques(topo: &Topology) -> Vec<Vec<DeviceId>> {
    let k = topo.num_gpus();
    let mut assigned = vec![false; k];
    let mut cliques = Vec::new();
    for seed in 0..k {
        if assigned[seed] {
            continue;
        }
        let mut clique = vec![seed as DeviceId];
        assigned[seed] = true;
        for cand in (seed + 1)..k {
            if assigned[cand] {
                continue;
            }
            if clique.iter().all(|&m| topo.has_nvlink(m, cand as DeviceId)) {
                clique.push(cand as DeviceId);
                assigned[cand] = true;
            }
        }
        cliques.push(clique);
    }
    cliques
}

/// Per-GPU cache capacity in rows, derived from device memory minus the
/// topology share and a training workspace reserve (the paper configures
/// systems to "maximize the memory available for caching while allocating
/// sufficient memory to sample and train", §7.1).
pub fn cache_capacity_rows(
    gpu_mem: u64,
    feat_bytes_per_row: u64,
    topology_share: u64,
    workspace: u64,
) -> u64 {
    gpu_mem.saturating_sub(topology_share).saturating_sub(workspace) / feat_bytes_per_row.max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{rmat, GenParams};
    use crate::partition::{partition_graph, Strategy};
    use crate::presample::PresampleWeights;

    #[test]
    fn none_cache_always_misses() {
        let topo = Topology::p3_8xlarge(32.0);
        let c = FeatureCache::none(100, 4);
        assert_eq!(c.fetch_source(5, 0, &topo), FetchSource::Host);
        assert_eq!(c.coverage(), 0.0);
    }

    #[test]
    fn distributed_partitions_within_clique() {
        let topo = Topology::p3_8xlarge(32.0);
        let ranking: Vec<u64> = (0..100).map(|v| 100 - v as u64).collect();
        let c = FeatureCache::distributed(&ranking, 10, &topo);
        // 4 GPUs × 10 rows = hottest 40 vertices cached exactly once.
        for v in 0..40u32 {
            let m = (0..4).filter(|&d| c.is_cached_on(v, d)).count();
            assert_eq!(m, 1, "vertex {v} cached {m} times");
        }
        for v in 40..100u32 {
            assert_eq!(c.fetch_source(v, 0, &topo), FetchSource::Host);
        }
        // Any GPU can reach any cached row (all-NVLink host).
        for v in 0..40u32 {
            for d in 0..4u16 {
                assert_ne!(c.fetch_source(v, d, &topo), FetchSource::Host, "v={v} d={d}");
            }
        }
    }

    #[test]
    fn distributed_replicates_across_cliques() {
        let topo = Topology::p3_16xlarge(32.0);
        let cliques = nvlink_cliques(&topo);
        assert_eq!(cliques.len(), 2, "cube mesh should give two 4-cliques: {cliques:?}");
        assert!(cliques.iter().all(|c| c.len() == 4));
        let ranking: Vec<u64> = (0..100).map(|v| 100 - v as u64).collect();
        let c = FeatureCache::distributed(&ranking, 5, &topo);
        // Hottest 20 are cached once per clique = twice total (replication).
        for v in 0..20u32 {
            let copies = (0..8).filter(|&d| c.is_cached_on(v, d)).count();
            assert_eq!(copies, 2, "vertex {v}");
        }
    }

    #[test]
    fn partitioned_cache_respects_ownership() {
        let g = rmat(&GenParams { num_vertices: 1000, num_edges: 4000, seed: 3 });
        let w = PresampleWeights::uniform(&g);
        let mask = vec![false; 1000];
        let p = partition_graph(&g, &w, &mask, Strategy::Edge, 4, 0.1, 5);
        let ranking: Vec<u64> = (0..1000).map(|v| 1000 - v as u64).collect();
        let c = FeatureCache::partitioned(&ranking, 50, &p);
        for v in 0..1000u32 {
            for d in 0..4u16 {
                if c.is_cached_on(v, d) {
                    assert_eq!(p.device_of(v), d, "vertex {v} cached off-owner");
                }
            }
        }
        // Budgets respected.
        for d in 0..4u16 {
            assert!(c.rows_on(d) <= 50);
        }
    }

    #[test]
    fn distributed_replicates_only_across_linkless_groups() {
        // Placement invariant: within one NVLink clique a row is cached at
        // most once (partitioning, not replication); replication happens
        // only between groups that share no direct link.
        for topo in [Topology::p3_8xlarge(32.0), Topology::p3_16xlarge(32.0)] {
            let ranking: Vec<u64> = (0..200).map(|v| 200 - v as u64).collect();
            let c = FeatureCache::distributed(&ranking, 7, &topo);
            let cliques = nvlink_cliques(&topo);
            for v in 0..200u32 {
                for clique in &cliques {
                    let copies =
                        clique.iter().filter(|&&d| c.is_cached_on(v, d)).count();
                    assert!(copies <= 1, "vertex {v} cached {copies}× within one clique");
                }
            }
            // On the all-NVLink 4-GPU host there is a single clique, so no
            // vertex may be replicated at all.
            if topo.num_gpus() == 4 {
                for v in 0..200u32 {
                    let copies = (0..4u16).filter(|&d| c.is_cached_on(v, d)).count();
                    assert!(copies <= 1, "single clique must not replicate (vertex {v})");
                }
            }
        }
    }

    #[test]
    fn distributed_respects_per_device_budget() {
        for gpus in [4usize, 8] {
            let topo = Topology::for_gpus(gpus, 32.0);
            let ranking: Vec<u64> = (0..500).map(|v| 500 - v as u64).collect();
            let budget = 13u64;
            let c = FeatureCache::distributed(&ranking, budget, &topo);
            for d in 0..gpus as u16 {
                assert!(c.rows_on(d) <= budget, "device {d} over budget: {}", c.rows_on(d));
            }
        }
    }

    #[test]
    fn fetch_source_agrees_with_topology_on_truncated_meshes() {
        // Regression (8-GPU cube mesh truncations): a vertex cached only on
        // a peer the topology gives us no NVLink to must resolve to Host —
        // Peer(o) is only ever returned with an actual direct link.
        for gpus in [5usize, 6, 7, 8] {
            let topo = Topology::for_gpus(gpus, 32.0);
            let n = 300usize;
            let ranking: Vec<u64> = (0..n).map(|v| n as u64 - v as u64).collect();
            let c = FeatureCache::distributed(&ranking, 9, &topo);
            for v in 0..n as Vid {
                for d in 0..gpus as DeviceId {
                    match c.fetch_source(v, d, &topo) {
                        FetchSource::Local => assert!(c.is_cached_on(v, d)),
                        FetchSource::Peer(o) => {
                            assert!(c.is_cached_on(v, o), "Peer({o}) not actually cached");
                            assert!(
                                topo.has_nvlink(d, o),
                                "gpus={gpus} v={v}: Peer({o}) reported for d={d} without NVLink"
                            );
                        }
                        FetchSource::Host => {
                            for o in 0..gpus as DeviceId {
                                assert!(
                                    !(c.is_cached_on(v, o) && (o == d || topo.has_nvlink(d, o))),
                                    "gpus={gpus} v={v} d={d}: reachable copy on {o} missed"
                                );
                            }
                        }
                    }
                }
            }
        }
        // Concrete pinned scenario on the 5-GPU truncation: cliques are
        // {0,1,2,3} and {4}; with capacity 1 the 2nd-hottest vertex is
        // cached only on device 1, which device 4 has no NVLink to.
        let t5 = Topology::for_gpus(5, 32.0);
        let ranking: Vec<u64> = (0..8).map(|v| 8 - v as u64).collect();
        let c = FeatureCache::distributed(&ranking, 1, &t5);
        assert!(c.is_cached_on(1, 1) && !c.is_cached_on(1, 4));
        assert_eq!(
            c.fetch_source(1, 4, &t5),
            FetchSource::Host,
            "copy on a linkless peer must fall back to host"
        );
        assert_eq!(c.fetch_source(1, 0, &t5), FetchSource::Peer(1));
    }

    #[test]
    fn replicated_fetch_matches_plain_on_a_single_host() {
        for gpus in [4usize, 6, 8] {
            let topo = Topology::for_gpus(gpus, 32.0);
            let ranking: Vec<u64> = (0..120).map(|v| 120 - v as u64).collect();
            let c = FeatureCache::distributed(&ranking, 7, &topo);
            for v in 0..120 as Vid {
                for d in 0..gpus as DeviceId {
                    assert_eq!(
                        c.fetch_source_replicated(v, d, &topo, gpus),
                        c.fetch_source(v, d, &topo),
                        "gpus={gpus} v={v} d={d}"
                    );
                }
            }
        }
    }

    #[test]
    fn replicated_fetch_maps_copies_into_the_querying_host_block() {
        // 2 hosts × 4 GPUs, ownership-partitioned cache over the global
        // k=8 device set: under §7.4 every host holds the same rows, so a
        // bit for global device `o` resolves within host 1's block.
        let topo = Topology::multi_host(2, 32.0);
        let part = Partitioning {
            assignment: (0..32u32).map(|v| (v % 8) as DeviceId).collect(),
            k: 8,
        };
        let ranking = vec![1u64; 32];
        let c = FeatureCache::partitioned(&ranking, 4, &part);
        // Vertex 2 is owned (and cached) by global device 2; host 1's
        // replica lives on local device 2 = global 6.
        assert_eq!(c.fetch_source_replicated(2, 6, &topo, 4), FetchSource::Local);
        // Host 1's device 5 reaches that replica over the in-host NVLink.
        assert_eq!(c.fetch_source_replicated(2, 5, &topo, 4), FetchSource::Peer(6));
        // An uncached vertex still misses to host memory.
        let none = FeatureCache::none(32, 8);
        assert_eq!(none.fetch_source_replicated(2, 6, &topo, 4), FetchSource::Host);
    }

    #[test]
    fn fetch_source_ignores_copies_outside_the_topology() {
        // A placement built for 8 devices queried under a 4-GPU topology
        // must not classify (or index) devices the topology doesn't model.
        let t8 = Topology::p3_16xlarge(32.0);
        let ranking: Vec<u64> = (0..64).map(|v| 64 - v as u64).collect();
        let c = FeatureCache::distributed(&ranking, 4, &t8);
        let t4 = Topology::p3_8xlarge(32.0);
        for v in 0..64u32 {
            for d in 0..4u16 {
                match c.fetch_source(v, d, &t4) {
                    FetchSource::Peer(o) => assert!((o as usize) < t4.num_gpus()),
                    FetchSource::Local | FetchSource::Host => {}
                }
            }
        }
    }

    #[test]
    fn capacity_rows_math() {
        assert_eq!(cache_capacity_rows(1000, 10, 200, 300), 50);
        assert_eq!(cache_capacity_rows(100, 10, 200, 0), 0, "saturating");
    }

    #[test]
    fn ranked_order_is_descending() {
        let r = vec![5u64, 9, 1, 9];
        let o = ranked_order(&r);
        assert_eq!(o[..2], [1, 3], "ties broken by id");
        assert_eq!(o[2], 0);
        assert_eq!(o[3], 2);
    }
}
