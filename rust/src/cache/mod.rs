//! Static GPU feature caches (paper §2.2, §3, §7.1).
//!
//! Three placement policies, matching the systems compared in the paper:
//!
//! * **None** — DGL: no distributed cache (DGL only caches when everything
//!   fits on a single GPU, which never holds for the evaluated graphs).
//! * **Distributed** — Quiver/GNNLab: the hottest vertices (ranked by
//!   pre-sampling frequency, the criterion of [41] used by both Quiver and
//!   GSplit in §7.1) are *partitioned* across GPUs that share NVLink, and
//!   *replicated* across GPU groups with no direct link (§7.4).
//! * **Partitioned** — GSplit: vertex `v` may be cached **only on the
//!   device `f_G(v)` that owns it**, keeping the cache consistent with the
//!   splits; each device caches its hottest owned vertices.
//!
//! The cache answers one question on the hot path: *from where does device
//! `d` obtain the input features of vertex `v`?* — locally, from an NVLink
//! peer, or from host memory over PCIe.

use crate::devices::Topology;
use crate::partition::Partitioning;
use crate::{DeviceId, Vid};

/// Where a feature row is served from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FetchSource {
    /// Cached on the requesting GPU.
    Local,
    /// Cached on an NVLink-connected peer GPU.
    Peer(DeviceId),
    /// Not cached anywhere reachable: host memory over PCIe.
    Host,
}

/// Immutable cache placement: a per-vertex bitmask of devices holding the
/// row (supports replication; `k ≤ 32`).
#[derive(Debug, Clone)]
pub struct FeatureCache {
    mask: Vec<u32>,
    k: usize,
    /// Rows cached per device (for reporting / capacity assertions).
    per_dev_rows: Vec<u64>,
}

impl FeatureCache {
    /// DGL-style: nothing cached.
    pub fn none(num_vertices: usize, k: usize) -> Self {
        FeatureCache { mask: vec![0; num_vertices], k, per_dev_rows: vec![0; k] }
    }

    /// Quiver-style distributed cache. `capacity_rows` is the per-GPU
    /// budget. Hot vertices (by `ranking` weight, descending) are
    /// partitioned round-robin within each NVLink clique and replicated
    /// across cliques.
    pub fn distributed(
        ranking: &[u64],
        capacity_rows: u64,
        topo: &Topology,
    ) -> Self {
        let k = topo.num_gpus();
        assert!(k <= 32);
        let n = ranking.len();
        let mut cache = FeatureCache { mask: vec![0; n], k, per_dev_rows: vec![0; k] };
        let order = ranked_order(ranking);
        let cliques = nvlink_cliques(topo);
        for clique in &cliques {
            // Partition the hottest clique.len()×capacity rows round-robin.
            let mut budget: Vec<u64> = clique.iter().map(|_| capacity_rows).collect();
            let mut slot = 0usize;
            for &v in &order {
                if budget.iter().all(|&b| b == 0) {
                    break;
                }
                // advance to a clique member with remaining budget
                let mut placed = false;
                for _ in 0..clique.len() {
                    let d = clique[slot % clique.len()];
                    let b = &mut budget[slot % clique.len()];
                    slot += 1;
                    if *b > 0 {
                        cache.mask[v as usize] |= 1 << d;
                        cache.per_dev_rows[d as usize] += 1;
                        *b -= 1;
                        placed = true;
                        break;
                    }
                }
                if !placed {
                    break;
                }
            }
        }
        cache
    }

    /// GSplit-style partitioned cache: device `f_G(v)` caches its hottest
    /// owned vertices up to `capacity_rows`.
    pub fn partitioned(
        ranking: &[u64],
        capacity_rows: u64,
        part: &Partitioning,
    ) -> Self {
        let k = part.k;
        assert!(k <= 32);
        let n = ranking.len();
        let mut cache = FeatureCache { mask: vec![0; n], k, per_dev_rows: vec![0; k] };
        let order = ranked_order(ranking);
        let mut budget = vec![capacity_rows; k];
        for &v in &order {
            let d = part.device_of(v) as usize;
            if budget[d] > 0 {
                cache.mask[v as usize] |= 1 << d;
                cache.per_dev_rows[d] += 1;
                budget[d] -= 1;
            }
        }
        cache
    }

    #[inline]
    pub fn is_cached_on(&self, v: Vid, d: DeviceId) -> bool {
        self.mask[v as usize] & (1 << d) != 0
    }

    /// Resolve where device `d` fetches `v` from. Peer fetches require a
    /// direct NVLink (Quiver's constraint, §7.4).
    #[inline]
    pub fn fetch_source(&self, v: Vid, d: DeviceId, topo: &Topology) -> FetchSource {
        let m = self.mask[v as usize];
        if m == 0 {
            return FetchSource::Host;
        }
        if m & (1 << d) != 0 {
            return FetchSource::Local;
        }
        let mut bits = m;
        while bits != 0 {
            let o = bits.trailing_zeros() as DeviceId;
            bits &= bits - 1;
            if topo.has_nvlink(d, o) {
                return FetchSource::Peer(o);
            }
        }
        FetchSource::Host
    }

    /// Fraction of all vertices cached on ≥1 device.
    pub fn coverage(&self) -> f64 {
        let cached = self.mask.iter().filter(|&&m| m != 0).count();
        cached as f64 / self.mask.len().max(1) as f64
    }

    pub fn rows_on(&self, d: DeviceId) -> u64 {
        self.per_dev_rows[d as usize]
    }

    pub fn k(&self) -> usize {
        self.k
    }
}

/// Vertices in descending ranking order (stable: ties by vertex id).
fn ranked_order(ranking: &[u64]) -> Vec<Vid> {
    let mut order: Vec<Vid> = (0..ranking.len() as Vid).collect();
    order.sort_by_key(|&v| std::cmp::Reverse((ranking[v as usize], std::cmp::Reverse(v))));
    order
}

/// Greedy NVLink clique cover: groups of GPUs that are pairwise
/// NVLink-connected. On p3.8xlarge this is one clique of 4; on the
/// p3.16xlarge cube mesh it yields two cliques of 4 (matching Quiver's
/// replication behaviour described in §7.4).
pub fn nvlink_cliques(topo: &Topology) -> Vec<Vec<DeviceId>> {
    let k = topo.num_gpus();
    let mut assigned = vec![false; k];
    let mut cliques = Vec::new();
    for seed in 0..k {
        if assigned[seed] {
            continue;
        }
        let mut clique = vec![seed as DeviceId];
        assigned[seed] = true;
        for cand in (seed + 1)..k {
            if assigned[cand] {
                continue;
            }
            if clique.iter().all(|&m| topo.has_nvlink(m, cand as DeviceId)) {
                clique.push(cand as DeviceId);
                assigned[cand] = true;
            }
        }
        cliques.push(clique);
    }
    cliques
}

/// Per-GPU cache capacity in rows, derived from device memory minus the
/// topology share and a training workspace reserve (the paper configures
/// systems to "maximize the memory available for caching while allocating
/// sufficient memory to sample and train", §7.1).
pub fn cache_capacity_rows(
    gpu_mem: u64,
    feat_bytes_per_row: u64,
    topology_share: u64,
    workspace: u64,
) -> u64 {
    gpu_mem.saturating_sub(topology_share).saturating_sub(workspace) / feat_bytes_per_row.max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{rmat, GenParams};
    use crate::partition::{partition_graph, Strategy};
    use crate::presample::PresampleWeights;

    #[test]
    fn none_cache_always_misses() {
        let topo = Topology::p3_8xlarge(32.0);
        let c = FeatureCache::none(100, 4);
        assert_eq!(c.fetch_source(5, 0, &topo), FetchSource::Host);
        assert_eq!(c.coverage(), 0.0);
    }

    #[test]
    fn distributed_partitions_within_clique() {
        let topo = Topology::p3_8xlarge(32.0);
        let ranking: Vec<u64> = (0..100).map(|v| 100 - v as u64).collect();
        let c = FeatureCache::distributed(&ranking, 10, &topo);
        // 4 GPUs × 10 rows = hottest 40 vertices cached exactly once.
        for v in 0..40u32 {
            let m = (0..4).filter(|&d| c.is_cached_on(v, d)).count();
            assert_eq!(m, 1, "vertex {v} cached {m} times");
        }
        for v in 40..100u32 {
            assert_eq!(c.fetch_source(v, 0, &topo), FetchSource::Host);
        }
        // Any GPU can reach any cached row (all-NVLink host).
        for v in 0..40u32 {
            for d in 0..4u16 {
                assert_ne!(c.fetch_source(v, d, &topo), FetchSource::Host, "v={v} d={d}");
            }
        }
    }

    #[test]
    fn distributed_replicates_across_cliques() {
        let topo = Topology::p3_16xlarge(32.0);
        let cliques = nvlink_cliques(&topo);
        assert_eq!(cliques.len(), 2, "cube mesh should give two 4-cliques: {cliques:?}");
        assert!(cliques.iter().all(|c| c.len() == 4));
        let ranking: Vec<u64> = (0..100).map(|v| 100 - v as u64).collect();
        let c = FeatureCache::distributed(&ranking, 5, &topo);
        // Hottest 20 are cached once per clique = twice total (replication).
        for v in 0..20u32 {
            let copies = (0..8).filter(|&d| c.is_cached_on(v, d)).count();
            assert_eq!(copies, 2, "vertex {v}");
        }
    }

    #[test]
    fn partitioned_cache_respects_ownership() {
        let g = rmat(&GenParams { num_vertices: 1000, num_edges: 4000, seed: 3 });
        let w = PresampleWeights::uniform(&g);
        let mask = vec![false; 1000];
        let p = partition_graph(&g, &w, &mask, Strategy::Edge, 4, 0.1, 5);
        let ranking: Vec<u64> = (0..1000).map(|v| 1000 - v as u64).collect();
        let c = FeatureCache::partitioned(&ranking, 50, &p);
        for v in 0..1000u32 {
            for d in 0..4u16 {
                if c.is_cached_on(v, d) {
                    assert_eq!(p.device_of(v), d, "vertex {v} cached off-owner");
                }
            }
        }
        // Budgets respected.
        for d in 0..4u16 {
            assert!(c.rows_on(d) <= 50);
        }
    }

    #[test]
    fn capacity_rows_math() {
        assert_eq!(cache_capacity_rows(1000, 10, 200, 300), 50);
        assert_eq!(cache_capacity_rows(100, 10, 200, 0), 0, "saturating");
    }

    #[test]
    fn ranked_order_is_descending() {
        let r = vec![5u64, 9, 1, 9];
        let o = ranked_order(&r);
        assert_eq!(o[..2], [1, 3], "ties broken by id");
        assert_eq!(o[2], 0);
        assert_eq!(o[3], 2);
    }
}
