//! Offline pre-sampling (paper §5, "Finding the global partitioning
//! function", first stage).
//!
//! Runs the *same* sampling algorithm used during training for a fixed
//! number of epochs and accumulates, for every vertex `v`, the count `k_v`
//! of times it appears at a layer l > 0 (i.e. as a destination of any
//! sampled layer), and for every CSR edge slot `e`, the count `k_e` of
//! times that edge is sampled. The weights `w_V(v) = k_v / N` and
//! `w_E(e) = k_e / N` turn the input graph into the weighted graph `G_w`
//! that the min-edge-cut partitioner consumes; by the law-of-large-numbers
//! argument in the paper's Analysis, partitioning `G_w` minimizes the
//! *expected* shuffle volume and balances the *expected* per-split load of
//! a random mini-batch.

use crate::graph::CsrGraph;
use crate::rng::{derive_seed, Pcg32};
use crate::sampling::Sampler;
use crate::Vid;

/// Accumulated pre-sampling statistics (raw counts; weights are counts / N,
/// but the partitioner is scale-invariant so we keep integers).
#[derive(Debug, Clone)]
pub struct PresampleWeights {
    /// `k_v` per vertex: appearances as a layer-(l>0) destination.
    pub vertex: Vec<u64>,
    /// `k_e` per CSR edge slot (directed dst→src sampling events).
    pub edge: Vec<u32>,
    /// Number of pre-sampling epochs that produced these counts.
    pub epochs: usize,
}

impl PresampleWeights {
    pub fn uniform(g: &CsrGraph) -> Self {
        PresampleWeights {
            vertex: vec![1; g.num_vertices()],
            edge: vec![1; g.num_edges()],
            epochs: 0,
        }
    }
}

/// Configuration for the pre-sampling stage.
#[derive(Debug, Clone)]
pub struct PresampleConfig {
    pub epochs: usize,
    pub batch_size: usize,
    pub fanouts: Vec<usize>,
    pub seed: u64,
}

/// Run pre-sampling: `epochs` passes over the training targets, sampling
/// mini-batches exactly as the trainer does and accumulating visit counts.
///
/// Multi-threaded: epochs × batches are sharded over worker threads, each
/// with a deterministic RNG stream (results are independent of the thread
/// count).
pub fn presample(
    g: &CsrGraph,
    train_targets: &[Vid],
    cfg: &PresampleConfig,
) -> PresampleWeights {
    let num_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16);
    // Work items: (epoch, batch_index, target range).
    let mut batches: Vec<(usize, usize)> = Vec::new();
    let iters = train_targets.len().div_ceil(cfg.batch_size).max(1);
    for e in 0..cfg.epochs {
        for b in 0..iters {
            batches.push((e, b));
        }
    }
    let vertex_len = g.num_vertices();
    let edge_len = g.num_edges();

    let partials: Vec<(Vec<u64>, Vec<u32>)> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for t in 0..num_threads {
            let batches = &batches;
            let handle = scope.spawn(move || {
                let mut vw = vec![0u64; vertex_len];
                let mut ew = vec![0u32; edge_len];
                let mut sampler = Sampler::new();
                let mut scratch = Vec::new();
                for &(epoch, batch) in batches.iter().skip(t).step_by(num_threads) {
                    // Epoch target permutation must match the trainer's.
                    let mut targets = train_targets.to_vec();
                    let mut erng = Pcg32::new(derive_seed(cfg.seed, &[epoch as u64]));
                    erng.shuffle(&mut targets);
                    let lo = batch * cfg.batch_size;
                    let hi = (lo + cfg.batch_size).min(targets.len());
                    let mut brng = Pcg32::new(derive_seed(
                        cfg.seed,
                        &[epoch as u64, batch as u64, 0xbeef],
                    ));
                    accumulate_batch(
                        g,
                        &targets[lo..hi],
                        &cfg.fanouts,
                        &mut sampler,
                        &mut brng,
                        &mut vw,
                        &mut ew,
                        &mut scratch,
                    );
                }
                (vw, ew)
            });
            handles.push(handle);
        }
        handles.into_iter().map(|h| h.join().expect("presample worker panicked")).collect()
    });

    let mut vertex = vec![0u64; vertex_len];
    let mut edge = vec![0u32; edge_len];
    for (vw, ew) in partials {
        for (a, b) in vertex.iter_mut().zip(&vw) {
            *a += b;
        }
        for (a, b) in edge.iter_mut().zip(&ew) {
            *a += b;
        }
    }
    PresampleWeights { vertex, edge, epochs: cfg.epochs }
}

/// Sample one mini-batch and accumulate its visit counts.
#[allow(clippy::too_many_arguments)]
fn accumulate_batch(
    g: &CsrGraph,
    targets: &[Vid],
    fanouts: &[usize],
    sampler: &mut Sampler,
    rng: &mut Pcg32,
    vw: &mut [u64],
    ew: &mut [u32],
    scratch: &mut Vec<u32>,
) {
    let _ = scratch;
    let mb = sampler.sample(g, targets, fanouts, rng);
    for layer in &mb.layers {
        for (i, &d) in layer.dst.iter().enumerate() {
            // Destination of a sampled layer ⇒ k_v event (layer l > 0 in
            // the paper's bottom-up numbering: every dst set is at l > 0).
            vw[d as usize] += 1;
            // Every sampled edge ⇒ k_e event. The local neighbor index j
            // refers to layer.src; we need the CSR slot of (d → src[j]).
            // Recover it by scanning d's (sorted) adjacency with binary
            // search — O(log deg) per edge, done offline.
            let nbrs = g.neighbors(d);
            for &j in layer.neighbors_of(i) {
                let u = layer.src[j as usize];
                if let Ok(pos) = nbrs.binary_search(&u) {
                    ew[g.edge_id(d, pos as u32) as usize] += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{rmat, GenParams};

    fn setup() -> (CsrGraph, Vec<Vid>) {
        let g = rmat(&GenParams { num_vertices: 2048, num_edges: 16384, seed: 21 });
        let targets: Vec<Vid> = (0..512).collect();
        (g, targets)
    }

    #[test]
    fn counts_are_positive_and_bounded() {
        let (g, targets) = setup();
        let cfg = PresampleConfig { epochs: 3, batch_size: 128, fanouts: vec![5, 5], seed: 7 };
        let w = presample(&g, &targets, &cfg);
        // Every target appears as a top-layer dst exactly once per epoch,
        // so its count is at least epochs.
        for &t in &targets {
            assert!(w.vertex[t as usize] >= cfg.epochs as u64, "target {t}");
        }
        // Total edge count equals what the sampler reports.
        let total_e: u64 = w.edge.iter().map(|&x| x as u64).sum();
        assert!(total_e > 0);
        // fanout bounds: per epoch each target row samples ≤ 5 + 5·(≤6 srcs)…
        // just sanity-bound total: epochs × batch × (5 + 30·5)
        let bound = cfg.epochs as u64 * targets.len() as u64 * (5 + 6 * 5) as u64;
        assert!(total_e <= bound, "total_e={total_e} bound={bound}");
    }

    #[test]
    fn deterministic_across_thread_schedules() {
        let (g, targets) = setup();
        let cfg = PresampleConfig { epochs: 2, batch_size: 64, fanouts: vec![4, 4], seed: 11 };
        let a = presample(&g, &targets, &cfg);
        let b = presample(&g, &targets, &cfg);
        assert_eq!(a.vertex, b.vertex);
        assert_eq!(a.edge, b.edge);
    }

    #[test]
    fn more_epochs_more_counts() {
        let (g, targets) = setup();
        let mk = |e| PresampleConfig { epochs: e, batch_size: 128, fanouts: vec![5], seed: 3 };
        let w1 = presample(&g, &targets, &mk(1));
        let w4 = presample(&g, &targets, &mk(4));
        let s1: u64 = w1.vertex.iter().sum();
        let s4: u64 = w4.vertex.iter().sum();
        assert!(s4 > 3 * s1, "s1={s1} s4={s4}");
    }

    #[test]
    fn uniform_weights_shape() {
        let (g, _) = setup();
        let w = PresampleWeights::uniform(&g);
        assert_eq!(w.vertex.len(), g.num_vertices());
        assert_eq!(w.edge.len(), g.num_edges());
    }
}
