//! GNN model configuration, FLOP accounting, and the parameter store used
//! by the real (PJRT) training path.
//!
//! The models match the paper's evaluation (§7.1): **GraphSage** (mean
//! aggregator) and **GAT** (single-head attention; the paper's GAT hidden
//! size counts the concatenated output). Layer compute itself lives in the
//! AOT-compiled HLO (L2/L1); this module owns shapes, parameter tensors,
//! initialization, and the SGD step applied after gradient all-reduce.

use crate::rng::Pcg32;

/// Which GNN architecture.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GnnKind {
    GraphSage,
    Gat,
}

impl GnnKind {
    pub fn name(&self) -> &'static str {
        match self {
            GnnKind::GraphSage => "GraphSage",
            GnnKind::Gat => "GAT",
        }
    }
}

/// Full model shape description.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub kind: GnnKind,
    pub feat_dim: usize,
    pub hidden: usize,
    pub num_classes: usize,
    pub num_layers: usize,
}

impl ModelConfig {
    /// Input dim of layer `l` (0 = bottom).
    pub fn in_dim(&self, l: usize) -> usize {
        if l == 0 {
            self.feat_dim
        } else {
            self.hidden
        }
    }

    /// Output dim of layer `l`.
    pub fn out_dim(&self, l: usize) -> usize {
        if l + 1 == self.num_layers {
            self.num_classes
        } else {
            self.hidden
        }
    }

    /// Forward FLOPs to compute `num_dst` outputs of layer `l` from
    /// `num_edges` aggregated neighbors.
    ///
    /// GraphSage: two dense transforms per dst (self + aggregated
    /// neighbor), aggregation itself is bandwidth-bound (counted in
    /// `agg_bytes`, not FLOPs).
    /// GAT: one dense transform per dst plus per-edge attention scoring
    /// (2·out dot products) and per-edge weighted accumulation.
    pub fn layer_fwd_flops(&self, l: usize, num_dst: u64, num_edges: u64) -> u64 {
        let din = self.in_dim(l) as u64;
        let dout = self.out_dim(l) as u64;
        match self.kind {
            GnnKind::GraphSage => num_dst * 2 * (2 * din * dout),
            GnnKind::Gat => {
                let dense = num_dst * 2 * din * dout;
                let attn = num_edges * (4 * dout + 8);
                let accum = num_edges * 2 * dout;
                dense + attn + accum
            }
        }
    }

    /// Irregular memory traffic (bytes) of aggregating `num_edges`
    /// neighbors of width `in_dim(l)` plus writing `num_dst` outputs —
    /// the gather/scatter part of the layer that the MXU cannot help with.
    pub fn layer_agg_bytes(&self, l: usize, num_dst: u64, num_edges: u64) -> u64 {
        let din = self.in_dim(l) as u64 * 4;
        let dout = self.out_dim(l) as u64 * 4;
        // GAT touches each edge twice (score pass + weighted-sum pass).
        let passes = match self.kind {
            GnnKind::GraphSage => 1,
            GnnKind::Gat => 2,
        };
        num_edges * din * passes + num_dst * (din + dout)
    }

    /// Bytes of one hidden row *entering* layer `l` (what a training
    /// shuffle moves at that layer boundary).
    pub fn row_bytes_in(&self, l: usize) -> u64 {
        self.in_dim(l) as u64 * 4
    }
}

/// One layer's parameters, stored as flat row-major f32 tensors.
#[derive(Debug, Clone)]
pub struct LayerParams {
    /// GraphSage: `[w_self (din×dout), w_neigh (din×dout), bias (dout)]`.
    /// GAT: `[w (din×dout), a_src (dout), a_dst (dout), bias (dout)]`.
    pub tensors: Vec<Vec<f32>>,
    pub shapes: Vec<(usize, usize)>,
}

/// All model parameters (replicated on every device; gradients are
/// all-reduced before the update, matching synchronous data/split
/// parallel training).
#[derive(Debug, Clone)]
pub struct ParamStore {
    pub cfg: ModelConfig,
    pub layers: Vec<LayerParams>,
}

impl ParamStore {
    /// Xavier/Glorot-uniform init, deterministic per seed.
    pub fn init(cfg: &ModelConfig, seed: u64) -> Self {
        let mut rng = Pcg32::new(seed);
        let mut layers = Vec::with_capacity(cfg.num_layers);
        for l in 0..cfg.num_layers {
            let (din, dout) = (cfg.in_dim(l), cfg.out_dim(l));
            let mut tensors = Vec::new();
            let mut shapes = Vec::new();
            let mat = |r: usize, c: usize, rng: &mut Pcg32| {
                let bound = (6.0 / (r + c) as f64).sqrt() as f32;
                let t: Vec<f32> =
                    (0..r * c).map(|_| (rng.next_f32() * 2.0 - 1.0) * bound).collect();
                (t, (r, c))
            };
            match cfg.kind {
                GnnKind::GraphSage => {
                    for _ in 0..2 {
                        let (t, s) = mat(din, dout, &mut rng);
                        tensors.push(t);
                        shapes.push(s);
                    }
                    tensors.push(vec![0.0; dout]);
                    shapes.push((1, dout));
                }
                GnnKind::Gat => {
                    let (t, s) = mat(din, dout, &mut rng);
                    tensors.push(t);
                    shapes.push(s);
                    for _ in 0..2 {
                        let (t, s) = mat(1, dout, &mut rng);
                        tensors.push(t);
                        shapes.push(s);
                    }
                    tensors.push(vec![0.0; dout]);
                    shapes.push((1, dout));
                }
            }
            layers.push(LayerParams { tensors, shapes });
        }
        ParamStore { cfg: cfg.clone(), layers }
    }

    pub fn num_params(&self) -> usize {
        self.layers.iter().flat_map(|l| l.tensors.iter()).map(Vec::len).sum()
    }

    /// SGD step: `p -= lr * g` over flat gradients laid out layer by
    /// layer, tensor by tensor (the gradient layout the runtime produces).
    pub fn sgd_step(&mut self, grads: &[Vec<Vec<f32>>], lr: f32) {
        assert_eq!(grads.len(), self.layers.len());
        for (layer, glayer) in self.layers.iter_mut().zip(grads) {
            assert_eq!(layer.tensors.len(), glayer.len());
            for (t, g) in layer.tensors.iter_mut().zip(glayer) {
                assert_eq!(t.len(), g.len());
                for (p, gv) in t.iter_mut().zip(g) {
                    *p -= lr * gv;
                }
            }
        }
    }

    /// Average several replicas' gradients (the all-reduce of synchronous
    /// multi-GPU training, simulated).
    pub fn allreduce_mean(replica_grads: &[Vec<Vec<Vec<f32>>>]) -> Vec<Vec<Vec<f32>>> {
        assert!(!replica_grads.is_empty());
        let mut out = replica_grads[0].clone();
        let n = replica_grads.len() as f32;
        for rep in &replica_grads[1..] {
            for (ol, rl) in out.iter_mut().zip(rep) {
                for (ot, rt) in ol.iter_mut().zip(rl) {
                    for (o, r) in ot.iter_mut().zip(rt) {
                        *o += r;
                    }
                }
            }
        }
        for l in &mut out {
            for t in l {
                for v in t {
                    *v /= n;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(kind: GnnKind) -> ModelConfig {
        ModelConfig { kind, feat_dim: 32, hidden: 16, num_classes: 4, num_layers: 3 }
    }

    #[test]
    fn dims_chain() {
        let c = cfg(GnnKind::GraphSage);
        assert_eq!(c.in_dim(0), 32);
        assert_eq!(c.out_dim(0), 16);
        assert_eq!(c.in_dim(1), 16);
        assert_eq!(c.out_dim(2), 4);
    }

    #[test]
    fn gat_costs_more_per_edge() {
        let s = cfg(GnnKind::GraphSage);
        let g = cfg(GnnKind::Gat);
        let (d, e) = (1000, 15000);
        assert!(g.layer_fwd_flops(1, d, e) > s.layer_fwd_flops(1, d, e) / 2);
        assert!(g.layer_agg_bytes(1, d, e) > s.layer_agg_bytes(1, d, e));
        // FLOPs grow with edges for GAT but not for Sage.
        assert_eq!(s.layer_fwd_flops(1, d, e), s.layer_fwd_flops(1, d, 2 * e));
        assert!(g.layer_fwd_flops(1, d, 2 * e) > g.layer_fwd_flops(1, d, e));
    }

    #[test]
    fn param_store_shapes() {
        let c = cfg(GnnKind::GraphSage);
        let p = ParamStore::init(&c, 1);
        assert_eq!(p.layers.len(), 3);
        assert_eq!(p.layers[0].shapes[0], (32, 16));
        assert_eq!(p.layers[2].shapes[1], (16, 4));
        // Deterministic init.
        let p2 = ParamStore::init(&c, 1);
        assert_eq!(p.layers[1].tensors[0], p2.layers[1].tensors[0]);
        let p3 = ParamStore::init(&c, 2);
        assert_ne!(p.layers[1].tensors[0], p3.layers[1].tensors[0]);
    }

    #[test]
    fn gat_param_layout() {
        let c = cfg(GnnKind::Gat);
        let p = ParamStore::init(&c, 3);
        assert_eq!(p.layers[0].tensors.len(), 4);
        assert_eq!(p.layers[0].shapes, vec![(32, 16), (1, 16), (1, 16), (1, 16)]);
    }

    #[test]
    fn sgd_and_allreduce() {
        let c = ModelConfig {
            kind: GnnKind::GraphSage,
            feat_dim: 2,
            hidden: 2,
            num_classes: 2,
            num_layers: 1,
        };
        let mut p = ParamStore::init(&c, 1);
        let before = p.layers[0].tensors[0].clone();
        let ones: Vec<Vec<Vec<f32>>> = vec![p
            .layers[0]
            .tensors
            .iter()
            .map(|t| vec![1.0; t.len()])
            .collect()];
        let threes: Vec<Vec<Vec<f32>>> = vec![p
            .layers[0]
            .tensors
            .iter()
            .map(|t| vec![3.0; t.len()])
            .collect()];
        let avg = ParamStore::allreduce_mean(&[ones.clone(), threes]);
        assert!(avg[0][0].iter().all(|&v| (v - 2.0).abs() < 1e-6));
        p.sgd_step(&avg, 0.1);
        for (a, b) in p.layers[0].tensors[0].iter().zip(&before) {
            assert!((a - (b - 0.2)).abs() < 1e-6);
        }
    }
}
