//! # GSplit — split-parallel mini-batch GNN training
//!
//! Reproduction of *"GSplit: Scaling Graph Neural Network Training on Large
//! Graphs via Split-Parallelism"* (Polisetty et al., 2023) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the coordinator: cooperative split-parallel
//!   sampling, the online splitting algorithm with its offline pre-sampling +
//!   weighted min-edge-cut partitioning stages, feature caches, a simulated
//!   multi-GPU/multi-host device topology with a calibrated transfer cost
//!   model, and five training engines (DGL-like data parallel, Quiver-like
//!   cached data parallel, P3*-like push-pull, CAGNET-style 1D full-graph,
//!   and GSplit split parallel).
//! * **runtime** — the numeric [`Backend`](crate::runtime::Backend)
//!   abstraction behind the trainer. The default build uses the pure-Rust
//!   [`NativeBackend`](crate::runtime::NativeBackend) (GraphSage/GAT
//!   forward + backward and the softmax-CE loss head, validated against
//!   the JAX references), so a fresh clone builds, trains, and tests with
//!   zero external artifacts.
//! * **L2/L1 (python/, optional, build time only)** — JAX GraphSage/GAT
//!   layers over Pallas gather/attention kernels, AOT-lowered to HLO text
//!   and executed through PJRT when the crate is built with
//!   `--features pjrt`; Python is never on the training hot path.
//!
//! See `README.md` for the architecture map and experiment index.

// The pre-`TrainConfig` setters survive only as deprecated shims for
// downstream callers; nothing inside the crate may use them.
#![deny(deprecated)]

pub mod bench_harness;
pub mod cache;
pub mod cli;
pub mod collectives;
pub mod config;
pub mod costmodel;
pub mod devices;
pub mod exec;
pub mod graph;
pub mod model;
pub mod obs;
pub mod partition;
pub mod presample;
pub mod rng;
pub mod runtime;
pub mod sampling;
pub mod serving;
pub mod split;
pub mod testing;
pub mod train;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Vertex identifier. Graphs in this crate are bounded by `u32::MAX` vertices
/// (the paper's largest graph, Papers100M, has 111M vertices — comfortably
/// within range; our scaled stand-ins are far smaller).
pub type Vid = u32;

/// Edge index into a CSR adjacency array.
pub type Eid = u64;

/// Device (simulated GPU) identifier.
pub type DeviceId = u16;
