//! Simulated multi-GPU / multi-host device topology.
//!
//! The paper's testbed is AWS p3.8xlarge (4× V100 16GB, all-to-all NVLink,
//! PCIe 3.0×16 to the host) and p3.16xlarge (8× V100, NVLink hybrid cube
//! mesh where **not all GPU pairs are directly connected** — the property
//! Quiver's cache replication reacts to in §7.4). We model devices, links,
//! and bandwidths; the engines run the real data-movement logic over this
//! topology and the cost model converts byte/edge counts into seconds.
//!
//! GPU memory is scaled down by the dataset's `scale_divisor` so cache-fit
//! fractions match the paper (DESIGN.md §3).

use crate::DeviceId;

/// Kind of interconnect between two endpoints.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkKind {
    /// Direct GPU↔GPU NVLink.
    NvLink,
    /// Through host memory over PCIe (also used for host→GPU feature loads).
    PcieHost,
    /// Cross-host network (multi-host experiments).
    Network,
    /// Same device (free).
    Local,
}

/// Hardware constants (bandwidths in bytes/second, latencies in seconds).
///
/// Effective (achievable) numbers for the paper's testbed, not peaks:
/// PCIe 3.0×16 ≈ 12.8 GB/s, NVLink (V100 gen2, per direction, after
/// protocol overhead) ≈ 44 GB/s, 25 Gbit EC2 networking ≈ 2.4 GB/s.
#[derive(Debug, Clone)]
pub struct HardwareModel {
    pub pcie_bw: f64,
    pub nvlink_bw: f64,
    pub network_bw: f64,
    pub pcie_lat: f64,
    pub nvlink_lat: f64,
    pub network_lat: f64,
    /// Sequential read bandwidth of the host's local NVMe SSD — the tier
    /// out-of-core feature rows fall through to when they miss the chunk
    /// buffer (DESIGN.md §Loading).
    pub disk_bw: f64,
    pub disk_lat: f64,
    /// Effective GPU FLOP/s for dense f32 GNN layer compute. V100 peak is
    /// 15.7 TFLOP/s; sparse-aggregation-heavy GNN kernels achieve a small
    /// fraction — calibrated so DGL's FB times land in the paper's range.
    pub gpu_flops: f64,
    /// Effective GPU memory bandwidth (bytes/s) for the irregular gather /
    /// aggregation portions (V100 HBM2 900 GB/s peak, ~60% achievable).
    pub gpu_membw: f64,
    /// Host-side per-sampled-edge cost for CPU work that accompanies GPU
    /// sampling (batching, index assembly) — calibrated, seconds/edge.
    pub sample_edge_cost: f64,
    /// GPU memory per device in bytes (scaled by dataset divisor).
    pub gpu_mem: u64,
}

impl HardwareModel {
    /// V100 p3.8xlarge/p3.16xlarge constants, with GPU memory divided by
    /// `scale_divisor` to preserve cache-fit fractions on scaled datasets.
    pub fn v100(scale_divisor: f64) -> Self {
        HardwareModel {
            pcie_bw: 12.8e9,
            nvlink_bw: 44.0e9,
            network_bw: 2.4e9,
            pcie_lat: 10e-6,
            nvlink_lat: 5e-6,
            network_lat: 40e-6,
            disk_bw: 2.0e9,
            disk_lat: 90e-6,
            gpu_flops: 14.0e12,
            gpu_membw: 550.0e9,
            sample_edge_cost: 9.0e-9,
            gpu_mem: (16.0e9 / scale_divisor) as u64,
        }
    }
}

/// A host×GPU topology.
#[derive(Debug, Clone)]
pub struct Topology {
    pub num_hosts: usize,
    pub gpus_per_host: usize,
    /// `direct[a][b]`: whether GPUs a and b (global indices) share an
    /// NVLink (same host only).
    direct: Vec<Vec<bool>>,
    pub hw: HardwareModel,
}

impl Topology {
    pub fn num_gpus(&self) -> usize {
        self.num_hosts * self.gpus_per_host
    }

    pub fn host_of(&self, gpu: DeviceId) -> usize {
        gpu as usize / self.gpus_per_host
    }

    /// Link used for a transfer from `a` to `b`.
    pub fn link(&self, a: DeviceId, b: DeviceId) -> LinkKind {
        if a == b {
            LinkKind::Local
        } else if self.host_of(a) != self.host_of(b) {
            LinkKind::Network
        } else if self.direct[a as usize][b as usize] {
            LinkKind::NvLink
        } else {
            // Same host, no direct NVLink: staged through host memory.
            LinkKind::PcieHost
        }
    }

    /// Whether `a` and `b` share a direct NVLink. Total over all inputs:
    /// device ids the topology doesn't model (e.g. cache-placement bits
    /// from a wider device set than this — possibly truncated — topology)
    /// are simply not linked, rather than a panic.
    pub fn has_nvlink(&self, a: DeviceId, b: DeviceId) -> bool {
        if (a as usize) >= self.num_gpus() || (b as usize) >= self.num_gpus() {
            return false;
        }
        self.link(a, b) == LinkKind::NvLink
    }

    /// Seconds to move `bytes` from `a` to `b`.
    pub fn transfer_time(&self, a: DeviceId, b: DeviceId, bytes: u64) -> f64 {
        let hw = &self.hw;
        match self.link(a, b) {
            LinkKind::Local => 0.0,
            LinkKind::NvLink => hw.nvlink_lat + bytes as f64 / hw.nvlink_bw,
            LinkKind::PcieHost => 2.0 * (hw.pcie_lat + bytes as f64 / hw.pcie_bw),
            LinkKind::Network => hw.network_lat + bytes as f64 / hw.network_bw,
        }
    }

    /// Seconds to load `bytes` from host memory into one GPU over PCIe.
    pub fn host_load_time(&self, bytes: u64) -> f64 {
        self.hw.pcie_lat + bytes as f64 / self.hw.pcie_bw
    }

    /// Seconds to load `bytes` that missed the host's chunk buffer: read
    /// from the local SSD into host RAM, then cross PCIe like any host
    /// load (the stages don't overlap at the fidelity the model needs).
    pub fn disk_load_time(&self, bytes: u64) -> f64 {
        self.hw.disk_lat + bytes as f64 / self.hw.disk_bw + self.host_load_time(bytes)
    }

    /// p3.8xlarge: 4 GPUs, all-to-all NVLink.
    pub fn p3_8xlarge(scale_divisor: f64) -> Self {
        Self::single_host(4, true, scale_divisor)
    }

    /// p3.16xlarge: 8 GPUs in the V100 hybrid cube mesh — each GPU has
    /// direct NVLink to 4 peers; the other 3 require a hop (we model that
    /// as PCIe-staged, which is what NCCL falls back to for p2p without
    /// a direct link when peer routing is off).
    pub fn p3_16xlarge(scale_divisor: f64) -> Self {
        let mut direct = vec![vec![false; 8]; 8];
        // DGX-1 style hybrid cube mesh adjacency.
        let pairs: [(usize, usize); 16] = [
            (0, 1), (0, 2), (0, 3), (0, 4),
            (1, 2), (1, 3), (1, 5),
            (2, 3), (2, 6),
            (3, 7),
            (4, 5), (4, 6), (4, 7),
            (5, 6), (5, 7),
            (6, 7),
        ];
        for (a, b) in pairs {
            direct[a][b] = true;
            direct[b][a] = true;
        }
        Topology {
            num_hosts: 1,
            gpus_per_host: 8,
            direct,
            hw: HardwareModel::v100(scale_divisor),
        }
    }

    /// Single host with `g` GPUs, optionally all-to-all NVLink.
    pub fn single_host(g: usize, all_nvlink: bool, scale_divisor: f64) -> Self {
        let direct = vec![vec![all_nvlink; g]; g];
        Topology { num_hosts: 1, gpus_per_host: g, direct, hw: HardwareModel::v100(scale_divisor) }
    }

    /// `h` hosts × 4 GPUs (p3.8xlarge each), as in the paper's multi-host
    /// experiments (Fig. 6b).
    pub fn multi_host(h: usize, scale_divisor: f64) -> Self {
        let g = 4 * h;
        let mut direct = vec![vec![false; g]; g];
        for host in 0..h {
            for a in 0..4 {
                for b in 0..4 {
                    if a != b {
                        direct[host * 4 + a][host * 4 + b] = true;
                    }
                }
            }
        }
        Topology { num_hosts: h, gpus_per_host: 4, direct, hw: HardwareModel::v100(scale_divisor) }
    }

    /// Topology for `gpus` on one host, matching the paper's instances
    /// (≤4 → all NVLink; 5–8 → cube mesh, truncated below 8).
    ///
    /// Truncation keeps `direct` square at `gpus × gpus` and updates
    /// `gpus_per_host` in the same step, so `num_gpus()` and `link()`
    /// agree for every size (see the regression test below). Out-of-range
    /// requests return [`TopologyError`] instead of panicking: no
    /// single-host V100 instance has more than 8 GPUs — use
    /// [`Topology::multi_host`] for those.
    pub fn for_gpus(gpus: usize, scale_divisor: f64) -> Result<Self, TopologyError> {
        if gpus < 1 {
            return Err(TopologyError::NoGpus);
        }
        if gpus > 8 {
            return Err(TopologyError::TooManyGpus { requested: gpus });
        }
        Ok(if gpus <= 4 {
            Self::single_host(gpus, true, scale_divisor)
        } else {
            let mut t = Self::p3_16xlarge(scale_divisor);
            t.gpus_per_host = gpus;
            t.direct.truncate(gpus);
            for row in &mut t.direct {
                row.truncate(gpus);
            }
            debug_assert!(t.direct.len() == t.num_gpus());
            debug_assert!(t.direct.iter().all(|r| r.len() == t.num_gpus()));
            t
        })
    }
}

/// A GPU-count request no modeled instance can satisfy
/// ([`Topology::for_gpus`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TopologyError {
    /// Requested zero GPUs.
    NoGpus,
    /// Requested more GPUs than any single-host V100 instance has.
    TooManyGpus { requested: usize },
}

impl std::fmt::Display for TopologyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TopologyError::NoGpus => write!(f, "topology needs at least one GPU"),
            TopologyError::TooManyGpus { requested } => write!(
                f,
                "single-host topologies model at most 8 GPUs (p3.16xlarge), \
                 got {requested}; use Topology::multi_host for multi-host runs"
            ),
        }
    }
}

impl std::error::Error for TopologyError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p3_8x_all_pairs_nvlink() {
        let t = Topology::p3_8xlarge(32.0);
        assert_eq!(t.num_gpus(), 4);
        for a in 0..4u16 {
            for b in 0..4u16 {
                if a != b {
                    assert_eq!(t.link(a, b), LinkKind::NvLink);
                } else {
                    assert_eq!(t.link(a, b), LinkKind::Local);
                }
            }
        }
    }

    #[test]
    fn p3_16x_has_missing_links() {
        let t = Topology::p3_16xlarge(32.0);
        assert_eq!(t.num_gpus(), 8);
        let mut missing = 0;
        for a in 0..8u16 {
            for b in 0..8u16 {
                if a != b && t.link(a, b) == LinkKind::PcieHost {
                    missing += 1;
                }
            }
        }
        // 8 GPUs × 7 peers = 56 ordered pairs; 32 have NVLink, 24 don't.
        assert_eq!(missing, 24, "hybrid cube mesh should leave 24 ordered pairs indirect");
    }

    #[test]
    fn multihost_links() {
        let t = Topology::multi_host(2, 32.0);
        assert_eq!(t.num_gpus(), 8);
        assert_eq!(t.link(0, 3), LinkKind::NvLink);
        assert_eq!(t.link(0, 4), LinkKind::Network);
        assert_eq!(t.host_of(5), 1);
    }

    #[test]
    fn transfer_times_ordered_by_link_speed() {
        let t = Topology::multi_host(2, 32.0);
        let bytes = 64 << 20;
        let nv = t.transfer_time(0, 1, bytes);
        let net = t.transfer_time(0, 4, bytes);
        assert!(nv < net);
        assert_eq!(t.transfer_time(2, 2, bytes), 0.0);
        // Host load of the same bytes sits between NVLink and network.
        let host = t.host_load_time(bytes);
        assert!(nv < host && host < net, "nv={nv} host={host} net={net}");
        // Disk fall-through is strictly slower than a pure host load (it
        // includes one) but uses the same-model SSD regardless of scale.
        let disk = t.disk_load_time(bytes);
        assert!(disk > host, "disk={disk} host={host}");
        assert!((disk - (t.hw.disk_lat + bytes as f64 / t.hw.disk_bw + host)).abs() < 1e-15);
    }

    #[test]
    fn for_gpus_truncation_keeps_direct_consistent() {
        // Regression: for every truncated size, `num_gpus()` and `link()`
        // must agree — every pair below `num_gpus()` resolves without
        // panicking, the diagonal is Local, and links are symmetric.
        for g in 1..=8usize {
            let t = Topology::for_gpus(g, 32.0).unwrap();
            assert_eq!(t.num_gpus(), g, "num_gpus for size {g}");
            for a in 0..g as u16 {
                for b in 0..g as u16 {
                    let l = t.link(a, b);
                    if a == b {
                        assert_eq!(l, LinkKind::Local);
                    } else {
                        assert_ne!(l, LinkKind::Local, "distinct GPUs share a Local link");
                        assert_eq!(l, t.link(b, a), "asymmetric link {a}<->{b} at size {g}");
                    }
                }
            }
        }
        // 5-GPU cube-mesh subset: GPU 4 keeps its NVLink to 0 but reaches
        // 1–3 through host memory.
        let t5 = Topology::for_gpus(5, 32.0).unwrap();
        assert_eq!(t5.link(4, 0), LinkKind::NvLink);
        assert_eq!(t5.link(4, 1), LinkKind::PcieHost);
    }

    #[test]
    fn has_nvlink_is_total_over_out_of_range_devices() {
        let t = Topology::for_gpus(5, 32.0).unwrap();
        assert!(t.has_nvlink(0, 1));
        assert!(!t.has_nvlink(0, 5), "unmodeled device is never linked");
        assert!(!t.has_nvlink(9, 0));
        assert!(!t.has_nvlink(3, 3), "self link is Local, not NVLink");
    }

    #[test]
    fn for_gpus_rejects_out_of_range_counts_with_typed_errors() {
        // Regression: >8 GPUs used to panic deep inside topology
        // construction; now it is a typed error a CLI can print.
        let err = Topology::for_gpus(9, 1.0).unwrap_err();
        assert_eq!(err, TopologyError::TooManyGpus { requested: 9 });
        assert!(err.to_string().contains("at most 8 GPUs"), "{err}");
        assert!(err.to_string().contains("multi_host"), "{err}");
        assert_eq!(Topology::for_gpus(0, 1.0).unwrap_err(), TopologyError::NoGpus);
        // The boundary sizes stay fine.
        assert!(Topology::for_gpus(1, 1.0).is_ok());
        assert!(Topology::for_gpus(8, 1.0).is_ok());
    }

    #[test]
    fn gpu_memory_scales() {
        let t32 = Topology::p3_8xlarge(32.0);
        let t1 = Topology::p3_8xlarge(1.0);
        assert_eq!(t1.hw.gpu_mem, 32 * t32.hw.gpu_mem);
    }
}
