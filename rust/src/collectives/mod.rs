//! Deterministic collective-communication primitives over a k×k channel
//! fabric (DESIGN.md §Collectives).
//!
//! The split-parallel executor used to build its device-to-device channel
//! fabric inline (twice — once for training, once for inference). This
//! module owns that fabric as a reusable type, [`Fabric`], plus the three
//! collectives the pipeline is composed of:
//!
//! * [`FabricEndpoint::all_to_all`] — chunked [`RowChunk`] streaming over
//!   the k×k bounded channels with interleaved send/receive pumping, used
//!   by the per-layer forward/backward shuffles and the pre-forward
//!   loading exchange;
//! * [`all_reduce`] — coordinator-side reduction of per-device tensor
//!   contributions, applied in fixed device order;
//! * [`broadcast`] — fan-out of one message to every worker, in fixed
//!   worker order, delivered exactly once per receiver.
//!
//! # Determinism contract
//!
//! Every primitive is **deterministic by construction** — bit-identical
//! results at any worker count, channel capacity, or thread interleaving:
//!
//! * `all_to_all` never merges floats on arrival: the caller's `deliver`
//!   closure scatters each chunk to positions derived from the shared
//!   plan, and callers that must accumulate stage chunks per source and
//!   apply them in fixed device order afterwards;
//! * `all_reduce` visits contributions in slice order (ascending device
//!   id at every call site), reproducing the serial accumulation order
//!   exactly — never `+=` in arrival order;
//! * `broadcast` sends to receivers in slice order over dedicated
//!   channels, so each receiver sees exactly one copy.
//!
//! # Phase alignment and deadlock freedom
//!
//! `all_to_all` has no barrier: both endpoints of every link compute the
//! expected chunk count from the shared plan ([`FabricEndpoint::chunks_of`]
//! over the same send lists), so senders and receivers agree on when a
//! phase is complete without exchanging control messages. Channels are
//! bounded ([`Fabric::new`]'s `channel_cap`); when a link backs up, the
//! pump interleaves sends with receives, so small capacities throttle
//! throughput without deadlocking. A shared abort flag (set by
//! [`Fabric::abort_handle`] holders when a peer dies) breaks the pump out
//! of an exchange that can never complete.
//!
//! Collective activity is traced under the `collective` phase
//! ([`crate::obs::Phase::Collective`]), nested inside whatever pipeline
//! phase the caller opened.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TryRecvError, TrySendError};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::obs::Phase;
use crate::span;

/// One typed all-to-all payload: `rows` holds packed row-major values for
/// positions `start .. start + rows.len()/width` of the (from→to) send
/// list of the current exchange phase.
pub struct RowChunk {
    pub start: u32,
    pub rows: Vec<f32>,
}

/// Outbound chunk queue for one (owned device `li` → destination `to`)
/// link of an [`FabricEndpoint::all_to_all`] call.
pub struct OutQueue {
    /// Index into the endpoint's owned-device list (not a device id).
    pub li: usize,
    /// Destination device id.
    pub to: usize,
    pub q: VecDeque<RowChunk>,
}

/// Spin-then-yield-then-sleep schedule for the exchange pump.
const SPIN_YIELDS: u32 = 256;

/// A k×k fabric of bounded typed channels — one directed link per device
/// pair — plus the shared abort flag and chunking parameters every
/// endpoint inherits. Build one per executor run, then hand each worker
/// its devices' endpoints via [`Fabric::endpoint`].
pub struct Fabric {
    k: usize,
    chunk_rows: usize,
    abort: Arc<AtomicBool>,
    senders: Vec<Vec<Option<SyncSender<RowChunk>>>>,
    receivers: Vec<Vec<Option<Receiver<RowChunk>>>>,
}

impl Fabric {
    /// Build the k×k channel fabric. Each directed link buffers at most
    /// `channel_cap` chunks (≥1); exchange messages are split into chunks
    /// of at most `chunk_rows` rows (≥1). Neither knob can affect results
    /// — only throughput and memory.
    pub fn new(k: usize, channel_cap: usize, chunk_rows: usize) -> Self {
        let channel_cap = channel_cap.max(1);
        let mut senders: Vec<Vec<Option<SyncSender<RowChunk>>>> =
            (0..k).map(|_| (0..k).map(|_| None).collect()).collect();
        let mut receivers: Vec<Vec<Option<Receiver<RowChunk>>>> =
            (0..k).map(|_| (0..k).map(|_| None).collect()).collect();
        for from in 0..k {
            for to in 0..k {
                let (tx, rx) = sync_channel::<RowChunk>(channel_cap);
                senders[from][to] = Some(tx);
                receivers[to][from] = Some(rx);
            }
        }
        Fabric {
            k,
            chunk_rows: chunk_rows.max(1),
            abort: Arc::new(AtomicBool::new(false)),
            senders,
            receivers,
        }
    }

    /// Number of devices the fabric connects.
    pub fn k(&self) -> usize {
        self.k
    }

    /// The shared abort flag: set it when a participant dies so peers
    /// pumping an exchange fail fast instead of spinning forever.
    pub fn abort_handle(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.abort)
    }

    /// Take the channel endpoints of `owned` devices (each device's k
    /// outbound senders and k inbound receivers). Every device's endpoints
    /// can be taken exactly once; the union of all `endpoint` calls must
    /// cover each device at most once.
    ///
    /// # Panics
    ///
    /// If a device id is out of range or its endpoints were already taken.
    pub fn endpoint(&mut self, owned: Vec<usize>) -> FabricEndpoint {
        let k = self.k;
        let send: Vec<Vec<SyncSender<RowChunk>>> = owned
            .iter()
            .map(|&d| (0..k).map(|to| self.senders[d][to].take().expect("sender taken once")).collect())
            .collect();
        let recv: Vec<Vec<Receiver<RowChunk>>> = owned
            .iter()
            .map(|&d| {
                (0..k).map(|from| self.receivers[d][from].take().expect("receiver taken once")).collect()
            })
            .collect();
        FabricEndpoint {
            k,
            chunk_rows: self.chunk_rows,
            owned,
            send,
            recv,
            abort: Arc::clone(&self.abort),
        }
    }
}

/// One participant's side of the [`Fabric`]: the senders and receivers of
/// its owned devices, plus the shared chunking/abort parameters. Movable
/// into a worker thread.
pub struct FabricEndpoint {
    k: usize,
    chunk_rows: usize,
    /// Owned device ids, ascending.
    owned: Vec<usize>,
    /// `send[li][to]` — sender of the (owned[li] → to) channel.
    send: Vec<Vec<SyncSender<RowChunk>>>,
    /// `recv[li][from]` — receiver of the (from → owned[li]) channel.
    recv: Vec<Vec<Receiver<RowChunk>>>,
    abort: Arc<AtomicBool>,
}

impl FabricEndpoint {
    /// Number of devices in the fabric.
    pub fn k(&self) -> usize {
        self.k
    }

    /// The devices this endpoint owns, ascending.
    pub fn owned(&self) -> &[usize] {
        &self.owned
    }

    /// Chunk count of a `rows`-row exchange message (0 rows ⇒ no message).
    /// Sender and receiver both derive counts from the shared plan, so the
    /// two sides of every link always agree — the no-barrier phase
    /// alignment the module docs describe.
    pub fn chunks_of(&self, rows: usize) -> usize {
        if rows == 0 {
            0
        } else {
            rows.div_ceil(self.chunk_rows)
        }
    }

    /// Pack `n_rows` logical rows into [`RowChunk`]s of ≤ `chunk_rows`,
    /// `append(i, buf)` supplying row `i`'s `width` values. The one
    /// chunking implementation behind every exchange phase — chunk counts
    /// always match [`FabricEndpoint::chunks_of`].
    pub fn pack_chunks(
        &self,
        n_rows: usize,
        width: usize,
        mut append: impl FnMut(usize, &mut Vec<f32>),
    ) -> VecDeque<RowChunk> {
        let mut out = VecDeque::with_capacity(self.chunks_of(n_rows));
        let mut start = 0usize;
        while start < n_rows {
            let n = (n_rows - start).min(self.chunk_rows);
            let mut rows = Vec::with_capacity(n * width);
            for i in start..start + n {
                append(i, &mut rows);
            }
            out.push_back(RowChunk { start: start as u32, rows });
            start += n;
        }
        out
    }

    /// Pack `src` rows at `idx` positions into chunks of ≤ `chunk_rows`.
    pub fn pack_rows(&self, src: &[f32], idx: &[u32], width: usize) -> VecDeque<RowChunk> {
        self.pack_chunks(idx.len(), width, |i, rows| {
            let p = idx[i] as usize;
            rows.extend_from_slice(&src[p * width..(p + 1) * width]);
        })
    }

    /// One all-to-all exchange phase: drive the queued sends in `outgoing`
    /// and the expected receives in `expect[li][from]` (chunk counts, from
    /// [`FabricEndpoint::chunks_of`] over the shared plan) to completion,
    /// interleaving both so bounded channels cannot deadlock.
    /// `deliver(li, from, chunk)` consumes each arriving chunk; it must
    /// scatter to disjoint positions or stage for a later fixed-order
    /// reduction — never accumulate in arrival order (the determinism
    /// contract in the module docs).
    pub fn all_to_all(
        &self,
        outgoing: &mut [OutQueue],
        expect: &mut [Vec<usize>],
        mut deliver: impl FnMut(usize, usize, RowChunk),
    ) -> Result<()> {
        let _s = span!(Phase::Collective);
        let mut spins = 0u32;
        loop {
            let mut progress = false;
            for oq in outgoing.iter_mut() {
                while let Some(chunk) = oq.q.pop_front() {
                    match self.send[oq.li][oq.to].try_send(chunk) {
                        Ok(()) => progress = true,
                        Err(TrySendError::Full(c)) => {
                            oq.q.push_front(c);
                            break;
                        }
                        Err(TrySendError::Disconnected(_)) => bail!("row channel closed"),
                    }
                }
            }
            let mut pending = outgoing.iter().any(|o| !o.q.is_empty());
            for li in 0..self.owned.len() {
                for from in 0..self.k {
                    while expect[li][from] > 0 {
                        match self.recv[li][from].try_recv() {
                            Ok(chunk) => {
                                expect[li][from] -= 1;
                                progress = true;
                                deliver(li, from, chunk);
                            }
                            Err(TryRecvError::Empty) => break,
                            Err(TryRecvError::Disconnected) => bail!("row channel closed"),
                        }
                    }
                    if expect[li][from] > 0 {
                        pending = true;
                    }
                }
            }
            if !pending {
                return Ok(());
            }
            if self.abort.load(Ordering::Relaxed) {
                bail!("aborted: a peer worker failed");
            }
            if progress {
                spins = 0;
            } else {
                spins += 1;
                if spins < SPIN_YIELDS {
                    thread::yield_now();
                } else {
                    thread::sleep(Duration::from_micros(50));
                }
            }
        }
    }
}

/// Fixed-order all-reduce: accumulate each participant's per-tensor
/// contribution into `acc`, visiting `contribs` strictly in slice order
/// (ascending device id at every call site) — the serial accumulation
/// order, bit-identical at any worker count. `None` entries (devices that
/// were inactive this phase) are skipped without perturbing the order.
pub fn all_reduce(acc: &mut [Vec<f32>], contribs: &[Option<&Vec<Vec<f32>>>]) {
    let _s = span!(Phase::Collective);
    for contrib in contribs.iter().flatten() {
        for (t, g) in acc.iter_mut().zip(contrib.iter()) {
            for (a, b) in t.iter_mut().zip(g) {
                *a += b;
            }
        }
    }
}

/// Broadcast `msg` to every receiver in fixed slice order. Each receiver
/// gets exactly one copy (dedicated channels, one send per receiver); the
/// last send moves `msg` instead of cloning it. Fails if any receiver has
/// hung up.
pub fn broadcast<T: Clone>(txs: &[SyncSender<T>], msg: T) -> Result<()> {
    let _s = span!(Phase::Collective);
    if let Some((last, rest)) = txs.split_last() {
        for tx in rest {
            tx.send(msg.clone()).map_err(|_| anyhow!("broadcast receiver disconnected"))?;
        }
        last.send(msg).map_err(|_| anyhow!("broadcast receiver disconnected"))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_of_matches_pack_chunks() {
        let mut fabric = Fabric::new(1, 1, 3);
        let ep = fabric.endpoint(vec![0]);
        for rows in [0usize, 1, 2, 3, 4, 6, 7] {
            let chunks = ep.pack_chunks(rows, 2, |i, buf| buf.extend([i as f32, 0.0]));
            assert_eq!(chunks.len(), ep.chunks_of(rows), "rows={rows}");
        }
    }

    #[test]
    fn all_reduce_skips_inactive_and_sums_in_order() {
        let mut acc = vec![vec![0f32; 3], vec![0f32; 2]];
        let a = vec![vec![1.0, 2.0, 3.0], vec![4.0, 5.0]];
        let b = vec![vec![10.0, 20.0, 30.0], vec![40.0, 50.0]];
        all_reduce(&mut acc, &[Some(&a), None, Some(&b)]);
        assert_eq!(acc[0], vec![11.0, 22.0, 33.0]);
        assert_eq!(acc[1], vec![44.0, 55.0]);
    }

    #[test]
    fn broadcast_delivers_one_copy_per_receiver() {
        let (txs, rxs): (Vec<_>, Vec<_>) = (0..3).map(|_| sync_channel::<u32>(1)).unzip();
        broadcast(&txs, 7).unwrap();
        for rx in &rxs {
            assert_eq!(rx.recv().unwrap(), 7);
            assert!(rx.try_recv().is_err(), "exactly one copy per receiver");
        }
    }

    #[test]
    fn broadcast_fails_on_disconnected_receiver() {
        let (txs, rxs): (Vec<_>, Vec<_>) = (0..2).map(|_| sync_channel::<u32>(1)).unzip();
        drop(rxs);
        assert!(broadcast(&txs, 1).is_err());
    }

    #[test]
    #[should_panic(expected = "taken once")]
    fn endpoint_double_take_panics() {
        let mut fabric = Fabric::new(2, 1, 1);
        let _a = fabric.endpoint(vec![0]);
        let _b = fabric.endpoint(vec![0]);
    }
}
