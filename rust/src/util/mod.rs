//! Small self-contained utilities: JSON reading/writing, ASCII table
//! rendering, timing, and logging. These exist in-tree because the build
//! environment's crate registry does not carry `serde`/`serde_json`/`clap`
//! (see DESIGN.md §3).

pub mod json;
pub mod log;
pub mod table;
pub mod timer;

pub use json::JsonValue;
pub use table::Table;
pub use timer::Stopwatch;

/// Format a byte count with binary units.
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format a large count with SI-style suffixes (K/M/B), matching how the
/// paper reports edge and feature-vector counts in Table 1.
pub fn fmt_count(n: u64) -> String {
    if n >= 1_000_000_000 {
        format!("{:.1}B", n as f64 / 1e9)
    } else if n >= 1_000_000 {
        format!("{:.0}M", n as f64 / 1e6)
    } else if n >= 1_000 {
        format!("{:.1}K", n as f64 / 1e3)
    } else {
        n.to_string()
    }
}

/// Format seconds with adaptive precision.
pub fn fmt_secs(s: f64) -> String {
    if s >= 100.0 {
        format!("{s:.0}")
    } else if s >= 10.0 {
        format!("{s:.1}")
    } else {
        format!("{s:.2}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(12), "12 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn count_formatting() {
        assert_eq!(fmt_count(926_000_000), "926M");
        assert_eq!(fmt_count(13_400_000_000), "13.4B");
        assert_eq!(fmt_count(751), "751");
        assert_eq!(fmt_count(4_200), "4.2K");
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(fmt_secs(283.4), "283");
        assert_eq!(fmt_secs(62.7), "62.7");
        assert_eq!(fmt_secs(1.5), "1.50");
    }
}
