//! Minimal JSON value model, writer, and recursive-descent parser.
//!
//! Used for the AOT artifact manifest (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`), metrics JSONL emission, and experiment reports.
//! Implemented in-tree because `serde_json` is unavailable offline.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Object keys are kept sorted (BTreeMap) so output is
/// deterministic, which keeps golden-file tests stable.
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<JsonValue>),
    Obj(BTreeMap<String, JsonValue>),
}

impl JsonValue {
    pub fn parse(text: &str) -> Result<JsonValue> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {} of JSON input", p.i);
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            JsonValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, JsonValue>> {
        match self {
            JsonValue::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access with a contextual error.
    pub fn get(&self, key: &str) -> Result<&JsonValue> {
        self.as_obj()
            .and_then(|o| o.get(key))
            .ok_or_else(|| anyhow!("missing JSON field `{key}`"))
    }

    pub fn obj(pairs: Vec<(&str, JsonValue)>) -> JsonValue {
        JsonValue::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> JsonValue {
        JsonValue::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> JsonValue {
        JsonValue::Num(n.into())
    }
}

impl fmt::Display for JsonValue {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonValue::Null => f.write_str("null"),
            JsonValue::Bool(b) => write!(f, "{b}"),
            JsonValue::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            JsonValue::Str(s) => write_escaped(f, s),
            JsonValue::Arr(a) => {
                f.write_str("[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{v}")?;
                }
                f.write_str("]")
            }
            JsonValue::Obj(o) => {
                f.write_str("{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_escaped(f, k)?;
                    f.write_str(":")?;
                    write!(f, "{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\r' => f.write_str("\\r")?,
            '\t' => f.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected `{}` at byte {}", c as char, self.i)
        }
    }

    fn value(&mut self) -> Result<JsonValue> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(JsonValue::Str(self.string()?)),
            Some(b't') => self.lit("true", JsonValue::Bool(true)),
            Some(b'f') => self.lit("false", JsonValue::Bool(false)),
            Some(b'n') => self.lit("null", JsonValue::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i),
        }
    }

    fn lit(&mut self, word: &str, v: JsonValue) -> Result<JsonValue> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<JsonValue> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(JsonValue::Num(s.parse::<f64>()?))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => bail!("bad escape {other:?}"),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let rest = std::str::from_utf8(&self.b[self.i..])?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<JsonValue> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(JsonValue::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(JsonValue::Arr(items));
                }
                other => bail!("expected , or ] got {other:?} at byte {}", self.i),
            }
        }
    }

    fn object(&mut self) -> Result<JsonValue> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(JsonValue::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(JsonValue::Obj(map));
                }
                other => bail!("expected , or }} got {other:?} at byte {}", self.i),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e1}}"#;
        let v = JsonValue::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_u64(), Some(1));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-25.0));
        let printed = v.to_string();
        let reparsed = JsonValue::parse(&printed).unwrap();
        assert_eq!(v, reparsed);
    }

    #[test]
    fn parses_nested_arrays() {
        let v = JsonValue::parse("[[1,2],[3,4],[]]").unwrap();
        let arr = v.as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[1].as_arr().unwrap()[0].as_u64(), Some(3));
    }

    #[test]
    fn rejects_garbage() {
        assert!(JsonValue::parse("{").is_err());
        assert!(JsonValue::parse("[1,]").is_err());
        assert!(JsonValue::parse("12 34").is_err());
        assert!(JsonValue::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn escapes_on_output() {
        let v = JsonValue::str("a\"b\\c\nd");
        let s = v.to_string();
        assert_eq!(s, r#""a\"b\\c\nd""#);
        assert_eq!(JsonValue::parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        let v = JsonValue::parse(r#""é""#).unwrap();
        assert_eq!(v.as_str(), Some("é"));
    }
}
