//! ASCII table renderer used by every bench binary to print paper-style
//! tables (Table 1, Table 3, …) to stdout.

/// A simple left/right-aligned ASCII table.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    /// Columns that should be right-aligned (numeric columns).
    right: Vec<bool>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            right: header.iter().map(|_| true).collect(),
        }
    }

    /// Mark column `i` as left-aligned (labels). All columns default to
    /// right-aligned since most table content is numeric.
    pub fn left(mut self, i: usize) -> Self {
        if i < self.right.len() {
            self.right[i] = false;
        }
        self
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
    }

    /// A horizontal separator row.
    pub fn sep(&mut self) {
        self.rows.push(Vec::new());
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut width = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = width[i].max(h.chars().count());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let hline = |out: &mut String| {
            out.push('+');
            for w in &width {
                out.push_str(&"-".repeat(w + 2));
                out.push('+');
            }
            out.push('\n');
        };
        let fmt_row = |out: &mut String, cells: &[String], right: &[bool]| {
            out.push('|');
            for i in 0..ncols {
                let cell = cells.get(i).map(String::as_str).unwrap_or("");
                let pad = width[i] - cell.chars().count();
                if right.get(i).copied().unwrap_or(false) {
                    out.push_str(&format!(" {}{} |", " ".repeat(pad), cell));
                } else {
                    out.push_str(&format!(" {}{} |", cell, " ".repeat(pad)));
                }
            }
            out.push('\n');
        };
        hline(&mut out);
        let left_hdr: Vec<bool> = self.header.iter().map(|_| false).collect();
        fmt_row(&mut out, &self.header, &left_hdr);
        hline(&mut out);
        for row in &self.rows {
            if row.is_empty() {
                hline(&mut out);
            } else {
                fmt_row(&mut out, row, &self.right);
            }
        }
        hline(&mut out);
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Convenience macro for building a row of strings.
#[macro_export]
macro_rules! table_row {
    ($($cell:expr),* $(,)?) => {
        vec![$($cell.to_string()),*]
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["System", "Total(s)", "Speedup"]).left(0);
        t.row(vec!["DGL".into(), "73.4".into(), "4.4x".into()]);
        t.row(vec!["GSplit".into(), "16.7".into(), "".into()]);
        let s = t.render();
        assert!(s.contains("| System | Total(s) | Speedup |"));
        assert!(s.contains("| DGL    |     73.4 |    4.4x |"));
        // Every line is equally wide.
        let widths: Vec<usize> = s.lines().map(|l| l.chars().count()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    #[should_panic]
    fn rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
