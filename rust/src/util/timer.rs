//! Wall-clock timing helpers used by the engines and the bench harness.

use std::time::Instant;

/// A restartable stopwatch that accumulates elapsed seconds across
/// start/stop pairs. Engines keep one per training phase (S / L / FB).
#[derive(Debug, Clone)]
pub struct Stopwatch {
    total: f64,
    started: Option<Instant>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Stopwatch { total: 0.0, started: None }
    }

    pub fn start(&mut self) {
        debug_assert!(self.started.is_none(), "stopwatch already running");
        self.started = Some(Instant::now());
    }

    pub fn stop(&mut self) {
        debug_assert!(self.started.is_some(), "stopwatch stopped but never started");
        if let Some(t0) = self.started.take() {
            self.total += t0.elapsed().as_secs_f64();
        }
    }

    /// Whether an interval is currently open (started but not stopped).
    pub fn running(&self) -> bool {
        self.started.is_some()
    }

    /// Accumulated seconds of **closed** intervals only. A currently
    /// running interval is excluded — use [`Stopwatch::elapsed_total`] to
    /// include it.
    pub fn secs(&self) -> f64 {
        self.total
    }

    /// Accumulated seconds including the currently running interval, if
    /// any. Unlike [`Stopwatch::secs`], reading this mid-interval never
    /// under-reports.
    pub fn elapsed_total(&self) -> f64 {
        self.total + self.started.map_or(0.0, |t0| t0.elapsed().as_secs_f64())
    }

    pub fn reset(&mut self) {
        self.total = 0.0;
        self.started = None;
    }

    /// Time a closure and add its duration.
    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        self.start();
        let out = f();
        self.stop();
        out
    }
}

/// Measure a closure once, returning (seconds, value).
pub fn timed<T>(f: impl FnOnce() -> T) -> (f64, T) {
    let t0 = Instant::now();
    let v = f();
    (t0.elapsed().as_secs_f64(), v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let mut sw = Stopwatch::new();
        sw.time(|| std::thread::sleep(std::time::Duration::from_millis(5)));
        sw.time(|| std::thread::sleep(std::time::Duration::from_millis(5)));
        assert!(sw.secs() >= 0.009, "got {}", sw.secs());
    }

    #[test]
    fn timed_returns_value() {
        let (secs, v) = timed(|| 42);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn running_reflects_open_interval() {
        let mut sw = Stopwatch::new();
        assert!(!sw.running());
        sw.start();
        assert!(sw.running());
        sw.stop();
        assert!(!sw.running());
        sw.start();
        sw.reset();
        assert!(!sw.running(), "reset closes the open interval");
    }

    #[test]
    fn elapsed_total_includes_the_live_interval() {
        // Regression: secs() silently excluded a currently-running
        // interval, so mid-phase reads under-reported.
        let mut sw = Stopwatch::new();
        sw.time(|| std::thread::sleep(std::time::Duration::from_millis(5)));
        let closed = sw.secs();
        sw.start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert_eq!(sw.secs(), closed, "secs() still reports closed intervals only");
        let live = sw.elapsed_total();
        assert!(live >= closed + 0.004, "live interval missing: {live} vs {closed}");
        sw.stop();
        assert!(sw.secs() >= live, "stop() folds the live interval into secs()");
        assert_eq!(sw.secs(), sw.elapsed_total(), "equal while not running");
    }

    #[test]
    #[should_panic(expected = "stopwatch stopped but never started")]
    #[cfg(debug_assertions)]
    fn stop_without_start_is_a_debug_panic() {
        let mut sw = Stopwatch::new();
        sw.stop();
    }
}
