//! Wall-clock timing helpers used by the engines and the bench harness.

use std::time::Instant;

/// A restartable stopwatch that accumulates elapsed seconds across
/// start/stop pairs. Engines keep one per training phase (S / L / FB).
#[derive(Debug, Clone)]
pub struct Stopwatch {
    total: f64,
    started: Option<Instant>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Stopwatch { total: 0.0, started: None }
    }

    pub fn start(&mut self) {
        debug_assert!(self.started.is_none(), "stopwatch already running");
        self.started = Some(Instant::now());
    }

    pub fn stop(&mut self) {
        if let Some(t0) = self.started.take() {
            self.total += t0.elapsed().as_secs_f64();
        }
    }

    /// Accumulated seconds (not counting a currently-running interval).
    pub fn secs(&self) -> f64 {
        self.total
    }

    pub fn reset(&mut self) {
        self.total = 0.0;
        self.started = None;
    }

    /// Time a closure and add its duration.
    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        self.start();
        let out = f();
        self.stop();
        out
    }
}

/// Measure a closure once, returning (seconds, value).
pub fn timed<T>(f: impl FnOnce() -> T) -> (f64, T) {
    let t0 = Instant::now();
    let v = f();
    (t0.elapsed().as_secs_f64(), v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let mut sw = Stopwatch::new();
        sw.time(|| std::thread::sleep(std::time::Duration::from_millis(5)));
        sw.time(|| std::thread::sleep(std::time::Duration::from_millis(5)));
        assert!(sw.secs() >= 0.009, "got {}", sw.secs());
    }

    #[test]
    fn timed_returns_value() {
        let (secs, v) = timed(|| 42);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
