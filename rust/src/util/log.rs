//! Tiny leveled logger (stderr). `GSPLIT_LOG=debug|info|warn|error` selects
//! verbosity; defaults to `info`.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(1);
static INIT: OnceLock<()> = OnceLock::new();

fn ensure_init() {
    INIT.get_or_init(|| {
        let lvl = match std::env::var("GSPLIT_LOG").as_deref() {
            Ok("debug") => Level::Debug,
            Ok("warn") => Level::Warn,
            Ok("error") => Level::Error,
            _ => Level::Info,
        };
        LEVEL.store(lvl as u8, Ordering::Relaxed);
    });
}

pub fn enabled(level: Level) -> bool {
    ensure_init();
    level as u8 >= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        let tag = match level {
            Level::Debug => "DEBUG",
            Level::Info => "INFO ",
            Level::Warn => "WARN ",
            Level::Error => "ERROR",
        };
        eprintln!("[gsplit {tag}] {args}");
    }
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, format_args!($($arg)*)) };
}
