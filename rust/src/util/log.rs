//! Tiny leveled logger (stderr). `GSPLIT_LOG=debug|info|warn|error|off`
//! selects verbosity; defaults to `info`. An unrecognized value falls back
//! to `info` with a one-time warning naming the bad value (it used to fall
//! back silently).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

/// Threshold above every message level: `GSPLIT_LOG=off` silences all
/// output.
const OFF: u8 = Level::Error as u8 + 1;

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static INIT: OnceLock<()> = OnceLock::new();

/// Parse one `GSPLIT_LOG` value into a threshold for `LEVEL`.
fn parse_env_level(s: &str) -> Option<u8> {
    match s {
        "debug" => Some(Level::Debug as u8),
        "info" => Some(Level::Info as u8),
        "warn" => Some(Level::Warn as u8),
        "error" => Some(Level::Error as u8),
        "off" => Some(OFF),
        _ => None,
    }
}

/// Resolve the raw env lookup to a threshold, plus the invalid value to
/// warn about (once), if any. Pure so the init policy is unit-testable —
/// the `OnceLock` wrapper below only runs it a single time.
fn resolve(env: Option<&str>) -> (u8, Option<&str>) {
    match env {
        None => (Level::Info as u8, None),
        Some(s) => match parse_env_level(s) {
            Some(t) => (t, None),
            None => (Level::Info as u8, Some(s)),
        },
    }
}

fn ensure_init() {
    INIT.get_or_init(|| {
        let var = std::env::var("GSPLIT_LOG").ok();
        let (threshold, bad) = resolve(var.as_deref());
        if let Some(bad) = bad {
            // Direct eprintln: routing through log() here would re-enter
            // the OnceLock initializer.
            eprintln!(
                "[gsplit WARN ] invalid GSPLIT_LOG value `{bad}` \
                 (expected debug|info|warn|error|off); using info"
            );
        }
        LEVEL.store(threshold, Ordering::Relaxed);
    });
}

pub fn enabled(level: Level) -> bool {
    ensure_init();
    level as u8 >= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        let tag = match level {
            Level::Debug => "DEBUG",
            Level::Info => "INFO ",
            Level::Warn => "WARN ",
            Level::Error => "ERROR",
        };
        eprintln!("[gsplit {tag}] {args}");
    }
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_documented_value() {
        assert_eq!(parse_env_level("debug"), Some(Level::Debug as u8));
        assert_eq!(parse_env_level("info"), Some(Level::Info as u8));
        assert_eq!(parse_env_level("warn"), Some(Level::Warn as u8));
        assert_eq!(parse_env_level("error"), Some(Level::Error as u8));
        assert_eq!(parse_env_level("off"), Some(OFF));
    }

    #[test]
    fn rejects_unknown_and_miscased_values() {
        for bad in ["INFO", "Debug", "trace", "verbose", "", " info"] {
            assert_eq!(parse_env_level(bad), None, "`{bad}` must not parse");
        }
    }

    #[test]
    fn off_silences_every_level() {
        for lvl in [Level::Debug, Level::Info, Level::Warn, Level::Error] {
            assert!((lvl as u8) < OFF, "{lvl:?} must be below the off threshold");
        }
    }

    #[test]
    fn resolve_unset_defaults_to_info_without_warning() {
        assert_eq!(resolve(None), (Level::Info as u8, None));
    }

    #[test]
    fn resolve_valid_value_sets_threshold_without_warning() {
        assert_eq!(resolve(Some("error")), (Level::Error as u8, None));
        assert_eq!(resolve(Some("off")), (OFF, None));
    }

    #[test]
    fn resolve_invalid_value_falls_back_to_info_and_names_it() {
        // Regression: an invalid value used to fall back silently.
        assert_eq!(resolve(Some("loud")), (Level::Info as u8, Some("loud")));
    }

    #[test]
    fn level_ordering_matches_severity() {
        assert!(Level::Debug < Level::Info);
        assert!(Level::Info < Level::Warn);
        assert!(Level::Warn < Level::Error);
    }
}
