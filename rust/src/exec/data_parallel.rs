//! Data-parallel engines: DGL (no distributed cache) and Quiver
//! (distributed NVLink cache with cross-clique replication).
//!
//! Each GPU independently samples its own micro-batch of the mini-batch's
//! target vertices and loads the input features of *all* vertices in its
//! bottom layer — the redundant loading/computation the paper's Table 1
//! quantifies and GSplit eliminates.

use crate::cache::{FeatureCache, FetchSource};
use crate::costmodel::IterCounters;
use crate::exec::{add_grad_allreduce, micro_batches, Engine, EngineCtx};
use crate::graph::FeatureSource;
use crate::presample::PresampleWeights;
use crate::rng::{derive_seed, Pcg32};
use crate::sampling::Sampler;
use crate::{DeviceId, Vid};

/// Which cache policy the data-parallel engine runs with.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Policy {
    Dgl,
    Quiver,
}

pub struct DataParallel {
    policy: Policy,
    cache: FeatureCache,
    samplers: Vec<Sampler>,
}

impl DataParallel {
    /// DGL: no distributed cache (DGL only caches graphs that fully fit on
    /// one GPU, which never holds for the evaluated graphs — §7.1).
    pub fn dgl(ctx: &EngineCtx) -> Self {
        DataParallel {
            policy: Policy::Dgl,
            cache: FeatureCache::none(ctx.ds.graph.num_vertices(), ctx.k()),
            samplers: (0..ctx.k()).map(|_| Sampler::new()).collect(),
        }
    }

    /// Quiver: hottest vertices (pre-sampling frequency ranking, the
    /// GNNLab criterion both Quiver and GSplit use in §7.1) partitioned
    /// across NVLink cliques and replicated across them.
    pub fn quiver(ctx: &EngineCtx, weights: &PresampleWeights, batch_size: usize) -> Self {
        let rows = ctx.cache_rows(batch_size);
        DataParallel {
            policy: Policy::Quiver,
            cache: FeatureCache::distributed(&weights.vertex, rows, &ctx.topo),
            samplers: (0..ctx.k()).map(|_| Sampler::new()).collect(),
        }
    }

    pub fn cache(&self) -> &FeatureCache {
        &self.cache
    }
}

impl Engine for DataParallel {
    fn name(&self) -> &'static str {
        match self.policy {
            Policy::Dgl => "DGL",
            Policy::Quiver => "Quiver",
        }
    }

    fn iteration(&mut self, ctx: &EngineCtx, targets: &[Vid], seed: u64) -> IterCounters {
        let k = ctx.k();
        let mut c = IterCounters::new(k);
        let row_bytes = ctx.ds.features.row_bytes();
        let micro = micro_batches(targets, k);
        for (d, mtargets) in micro.iter().enumerate() {
            if mtargets.is_empty() {
                continue;
            }
            let mut rng = Pcg32::new(derive_seed(seed, &[d as u64]));
            let mb = self.samplers[d].sample(&ctx.ds.graph, mtargets, &ctx.fanouts, &mut rng);
            // --- sampling work ---
            c.sampled_edges[d] = mb.total_edges();
            // --- loading: every bottom-layer source, from cache or host ---
            for &v in mb.input_vertices() {
                match self.cache.fetch_source(v, d as DeviceId, &ctx.topo) {
                    FetchSource::Local => {}
                    FetchSource::Peer(o) => c.peer_load.add(o, d as DeviceId, row_bytes),
                    FetchSource::Host => c.host_load_bytes[d] += row_bytes,
                }
            }
            // --- forward compute (per layer) ---
            for (i, layer) in mb.layers.iter().enumerate() {
                let l = ctx.model_layer(i);
                c.fwd_flops[d] +=
                    ctx.model.layer_fwd_flops(l, layer.num_dst() as u64, layer.num_edges());
                c.agg_bytes[d] +=
                    ctx.model.layer_agg_bytes(l, layer.num_dst() as u64, layer.num_edges());
            }
        }
        add_grad_allreduce(&mut c, ctx.param_bytes());
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::Topology;
    use crate::graph::StandIn;
    use crate::model::GnnKind;

    fn ctx(ds: &crate::graph::Dataset) -> EngineCtx<'_> {
        EngineCtx::new(ds, Topology::p3_8xlarge(1.0), GnnKind::GraphSage, 64, 2, 5)
    }

    #[test]
    fn dgl_loads_everything_from_host() {
        let ds = StandIn::Tiny.load().unwrap();
        let ctx = ctx(&ds);
        let mut e = DataParallel::dgl(&ctx);
        let targets: Vec<Vid> = (0..128).collect();
        let c = e.iteration(&ctx, &targets, 1);
        assert!(c.host_load_bytes.iter().sum::<u64>() > 0);
        assert_eq!(c.peer_load.total_remote(), 0, "DGL has no distributed cache");
        assert!(c.sampled_edges.iter().all(|&e| e > 0));
        assert!(c.fwd_flops.iter().all(|&f| f > 0));
    }

    #[test]
    fn quiver_cache_cuts_host_loads() {
        let ds = StandIn::Tiny.load().unwrap();
        let ctx = ctx(&ds);
        let weights = PresampleWeights::uniform(&ds.graph);
        let mut dgl = DataParallel::dgl(&ctx);
        let mut quiver = DataParallel::quiver(&ctx, &weights, 128);
        assert!(quiver.cache().coverage() > 0.9, "tiny graph should fully fit");
        let targets: Vec<Vid> = (0..128).collect();
        let cd = dgl.iteration(&ctx, &targets, 1);
        let cq = quiver.iteration(&ctx, &targets, 1);
        let (hd, hq) = (
            cd.host_load_bytes.iter().sum::<u64>(),
            cq.host_load_bytes.iter().sum::<u64>(),
        );
        assert!(hq < hd / 10, "quiver host loads {hq} should be ≪ dgl {hd}");
        assert!(cq.peer_load.total_remote() > 0, "quiver uses NVLink peers");
        // Sampling and compute identical (same micro-batches, same seed).
        assert_eq!(cd.sampled_edges, cq.sampled_edges);
        assert_eq!(cd.fwd_flops, cq.fwd_flops);
    }

    #[test]
    fn iterations_are_deterministic() {
        let ds = StandIn::Tiny.load().unwrap();
        let ctx = ctx(&ds);
        let mut e = DataParallel::dgl(&ctx);
        let targets: Vec<Vid> = (50..150).collect();
        let a = e.iteration(&ctx, &targets, 7);
        let b = e.iteration(&ctx, &targets, 7);
        assert_eq!(a.sampled_edges, b.sampled_edges);
        assert_eq!(a.host_load_bytes, b.host_load_bytes);
        let c = e.iteration(&ctx, &targets, 8);
        assert_ne!(a.sampled_edges, c.sampled_edges);
    }
}
