//! CAGNET-style 1D full-graph engine (Tripathy et al., SC'20): no
//! sampling at all — every epoch is one full-graph forward/backward pass.
//! The adjacency (and the feature matrix with it) is row-partitioned into
//! `k` contiguous blocks, one per GPU; at every layer each GPU aggregates
//! the *full* neighborhoods of its owned rows, which requires an
//! all-to-all of the activation rows owned by other partitions. A remote
//! row transfers **once per needing device per layer** (the CAGNET
//! broadcast is counted at its useful volume), at that layer's input
//! width.
//!
//! This is the sampling-free baseline the paper's mini-batch systems are
//! implicitly compared against: S is (near) zero, but L and the shuffle
//! volume scale with the whole graph instead of a mini-batch frontier.

use crate::costmodel::IterCounters;
use crate::exec::{add_grad_allreduce, Engine, EngineCtx};
use crate::graph::{FeatureSource, HostTier};
use crate::{DeviceId, Vid};

pub struct FullGraph {
    k: usize,
    /// Exclusive upper bound of each device's contiguous vertex block:
    /// device `d` owns rows `[bounds[d-1], bounds[d])` (with `bounds[-1]`
    /// read as 0).
    bounds: Vec<usize>,
}

impl FullGraph {
    /// Row-partition the graph in `ctx` into `ctx.k()` contiguous blocks.
    pub fn new(ctx: &EngineCtx) -> Self {
        let n = ctx.ds.graph.num_vertices();
        let k = ctx.k();
        FullGraph { k, bounds: (1..=k).map(|d| d * n / k).collect() }
    }

    /// Device owning vertex `v` under the 1D row partition.
    pub fn owner(&self, v: Vid) -> usize {
        self.bounds.partition_point(|&b| b <= v as usize)
    }

    /// Half-open vertex range `[lo, hi)` owned by device `d`.
    pub fn block(&self, d: usize) -> (usize, usize) {
        let lo = if d == 0 { 0 } else { self.bounds[d - 1] };
        (lo, self.bounds[d])
    }
}

impl Engine for FullGraph {
    fn name(&self) -> &'static str {
        "FullGraph"
    }

    /// One full-graph pass. `targets` and `seed` are ignored: full-graph
    /// training touches every vertex every epoch and has no sampling
    /// randomness, so callers should run **one** iteration per epoch
    /// (e.g. `run_epoch` with `batch_size >= |targets|`).
    fn iteration(&mut self, ctx: &EngineCtx, _targets: &[Vid], _seed: u64) -> IterCounters {
        let mut c = IterCounters::new(self.k);
        let g = &ctx.ds.graph;
        let row_bytes = ctx.ds.features.row_bytes();
        // Loading: the feature matrix is partitioned with the rows — each
        // device stages exactly its own block from the host, split by the
        // feature source's tier like the mini-batch engines (`probe_row`
        // advances the same chunk-buffer state as a real fetch).
        for d in 0..self.k {
            let (lo, hi) = self.block(d);
            for v in lo..hi {
                match ctx.ds.features.probe_row(v as Vid) {
                    HostTier::Ram => c.host_load_bytes[d] += row_bytes,
                    HostTier::Disk => c.disk_load_bytes[d] += row_bytes,
                }
            }
        }
        // Per layer (model order, bottom up): full-neighborhood aggregation
        // over owned rows plus the all-to-all of remote activation rows.
        // `seen` deduplicates remote rows per (layer, destination device) —
        // a row crosses each needed link once per layer.
        let mut seen = vec![u32::MAX; g.num_vertices()];
        for l in 0..ctx.model.num_layers {
            let hid_bytes = ctx.model.row_bytes_in(l);
            for d in 0..self.k {
                let stamp = (l * self.k + d) as u32;
                let (lo, hi) = self.block(d);
                let mut edges = 0u64;
                for v in lo..hi {
                    for &u in g.neighbors(v as Vid) {
                        edges += 1;
                        let o = self.owner(u);
                        if o != d && seen[u as usize] != stamp {
                            seen[u as usize] = stamp;
                            c.train_comm.add(o as DeviceId, d as DeviceId, hid_bytes);
                        }
                    }
                }
                c.fwd_flops[d] += ctx.model.layer_fwd_flops(l, (hi - lo) as u64, edges);
                c.agg_bytes[d] += ctx.model.layer_agg_bytes(l, (hi - lo) as u64, edges);
            }
        }
        add_grad_allreduce(&mut c, ctx.param_bytes());
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::Topology;
    use crate::graph::StandIn;
    use crate::model::GnnKind;

    fn ctx(ds: &crate::graph::Dataset, topo: Topology) -> EngineCtx<'_> {
        EngineCtx::new(ds, topo, GnnKind::GraphSage, 64, 2, 5)
    }

    #[test]
    fn blocks_cover_vertices_and_owner_agrees() {
        let ds = StandIn::Tiny.load().unwrap();
        let c = ctx(&ds, Topology::p3_8xlarge(1.0));
        let fg = FullGraph::new(&c);
        let n = ds.graph.num_vertices();
        let mut covered = 0usize;
        for d in 0..c.k() {
            let (lo, hi) = fg.block(d);
            covered += hi - lo;
            for v in lo..hi {
                assert_eq!(fg.owner(v as Vid), d, "vertex {v}");
            }
        }
        assert_eq!(covered, n, "blocks must partition the vertex set");
    }

    #[test]
    fn processes_every_edge_every_layer_without_sampling() {
        let ds = StandIn::Tiny.load().unwrap();
        let c = ctx(&ds, Topology::p3_8xlarge(1.0));
        let mut fg = FullGraph::new(&c);
        let out = fg.iteration(&c, &[], 0);
        // No sampling phase at all.
        assert_eq!(out.sampled_edges.iter().sum::<u64>(), 0);
        assert_eq!(out.sample_comm.total_remote(), 0);
        // Full feature matrix loaded exactly once.
        let loaded: u64 =
            out.host_load_bytes.iter().sum::<u64>() + out.disk_load_bytes.iter().sum::<u64>();
        assert_eq!(loaded, ds.graph.num_vertices() as u64 * ds.features.row_bytes());
        // Compute covers owned rows on every device.
        assert!(out.fwd_flops.iter().all(|&f| f > 0), "{:?}", out.fwd_flops);
    }

    #[test]
    fn deterministic_and_target_independent() {
        let ds = StandIn::Tiny.load().unwrap();
        let c = ctx(&ds, Topology::p3_8xlarge(1.0));
        let mut fg = FullGraph::new(&c);
        let a = fg.iteration(&c, &[1, 2, 3], 7);
        let b = fg.iteration(&c, &[], 99);
        assert_eq!(a.train_comm, b.train_comm);
        assert_eq!(a.fwd_flops, b.fwd_flops);
        assert_eq!(a.host_load_bytes, b.host_load_bytes);
    }

    #[test]
    fn remote_rows_dedup_per_layer_and_destination() {
        let ds = StandIn::Tiny.load().unwrap();
        let c = ctx(&ds, Topology::p3_8xlarge(1.0));
        let mut fg = FullGraph::new(&c);
        let out = fg.iteration(&c, &[], 0);
        // Upper bound: every remote row at most once per (layer, dst) pair,
        // i.e. strictly less than counting one transfer per cross edge.
        let mut per_edge = 0u64;
        for l in 0..c.model.num_layers {
            let w = c.model.row_bytes_in(l);
            for d in 0..c.k() {
                let (lo, hi) = fg.block(d);
                for v in lo..hi {
                    for &u in ds.graph.neighbors(v as Vid) {
                        if fg.owner(u) != d {
                            per_edge += w;
                        }
                    }
                }
            }
        }
        let allreduce = {
            let mut base = IterCounters::new(c.k());
            add_grad_allreduce(&mut base, c.param_bytes());
            base.train_comm.total_remote()
        };
        let shuffled = out.train_comm.total_remote() - allreduce;
        assert!(shuffled > 0, "cross-partition edges must shuffle rows");
        assert!(shuffled <= per_edge, "dedup must not exceed per-edge counting");
    }

    #[test]
    fn single_gpu_has_no_shuffle_or_allreduce() {
        let ds = StandIn::Tiny.load().unwrap();
        let c = ctx(&ds, Topology::single_host(1, false, 1.0));
        let mut fg = FullGraph::new(&c);
        let out = fg.iteration(&c, &[], 0);
        assert_eq!(out.train_comm.total_remote(), 0);
    }
}
