//! P3*-style push-pull parallelism (paper §2.2, Figure 1(b)) adapted to a
//! single-host multi-GPU setting, exactly as the paper's own P3*
//! re-implementation:
//!
//! * input features are stored as **slices**: each GPU keeps `1/k` of every
//!   vertex's feature vector (only possible when the full feature matrix
//!   fits across the GPUs; otherwise P3* loads features from host like
//!   data parallelism — it "cannot cache input features for only a subset
//!   of the vertices", §7.1),
//! * every GPU computes *partial* bottom-layer activations for **all**
//!   micro-batches on its slice (model-parallel bottom layer),
//! * a push-pull shuffle reduces the partials to the micro-batch owner,
//!   after which the remaining layers run data-parallel.

use crate::costmodel::IterCounters;
use crate::exec::{add_grad_allreduce, micro_batches, Engine, EngineCtx};
use crate::graph::FeatureSource;
use crate::rng::{derive_seed, Pcg32};
use crate::sampling::Sampler;
use crate::{DeviceId, Vid};

pub struct PushPull {
    /// Whether the feature matrix fits sliced across the GPUs.
    sliced: bool,
    samplers: Vec<Sampler>,
}

impl PushPull {
    pub fn new(ctx: &EngineCtx, batch_size: usize) -> Self {
        // Paper-scale fit test: a 1/k slice of every feature vector must
        // fit in the per-GPU budget (§7.1: P3* only uses caching when the
        // whole graph's features fit — Orkut).
        let total_feat_full =
            (ctx.ds.spec.feature_bytes() as f64 * ctx.ds.spec.scale_divisor) as u64;
        let k = ctx.k() as u64;
        let sliced = total_feat_full / k <= ctx.paper_scale_cache_budget(batch_size);
        PushPull { sliced, samplers: (0..ctx.k()).map(|_| Sampler::new()).collect() }
    }

    pub fn is_sliced(&self) -> bool {
        self.sliced
    }
}

impl Engine for PushPull {
    fn name(&self) -> &'static str {
        "P3*"
    }

    fn iteration(&mut self, ctx: &EngineCtx, targets: &[Vid], seed: u64) -> IterCounters {
        let k = ctx.k();
        let mut c = IterCounters::new(k);
        let row_bytes = ctx.ds.features.row_bytes();
        let micro = micro_batches(targets, k);
        // Sample all micro-batches (as data parallel does).
        let mbs: Vec<_> = micro
            .iter()
            .enumerate()
            .map(|(d, mtargets)| {
                let mut rng = Pcg32::new(derive_seed(seed, &[d as u64]));
                self.samplers[d].sample(&ctx.ds.graph, mtargets, &ctx.fanouts, &mut rng)
            })
            .collect();

        let bottom_idx = ctx.fanouts.len() - 1; // sampled-layer index of the bottom
        let bottom_l = 0; // model layer index
        let dout0 = ctx.model.out_dim(bottom_l) as u64;

        for (d, mb) in mbs.iter().enumerate() {
            c.sampled_edges[d] = mb.total_edges();

            // --- loading ---
            let num_inputs = mb.input_vertices().len() as u64;
            if self.sliced {
                // Features live sliced on the GPUs; the owner of micro-batch
                // d must broadcast its bottom-layer *structure* (vertex ids +
                // neighbor indices) to every other GPU so they can compute
                // partials. 8 bytes per bottom-layer entry.
                let struct_bytes = (num_inputs
                    + mb.layers[bottom_idx].num_edges())
                    * 8;
                for o in 0..k {
                    if o != d {
                        c.sample_comm.add(d as DeviceId, o as DeviceId, struct_bytes);
                    }
                }
            } else {
                // No slicing possible: every GPU pulls the slice columns of
                // all inputs of the *whole mini-batch* from host memory
                // (paper: "P3* loads all the features in the mini-batch").
                let union_inputs: u64 = mbs.iter().map(|m| m.input_vertices().len() as u64).sum();
                c.host_load_bytes[d] += union_inputs * row_bytes / k as u64;
            }

            // --- bottom layer: model parallel over feature slices ---
            // Each GPU computes partials for ALL micro-batches on 1/k of
            // the input width: aggregate work equals the full bottom layer
            // of every micro-batch, split evenly.
            let bottom = &mb.layers[bottom_idx];
            let bot_flops =
                ctx.model.layer_fwd_flops(bottom_l, bottom.num_dst() as u64, bottom.num_edges());
            let bot_agg =
                ctx.model.layer_agg_bytes(bottom_l, bottom.num_dst() as u64, bottom.num_edges());
            for g in 0..k {
                c.fwd_flops[g] += bot_flops / k as u64;
                c.agg_bytes[g] += bot_agg / k as u64;
            }
            // Push: every GPU g ≠ d sends its partial activations for micro-
            // batch d's bottom destinations to d (reduce at owner).
            let push_bytes = bottom.num_dst() as u64 * dout0 * 4;
            for g in 0..k {
                if g != d {
                    c.train_comm.add(g as DeviceId, d as DeviceId, push_bytes);
                }
            }

            // --- upper layers: data parallel on the owner GPU ---
            for (i, layer) in mb.layers.iter().enumerate() {
                if i == bottom_idx {
                    continue;
                }
                let l = ctx.model_layer(i);
                c.fwd_flops[d] +=
                    ctx.model.layer_fwd_flops(l, layer.num_dst() as u64, layer.num_edges());
                c.agg_bytes[d] +=
                    ctx.model.layer_agg_bytes(l, layer.num_dst() as u64, layer.num_edges());
            }
        }
        add_grad_allreduce(&mut c, ctx.param_bytes());
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::Topology;
    use crate::exec::DataParallel;
    use crate::graph::StandIn;
    use crate::model::GnnKind;

    fn ctx(ds: &crate::graph::Dataset, divisor: f64) -> EngineCtx<'_> {
        EngineCtx::new(ds, Topology::p3_8xlarge(divisor), GnnKind::GraphSage, 64, 2, 5)
    }

    #[test]
    fn sliced_when_features_fit() {
        let ds = StandIn::Tiny.load().unwrap();
        let ctx1 = ctx(&ds, 1.0);
        let pp = PushPull::new(&ctx1, 128);
        assert!(pp.is_sliced(), "tiny features fit easily at full GPU memory");
    }

    #[test]
    fn sliced_mode_has_no_host_loads_but_shuffles() {
        let ds = StandIn::Tiny.load().unwrap();
        let ctx = ctx(&ds, 1.0);
        let mut pp = PushPull::new(&ctx, 128);
        let targets: Vec<Vid> = (0..128).collect();
        let c = pp.iteration(&ctx, &targets, 3);
        assert_eq!(c.host_load_bytes.iter().sum::<u64>(), 0);
        assert!(c.train_comm.total_remote() > 0, "push-pull must shuffle partials");
    }

    #[test]
    fn pushpull_shuffles_more_than_it_saves_vs_quiver_shape() {
        // The paper's qualitative claim: P3*'s shuffle bytes exceed split
        // parallelism's (tested cross-engine in integration tests); here
        // check partial-activation volume scales with bottom dst count.
        let ds = StandIn::Tiny.load().unwrap();
        let ctx = ctx(&ds, 1.0);
        let mut pp = PushPull::new(&ctx, 256);
        let c_small = pp.iteration(&ctx, &(0..64).collect::<Vec<_>>(), 1);
        let c_big = pp.iteration(&ctx, &(0..256).collect::<Vec<_>>(), 1);
        assert!(c_big.train_comm.total_remote() > 2 * c_small.train_comm.total_remote());
    }

    #[test]
    fn compute_is_balanced_across_gpus_for_bottom_layer() {
        let ds = StandIn::Tiny.load().unwrap();
        let ctx = ctx(&ds, 1.0);
        let mut pp = PushPull::new(&ctx, 128);
        let mut dp = DataParallel::dgl(&ctx);
        let targets: Vec<Vid> = (0..128).collect();
        let cp = pp.iteration(&ctx, &targets, 5);
        let cd = dp.iteration(&ctx, &targets, 5);
        // Same sampling; P3* redistributes bottom-layer flops evenly, so
        // total flops match data parallel (same work, different placement).
        let (tp, td): (u64, u64) = (cp.fwd_flops.iter().sum(), cd.fwd_flops.iter().sum());
        let diff = (tp as f64 - td as f64).abs() / td as f64;
        assert!(diff < 0.02, "total flops should match: p3*={tp} dgl={td}");
    }
}
