//! Training engines — one per system in the paper's evaluation (§7.1):
//!
//! | Engine | Paper system | Parallelism | Cache |
//! |---|---|---|---|
//! | [`DataParallel::dgl`]    | DGL    | data parallel | none |
//! | [`DataParallel::quiver`] | Quiver | data parallel | distributed (NVLink, replicated across cliques) |
//! | [`PushPull`]             | P3\*   | push-pull hybrid | feature slices (full graphs only) |
//! | [`FullGraph`]            | CAGNET (1D) | full-graph, row-partitioned | none (features partitioned with the rows) |
//! | [`SplitParallel`]        | GSplit | split parallel | partitioned, consistent with `f_G` |
//!
//! Engines execute the *real* sampling / splitting / cache-lookup / shuffle
//! logic and record exact counts into [`IterCounters`]; the cost model
//! turns counts into the paper's S/L/FB seconds. The same structures drive
//! the real-compute training path (`train/`).

mod data_parallel;
mod full_graph;
mod push_pull;
mod split_parallel;

pub use data_parallel::DataParallel;
pub use full_graph::FullGraph;
pub use push_pull::PushPull;
pub use split_parallel::SplitParallel;

use crate::costmodel::{iter_time, IterCounters, PhaseBreakdown};
use crate::devices::Topology;
use crate::graph::{Dataset, FeatureSource};
use crate::model::{GnnKind, ModelConfig};
use crate::rng::derive_seed;
use crate::{DeviceId, Vid};

/// Everything an engine needs besides its own state.
pub struct EngineCtx<'a> {
    pub ds: &'a Dataset,
    pub topo: Topology,
    pub model: ModelConfig,
    /// Per-layer fanouts, top layer first (uniform in the paper).
    pub fanouts: Vec<usize>,
}

impl<'a> EngineCtx<'a> {
    pub fn new(
        ds: &'a Dataset,
        topo: Topology,
        kind: GnnKind,
        hidden: usize,
        num_layers: usize,
        fanout: usize,
    ) -> Self {
        let model = ModelConfig {
            kind,
            feat_dim: ds.spec.feat_dim,
            hidden,
            // Stand-in labels use 16 classes; only affects the top layer's
            // (tiny) output dim in the cost accounting.
            num_classes: 16,
            num_layers,
        };
        EngineCtx { ds, topo, model, fanouts: vec![fanout; num_layers] }
    }

    pub fn k(&self) -> usize {
        self.topo.num_gpus()
    }

    /// Map a sampled-layer index (0 = top) to the model layer index
    /// (0 = bottom) used for dims/FLOPs.
    pub fn model_layer(&self, sampled_idx: usize) -> usize {
        self.model.num_layers - 1 - sampled_idx
    }

    /// Total parameter bytes (for the gradient all-reduce accounting).
    pub fn param_bytes(&self) -> u64 {
        let mut total = 0u64;
        for l in 0..self.model.num_layers {
            let (din, dout) = (self.model.in_dim(l) as u64, self.model.out_dim(l) as u64);
            total += match self.model.kind {
                GnnKind::GraphSage => 2 * din * dout + dout,
                GnnKind::Gat => din * dout + 3 * dout,
            };
        }
        total * 4
    }

    /// Per-GPU training workspace estimate (bytes): activations and sample
    /// structures for one in-flight mini-batch (paper §7.1: systems
    /// "allocate sufficient memory to sample and train without OOM").
    ///
    /// This is a **paper-scale** quantity: the mini-batch (and therefore
    /// the workspace) does not shrink with the dataset stand-in — batch
    /// size and fanout are the paper's. All memory budgeting happens at
    /// paper scale and only the final cache row count is divided by the
    /// dataset's `scale_divisor` (see `cache_rows`).
    pub fn workspace_bytes(&self, batch_size: usize) -> u64 {
        let mut rows = batch_size as u64;
        let mut total_rows = rows;
        for &f in &self.fanouts {
            rows *= (f + 1) as u64;
            total_rows += rows;
        }
        // Activations (hidden width) + input features at the bottom +
        // index structures; 3× slack for fwd+bwd temporaries.
        let per_gpu_rows = total_rows / self.k() as u64;
        3 * per_gpu_rows * (self.model.hidden.max(self.model.feat_dim) as u64 * 4 + 16)
    }

    /// Per-GPU memory left for caching, in bytes, **at paper scale**
    /// (16 GB V100 minus the topology share and the training workspace).
    pub fn paper_scale_cache_budget(&self, batch_size: usize) -> u64 {
        let div = self.ds.spec.scale_divisor;
        let gpu_full = (self.topo.hw.gpu_mem as f64 * div) as u64;
        let topo_full =
            ((self.ds.graph.topology_bytes() as f64 * div) as u64) / self.k() as u64;
        gpu_full
            .saturating_sub(topo_full)
            .saturating_sub(self.workspace_bytes(batch_size))
    }

    /// Per-GPU cache capacity in feature rows at stand-in scale: the
    /// paper-scale row budget divided by the dataset's scale factor, so
    /// the *cache-fit fraction* matches the paper's testbed.
    pub fn cache_rows(&self, batch_size: usize) -> u64 {
        let budget = self.paper_scale_cache_budget(batch_size);
        let rows_full = budget / self.ds.features.row_bytes().max(1);
        (rows_full as f64 / self.ds.spec.scale_divisor) as u64
    }
}

/// A mini-batch training engine.
///
/// # Example
///
/// Count one epoch of the DGL-like data-parallel engine and convert the
/// counters into the paper's S/L/FB seconds:
///
/// ```no_run
/// use gsplit::devices::Topology;
/// use gsplit::exec::{run_epoch, DataParallel, EngineCtx};
/// use gsplit::graph::StandIn;
/// use gsplit::model::GnnKind;
///
/// let ds = StandIn::Tiny.load().unwrap();
/// let topo = Topology::p3_8xlarge(ds.spec.scale_divisor);
/// let ctx = EngineCtx::new(&ds, topo, GnnKind::GraphSage, 64, 3, 5);
/// let mut dgl = DataParallel::dgl(&ctx);
/// let (counters, time) = run_epoch(&mut dgl, &ctx, 256, 42);
/// println!(
///     "S+L+FB = {:.3}s over {} sampled edges",
///     time.total(),
///     counters.sampled_edges.iter().sum::<u64>()
/// );
/// ```
pub trait Engine {
    fn name(&self) -> &'static str;

    /// Execute one mini-batch iteration (counting only — the real-compute
    /// path lives in `train/`). `seed` must be unique per iteration.
    fn iteration(&mut self, ctx: &EngineCtx, targets: &[Vid], seed: u64) -> IterCounters;
}

/// Run one epoch: shuffled targets, `batch_size` chunks, summed counters
/// and modeled S/L/FB time.
pub fn run_epoch(
    engine: &mut dyn Engine,
    ctx: &EngineCtx,
    batch_size: usize,
    epoch_seed: u64,
) -> (IterCounters, PhaseBreakdown) {
    let targets = ctx.ds.epoch_targets(epoch_seed);
    let mut total = IterCounters::new(ctx.k());
    let mut time = PhaseBreakdown::default();
    for (i, chunk) in targets.chunks(batch_size).enumerate() {
        let c = engine.iteration(ctx, chunk, derive_seed(epoch_seed, &[i as u64]));
        time.add(iter_time(&c, &ctx.topo));
        total.merge(&c);
    }
    total.record_metrics(engine.name());
    (total, time)
}

/// Add the synchronous gradient all-reduce to the FB communication: ring
/// all-reduce moves `2·P·(k-1)/k` bytes per GPU along the ring.
pub(crate) fn add_grad_allreduce(c: &mut IterCounters, param_bytes: u64) {
    let k = c.k;
    if k <= 1 {
        return;
    }
    let per_link = 2 * param_bytes * (k as u64 - 1) / k as u64;
    for d in 0..k {
        let next = ((d + 1) % k) as DeviceId;
        c.train_comm.add(d as DeviceId, next, per_link);
    }
}

/// Round-robin partition of the mini-batch targets into `k` micro-batches
/// (data-parallel systems; the paper partitions targets among GPUs).
pub(crate) fn micro_batches(targets: &[Vid], k: usize) -> Vec<Vec<Vid>> {
    let mut out = vec![Vec::with_capacity(targets.len() / k + 1); k];
    for (i, &t) in targets.iter().enumerate() {
        out[i % k].push(t);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::StandIn;

    #[test]
    fn micro_batches_cover_targets() {
        let t: Vec<Vid> = (0..10).collect();
        let mb = micro_batches(&t, 4);
        assert_eq!(mb.len(), 4);
        assert_eq!(mb[0], vec![0, 4, 8]);
        assert_eq!(mb[3], vec![3, 7]);
        let total: usize = mb.iter().map(Vec::len).sum();
        assert_eq!(total, 10);
    }

    #[test]
    fn grad_allreduce_scales_with_k() {
        let mut c2 = IterCounters::new(2);
        add_grad_allreduce(&mut c2, 1000);
        assert_eq!(c2.train_comm.total_remote(), 2 * 1000); // 2 links × P
        let mut c1 = IterCounters::new(1);
        add_grad_allreduce(&mut c1, 1000);
        assert_eq!(c1.train_comm.total_remote(), 0);
    }

    #[test]
    fn ctx_basics() {
        let ds = StandIn::Tiny.load().unwrap();
        let topo = Topology::p3_8xlarge(1.0);
        let ctx = EngineCtx::new(&ds, topo, GnnKind::GraphSage, 64, 3, 5);
        assert_eq!(ctx.k(), 4);
        assert_eq!(ctx.model_layer(0), 2);
        assert_eq!(ctx.model_layer(2), 0);
        assert!(ctx.param_bytes() > 0);
        assert!(ctx.cache_rows(256) > 0);
    }
}
