//! GSplit's split-parallel engine (paper §3–§5): one mini-batch per
//! iteration, cooperatively sampled and split across GPUs by the online
//! splitting function, with non-overlapping feature loads, a partitioned
//! cache consistent with `f_G`, and per-layer all-to-all shuffles whose
//! volume the shuffle index determines exactly.
//!
//! Multi-host (paper §7.4): data parallelism **across** hosts — targets are
//! partitioned per host, each host runs split parallelism internally over
//! its own 4 GPUs, and gradients all-reduce across everything.

use crate::cache::{FeatureCache, FetchSource};
use crate::costmodel::IterCounters;
use crate::exec::{add_grad_allreduce, Engine, EngineCtx};
use crate::graph::{FeatureSource, HostTier};
use crate::partition::Partitioning;
use crate::rng::derive_seed;
use crate::split::{SplitPlan, SplitSampler};
use crate::{DeviceId, Vid};

/// Bytes shuffled per remote vertex during *sampling* (vertex id + shuffle
/// index slot).
const SAMPLE_ROW_BYTES: u64 = 8;

pub struct SplitParallel {
    /// Global partitioning function f_G (per-GPU, global device ids).
    part: Partitioning,
    cache: FeatureCache,
    samplers: Vec<SplitSampler>,
    gpus_per_host: usize,
    num_hosts: usize,
}

impl SplitParallel {
    /// Single- or multi-host engine. `part` must assign vertices to all
    /// `ctx.k()` global GPUs; `ranking` orders vertices for the cache
    /// (pre-sample frequency). For multi-host, all hosts cache the same
    /// features on their GPUs (§7.4) — ownership within a host follows
    /// `part` modulo the host's GPU block.
    pub fn new(ctx: &EngineCtx, part: Partitioning, ranking: &[u64], batch_size: usize) -> Self {
        assert_eq!(part.k, ctx.k(), "partitioning must cover all GPUs");
        let rows = ctx.cache_rows(batch_size);
        let cache = FeatureCache::partitioned(ranking, rows, &part);
        let num_hosts = ctx.topo.num_hosts;
        let gpus_per_host = ctx.topo.gpus_per_host;
        SplitParallel {
            part,
            cache,
            samplers: (0..num_hosts).map(|_| SplitSampler::new(gpus_per_host)).collect(),
            gpus_per_host,
            num_hosts,
        }
    }

    pub fn cache(&self) -> &FeatureCache {
        &self.cache
    }

    pub fn partitioning(&self) -> &Partitioning {
        &self.part
    }

    /// Produce the split plan for one host's share of the targets (also
    /// used by the real-compute trainer).
    pub fn plan_for_host(
        &mut self,
        ctx: &EngineCtx,
        host: usize,
        targets: &[Vid],
        seed: u64,
    ) -> SplitPlan {
        // Host-local partitioning: vertex → GPU within this host's block.
        let local = self.host_local_part(host);
        self.samplers[host].sample(
            &ctx.ds.graph,
            targets,
            &ctx.fanouts,
            &local,
            derive_seed(seed, &[host as u64, 0x5911]),
        )
    }

    fn host_local_part(&self, _host: usize) -> Partitioning {
        // All hosts share the same within-host ownership pattern: global
        // device id modulo gpus_per_host (the paper caches the same
        // features on every host, so ownership is host-replicated).
        Partitioning {
            assignment: self
                .part
                .assignment
                .iter()
                .map(|&d| (d as usize % self.gpus_per_host) as DeviceId)
                .collect(),
            k: self.gpus_per_host,
        }
    }

    /// Run the cost model's counting over one host's [`SplitPlan`],
    /// accumulating into `c`.
    ///
    /// Public so that plan production is shared across the counting and
    /// real-compute paths: a plan produced by [`Self::plan_for_host`] *or*
    /// by the trainer's plan stage (`train::PreparedBatch`) can be
    /// accounted here to get the modeled S/L/FB seconds for the very same
    /// iteration the trainer executed numerically.
    pub fn account_plan(
        &self,
        ctx: &EngineCtx,
        host: usize,
        plan: &SplitPlan,
        c: &mut IterCounters,
    ) {
        let g0 = (host * self.gpus_per_host) as usize; // global id offset
        let row_bytes = ctx.ds.features.row_bytes();
        // --- sampling: per-device edge work + per-layer id shuffles ---
        for (i, layer) in plan.layers.iter().enumerate() {
            for (d, dl) in layer.per_dev.iter().enumerate() {
                c.sampled_edges[g0 + d] += dl.num_edges();
            }
            // Vertex-id all-to-all while splitting mixed frontiers.
            for from in 0..plan.k {
                for to in 0..plan.k {
                    if from != to {
                        let rows = layer.shuffle.send[from][to].len() as u64;
                        if rows > 0 {
                            c.sample_comm.add(
                                (g0 + from) as DeviceId,
                                (g0 + to) as DeviceId,
                                rows * SAMPLE_ROW_BYTES,
                            );
                        }
                    }
                }
            }
            // --- training-shuffle volume for this layer boundary ---
            let l = ctx.model_layer(i);
            let hid_bytes = ctx.model.row_bytes_in(l);
            for from in 0..plan.k {
                for to in 0..plan.k {
                    if from != to {
                        let rows = layer.shuffle.send[from][to].len() as u64;
                        if rows > 0 {
                            c.train_comm.add(
                                (g0 + from) as DeviceId,
                                (g0 + to) as DeviceId,
                                rows * hid_bytes,
                            );
                        }
                    }
                }
            }
            // --- forward compute ---
            for (d, dl) in layer.per_dev.iter().enumerate() {
                c.fwd_flops[g0 + d] +=
                    ctx.model.layer_fwd_flops(l, dl.num_dst() as u64, dl.num_edges());
                c.agg_bytes[g0 + d] +=
                    ctx.model.layer_agg_bytes(l, dl.num_dst() as u64, dl.num_edges());
            }
        }
        // --- loading: each device loads only its own (non-overlapping)
        // input frontier, classified Local / NVLink peer / PCIe host by the
        // same topology-aware classifier the trainer's loading stage uses
        // (under §7.4 replication every host caches the same rows): a copy
        // only reachable without a direct NVLink counts as a host load.
        // Host rows are further split by the feature source's host tier —
        // `probe_row` advances the same chunk-buffer state as the
        // trainer's `fetch_row`, so rows an out-of-core source would have
        // faulted in from disk land in `disk_load_bytes`.
        for (d, frontier) in plan.input_frontier.iter().enumerate() {
            let dev = (g0 + d) as DeviceId;
            for &v in frontier {
                match self.cache.fetch_source_replicated(v, dev, &ctx.topo, self.gpus_per_host) {
                    FetchSource::Local => c.local_load_bytes[g0 + d] += row_bytes,
                    FetchSource::Peer(o) => c.peer_load.add(o, dev, row_bytes),
                    FetchSource::Host => match ctx.ds.features.probe_row(v) {
                        HostTier::Ram => c.host_load_bytes[g0 + d] += row_bytes,
                        HostTier::Disk => c.disk_load_bytes[g0 + d] += row_bytes,
                    },
                }
            }
        }
    }
}

impl Engine for SplitParallel {
    fn name(&self) -> &'static str {
        "GSplit"
    }

    fn iteration(&mut self, ctx: &EngineCtx, targets: &[Vid], seed: u64) -> IterCounters {
        let mut c = IterCounters::new(ctx.k());
        // Data parallelism across hosts: contiguous target shares.
        let h = self.num_hosts;
        let share = targets.len().div_ceil(h);
        for host in 0..h {
            let lo = host * share;
            if lo >= targets.len() {
                break;
            }
            let hi = (lo + share).min(targets.len());
            let plan = self.plan_for_host(ctx, host, &targets[lo..hi], seed);
            self.account_plan(ctx, host, &plan, &mut c);
        }
        add_grad_allreduce(&mut c, ctx.param_bytes());
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::Topology;
    use crate::exec::DataParallel;
    use crate::graph::StandIn;
    use crate::model::GnnKind;
    use crate::partition::{partition_graph, Strategy};
    use crate::presample::PresampleWeights;

    fn setup(
        ds: &crate::graph::Dataset,
        topo: Topology,
    ) -> (EngineCtx<'_>, Partitioning, PresampleWeights) {
        let k = topo.num_gpus();
        let ctx = EngineCtx::new(ds, topo, GnnKind::GraphSage, 64, 2, 5);
        let w = PresampleWeights::uniform(&ds.graph);
        let mask = vec![false; ds.graph.num_vertices()];
        let p = partition_graph(&ds.graph, &w, &mask, Strategy::Edge, k, 0.1, 3);
        (ctx, p, w)
    }

    #[test]
    fn gsplit_loads_less_than_dgl() {
        let ds = StandIn::Tiny.load().unwrap();
        let (ctx, p, w) = setup(&ds, Topology::p3_8xlarge(1000.0)); // tiny GPUs: no cache
        let mut gs = SplitParallel::new(&ctx, p, &w.vertex, 128);
        let mut dgl = DataParallel::dgl(&ctx);
        let targets: Vec<Vid> = (0..256).collect();
        let cg = gs.iteration(&ctx, &targets, 2);
        let cd = dgl.iteration(&ctx, &targets, 2);
        let (lg, ld) = (
            cg.host_load_bytes.iter().sum::<u64>(),
            cd.host_load_bytes.iter().sum::<u64>(),
        );
        assert!(lg < ld, "gsplit {lg} must load less than dgl {ld} (no redundancy)");
        // And GSplit shuffles during training; DGL doesn't (beyond allreduce).
        assert!(cg.train_comm.total_remote() > cd.train_comm.total_remote());
    }

    #[test]
    fn partitioned_cache_eliminates_loads_when_everything_fits() {
        let ds = StandIn::Tiny.load().unwrap();
        let (ctx, p, w) = setup(&ds, Topology::p3_8xlarge(1.0)); // full memory
        let mut gs = SplitParallel::new(&ctx, p, &w.vertex, 128);
        assert!(gs.cache().coverage() > 0.99);
        let targets: Vec<Vid> = (0..256).collect();
        let c = gs.iteration(&ctx, &targets, 4);
        assert_eq!(c.host_load_bytes.iter().sum::<u64>(), 0, "fully cached ⇒ zero loads");
    }

    #[test]
    fn multihost_splits_targets_and_syncs_grads() {
        let ds = StandIn::Tiny.load().unwrap();
        let topo = Topology::multi_host(2, 1.0);
        let k = topo.num_gpus();
        let ctx = EngineCtx::new(&ds, topo, GnnKind::GraphSage, 64, 2, 5);
        let w = PresampleWeights::uniform(&ds.graph);
        let mask = vec![false; ds.graph.num_vertices()];
        let p = partition_graph(&ds.graph, &w, &mask, Strategy::Edge, k, 0.1, 3);
        let mut gs = SplitParallel::new(&ctx, p, &w.vertex, 128);
        let targets: Vec<Vid> = (0..256).collect();
        let c = gs.iteration(&ctx, &targets, 5);
        // All 8 GPUs sampled something.
        assert!(c.sampled_edges.iter().filter(|&&e| e > 0).count() >= 6, "{:?}", c.sampled_edges);
        // Gradient ring crosses hosts (network links exist in the matrix).
        assert!(c.train_comm.get(3, 4) > 0, "ring edge 3→4 crosses hosts");
    }

    #[test]
    fn account_plan_matches_engine_iteration() {
        // Shared plan production: a plan produced explicitly and fed to
        // `account_plan` must count exactly what `iteration` counts.
        let ds = StandIn::Tiny.load().unwrap();
        let (ctx, p, w) = setup(&ds, Topology::p3_8xlarge(1000.0));
        let mut gs = SplitParallel::new(&ctx, p, &w.vertex, 128);
        let targets: Vec<Vid> = (0..200).collect();
        let via_engine = gs.iteration(&ctx, &targets, 11);
        let mut manual = IterCounters::new(ctx.k());
        let plan = gs.plan_for_host(&ctx, 0, &targets, 11);
        gs.account_plan(&ctx, 0, &plan, &mut manual);
        crate::exec::add_grad_allreduce(&mut manual, ctx.param_bytes());
        assert_eq!(manual.sampled_edges, via_engine.sampled_edges);
        assert_eq!(manual.train_comm, via_engine.train_comm);
        assert_eq!(manual.host_load_bytes, via_engine.host_load_bytes);
        assert_eq!(manual.local_load_bytes, via_engine.local_load_bytes);
        assert_eq!(manual.peer_load, via_engine.peer_load);
    }

    #[test]
    fn loading_split_sums_to_uncached_total() {
        // The Local/NVLink/PCIe split re-routes bytes; it never changes how
        // many input rows an iteration materializes.
        let ds = StandIn::Tiny.load().unwrap();
        let targets: Vec<Vid> = (0..256).collect();
        let (ctx_nc, p_nc, w_nc) = setup(&ds, Topology::p3_8xlarge(1000.0)); // no cache fits
        let uncached = SplitParallel::new(&ctx_nc, p_nc, &w_nc.vertex, 128)
            .iteration(&ctx_nc, &targets, 3);
        assert_eq!(uncached.local_load_bytes.iter().sum::<u64>(), 0);
        let (ctx_c, p_c, w_c) = setup(&ds, Topology::p3_8xlarge(1.0)); // fully cached
        let cached =
            SplitParallel::new(&ctx_c, p_c, &w_c.vertex, 128).iteration(&ctx_c, &targets, 3);
        assert!(cached.local_load_bytes.iter().sum::<u64>() > 0);
        assert_eq!(
            cached.total_input_bytes(),
            uncached.total_input_bytes(),
            "cache policy must not change the materialized input volume"
        );
    }

    #[test]
    fn disk_backed_accounting_splits_host_into_four_tiers() {
        // With an out-of-core feature source, cache-miss rows split into
        // Host (chunk-buffer hit) and Disk (fault) — and the four tiers
        // still sum to the uncached in-RAM total for the same plan. Each
        // engine run gets its OWN disk dataset so the chunk-buffer state
        // always starts cold.
        let ram = StandIn::Tiny.load().unwrap();
        let dir = std::env::temp_dir().join(format!("gsplit_sp_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tiny.gsg");
        ram.write_gsg(&path).unwrap();
        let spec = StandIn::Tiny.spec();
        let open_disk = || {
            let mut ds =
                crate::graph::Dataset::open_ooc(&path, spec.train_frac, spec.seed ^ 0x5717)
                    .unwrap();
            // Small buffer (256-row chunks, 4 resident) so an epoch
            // exercises both buffer hits and disk faults.
            ds.features = std::sync::Arc::new(
                crate::graph::DiskFeatureStore::open(&path).unwrap().with_buffer(256, 4),
            );
            ds
        };
        let targets: Vec<Vid> = (0..256).collect();

        let ram_out = {
            let (ctx, p, w) = setup(&ram, Topology::p3_8xlarge(1000.0)); // no cache fits
            SplitParallel::new(&ctx, p, &w.vertex, 128).iteration(&ctx, &targets, 3)
        };
        let disk_out = {
            let ds = open_disk();
            let (ctx, p, w) = setup(&ds, Topology::p3_8xlarge(1000.0));
            SplitParallel::new(&ctx, p, &w.vertex, 128).iteration(&ctx, &targets, 3)
        };
        assert!(disk_out.disk_load_bytes.iter().sum::<u64>() > 0, "no disk faults counted");
        assert_eq!(
            disk_out.total_input_bytes(),
            ram_out.total_input_bytes(),
            "the feature source must not change the materialized input volume"
        );
        assert_eq!(ram_out.disk_load_bytes.iter().sum::<u64>(), 0, "RAM source has no disk tier");

        // Determinism of the split itself: a fresh disk dataset replays
        // the identical buffer-state evolution.
        let disk_again = {
            let ds = open_disk();
            let (ctx, p, w) = setup(&ds, Topology::p3_8xlarge(1000.0));
            SplitParallel::new(&ctx, p, &w.vertex, 128).iteration(&ctx, &targets, 3)
        };
        assert_eq!(disk_out.disk_load_bytes, disk_again.disk_load_bytes);
        assert_eq!(disk_out.host_load_bytes, disk_again.host_load_bytes);
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let ds = StandIn::Tiny.load().unwrap();
        let (ctx, p, w) = setup(&ds, Topology::p3_8xlarge(1.0));
        let mut gs = SplitParallel::new(&ctx, p, &w.vertex, 128);
        let targets: Vec<Vid> = (0..200).collect();
        let a = gs.iteration(&ctx, &targets, 9);
        let b = gs.iteration(&ctx, &targets, 9);
        assert_eq!(a.sampled_edges, b.sampled_edges);
        assert_eq!(a.train_comm, b.train_comm);
        let c = gs.iteration(&ctx, &targets, 10);
        assert_ne!(a.sampled_edges, c.sampled_edges);
    }
}
