//! Layer-wise mini-batch neighborhood sampling (the paper's "standard
//! neighborhood sampling", §7.1: uniform fanout per layer, without
//! replacement — DGL `NeighborSampler` semantics).
//!
//! Sampling proceeds **top-down**: layer L holds the target vertices; each
//! step samples ≤ `fanout` in-neighbors of every frontier vertex, and the
//! next frontier is the deduplicated union of the current frontier and the
//! sampled neighbors (GNN layers need each destination's own previous-layer
//! feature for the self term, so destinations are always part of the source
//! set — DGL "block" convention, `src[..num_dst] == dst`).

mod vmap;

pub use vmap::VertexMap;

use crate::graph::CsrGraph;
use crate::rng::{sample_without_replacement, Pcg32};
use crate::Vid;

/// Sentinel local index marking a padded (absent) neighbor slot.
pub const NO_NEIGHBOR: u32 = u32::MAX;

/// One sampled GNN layer ("block"): edges from a source vertex set to a
/// destination vertex set, stored as a dense `[num_dst, fanout]` neighbor
/// table of local indices into `src`.
#[derive(Debug, Clone, Default)]
pub struct LayerSample {
    /// Destination vertices (global ids). The hidden features of these are
    /// computed by this layer.
    pub dst: Vec<Vid>,
    /// Source vertices (global ids); `src[..dst.len()] == dst`.
    pub src: Vec<Vid>,
    /// `[num_dst × fanout]` local indices into `src`; `NO_NEIGHBOR` pads
    /// rows of vertices with degree < fanout.
    pub neigh: Vec<u32>,
    /// Actual neighbor count per destination.
    pub neigh_len: Vec<u32>,
    /// Fanout this layer was sampled with (row stride of `neigh`).
    pub fanout: usize,
}

impl LayerSample {
    pub fn num_dst(&self) -> usize {
        self.dst.len()
    }

    pub fn num_src(&self) -> usize {
        self.src.len()
    }

    /// Number of sampled edges (excluding the implicit self edges).
    pub fn num_edges(&self) -> u64 {
        self.neigh_len.iter().map(|&c| c as u64).sum()
    }

    /// Neighbor row (local indices into `src`) of destination `i`.
    pub fn neighbors_of(&self, i: usize) -> &[u32] {
        &self.neigh[i * self.fanout..i * self.fanout + self.neigh_len[i] as usize]
    }

    fn clear(&mut self) {
        self.dst.clear();
        self.src.clear();
        self.neigh.clear();
        self.neigh_len.clear();
    }
}

/// A fully sampled mini-batch: `layers[0]` is the top layer (destinations =
/// targets), `layers.last()` the bottom layer whose `src` set needs input
/// features loaded.
#[derive(Debug, Clone, Default)]
pub struct MiniBatch {
    pub layers: Vec<LayerSample>,
}

impl MiniBatch {
    /// Vertices whose input features must be loaded (bottom-layer sources).
    pub fn input_vertices(&self) -> &[Vid] {
        &self.layers.last().expect("empty mini-batch").src
    }

    /// Total sampled edges across layers — the paper's "# edges computed"
    /// redundancy metric (Table 1).
    pub fn total_edges(&self) -> u64 {
        self.layers.iter().map(LayerSample::num_edges).sum()
    }

    /// Total destination vertices at layers l > 0 — the computation-load
    /// metric X_i of the splitting problem (Eq. 1).
    pub fn total_hidden_vertices(&self) -> u64 {
        self.layers.iter().map(|l| l.num_dst() as u64).sum()
    }
}

/// Reusable sampler: owns scratch buffers so per-iteration sampling is
/// allocation-free after warmup (hot-path requirement, see DESIGN.md §Perf).
pub struct Sampler {
    vmap: VertexMap,
    scratch: Vec<u32>,
}

impl Default for Sampler {
    fn default() -> Self {
        Self::new()
    }
}

impl Sampler {
    pub fn new() -> Self {
        Sampler { vmap: VertexMap::new(), scratch: Vec::with_capacity(64) }
    }

    /// Sample a mini-batch of `fanouts.len()` layers starting from
    /// `targets`. `fanouts[0]` is the fanout of the **top** layer.
    pub fn sample(
        &mut self,
        graph: &CsrGraph,
        targets: &[Vid],
        fanouts: &[usize],
        rng: &mut Pcg32,
    ) -> MiniBatch {
        let mut mb = MiniBatch { layers: Vec::with_capacity(fanouts.len()) };
        self.sample_into(graph, targets, fanouts, rng, &mut mb);
        mb
    }

    /// Like [`Self::sample`] but reuses the layer buffers of `out`.
    pub fn sample_into(
        &mut self,
        graph: &CsrGraph,
        targets: &[Vid],
        fanouts: &[usize],
        rng: &mut Pcg32,
        out: &mut MiniBatch,
    ) {
        out.layers.resize_with(fanouts.len(), LayerSample::default);
        // The frontier of the first layer is the target set itself.
        let mut frontier: Vec<Vid> = targets.to_vec();
        for (l, &fanout) in fanouts.iter().enumerate() {
            let layer = &mut out.layers[l];
            layer.clear();
            layer.fanout = fanout;
            self.sample_layer(graph, &frontier, fanout, rng, layer);
            frontier.clear();
            frontier.extend_from_slice(&layer.src);
        }
    }

    /// Sample one layer: neighbors of `frontier`, building the local-index
    /// table. This is the `sample_layer` of Algorithm 1 in the paper.
    pub fn sample_layer(
        &mut self,
        graph: &CsrGraph,
        frontier: &[Vid],
        fanout: usize,
        rng: &mut Pcg32,
        layer: &mut LayerSample,
    ) {
        let vmap = &mut self.vmap;
        vmap.reset(frontier.len() * (fanout + 1));
        layer.dst.extend_from_slice(frontier);
        // Destinations occupy the first local slots, in order.
        for &v in frontier {
            let (idx, fresh) = vmap.get_or_insert(v);
            debug_assert!(fresh, "duplicate vertex {v} in frontier");
            debug_assert_eq!(idx as usize, layer.src.len());
            layer.src.push(v);
        }
        // Write each neighbor row exactly once (sampled prefix + padded
        // tail) instead of pre-filling the whole table with NO_NEIGHBOR —
        // the table is the largest per-iteration buffer (M×K×4 bytes) and
        // double-writing it showed up in profiles (§Perf).
        layer.neigh.reserve(frontier.len() * fanout);
        unsafe { layer.neigh.set_len(frontier.len() * fanout) };
        layer.neigh_len.resize(frontier.len(), 0);
        for (i, &v) in frontier.iter().enumerate() {
            let nbrs = graph.neighbors(v);
            sample_without_replacement(rng, nbrs.len() as u32, fanout as u32, &mut self.scratch);
            let row = &mut layer.neigh[i * fanout..(i + 1) * fanout];
            for (j, &slot) in self.scratch.iter().enumerate() {
                let u = nbrs[slot as usize];
                let (idx, fresh) = vmap.get_or_insert(u);
                if fresh {
                    layer.src.push(u);
                }
                row[j] = idx;
            }
            row[self.scratch.len()..].fill(NO_NEIGHBOR);
            layer.neigh_len[i] = self.scratch.len() as u32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{rmat, GenParams};

    fn test_graph() -> CsrGraph {
        rmat(&GenParams { num_vertices: 1024, num_edges: 8192, seed: 5 })
    }

    #[test]
    fn block_invariants() {
        let g = test_graph();
        let mut s = Sampler::new();
        let mut rng = Pcg32::new(1);
        let targets: Vec<Vid> = (0..64).collect();
        let mb = s.sample(&g, &targets, &[5, 5, 5], &mut rng);
        assert_eq!(mb.layers.len(), 3);
        assert_eq!(mb.layers[0].dst, targets);
        for (l, layer) in mb.layers.iter().enumerate() {
            // dst is a prefix of src
            assert_eq!(&layer.src[..layer.num_dst()], &layer.dst[..], "layer {l}");
            // src has no duplicates
            let mut sorted = layer.src.clone();
            sorted.sort_unstable();
            let before = sorted.len();
            sorted.dedup();
            assert_eq!(before, sorted.len(), "layer {l} has duplicate srcs");
            // every neighbor index is valid and every real edge exists
            for i in 0..layer.num_dst() {
                for &j in layer.neighbors_of(i) {
                    assert!((j as usize) < layer.num_src());
                    let (d, srcv) = (layer.dst[i], layer.src[j as usize]);
                    assert!(g.neighbors(d).contains(&srcv), "{srcv} not a neighbor of {d}");
                }
                // padded slots are NO_NEIGHBOR
                let row = &layer.neigh[i * layer.fanout..(i + 1) * layer.fanout];
                for &x in &row[layer.neigh_len[i] as usize..] {
                    assert_eq!(x, NO_NEIGHBOR);
                }
            }
            // layer l+1 frontier == layer l src
            if l + 1 < mb.layers.len() {
                assert_eq!(mb.layers[l + 1].dst, layer.src, "frontier chaining at layer {l}");
            }
        }
    }

    #[test]
    fn respects_fanout_and_degree() {
        let g = test_graph();
        let mut s = Sampler::new();
        let mut rng = Pcg32::new(2);
        let targets: Vec<Vid> = (100..160).collect();
        let mb = s.sample(&g, &targets, &[7], &mut rng);
        let layer = &mb.layers[0];
        for (i, &v) in layer.dst.iter().enumerate() {
            let expect = (g.degree(v) as usize).min(7);
            assert_eq!(layer.neigh_len[i] as usize, expect, "vertex {v}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let g = test_graph();
        let targets: Vec<Vid> = (0..32).collect();
        let mut s1 = Sampler::new();
        let mut s2 = Sampler::new();
        let a = s1.sample(&g, &targets, &[5, 5], &mut Pcg32::new(9));
        let b = s2.sample(&g, &targets, &[5, 5], &mut Pcg32::new(9));
        assert_eq!(a.layers[1].src, b.layers[1].src);
        assert_eq!(a.layers[1].neigh, b.layers[1].neigh);
        let c = s1.sample(&g, &targets, &[5, 5], &mut Pcg32::new(10));
        assert_ne!(a.layers[1].neigh, c.layers[1].neigh);
    }

    #[test]
    fn edge_counts_are_consistent() {
        let g = test_graph();
        let mut s = Sampler::new();
        let mut rng = Pcg32::new(3);
        let targets: Vec<Vid> = (0..128).collect();
        let mb = s.sample(&g, &targets, &[5, 5], &mut rng);
        let manual: u64 = mb
            .layers
            .iter()
            .map(|l| (0..l.num_dst()).map(|i| l.neighbors_of(i).len() as u64).sum::<u64>())
            .sum();
        assert_eq!(mb.total_edges(), manual);
        assert!(mb.total_edges() > 0);
    }

    #[test]
    fn sample_into_reuses_buffers() {
        let g = test_graph();
        let mut s = Sampler::new();
        let mut rng = Pcg32::new(4);
        let mut mb = MiniBatch::default();
        let t1: Vec<Vid> = (0..16).collect();
        s.sample_into(&g, &t1, &[3, 3], &mut rng, &mut mb);
        let first_src = mb.layers[1].src.clone();
        let t2: Vec<Vid> = (500..516).collect();
        s.sample_into(&g, &t2, &[3, 3], &mut rng, &mut mb);
        assert_eq!(mb.layers[0].dst, t2);
        assert_ne!(mb.layers[1].src, first_src);
    }
}
