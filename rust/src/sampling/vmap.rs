//! Open-addressing vertex → local-index map.
//!
//! The sampler assigns compact local indices to global vertex ids once per
//! layer. `std::collections::HashMap<u32, u32>` with SipHash was the top
//! entry in early profiles; this table replaces it with linear probing, a
//! multiplicative hash, and a single packed slot array.
//!
//! §Perf note: a generation-stamped variant (O(1) reset, no memset) was
//! tried and REVERTED — the second stamps array doubles the cache lines
//! touched per probe and regressed `vertex_map_1M` 13.5 → 19.5 ms (+45%).
//! The memset on reset is sequential and prefetch-friendly; the probes are
//! the random accesses that matter. See EXPERIMENTS.md §Perf.

use crate::Vid;

const EMPTY: u64 = u64::MAX;

/// Maps `Vid` keys to dense `u32` local indices in insertion order.
pub struct VertexMap {
    /// Slot = (key << 32) | value, or EMPTY.
    slots: Vec<u64>,
    mask: usize,
    len: u32,
}

impl Default for VertexMap {
    fn default() -> Self {
        Self::new()
    }
}

impl VertexMap {
    pub fn new() -> Self {
        VertexMap { slots: vec![EMPTY; 16], mask: 15, len: 0 }
    }

    /// Clear and ensure capacity for ~`expected` keys at ≤ 50% load.
    pub fn reset(&mut self, expected: usize) {
        let needed = (expected.max(8) * 2).next_power_of_two();
        if self.slots.len() < needed {
            self.slots = vec![EMPTY; needed];
        } else {
            self.slots.fill(EMPTY);
        }
        self.mask = self.slots.len() - 1;
        self.len = 0;
    }

    #[inline]
    fn hash(key: Vid) -> usize {
        // Fibonacci hashing: odd multiplicative constant ≈ 2^64/φ.
        ((key as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32) as usize
    }

    /// Insert `key` if absent; returns `(local_index, freshly_inserted)`.
    #[inline]
    pub fn get_or_insert(&mut self, key: Vid) -> (u32, bool) {
        let mut i = Self::hash(key) & self.mask;
        loop {
            let slot = self.slots[i];
            if slot == EMPTY {
                let idx = self.len;
                self.slots[i] = ((key as u64) << 32) | idx as u64;
                self.len += 1;
                // Grow if load factor exceeded (rare: reset() pre-sizes).
                if (self.len as usize) * 2 > self.slots.len() {
                    self.grow();
                }
                return (idx, true);
            }
            if (slot >> 32) as Vid == key {
                return (slot as u32, false);
            }
            i = (i + 1) & self.mask;
        }
    }

    /// Lookup without insertion.
    #[inline]
    pub fn get(&self, key: Vid) -> Option<u32> {
        let mut i = Self::hash(key) & self.mask;
        loop {
            let slot = self.slots[i];
            if slot == EMPTY {
                return None;
            }
            if (slot >> 32) as Vid == key {
                return Some(slot as u32);
            }
            i = (i + 1) & self.mask;
        }
    }

    pub fn len(&self) -> usize {
        self.len as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[cold]
    fn grow(&mut self) {
        let new_len = self.slots.len() * 2;
        let old = std::mem::replace(&mut self.slots, vec![EMPTY; new_len]);
        self.mask = new_len - 1;
        for slot in old {
            if slot != EMPTY {
                let key = (slot >> 32) as Vid;
                let mut i = Self::hash(key) & self.mask;
                while self.slots[i] != EMPTY {
                    i = (i + 1) & self.mask;
                }
                self.slots[i] = slot;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    #[test]
    fn assigns_dense_indices_in_insertion_order() {
        let mut m = VertexMap::new();
        m.reset(10);
        assert_eq!(m.get_or_insert(100), (0, true));
        assert_eq!(m.get_or_insert(7), (1, true));
        assert_eq!(m.get_or_insert(100), (0, false));
        assert_eq!(m.get_or_insert(42), (2, true));
        assert_eq!(m.len(), 3);
        assert_eq!(m.get(7), Some(1));
        assert_eq!(m.get(9), None);
    }

    #[test]
    fn reset_clears() {
        let mut m = VertexMap::new();
        m.reset(4);
        m.get_or_insert(1);
        m.reset(4);
        assert!(m.is_empty());
        assert_eq!(m.get(1), None);
        assert_eq!(m.get_or_insert(2), (0, true));
    }

    #[test]
    fn many_resets_stay_correct() {
        // Generation stamping: stale entries from earlier epochs must
        // never leak into later ones.
        let mut m = VertexMap::new();
        for round in 0..2000u32 {
            m.reset(8);
            assert_eq!(m.get(round), None, "stale hit in round {round}");
            let (idx, fresh) = m.get_or_insert(round % 16);
            assert!(fresh);
            assert_eq!(idx, 0);
        }
    }

    #[test]
    fn survives_growth_and_collisions() {
        let mut m = VertexMap::new();
        m.reset(2); // deliberately undersized; forces grow()
        let mut rng = Pcg32::new(8);
        let keys: Vec<Vid> = (0..5000).map(|_| rng.next_u32()).collect();
        let mut expect = std::collections::HashMap::new();
        for &k in &keys {
            let (idx, fresh) = m.get_or_insert(k);
            match expect.entry(k) {
                std::collections::hash_map::Entry::Occupied(e) => {
                    assert!(!fresh);
                    assert_eq!(*e.get(), idx);
                }
                std::collections::hash_map::Entry::Vacant(e) => {
                    assert!(fresh);
                    e.insert(idx);
                }
            }
        }
        assert_eq!(m.len(), expect.len());
        for (&k, &idx) in &expect {
            assert_eq!(m.get(k), Some(idx));
        }
    }

    #[test]
    fn matches_std_hashmap_under_random_workload() {
        // Property check: VertexMap behaves exactly like the reference map.
        let mut rng = Pcg32::new(99);
        for trial in 0..20 {
            let mut m = VertexMap::new();
            m.reset(64);
            let mut reference: Vec<Vid> = Vec::new();
            for _ in 0..500 {
                let k = rng.gen_range(200); // many collisions
                let (idx, fresh) = m.get_or_insert(k);
                match reference.iter().position(|&x| x == k) {
                    Some(p) => {
                        assert!(!fresh, "trial {trial}");
                        assert_eq!(idx as usize, p);
                    }
                    None => {
                        assert!(fresh);
                        assert_eq!(idx as usize, reference.len());
                        reference.push(k);
                    }
                }
            }
        }
    }
}
