//! Experiment configuration: a TOML-subset parser plus the typed config
//! structs consumed by the CLI, the trainer, and every bench.

mod toml;

pub use toml::{parse_toml, TomlValue};

use anyhow::{bail, Result};

use crate::graph::StandIn;
use crate::model::GnnKind;

/// Hyperparameters for one training run — the paper's defaults (§7.1):
/// fanout 15 per layer, 3 layers, hidden 256, batch 1024.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainConfig {
    pub model: GnnKind,
    pub num_layers: usize,
    pub fanout: usize,
    pub hidden: usize,
    pub batch_size: usize,
    pub lr: f32,
    pub epochs: usize,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: GnnKind::GraphSage,
            num_layers: 3,
            fanout: 15,
            hidden: 256,
            batch_size: 1024,
            lr: 0.003,
            epochs: 1,
            seed: 42,
        }
    }
}

impl TrainConfig {
    /// Per-layer fanouts, bottom layer first (uniform fanout, as the paper's
    /// default neighborhood sampling).
    pub fn fanouts(&self) -> Vec<usize> {
        vec![self.fanout; self.num_layers]
    }
}

/// A full experiment description parsed from TOML.
#[derive(Debug, Clone)]
pub struct ExpConfig {
    pub dataset: StandIn,
    pub train: TrainConfig,
    pub num_gpus: usize,
    pub num_hosts: usize,
    pub system: String,
    pub partitioner: String,
    pub presample_epochs: usize,
}

impl Default for ExpConfig {
    fn default() -> Self {
        ExpConfig {
            dataset: StandIn::Tiny,
            train: TrainConfig::default(),
            num_gpus: 4,
            num_hosts: 1,
            system: "gsplit".into(),
            partitioner: "gsplit".into(),
            presample_epochs: 10,
        }
    }
}

impl ExpConfig {
    /// Parse from a TOML document. Unknown keys are rejected so typos in
    /// experiment files fail loudly rather than silently running defaults.
    pub fn from_toml(text: &str) -> Result<ExpConfig> {
        let doc = parse_toml(text)?;
        let mut cfg = ExpConfig::default();
        for (key, val) in doc.iter() {
            match key.as_str() {
                "dataset" => cfg.dataset = parse_dataset(val.as_str_or(key)?)?,
                "model" => cfg.train.model = parse_model(val.as_str_or(key)?)?,
                "layers" => cfg.train.num_layers = val.as_usize_or(key)?,
                "fanout" => cfg.train.fanout = val.as_usize_or(key)?,
                "hidden" => cfg.train.hidden = val.as_usize_or(key)?,
                "batch_size" => cfg.train.batch_size = val.as_usize_or(key)?,
                "lr" => cfg.train.lr = val.as_f64_or(key)? as f32,
                "epochs" => cfg.train.epochs = val.as_usize_or(key)?,
                "seed" => cfg.train.seed = val.as_usize_or(key)? as u64,
                "gpus" => cfg.num_gpus = val.as_usize_or(key)?,
                "hosts" => cfg.num_hosts = val.as_usize_or(key)?,
                "system" => cfg.system = val.as_str_or(key)?.to_string(),
                "partitioner" => cfg.partitioner = val.as_str_or(key)?.to_string(),
                "presample_epochs" => cfg.presample_epochs = val.as_usize_or(key)?,
                other => bail!("unknown config key `{other}`"),
            }
        }
        Ok(cfg)
    }
}

pub fn parse_dataset(s: &str) -> Result<StandIn> {
    Ok(match s {
        "orkut-s" | "orkut" => StandIn::OrkutS,
        "papers-s" | "papers100m" => StandIn::PapersS,
        "friendster-s" | "friendster" => StandIn::FriendsterS,
        "tiny" => StandIn::Tiny,
        other => bail!("unknown dataset `{other}` (orkut-s|papers-s|friendster-s|tiny)"),
    })
}

pub fn parse_model(s: &str) -> Result<GnnKind> {
    Ok(match s {
        "sage" | "graphsage" => GnnKind::GraphSage,
        "gat" => GnnKind::Gat,
        other => bail!("unknown model `{other}` (sage|gat)"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let cfg = ExpConfig::from_toml(
            r#"
            # experiment: table 3 row
            dataset = "papers-s"
            model = "gat"
            layers = 3
            fanout = 15
            hidden = 256
            batch_size = 1024
            gpus = 4
            system = "gsplit"
            partitioner = "edge"
            presample_epochs = 10
            "#,
        )
        .unwrap();
        assert_eq!(cfg.dataset, StandIn::PapersS);
        assert_eq!(cfg.train.model, GnnKind::Gat);
        assert_eq!(cfg.train.hidden, 256);
        assert_eq!(cfg.partitioner, "edge");
    }

    #[test]
    fn rejects_unknown_key() {
        assert!(ExpConfig::from_toml("basch_size = 12").is_err());
    }

    #[test]
    fn rejects_bad_dataset() {
        assert!(ExpConfig::from_toml(r#"dataset = "ogbn-nope""#).is_err());
    }

    #[test]
    fn default_matches_paper_defaults() {
        let t = TrainConfig::default();
        assert_eq!(t.fanouts(), vec![15, 15, 15]);
        assert_eq!(t.hidden, 256);
        assert_eq!(t.batch_size, 1024);
    }
}
