//! A TOML-subset parser: top-level `key = value` pairs and `[section]`
//! headers (flattened to `section.key`), with string / integer / float /
//! boolean / inline-array values and `#` comments. Covers everything the
//! experiment files need; the full TOML grammar (dates, nested tables,
//! multi-line strings) is intentionally out of scope.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str_or(&self, key: &str) -> Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            other => bail!("config key `{key}` expects a string, got {other:?}"),
        }
    }

    pub fn as_usize_or(&self, key: &str) -> Result<usize> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Ok(*i as usize),
            other => bail!("config key `{key}` expects a non-negative integer, got {other:?}"),
        }
    }

    pub fn as_f64_or(&self, key: &str) -> Result<f64> {
        match self {
            TomlValue::Float(f) => Ok(*f),
            TomlValue::Int(i) => Ok(*i as f64),
            other => bail!("config key `{key}` expects a number, got {other:?}"),
        }
    }

    pub fn as_bool_or(&self, key: &str) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            other => bail!("config key `{key}` expects a boolean, got {other:?}"),
        }
    }
}

/// Parse a TOML-subset document into a flat, ordered key → value map.
pub fn parse_toml(text: &str) -> Result<BTreeMap<String, TomlValue>> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| anyhow!("line {}: unterminated section header", lineno + 1))?;
            section = name.trim().to_string();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| anyhow!("line {}: expected `key = value`", lineno + 1))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            bail!("line {}: empty key", lineno + 1);
        }
        let full_key =
            if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
        let value = parse_value(line[eq + 1..].trim())
            .map_err(|e| anyhow!("line {}: {e}", lineno + 1))?;
        if out.insert(full_key.clone(), value).is_some() {
            bail!("line {}: duplicate key `{full_key}`", lineno + 1);
        }
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue> {
    if s.is_empty() {
        bail!("missing value");
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or_else(|| anyhow!("unterminated string"))?;
        if inner.contains('"') {
            bail!("embedded quotes are not supported");
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or_else(|| anyhow!("unterminated array"))?;
        let mut items = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in trimmed.split(',') {
                let p = part.trim();
                if p.is_empty() {
                    continue; // allow trailing comma
                }
                items.push(parse_value(p)?);
            }
        }
        return Ok(TomlValue::Arr(items));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if s.contains('.') || s.contains('e') || s.contains('E') {
        if let Ok(f) = s.parse::<f64>() {
            return Ok(TomlValue::Float(f));
        }
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    bail!("cannot parse value `{s}`")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_sections() {
        let doc = parse_toml(
            r#"
            a = 1
            b = "two"   # trailing comment
            c = 3.5
            d = true
            [sec]
            e = [1, 2, 3,]
            "#,
        )
        .unwrap();
        assert_eq!(doc["a"], TomlValue::Int(1));
        assert_eq!(doc["b"], TomlValue::Str("two".into()));
        assert_eq!(doc["c"], TomlValue::Float(3.5));
        assert_eq!(doc["d"], TomlValue::Bool(true));
        assert_eq!(
            doc["sec.e"],
            TomlValue::Arr(vec![TomlValue::Int(1), TomlValue::Int(2), TomlValue::Int(3)])
        );
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = parse_toml(r##"k = "a#b""##).unwrap();
        assert_eq!(doc["k"], TomlValue::Str("a#b".into()));
    }

    #[test]
    fn underscored_ints() {
        let doc = parse_toml("n = 1_000_000").unwrap();
        assert_eq!(doc["n"], TomlValue::Int(1_000_000));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = parse_toml("good = 1\nbad value").unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn rejects_duplicates() {
        assert!(parse_toml("a = 1\na = 2").is_err());
    }
}
