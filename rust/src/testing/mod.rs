//! Property-testing helpers (a `proptest`-lite: the real crate is not in
//! the offline registry). Runs an invariant over many seeded random cases
//! and reports the first failing seed so failures are reproducible.

use crate::rng::Pcg32;

/// Run `check(rng, case_index)` for `cases` deterministic random cases.
/// Panics with the failing case's seed on the first violation so the case
/// can be replayed in isolation.
pub fn for_all_seeds(name: &str, cases: u64, mut check: impl FnMut(&mut Pcg32, u64)) {
    for case in 0..cases {
        let seed = 0x5EED_0000 + case;
        let mut rng = Pcg32::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            check(&mut rng, case)
        }));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property `{name}` failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Draw a random subset of `0..n` of the given size (distinct, sorted).
pub fn random_subset(rng: &mut Pcg32, n: u32, size: usize) -> Vec<u32> {
    let mut v: Vec<u32> = (0..n).collect();
    rng.shuffle(&mut v);
    v.truncate(size.min(n as usize));
    v.sort_unstable();
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_when_invariant_holds() {
        for_all_seeds("sum-commutes", 20, |rng, _| {
            let a = rng.gen_range(100) as i64;
            let b = rng.gen_range(100) as i64;
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed at case 0")]
    fn reports_failing_case() {
        for_all_seeds("always-fails", 5, |_, _| panic!("boom"));
    }

    #[test]
    fn random_subset_properties() {
        for_all_seeds("subset", 20, |rng, _| {
            let s = random_subset(rng, 50, 10);
            assert_eq!(s.len(), 10);
            assert!(s.windows(2).all(|w| w[0] < w[1]));
            assert!(s.iter().all(|&x| x < 50));
        });
    }
}
