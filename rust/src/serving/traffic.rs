//! Seeded closed-loop traffic generation for `gsplit serve` (DESIGN.md
//! §Serving).
//!
//! Real per-vertex inference traffic is heavily skewed — a small hot set
//! of vertices (popular users, trending items) absorbs most requests,
//! which is exactly the skew GSplit's hotness-aware caching exploits. The
//! generator models it with a **Zipf** popularity law: rank-`r` vertex
//! drawn with probability ∝ 1/(r+1)^s, ranks mapped to vertex ids by a
//! seeded permutation so the hot set is not just the lowest ids.
//!
//! Everything is seed-deterministic: [`request_stream`] is a pure function
//! of its [`TrafficConfig`], so `BENCH_serving.json` numbers are
//! reproducible run to run (pinned by the unit tests below). The
//! closed-loop driver ([`run_closed_loop`]) shares one stream across its
//! workers: each in-flight request waits for its response before the
//! worker takes the next one, and [`AdmitError::QueueFull`] rejections
//! are counted and retried — backpressure slows the offered load instead
//! of crashing it.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::thread;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::obs::metrics;
use crate::rng::{derive_seed, Pcg32};
use crate::serving::{AdmitError, ServeClient};
use crate::Vid;

/// Traffic shape: how many requests, from how many concurrent clients,
/// over which vertex population, at what popularity skew.
#[derive(Debug, Clone, Copy)]
pub struct TrafficConfig {
    /// Total requests across all workers.
    pub requests: usize,
    /// Concurrent closed-loop clients (each waits for its response before
    /// sending the next request).
    pub concurrency: usize,
    /// Zipf exponent `s`: 0 is uniform; ~1 is web-like; higher
    /// concentrates traffic further onto the hot set.
    pub skew: f64,
    pub seed: u64,
    /// Vertex population size (requests target `0..vertices`).
    pub vertices: usize,
}

impl Default for TrafficConfig {
    fn default() -> Self {
        TrafficConfig { requests: 1000, concurrency: 4, skew: 1.0, seed: 0, vertices: 1 }
    }
}

/// Zipf-distributed vertex sampler: rank `r` (0-based) has weight
/// `1/(r+1)^s`, and a seeded permutation maps ranks to vertex ids.
#[derive(Debug, Clone)]
pub struct ZipfSampler {
    /// Cumulative rank weights; `cum[r]` = sum of weights of ranks `0..=r`.
    cum: Vec<f64>,
    /// `perm[rank]` = vertex id.
    perm: Vec<Vid>,
}

impl ZipfSampler {
    pub fn new(vertices: usize, skew: f64, seed: u64) -> Self {
        assert!(vertices > 0, "Zipf sampler needs a non-empty vertex population");
        let mut cum = Vec::with_capacity(vertices);
        let mut total = 0f64;
        for r in 0..vertices {
            total += 1.0 / ((r + 1) as f64).powf(skew);
            cum.push(total);
        }
        let mut perm: Vec<Vid> = (0..vertices as Vid).collect();
        Pcg32::new(derive_seed(seed, &[0x51F7])).shuffle(&mut perm);
        ZipfSampler { cum, perm }
    }

    /// Draw one vertex.
    pub fn sample(&self, rng: &mut Pcg32) -> Vid {
        let total = *self.cum.last().expect("non-empty");
        let x = rng.next_f64() * total;
        // First rank whose cumulative weight reaches x (min guards the
        // x == total edge from floating-point rounding).
        let rank = self.cum.partition_point(|&c| c < x).min(self.perm.len() - 1);
        self.perm[rank]
    }
}

/// The full request stream a [`TrafficConfig`] generates — a pure
/// function of the config, which is the determinism contract the bench
/// relies on (same seed ⇒ identical vertex ids in identical order).
pub fn request_stream(cfg: &TrafficConfig) -> Vec<Vid> {
    let sampler = ZipfSampler::new(cfg.vertices, cfg.skew, cfg.seed);
    let mut rng = Pcg32::new(derive_seed(cfg.seed, &[0x7AFF]));
    (0..cfg.requests).map(|_| sampler.sample(&mut rng)).collect()
}

/// Outcome of one closed-loop run.
#[derive(Debug, Clone, Copy, Default)]
pub struct TrafficReport {
    /// Requests submitted and answered.
    pub sent: u64,
    /// `QueueFull` rejections observed (each was retried until admitted).
    pub rejected: u64,
}

/// Drive a pre-generated request stream through the client from
/// `cfg.concurrency` closed-loop workers. Workers claim stream positions
/// atomically, so together they submit each request exactly once;
/// `QueueFull` backpressure is counted, published as the
/// `serve_rejects{reason=queue_full}` counter, and retried after a short
/// pause.
pub fn run_closed_loop(client: &ServeClient, cfg: &TrafficConfig) -> Result<TrafficReport> {
    let stream = request_stream(cfg);
    let rejects_ctr = metrics::registry().counter("serve_rejects", &[("reason", "queue_full")]);
    let next = AtomicUsize::new(0);
    let rejected = AtomicU64::new(0);
    let workers = cfg.concurrency.max(1);
    thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            let stream = &stream;
            let next = &next;
            let rejected = &rejected;
            let rejects_ctr = &rejects_ctr;
            handles.push(scope.spawn(move || -> Result<()> {
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    let Some(&vid) = stream.get(i) else { return Ok(()) };
                    let pending = loop {
                        match client.submit(vid) {
                            Ok(p) => break p,
                            Err(AdmitError::QueueFull { .. }) => {
                                rejected.fetch_add(1, Ordering::Relaxed);
                                rejects_ctr.inc();
                                thread::sleep(Duration::from_micros(100));
                            }
                            Err(e) => return Err(anyhow!("admission failed: {e}")),
                        }
                    };
                    pending.wait()?;
                }
            }));
        }
        for h in handles {
            h.join().map_err(|_| anyhow!("traffic worker panicked"))??;
        }
        Ok(())
    })?;
    Ok(TrafficReport {
        sent: stream.len() as u64,
        rejected: rejected.load(Ordering::Relaxed),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_stream() {
        let cfg = TrafficConfig { requests: 500, vertices: 200, skew: 1.2, seed: 9, ..Default::default() };
        assert_eq!(request_stream(&cfg), request_stream(&cfg));
        let other = TrafficConfig { seed: 10, ..cfg };
        assert_ne!(request_stream(&cfg), request_stream(&other), "seed must matter");
        let flatter = TrafficConfig { skew: 0.3, ..cfg };
        assert_ne!(request_stream(&cfg), request_stream(&flatter), "skew must matter");
    }

    #[test]
    fn stream_stays_in_range() {
        let cfg = TrafficConfig { requests: 2000, vertices: 37, skew: 1.5, seed: 3, ..Default::default() };
        for v in request_stream(&cfg) {
            assert!((v as usize) < cfg.vertices);
        }
    }

    /// Higher skew ⇒ a larger share of requests on the hottest 1% of
    /// vertices — the property that makes hotness caching pay off.
    #[test]
    fn higher_skew_concentrates_traffic() {
        let top_share = |skew: f64| -> f64 {
            let cfg = TrafficConfig {
                requests: 20_000,
                vertices: 1000,
                skew,
                seed: 5,
                ..Default::default()
            };
            let stream = request_stream(&cfg);
            let mut counts = vec![0u64; cfg.vertices];
            for v in &stream {
                counts[*v as usize] += 1;
            }
            counts.sort_unstable_by(|a, b| b.cmp(a));
            let top = cfg.vertices / 100; // hottest 1%
            counts[..top].iter().sum::<u64>() as f64 / stream.len() as f64
        };
        let flat = top_share(0.5);
        let steep = top_share(1.5);
        assert!(
            steep > flat + 0.1,
            "skew 1.5 must concentrate traffic well beyond skew 0.5 (got {steep:.3} vs {flat:.3})"
        );
        assert!(steep > 0.3, "skew 1.5 should put >30% of traffic on the top 1% (got {steep:.3})");
    }

    #[test]
    fn zipf_permutation_decouples_rank_from_id() {
        let a = ZipfSampler::new(256, 1.5, 11);
        let b = ZipfSampler::new(256, 1.5, 11);
        assert_eq!(a.perm, b.perm, "rank→vertex map is seed-deterministic");
        let c = ZipfSampler::new(256, 1.5, 12);
        assert_ne!(a.perm, c.perm, "different seeds permute the hot set differently");
        let identity: Vec<Vid> = (0..256).collect();
        assert_ne!(a.perm, identity, "the hot set must not simply be the lowest vertex ids");
        let mut sorted = a.perm.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, identity, "rank→vertex map is a permutation");
    }
}
