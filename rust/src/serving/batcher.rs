//! Dynamic micro-batching state machine (DESIGN.md §Serving).
//!
//! A [`MicroBatcher`] coalesces admitted requests into micro-batches,
//! flushing whichever comes first: the batch fills to `max_batch`, or
//! `max_wait` elapses since the batch's **first** request arrived (so a
//! lone request is never held longer than `max_wait`). It is a pure state
//! machine — the caller supplies every timestamp and drives the clock —
//! which is what makes the flush rules unit-testable without threads or
//! sleeps.

use std::time::{Duration, Instant};

/// Coalesces items into micro-batches; flush on size or age, whichever
/// comes first. `max_wait == 0` degrades to one batch per item.
#[derive(Debug)]
pub struct MicroBatcher<T> {
    max_batch: usize,
    max_wait: Duration,
    buf: Vec<T>,
    /// Flush-by time of the pending batch; `Some` iff `buf` is non-empty.
    deadline: Option<Instant>,
}

impl<T> MicroBatcher<T> {
    /// `max_batch` is clamped to at least 1.
    pub fn new(max_batch: usize, max_wait: Duration) -> Self {
        MicroBatcher { max_batch: max_batch.max(1), max_wait, buf: Vec::new(), deadline: None }
    }

    /// Add one item at time `now`. Returns the completed batch when this
    /// push fills it to `max_batch` (or immediately under zero `max_wait`).
    pub fn push(&mut self, item: T, now: Instant) -> Option<Vec<T>> {
        if self.buf.is_empty() {
            self.deadline = Some(now + self.max_wait);
        }
        self.buf.push(item);
        if self.buf.len() >= self.max_batch || self.max_wait.is_zero() {
            self.flush()
        } else {
            None
        }
    }

    /// Whether the pending batch's `max_wait` deadline has passed.
    pub fn due(&self, now: Instant) -> bool {
        matches!(self.deadline, Some(d) if now >= d)
    }

    /// Flush-by time of the pending batch, if one is pending — the longest
    /// the serve loop may block waiting for more requests.
    pub fn deadline(&self) -> Option<Instant> {
        self.deadline
    }

    /// Take the pending batch (deadline or shutdown drain); `None` when
    /// nothing is pending.
    pub fn flush(&mut self) -> Option<Vec<T>> {
        if self.buf.is_empty() {
            return None;
        }
        self.deadline = None;
        Some(std::mem::take(&mut self.buf))
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flushes_when_full() {
        let mut b = MicroBatcher::new(3, Duration::from_secs(60));
        let t = Instant::now();
        assert_eq!(b.push(1, t), None);
        assert_eq!(b.push(2, t), None);
        assert_eq!(b.push(3, t), Some(vec![1, 2, 3]));
        assert!(b.is_empty());
        assert_eq!(b.deadline(), None);
        // The next batch starts fresh.
        assert_eq!(b.push(4, t), None);
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn zero_wait_degrades_to_per_item_batches() {
        let mut b = MicroBatcher::new(8, Duration::ZERO);
        let t = Instant::now();
        assert_eq!(b.push(7, t), Some(vec![7]));
        assert_eq!(b.push(9, t), Some(vec![9]));
        assert!(b.is_empty());
    }

    #[test]
    fn deadline_is_anchored_to_the_first_item() {
        let wait = Duration::from_millis(10);
        let mut b = MicroBatcher::new(100, wait);
        let t0 = Instant::now();
        assert_eq!(b.push('a', t0), None);
        // A later push must not extend the deadline.
        assert_eq!(b.push('b', t0 + Duration::from_millis(5)), None);
        assert!(!b.due(t0));
        assert!(!b.due(t0 + Duration::from_millis(9)));
        assert!(b.due(t0 + wait));
        assert_eq!(b.deadline(), Some(t0 + wait));
        assert_eq!(b.flush(), Some(vec!['a', 'b']));
        assert!(!b.due(t0 + Duration::from_secs(1)), "empty batcher is never due");
    }

    #[test]
    fn flush_on_empty_is_none() {
        let mut b: MicroBatcher<u32> = MicroBatcher::new(4, Duration::from_millis(1));
        assert_eq!(b.flush(), None);
        assert_eq!(b.deadline(), None);
    }

    #[test]
    fn max_batch_zero_clamps_to_one() {
        let mut b = MicroBatcher::new(0, Duration::from_secs(60));
        assert_eq!(b.push(1, Instant::now()), Some(vec![1]));
    }
}
