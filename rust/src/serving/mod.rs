//! Online split-parallel inference service (`gsplit serve`, DESIGN.md
//! §Serving).
//!
//! The trainer answers *batches*; a production system answers *queries*.
//! This module turns a trained [`Trainer`] into a long-running service:
//!
//! * **admission** — requests enter through a bounded queue
//!   ([`ServeClient::submit`]). At capacity the submit **rejects with a
//!   descriptive [`AdmitError`]** instead of blocking, so a traffic spike
//!   degrades into explicit backpressure, never into an unbounded queue or
//!   a stuck client;
//! * **dynamic micro-batching** — the serve loop coalesces admitted
//!   requests with a [`MicroBatcher`]: flush when the batch reaches
//!   `max_batch` or when the oldest request has waited `max_wait`,
//!   whichever comes first (`max_wait == 0` degrades to per-request
//!   batches);
//! * **split-parallel inference** — each micro-batch runs through
//!   [`Trainer::infer`]: cooperative stateless sampling, the cache-aware
//!   loading stage (same `CachePolicy`/`FeatureSource` paths as training,
//!   RAM or out-of-core), and the forward pass on the serial or pipelined
//!   executor. No backward, no parameter update, no labels;
//! * **shutdown drain** — dropping the [`ServeClient`] closes the queue;
//!   the loop finishes every in-flight request before exiting, so
//!   submitted work is never silently dropped.
//!
//! Served logits are **bit-identical** to an offline
//! [`Trainer::infer`] call on the same vertices: per-vertex stateless
//! sampling makes each neighborhood independent of micro-batch
//! composition, and the executors are bit-identical to each other by the
//! §Executor contract. `tests/serving_equivalence.rs` pins this across
//! batch boundaries × cache policies × worker counts × RAM/disk backing.

mod batcher;
pub mod traffic;

pub use batcher::MicroBatcher;

use std::collections::HashMap;
use std::fmt;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::graph::Dataset;
use crate::obs::{metrics, Phase};
use crate::span;
use crate::train::Trainer;
use crate::Vid;

/// Serving knobs: admission-queue bound, micro-batch flush rules, and the
/// sampling seed served responses are pinned to.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Flush a micro-batch at this many requests.
    pub max_batch: usize,
    /// Flush a micro-batch when its oldest request has waited this long
    /// (zero ⇒ one batch per request).
    pub max_wait: Duration,
    /// Bounded admission-queue capacity; submits beyond it are rejected
    /// with [`AdmitError::QueueFull`].
    pub queue_cap: usize,
    /// Sampling seed: every micro-batch samples with per-vertex streams
    /// derived from this one seed, which is what makes served logits
    /// independent of micro-batch grouping (DESIGN.md §Serving).
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 32,
            max_wait: Duration::from_micros(2000),
            queue_cap: 1024,
            seed: 0,
        }
    }
}

/// Why a request was not admitted. Admission never blocks: the caller
/// always gets either a [`PendingResponse`] or one of these, immediately.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AdmitError {
    /// The bounded admission queue is at capacity — backpressure; retry
    /// later or shed the request.
    QueueFull { cap: usize },
    /// The serve loop has exited; no further requests can be answered.
    ShuttingDown,
    /// The requested vertex is not in the served graph.
    UnknownVertex { vid: Vid, num_vertices: usize },
}

impl fmt::Display for AdmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AdmitError::QueueFull { cap } => {
                write!(f, "admission queue full ({cap} requests in flight); retry later")
            }
            AdmitError::ShuttingDown => write!(f, "serving loop is shutting down"),
            AdmitError::UnknownVertex { vid, num_vertices } => {
                write!(f, "vertex {vid} not in served graph ({num_vertices} vertices)")
            }
        }
    }
}

impl std::error::Error for AdmitError {}

/// One answered request: the requested vertex's top-layer logits and its
/// admission-to-response latency.
#[derive(Debug, Clone)]
pub struct Response {
    pub vid: Vid,
    /// `num_classes` logits, bit-identical to an offline
    /// [`Trainer::infer`] on the same seed.
    pub logits: Vec<f32>,
    pub latency: Duration,
}

/// Handle to one admitted request; [`PendingResponse::wait`] blocks until
/// the serve loop answers (or drops) it.
#[derive(Debug)]
pub struct PendingResponse {
    rx: Receiver<std::result::Result<Response, String>>,
}

impl PendingResponse {
    /// Block until the response arrives.
    pub fn wait(self) -> Result<Response> {
        match self.rx.recv() {
            Ok(Ok(r)) => Ok(r),
            Ok(Err(e)) => Err(anyhow!("inference failed: {e}")),
            Err(_) => Err(anyhow!("serving loop dropped the request before answering")),
        }
    }
}

/// One request in flight between admission and the serve loop.
struct Envelope {
    vid: Vid,
    tx: mpsc::Sender<std::result::Result<Response, String>>,
    admitted: Instant,
}

/// Client side of the admission queue. Clonable across threads is not
/// needed — share it by reference (submission is `&self`); dropping the
/// last reference closes the queue and lets the serve loop drain + exit.
#[derive(Debug)]
pub struct ServeClient {
    tx: SyncSender<Envelope>,
    queue_cap: usize,
    num_vertices: usize,
}

impl ServeClient {
    /// Admit one per-vertex inference request. Never blocks: at capacity
    /// this returns [`AdmitError::QueueFull`] immediately.
    pub fn submit(&self, vid: Vid) -> std::result::Result<PendingResponse, AdmitError> {
        if (vid as usize) >= self.num_vertices {
            return Err(AdmitError::UnknownVertex { vid, num_vertices: self.num_vertices });
        }
        let (tx, rx) = mpsc::channel();
        let env = Envelope { vid, tx, admitted: Instant::now() };
        match self.tx.try_send(env) {
            Ok(()) => Ok(PendingResponse { rx }),
            Err(TrySendError::Full(_)) => Err(AdmitError::QueueFull { cap: self.queue_cap }),
            Err(TrySendError::Disconnected(_)) => Err(AdmitError::ShuttingDown),
        }
    }

    /// Number of vertices in the served graph.
    pub fn num_vertices(&self) -> usize {
        self.num_vertices
    }
}

/// Aggregate serving statistics for one [`run`].
#[derive(Debug, Clone, Default)]
pub struct ServeReport {
    /// Requests answered (duplicates within a micro-batch each count).
    pub served: u64,
    /// Micro-batches executed.
    pub batches: u64,
    /// Admission-to-response latency of every served request, seconds.
    pub latencies_s: Vec<f64>,
    /// Wall time of the serve loop, admission open through drain.
    pub wall: Duration,
}

impl ServeReport {
    /// Nearest-rank latency percentile (`p` in 0..=100); 0 when nothing
    /// was served.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.latencies_s.is_empty() {
            return 0.0;
        }
        let mut sorted = self.latencies_s.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
        let idx = ((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
        sorted[idx.min(sorted.len() - 1)]
    }

    /// Served requests per second of loop wall time.
    pub fn rps(&self) -> f64 {
        self.served as f64 / self.wall.as_secs_f64().max(1e-9)
    }
}

/// Run the serving loop around a client closure: the loop serves on a
/// scoped thread while `f` drives traffic through the [`ServeClient`] on
/// the calling thread. When `f` returns (or unwinds) the client drops,
/// the queue closes, the loop drains every in-flight request, and the
/// [`ServeReport`] comes back with `f`'s result.
///
/// The trainer must already hold trained parameters; serving never
/// updates them and never touches `ds.labels`.
pub fn run<R>(
    trainer: &mut Trainer<'_>,
    ds: &Dataset,
    cfg: ServeConfig,
    f: impl FnOnce(&ServeClient) -> R,
) -> Result<(R, ServeReport)> {
    let (tx, rx) = mpsc::sync_channel::<Envelope>(cfg.queue_cap.max(1));
    let num_vertices = ds.graph.num_vertices();
    let queue_cap = cfg.queue_cap.max(1);
    thread::scope(|scope| {
        let handle = scope.spawn(move || serve_loop(trainer, ds, &cfg, rx));
        // The client lives inside this scope so an unwinding `f` still
        // drops it, closing the queue — the loop always drains and exits,
        // and the scope can always join.
        let client = ServeClient { tx, queue_cap, num_vertices };
        let out = f(&client);
        drop(client);
        let report = handle.join().map_err(|_| anyhow!("serve loop panicked"))??;
        Ok((out, report))
    })
}

/// The serve loop: gather one micro-batch (flush on deadline, fill, or
/// shutdown drain), run it, fan responses out, repeat until the queue is
/// closed and empty.
fn serve_loop(
    trainer: &mut Trainer<'_>,
    ds: &Dataset,
    cfg: &ServeConfig,
    rx: Receiver<Envelope>,
) -> Result<ServeReport> {
    crate::obs::set_thread_label("serve-loop");
    let requests_ctr = metrics::registry().counter("serve_requests", &[]);
    let batches_ctr = metrics::registry().counter("serve_batches", &[]);
    let mut batcher: MicroBatcher<Envelope> = MicroBatcher::new(cfg.max_batch, cfg.max_wait);
    let mut report = ServeReport::default();
    let t0 = Instant::now();
    let mut done = false;
    while !done || !batcher.is_empty() {
        // --- Gather one micro-batch ---
        let batch: Vec<Envelope> = loop {
            if done {
                // Queue closed: drain whatever is pending as a final batch.
                match batcher.flush() {
                    Some(b) => break b,
                    None => break Vec::new(),
                }
            }
            let now = Instant::now();
            if batcher.due(now) {
                break batcher.flush().expect("due batcher has a pending batch");
            }
            // Block until the pending batch's deadline (or an idle poll
            // tick when nothing is pending) for the next request.
            let wait = match batcher.deadline() {
                Some(deadline) => deadline.saturating_duration_since(now),
                None => Duration::from_millis(50),
            };
            match rx.recv_timeout(wait) {
                Ok(env) => {
                    requests_ctr.inc();
                    if let Some(b) = batcher.push(env, Instant::now()) {
                        break b;
                    }
                }
                Err(RecvTimeoutError::Timeout) => {} // re-check due()
                Err(RecvTimeoutError::Disconnected) => done = true,
            }
        };
        if batch.is_empty() {
            continue;
        }
        serve_one_batch(trainer, ds, cfg, batch, &mut report)?;
        batches_ctr.inc();
    }
    report.wall = t0.elapsed();
    Ok(report)
}

/// Execute one micro-batch: dedupe vertices (first-seen order), run the
/// split-parallel forward, fan each requester its row. An inference error
/// is fanned to every requester in the batch, then propagated.
fn serve_one_batch(
    trainer: &mut Trainer<'_>,
    ds: &Dataset,
    cfg: &ServeConfig,
    batch: Vec<Envelope>,
    report: &mut ServeReport,
) -> Result<()> {
    let _s = span!(Phase::ServeBatch);
    let mut uniq: Vec<Vid> = Vec::with_capacity(batch.len());
    let mut row_of: HashMap<Vid, usize> = HashMap::with_capacity(batch.len());
    for env in &batch {
        if !row_of.contains_key(&env.vid) {
            row_of.insert(env.vid, uniq.len());
            uniq.push(env.vid);
        }
    }
    // The seed is the same for every micro-batch: per-vertex stateless
    // streams make repeat requests for a vertex bit-identical no matter
    // which batch they land in.
    match trainer.infer(ds, &uniq, cfg.seed) {
        Ok(flat) => {
            let c = trainer.params.cfg.num_classes;
            let now = Instant::now();
            report.batches += 1;
            for env in batch {
                let i = row_of[&env.vid];
                let latency = now.saturating_duration_since(env.admitted);
                report.served += 1;
                report.latencies_s.push(latency.as_secs_f64());
                let resp = Response {
                    vid: env.vid,
                    logits: flat[i * c..(i + 1) * c].to_vec(),
                    latency,
                };
                // A requester that gave up is not an error for the batch.
                let _ = env.tx.send(Ok(resp));
            }
            Ok(())
        }
        Err(e) => {
            let msg = e.to_string();
            for env in batch {
                let _ = env.tx.send(Err(msg.clone()));
            }
            Err(e)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_client(cap: usize, num_vertices: usize) -> (ServeClient, Receiver<Envelope>) {
        let (tx, rx) = mpsc::sync_channel(cap);
        (ServeClient { tx, queue_cap: cap, num_vertices }, rx)
    }

    #[test]
    fn queue_at_capacity_rejects_without_blocking() {
        let (client, _rx) = test_client(2, 100);
        assert!(client.submit(1).is_ok());
        assert!(client.submit(2).is_ok());
        let t0 = Instant::now();
        let err = client.submit(3).expect_err("third submit must be rejected");
        assert_eq!(err, AdmitError::QueueFull { cap: 2 });
        assert!(
            t0.elapsed() < Duration::from_secs(1),
            "rejection must be immediate, not a blocked send"
        );
    }

    #[test]
    fn out_of_range_vertex_is_rejected_before_admission() {
        let (client, rx) = test_client(8, 10);
        let err = client.submit(10).expect_err("vid == num_vertices is out of range");
        assert_eq!(err, AdmitError::UnknownVertex { vid: 10, num_vertices: 10 });
        assert!(client.submit(9).is_ok());
        // The bad request never entered the queue.
        assert_eq!(rx.try_iter().count(), 1);
    }

    #[test]
    fn closed_loop_reports_shutting_down() {
        let (client, rx) = test_client(8, 10);
        drop(rx);
        assert_eq!(client.submit(0).expect_err("loop is gone"), AdmitError::ShuttingDown);
    }

    #[test]
    fn admit_errors_are_descriptive() {
        assert_eq!(
            AdmitError::QueueFull { cap: 4 }.to_string(),
            "admission queue full (4 requests in flight); retry later"
        );
        assert_eq!(AdmitError::ShuttingDown.to_string(), "serving loop is shutting down");
        assert_eq!(
            AdmitError::UnknownVertex { vid: 7, num_vertices: 5 }.to_string(),
            "vertex 7 not in served graph (5 vertices)"
        );
    }

    #[test]
    fn dropped_loop_fails_pending_waits_instead_of_hanging() {
        let (client, rx) = test_client(8, 10);
        let pending = client.submit(3).expect("admitted");
        drop(rx); // the loop dies with the envelope unanswered
        let err = pending.wait().expect_err("wait must fail, not hang");
        assert!(err.to_string().contains("dropped the request"));
    }

    #[test]
    fn report_percentiles_and_rps() {
        let report = ServeReport {
            served: 4,
            batches: 2,
            latencies_s: vec![0.004, 0.001, 0.003, 0.002],
            wall: Duration::from_secs(2),
        };
        assert_eq!(report.percentile(0.0), 0.001);
        assert_eq!(report.percentile(100.0), 0.004);
        assert_eq!(report.percentile(50.0), 0.003); // nearest-rank on 4 samples
        assert!((report.rps() - 2.0).abs() < 1e-9);
        assert_eq!(ServeReport::default().percentile(99.0), 0.0);
    }
}
