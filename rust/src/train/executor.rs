//! Threaded, pipelined split-parallel executor (DESIGN.md §Executor).
//!
//! The serial trainer runs every simulated device one after another; this
//! module runs the same cooperative algorithm on worker threads:
//!
//! * **compute stage** — the `k` simulated devices are assigned round-robin
//!   to `workers` OS threads; each device runs its own [`Backend`] layer
//!   calls on its slice of the mini-batch,
//! * **exchange stage** — per-layer all-to-all shuffles of hidden-feature
//!   rows (forward) and their gradients (backward) flow through a
//!   [`Fabric`] of typed bounded channels ([`RowChunk`] messages),
//!   mirroring Algorithms 1–2; gradient all-reduce contributions and loss
//!   statistics travel to the coordinator over a typed result channel,
//! * **plan stage** — while the workers train batch *t*, the coordinator
//!   thread runs the plan stage for batch *t+1* (cooperative sampling +
//!   input-feature gather), the paper §6 inter-batch overlap.
//!
//! When the trainer has a [`ResidentCache`] installed, each batch starts
//! with an extra **loading exchange** phase: rows the plan stage
//! classified as `Peer` are served out of the owning device's resident
//! cache over the same channel fabric, before the first forward shuffle
//! (DESIGN.md §Loading). Destination rows are distinct and the payloads
//! are bit-exact copies of host rows, so the phase preserves the
//! determinism contract at every cache policy and budget.
//!
//! The executor is **bit-identical** to the serial trainer for the same
//! seed, at every worker count and channel capacity. The communication
//! primitives carrying that contract — the chunked all-to-all pump, the
//! fixed-order all-reduce, and the job broadcast — live in
//! [`crate::collectives`] (DESIGN.md §Collectives); this module adds the
//! trainer-specific composition: per-device compute is self-contained (so
//! thread interleaving cannot change it), forward shuffle rows scatter to
//! disjoint `mixed_src` positions, backward contributions are staged per
//! source device and applied in fixed device order `0..k`, and the
//! coordinator reduces loss statistics and gradients in fixed device
//! order before the SGD step on the one canonical [`ParamStore`].

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::cache::ResidentCache;
use crate::collectives::{self, Fabric, FabricEndpoint, OutQueue, RowChunk};
use crate::graph::{Dataset, FeatureSource};
use crate::model::{ModelConfig, ParamStore};
use crate::obs::Phase;
use crate::runtime::Backend;
use crate::span;
use crate::split::SplitPlan;
use crate::{DeviceId, Vid};

use super::plan::PreparedBatch;
use super::{IterStats, Trainer};

/// How a [`Trainer`] executes mini-batches.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecMode {
    /// Reference executor: every simulated device runs one after another on
    /// the calling thread.
    #[default]
    Serial,
    /// Threaded, pipelined executor — bit-identical to [`ExecMode::Serial`]
    /// for the same seed (see the module docs for the contract).
    Pipelined(PipelineConfig),
}

impl ExecMode {
    /// The single executor-selection surface: training, evaluation, and
    /// inference (and therefore serving, which routes through
    /// `Trainer::infer`) all pick serial-vs-pipelined here, so a future
    /// execution engine is one new match arm instead of one per entry
    /// point. `ctx` threads the caller's state (e.g. `&mut Trainer` plus
    /// a prepared batch) into whichever arm runs.
    pub fn dispatch<C, T>(
        self,
        ctx: C,
        serial: impl FnOnce(C) -> Result<T>,
        pipelined: impl FnOnce(C, PipelineConfig) -> Result<T>,
    ) -> Result<T> {
        match self {
            ExecMode::Serial => serial(ctx),
            ExecMode::Pipelined(cfg) => pipelined(ctx, cfg),
        }
    }
}

/// Tuning knobs of the pipelined executor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PipelineConfig {
    /// Worker threads the simulated devices are distributed over
    /// (round-robin). Clamped to `1..=k`.
    pub workers: usize,
    /// Bounded capacity, in [`RowChunk`] messages, of each directed
    /// device-to-device channel. Small capacities force backpressure;
    /// results are unaffected.
    pub channel_cap: usize,
    /// Maximum rows per shuffle chunk. Small values increase message count
    /// (useful for stress tests); results are unaffected.
    pub chunk_rows: usize,
}

impl PipelineConfig {
    /// A sensible configuration for `workers` threads.
    pub fn with_workers(workers: usize) -> Self {
        PipelineConfig { workers: workers.max(1), channel_cap: 8, chunk_rows: 4096 }
    }
}

impl Default for PipelineConfig {
    fn default() -> Self {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self::with_workers(n)
    }
}

/// One mini-batch to execute: the target vertices plus the fully derived
/// plan-stage seed (so serial and pipelined paths share seed derivation).
pub(super) struct BatchSpec {
    pub targets: Vec<Vid>,
    pub plan_seed: u64,
}

/// Work order broadcast to every worker ([`collectives::broadcast`] — the
/// `Clone` is per-receiver; payloads are shared via [`Arc`]).
#[derive(Clone)]
enum Job {
    Batch {
        idx: usize,
        prep: Arc<PreparedBatch>,
        params: Arc<ParamStore>,
        backward: bool,
    },
    /// Forward-only inference: run the shared loading-exchange + forward
    /// front half and report each owned device's top-layer logits — no
    /// loss head (labels never touched), no backward, no SGD step.
    Infer {
        idx: usize,
        prep: Arc<PreparedBatch>,
        params: Arc<ParamStore>,
    },
    Stop,
}

/// Per-device outcome returned to the coordinator for the fixed-order
/// reduction (loss stats + parameter-gradient all-reduce).
struct DeviceResult {
    batch_idx: usize,
    dev: usize,
    examples: usize,
    loss_weighted: f32,
    correct: f32,
    /// Per sampled layer `i`: `Some(per-tensor grads)` iff the device was
    /// backward-active there (mirrors the serial skip condition).
    #[allow(clippy::type_complexity)]
    gparams: Vec<Option<Vec<Vec<f32>>>>,
}

enum WorkerMsg {
    Dev(DeviceResult),
    /// One device's top-layer logits for a [`Job::Infer`] batch, row-major
    /// `[num_dst, num_classes]` in `plan.layers[0].per_dev[dev].dst` order.
    Logits {
        batch_idx: usize,
        dev: usize,
        rows: Vec<f32>,
    },
    Err(String),
}

/// Sets the shared abort flag when dropped, so fellow workers never spin
/// forever waiting for chunks from a worker that panicked or errored out.
/// (At clean shutdown everything is already drained, so the flag is inert.)
struct AbortOnDrop(Arc<AtomicBool>);

impl Drop for AbortOnDrop {
    fn drop(&mut self) {
        self.0.store(true, Ordering::SeqCst);
    }
}

/// Trainer state the worker threads borrow, copied out before the thread
/// scope so the coordinator keeps exclusive use of `&mut Trainer` for the
/// overlapped plan stage.
struct WorkerCtx<'e> {
    backend: &'e dyn Backend,
    ds: &'e Dataset,
    model_cfg: ModelConfig,
    kernel_k: usize,
    cache: Option<Arc<ResidentCache>>,
}

impl<'e> WorkerCtx<'e> {
    fn of(trainer: &Trainer<'e>, ds: &'e Dataset) -> Self {
        WorkerCtx {
            backend: trainer.backend,
            ds,
            model_cfg: trainer.params.cfg.clone(),
            kernel_k: trainer.fanouts[0],
            cache: trainer.cache.clone(),
        }
    }
}

/// Spawn the worker pool: each of the `n_workers` threads takes its
/// round-robin devices' [`Fabric`] endpoints and listens on a depth-1 job
/// channel. Shared by the training and inference drivers — the one place
/// the fabric is wired to threads.
fn spawn_workers<'scope, 'env: 'scope>(
    scope: &'scope thread::Scope<'scope, 'env>,
    ctx: &WorkerCtx<'env>,
    fabric: &mut Fabric,
    n_workers: usize,
    res_tx: &Sender<WorkerMsg>,
) -> Vec<SyncSender<Job>> {
    let k = fabric.k();
    let abort = fabric.abort_handle();
    let mut job_txs: Vec<SyncSender<Job>> = Vec::with_capacity(n_workers);
    for w in 0..n_workers {
        let endpoint = fabric.endpoint((0..k).filter(|d| d % n_workers == w).collect());
        let (jtx, jrx) = sync_channel::<Job>(1);
        job_txs.push(jtx);
        let worker = Worker {
            backend: ctx.backend,
            ds: ctx.ds,
            cfg: ctx.model_cfg.clone(),
            kernel_k: ctx.kernel_k,
            cache: ctx.cache.clone(),
            fabric: endpoint,
            abort: Arc::clone(&abort),
            res_tx: res_tx.clone(),
        };
        scope.spawn(move || {
            crate::obs::set_thread_label(&format!("worker-{w}"));
            let guard = AbortOnDrop(Arc::clone(&worker.abort));
            worker.run(jrx);
            drop(guard);
        });
    }
    job_txs
}

/// Run `specs` through the threaded pipelined executor. Returns one
/// [`IterStats`] per batch; when `backward`, the trainer's parameters are
/// stepped after each batch exactly as the serial path would.
pub(super) fn run_batches(
    trainer: &mut Trainer<'_>,
    ds: &Dataset,
    specs: &[BatchSpec],
    backward: bool,
    cfg: PipelineConfig,
) -> Result<Vec<IterStats>> {
    if specs.is_empty() {
        return Ok(Vec::new());
    }
    crate::obs::set_thread_label("coordinator");
    let k = trainer.part.k;
    let n_workers = cfg.workers.clamp(1, k);
    let lr = trainer.lr;
    let wctx = WorkerCtx::of(trainer, ds);
    let model_cfg = wctx.model_cfg.clone();

    let mut fabric = Fabric::new(k, cfg.channel_cap, cfg.chunk_rows);
    let abort = fabric.abort_handle();
    let (res_tx, res_rx) = channel::<WorkerMsg>();

    let mut stats: Vec<IterStats> = Vec::with_capacity(specs.len());
    thread::scope(|scope| -> Result<()> {
        let job_txs = spawn_workers(scope, &wctx, &mut fabric, n_workers, &res_tx);
        drop(res_tx);

        let mut next_prep: Option<Arc<PreparedBatch>> = None;
        for (t, spec) in specs.iter().enumerate() {
            let prep = match next_prep.take() {
                Some(p) => p,
                None => Arc::new(trainer.prepare(ds, &spec.targets, spec.plan_seed)),
            };
            let params = Arc::new(trainer.params.clone());
            collectives::broadcast(
                &job_txs,
                Job::Batch {
                    idx: t,
                    prep: Arc::clone(&prep),
                    params: Arc::clone(&params),
                    backward,
                },
            )
            .map_err(|_| anyhow!("executor worker exited early"))?;
            // Plan stage for batch t+1 overlaps the workers training batch t.
            if let Some(next) = specs.get(t + 1) {
                let _s = span!(Phase::SampleAhead, batch = trainer.batches_prepared);
                next_prep = Some(Arc::new(trainer.prepare(ds, &next.targets, next.plan_seed)));
            }
            // Collect every device's result, then reduce in device order.
            // Timed receive: a worker that panics sets the abort flag (via
            // AbortOnDrop) without ever sending a result, and its idle
            // peers cannot wake the coordinator — so poll the flag instead
            // of blocking forever.
            let mut by_dev: Vec<Option<DeviceResult>> = (0..k).map(|_| None).collect();
            let mut got = 0usize;
            while got < k {
                match res_rx.recv_timeout(Duration::from_millis(100)) {
                    Ok(WorkerMsg::Dev(r)) => {
                        debug_assert_eq!(r.batch_idx, t);
                        debug_assert!(by_dev[r.dev].is_none());
                        by_dev[r.dev] = Some(r);
                        got += 1;
                    }
                    Ok(WorkerMsg::Logits { .. }) => {
                        bail!("unexpected inference result during training")
                    }
                    Ok(WorkerMsg::Err(e)) => bail!("executor worker failed: {e}"),
                    Err(RecvTimeoutError::Timeout) => {
                        if abort.load(Ordering::SeqCst) {
                            bail!("executor worker died (panic or abort)");
                        }
                    }
                    Err(RecvTimeoutError::Disconnected) => bail!("executor workers disconnected"),
                }
            }
            {
                let _s = span!(Phase::GradReduce, batch = prep.batch_idx);
                stats.push(reduce_batch(trainer, &model_cfg, &prep.plan, &by_dev, backward, lr));
            }
        }
        let _ = collectives::broadcast(&job_txs, Job::Stop);
        Ok(())
    })?;
    Ok(stats)
}

/// Run one prepared batch's forward-only inference through the threaded
/// pipelined executor: the same worker pool, channel fabric, and exchange
/// phases as [`run_batches`], but workers stop at the top layer and report
/// logits instead of loss statistics and gradients. Returns per-device
/// top-layer logits, `out[d]` row-major `[num_dst, num_classes]` in
/// `plan.layers[0].per_dev[d].dst` order — bit-identical to the serial
/// inference path for the same `PreparedBatch` (the forward half of the
/// module's determinism contract; labels are never touched).
pub(super) fn run_infer(
    trainer: &Trainer<'_>,
    ds: &Dataset,
    prep: PreparedBatch,
    cfg: PipelineConfig,
) -> Result<Vec<Vec<f32>>> {
    crate::obs::set_thread_label("coordinator");
    let k = trainer.part.k;
    let n_workers = cfg.workers.clamp(1, k);
    let wctx = WorkerCtx::of(trainer, ds);

    let mut fabric = Fabric::new(k, cfg.channel_cap, cfg.chunk_rows);
    let abort = fabric.abort_handle();
    let (res_tx, res_rx) = channel::<WorkerMsg>();
    let prep = Arc::new(prep);
    let params = Arc::new(trainer.params.clone());

    let mut logits: Vec<Vec<f32>> = vec![Vec::new(); k];
    thread::scope(|scope| -> Result<()> {
        let job_txs = spawn_workers(scope, &wctx, &mut fabric, n_workers, &res_tx);
        drop(res_tx);

        collectives::broadcast(
            &job_txs,
            Job::Infer { idx: 0, prep: Arc::clone(&prep), params: Arc::clone(&params) },
        )
        .map_err(|_| anyhow!("executor worker exited early"))?;
        // Collect every device's logits (same timed-receive abort polling
        // as the training coordinator).
        let mut seen = vec![false; k];
        let mut got = 0usize;
        while got < k {
            match res_rx.recv_timeout(Duration::from_millis(100)) {
                Ok(WorkerMsg::Logits { batch_idx, dev, rows }) => {
                    debug_assert_eq!(batch_idx, 0);
                    debug_assert!(!seen[dev]);
                    seen[dev] = true;
                    logits[dev] = rows;
                    got += 1;
                }
                Ok(WorkerMsg::Dev(_)) => bail!("unexpected training result during inference"),
                Ok(WorkerMsg::Err(e)) => bail!("executor worker failed: {e}"),
                Err(RecvTimeoutError::Timeout) => {
                    if abort.load(Ordering::SeqCst) {
                        bail!("executor worker died (panic or abort)");
                    }
                }
                Err(RecvTimeoutError::Disconnected) => bail!("executor workers disconnected"),
            }
        }
        let _ = collectives::broadcast(&job_txs, Job::Stop);
        Ok(())
    })?;
    Ok(logits)
}

/// Fixed-device-order reduction of one batch's per-device results: loss
/// statistics, the gradient all-reduce ([`collectives::all_reduce`]), and
/// the SGD step — the same floating-point operation sequence as the
/// serial trainer.
fn reduce_batch(
    trainer: &mut Trainer<'_>,
    cfg: &ModelConfig,
    plan: &SplitPlan,
    by_dev: &[Option<DeviceResult>],
    backward: bool,
    lr: f32,
) -> IterStats {
    let total_examples: usize = plan.layers[0].per_dev.iter().map(|dl| dl.num_dst()).sum();
    let mut loss_sum = 0f32;
    let mut correct = 0f32;
    for r in by_dev.iter() {
        let r = r.as_ref().expect("every device reports");
        if r.examples == 0 {
            continue;
        }
        loss_sum += r.loss_weighted;
        correct += r.correct;
    }
    let stats = IterStats {
        loss: loss_sum / total_examples.max(1) as f32,
        correct,
        examples: total_examples,
    };
    if backward {
        let num_layers = plan.layers.len();
        let mut g_params: Vec<Vec<Vec<f32>>> = trainer
            .params
            .layers
            .iter()
            .map(|lp| lp.tensors.iter().map(|t| vec![0f32; t.len()]).collect())
            .collect();
        for i in 0..num_layers {
            let l = cfg.num_layers - 1 - i;
            let contribs: Vec<Option<&Vec<Vec<f32>>>> = by_dev
                .iter()
                .map(|r| r.as_ref().expect("every device reports").gparams[i].as_ref())
                .collect();
            collectives::all_reduce(&mut g_params[l], &contribs);
        }
        trainer.params.sgd_step(&g_params, lr);
    }
    stats
}

/// One worker thread: a static subset of the simulated devices plus its
/// side of the channel fabric.
struct Worker<'e> {
    backend: &'e dyn Backend,
    ds: &'e Dataset,
    cfg: ModelConfig,
    kernel_k: usize,
    /// Resident feature cache shared with the trainer; this worker serves
    /// its owned devices' cached rows during the loading exchange phase.
    cache: Option<Arc<ResidentCache>>,
    /// This worker's side of the [`Fabric`]: its owned devices' senders
    /// and receivers, the chunking parameters, and the abort flag the
    /// all-to-all pump polls.
    fabric: FabricEndpoint,
    abort: Arc<AtomicBool>,
    res_tx: Sender<WorkerMsg>,
}

impl<'e> Worker<'e> {
    fn run(&self, jobs: Receiver<Job>) {
        loop {
            match jobs.recv() {
                Ok(Job::Batch { idx, prep, params, backward }) => {
                    match self.run_batch(idx, &prep, &params, backward) {
                        Ok(results) => {
                            for r in results {
                                if self.res_tx.send(WorkerMsg::Dev(r)).is_err() {
                                    return;
                                }
                            }
                        }
                        Err(e) => {
                            self.abort.store(true, Ordering::SeqCst);
                            let _ = self.res_tx.send(WorkerMsg::Err(e.to_string()));
                            return;
                        }
                    }
                }
                Ok(Job::Infer { idx, prep, params }) => {
                    match self.fwd_to_top(&prep, &params) {
                        Ok((_mixed, hidden)) => {
                            for (rows, &d) in hidden.into_iter().zip(self.fabric.owned()) {
                                let msg = WorkerMsg::Logits { batch_idx: idx, dev: d, rows };
                                if self.res_tx.send(msg).is_err() {
                                    return;
                                }
                            }
                        }
                        Err(e) => {
                            self.abort.store(true, Ordering::SeqCst);
                            let _ = self.res_tx.send(WorkerMsg::Err(e.to_string()));
                            return;
                        }
                    }
                }
                Ok(Job::Stop) | Err(_) => return,
            }
        }
    }

    /// Pack resident-cache rows of device `d` for `vids` (the loading
    /// exchange phase's counterpart of [`FabricEndpoint::pack_rows`]).
    fn pack_cache_rows(
        &self,
        cache: &ResidentCache,
        d: DeviceId,
        vids: &[Vid],
        width: usize,
    ) -> VecDeque<RowChunk> {
        self.fabric.pack_chunks(vids.len(), width, |i, rows| {
            rows.extend_from_slice(
                cache.resident_row(d, vids[i]).expect("peer-served row resident on server"),
            );
        })
    }

    /// Loading exchange + bottom-up forward over this worker's owned
    /// devices — the shared front half of training ([`Worker::run_batch`])
    /// and forward-only inference ([`Job::Infer`]). Returns the per-layer
    /// mixed-frontier inputs (kept for the backward pass) and each owned
    /// device's top-layer hidden rows, both indexed like
    /// `self.fabric.owned()`.
    #[allow(clippy::type_complexity)]
    fn fwd_to_top(
        &self,
        prep: &PreparedBatch,
        params: &ParamStore,
    ) -> Result<(Vec<Vec<Vec<f32>>>, Vec<Vec<f32>>)> {
        let plan = &prep.plan;
        let k = plan.k;
        let num_layers = plan.layers.len();
        let cfg = &self.cfg;
        let kernel_k = self.kernel_k;
        let owned = self.fabric.owned().to_vec();
        let n_own = owned.len();
        // Global batch counter for trace labels (the coordinator's batch
        // index is per-call; spans use the trainer-global one so serial
        // and pipelined traces label batches identically).
        let bidx = prep.batch_idx;

        // Owned rows at the current bottom-up boundary, starting from the
        // input features the plan stage gathered.
        let mut hidden: Vec<Vec<f32>> =
            owned.iter().map(|&d| prep.feats[d].clone()).collect();
        // mixed[i][li]: materialized mixed-frontier inputs, kept for backward.
        let mut mixed: Vec<Vec<Vec<f32>>> =
            (0..num_layers).map(|_| vec![Vec::new(); n_own]).collect();

        // --- Loading exchange: serve Peer-classified rows out of this
        // worker's resident caches and fill the holes the plan stage left
        // in the input buffers (DESIGN.md §Loading). Whether this phase
        // exists is a trainer-level invariant (cache installed or not), so
        // every worker agrees on the phase sequence; expected chunk counts
        // derive from the shared LoadingPlan; destination rows are
        // distinct, so arrival order is irrelevant.
        if let Some(cache) = &self.cache {
            let _s = span!(Phase::LoadExchange, batch = bidx);
            let dim = self.ds.features.dim();
            let load = &prep.loading;
            let mut outgoing: Vec<OutQueue> = Vec::new();
            for (li, &d) in owned.iter().enumerate() {
                for to in 0..k {
                    let pf = &load.peer_fetch[d][to];
                    if pf.is_empty() {
                        continue;
                    }
                    outgoing.push(OutQueue {
                        li,
                        to,
                        q: self.pack_cache_rows(cache, d as DeviceId, &pf.vids, dim),
                    });
                }
            }
            let mut expect = vec![vec![0usize; k]; n_own];
            for (li, &d) in owned.iter().enumerate() {
                for from in 0..k {
                    expect[li][from] = self.fabric.chunks_of(load.peer_fetch[from][d].len());
                }
            }
            let hidden_mut = &mut hidden;
            self.fabric.all_to_all(&mut outgoing, &mut expect, |li, from, chunk| {
                let pf = &load.peer_fetch[from][owned[li]];
                let nrows = chunk.rows.len() / dim;
                let start = chunk.start as usize;
                for j in 0..nrows {
                    let pos = pf.dst_rows[start + j] as usize;
                    hidden_mut[li][pos * dim..(pos + 1) * dim]
                        .copy_from_slice(&chunk.rows[j * dim..(j + 1) * dim]);
                }
            })?;
        }

        // --- Forward, bottom-up ---
        for i in (0..num_layers).rev() {
            let l = cfg.num_layers - 1 - i;
            let (din, dout) = (cfg.in_dim(l), cfg.out_dim(l));
            let relu = l + 1 < cfg.num_layers;
            let layer = &plan.layers[i];

            // Exchange: pack owned rows for every destination device...
            let mut outgoing: Vec<OutQueue> = Vec::new();
            {
                let _s = span!(Phase::ShuffleFwdSend, batch = bidx, layer = i);
                for (li, &d) in owned.iter().enumerate() {
                    for to in 0..k {
                        let idx = &layer.shuffle.send[d][to];
                        if idx.is_empty() {
                            continue;
                        }
                        outgoing.push(OutQueue {
                            li,
                            to,
                            q: self.fabric.pack_rows(&hidden[li], idx, din),
                        });
                    }
                }
            }
            // ...and scatter arriving rows into the mixed frontiers (the
            // shuffle index is a bijection, so positions are disjoint and
            // arrival order cannot matter).
            let mut expect = vec![vec![0usize; k]; n_own];
            for (li, &d) in owned.iter().enumerate() {
                mixed[i][li] = vec![0f32; layer.per_dev[d].mixed_src.len() * din];
                for from in 0..k {
                    expect[li][from] = self.fabric.chunks_of(layer.shuffle.send[from][d].len());
                }
            }
            let mixed_i = &mut mixed[i];
            {
                let _s = span!(Phase::ShuffleFwdRecv, batch = bidx, layer = i);
                self.fabric.all_to_all(&mut outgoing, &mut expect, |li, from, chunk| {
                    let rl = &layer.shuffle.recv[owned[li]][from];
                    let nrows = chunk.rows.len() / din;
                    let start = chunk.start as usize;
                    for j in 0..nrows {
                        let pos = rl[start + j] as usize;
                        mixed_i[li][pos * din..(pos + 1) * din]
                            .copy_from_slice(&chunk.rows[j * din..(j + 1) * din]);
                    }
                })?;
            }

            // Compute this layer's owned hidden rows.
            for (li, &d) in owned.iter().enumerate() {
                let dl = &layer.per_dev[d];
                if dl.num_dst() == 0 {
                    hidden[li] = Vec::new();
                    continue;
                }
                let _s = span!(Phase::ComputeFwd, device = d, batch = bidx, layer = i);
                hidden[li] = self.backend.layer_fwd(
                    cfg.kind,
                    din,
                    dout,
                    relu,
                    &mixed[i][li],
                    dl.mixed_src.len(),
                    &dl.neigh,
                    dl.num_dst(),
                    kernel_k,
                    &params.layers[l],
                )?;
            }
        }
        Ok((mixed, hidden))
    }

    /// Execute this worker's share of one mini-batch: the same per-device
    /// math as the serial trainer, with channel all-to-alls where the
    /// serial code indexes other devices' buffers directly.
    fn run_batch(
        &self,
        batch_idx: usize,
        prep: &PreparedBatch,
        params: &ParamStore,
        backward: bool,
    ) -> Result<Vec<DeviceResult>> {
        let plan = &prep.plan;
        let k = plan.k;
        let num_layers = plan.layers.len();
        let cfg = &self.cfg;
        let kernel_k = self.kernel_k;
        let owned = self.fabric.owned().to_vec();
        let n_own = owned.len();
        let bidx = prep.batch_idx;
        let (mixed, hidden) = self.fwd_to_top(prep, params)?;

        // --- Loss head per owned device ---
        let c = cfg.num_classes;
        let total_examples: usize = plan.layers[0].per_dev.iter().map(|dl| dl.num_dst()).sum();
        let mut dev_loss = vec![0f32; n_own];
        let mut dev_correct = vec![0f32; n_own];
        let mut dev_examples = vec![0usize; n_own];
        let mut g_out: Vec<Vec<f32>> = vec![Vec::new(); n_own];
        for (li, &d) in owned.iter().enumerate() {
            let dl = &plan.layers[0].per_dev[d];
            let b_d = dl.num_dst();
            dev_examples[li] = b_d;
            if b_d == 0 {
                continue;
            }
            let _s = span!(Phase::Loss, device = d, batch = bidx);
            let labels: Vec<i32> =
                dl.dst.iter().map(|&v| self.ds.labels.labels[v as usize] as i32).collect();
            let (out, g_logits) = self.backend.loss(&hidden[li], &labels, b_d, c)?;
            dev_loss[li] = out.loss * b_d as f32;
            dev_correct[li] = out.correct;
            if backward {
                // Rescale device-mean gradient to global-mean (identical
                // expression to the serial path).
                let scale = 1.0 / total_examples as f32 * b_d as f32;
                g_out[li] = g_logits.iter().map(|g| g * scale).collect();
            }
        }

        // --- Backward, top-down ---
        #[allow(clippy::type_complexity)]
        let mut gparams: Vec<Vec<Option<Vec<Vec<f32>>>>> =
            (0..n_own).map(|_| vec![None; num_layers]).collect();
        if backward {
            for i in 0..num_layers {
                let l = cfg.num_layers - 1 - i;
                let (din, dout) = (cfg.in_dim(l), cfg.out_dim(l));
                let relu = l + 1 < cfg.num_layers;
                let layer = &plan.layers[i];

                // Per-device VJP, then send mixed-row gradients back to the
                // owners along the reversed shuffle index.
                let mut outgoing: Vec<OutQueue> = Vec::new();
                for (li, &d) in owned.iter().enumerate() {
                    let dl = &layer.per_dev[d];
                    let active = plan.bwd_active(i, d);
                    debug_assert_eq!(active, dl.num_dst() != 0 && !g_out[li].is_empty());
                    if !active {
                        continue;
                    }
                    let grads = {
                        let _s = span!(Phase::ComputeBwd, device = d, batch = bidx, layer = i);
                        self.backend.layer_bwd(
                            cfg.kind,
                            din,
                            dout,
                            relu,
                            &mixed[i][li],
                            dl.mixed_src.len(),
                            &dl.neigh,
                            dl.num_dst(),
                            kernel_k,
                            &g_out[li],
                            &params.layers[l],
                        )?
                    };
                    let _s = span!(Phase::ShuffleBwdSend, device = d, batch = bidx, layer = i);
                    for to in 0..k {
                        let idx = &layer.shuffle.recv[d][to];
                        if idx.is_empty() {
                            continue;
                        }
                        outgoing.push(OutQueue {
                            li,
                            to,
                            q: self.fabric.pack_rows(&grads.g_x, idx, din),
                        });
                    }
                    gparams[li][i] = Some(grads.g_params);
                }

                // Receive into per-source staging buffers — NOT applied on
                // arrival, so the scatter-add below can run in the fixed
                // device order the determinism contract requires.
                let mut expect = vec![vec![0usize; k]; n_own];
                let mut stage: Vec<Vec<Vec<RowChunk>>> =
                    (0..n_own).map(|_| (0..k).map(|_| Vec::new()).collect()).collect();
                for (li, &o) in owned.iter().enumerate() {
                    for from in 0..k {
                        if plan.bwd_active(i, from) {
                            expect[li][from] =
                                self.fabric.chunks_of(layer.shuffle.send[o][from].len());
                        }
                    }
                }
                let _s = span!(Phase::ShuffleBwdRecv, batch = bidx, layer = i);
                self.fabric.all_to_all(&mut outgoing, &mut expect, |li, from, chunk| {
                    stage[li][from].push(chunk);
                })?;

                // Accumulate per source, in fixed device order 0..k, each
                // source's chunks in send-list order — the serial ordering.
                for (li, &o) in owned.iter().enumerate() {
                    let mut g = vec![0f32; plan.owned_rows(i, o).len() * din];
                    for from in 0..k {
                        let sl = &layer.shuffle.send[o][from];
                        for chunk in &stage[li][from] {
                            let nrows = chunk.rows.len() / din;
                            let start = chunk.start as usize;
                            for j in 0..nrows {
                                let pos = sl[start + j] as usize;
                                let dst = &mut g[pos * din..(pos + 1) * din];
                                let src = &chunk.rows[j * din..(j + 1) * din];
                                for (a, b) in dst.iter_mut().zip(src) {
                                    *a += b;
                                }
                            }
                        }
                    }
                    g_out[li] = g;
                }
            }
        }

        let mut results = Vec::with_capacity(n_own);
        for (li, &d) in owned.iter().enumerate() {
            results.push(DeviceResult {
                batch_idx,
                dev: d,
                examples: dev_examples[li],
                loss_weighted: dev_loss[li],
                correct: dev_correct[li],
                gparams: std::mem::take(&mut gparams[li]),
            });
        }
        Ok(results)
    }
}
