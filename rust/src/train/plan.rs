//! Plan stage of the split-parallel executor (DESIGN.md §Executor,
//! §Loading).
//!
//! Producing a mini-batch's [`SplitPlan`] (cooperative sampling + shuffle
//! index, the paper's S phase) and gathering each device's non-overlapping
//! input-feature rows (the L phase) depend only on the dataset, the
//! partitioning, the cache placement, and the iteration seed — **not** on
//! the model parameters. Packaging both as one [`PreparedBatch`] lets the
//! serial executor consume it inline and lets the pipelined executor
//! prepare batch *t+1* while the workers are still training batch *t* (the
//! paper §6 inter-batch overlap).
//!
//! With a [`ResidentCache`] installed, the loading stage classifies every
//! input row by [`FetchSource`]:
//!
//! * **Local** — copied here from the device's own resident cache;
//! * **Peer(o)** — left as a hole in `feats[d]`, recorded in the
//!   [`LoadingPlan`] so the executor's pre-forward exchange phase can pull
//!   it from device `o`'s resident cache (serial: direct copy in fixed
//!   device order; pipelined: over the k×k channel fabric);
//! * **Host** — copied here from the [`FeatureSource`] (the PCIe
//!   fallback). The source reports which host-side tier actually served
//!   the row: host RAM (`host_bytes`) or, for an out-of-core
//!   `DiskFeatureStore` whose chunk buffer missed, disk (`disk_bytes`) —
//!   the fourth tier of DESIGN.md §Loading.
//!
//! All sources hold bit-exact copies of the same rows, so neither the
//! cache policy nor the feature source can change the numerics — only the
//! byte accounting. The Host/Disk split is itself deterministic because
//! `prepare_batch` runs single-threaded on the coordinator in batch order
//! under both executors, so the chunk-buffer state evolves identically.

use crate::cache::{FetchSource, LoadStats, ResidentCache};
use crate::graph::{Dataset, FeatureSource, HostTier};
use crate::obs::Phase;
use crate::partition::Partitioning;
use crate::span;
use crate::split::{SplitPlan, SplitSampler};
use crate::{DeviceId, Vid};

/// One (server → client) slice of the pre-forward exchange: rows the
/// client needs from the server's resident cache.
#[derive(Debug, Clone, Default)]
pub struct PeerFetch {
    /// Vertices to serve, in the client's deterministic request order.
    pub vids: Vec<Vid>,
    /// For each vid, the destination row in the client's `feats` buffer
    /// (positions are distinct: each hole is filled exactly once).
    pub dst_rows: Vec<u32>,
}

impl PeerFetch {
    pub fn len(&self) -> usize {
        self.vids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.vids.is_empty()
    }
}

/// Loading-stage output of the plan stage: the peer-exchange wiring plus
/// per-device Local/Peer/Host/Disk byte accounting.
#[derive(Debug, Clone, Default)]
pub struct LoadingPlan {
    /// `peer_fetch[server][client]` — rows `client` pulls from `server`'s
    /// resident cache. All-empty when no cache is installed.
    pub peer_fetch: Vec<Vec<PeerFetch>>,
    /// Per-device byte split of this batch's input rows.
    pub stats: Vec<LoadStats>,
}

impl LoadingPlan {
    fn empty(k: usize) -> Self {
        LoadingPlan {
            peer_fetch: (0..k).map(|_| vec![PeerFetch::default(); k]).collect(),
            stats: vec![LoadStats::default(); k],
        }
    }

    /// Whether any row travels through the pre-forward exchange phase.
    pub fn has_peer_traffic(&self) -> bool {
        self.peer_fetch.iter().flatten().any(|pf| !pf.is_empty())
    }
}

/// Everything the compute/exchange stages need for one mini-batch: the
/// cooperative [`SplitPlan`], each device's gathered input-feature rows
/// (ordered like `plan.input_frontier[d]`, which is also the order the
/// bottom layer's shuffle `send` indices refer to), and the loading plan.
/// Rows classified `Peer` are zero-filled holes in `feats` until the
/// executor's exchange phase materializes them.
#[derive(Debug, Clone)]
pub struct PreparedBatch {
    pub plan: SplitPlan,
    /// `feats[d]` — row-major `[input_frontier[d].len(), feat_dim]`.
    pub feats: Vec<Vec<f32>>,
    pub loading: LoadingPlan,
    /// Trainer-wide running batch index, used to label trace spans
    /// (`crate::obs`) — never consulted by the numerics.
    pub batch_idx: u64,
}

/// Run the plan stage for one mini-batch: sample + split cooperatively,
/// then gather every device's own input frontier, classifying each row
/// against the cache placement (if any).
///
/// `plan_seed` must already be the per-iteration derived seed; the same
/// seed always yields the same `PreparedBatch` regardless of which
/// executor later consumes it.
///
/// `stateless` selects [`SplitSampler::sample_stateless`] — per-vertex RNG
/// streams, so each vertex's sampled neighborhood is independent of the
/// batch it arrives in. The serving path requires this (DESIGN.md
/// §Serving: served logits must not depend on micro-batch grouping);
/// training keeps the cheaper per-device streams. Labels are never
/// consulted here — a `PreparedBatch` is label-free by construction, which
/// is what lets the serving path run on label-stripped datasets (pinned by
/// `serving_equivalence.rs`).
#[allow(clippy::too_many_arguments)]
pub(super) fn prepare_batch(
    sampler: &mut SplitSampler,
    ds: &Dataset,
    targets: &[Vid],
    fanouts: &[usize],
    part: &Partitioning,
    cache: Option<&ResidentCache>,
    plan_seed: u64,
    batch_idx: u64,
    stateless: bool,
) -> PreparedBatch {
    let plan = {
        let _s = span!(Phase::Sample, batch = batch_idx);
        if stateless {
            sampler.sample_stateless(&ds.graph, targets, fanouts, part, plan_seed)
        } else {
            sampler.sample(&ds.graph, targets, fanouts, part, plan_seed)
        }
    };
    let _load_span = span!(Phase::Load, batch = batch_idx);
    let k = plan.k;
    let dim = ds.features.dim();
    let row_bytes = ds.features.row_bytes();
    // Loading: each device gathers ONLY its own input frontier (the
    // paper's non-overlapping loads property).
    let mut feats: Vec<Vec<f32>> = Vec::with_capacity(k);
    let mut loading = LoadingPlan::empty(k);
    for d in 0..k {
        let frontier = &plan.input_frontier[d];
        let mut buf = vec![0f32; frontier.len() * dim];
        match cache {
            None => {
                for (row, &v) in frontier.iter().enumerate() {
                    match ds.features.fetch_row(v, &mut buf[row * dim..(row + 1) * dim]) {
                        HostTier::Ram => loading.stats[d].host_bytes += row_bytes,
                        HostTier::Disk => loading.stats[d].disk_bytes += row_bytes,
                    }
                }
            }
            Some(c) => {
                for (row, &v) in frontier.iter().enumerate() {
                    match c.fetch_source(v, d as DeviceId) {
                        FetchSource::Local => {
                            let src = c.resident_row(d as DeviceId, v).expect("Local row resident");
                            buf[row * dim..(row + 1) * dim].copy_from_slice(src);
                            loading.stats[d].local_bytes += row_bytes;
                        }
                        FetchSource::Peer(o) => {
                            let pf = &mut loading.peer_fetch[o as usize][d];
                            pf.vids.push(v);
                            pf.dst_rows.push(row as u32);
                            loading.stats[d].peer_bytes += row_bytes;
                        }
                        FetchSource::Host => {
                            match ds.features.fetch_row(v, &mut buf[row * dim..(row + 1) * dim])
                            {
                                HostTier::Ram => loading.stats[d].host_bytes += row_bytes,
                                HostTier::Disk => loading.stats[d].disk_bytes += row_bytes,
                            }
                        }
                    }
                }
            }
        }
        feats.push(buf);
    }
    PreparedBatch { plan, feats, loading, batch_idx }
}
