//! Plan stage of the split-parallel executor (DESIGN.md §Executor).
//!
//! Producing a mini-batch's [`SplitPlan`] (cooperative sampling + shuffle
//! index, the paper's S phase) and gathering each device's non-overlapping
//! input-feature rows (the L phase) depend only on the dataset, the
//! partitioning, and the iteration seed — **not** on the model parameters.
//! Packaging both as one [`PreparedBatch`] lets the serial executor consume
//! it inline and lets the pipelined executor prepare batch *t+1* while the
//! workers are still training batch *t* (the paper §6 inter-batch overlap).

use crate::graph::Dataset;
use crate::partition::Partitioning;
use crate::split::{SplitPlan, SplitSampler};
use crate::Vid;

/// Everything the compute/exchange stages need for one mini-batch: the
/// cooperative [`SplitPlan`] plus each device's gathered input-feature rows
/// (ordered like `plan.input_frontier[d]`, which is also the order the
/// bottom layer's shuffle `send` indices refer to).
#[derive(Debug, Clone)]
pub struct PreparedBatch {
    pub plan: SplitPlan,
    /// `feats[d]` — row-major `[input_frontier[d].len(), feat_dim]`.
    pub feats: Vec<Vec<f32>>,
}

/// Run the plan stage for one mini-batch: sample + split cooperatively,
/// then gather every device's own input frontier.
///
/// `plan_seed` must already be the per-iteration derived seed; the same
/// seed always yields the same `PreparedBatch` regardless of which
/// executor later consumes it.
pub(super) fn prepare_batch(
    sampler: &mut SplitSampler,
    ds: &Dataset,
    targets: &[Vid],
    fanouts: &[usize],
    part: &Partitioning,
    plan_seed: u64,
) -> PreparedBatch {
    let plan = sampler.sample(&ds.graph, targets, fanouts, part, plan_seed);
    // Loading: each device gathers ONLY its own input frontier (the
    // paper's non-overlapping loads property).
    let mut feats: Vec<Vec<f32>> = Vec::with_capacity(plan.k);
    for d in 0..plan.k {
        let mut buf = Vec::new();
        ds.features.gather(&plan.input_frontier[d], &mut buf);
        feats.push(buf);
    }
    PreparedBatch { plan, feats }
}
