//! End-to-end split-parallel training with **real compute**, composed
//! exactly as the paper's Algorithms 1 & 2 — per-layer all-to-all shuffles
//! of hidden features on the way up and of gradients (reverse shuffle,
//! same shuffle index) on the way down, followed by a gradient all-reduce
//! and an SGD step.
//!
//! The numeric kernels come from a [`Backend`]: the pure-Rust
//! [`NativeBackend`](crate::runtime::NativeBackend) by default, or the
//! PJRT runtime over AOT-compiled JAX/Pallas executables when the crate is
//! built with `--features pjrt`. The trainer itself is backend-agnostic —
//! it owns the sampling, the shuffles, the loss-head scaling, and the
//! optimizer step.
//!
//! The simulated devices execute serially in one process (timing comes
//! from the cost model; *numerics* come from here).

use anyhow::{ensure, Result};

use crate::graph::Dataset;
use crate::model::{ModelConfig, ParamStore};
use crate::partition::Partitioning;
use crate::rng::derive_seed;
use crate::runtime::Backend;
use crate::split::{SplitPlan, SplitSampler};
use crate::Vid;

/// Per-iteration training statistics.
#[derive(Debug, Clone, Copy)]
pub struct IterStats {
    pub loss: f32,
    pub correct: f32,
    pub examples: usize,
}

impl IterStats {
    pub fn accuracy(&self) -> f32 {
        if self.examples == 0 {
            0.0
        } else {
            self.correct / self.examples as f32
        }
    }
}

/// Split-parallel trainer over a fixed partitioning and a numeric backend.
pub struct Trainer<'a> {
    pub backend: &'a dyn Backend,
    pub params: ParamStore,
    part: Partitioning,
    sampler: SplitSampler,
    fanouts: Vec<usize>,
    lr: f32,
}

impl<'a> Trainer<'a> {
    /// Build a trainer: `fanout` is the per-layer neighbor fanout (uniform
    /// across layers, like the paper's sampling setup). With the PJRT
    /// backend this must equal the manifest's `kernel_fanout` and `cfg`
    /// must match the exported dims — the runtime rejects mismatches when
    /// it picks artifacts.
    pub fn new(
        backend: &'a dyn Backend,
        cfg: &ModelConfig,
        fanout: usize,
        part: Partitioning,
        lr: f32,
        seed: u64,
    ) -> Result<Self> {
        ensure!(cfg.num_layers > 0, "model needs at least one layer");
        ensure!(fanout > 0, "fanout must be positive");
        ensure!(part.k > 0, "partitioning needs at least one device");
        Ok(Trainer {
            backend,
            params: ParamStore::init(cfg, seed),
            sampler: SplitSampler::new(part.k),
            part,
            fanouts: vec![fanout; cfg.num_layers],
            lr,
        })
    }

    pub fn partitioning(&self) -> &Partitioning {
        &self.part
    }

    /// One cooperative split-parallel training iteration on `targets`.
    pub fn train_iteration(&mut self, ds: &Dataset, targets: &[Vid], seed: u64) -> Result<IterStats> {
        let plan = self.sampler.sample(
            &ds.graph,
            targets,
            &self.fanouts,
            &self.part,
            derive_seed(seed, &[0x17e2]),
        );
        let (stats, grads) = self.forward_backward(ds, &plan, true)?;
        self.params.sgd_step(&grads.expect("grads requested"), self.lr);
        Ok(stats)
    }

    /// Forward-only evaluation (accuracy / loss on given targets).
    pub fn evaluate(&mut self, ds: &Dataset, targets: &[Vid], seed: u64) -> Result<IterStats> {
        let plan = self.sampler.sample(
            &ds.graph,
            targets,
            &self.fanouts,
            &self.part,
            derive_seed(seed, &[0xE7A1]),
        );
        let (stats, _) = self.forward_backward(ds, &plan, false)?;
        Ok(stats)
    }

    /// The cooperative forward (+ optional backward) pass of Algorithms 1–2.
    #[allow(clippy::type_complexity)]
    fn forward_backward(
        &mut self,
        ds: &Dataset,
        plan: &SplitPlan,
        backward: bool,
    ) -> Result<(IterStats, Option<Vec<Vec<Vec<f32>>>>)> {
        let cfg = self.params.cfg.clone();
        let k = plan.k;
        let num_layers = plan.layers.len();
        let kernel_k = self.fanouts[0];

        // --- Loading: each device gathers ONLY its own input frontier ---
        let mut owned: Vec<Vec<f32>> = Vec::with_capacity(k);
        for d in 0..k {
            let mut buf = Vec::new();
            ds.features.gather(&plan.input_frontier[d], &mut buf);
            owned.push(buf);
        }

        // --- Forward, bottom-up; keep mixed inputs for the backward ---
        // mixed[i][d]: the materialized mixed-frontier rows of layer i.
        let mut mixed: Vec<Vec<Vec<f32>>> = vec![vec![Vec::new(); k]; num_layers];
        let mut hidden: Vec<Vec<f32>> = owned; // rows owned per dev at current boundary
        for i in (0..num_layers).rev() {
            let l = cfg.num_layers - 1 - i; // model layer (0 = bottom)
            let (din, dout) = (cfg.in_dim(l), cfg.out_dim(l));
            let relu = l + 1 < cfg.num_layers;
            let layer = &plan.layers[i];
            // Shuffle: materialize each device's mixed frontier from owned
            // rows of the boundary below (all-to-all of Algorithm 2 line 5).
            for d in 0..k {
                let dl = &layer.per_dev[d];
                let mut buf = vec![0f32; dl.mixed_src.len() * din];
                for from in 0..k {
                    let send = &layer.shuffle.send[from][d];
                    let recv = &layer.shuffle.recv[d][from];
                    for (&s_idx, &r_idx) in send.iter().zip(recv) {
                        let src = &hidden[from][s_idx as usize * din..(s_idx as usize + 1) * din];
                        buf[r_idx as usize * din..(r_idx as usize + 1) * din]
                            .copy_from_slice(src);
                    }
                }
                mixed[i][d] = buf;
            }
            // Compute this layer's owned hidden rows per device.
            let mut next_hidden: Vec<Vec<f32>> = Vec::with_capacity(k);
            for d in 0..k {
                let dl = &layer.per_dev[d];
                if dl.num_dst() == 0 {
                    next_hidden.push(Vec::new());
                    continue;
                }
                let h = self.backend.layer_fwd(
                    cfg.kind,
                    din,
                    dout,
                    relu,
                    &mixed[i][d],
                    dl.mixed_src.len(),
                    &dl.neigh,
                    dl.num_dst(),
                    kernel_k,
                    &self.params.layers[l],
                )?;
                next_hidden.push(h);
            }
            hidden = next_hidden;
        }

        // --- Loss head per device (top-layer dst are the targets) ---
        let c = cfg.num_classes;
        let total_examples: usize = plan.layers[0].per_dev.iter().map(|dl| dl.num_dst()).sum();
        let mut loss_sum = 0f32;
        let mut correct = 0f32;
        let mut g_out: Vec<Vec<f32>> = vec![Vec::new(); k];
        for d in 0..k {
            let dl = &plan.layers[0].per_dev[d];
            let b_d = dl.num_dst();
            if b_d == 0 {
                continue;
            }
            let labels: Vec<i32> =
                dl.dst.iter().map(|&v| ds.labels.labels[v as usize] as i32).collect();
            let (out, g_logits) = self.backend.loss(&hidden[d], &labels, b_d, c)?;
            loss_sum += out.loss * b_d as f32;
            correct += out.correct;
            if backward {
                // Rescale device-mean gradient to global-mean.
                let scale = 1.0 / total_examples as f32 * b_d as f32;
                g_out[d] = g_logits.iter().map(|g| g * scale).collect();
            }
        }
        let stats = IterStats {
            loss: loss_sum / total_examples.max(1) as f32,
            correct,
            examples: total_examples,
        };
        if !backward {
            return Ok((stats, None));
        }

        // --- Backward, top-down: per-layer VJP + reverse shuffle ---
        let mut g_params: Vec<Vec<Vec<f32>>> = self
            .params
            .layers
            .iter()
            .map(|lp| lp.tensors.iter().map(|t| vec![0f32; t.len()]).collect())
            .collect();
        for i in 0..num_layers {
            let l = cfg.num_layers - 1 - i;
            let (din, dout) = (cfg.in_dim(l), cfg.out_dim(l));
            let relu = l + 1 < cfg.num_layers;
            let layer = &plan.layers[i];
            // Gradient w.r.t. the owned rows of the boundary below.
            let mut g_owned: Vec<Vec<f32>> = (0..k)
                .map(|d| vec![0f32; plan.owned_rows(i, d).len() * din])
                .collect();
            for d in 0..k {
                let dl = &layer.per_dev[d];
                if dl.num_dst() == 0 || g_out[d].is_empty() {
                    continue;
                }
                let grads = self.backend.layer_bwd(
                    cfg.kind,
                    din,
                    dout,
                    relu,
                    &mixed[i][d],
                    dl.mixed_src.len(),
                    &dl.neigh,
                    dl.num_dst(),
                    kernel_k,
                    &g_out[d],
                    &self.params.layers[l],
                )?;
                for (acc, g) in g_params[l].iter_mut().zip(&grads.g_params) {
                    for (a, b) in acc.iter_mut().zip(g) {
                        *a += b;
                    }
                }
                // Reverse shuffle: scatter-add mixed-row gradients back to
                // the owners (gradients flow along the same shuffle index).
                for from in 0..k {
                    let send = &layer.shuffle.send[from][d];
                    let recv = &layer.shuffle.recv[d][from];
                    for (&s_idx, &r_idx) in send.iter().zip(recv) {
                        let src = &grads.g_x
                            [r_idx as usize * din..(r_idx as usize + 1) * din];
                        let dst = &mut g_owned[from]
                            [s_idx as usize * din..(s_idx as usize + 1) * din];
                        for (a, b) in dst.iter_mut().zip(src) {
                            *a += b;
                        }
                    }
                }
            }
            // The owned-row gradients become next layer's g_out (layer i+1
            // dst rows); at the bottom they are input-feature grads: dropped.
            g_out = g_owned;
        }
        Ok((stats, Some(g_params)))
    }
}

/// Convenience: one full training epoch; returns per-iteration stats.
pub fn train_epoch(
    trainer: &mut Trainer,
    ds: &Dataset,
    batch_size: usize,
    epoch_seed: u64,
) -> Result<Vec<IterStats>> {
    let targets = ds.epoch_targets(epoch_seed);
    let mut out = Vec::new();
    for (i, chunk) in targets.chunks(batch_size).enumerate() {
        out.push(trainer.train_iteration(ds, chunk, derive_seed(epoch_seed, &[i as u64]))?);
    }
    Ok(out)
}
