//! End-to-end split-parallel training with **real compute**, composed
//! exactly as the paper's Algorithms 1 & 2 — per-layer all-to-all shuffles
//! of hidden features on the way up and of gradients (reverse shuffle,
//! same shuffle index) on the way down, followed by a gradient all-reduce
//! and an SGD step.
//!
//! The numeric kernels come from a [`Backend`]: the pure-Rust
//! [`NativeBackend`](crate::runtime::NativeBackend) by default, or the
//! PJRT runtime over AOT-compiled JAX/Pallas executables when the crate is
//! built with `--features pjrt`. The trainer itself is backend-agnostic —
//! it owns the sampling, the shuffles, the loss-head scaling, and the
//! optimizer step.
//!
//! Execution is split into three stages (DESIGN.md §Executor):
//!
//! * **plan** ([`plan`] module) — cooperative sampling + input-feature
//!   gather, independent of the model parameters. With a
//!   [`ResidentCache`] installed ([`TrainConfig::cache`]), the gather is
//!   cache-aware: rows are classified Local / Peer / Host and peer rows
//!   travel through an extra pre-forward exchange phase (DESIGN.md
//!   §Loading) — numerics are identical at any policy or budget, only
//!   the Local/NVLink/PCIe byte split ([`Trainer::load_stats`]) changes;
//! * **compute** — per-device [`Backend`] layer calls;
//! * **exchange** — the per-layer all-to-alls and the gradient all-reduce.
//!
//! Two executors drive those stages, selected by [`ExecMode`]:
//! [`ExecMode::Serial`] runs every simulated device one after another on
//! the calling thread (the reference semantics; timing comes from the cost
//! model, *numerics* come from here), while [`ExecMode::Pipelined`] runs
//! one worker-thread pool over the devices and overlaps the next batch's
//! plan stage with the current batch's compute — **bit-identical** to the
//! serial executor for the same seed. Every entry point (train, evaluate,
//! infer — and serving, via [`Trainer::infer`]) picks its executor through
//! the single [`ExecMode::dispatch`] surface.
//!
//! A trainer is configured once through [`TrainConfig`] (executor, cache,
//! tracing) applied by [`Trainer::with_config`]; the per-field setters
//! accreted by earlier revisions remain as deprecated shims.

mod executor;
mod plan;
mod serial;

pub use executor::{ExecMode, PipelineConfig};
pub use plan::{LoadingPlan, PeerFetch, PreparedBatch};

use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::cache::{LoadStats, ResidentCache};
use crate::graph::Dataset;
use crate::model::{ModelConfig, ParamStore};
use crate::obs::Phase;
use crate::partition::Partitioning;
use crate::rng::derive_seed;
use crate::runtime::Backend;
use crate::span;
use crate::split::SplitSampler;
use crate::Vid;

use executor::BatchSpec;

/// Per-iteration training statistics.
#[derive(Debug, Clone, Copy)]
pub struct IterStats {
    pub loss: f32,
    pub correct: f32,
    pub examples: usize,
}

impl IterStats {
    pub fn accuracy(&self) -> f32 {
        if self.examples == 0 {
            0.0
        } else {
            self.correct / self.examples as f32
        }
    }
}

/// Unified trainer configuration: executor selection, the cache-aware
/// loading stage, and span tracing — everything that used to be scattered
/// over per-field setters — built with a chainable builder and applied by
/// [`Trainer::with_config`] (or [`Trainer::apply_config`] in place).
///
/// ```
/// use gsplit::train::{ExecMode, PipelineConfig, TrainConfig};
///
/// let cfg = TrainConfig::new()
///     .exec(ExecMode::Pipelined(PipelineConfig::with_workers(2)))
///     .trace(false);
/// assert_eq!(cfg.exec, ExecMode::Pipelined(PipelineConfig::with_workers(2)));
/// ```
#[derive(Clone, Default)]
pub struct TrainConfig {
    /// Executor selection ([`ExecMode::Serial`] by default).
    pub exec: ExecMode,
    /// Cache-aware loading stage (DESIGN.md §Loading). `None` gathers
    /// every input row from host memory. Numerics are unaffected at any
    /// policy or budget — only the Local/NVLink/PCIe byte split changes.
    pub cache: Option<Arc<ResidentCache>>,
    /// Span tracing: `Some(on)` sets the process-global tracer
    /// (`crate::obs`), `None` leaves it as-is (so `GSPLIT_TRACE`-enabled
    /// runs are not clobbered by a config that never mentioned tracing).
    pub trace: Option<bool>,
}

impl TrainConfig {
    /// An all-defaults configuration: serial executor, no cache, tracing
    /// untouched.
    pub fn new() -> Self {
        Self::default()
    }

    /// Select the executor.
    pub fn exec(mut self, mode: ExecMode) -> Self {
        self.exec = mode;
        self
    }

    /// Convenience: `workers == 0` selects [`ExecMode::Serial`], otherwise
    /// a pipelined executor with that many worker threads.
    pub fn parallel_workers(mut self, workers: usize) -> Self {
        self.exec = if workers == 0 {
            ExecMode::Serial
        } else {
            ExecMode::Pipelined(PipelineConfig::with_workers(workers))
        };
        self
    }

    /// Install (or, with `None`, remove) the cache-aware loading stage.
    pub fn cache(mut self, cache: Option<Arc<ResidentCache>>) -> Self {
        self.cache = cache;
        self
    }

    /// Enable or disable span tracing when the config is applied.
    pub fn trace(mut self, on: bool) -> Self {
        self.trace = Some(on);
        self
    }
}

/// Split-parallel trainer over a fixed partitioning and a numeric backend.
///
/// # Example
///
/// The serial and pipelined executors produce bit-identical results for
/// the same seed:
///
/// ```
/// use gsplit::graph::Dataset;
/// use gsplit::model::{GnnKind, ModelConfig};
/// use gsplit::partition::Partitioning;
/// use gsplit::runtime::NativeBackend;
/// use gsplit::train::{train_epoch, TrainConfig, Trainer};
///
/// let cfg = ModelConfig {
///     kind: GnnKind::GraphSage,
///     feat_dim: 8,
///     hidden: 8,
///     num_classes: 4,
///     num_layers: 2,
/// };
/// let ds = Dataset::sbm_learnable(512, cfg.num_classes, cfg.feat_dim, 0.6, 1);
/// let part = Partitioning { assignment: (0..512u32).map(|v| (v % 2) as u16).collect(), k: 2 };
/// let backend = NativeBackend::new();
///
/// let mut serial = Trainer::new(&backend, &cfg, 4, part.clone(), 0.1, 7).unwrap();
/// let mut pipelined = Trainer::new(&backend, &cfg, 4, part, 0.1, 7)
///     .unwrap()
///     .with_config(TrainConfig::new().parallel_workers(2))
///     .unwrap();
///
/// let a = train_epoch(&mut serial, &ds, 128, 0).unwrap();
/// let b = train_epoch(&mut pipelined, &ds, 128, 0).unwrap();
/// assert_eq!(a.len(), b.len());
/// for (x, y) in a.iter().zip(&b) {
///     assert_eq!(x.loss.to_bits(), y.loss.to_bits());
/// }
/// ```
pub struct Trainer<'a> {
    pub backend: &'a dyn Backend,
    pub params: ParamStore,
    part: Partitioning,
    sampler: SplitSampler,
    fanouts: Vec<usize>,
    lr: f32,
    mode: ExecMode,
    /// Cache-aware loading stage (DESIGN.md §Loading). `None` gathers
    /// every input row from host memory.
    cache: Option<Arc<ResidentCache>>,
    /// Per-device Local/NVLink/PCIe byte accounting, accumulated across
    /// every plan stage this trainer ran.
    load_stats: Vec<LoadStats>,
    /// Running count of plan stages, used to label trace spans with a
    /// batch index (`crate::obs`).
    batches_prepared: u64,
}

impl<'a> Trainer<'a> {
    /// Build a trainer: `fanout` is the per-layer neighbor fanout (uniform
    /// across layers, like the paper's sampling setup). With the PJRT
    /// backend this must equal the manifest's `kernel_fanout` and `cfg`
    /// must match the exported dims — the runtime rejects mismatches when
    /// it picks artifacts. Starts with a default [`TrainConfig`] (serial
    /// executor, no cache); see [`Trainer::with_config`].
    pub fn new(
        backend: &'a dyn Backend,
        cfg: &ModelConfig,
        fanout: usize,
        part: Partitioning,
        lr: f32,
        seed: u64,
    ) -> Result<Self> {
        ensure!(cfg.num_layers > 0, "model needs at least one layer");
        ensure!(fanout > 0, "fanout must be positive");
        ensure!(part.k > 0, "partitioning needs at least one device");
        let load_stats = vec![LoadStats::default(); part.k];
        Ok(Trainer {
            backend,
            params: ParamStore::init(cfg, seed),
            sampler: SplitSampler::new(part.k),
            part,
            fanouts: vec![fanout; cfg.num_layers],
            lr,
            mode: ExecMode::Serial,
            cache: None,
            load_stats,
            batches_prepared: 0,
        })
    }

    pub fn partitioning(&self) -> &Partitioning {
        &self.part
    }

    /// Apply a [`TrainConfig`], builder-style — the single configuration
    /// surface. Validates the cache (it must be built for this trainer's
    /// device count) and, when the config says so, toggles the
    /// process-global tracer.
    pub fn with_config(mut self, cfg: TrainConfig) -> Result<Self> {
        self.apply_config(cfg)?;
        Ok(self)
    }

    /// In-place [`Trainer::with_config`], for re-configuring an existing
    /// trainer between runs.
    pub fn apply_config(&mut self, cfg: TrainConfig) -> Result<()> {
        self.install_cache(cfg.cache)?;
        self.mode = cfg.exec;
        if let Some(on) = cfg.trace {
            crate::obs::set_enabled(on);
        }
        Ok(())
    }

    /// Install (or remove) the cache-aware loading stage. Both executors
    /// honour it; numerics are unaffected at any policy or budget because
    /// cached rows are bit-exact copies of the host rows (DESIGN.md
    /// §Loading) — only the Local/NVLink/PCIe byte split changes.
    fn install_cache(&mut self, cache: Option<Arc<ResidentCache>>) -> Result<()> {
        if let Some(c) = &cache {
            ensure!(
                c.k() == self.part.k,
                "cache built for {} devices, trainer has {}",
                c.k(),
                self.part.k
            );
        }
        self.cache = cache;
        Ok(())
    }

    /// Deprecated shim over [`TrainConfig::cache`] + [`Trainer::apply_config`].
    #[deprecated(note = "use TrainConfig::cache with Trainer::with_config/apply_config")]
    pub fn set_cache(&mut self, cache: Option<Arc<ResidentCache>>) -> Result<()> {
        self.install_cache(cache)
    }

    /// Deprecated shim over [`TrainConfig::cache`] + [`Trainer::with_config`].
    #[deprecated(note = "use TrainConfig::cache with Trainer::with_config")]
    pub fn with_cache(mut self, cache: Arc<ResidentCache>) -> Result<Self> {
        self.install_cache(Some(cache))?;
        Ok(self)
    }

    /// The installed cache, if any.
    pub fn cache(&self) -> Option<&ResidentCache> {
        self.cache.as_deref()
    }

    /// Per-device Local/NVLink/PCIe loading byte split, accumulated over
    /// every iteration (training and evaluation) this trainer executed.
    pub fn load_stats(&self) -> &[LoadStats] {
        &self.load_stats
    }

    pub fn reset_load_stats(&mut self) {
        self.load_stats = vec![LoadStats::default(); self.part.k];
    }

    /// Run the plan stage (sampling + cache-classified feature gather) and
    /// accumulate its byte accounting — the single entry point both
    /// executors share.
    fn prepare(&mut self, ds: &Dataset, targets: &[Vid], plan_seed: u64) -> PreparedBatch {
        self.prepare_impl(ds, targets, plan_seed, false, "train")
    }

    /// Plan stage for the serving path: per-vertex stateless sampling
    /// (micro-batch-composition-independent neighborhoods, DESIGN.md
    /// §Serving), byte accounting recorded under the `serve` metrics
    /// scope. Same loading classification and cache paths as training.
    fn prepare_infer(&mut self, ds: &Dataset, targets: &[Vid], plan_seed: u64) -> PreparedBatch {
        self.prepare_impl(ds, targets, plan_seed, true, "serve")
    }

    fn prepare_impl(
        &mut self,
        ds: &Dataset,
        targets: &[Vid],
        plan_seed: u64,
        stateless: bool,
        scope: &str,
    ) -> PreparedBatch {
        let batch_idx = self.batches_prepared;
        self.batches_prepared += 1;
        let prep = plan::prepare_batch(
            &mut self.sampler,
            ds,
            targets,
            &self.fanouts,
            &self.part,
            self.cache.as_deref(),
            plan_seed,
            batch_idx,
            stateless,
        );
        for (acc, s) in self.load_stats.iter_mut().zip(&prep.loading.stats) {
            acc.merge(s);
        }
        LoadStats::sum(prep.loading.stats.iter()).record_metrics(scope);
        prep
    }

    /// Deprecated shim over [`TrainConfig::trace`]. Tracing never affects
    /// numerics: traced and untraced runs are bit-identical (see
    /// `executor_equivalence.rs`).
    #[deprecated(note = "use TrainConfig::trace with Trainer::with_config/apply_config")]
    pub fn set_trace(&mut self, enabled: bool) {
        crate::obs::set_enabled(enabled);
    }

    /// Deprecated shim over [`TrainConfig::exec`]. [`ExecMode::Pipelined`]
    /// spawns its worker threads per call ([`train_epoch`] pipelines a
    /// whole epoch through one pool; a single
    /// [`Trainer::train_iteration`] pays one spawn).
    #[deprecated(note = "use TrainConfig::exec with Trainer::with_config/apply_config")]
    pub fn set_exec_mode(&mut self, mode: ExecMode) {
        self.mode = mode;
    }

    /// The currently selected executor.
    pub fn exec_mode(&self) -> ExecMode {
        self.mode
    }

    /// Deprecated shim over [`TrainConfig::parallel_workers`].
    #[deprecated(note = "use TrainConfig::parallel_workers with Trainer::with_config")]
    pub fn with_parallel_workers(mut self, workers: usize) -> Self {
        self.mode = if workers == 0 {
            ExecMode::Serial
        } else {
            ExecMode::Pipelined(PipelineConfig::with_workers(workers))
        };
        self
    }

    /// One cooperative split-parallel training iteration on `targets`.
    pub fn train_iteration(&mut self, ds: &Dataset, targets: &[Vid], seed: u64) -> Result<IterStats> {
        let plan_seed = derive_seed(seed, &[0x17e2]);
        let mode = self.mode;
        mode.dispatch(
            &mut *self,
            |t| {
                let prep = t.prepare(ds, targets, plan_seed);
                let batch_idx = prep.batch_idx;
                let (stats, grads) = t.forward_backward(ds, prep, true)?;
                {
                    let _s = span!(Phase::GradReduce, batch = batch_idx);
                    t.params.sgd_step(&grads.expect("grads requested"), t.lr);
                }
                Ok(stats)
            },
            |t, cfg| {
                let specs = [BatchSpec { targets: targets.to_vec(), plan_seed }];
                let mut out = executor::run_batches(t, ds, &specs, true, cfg)?;
                Ok(out.pop().expect("one batch"))
            },
        )
    }

    /// Forward-only evaluation (accuracy / loss on given targets).
    pub fn evaluate(&mut self, ds: &Dataset, targets: &[Vid], seed: u64) -> Result<IterStats> {
        let plan_seed = derive_seed(seed, &[0xE7A1]);
        let mode = self.mode;
        mode.dispatch(
            &mut *self,
            |t| {
                let prep = t.prepare(ds, targets, plan_seed);
                let (stats, _) = t.forward_backward(ds, prep, false)?;
                Ok(stats)
            },
            |t, cfg| {
                let specs = [BatchSpec { targets: targets.to_vec(), plan_seed }];
                let mut out = executor::run_batches(t, ds, &specs, false, cfg)?;
                Ok(out.pop().expect("one batch"))
            },
        )
    }

    /// Forward-only inference on `targets`: returns the top-layer logits
    /// as a flat row-major `[targets.len(), num_classes]` buffer, rows in
    /// `targets` order. Never touches `ds.labels` (serves label-stripped
    /// datasets) and never updates parameters.
    ///
    /// Sampling uses per-vertex stateless RNG streams keyed on `seed`
    /// ([`SplitSampler::sample_stateless`]), so for a fixed seed each
    /// vertex's logits are a pure function of the trained parameters —
    /// independent of which other vertices share its micro-batch and of
    /// the executor ([`ExecMode`]); this is the bit-identity contract the
    /// serving layer (`crate::serving`) is built on (DESIGN.md §Serving,
    /// pinned by `serving_equivalence.rs`).
    ///
    /// `targets` must be unique and in-range — the cooperative sampler's
    /// split invariants assume distinct top-layer destinations.
    pub fn infer(&mut self, ds: &Dataset, targets: &[Vid], seed: u64) -> Result<Vec<f32>> {
        if targets.is_empty() {
            return Ok(Vec::new());
        }
        let n = ds.graph.num_vertices() as Vid;
        let mut seen = std::collections::HashSet::with_capacity(targets.len());
        for &v in targets {
            ensure!(v < n, "inference target {v} out of range (graph has {n} vertices)");
            ensure!(seen.insert(v), "duplicate inference target {v}");
        }
        let prep = self.prepare_infer(ds, targets, seed);
        let batch_idx = prep.batch_idx;
        let _s = span!(Phase::ServeInfer, batch = batch_idx);
        // Top-layer dst lists: where each target's logits row lands.
        let top_dst: Vec<Vec<Vid>> =
            prep.plan.layers[0].per_dev.iter().map(|dl| dl.dst.clone()).collect();
        let mode = self.mode;
        let per_dev: Vec<Vec<f32>> = mode.dispatch(
            (&mut *self, prep),
            |(t, prep)| t.infer_serial(ds, prep),
            |(t, prep), cfg| executor::run_infer(t, ds, prep, cfg),
        )?;
        // Reassemble into `targets` order.
        let c = self.params.cfg.num_classes;
        let mut row_of = std::collections::HashMap::with_capacity(targets.len());
        for (d, dst) in top_dst.iter().enumerate() {
            for (row, &v) in dst.iter().enumerate() {
                row_of.insert(v, (d, row));
            }
        }
        let mut out = vec![0f32; targets.len() * c];
        for (i, v) in targets.iter().enumerate() {
            let &(d, row) = row_of.get(v).expect("target present in top-layer dst");
            out[i * c..(i + 1) * c].copy_from_slice(&per_dev[d][row * c..(row + 1) * c]);
        }
        Ok(out)
    }
}

/// Convenience: one full training epoch; returns per-iteration stats.
///
/// With [`ExecMode::Pipelined`] the whole epoch runs through one worker
/// pool and the plan stage of batch *t+1* overlaps the compute of batch
/// *t*; the per-batch seeds (and therefore all results) are identical to
/// the serial path.
pub fn train_epoch(
    trainer: &mut Trainer,
    ds: &Dataset,
    batch_size: usize,
    epoch_seed: u64,
) -> Result<Vec<IterStats>> {
    let targets = ds.epoch_targets(epoch_seed);
    let mode = trainer.mode;
    mode.dispatch(
        trainer,
        |t| {
            let mut out = Vec::new();
            for (i, chunk) in targets.chunks(batch_size).enumerate() {
                out.push(t.train_iteration(ds, chunk, derive_seed(epoch_seed, &[i as u64]))?);
            }
            Ok(out)
        },
        |t, cfg| {
            let specs: Vec<BatchSpec> = targets
                .chunks(batch_size)
                .enumerate()
                .map(|(i, chunk)| BatchSpec {
                    targets: chunk.to_vec(),
                    plan_seed: derive_seed(derive_seed(epoch_seed, &[i as u64]), &[0x17e2]),
                })
                .collect();
            executor::run_batches(t, ds, &specs, true, cfg)
        },
    )
}
