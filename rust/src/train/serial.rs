//! Serial reference executor: the cooperative forward (+ optional
//! backward) pass of Algorithms 1–2, with every simulated device executed
//! one after another on the calling thread.
//!
//! This is the semantic oracle for the threaded executor in
//! [`executor`](super::executor): the pipelined path must reproduce these
//! numerics **bit for bit** (DESIGN.md §Executor), so keep this code
//! boring and keep every floating-point reduction in explicit, fixed
//! device order.

use anyhow::Result;

use crate::graph::{Dataset, FeatureSource};
use crate::obs::Phase;
use crate::span;
use crate::split::SplitPlan;
use crate::train::plan::{LoadingPlan, PreparedBatch};
use crate::train::{IterStats, Trainer};

impl<'a> Trainer<'a> {
    /// Loading exchange + the bottom-up cooperative forward of Algorithm 2,
    /// executed serially over all devices — the single operation sequence
    /// shared by training ([`Trainer::forward_backward`]) and label-free
    /// inference ([`Trainer::infer_serial`]). Returns the per-layer mixed
    /// frontier inputs (kept for the backward pass) and the top-layer
    /// hidden rows per device (`hidden[d]` rows align with
    /// `plan.layers[0].per_dev[d].dst`, width = `num_classes`).
    #[allow(clippy::type_complexity)]
    fn forward_pass(
        &self,
        ds: &Dataset,
        plan: &SplitPlan,
        mut feats: Vec<Vec<f32>>,
        loading: &LoadingPlan,
        batch_idx: u64,
    ) -> Result<(Vec<Vec<Vec<f32>>>, Vec<Vec<f32>>)> {
        let cfg = &self.params.cfg;
        let k = plan.k;
        let num_layers = plan.layers.len();
        let kernel_k = self.fanouts[0];

        // --- Loading exchange: materialize Peer-classified rows from the
        // owning devices' resident caches, in fixed (server, client) order
        // — the reference ordering the pipelined executor's pre-forward
        // exchange phase must reproduce (DESIGN.md §Loading). Destination
        // rows are distinct, so this is a pure scatter of bit-exact host
        // copies; order only matters for auditability.
        if let Some(cache) = &self.cache {
            let _s = span!(Phase::LoadExchange, batch = batch_idx);
            let dim = ds.features.dim();
            for server in 0..k {
                for client in 0..k {
                    let pf = &loading.peer_fetch[server][client];
                    for (&v, &row) in pf.vids.iter().zip(&pf.dst_rows) {
                        let src = cache
                            .resident_row(server as crate::DeviceId, v)
                            .expect("peer-served row resident on server");
                        feats[client][row as usize * dim..(row as usize + 1) * dim]
                            .copy_from_slice(src);
                    }
                }
            }
        } else {
            debug_assert!(!loading.has_peer_traffic(), "peer fetches require a cache");
        }

        // --- Forward, bottom-up; keep mixed inputs for the backward ---
        // mixed[i][d]: the materialized mixed-frontier rows of layer i.
        let mut mixed: Vec<Vec<Vec<f32>>> = vec![vec![Vec::new(); k]; num_layers];
        // Rows owned per device at the current boundary, starting from the
        // input features the plan stage gathered.
        let mut hidden: Vec<Vec<f32>> = feats;
        for i in (0..num_layers).rev() {
            let l = cfg.num_layers - 1 - i; // model layer (0 = bottom)
            let (din, dout) = (cfg.in_dim(l), cfg.out_dim(l));
            let relu = l + 1 < cfg.num_layers;
            let layer = &plan.layers[i];
            // Shuffle: materialize each device's mixed frontier from owned
            // rows of the boundary below (all-to-all of Algorithm 2 line 5).
            {
                let _s = span!(Phase::ShuffleFwd, batch = batch_idx, layer = i);
                for d in 0..k {
                    let dl = &layer.per_dev[d];
                    let mut buf = vec![0f32; dl.mixed_src.len() * din];
                    for from in 0..k {
                        let send = &layer.shuffle.send[from][d];
                        let recv = &layer.shuffle.recv[d][from];
                        for (&s_idx, &r_idx) in send.iter().zip(recv) {
                            let src =
                                &hidden[from][s_idx as usize * din..(s_idx as usize + 1) * din];
                            buf[r_idx as usize * din..(r_idx as usize + 1) * din]
                                .copy_from_slice(src);
                        }
                    }
                    mixed[i][d] = buf;
                }
            }
            // Compute this layer's owned hidden rows per device.
            let mut next_hidden: Vec<Vec<f32>> = Vec::with_capacity(k);
            for d in 0..k {
                let dl = &layer.per_dev[d];
                if dl.num_dst() == 0 {
                    next_hidden.push(Vec::new());
                    continue;
                }
                let _s = span!(Phase::ComputeFwd, device = d, batch = batch_idx, layer = i);
                let h = self.backend.layer_fwd(
                    cfg.kind,
                    din,
                    dout,
                    relu,
                    &mixed[i][d],
                    dl.mixed_src.len(),
                    &dl.neigh,
                    dl.num_dst(),
                    kernel_k,
                    &self.params.layers[l],
                )?;
                next_hidden.push(h);
            }
            hidden = next_hidden;
        }
        Ok((mixed, hidden))
    }

    /// Forward-only serial inference: top-layer logits per device, **never
    /// touching labels** — a [`PreparedBatch`] is label-free by
    /// construction and the loss head is the only consumer of
    /// `ds.labels`, so a label-stripped dataset serves fine here (pinned
    /// by `serving_equivalence.rs`).
    pub(super) fn infer_serial(&self, ds: &Dataset, prep: PreparedBatch) -> Result<Vec<Vec<f32>>> {
        let PreparedBatch { plan, feats, loading, batch_idx } = prep;
        let (_mixed, hidden) = self.forward_pass(ds, &plan, feats, &loading, batch_idx)?;
        Ok(hidden)
    }

    /// The cooperative forward (+ optional backward) pass of Algorithms
    /// 1–2, executed serially over all devices.
    #[allow(clippy::type_complexity)]
    pub(super) fn forward_backward(
        &mut self,
        ds: &Dataset,
        prep: PreparedBatch,
        backward: bool,
    ) -> Result<(IterStats, Option<Vec<Vec<Vec<f32>>>>)> {
        let cfg = self.params.cfg.clone();
        let PreparedBatch { plan, feats, loading, batch_idx } = prep;
        let k = plan.k;
        let num_layers = plan.layers.len();
        let kernel_k = self.fanouts[0];
        let (mixed, hidden) = self.forward_pass(ds, &plan, feats, &loading, batch_idx)?;

        // --- Loss head per device (top-layer dst are the targets) ---
        let c = cfg.num_classes;
        let total_examples: usize = plan.layers[0].per_dev.iter().map(|dl| dl.num_dst()).sum();
        let mut loss_sum = 0f32;
        let mut correct = 0f32;
        let mut g_out: Vec<Vec<f32>> = vec![Vec::new(); k];
        for d in 0..k {
            let dl = &plan.layers[0].per_dev[d];
            let b_d = dl.num_dst();
            if b_d == 0 {
                continue;
            }
            let _s = span!(Phase::Loss, device = d, batch = batch_idx);
            let labels: Vec<i32> =
                dl.dst.iter().map(|&v| ds.labels.labels[v as usize] as i32).collect();
            let (out, g_logits) = self.backend.loss(&hidden[d], &labels, b_d, c)?;
            loss_sum += out.loss * b_d as f32;
            correct += out.correct;
            if backward {
                // Rescale device-mean gradient to global-mean.
                let scale = 1.0 / total_examples as f32 * b_d as f32;
                g_out[d] = g_logits.iter().map(|g| g * scale).collect();
            }
        }
        let stats = IterStats {
            loss: loss_sum / total_examples.max(1) as f32,
            correct,
            examples: total_examples,
        };
        if !backward {
            return Ok((stats, None));
        }

        // --- Backward, top-down: per-layer VJP + reverse shuffle ---
        let mut g_params: Vec<Vec<Vec<f32>>> = self
            .params
            .layers
            .iter()
            .map(|lp| lp.tensors.iter().map(|t| vec![0f32; t.len()]).collect())
            .collect();
        for i in 0..num_layers {
            let l = cfg.num_layers - 1 - i;
            let (din, dout) = (cfg.in_dim(l), cfg.out_dim(l));
            let relu = l + 1 < cfg.num_layers;
            let layer = &plan.layers[i];
            // Gradient w.r.t. the owned rows of the boundary below.
            let mut g_owned: Vec<Vec<f32>> = (0..k)
                .map(|d| vec![0f32; plan.owned_rows(i, d).len() * din])
                .collect();
            for d in 0..k {
                let dl = &layer.per_dev[d];
                if dl.num_dst() == 0 || g_out[d].is_empty() {
                    debug_assert!(!plan.bwd_active(i, d));
                    continue;
                }
                debug_assert!(plan.bwd_active(i, d));
                let grads = {
                    let _s = span!(Phase::ComputeBwd, device = d, batch = batch_idx, layer = i);
                    self.backend.layer_bwd(
                        cfg.kind,
                        din,
                        dout,
                        relu,
                        &mixed[i][d],
                        dl.mixed_src.len(),
                        &dl.neigh,
                        dl.num_dst(),
                        kernel_k,
                        &g_out[d],
                        &self.params.layers[l],
                    )?
                };
                for (acc, g) in g_params[l].iter_mut().zip(&grads.g_params) {
                    for (a, b) in acc.iter_mut().zip(g) {
                        *a += b;
                    }
                }
                // Reverse shuffle: scatter-add mixed-row gradients back to
                // the owners (gradients flow along the same shuffle index).
                let _s = span!(Phase::ShuffleBwd, device = d, batch = batch_idx, layer = i);
                for from in 0..k {
                    let send = &layer.shuffle.send[from][d];
                    let recv = &layer.shuffle.recv[d][from];
                    for (&s_idx, &r_idx) in send.iter().zip(recv) {
                        let src = &grads.g_x
                            [r_idx as usize * din..(r_idx as usize + 1) * din];
                        let dst = &mut g_owned[from]
                            [s_idx as usize * din..(s_idx as usize + 1) * din];
                        for (a, b) in dst.iter_mut().zip(src) {
                            *a += b;
                        }
                    }
                }
            }
            // The owned-row gradients become next layer's g_out (layer i+1
            // dst rows); at the bottom they are input-feature grads: dropped.
            g_out = g_owned;
        }
        Ok((stats, Some(g_params)))
    }
}
