//! Hand-rolled command-line parsing (the offline registry has no `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.
//! Each binary declares the options it understands; unknown options are an
//! error (typos must not silently fall back to defaults).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed arguments: options plus positionals, with typed accessors.
#[derive(Debug, Default, Clone)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

/// Declarative option spec used for validation and `--help` output.
pub struct OptSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub help: &'static str,
}

impl Args {
    /// Parse `argv[1..]` against a spec. `--help` prints usage and exits.
    pub fn parse(argv: impl Iterator<Item = String>, spec: &[OptSpec], about: &str) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.peekable();
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                print_help(spec, about);
                std::process::exit(0);
            }
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let s = spec
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| anyhow!("unknown option --{name} (see --help)"))?;
                if s.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| anyhow!("option --{name} expects a value"))?,
                    };
                    args.opts.insert(name, val);
                } else {
                    if inline_val.is_some() {
                        bail!("flag --{name} does not take a value");
                    }
                    args.flags.push(name);
                }
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    /// Parse the process arguments (skipping argv[0] and, for `cargo bench`
    /// invocations, the `--bench` flag cargo appends).
    pub fn from_env(spec: &[OptSpec], about: &str) -> Result<Args> {
        let argv = std::env::args().skip(1).filter(|a| a != "--bench");
        Self::parse(argv, spec, about)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(String::as_str)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.opts.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<usize>()
                .map_err(|_| anyhow!("--{name} expects an integer, got `{v}`")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.opts.get(name) {
            None => Ok(default),
            Some(v) => {
                v.parse::<u64>().map_err(|_| anyhow!("--{name} expects an integer, got `{v}`"))
            }
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.opts.get(name) {
            None => Ok(default),
            Some(v) => {
                v.parse::<f64>().map_err(|_| anyhow!("--{name} expects a number, got `{v}`"))
            }
        }
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.opts.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

fn print_help(spec: &[OptSpec], about: &str) {
    println!("{about}\n\nOptions:");
    for s in spec {
        let arg = if s.takes_value { format!("--{} <v>", s.name) } else { format!("--{}", s.name) };
        println!("  {arg:24} {}", s.help);
    }
    println!("  {:24} print this help", "--help");
}

/// Shorthand for building specs.
#[macro_export]
macro_rules! opts {
    ($(($name:literal, $takes:expr, $help:literal)),* $(,)?) => {
        &[$($crate::cli::OptSpec { name: $name, takes_value: $takes, help: $help }),*]
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "gpus", takes_value: true, help: "" },
            OptSpec { name: "verbose", takes_value: false, help: "" },
            OptSpec { name: "lr", takes_value: true, help: "" },
        ]
    }

    fn parse(args: &[&str]) -> Result<Args> {
        Args::parse(args.iter().map(|s| s.to_string()), &spec(), "t")
    }

    #[test]
    fn parses_values_and_flags() {
        let a = parse(&["--gpus", "8", "--verbose", "pos1", "--lr=0.01"]).unwrap();
        assert_eq!(a.get_usize("gpus", 4).unwrap(), 8);
        assert!(a.flag("verbose"));
        assert_eq!(a.get_f64("lr", 0.0).unwrap(), 0.01);
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]).unwrap();
        assert_eq!(a.get_usize("gpus", 4).unwrap(), 4);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn rejects_unknown_and_malformed() {
        assert!(parse(&["--nope"]).is_err());
        assert!(parse(&["--gpus"]).is_err());
        assert!(parse(&["--verbose=1"]).is_err());
        assert!(parse(&["--gpus", "abc"]).unwrap().get_usize("gpus", 1).is_err());
    }
}
