//! `artifacts/manifest.json` parsing — the compile-time contract between
//! `python/compile/aot.py` and the Rust runtime.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::model::GnnKind;
use crate::util::JsonValue;

/// One artifact entry.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    /// "layer_fwd" | "layer_bwd" | "loss".
    pub kind: String,
    pub model: Option<GnnKind>,
    pub din: usize,
    pub dout: usize,
    pub relu: bool,
    /// Destination-row bucket (or batch bucket for loss).
    pub m: usize,
    /// Mixed-frontier capacity (layer artifacts).
    pub n: usize,
    /// Neighbor fanout (layer artifacts).
    pub k: usize,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub kernel_fanout: usize,
    pub m_buckets: Vec<usize>,
    pub feat_dim: usize,
    pub hidden: usize,
    pub num_classes: usize,
    /// (din, dout, relu) bottom→top of the default exported model.
    pub layer_dims: Vec<(usize, usize, bool)>,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let v = JsonValue::parse(text)?;
        let version = v.get("version")?.as_u64().unwrap_or(0);
        if version != 1 {
            anyhow::bail!("unsupported manifest version {version}");
        }
        let layer_dims = v
            .get("layer_dims")?
            .as_arr()
            .ok_or_else(|| anyhow!("layer_dims not an array"))?
            .iter()
            .map(|e| {
                let a = e.as_arr().ok_or_else(|| anyhow!("layer_dims entry"))?;
                Ok((
                    a[0].as_usize().unwrap(),
                    a[1].as_usize().unwrap(),
                    a[2].as_bool().or(a[2].as_u64().map(|x| x != 0)).unwrap_or(false),
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        let mut artifacts = Vec::new();
        for e in v.get("artifacts")?.as_arr().ok_or_else(|| anyhow!("artifacts"))? {
            let get_usize = |k: &str| e.get(k).ok().and_then(|x| x.as_usize()).unwrap_or(0);
            let model = match e.get("model").ok().and_then(|m| m.as_str()) {
                Some("sage") => Some(GnnKind::GraphSage),
                Some("gat") => Some(GnnKind::Gat),
                _ => None,
            };
            artifacts.push(ArtifactMeta {
                name: e.get("name")?.as_str().ok_or_else(|| anyhow!("name"))?.to_string(),
                file: e.get("file")?.as_str().ok_or_else(|| anyhow!("file"))?.to_string(),
                kind: e.get("kind")?.as_str().ok_or_else(|| anyhow!("kind"))?.to_string(),
                model,
                din: get_usize("din"),
                dout: get_usize("dout"),
                relu: e.get("relu").ok().and_then(|x| x.as_bool()).unwrap_or(false),
                m: get_usize("m").max(get_usize("b")),
                n: get_usize("n"),
                k: get_usize("k"),
            });
        }
        Ok(Manifest {
            kernel_fanout: v.get("kernel_fanout")?.as_usize().unwrap_or(0),
            m_buckets: v
                .get("m_buckets")?
                .as_arr()
                .ok_or_else(|| anyhow!("m_buckets"))?
                .iter()
                .filter_map(|x| x.as_usize())
                .collect(),
            feat_dim: v.get("feat_dim")?.as_usize().unwrap_or(0),
            hidden: v.get("hidden")?.as_usize().unwrap_or(0),
            num_classes: v.get("num_classes")?.as_usize().unwrap_or(0),
            layer_dims,
            artifacts,
        })
    }

    pub fn by_name(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Smallest layer bucket with `m ≥ m_need` for the signature.
    pub fn pick_layer(
        &self,
        kind: &str,
        model: GnnKind,
        din: usize,
        dout: usize,
        relu: bool,
        m_need: usize,
    ) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|a| {
                a.kind == kind
                    && a.model == Some(model)
                    && a.din == din
                    && a.dout == dout
                    && a.relu == relu
                    && a.m >= m_need
            })
            .min_by_key(|a| a.m)
    }

    /// Smallest loss bucket with `b ≥ b_need` and matching class count.
    pub fn pick_loss(&self, b_need: usize, c: usize) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == "loss" && a.dout == 0 && a.m >= b_need && self.num_classes == c)
            .min_by_key(|a| a.m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "kernel_fanout": 5, "m_buckets": [256, 1024],
      "loss_buckets": [256], "feat_dim": 32, "hidden": 64, "num_classes": 8,
      "layer_dims": [[32, 64, true], [64, 8, false]],
      "artifacts": [
        {"name": "sage_32x64_r1_m256_fwd", "file": "a.hlo.txt", "kind": "layer_fwd",
         "model": "sage", "din": 32, "dout": 64, "relu": true, "m": 256, "n": 1536, "k": 5},
        {"name": "sage_32x64_r1_m1024_fwd", "file": "b.hlo.txt", "kind": "layer_fwd",
         "model": "sage", "din": 32, "dout": 64, "relu": true, "m": 1024, "n": 6144, "k": 5},
        {"name": "loss_b256_c8", "file": "l.hlo.txt", "kind": "loss", "b": 256, "c": 8}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.kernel_fanout, 5);
        assert_eq!(m.layer_dims, vec![(32, 64, true), (64, 8, false)]);
        assert_eq!(m.artifacts.len(), 3);
        assert_eq!(m.by_name("loss_b256_c8").unwrap().m, 256);
    }

    #[test]
    fn picks_smallest_fitting_bucket() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = m.pick_layer("layer_fwd", GnnKind::GraphSage, 32, 64, true, 100).unwrap();
        assert_eq!(a.m, 256);
        let a = m.pick_layer("layer_fwd", GnnKind::GraphSage, 32, 64, true, 257).unwrap();
        assert_eq!(a.m, 1024);
        assert!(m.pick_layer("layer_fwd", GnnKind::GraphSage, 32, 64, true, 5000).is_none());
        assert!(m.pick_layer("layer_fwd", GnnKind::Gat, 32, 64, true, 10).is_none());
    }

    #[test]
    fn picks_loss() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.pick_loss(100, 8).is_some());
        assert!(m.pick_loss(300, 8).is_none());
        assert!(m.pick_loss(100, 4).is_none());
    }

    #[test]
    fn rejects_bad_version() {
        assert!(Manifest::parse(r#"{"version": 9}"#).is_err());
    }
}
