//! [`NativeBackend`] — the pure-Rust reference implementation of the
//! [`Backend`] trait.
//!
//! Implements GraphSage mean-aggregation and single-head GAT attention
//! (forward **and** backward) plus the masked softmax-CE loss head, with
//! semantics identical to the JAX references in
//! `python/compile/kernels/ref.py` / `python/compile/model.py`:
//!
//! * neighbor slots equal to [`NO_NEIGHBOR`] are padding; the mean divides
//!   by `max(real_count, 1)`, so zero-degree rows aggregate to zeros,
//! * GAT adds an implicit self edge (always valid), applies
//!   `LeakyReLU(0.2)` to the attention logits, and softmax-normalizes over
//!   `{self} ∪ real neighbors`,
//! * ReLU backward masks on the *pre-activation* sign (gradient 0 at 0),
//!   matching `jax.nn.relu`'s VJP,
//! * the loss head returns the mean CE over the batch and a logit gradient
//!   already divided by the batch size.
//!
//! The backward passes were derived by hand and are pinned two ways: the
//! golden-value tests below embed outputs computed with the repo's JAX
//! oracles, and finite-difference tests check every gradient path against
//! the forward implementation.
//!
//! The straight scalar loops in this file are the **reference oracle** —
//! keep them boring; faster paths are tested against them. Execution
//! dispatches per [`KernelKind`]: `Scalar` runs the oracle loops verbatim,
//! while `Blocked`/`Simd` compose the same layers from the cache-blocked
//! primitives in [`super::kernels`] (batch gather-mean → register-blocked
//! dense transform → fused attention). `Blocked` — the default — is
//! bit-identical to `Scalar` by construction (see the contract table in
//! `kernels/mod.rs`), so every golden/finite-difference test below runs
//! unchanged under either; `simd` relaxes to a documented tolerance and is
//! compared in `rust/tests/kernel_equivalence.rs`. Override the choice per
//! process with `GSPLIT_KERNELS=scalar|blocked|simd`.

use anyhow::{bail, ensure};

use super::kernels::{self, KernelKind};
use super::{Backend, LayerGrads, LossOut};
use crate::model::{GnnKind, LayerParams};
use crate::sampling::NO_NEIGHBOR;
use crate::Result;

/// GAT LeakyReLU slope (Velickovic et al. 2018), matching `ref.py`.
const LEAKY_SLOPE: f32 = 0.2;

/// Pure-Rust execution backend. `Copy` and cheap to construct; the only
/// state is the kernel choice, fixed per instance so concurrent executor
/// threads sharing one backend always agree on numerics.
#[derive(Debug, Clone, Copy)]
pub struct NativeBackend {
    kernels: KernelKind,
}

impl NativeBackend {
    /// Backend with the process-wide kernel choice (`GSPLIT_KERNELS` if
    /// set, else `blocked`; see [`KernelKind::from_env`]).
    pub fn new() -> NativeBackend {
        NativeBackend { kernels: KernelKind::from_env() }
    }

    /// Backend pinned to a specific kernel variant (A/B tests, benches).
    /// An unavailable `Simd` request folds back to `Blocked`.
    pub fn with_kernels(kind: KernelKind) -> NativeBackend {
        NativeBackend { kernels: kind.resolve() }
    }

    /// The kernel variant this instance dispatches to.
    pub fn kernels(&self) -> KernelKind {
        self.kernels
    }
}

impl Default for NativeBackend {
    fn default() -> NativeBackend {
        NativeBackend::new()
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn layer_fwd(
        &self,
        model: GnnKind,
        din: usize,
        dout: usize,
        relu: bool,
        x: &[f32],
        n_real: usize,
        neigh: &[u32],
        m_real: usize,
        k_real: usize,
        params: &LayerParams,
    ) -> Result<Vec<f32>> {
        check_layer_args(model, din, dout, x, n_real, neigh, m_real, k_real, params)?;
        match model {
            GnnKind::GraphSage => {
                let (w_self, w_neigh, bias) = sage_params(params);
                Ok(match self.kernels {
                    KernelKind::Scalar => {
                        sage_fwd(x, neigh, m_real, k_real, din, dout, relu, w_self, w_neigh, bias)
                    }
                    k => sage_fwd_fast(
                        k, x, neigh, m_real, k_real, din, dout, relu, w_self, w_neigh, bias,
                    ),
                })
            }
            GnnKind::Gat => {
                let (w, a_src, a_dst, bias) = gat_params(params);
                Ok(match self.kernels {
                    KernelKind::Scalar => gat_fwd(
                        x, n_real, neigh, m_real, k_real, din, dout, relu, w, a_src, a_dst, bias,
                    ),
                    k => gat_fwd_fast(
                        k, x, n_real, neigh, m_real, k_real, din, dout, relu, w, a_src, a_dst,
                        bias,
                    ),
                })
            }
        }
    }

    fn layer_bwd(
        &self,
        model: GnnKind,
        din: usize,
        dout: usize,
        relu: bool,
        x: &[f32],
        n_real: usize,
        neigh: &[u32],
        m_real: usize,
        k_real: usize,
        g_out: &[f32],
        params: &LayerParams,
    ) -> Result<LayerGrads> {
        check_layer_args(model, din, dout, x, n_real, neigh, m_real, k_real, params)?;
        ensure!(
            g_out.len() == m_real * dout,
            "g_out has {} values, expected m_real*dout = {}",
            g_out.len(),
            m_real * dout
        );
        match model {
            GnnKind::GraphSage => {
                let (w_self, w_neigh, bias) = sage_params(params);
                Ok(match self.kernels {
                    KernelKind::Scalar => sage_bwd(
                        x, n_real, neigh, m_real, k_real, din, dout, relu, w_self, w_neigh, bias,
                        g_out,
                    ),
                    k => sage_bwd_fast(
                        k, x, n_real, neigh, m_real, k_real, din, dout, relu, w_self, w_neigh,
                        bias, g_out,
                    ),
                })
            }
            GnnKind::Gat => {
                let (w, a_src, a_dst, bias) = gat_params(params);
                Ok(match self.kernels {
                    KernelKind::Scalar => gat_bwd(
                        x, n_real, neigh, m_real, k_real, din, dout, relu, w, a_src, a_dst, bias,
                        g_out,
                    ),
                    k => gat_bwd_fast(
                        k, x, n_real, neigh, m_real, k_real, din, dout, relu, w, a_src, a_dst,
                        bias, g_out,
                    ),
                })
            }
        }
    }

    fn loss(
        &self,
        logits: &[f32],
        labels: &[i32],
        b_real: usize,
        c: usize,
    ) -> Result<(LossOut, Vec<f32>)> {
        ensure!(c > 0, "loss head needs at least one class");
        ensure!(
            logits.len() == b_real * c,
            "logits have {} values, expected b_real*c = {}",
            logits.len(),
            b_real * c
        );
        ensure!(labels.len() == b_real, "labels/batch mismatch: {} vs {b_real}", labels.len());
        let denom = b_real.max(1) as f32;
        let mut loss = 0f32;
        let mut correct = 0f32;
        let mut g = vec![0f32; b_real * c];
        for i in 0..b_real {
            let row = &logits[i * c..(i + 1) * c];
            let lbl = labels[i];
            ensure!(
                (0..c as i32).contains(&lbl),
                "label {lbl} out of range for {c} classes (row {i})"
            );
            let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let mut sum = 0f32;
            for &v in row {
                sum += (v - mx).exp();
            }
            // -log softmax[label], in log-sum-exp form.
            loss += sum.ln() - (row[lbl as usize] - mx);
            let grow = &mut g[i * c..(i + 1) * c];
            for (gq, &v) in grow.iter_mut().zip(row) {
                *gq = (v - mx).exp() / sum / denom;
            }
            grow[lbl as usize] -= 1.0 / denom;
            // First-maximum argmax, matching jnp.argmax tie-breaking.
            let mut best = 0usize;
            for (q, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = q;
                }
            }
            if best as i32 == lbl {
                correct += 1.0;
            }
        }
        Ok((LossOut { loss: loss / denom, correct }, g))
    }
}

// ---------------------------------------------------------------------------
// Shared validation / parameter unpacking
// ---------------------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn check_layer_args(
    model: GnnKind,
    din: usize,
    dout: usize,
    x: &[f32],
    n_real: usize,
    neigh: &[u32],
    m_real: usize,
    k_real: usize,
    params: &LayerParams,
) -> Result<()> {
    ensure!(din > 0 && dout > 0, "layer dims must be positive ({din}x{dout})");
    ensure!(
        x.len() == n_real * din,
        "x has {} values, expected n_real*din = {}",
        x.len(),
        n_real * din
    );
    ensure!(
        neigh.len() == m_real * k_real,
        "neigh has {} entries, expected m_real*k_real = {}",
        neigh.len(),
        m_real * k_real
    );
    ensure!(
        m_real <= n_real,
        "destinations must be a prefix of the mixed rows (m_real={m_real} > n_real={n_real})"
    );
    for (slot, &v) in neigh.iter().enumerate() {
        if v != NO_NEIGHBOR && v as usize >= n_real {
            bail!("neigh[{slot}] = {v} out of range for {n_real} mixed rows");
        }
    }
    // Validate each parameter tensor against the layer dims *by name*, so a
    // din/dout mismatch fails here with a pointed message instead of
    // slice-panicking deep inside the kernels.
    let want = match model {
        GnnKind::GraphSage => {
            vec![("w_self", din * dout), ("w_neigh", din * dout), ("bias", dout)]
        }
        GnnKind::Gat => {
            vec![("w", din * dout), ("a_src", dout), ("a_dst", dout), ("bias", dout)]
        }
    };
    ensure!(
        params.tensors.len() == want.len(),
        "{model:?} layer expects {} parameter tensors, got {}",
        want.len(),
        params.tensors.len()
    );
    for (tensor, (name, w)) in params.tensors.iter().zip(&want) {
        ensure!(
            tensor.len() == *w,
            "{model:?} parameter tensor `{name}` has {} values, expected {w} for din={din}, \
             dout={dout}",
            tensor.len()
        );
    }
    Ok(())
}

fn sage_params(p: &LayerParams) -> (&[f32], &[f32], &[f32]) {
    (&p.tensors[0], &p.tensors[1], &p.tensors[2])
}

fn gat_params(p: &LayerParams) -> (&[f32], &[f32], &[f32], &[f32]) {
    (&p.tensors[0], &p.tensors[1], &p.tensors[2], &p.tensors[3])
}

/// Masked mean of the sampled neighbor rows of destination `i` into `agg`
/// (length `din`). Mirrors `gather_mean_ref`: divide by `max(count, 1)`.
/// Returns the divisor actually used.
fn aggregate_row(x: &[f32], neigh: &[u32], i: usize, k: usize, din: usize, agg: &mut [f32]) -> f32 {
    agg.fill(0.0);
    let mut cnt = 0u32;
    for &v in &neigh[i * k..(i + 1) * k] {
        if v != NO_NEIGHBOR {
            let row = &x[v as usize * din..(v as usize + 1) * din];
            for (a, &b) in agg.iter_mut().zip(row) {
                *a += b;
            }
            cnt += 1;
        }
    }
    let denom = cnt.max(1) as f32;
    let inv = 1.0 / denom;
    for a in agg.iter_mut() {
        *a *= inv;
    }
    denom
}

// ---------------------------------------------------------------------------
// GraphSage: h = act(x_self @ w_self + mean(x_nbr) @ w_neigh + bias)
// ---------------------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn sage_fwd(
    x: &[f32],
    neigh: &[u32],
    m: usize,
    k: usize,
    din: usize,
    dout: usize,
    relu: bool,
    w_self: &[f32],
    w_neigh: &[f32],
    bias: &[f32],
) -> Vec<f32> {
    let mut out = vec![0f32; m * dout];
    let mut agg = vec![0f32; din];
    for i in 0..m {
        aggregate_row(x, neigh, i, k, din, &mut agg);
        let x_self = &x[i * din..(i + 1) * din];
        let o = &mut out[i * dout..(i + 1) * dout];
        o.copy_from_slice(bias);
        for p in 0..din {
            let (xs, ag) = (x_self[p], agg[p]);
            let ws = &w_self[p * dout..(p + 1) * dout];
            let wn = &w_neigh[p * dout..(p + 1) * dout];
            for q in 0..dout {
                o[q] += xs * ws[q] + ag * wn[q];
            }
        }
        if relu {
            for v in o.iter_mut() {
                *v = v.max(0.0);
            }
        }
    }
    out
}

#[allow(clippy::too_many_arguments)]
fn sage_bwd(
    x: &[f32],
    n: usize,
    neigh: &[u32],
    m: usize,
    k: usize,
    din: usize,
    dout: usize,
    relu: bool,
    w_self: &[f32],
    w_neigh: &[f32],
    bias: &[f32],
    g_out: &[f32],
) -> LayerGrads {
    let mut g_x = vec![0f32; n * din];
    let mut g_ws = vec![0f32; din * dout];
    let mut g_wn = vec![0f32; din * dout];
    let mut g_b = vec![0f32; dout];
    let mut agg = vec![0f32; din];
    let mut g = vec![0f32; dout];
    let mut g_agg = vec![0f32; din];
    for i in 0..m {
        let denom = aggregate_row(x, neigh, i, k, din, &mut agg);
        let x_self = &x[i * din..(i + 1) * din];
        g.copy_from_slice(&g_out[i * dout..(i + 1) * dout]);
        if relu {
            // Recompute the pre-activation to mask the gradient; ReLU's
            // VJP is 0 at 0, so mask on `h_pre <= 0`.
            for (q, gq) in g.iter_mut().enumerate() {
                let mut h = bias[q];
                for p in 0..din {
                    h += x_self[p] * w_self[p * dout + q] + agg[p] * w_neigh[p * dout + q];
                }
                if h <= 0.0 {
                    *gq = 0.0;
                }
            }
        }
        for (b, &gq) in g_b.iter_mut().zip(&g) {
            *b += gq;
        }
        for p in 0..din {
            let (xs, ag) = (x_self[p], agg[p]);
            let ws_row = &mut g_ws[p * dout..(p + 1) * dout];
            let wn_row = &mut g_wn[p * dout..(p + 1) * dout];
            for q in 0..dout {
                ws_row[q] += xs * g[q];
                wn_row[q] += ag * g[q];
            }
        }
        // d/dx_self: g @ w_self^T (the destination row may also appear as a
        // neighbor of other rows, so accumulate).
        for p in 0..din {
            let mut s = 0f32;
            let mut sn = 0f32;
            for q in 0..dout {
                s += g[q] * w_self[p * dout + q];
                sn += g[q] * w_neigh[p * dout + q];
            }
            g_x[i * din + p] += s;
            g_agg[p] = sn / denom;
        }
        // Scatter the mean's gradient into every real neighbor row
        // (mirrors gather_mean_grad_x_ref: g/cnt per sampled edge).
        for &v in &neigh[i * k..(i + 1) * k] {
            if v != NO_NEIGHBOR {
                let row = &mut g_x[v as usize * din..(v as usize + 1) * din];
                for (r, &ga) in row.iter_mut().zip(&g_agg) {
                    *r += ga;
                }
            }
        }
    }
    LayerGrads { g_x, g_params: vec![g_ws, g_wn, g_b] }
}

// ---------------------------------------------------------------------------
// GAT: z = x @ w; attention over {self} ∪ neighbors with LeakyReLU logits
// ---------------------------------------------------------------------------

fn leaky(v: f32) -> f32 {
    if v >= 0.0 {
        v
    } else {
        LEAKY_SLOPE * v
    }
}

/// Projection shared by GAT forward and backward: `z = x @ w` plus the
/// per-row attention terms `s_src = z @ a_src` and `s_dst = (z @ a_dst)[:m]`.
#[allow(clippy::too_many_arguments)]
fn gat_project(
    x: &[f32],
    n: usize,
    m: usize,
    din: usize,
    dout: usize,
    w: &[f32],
    a_src: &[f32],
    a_dst: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut z = vec![0f32; n * dout];
    for r in 0..n {
        let xr = &x[r * din..(r + 1) * din];
        let zr = &mut z[r * dout..(r + 1) * dout];
        for p in 0..din {
            let xv = xr[p];
            let wrow = &w[p * dout..(p + 1) * dout];
            for q in 0..dout {
                zr[q] += xv * wrow[q];
            }
        }
    }
    let dot = |row: &[f32], a: &[f32]| -> f32 { row.iter().zip(a).map(|(x, y)| x * y).sum() };
    let s_src: Vec<f32> = (0..n).map(|r| dot(&z[r * dout..(r + 1) * dout], a_src)).collect();
    let s_dst: Vec<f32> = (0..m).map(|r| dot(&z[r * dout..(r + 1) * dout], a_dst)).collect();
    (z, s_src, s_dst)
}

/// Attention rows of destination `i`: the implicit self edge first, then
/// every real neighbor; `logits` gets the pre-softmax LeakyReLU scores.
#[allow(clippy::too_many_arguments)]
fn attention_rows(
    neigh: &[u32],
    i: usize,
    k: usize,
    s_src: &[f32],
    s_dst: &[f32],
    rows: &mut Vec<usize>,
    logits: &mut Vec<f32>,
) {
    rows.clear();
    logits.clear();
    rows.push(i);
    logits.push(s_dst[i] + s_src[i]);
    for &v in &neigh[i * k..(i + 1) * k] {
        if v != NO_NEIGHBOR {
            rows.push(v as usize);
            logits.push(s_dst[i] + s_src[v as usize]);
        }
    }
}

/// Softmax of `leaky(logits)` in place; returns nothing, `logits` becomes α.
fn softmax_leaky(logits: &mut [f32]) {
    let mut mx = f32::NEG_INFINITY;
    for t in logits.iter_mut() {
        *t = leaky(*t);
        mx = mx.max(*t);
    }
    let mut sum = 0f32;
    for t in logits.iter_mut() {
        *t = (*t - mx).exp();
        sum += *t;
    }
    for t in logits.iter_mut() {
        *t /= sum;
    }
}

#[allow(clippy::too_many_arguments)]
fn gat_fwd(
    x: &[f32],
    n: usize,
    neigh: &[u32],
    m: usize,
    k: usize,
    din: usize,
    dout: usize,
    relu: bool,
    w: &[f32],
    a_src: &[f32],
    a_dst: &[f32],
    bias: &[f32],
) -> Vec<f32> {
    let (z, s_src, s_dst) = gat_project(x, n, m, din, dout, w, a_src, a_dst);
    let mut out = vec![0f32; m * dout];
    let mut rows = Vec::with_capacity(k + 1);
    let mut alpha = Vec::with_capacity(k + 1);
    for i in 0..m {
        attention_rows(neigh, i, k, &s_src, &s_dst, &mut rows, &mut alpha);
        softmax_leaky(&mut alpha);
        let o = &mut out[i * dout..(i + 1) * dout];
        o.copy_from_slice(bias);
        for (&r, &a) in rows.iter().zip(&alpha) {
            let zr = &z[r * dout..(r + 1) * dout];
            for q in 0..dout {
                o[q] += a * zr[q];
            }
        }
        if relu {
            for v in o.iter_mut() {
                *v = v.max(0.0);
            }
        }
    }
    out
}

#[allow(clippy::too_many_arguments)]
fn gat_bwd(
    x: &[f32],
    n: usize,
    neigh: &[u32],
    m: usize,
    k: usize,
    din: usize,
    dout: usize,
    relu: bool,
    w: &[f32],
    a_src: &[f32],
    a_dst: &[f32],
    bias: &[f32],
    g_out: &[f32],
) -> LayerGrads {
    let (z, s_src, s_dst) = gat_project(x, n, m, din, dout, w, a_src, a_dst);
    let mut g_z = vec![0f32; n * dout];
    let mut g_ssrc = vec![0f32; n];
    let mut g_sdst = vec![0f32; m];
    let mut g_b = vec![0f32; dout];
    let mut g = vec![0f32; dout];
    let mut rows = Vec::with_capacity(k + 1);
    let mut ells = Vec::with_capacity(k + 1);
    let mut alpha = Vec::with_capacity(k + 1);
    let mut g_alpha = Vec::with_capacity(k + 1);
    for i in 0..m {
        attention_rows(neigh, i, k, &s_src, &s_dst, &mut rows, &mut ells);
        alpha.clear();
        alpha.extend_from_slice(&ells);
        softmax_leaky(&mut alpha);
        g.copy_from_slice(&g_out[i * dout..(i + 1) * dout]);
        if relu {
            // Recompute h_pre = Σ α z + bias for the ReLU mask.
            for (q, gq) in g.iter_mut().enumerate() {
                let mut h = bias[q];
                for (&r, &a) in rows.iter().zip(&alpha) {
                    h += a * z[r * dout + q];
                }
                if h <= 0.0 {
                    *gq = 0.0;
                }
            }
        }
        for (b, &gq) in g_b.iter_mut().zip(&g) {
            *b += gq;
        }
        // out_i = Σ_j α_j z[r_j]:   g_α_j = g · z[r_j],   g_z[r_j] += α_j g.
        g_alpha.clear();
        for (&r, &a) in rows.iter().zip(&alpha) {
            let zr = &z[r * dout..(r + 1) * dout];
            let mut d = 0f32;
            let grow = &mut g_z[r * dout..(r + 1) * dout];
            for q in 0..dout {
                d += g[q] * zr[q];
                grow[q] += a * g[q];
            }
            g_alpha.push(d);
        }
        // Softmax VJP: g_t_j = α_j (g_α_j − Σ_l α_l g_α_l), then the
        // LeakyReLU VJP on the raw logit ℓ_j = s_dst[i] + s_src[r_j].
        let dot: f32 = alpha.iter().zip(&g_alpha).map(|(a, ga)| a * ga).sum();
        for ((&a, &ga), (&ell, &r)) in
            alpha.iter().zip(&g_alpha).zip(ells.iter().zip(&rows))
        {
            let slope = if ell >= 0.0 { 1.0 } else { LEAKY_SLOPE };
            let g_ell = a * (ga - dot) * slope;
            g_sdst[i] += g_ell;
            g_ssrc[r] += g_ell;
        }
    }
    // s_src = z @ a_src and s_dst = (z @ a_dst)[:m] feed back into z and
    // into the attention vectors.
    let mut g_asrc = vec![0f32; dout];
    let mut g_adst = vec![0f32; dout];
    for r in 0..n {
        let zr = &z[r * dout..(r + 1) * dout];
        let grow = &mut g_z[r * dout..(r + 1) * dout];
        let gs = g_ssrc[r];
        for q in 0..dout {
            grow[q] += gs * a_src[q];
            g_asrc[q] += gs * zr[q];
        }
    }
    for i in 0..m {
        let zr = &z[i * dout..(i + 1) * dout];
        let grow = &mut g_z[i * dout..(i + 1) * dout];
        let gd = g_sdst[i];
        for q in 0..dout {
            grow[q] += gd * a_dst[q];
            g_adst[q] += gd * zr[q];
        }
    }
    // z = x @ w:  g_x = g_z @ w^T,  g_w = x^T @ g_z.
    let mut g_x = vec![0f32; n * din];
    let mut g_w = vec![0f32; din * dout];
    for r in 0..n {
        let xr = &x[r * din..(r + 1) * din];
        let gz = &g_z[r * dout..(r + 1) * dout];
        let gx = &mut g_x[r * din..(r + 1) * din];
        for p in 0..din {
            let wrow = &w[p * dout..(p + 1) * dout];
            let gw_row = &mut g_w[p * dout..(p + 1) * dout];
            let mut s = 0f32;
            for q in 0..dout {
                s += gz[q] * wrow[q];
                gw_row[q] += xr[p] * gz[q];
            }
            gx[p] += s;
        }
    }
    LayerGrads { g_x, g_params: vec![g_w, g_asrc, g_adst, g_b] }
}

// ---------------------------------------------------------------------------
// Fast paths: the same layers composed from the blocked/simd kernel
// primitives (batch gather-mean → register-blocked dense → fused attention).
// With `KernelKind::Blocked` every function here is bit-identical to its
// scalar twin above — each output element sees the same additions in the
// same order — which `rust/tests/kernel_equivalence.rs` enforces.
// ---------------------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn sage_fwd_fast(
    kind: KernelKind,
    x: &[f32],
    neigh: &[u32],
    m: usize,
    k: usize,
    din: usize,
    dout: usize,
    relu: bool,
    w_self: &[f32],
    w_neigh: &[f32],
    bias: &[f32],
) -> Vec<f32> {
    // Materializing the m×din aggregate matrix turns the per-row rank-1
    // updates of the scalar path into one register-blocked dual transform.
    let mut agg = vec![0f32; m * din];
    let mut denoms = vec![0f32; m];
    kernels::gather::gather_mean(kind, x, neigh, m, k, din, &mut agg, &mut denoms);
    let mut out = vec![0f32; m * dout];
    kernels::dense::dense_bias_act(
        kind,
        m,
        din,
        dout,
        &x[..m * din],
        w_self,
        Some((&agg, w_neigh)),
        Some(bias),
        relu,
        &mut out,
    );
    out
}

#[allow(clippy::too_many_arguments)]
fn sage_bwd_fast(
    kind: KernelKind,
    x: &[f32],
    n: usize,
    neigh: &[u32],
    m: usize,
    k: usize,
    din: usize,
    dout: usize,
    relu: bool,
    w_self: &[f32],
    w_neigh: &[f32],
    bias: &[f32],
    g_out: &[f32],
) -> LayerGrads {
    let mut agg = vec![0f32; m * din];
    let mut denoms = vec![0f32; m];
    kernels::gather::gather_mean(kind, x, neigh, m, k, din, &mut agg, &mut denoms);
    let x_self = &x[..m * din];
    let mut g = g_out.to_vec();
    if relu {
        // Recompute the pre-activation batch-wide for the mask; bit-equal
        // to the scalar recompute, so the masks agree exactly.
        let mut h = vec![0f32; m * dout];
        kernels::dense::dense_bias_act(
            kind,
            m,
            din,
            dout,
            x_self,
            w_self,
            Some((&agg, w_neigh)),
            Some(bias),
            false,
            &mut h,
        );
        for (gv, &hv) in g.iter_mut().zip(&h) {
            if hv <= 0.0 {
                *gv = 0.0;
            }
        }
    }
    let mut g_b = vec![0f32; dout];
    for i in 0..m {
        for (b, &gq) in g_b.iter_mut().zip(&g[i * dout..(i + 1) * dout]) {
            *b += gq;
        }
    }
    let mut g_ws = vec![0f32; din * dout];
    kernels::dense::matmul_gw_acc(kind, m, din, dout, x_self, &g, &mut g_ws);
    let mut g_wn = vec![0f32; din * dout];
    kernels::dense::matmul_gw_acc(kind, m, din, dout, &agg, &g, &mut g_wn);
    // Per-destination input gradients: s_self = G @ w_selfᵀ feeds the
    // destination's own row, s_nbr = (G @ w_neighᵀ) / denom is scattered to
    // its sampled neighbors.
    let mut s_self = vec![0f32; m * din];
    kernels::dense::matmul_gx_acc(kind, m, din, dout, &g, w_self, &mut s_self);
    let mut s_nbr = vec![0f32; m * din];
    kernels::dense::matmul_gx_acc(kind, m, din, dout, &g, w_neigh, &mut s_nbr);
    for i in 0..m {
        let d = denoms[i];
        for v in &mut s_nbr[i * din..(i + 1) * din] {
            *v /= d;
        }
    }
    // The write order into g_x must stay per-destination-interleaved (self
    // add, then the neighbor scatter, destinations ascending): a row can
    // receive its self gradient from i₁ and scattered gradients from some
    // i₂ < i₁, and float addition does not commute bitwise.
    let mut g_x = vec![0f32; n * din];
    for i in 0..m {
        for (o, &s) in g_x[i * din..(i + 1) * din].iter_mut().zip(&s_self[i * din..(i + 1) * din])
        {
            *o += s;
        }
        let srow = &s_nbr[i * din..(i + 1) * din];
        for &v in &neigh[i * k..(i + 1) * k] {
            if v != NO_NEIGHBOR {
                let row = &mut g_x[v as usize * din..(v as usize + 1) * din];
                for (r, &ga) in row.iter_mut().zip(srow) {
                    *r += ga;
                }
            }
        }
    }
    LayerGrads { g_x, g_params: vec![g_ws, g_wn, g_b] }
}

/// Fast twin of `gat_project`: blocked dense for `z = x @ w`, then the same
/// ascending-`q` scalar dots for `s_src`/`s_dst` under every kernel kind
/// (they are O(n·dout) and keeping them scalar keeps them bit-exact).
#[allow(clippy::too_many_arguments)]
fn gat_project_fast(
    kind: KernelKind,
    x: &[f32],
    n: usize,
    m: usize,
    din: usize,
    dout: usize,
    w: &[f32],
    a_src: &[f32],
    a_dst: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut z = vec![0f32; n * dout];
    kernels::dense::dense_bias_act(kind, n, din, dout, x, w, None, None, false, &mut z);
    let dot = |row: &[f32], a: &[f32]| -> f32 { row.iter().zip(a).map(|(x, y)| x * y).sum() };
    let s_src: Vec<f32> = (0..n).map(|r| dot(&z[r * dout..(r + 1) * dout], a_src)).collect();
    let s_dst: Vec<f32> = (0..m).map(|r| dot(&z[r * dout..(r + 1) * dout], a_dst)).collect();
    (z, s_src, s_dst)
}

#[allow(clippy::too_many_arguments)]
fn gat_fwd_fast(
    kind: KernelKind,
    x: &[f32],
    n: usize,
    neigh: &[u32],
    m: usize,
    k: usize,
    din: usize,
    dout: usize,
    relu: bool,
    w: &[f32],
    a_src: &[f32],
    a_dst: &[f32],
    bias: &[f32],
) -> Vec<f32> {
    let (z, s_src, s_dst) = gat_project_fast(kind, x, n, m, din, dout, w, a_src, a_dst);
    let mut out = vec![0f32; m * dout];
    kernels::attn::attention_fwd(
        kind, &z, &s_src, &s_dst, neigh, m, k, dout, bias, relu, &mut out,
    );
    out
}

#[allow(clippy::too_many_arguments)]
fn gat_bwd_fast(
    kind: KernelKind,
    x: &[f32],
    n: usize,
    neigh: &[u32],
    m: usize,
    k: usize,
    din: usize,
    dout: usize,
    relu: bool,
    w: &[f32],
    a_src: &[f32],
    a_dst: &[f32],
    bias: &[f32],
    g_out: &[f32],
) -> LayerGrads {
    let (z, s_src, s_dst) = gat_project_fast(kind, x, n, m, din, dout, w, a_src, a_dst);
    let mut g_z = vec![0f32; n * dout];
    let mut g_ssrc = vec![0f32; n];
    let mut g_sdst = vec![0f32; m];
    let mut g_b = vec![0f32; dout];
    kernels::attn::attention_bwd(
        kind, &z, &s_src, &s_dst, neigh, m, k, dout, bias, relu, g_out, &mut g_z, &mut g_ssrc,
        &mut g_sdst, &mut g_b,
    );
    // s_src = z @ a_src and s_dst = (z @ a_dst)[:m] feed back into z and
    // the attention vectors — same loops as the scalar path.
    let mut g_asrc = vec![0f32; dout];
    let mut g_adst = vec![0f32; dout];
    for r in 0..n {
        let zr = &z[r * dout..(r + 1) * dout];
        let grow = &mut g_z[r * dout..(r + 1) * dout];
        let gs = g_ssrc[r];
        for q in 0..dout {
            grow[q] += gs * a_src[q];
            g_asrc[q] += gs * zr[q];
        }
    }
    for i in 0..m {
        let zr = &z[i * dout..(i + 1) * dout];
        let grow = &mut g_z[i * dout..(i + 1) * dout];
        let gd = g_sdst[i];
        for q in 0..dout {
            grow[q] += gd * a_dst[q];
            g_adst[q] += gd * zr[q];
        }
    }
    // Projection VJP over all n mixed rows: g_x = g_z @ wᵀ, g_w = xᵀ @ g_z.
    let mut g_x = vec![0f32; n * din];
    kernels::dense::matmul_gx_acc(kind, n, din, dout, &g_z, w, &mut g_x);
    let mut g_w = vec![0f32; din * dout];
    kernels::dense::matmul_gw_acc(kind, n, din, dout, x, &g_z, &mut g_w);
    LayerGrads { g_x, g_params: vec![g_w, g_asrc, g_adst, g_b] }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelConfig, ParamStore};

    const NB: u32 = NO_NEIGHBOR;

    fn be() -> NativeBackend {
        NativeBackend::new()
    }

    fn approx(a: &[f32], b: &[f32], tol: f32) {
        assert_eq!(a.len(), b.len(), "length mismatch");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() <= tol * (1.0 + y.abs()), "[{i}]: {x} vs {y}");
        }
    }

    /// Identity-weight GraphSage layer over x = [[1,2],[3,4],[5,6]]
    /// (row 0 is the destination), bias [0.5, -0.5], no ReLU.
    fn sage_identity() -> (Vec<f32>, LayerParams) {
        let x = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let eye = vec![1.0, 0.0, 0.0, 1.0];
        let params = LayerParams {
            tensors: vec![eye.clone(), eye, vec![0.5, -0.5]],
            shapes: vec![(2, 2), (2, 2), (1, 2)],
        };
        (x, params)
    }

    #[test]
    fn sage_fwd_hand_fixtures() {
        // Golden values hand-computed and cross-checked against
        // python/compile/kernels/ref.py (gather_mean_ref + sage_layer).
        let (x, params) = sage_identity();
        let b = be();
        // Both neighbors real: agg = mean(row1,row2) = [4,5];
        // h = [1,2] + [4,5] + [0.5,-0.5].
        let out = b
            .layer_fwd(GnnKind::GraphSage, 2, 2, false, &x, 3, &[1, 2], 1, 2, &params)
            .unwrap();
        approx(&out, &[5.5, 6.5], 1e-6);
        // One padded slot: agg = row1 = [3,4].
        let out = b
            .layer_fwd(GnnKind::GraphSage, 2, 2, false, &x, 3, &[1, NB], 1, 2, &params)
            .unwrap();
        approx(&out, &[4.5, 5.5], 1e-6);
        // Zero-degree row: agg = 0 (the max(count,1) divisor).
        let out = b
            .layer_fwd(GnnKind::GraphSage, 2, 2, false, &x, 3, &[NB, NB], 1, 2, &params)
            .unwrap();
        approx(&out, &[1.5, 1.5], 1e-6);
    }

    #[test]
    fn sage_bwd_hand_fixture() {
        // Same layer, g_out = [1,1]: g_x = [[1,1],[.5,.5],[.5,.5]],
        // g_ws = x_selfᵀ g = [[1,1],[2,2]], g_wn = aggᵀ g = [[4,4],[5,5]],
        // g_b = [1,1]. (Cross-checked against jax.vjp of the reference.)
        let (x, params) = sage_identity();
        let grads = be()
            .layer_bwd(GnnKind::GraphSage, 2, 2, false, &x, 3, &[1, 2], 1, 2, &[1.0, 1.0], &params)
            .unwrap();
        approx(&grads.g_x, &[1.0, 1.0, 0.5, 0.5, 0.5, 0.5], 1e-6);
        approx(&grads.g_params[0], &[1.0, 1.0, 2.0, 2.0], 1e-6);
        approx(&grads.g_params[1], &[4.0, 4.0, 5.0, 5.0], 1e-6);
        approx(&grads.g_params[2], &[1.0, 1.0], 1e-6);
    }

    #[test]
    fn sage_relu_masks_gradient_on_preactivation() {
        // bias [-10, 0.5] ⇒ h_pre = [1+4-10, 2+5+0.5] = [-5, 7.5] ⇒ relu
        // masks channel 0.
        let (x, mut params) = sage_identity();
        params.tensors[2] = vec![-10.0, 0.5];
        let b = be();
        let out = b
            .layer_fwd(GnnKind::GraphSage, 2, 2, true, &x, 3, &[1, 2], 1, 2, &params)
            .unwrap();
        approx(&out, &[0.0, 7.5], 1e-6);
        let grads = b
            .layer_bwd(GnnKind::GraphSage, 2, 2, true, &x, 3, &[1, 2], 1, 2, &[1.0, 1.0], &params)
            .unwrap();
        approx(&grads.g_x, &[0.0, 1.0, 0.0, 0.5, 0.0, 0.5], 1e-6);
        approx(&grads.g_params[2], &[0.0, 1.0], 1e-6);
    }

    #[test]
    fn loss_hand_fixture() {
        // logits [[0,0],[2,0]], labels [0,1]:
        //   row0 ce = ln 2, row1 ce = −ln σ₁([2,0]) ⇒ loss = 1.410038;
        //   correct = 1 (row0 tie → argmax 0 = label; row1 misses);
        //   g = (softmax − onehot)/2. (Matches model.loss_head in JAX.)
        let (out, g) = be().loss(&[0.0, 0.0, 2.0, 0.0], &[0, 1], 2, 2).unwrap();
        assert!((out.loss - 1.410038).abs() < 1e-5, "loss {}", out.loss);
        assert_eq!(out.correct, 1.0);
        approx(&g, &[-0.25, 0.25, 0.440399, -0.440399], 1e-5);
    }

    #[test]
    fn gat_isolated_vertex_keeps_self() {
        // All neighbors padded ⇒ attention collapses onto the self edge:
        // h = x @ w + bias (ref.py test_isolated_vertex_keeps_self).
        let x = vec![0.5, -0.5, 2.0, 1.0];
        let eye = vec![1.0, 0.0, 0.0, 1.0];
        let params = LayerParams {
            tensors: vec![eye, vec![0.3, -0.2], vec![-0.1, 0.4], vec![1.0, 1.0]],
            shapes: vec![(2, 2), (1, 2), (1, 2), (1, 2)],
        };
        let out = be()
            .layer_fwd(GnnKind::Gat, 2, 2, false, &x, 2, &[NB, NB, NB], 1, 3, &params)
            .unwrap();
        approx(&out, &[1.5, 0.5], 1e-6);
    }

    #[test]
    fn gat_attention_is_convex_combination() {
        // Identical projected rows ⇒ output equals that row regardless of
        // the attention weights (softmax weights sum to 1).
        let x: Vec<f32> = (0..4).flat_map(|_| [1.0f32, -2.0]).collect();
        let eye = vec![1.0, 0.0, 0.0, 1.0];
        let params = LayerParams {
            tensors: vec![eye, vec![0.7, 0.1], vec![-0.4, 0.2], vec![0.0, 0.0]],
            shapes: vec![(2, 2), (1, 2), (1, 2), (1, 2)],
        };
        let out = be()
            .layer_fwd(GnnKind::Gat, 2, 2, false, &x, 4, &[1, 2, 3], 1, 3, &params)
            .unwrap();
        approx(&out, &[1.0, -2.0], 1e-5);
    }

    #[test]
    fn gat_fwd_matches_jax_reference_golden() {
        // Nontrivial case (n=5, m=2, k=3, one row with padding) whose
        // expected output was computed with gat_layer over
        // python/compile/kernels/ref.py::gat_attention_ref (relu on).
        let x = vec![
            -0.5, -0.13636363, 0.22727275, -0.40909091, -0.04545453, 0.31818181, -0.31818181,
            0.04545456, 0.40909094, -0.22727272, 0.13636363, -0.5, -0.13636363, 0.22727275,
            -0.40909091,
        ];
        let w = vec![-0.4, 0.0, 0.4, -0.2, 0.2, -0.4];
        let params = LayerParams {
            tensors: vec![w, vec![0.3, -0.2], vec![-0.1, 0.4], vec![0.05, -0.05]],
            shapes: vec![(3, 2), (1, 2), (1, 2), (1, 2)],
        };
        let neigh = [2, 3, NB, 4, NB, NB];
        let out = be()
            .layer_fwd(GnnKind::Gat, 3, 2, true, &x, 5, &neigh, 2, 3, &params)
            .unwrap();
        approx(&out, &[0.20673026, 0.0, 0.18755361, 0.0], 1e-5);
    }

    /// Deterministic "ramp" inputs, as used by the AOT golden generator.
    fn ramp(len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|i| ((i * 37 + 11) % 97) as f32 / 97.0 * scale - scale / 2.0).collect()
    }

    /// Central finite difference of `f` at coordinate `probe` of `x`.
    fn fd(x: &[f32], probe: usize, eps: f32, f: impl Fn(&[f32]) -> f32) -> f32 {
        let mut xp = x.to_vec();
        xp[probe] += eps;
        let mut xm = x.to_vec();
        xm[probe] -= eps;
        (f(&xp) - f(&xm)) / (2.0 * eps)
    }

    fn fd_case(kind: GnnKind) {
        let (din, dout, m, k) = (6, 4, 5, 3);
        let n = m * (k + 1);
        let cfg = ModelConfig { kind, feat_dim: din, hidden: dout, num_classes: 4, num_layers: 2 };
        let store = ParamStore::init(&cfg, 7);
        let params = &store.layers[0];
        let x = ramp(n * din, 2.0);
        let mut neigh = vec![NB; m * k];
        for i in 0..m {
            for j in 0..k {
                if (i + j) % 4 != 3 {
                    neigh[i * k + j] = (m + i * k + j) as u32;
                }
            }
        }
        let b = be();
        // Scalar objective: weighted sum of outputs (weights break symmetry).
        let wts: Vec<f32> = (0..m * dout).map(|i| 0.3 + 0.1 * (i % 7) as f32).collect();
        let obj = |xx: &[f32]| -> f32 {
            b.layer_fwd(kind, din, dout, true, xx, n, &neigh, m, k, params)
                .unwrap()
                .iter()
                .zip(&wts)
                .map(|(o, w)| o * w)
                .sum()
        };
        let grads =
            b.layer_bwd(kind, din, dout, true, &x, n, &neigh, m, k, &wts, params).unwrap();
        assert_eq!(grads.g_x.len(), n * din);
        assert_eq!(grads.g_params.len(), params.tensors.len());
        // Probe a destination row, a neighbor row, and a padded-slot row.
        for probe in [3, m * din + 2, (n - 1) * din + 1] {
            let want = fd(&x, probe, 1e-2, &obj);
            let got = grads.g_x[probe];
            assert!(
                (want - got).abs() < 2e-2 * (1.0 + want.abs()),
                "{kind:?} g_x[{probe}]: fd {want} vs analytic {got}"
            );
        }
    }

    #[test]
    fn sage_bwd_matches_finite_difference() {
        fd_case(GnnKind::GraphSage);
    }

    #[test]
    fn gat_bwd_matches_finite_difference() {
        fd_case(GnnKind::Gat);
    }

    #[test]
    fn loss_grad_matches_finite_difference() {
        let (b_real, c) = (6, 5);
        let logits = ramp(b_real * c, 4.0);
        let labels: Vec<i32> = (0..b_real).map(|i| ((i * 3 + 1) % c) as i32).collect();
        let be = be();
        let (_, g) = be.loss(&logits, &labels, b_real, c).unwrap();
        for probe in [0, 7, b_real * c - 1] {
            let want = fd(&logits, probe, 1e-3, |lg| {
                be.loss(lg, &labels, b_real, c).unwrap().0.loss
            });
            assert!(
                (want - g[probe]).abs() < 1e-2 * (1.0 + want.abs()),
                "g_logits[{probe}]: fd {want} vs analytic {}",
                g[probe]
            );
        }
    }

    #[test]
    fn shape_validation_rejects_bad_inputs() {
        let (x, params) = sage_identity();
        let b = be();
        // x length mismatch.
        assert!(b
            .layer_fwd(GnnKind::GraphSage, 2, 2, false, &x[..4], 3, &[1, 2], 1, 2, &params)
            .is_err());
        // Neighbor index out of range.
        assert!(b
            .layer_fwd(GnnKind::GraphSage, 2, 2, false, &x, 3, &[9, 2], 1, 2, &params)
            .is_err());
        // Wrong parameter count for GAT.
        assert!(b.layer_fwd(GnnKind::Gat, 2, 2, false, &x, 3, &[1, 2], 1, 2, &params).is_err());
        // Label out of range.
        assert!(b.loss(&[0.0, 0.0], &[5], 1, 2).is_err());
    }

    #[test]
    fn param_validation_names_offending_tensor() {
        // Satellite bugfix regression: a din/dout-inconsistent parameter
        // tensor must fail validation naming the tensor, not slice-panic
        // inside the kernels.
        let (x, mut params) = sage_identity();
        params.tensors[1] = vec![0.0; 3]; // w_neigh should be din*dout = 4
        let err = be()
            .layer_fwd(GnnKind::GraphSage, 2, 2, false, &x, 3, &[1, 2], 1, 2, &params)
            .unwrap_err()
            .to_string();
        assert!(err.contains("`w_neigh`"), "message should name the tensor: {err}");
        assert!(err.contains("expected 4"), "message should state the expected size: {err}");
        assert!(err.contains("din=2"), "message should echo the dims: {err}");

        let eye = vec![1.0, 0.0, 0.0, 1.0];
        let gat = LayerParams {
            tensors: vec![eye, vec![0.3, -0.2], vec![-0.1], vec![1.0, 1.0]], // a_dst too short
            shapes: vec![(2, 2), (1, 2), (1, 2), (1, 2)],
        };
        let err = be()
            .layer_fwd(GnnKind::Gat, 2, 2, false, &x, 3, &[1, 2], 1, 2, &gat)
            .unwrap_err()
            .to_string();
        assert!(err.contains("`a_dst`"), "message should name the tensor: {err}");
    }

    #[test]
    fn blocked_layers_are_bit_identical_to_scalar() {
        // Spot check through the Backend API; the full shape sweep lives in
        // rust/tests/kernel_equivalence.rs.
        let (din, dout, m, k) = (6, 4, 5, 3);
        let n = m * (k + 1);
        let x = ramp(n * din, 2.0);
        let mut neigh = vec![NB; m * k];
        for i in 0..m {
            for j in 0..k {
                if (i + j) % 4 != 3 {
                    neigh[i * k + j] = (m + i * k + j) as u32;
                }
            }
        }
        let g_out = ramp(m * dout, 1.0);
        let scalar = NativeBackend::with_kernels(KernelKind::Scalar);
        let blocked = NativeBackend::with_kernels(KernelKind::Blocked);
        for kind in [GnnKind::GraphSage, GnnKind::Gat] {
            let cfg = ModelConfig {
                kind,
                feat_dim: din,
                hidden: dout,
                num_classes: 4,
                num_layers: 2,
            };
            let store = ParamStore::init(&cfg, 7);
            let params = &store.layers[0];
            let o_s =
                scalar.layer_fwd(kind, din, dout, true, &x, n, &neigh, m, k, params).unwrap();
            let o_b =
                blocked.layer_fwd(kind, din, dout, true, &x, n, &neigh, m, k, params).unwrap();
            assert_eq!(o_s, o_b, "{kind:?} fwd");
            let g_s = scalar
                .layer_bwd(kind, din, dout, true, &x, n, &neigh, m, k, &g_out, params)
                .unwrap();
            let g_b = blocked
                .layer_bwd(kind, din, dout, true, &x, n, &neigh, m, k, &g_out, params)
                .unwrap();
            assert_eq!(g_s.g_x, g_b.g_x, "{kind:?} g_x");
            assert_eq!(g_s.g_params, g_b.g_params, "{kind:?} g_params");
        }
    }

    #[test]
    fn deterministic_across_calls() {
        let (din, dout, m, k) = (5, 3, 4, 2);
        let n = m * (k + 1);
        let cfg = ModelConfig {
            kind: GnnKind::Gat,
            feat_dim: din,
            hidden: dout,
            num_classes: 3,
            num_layers: 2,
        };
        let store = ParamStore::init(&cfg, 11);
        let x = ramp(n * din, 1.0);
        let neigh: Vec<u32> = (0..m * k).map(|i| (m + i) as u32).collect();
        let b = be();
        let o1 = b
            .layer_fwd(GnnKind::Gat, din, dout, true, &x, n, &neigh, m, k, &store.layers[0])
            .unwrap();
        let o2 = b
            .layer_fwd(GnnKind::Gat, din, dout, true, &x, n, &neigh, m, k, &store.layers[0])
            .unwrap();
        assert_eq!(o1, o2);
    }
}
