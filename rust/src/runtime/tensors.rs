//! Literal construction/extraction helpers around the `xla` crate.

use anyhow::{anyhow, Result};

/// Build an f32 literal of the given dims from a flat row-major slice.
pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let expect: i64 = dims.iter().product();
    anyhow::ensure!(expect as usize == data.len(), "lit_f32: {dims:?} vs len {}", data.len());
    xla::Literal::vec1(data).reshape(dims).map_err(|e| anyhow!("reshape f32 {dims:?}: {e}"))
}

/// Build an i32 literal of the given dims from a flat row-major slice.
pub fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let expect: i64 = dims.iter().product();
    anyhow::ensure!(expect as usize == data.len(), "lit_i32: {dims:?} vs len {}", data.len());
    xla::Literal::vec1(data).reshape(dims).map_err(|e| anyhow!("reshape i32 {dims:?}: {e}"))
}

/// Extract a flat f32 vector from a literal.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let l = lit_f32(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
        assert_eq!(to_vec_f32(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(lit_f32(&[1.0; 5], &[2, 3]).is_err());
        assert!(lit_i32(&[1; 7], &[2, 3]).is_err());
    }

    #[test]
    fn i32_roundtrip() {
        let l = lit_i32(&[7, 8, 9], &[3]).unwrap();
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![7, 8, 9]);
    }
}
