//! Execution backends: the numeric kernels behind split-parallel training.
//!
//! The trainer composes per-layer forward/backward executions with its own
//! cross-device shuffles (paper §6: layer-centric kernel reuse), so the
//! only thing a backend must provide is the per-layer math. That contract
//! is the [`Backend`] trait — three entry points:
//!
//! * **layer forward** — one GNN layer (GraphSage mean-aggregation or
//!   single-head GAT attention) over a mixed-frontier feature matrix and a
//!   `[M, K]` sampled-neighbor table,
//! * **layer backward** — the layer's VJP: gradients w.r.t. the mixed
//!   input rows and every parameter tensor,
//! * **loss head** — masked softmax cross-entropy over target rows, with
//!   the logit gradient and the correct-prediction count.
//!
//! Two implementations ship:
//!
//! * [`NativeBackend`] (default) — pure Rust, zero external dependencies,
//!   numerically validated against the JAX references in
//!   `python/compile/kernels/ref.py`. This is what a fresh clone builds,
//!   trains, and tests with. Its hot loops dispatch through the
//!   cache-blocked [`kernels`] module ([`KernelKind`], overridable with
//!   `GSPLIT_KERNELS=scalar|blocked|simd`).
//! * `Runtime` (requires the `pjrt` cargo feature) — loads the AOT HLO
//!   artifacts produced by `python/compile/aot.py` and executes them
//!   through a PJRT client, exactly as before the backend split. See
//!   [`Manifest`] for the compile-time contract it consumes.
//!
//! Shared conventions (identical across backends, mirrored from
//! `python/compile/model.py`):
//!
//! * the mixed-frontier matrix `x` is `[n_real, din]` row-major with the
//!   `m_real` destination rows first (`x[..m_real]` are the destinations'
//!   own features),
//! * `neigh` is a `[m_real, k_real]` row-major table of indices into the
//!   rows of `x`, padded with [`NO_NEIGHBOR`](crate::sampling::NO_NEIGHBOR)
//!   for destinations with fewer than `k_real` sampled neighbors,
//! * parameter tensors follow the [`LayerParams`] layout
//!   (GraphSage: `[w_self, w_neigh, bias]`; GAT:
//!   `[w, a_src, a_dst, bias]`), and gradients are returned in that order.

pub mod kernels;
mod manifest;
mod native;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
mod tensors;

pub use kernels::KernelKind;
pub use manifest::{ArtifactMeta, Manifest};
pub use native::NativeBackend;

#[cfg(feature = "pjrt")]
pub use pjrt::Runtime;
#[cfg(feature = "pjrt")]
pub use tensors::{lit_f32, lit_i32, to_vec_f32};

use crate::model::{GnnKind, LayerParams};
use crate::Result;

/// Outputs of one layer-backward execution.
#[derive(Debug, Clone)]
pub struct LayerGrads {
    /// Gradient w.r.t. the mixed-frontier input rows (`n_real × din`).
    pub g_x: Vec<f32>,
    /// Gradients w.r.t. the layer parameters (same layout as
    /// `LayerParams::tensors`).
    pub g_params: Vec<Vec<f32>>,
}

/// Outputs of a loss-head execution.
#[derive(Debug, Clone, Copy)]
pub struct LossOut {
    /// Mean cross-entropy over the batch rows.
    pub loss: f32,
    /// Number of rows whose argmax prediction matches the label.
    pub correct: f32,
}

/// The per-layer numeric contract between the split-parallel trainer and
/// an execution engine. Object-safe: the trainer holds a `&dyn Backend`.
///
/// `Sync` is part of the contract: the threaded executor
/// (`train::ExecMode::Pipelined`) shares one backend reference across all
/// worker threads, so implementations must be safe to call concurrently.
/// [`NativeBackend`] is stateless; the PJRT `Runtime` guards its lazily
/// compiled executable cache with a mutex.
///
/// # Example
///
/// One GraphSage layer through the default backend:
///
/// ```
/// use gsplit::model::{GnnKind, ModelConfig, ParamStore};
/// use gsplit::runtime::{Backend, NativeBackend};
/// use gsplit::sampling::NO_NEIGHBOR;
///
/// let cfg = ModelConfig {
///     kind: GnnKind::GraphSage,
///     feat_dim: 4,
///     hidden: 4,
///     num_classes: 3,
///     num_layers: 1,
/// };
/// let params = ParamStore::init(&cfg, 7);
/// let backend = NativeBackend::new();
/// // Mixed frontier of 3 rows (2 destinations first), fanout 2.
/// let x = vec![0.1f32; 3 * 4];
/// let neigh = vec![2u32, 2, 2, NO_NEIGHBOR];
/// let out = backend
///     .layer_fwd(cfg.kind, 4, 3, false, &x, 3, &neigh, 2, 2, &params.layers[0])
///     .unwrap();
/// assert_eq!(out.len(), 2 * 3); // m_real × dout
/// ```
pub trait Backend: Sync {
    /// Short human-readable backend name (logs and diagnostics).
    fn name(&self) -> &'static str;

    /// Execute one GNN layer forward.
    ///
    /// `x` is the `[n_real, din]` mixed-frontier matrix (destinations
    /// first), `neigh` the `[m_real, k_real]` neighbor table into its rows.
    /// Returns the `m_real × dout` output rows.
    #[allow(clippy::too_many_arguments)]
    fn layer_fwd(
        &self,
        model: GnnKind,
        din: usize,
        dout: usize,
        relu: bool,
        x: &[f32],
        n_real: usize,
        neigh: &[u32],
        m_real: usize,
        k_real: usize,
        params: &LayerParams,
    ) -> Result<Vec<f32>>;

    /// Execute one GNN layer backward (VJP).
    ///
    /// `g_out` is the `[m_real, dout]` gradient of the loss w.r.t. this
    /// layer's outputs. Returns the gradient w.r.t. the mixed input rows
    /// and the parameter gradients.
    #[allow(clippy::too_many_arguments)]
    fn layer_bwd(
        &self,
        model: GnnKind,
        din: usize,
        dout: usize,
        relu: bool,
        x: &[f32],
        n_real: usize,
        neigh: &[u32],
        m_real: usize,
        k_real: usize,
        g_out: &[f32],
        params: &LayerParams,
    ) -> Result<LayerGrads>;

    /// Execute the loss head over `b_real` target rows with `c` classes.
    ///
    /// Returns the batch statistics and the `[b_real, c]` logit gradient
    /// of the *mean* cross-entropy (already divided by `b_real`).
    fn loss(
        &self,
        logits: &[f32],
        labels: &[i32],
        b_real: usize,
        c: usize,
    ) -> Result<(LossOut, Vec<f32>)>;
}
