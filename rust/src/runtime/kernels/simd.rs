//! AVX2/FMA inner loops (`--features simd`, `x86_64` only).
//!
//! Every function here is an `unsafe fn` carrying
//! `#[target_feature(enable = "avx2,fma")]` — on the pinned 1.84 toolchain
//! `target_feature` requires `unsafe fn` (safe `target_feature` stabilized
//! later) — and is only reachable through [`super::KernelKind::resolve`],
//! which returns `Simd` exclusively when `is_x86_feature_detected!` reports
//! both AVX2 and FMA at runtime.
//!
//! Numerics (see the contract table in [`super`]): [`gather_mean`] uses
//! only `add_ps`/`mul_ps`, which round exactly like their scalar
//! counterparts and preserve the ascending-slot order per element, so it is
//! **bit-identical** to the scalar oracle. The dense transforms and
//! attention accumulates use `fmadd_ps` (one rounding instead of two) and
//! [`dot`] reassociates the reduction across 8 lanes — those match within
//! [`super::SIMD_REL_TOL`].

#![allow(clippy::missing_safety_doc)] // one shared contract, documented above

use std::arch::x86_64::*;

use crate::sampling::NO_NEIGHBOR;

const L: usize = 8; // f32 lanes per AVX2 vector

/// Horizontal sum of one vector. Stores to a stack array and sums in lane
/// order — this is the only reassociation the simd dot introduces.
#[target_feature(enable = "avx2,fma")]
unsafe fn hsum(v: __m256) -> f32 {
    let mut t = [0f32; L];
    _mm256_storeu_ps(t.as_mut_ptr(), v);
    let mut s = 0f32;
    for x in t {
        s += x;
    }
    s
}

/// `Σ x·y` with lane-parallel FMA accumulation.
pub unsafe fn dot(x: &[f32], y: &[f32]) -> f32 {
    #[target_feature(enable = "avx2,fma")]
    unsafe fn imp(x: &[f32], y: &[f32]) -> f32 {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let full = n - n % L;
        let mut acc = _mm256_setzero_ps();
        let mut q = 0;
        while q < full {
            let xv = _mm256_loadu_ps(x.as_ptr().add(q));
            let yv = _mm256_loadu_ps(y.as_ptr().add(q));
            acc = _mm256_fmadd_ps(xv, yv, acc);
            q += L;
        }
        let mut s = hsum(acc);
        for q in full..n {
            s += x[q] * y[q];
        }
        s
    }
    imp(x, y)
}

/// `y += a·x` with FMA.
pub unsafe fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    #[target_feature(enable = "avx2,fma")]
    unsafe fn imp(a: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let full = n - n % L;
        let av = _mm256_set1_ps(a);
        let mut q = 0;
        while q < full {
            let xv = _mm256_loadu_ps(x.as_ptr().add(q));
            let yv = _mm256_loadu_ps(y.as_ptr().add(q));
            _mm256_storeu_ps(y.as_mut_ptr().add(q), _mm256_fmadd_ps(av, xv, yv));
            q += L;
        }
        for q in full..n {
            y[q] += a * x[q];
        }
    }
    imp(a, x, y)
}

/// FMA twin of `dense::dense_bias_act`: MR=4 destination rows × one AVX2
/// vector of output columns held in registers across the whole `din`
/// reduction; scalar row/column tails.
#[allow(clippy::too_many_arguments)]
pub unsafe fn dense_bias_act(
    m: usize,
    din: usize,
    dout: usize,
    a1: &[f32],
    w1: &[f32],
    pair: Option<(&[f32], &[f32])>,
    bias: Option<&[f32]>,
    relu: bool,
    out: &mut [f32],
) {
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn imp(
        m: usize,
        din: usize,
        dout: usize,
        a1: &[f32],
        w1: &[f32],
        pair: Option<(&[f32], &[f32])>,
        bias: Option<&[f32]>,
        relu: bool,
        out: &mut [f32],
    ) {
        const MR: usize = 4;
        let q_full = dout - dout % L;
        let zero = _mm256_setzero_ps();
        let mut i = 0;
        while i < m {
            let mr = (m - i).min(MR);
            let mut q0 = 0;
            while q0 < q_full {
                let init = match bias {
                    Some(b) => _mm256_loadu_ps(b.as_ptr().add(q0)),
                    None => zero,
                };
                let mut acc = [init; MR];
                match pair {
                    Some((a2, w2)) => {
                        for p in 0..din {
                            let w1v = _mm256_loadu_ps(w1.as_ptr().add(p * dout + q0));
                            let w2v = _mm256_loadu_ps(w2.as_ptr().add(p * dout + q0));
                            for (r, accr) in acc.iter_mut().enumerate().take(mr) {
                                let x1 = _mm256_set1_ps(a1[(i + r) * din + p]);
                                let x2 = _mm256_set1_ps(a2[(i + r) * din + p]);
                                *accr = _mm256_fmadd_ps(x1, w1v, *accr);
                                *accr = _mm256_fmadd_ps(x2, w2v, *accr);
                            }
                        }
                    }
                    None => {
                        for p in 0..din {
                            let w1v = _mm256_loadu_ps(w1.as_ptr().add(p * dout + q0));
                            for (r, accr) in acc.iter_mut().enumerate().take(mr) {
                                let x1 = _mm256_set1_ps(a1[(i + r) * din + p]);
                                *accr = _mm256_fmadd_ps(x1, w1v, *accr);
                            }
                        }
                    }
                }
                for (r, accr) in acc.iter().enumerate().take(mr) {
                    let v = if relu { _mm256_max_ps(*accr, zero) } else { *accr };
                    _mm256_storeu_ps(out.as_mut_ptr().add((i + r) * dout + q0), v);
                }
                q0 += L;
            }
            for q in q_full..dout {
                for r in 0..mr {
                    let mut acc = bias.map_or(0.0, |b| b[q]);
                    let a1r = &a1[(i + r) * din..(i + r + 1) * din];
                    match pair {
                        Some((a2, w2)) => {
                            let a2r = &a2[(i + r) * din..(i + r + 1) * din];
                            for p in 0..din {
                                acc += a1r[p] * w1[p * dout + q] + a2r[p] * w2[p * dout + q];
                            }
                        }
                        None => {
                            for p in 0..din {
                                acc += a1r[p] * w1[p * dout + q];
                            }
                        }
                    }
                    out[(i + r) * dout + q] = if relu { acc.max(0.0) } else { acc };
                }
            }
            i += mr;
        }
    }
    imp(m, din, dout, a1, w1, pair, bias, relu, out)
}

/// FMA twin of `dense::matmul_gx_acc`. Both `g[i,:]` and `w[p,:]` are
/// `dout`-contiguous, so each `gx[i,p]` is one vectorized dot — no
/// transpose needed.
pub unsafe fn matmul_gx_acc(
    m: usize,
    din: usize,
    dout: usize,
    g: &[f32],
    w: &[f32],
    gx: &mut [f32],
) {
    for i in 0..m {
        let grow = &g[i * dout..(i + 1) * dout];
        let gxrow = &mut gx[i * din..(i + 1) * din];
        for (p, o) in gxrow.iter_mut().enumerate() {
            *o += dot(grow, &w[p * dout..(p + 1) * dout]);
        }
    }
}

/// FMA twin of `dense::matmul_gw_acc`: rank-1 update per `(i,p)` as an
/// axpy over the contiguous `gw[p,:]` row.
pub unsafe fn matmul_gw_acc(
    m: usize,
    din: usize,
    dout: usize,
    a: &[f32],
    g: &[f32],
    gw: &mut [f32],
) {
    for i in 0..m {
        let grow = &g[i * dout..(i + 1) * dout];
        for p in 0..din {
            axpy(a[i * din + p], grow, &mut gw[p * dout..(p + 1) * dout]);
        }
    }
}

/// AVX2 twin of `gather::gather_mean`. Only `add_ps`/`mul_ps` — rounds
/// exactly like scalar, per-element slot order preserved: bit-identical to
/// the oracle.
pub unsafe fn gather_mean(
    x: &[f32],
    neigh: &[u32],
    m: usize,
    k: usize,
    din: usize,
    agg: &mut [f32],
    denoms: &mut [f32],
) {
    #[target_feature(enable = "avx2,fma")]
    unsafe fn imp(
        x: &[f32],
        neigh: &[u32],
        m: usize,
        k: usize,
        din: usize,
        agg: &mut [f32],
        denoms: &mut [f32],
    ) {
        let full = din - din % L;
        for i in 0..m {
            let arow = &mut agg[i * din..(i + 1) * din];
            arow.fill(0.0);
            let mut cnt = 0u32;
            for &v in &neigh[i * k..(i + 1) * k] {
                if v != NO_NEIGHBOR {
                    let row = &x[v as usize * din..(v as usize + 1) * din];
                    let mut p = 0;
                    while p < full {
                        let av = _mm256_loadu_ps(arow.as_ptr().add(p));
                        let rv = _mm256_loadu_ps(row.as_ptr().add(p));
                        _mm256_storeu_ps(arow.as_mut_ptr().add(p), _mm256_add_ps(av, rv));
                        p += L;
                    }
                    for p in full..din {
                        arow[p] += row[p];
                    }
                    cnt += 1;
                }
            }
            let denom = cnt.max(1) as f32;
            let inv = 1.0 / denom;
            let invv = _mm256_set1_ps(inv);
            let mut p = 0;
            while p < full {
                let av = _mm256_loadu_ps(arow.as_ptr().add(p));
                _mm256_storeu_ps(arow.as_mut_ptr().add(p), _mm256_mul_ps(av, invv));
                p += L;
            }
            for a in &mut arow[full..] {
                *a *= inv;
            }
            denoms[i] = denom;
        }
    }
    imp(x, neigh, m, k, din, agg, denoms)
}

/// FMA twin of `attn::attention_fwd`: scalar (bit-exact) softmax, FMA
/// weighted accumulate.
#[allow(clippy::too_many_arguments)]
pub unsafe fn attention_fwd(
    z: &[f32],
    s_src: &[f32],
    s_dst: &[f32],
    neigh: &[u32],
    m: usize,
    k: usize,
    dout: usize,
    bias: &[f32],
    relu: bool,
    out: &mut [f32],
) {
    let mut rows = Vec::with_capacity(k + 1);
    let mut alpha = Vec::with_capacity(k + 1);
    for i in 0..m {
        super::attn::rows_and_logits(neigh, i, k, s_src, s_dst, &mut rows, &mut alpha);
        super::attn::softmax_leaky(&mut alpha);
        let o = &mut out[i * dout..(i + 1) * dout];
        o.copy_from_slice(bias);
        for (&r, &a) in rows.iter().zip(&alpha) {
            axpy(a, &z[r * dout..(r + 1) * dout], o);
        }
        if relu {
            for v in o.iter_mut() {
                *v = v.max(0.0);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{simd_available, SIMD_REL_TOL};
    use super::*;

    fn ramp(len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|i| ((i * 37 + 11) % 97) as f32 / 97.0 * scale - scale / 2.0).collect()
    }

    #[test]
    fn simd_dot_and_axpy_match_scalar_within_tolerance() {
        if !simd_available() {
            return;
        }
        for n in [1, 7, 8, 9, 31, 64] {
            let x = ramp(n, 2.0);
            let y = ramp(n, 1.0);
            let want: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
            // SAFETY: guarded by simd_available above.
            let got = unsafe { dot(&x, &y) };
            assert!(
                (want - got).abs() <= SIMD_REL_TOL * (1.0 + want.abs()),
                "dot n={n}: {want} vs {got}"
            );
            let mut ys = y.clone();
            let mut yv = y.clone();
            for (o, &xv) in ys.iter_mut().zip(&x) {
                *o += 0.37 * xv;
            }
            // SAFETY: guarded by simd_available above.
            unsafe { axpy(0.37, &x, &mut yv) };
            for (s, v) in ys.iter().zip(&yv) {
                assert!((s - v).abs() <= SIMD_REL_TOL * (1.0 + s.abs()), "axpy n={n}");
            }
        }
    }

    #[test]
    fn simd_gather_mean_is_bit_identical_to_scalar() {
        if !simd_available() {
            return;
        }
        use super::super::{gather, KernelKind};
        let (m, k, din, n) = (5, 4, 19, 12);
        let x = ramp(n * din, 2.0);
        let neigh: Vec<u32> = (0..m * k)
            .map(|s| if s % 3 == 2 { NO_NEIGHBOR } else { (s % n) as u32 })
            .collect();
        let (mut a_s, mut d_s) = (vec![0f32; m * din], vec![0f32; m]);
        let (mut a_v, mut d_v) = (vec![0f32; m * din], vec![0f32; m]);
        gather::gather_mean(KernelKind::Scalar, &x, &neigh, m, k, din, &mut a_s, &mut d_s);
        // SAFETY: guarded by simd_available above.
        unsafe { gather_mean(&x, &neigh, m, k, din, &mut a_v, &mut d_v) };
        assert_eq!(a_s, a_v);
        assert_eq!(d_s, d_v);
    }
}
