//! One-pass GAT attention: logits → LeakyReLU → masked softmax → weighted
//! accumulate, per destination, without re-reading neighbor rows.
//!
//! The Rust port of `python/compile/kernels/gat_attn.py`'s fused scheme.
//! These kernels take the *projected* features `z = x·W` and the per-row
//! attention terms `s_src = z·a_src`, `s_dst = (z·a_dst)[:m]` (produced by
//! [`super::dense`] plus plain dots) and run the attention stage; the
//! projection VJPs are composed at the layer level in `native.rs`.
//!
//! **Numeric contract**: `blocked` is bit-identical to the scalar oracle —
//! the softmax keeps the exact scalar operation order (LeakyReLU, running
//! max, exp of the shifted logit, one divide), and the weighted accumulate
//! adds neighbor contributions in the same ascending-`j` order per output
//! element (lane-splitting the `dout` loop never reorders the additions one
//! element sees). The `simd` variant fuses the `α·z` multiply-add and
//! vectorizes the backward's `g·z` dot, so those results match within
//! [`SIMD_REL_TOL`](super::SIMD_REL_TOL); its softmax stays scalar and
//! bit-exact.

use super::KernelKind;
use crate::sampling::NO_NEIGHBOR;

/// GAT LeakyReLU slope (Velickovic et al. 2018). Must match `LEAKY_SLOPE`
/// in `native.rs`; the kernel-equivalence tests compare full layers across
/// kernel kinds, so a divergence fails loudly.
pub const LEAKY_SLOPE: f32 = 0.2;

fn leaky(v: f32) -> f32 {
    if v >= 0.0 {
        v
    } else {
        LEAKY_SLOPE * v
    }
}

/// Attention rows of destination `i`: the implicit self edge first, then
/// every real neighbor. `logits` gets the raw (pre-LeakyReLU) scores
/// `s_dst[i] + s_src[r]`. Same construction as `attention_rows` in
/// `native.rs`.
pub(super) fn rows_and_logits(
    neigh: &[u32],
    i: usize,
    k: usize,
    s_src: &[f32],
    s_dst: &[f32],
    rows: &mut Vec<usize>,
    logits: &mut Vec<f32>,
) {
    rows.clear();
    logits.clear();
    rows.push(i);
    logits.push(s_dst[i] + s_src[i]);
    for &v in &neigh[i * k..(i + 1) * k] {
        if v != NO_NEIGHBOR {
            rows.push(v as usize);
            logits.push(s_dst[i] + s_src[v as usize]);
        }
    }
}

/// Softmax of `leaky(logits)` in place, max-shifted; `logits` becomes α.
/// Exact operation order of `softmax_leaky` in `native.rs`.
pub(super) fn softmax_leaky(logits: &mut [f32]) {
    let mut mx = f32::NEG_INFINITY;
    for t in logits.iter_mut() {
        *t = leaky(*t);
        mx = mx.max(*t);
    }
    let mut sum = 0f32;
    for t in logits.iter_mut() {
        *t = (*t - mx).exp();
        sum += *t;
    }
    for t in logits.iter_mut() {
        *t /= sum;
    }
}

/// Fused attention forward for all `m` destinations:
/// `out[i,:] = act(bias + Σ_j α_ij · z[r_ij,:])` with α from the masked
/// LeakyReLU softmax over `{self} ∪ real neighbors`. `z` is `n×dout`,
/// `out` (`m×dout`) is fully overwritten.
#[allow(clippy::too_many_arguments)]
pub fn attention_fwd(
    kind: KernelKind,
    z: &[f32],
    s_src: &[f32],
    s_dst: &[f32],
    neigh: &[u32],
    m: usize,
    k: usize,
    dout: usize,
    bias: &[f32],
    relu: bool,
    out: &mut [f32],
) {
    debug_assert_eq!(neigh.len(), m * k);
    debug_assert_eq!(out.len(), m * dout);
    debug_assert_eq!(bias.len(), dout);
    debug_assert!(s_dst.len() >= m);
    match kind.resolve() {
        KernelKind::Scalar | KernelKind::Blocked => {
            // The scalar and blocked paths share this loop: the accumulate
            // is a j-outer axpy over contiguous rows, which autovectorizes;
            // blocking beyond that buys nothing because each destination's
            // working set (one α vector + one out row) already fits in
            // registers + L1. Kept as one arm so both kinds are trivially
            // bit-identical.
            let mut rows = Vec::with_capacity(k + 1);
            let mut alpha = Vec::with_capacity(k + 1);
            for i in 0..m {
                rows_and_logits(neigh, i, k, s_src, s_dst, &mut rows, &mut alpha);
                softmax_leaky(&mut alpha);
                let o = &mut out[i * dout..(i + 1) * dout];
                o.copy_from_slice(bias);
                for (&r, &a) in rows.iter().zip(&alpha) {
                    let zr = &z[r * dout..(r + 1) * dout];
                    for (ov, &zv) in o.iter_mut().zip(zr) {
                        *ov += a * zv;
                    }
                }
                if relu {
                    for v in o.iter_mut() {
                        *v = v.max(0.0);
                    }
                }
            }
        }
        KernelKind::Simd => {
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            // SAFETY: `resolve()` returns `Simd` only when AVX2+FMA were
            // detected at runtime.
            unsafe {
                super::simd::attention_fwd(z, s_src, s_dst, neigh, m, k, dout, bias, relu, out)
            }
            #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
            unreachable!("KernelKind::resolve folds simd away when unavailable")
        }
    }
}

/// Attention-stage VJP for all `m` destinations, accumulating into
/// `g_z` (`n×dout`), `g_ssrc` (`n`), `g_sdst` (`m`), and `g_b` (`dout`).
/// Recomputes α from `z`/`s_src`/`s_dst` exactly as the forward did; the
/// ReLU mask recomputes the pre-activation. Mirrors the per-destination
/// loop of `gat_bwd` in `native.rs` operation-for-operation (the `g·z`
/// dot keeps a single ascending-`q` accumulator), so `blocked` is
/// bit-identical to scalar.
#[allow(clippy::too_many_arguments)]
pub fn attention_bwd(
    kind: KernelKind,
    z: &[f32],
    s_src: &[f32],
    s_dst: &[f32],
    neigh: &[u32],
    m: usize,
    k: usize,
    dout: usize,
    bias: &[f32],
    relu: bool,
    g_out: &[f32],
    g_z: &mut [f32],
    g_ssrc: &mut [f32],
    g_sdst: &mut [f32],
    g_b: &mut [f32],
) {
    debug_assert_eq!(neigh.len(), m * k);
    debug_assert_eq!(g_out.len(), m * dout);
    debug_assert_eq!(g_b.len(), dout);
    debug_assert!(g_sdst.len() >= m);
    let simd = matches!(kind.resolve(), KernelKind::Simd);
    let mut rows = Vec::with_capacity(k + 1);
    let mut ells = Vec::with_capacity(k + 1);
    let mut alpha = Vec::with_capacity(k + 1);
    let mut g_alpha = Vec::with_capacity(k + 1);
    let mut g = vec![0f32; dout];
    let mut h = vec![0f32; dout];
    for i in 0..m {
        rows_and_logits(neigh, i, k, s_src, s_dst, &mut rows, &mut ells);
        alpha.clear();
        alpha.extend_from_slice(&ells);
        softmax_leaky(&mut alpha);
        g.copy_from_slice(&g_out[i * dout..(i + 1) * dout]);
        if relu {
            // Recompute h_pre = bias + Σ α z for the mask. j-outer order:
            // each h element still accumulates in ascending j, matching the
            // scalar reference's per-q inner loop bit-for-bit.
            h.copy_from_slice(bias);
            for (&r, &a) in rows.iter().zip(&alpha) {
                let zr = &z[r * dout..(r + 1) * dout];
                if simd {
                    axpy(a, zr, &mut h);
                } else {
                    for (hv, &zv) in h.iter_mut().zip(zr) {
                        *hv += a * zv;
                    }
                }
            }
            for (gq, &hv) in g.iter_mut().zip(&h) {
                if hv <= 0.0 {
                    *gq = 0.0;
                }
            }
        }
        for (b, &gq) in g_b.iter_mut().zip(&g) {
            *b += gq;
        }
        // out_i = Σ_j α_j z[r_j]:  g_α_j = g · z[r_j],  g_z[r_j] += α_j g.
        g_alpha.clear();
        for (&r, &a) in rows.iter().zip(&alpha) {
            let zr = &z[r * dout..(r + 1) * dout];
            let grow = &mut g_z[r * dout..(r + 1) * dout];
            let d = if simd {
                let d = dot(&g, zr);
                axpy(a, &g, grow);
                d
            } else {
                let mut d = 0f32;
                for q in 0..dout {
                    d += g[q] * zr[q];
                }
                for (gv, &gq) in grow.iter_mut().zip(&g) {
                    *gv += a * gq;
                }
                d
            };
            g_alpha.push(d);
        }
        // Softmax VJP: g_t_j = α_j (g_α_j − Σ_l α_l g_α_l), then the
        // LeakyReLU VJP on the raw logit ℓ_j.
        let s: f32 = alpha.iter().zip(&g_alpha).map(|(a, ga)| a * ga).sum();
        for ((&a, &ga), (&ell, &r)) in alpha.iter().zip(&g_alpha).zip(ells.iter().zip(&rows)) {
            let slope = if ell >= 0.0 { 1.0 } else { LEAKY_SLOPE };
            let g_ell = a * (ga - s) * slope;
            g_sdst[i] += g_ell;
            g_ssrc[r] += g_ell;
        }
    }
}

/// `y += a·x`, dispatched to FMA when the simd path is active.
fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if super::simd_available() {
        // SAFETY: AVX2+FMA detected.
        unsafe { super::simd::axpy(a, x, y) };
        return;
    }
    for (yv, &xv) in y.iter_mut().zip(x) {
        *yv += a * xv;
    }
}

/// `Σ x·y`, dispatched to a lane-parallel FMA reduction when the simd path
/// is active (reassociates; tolerance-gated per the module contract).
fn dot(x: &[f32], y: &[f32]) -> f32 {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if super::simd_available() {
        // SAFETY: AVX2+FMA detected.
        return unsafe { super::simd::dot(x, y) };
    }
    x.iter().zip(y).map(|(a, b)| a * b).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    const NB: u32 = NO_NEIGHBOR;

    fn ramp(len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|i| ((i * 37 + 11) % 97) as f32 / 97.0 * scale - scale / 2.0).collect()
    }

    #[test]
    fn blocked_attention_is_bit_identical_to_scalar() {
        let (n, m, k, dout) = (9, 4, 3, 7);
        let z = ramp(n * dout, 2.0);
        let s_src = ramp(n, 1.0);
        let s_dst = ramp(m, 1.0);
        let bias = ramp(dout, 0.3);
        let neigh = [4, 5, NB, 6, NB, NB, NB, NB, NB, 7, 8, 4];
        let g_out = ramp(m * dout, 1.0);
        for relu in [false, true] {
            let mut o_s = vec![0f32; m * dout];
            let mut o_b = vec![3f32; m * dout];
            attention_fwd(
                KernelKind::Scalar, &z, &s_src, &s_dst, &neigh, m, k, dout, &bias, relu, &mut o_s,
            );
            attention_fwd(
                KernelKind::Blocked, &z, &s_src, &s_dst, &neigh, m, k, dout, &bias, relu, &mut o_b,
            );
            assert_eq!(o_s, o_b, "relu={relu}");

            let mk = |_| (vec![0f32; n * dout], vec![0f32; n], vec![0f32; m], vec![0f32; dout]);
            let (mut gz_s, mut gs_s, mut gd_s, mut gb_s) = mk(());
            let (mut gz_b, mut gs_b, mut gd_b, mut gb_b) = mk(());
            attention_bwd(
                KernelKind::Scalar, &z, &s_src, &s_dst, &neigh, m, k, dout, &bias, relu, &g_out,
                &mut gz_s, &mut gs_s, &mut gd_s, &mut gb_s,
            );
            attention_bwd(
                KernelKind::Blocked, &z, &s_src, &s_dst, &neigh, m, k, dout, &bias, relu, &g_out,
                &mut gz_b, &mut gs_b, &mut gd_b, &mut gb_b,
            );
            assert_eq!(gz_s, gz_b, "relu={relu}");
            assert_eq!(gs_s, gs_b);
            assert_eq!(gd_s, gd_b);
            assert_eq!(gb_s, gb_b);
        }
    }

    #[test]
    fn isolated_destination_attends_to_self_only() {
        let (n, m, k, dout) = (2, 1, 3, 2);
        let z = vec![1.0, -2.0, 5.0, 5.0];
        let bias = vec![0.25, 0.25];
        let mut out = vec![0f32; m * dout];
        attention_fwd(
            KernelKind::Blocked,
            &z,
            &[0.3, 0.9],
            &[0.1],
            &[NB, NB, NB],
            m,
            k,
            dout,
            &bias,
            false,
            &mut out,
        );
        // α collapses onto the self edge: out = z[0] + bias.
        assert_eq!(out, vec![1.25, -1.75]);
    }
}
