//! Cache-blocked, vectorizable compute kernels for [`NativeBackend`].
//!
//! This module ports the blocking/padding scheme of the Pallas kernels
//! (`python/compile/kernels/gather_mean.py` / `gat_attn.py`, DESIGN.md
//! §Hardware-Adaptation) to the Rust backend, per DESIGN.md §Perf "Rust
//! kernel blocking":
//!
//! * [`dense`] — register-blocked, tiled dense transforms (the `x·W`
//!   halves of GraphSage and GAT) and their VJPs,
//! * [`gather`] — destination-tiled masked gather-mean aggregation fusing
//!   the neighbor reduce with the `1/max(count,1)` scale,
//! * [`attn`] — one-pass GAT attention: logits → LeakyReLU → masked
//!   softmax → weighted accumulate, without re-reading neighbor rows,
//! * [`simd`] (cargo feature `simd`, `x86_64` only) — `std::arch`
//!   AVX2/FMA inner loops behind runtime feature detection.
//!
//! Three variants are selectable per [`KernelKind`], overridden at runtime
//! with `GSPLIT_KERNELS=scalar|blocked|simd` for A/B testing:
//!
//! | kind      | inner loops | numeric contract vs the scalar oracle |
//! |-----------|-------------|----------------------------------------|
//! | `scalar`  | the original straight loops in `runtime/native.rs` | **is** the oracle |
//! | `blocked` | fixed-width-lane blocked scalar code (autovectorizes) | **bit-identical** (per-element accumulation order preserved by construction) |
//! | `simd`    | AVX2 + FMA intrinsics | bit-identical for gather-mean; dense transforms and attention accumulates fuse multiply-add and reassociate dot reductions, so they match within [`SIMD_REL_TOL`] |
//!
//! The `blocked` bit-identity contract is what keeps the golden and
//! finite-difference tests in `native.rs` bit-level, and is enforced (with
//! the tolerance-gated `simd` comparison) by
//! `rust/tests/kernel_equivalence.rs`. The serial and pipelined executors
//! remain bit-identical *to each other* under every kernel choice because
//! the choice is per-backend-instance and per-device compute is
//! self-contained (DESIGN.md §Executor).

pub mod attn;
pub mod dense;
pub mod gather;

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub mod simd;

use std::sync::OnceLock;

use anyhow::bail;

use crate::Result;

/// Relative tolerance for comparing `simd` kernel outputs against the
/// scalar oracle where the contract relaxes bit-identity (FMA fuses the
/// multiply-add rounding step; lane-parallel dot reductions reassociate).
/// Per element the error is bounded by `terms × ulp`; test shapes keep
/// `din, dout ≤ 96` and inputs O(1), so 1e-4 × (1 + |oracle|) is ~3
/// decimal orders above the worst case while still catching real bugs.
pub const SIMD_REL_TOL: f32 = 1e-4;

/// Which inner-loop implementation the backend dispatches to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelKind {
    /// The original straight scalar loops — the reference oracle.
    Scalar,
    /// Register-blocked / tiled scalar code that autovectorizes.
    /// Bit-identical to `Scalar` by construction.
    Blocked,
    /// AVX2/FMA intrinsics (`--features simd`, runtime-detected).
    Simd,
}

impl KernelKind {
    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Scalar => "scalar",
            KernelKind::Blocked => "blocked",
            KernelKind::Simd => "simd",
        }
    }

    pub fn parse(s: &str) -> Result<KernelKind> {
        match s {
            "scalar" => Ok(KernelKind::Scalar),
            "blocked" => Ok(KernelKind::Blocked),
            "simd" => Ok(KernelKind::Simd),
            other => bail!("unknown kernel kind `{other}` (scalar|blocked|simd)"),
        }
    }

    /// Every kind, for sweeps (benches, property tests). `Simd` is
    /// included even when unavailable; [`KernelKind::resolve`] then folds
    /// it back to `Blocked`.
    pub fn all() -> [KernelKind; 3] {
        [KernelKind::Scalar, KernelKind::Blocked, KernelKind::Simd]
    }

    /// Fold an unavailable choice onto the best available one: `Simd`
    /// degrades to `Blocked` when the `simd` feature is not compiled in or
    /// the CPU lacks AVX2+FMA. `Scalar`/`Blocked` are always available.
    pub fn resolve(self) -> KernelKind {
        match self {
            KernelKind::Simd if !simd_available() => KernelKind::Blocked,
            k => k,
        }
    }

    /// The kernel choice for this process: `GSPLIT_KERNELS` if set (an
    /// invalid value warns once and is ignored), else `Blocked` — the
    /// fastest kind whose numerics are machine-independent. `simd` is
    /// opt-in because FMA results differ per microarchitecture, and the
    /// repo's defaults are reproducible everywhere.
    pub fn from_env() -> KernelKind {
        static CHOICE: OnceLock<KernelKind> = OnceLock::new();
        *CHOICE.get_or_init(|| {
            let requested = match std::env::var("GSPLIT_KERNELS") {
                Ok(v) => match KernelKind::parse(&v) {
                    Ok(k) => k,
                    Err(e) => {
                        eprintln!("[gsplit] ignoring GSPLIT_KERNELS: {e}");
                        KernelKind::Blocked
                    }
                },
                Err(_) => KernelKind::Blocked,
            };
            let resolved = requested.resolve();
            if resolved != requested {
                eprintln!(
                    "[gsplit] GSPLIT_KERNELS={} unavailable (feature `simd` compiled: {}, \
                     AVX2+FMA detected: {}); falling back to `{}`",
                    requested.name(),
                    simd_compiled(),
                    simd_available(),
                    resolved.name()
                );
            }
            resolved
        })
    }
}

/// Whether the `simd` cargo feature (and the x86_64 target) was compiled.
pub fn simd_compiled() -> bool {
    cfg!(all(feature = "simd", target_arch = "x86_64"))
}

/// Whether the AVX2/FMA path is usable at runtime: compiled in *and* the
/// host CPU reports both features.
pub fn simd_available() -> bool {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
    }
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_names() {
        for k in KernelKind::all() {
            assert_eq!(KernelKind::parse(k.name()).unwrap(), k);
        }
        assert!(KernelKind::parse("avx512").is_err());
    }

    #[test]
    fn resolve_folds_unavailable_simd() {
        assert_eq!(KernelKind::Scalar.resolve(), KernelKind::Scalar);
        assert_eq!(KernelKind::Blocked.resolve(), KernelKind::Blocked);
        let r = KernelKind::Simd.resolve();
        if simd_available() {
            assert_eq!(r, KernelKind::Simd);
        } else {
            assert_eq!(r, KernelKind::Blocked);
        }
    }

    #[test]
    fn simd_available_implies_compiled() {
        if simd_available() {
            assert!(simd_compiled());
        }
    }
}
