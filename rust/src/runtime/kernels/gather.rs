//! Destination-tiled masked gather-mean aggregation.
//!
//! The Rust port of `python/compile/kernels/gather_mean.py`: for each
//! destination vertex, sum the feature rows of its real sampled neighbors
//! (slots equal to [`NO_NEIGHBOR`] are padding) and scale by
//! `1/max(count, 1)`, fusing the reduce with the scale in one pass over the
//! neighbor rows. Unlike the per-row `aggregate_row` helper in `native.rs`,
//! this materializes the whole `m×din` aggregate matrix in one call — which
//! is what lets the fast GraphSage path replace `m` rank-1 updates with one
//! register-blocked dense transform (see [`super::dense`]).
//!
//! **Bit-identity contract**: every variant — including `simd` — is
//! bit-identical to the scalar oracle. Each output element receives plain
//! additions in ascending slot order followed by one multiply by the
//! reciprocal count; lane-splitting an elementwise add never reorders the
//! additions *a single element* sees, and AVX2 `add_ps`/`mul_ps` round
//! exactly like their scalar counterparts.

use super::KernelKind;
use crate::sampling::NO_NEIGHBOR;

/// Destination rows per tile. Matches the spirit of `BLOCK_M` in
/// `gather_mean.py` scaled to CPU cache lines: 8 destination rows of
/// accumulators stay L1-resident for typical `din ≤ 1024`.
pub const BM: usize = 8;

/// Masked mean over sampled neighbors for all `m` destinations.
///
/// `x` is `n×din` (only rows referenced by `neigh` are read), `neigh` is
/// `m×k` with [`NO_NEIGHBOR`] padding, `agg` (`m×din`) and `denoms` (`m`)
/// are fully overwritten; `denoms[i] = max(real_count(i), 1)` — the divisor
/// the mean actually used, which the GraphSage backward needs to scale the
/// scattered gradient.
#[allow(clippy::too_many_arguments)]
pub fn gather_mean(
    kind: KernelKind,
    x: &[f32],
    neigh: &[u32],
    m: usize,
    k: usize,
    din: usize,
    agg: &mut [f32],
    denoms: &mut [f32],
) {
    debug_assert_eq!(neigh.len(), m * k);
    debug_assert_eq!(agg.len(), m * din);
    debug_assert_eq!(denoms.len(), m);
    match kind.resolve() {
        KernelKind::Scalar => {
            for i in 0..m {
                denoms[i] = row_scalar(x, neigh, i, k, din, &mut agg[i * din..(i + 1) * din]);
            }
        }
        KernelKind::Blocked => {
            // Destination tiles: the BM rows of accumulators written by one
            // tile stay cache-resident while their (random) neighbor rows
            // stream through. Per element the additions still run in
            // ascending slot order — bit-identical to scalar.
            let mut i0 = 0;
            while i0 < m {
                let ie = (i0 + BM).min(m);
                for i in i0..ie {
                    denoms[i] = row_scalar(x, neigh, i, k, din, &mut agg[i * din..(i + 1) * din]);
                }
                i0 = ie;
            }
        }
        KernelKind::Simd => {
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            // SAFETY: `resolve()` returns `Simd` only when AVX2+FMA were
            // detected at runtime.
            unsafe {
                super::simd::gather_mean(x, neigh, m, k, din, agg, denoms)
            }
            #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
            unreachable!("KernelKind::resolve folds simd away when unavailable")
        }
    }
}

/// One destination row: zero, sum real neighbor rows in slot order, scale
/// by `1/max(count,1)`. Returns the divisor. Same operation order as
/// `aggregate_row` in `native.rs`.
fn row_scalar(x: &[f32], neigh: &[u32], i: usize, k: usize, din: usize, agg: &mut [f32]) -> f32 {
    agg.fill(0.0);
    let mut cnt = 0u32;
    for &v in &neigh[i * k..(i + 1) * k] {
        if v != NO_NEIGHBOR {
            let row = &x[v as usize * din..(v as usize + 1) * din];
            for (a, &b) in agg.iter_mut().zip(row) {
                *a += b;
            }
            cnt += 1;
        }
    }
    let denom = cnt.max(1) as f32;
    let inv = 1.0 / denom;
    for a in agg.iter_mut() {
        *a *= inv;
    }
    denom
}

#[cfg(test)]
mod tests {
    use super::*;

    const NB: u32 = NO_NEIGHBOR;

    fn ramp(len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|i| ((i * 37 + 11) % 97) as f32 / 97.0 * scale - scale / 2.0).collect()
    }

    #[test]
    fn blocked_is_bit_identical_to_scalar() {
        // m spans tile boundaries; neigh mixes real slots, padding, an
        // all-padded (isolated) row, and repeated neighbors.
        let (m, k, din, n) = (11, 3, 13, 20);
        let x = ramp(n * din, 2.0);
        let mut neigh = vec![NB; m * k];
        for i in 0..m {
            for j in 0..k {
                if i != 4 && (i + j) % 3 != 2 {
                    neigh[i * k + j] = ((m + i + 2 * j) % n) as u32;
                }
            }
        }
        let (mut a_s, mut d_s) = (vec![0f32; m * din], vec![0f32; m]);
        let (mut a_b, mut d_b) = (vec![9f32; m * din], vec![9f32; m]);
        gather_mean(KernelKind::Scalar, &x, &neigh, m, k, din, &mut a_s, &mut d_s);
        gather_mean(KernelKind::Blocked, &x, &neigh, m, k, din, &mut a_b, &mut d_b);
        assert_eq!(a_s, a_b);
        assert_eq!(d_s, d_b);
        // The isolated row aggregated to zeros with divisor 1.
        assert!(a_s[4 * din..5 * din].iter().all(|&v| v == 0.0));
        assert_eq!(d_s[4], 1.0);
    }

    #[test]
    fn k_zero_gives_zero_aggregates() {
        let (m, din) = (3, 5);
        let x = ramp(m * din, 1.0);
        let mut agg = vec![7f32; m * din];
        let mut den = vec![0f32; m];
        gather_mean(KernelKind::Blocked, &x, &[], m, 0, din, &mut agg, &mut den);
        assert!(agg.iter().all(|&v| v == 0.0));
        assert!(den.iter().all(|&v| v == 1.0));
    }
}
