//! Register-blocked, tiled dense transforms and their VJPs.
//!
//! These are the `x·W` halves of GraphSage and GAT (DESIGN.md §Perf "Rust
//! kernel blocking"). Every entry point takes a [`KernelKind`] and
//! dispatches between the scalar reference loops, the register-blocked
//! autovectorizable loops, and (when compiled + detected) the AVX2/FMA
//! path.
//!
//! Blocking scheme (`blocked`):
//!
//! * forward ([`dense_bias_act`]): `MR×NR` register tiles — `MR = 4`
//!   destination rows × `NR = 8` output columns held in accumulators for
//!   the whole `din` reduction, so the output tile is written once instead
//!   of once per `p`, and each weight row is loaded once per 4 rows.
//! * input VJP ([`matmul_gx_acc`]): the weight matrix is transposed once
//!   per call, turning the per-element dot product into a q-outer saxpy
//!   that streams `din`-contiguous rows; q is chunked by 8 so the hot
//!   transposed panel stays in L1 across all `m` rows.
//! * weight VJP ([`matmul_gw_acc`]): destination rows are tiled by 8 so
//!   the `din×dout` gradient matrix is streamed once per tile rather than
//!   once per row.
//!
//! **Bit-identity contract**: for every element, the `blocked` variants
//! perform the same additions in the same order as the scalar reference
//! (accumulation runs over the reduction index in ascending order from the
//! same starting value; tiling only reorders *independent* elements), so
//! `blocked` output is bit-identical to `scalar`. The `simd` variants fuse
//! multiply-adds (FMA), which skips one rounding per term — they match
//! within [`SIMD_REL_TOL`](super::SIMD_REL_TOL) instead.

use super::KernelKind;

/// Output-column lanes per register tile (one AVX2 vector of f32).
pub const NR: usize = 8;
/// Destination rows per register tile.
pub const MR: usize = 4;

/// `out[i,:] = act(start + a1[i,:]·w1 (+ a2[i,:]·w2))` for `i < m`, where
/// `start` is `bias` (broadcast row) or zero, and `act` is ReLU when
/// `relu` is set. `a1`/`a2` are `m×din` row-major, `w1`/`w2` `din×dout`,
/// `out` `m×dout` (fully overwritten).
#[allow(clippy::too_many_arguments)]
pub fn dense_bias_act(
    kind: KernelKind,
    m: usize,
    din: usize,
    dout: usize,
    a1: &[f32],
    w1: &[f32],
    pair: Option<(&[f32], &[f32])>,
    bias: Option<&[f32]>,
    relu: bool,
    out: &mut [f32],
) {
    debug_assert_eq!(a1.len(), m * din);
    debug_assert_eq!(w1.len(), din * dout);
    debug_assert_eq!(out.len(), m * dout);
    if let Some((a2, w2)) = pair {
        debug_assert_eq!(a2.len(), m * din);
        debug_assert_eq!(w2.len(), din * dout);
    }
    if let Some(b) = bias {
        debug_assert_eq!(b.len(), dout);
    }
    match kind.resolve() {
        KernelKind::Scalar => dense_scalar(m, din, dout, a1, w1, pair, bias, relu, out),
        KernelKind::Blocked => dense_blocked(m, din, dout, a1, w1, pair, bias, relu, out),
        KernelKind::Simd => {
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            // SAFETY: `resolve()` returns `Simd` only when AVX2+FMA were
            // detected at runtime.
            unsafe {
                super::simd::dense_bias_act(m, din, dout, a1, w1, pair, bias, relu, out)
            }
            #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
            unreachable!("KernelKind::resolve folds simd away when unavailable")
        }
    }
}

/// `gx[i,p] += Σ_q g[i,q]·w[p,q]` — the input-side VJP `g · Wᵀ`,
/// accumulated into `gx` (`m×din`). `g` is `m×dout`, `w` `din×dout`.
pub fn matmul_gx_acc(
    kind: KernelKind,
    m: usize,
    din: usize,
    dout: usize,
    g: &[f32],
    w: &[f32],
    gx: &mut [f32],
) {
    debug_assert_eq!(g.len(), m * dout);
    debug_assert_eq!(w.len(), din * dout);
    debug_assert_eq!(gx.len(), m * din);
    match kind.resolve() {
        KernelKind::Scalar => {
            for i in 0..m {
                let grow = &g[i * dout..(i + 1) * dout];
                let gxrow = &mut gx[i * din..(i + 1) * din];
                for (p, o) in gxrow.iter_mut().enumerate() {
                    let mut s = 0f32;
                    for (q, &gq) in grow.iter().enumerate() {
                        s += gq * w[p * dout + q];
                    }
                    *o += s;
                }
            }
        }
        KernelKind::Blocked => gx_blocked(m, din, dout, g, w, gx),
        KernelKind::Simd => {
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            // SAFETY: `resolve()` returns `Simd` only when AVX2+FMA were
            // detected at runtime.
            unsafe {
                super::simd::matmul_gx_acc(m, din, dout, g, w, gx)
            }
            #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
            unreachable!("KernelKind::resolve folds simd away when unavailable")
        }
    }
}

/// `gw[p,q] += Σ_i a[i,p]·g[i,q]` — the weight-side VJP `Aᵀ · g`,
/// accumulated into `gw` (`din×dout`) with `i` ascending per element (the
/// serial accumulation order of the scalar backward passes).
pub fn matmul_gw_acc(
    kind: KernelKind,
    m: usize,
    din: usize,
    dout: usize,
    a: &[f32],
    g: &[f32],
    gw: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * din);
    debug_assert_eq!(g.len(), m * dout);
    debug_assert_eq!(gw.len(), din * dout);
    match kind.resolve() {
        KernelKind::Scalar => {
            for i in 0..m {
                let grow = &g[i * dout..(i + 1) * dout];
                for p in 0..din {
                    let av = a[i * din + p];
                    let gwrow = &mut gw[p * dout..(p + 1) * dout];
                    for (o, &gv) in gwrow.iter_mut().zip(grow) {
                        *o += av * gv;
                    }
                }
            }
        }
        KernelKind::Blocked => gw_blocked(m, din, dout, a, g, gw),
        KernelKind::Simd => {
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            // SAFETY: `resolve()` returns `Simd` only when AVX2+FMA were
            // detected at runtime.
            unsafe {
                super::simd::matmul_gw_acc(m, din, dout, a, g, gw)
            }
            #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
            unreachable!("KernelKind::resolve folds simd away when unavailable")
        }
    }
}

// ---------------------------------------------------------------------------
// Scalar reference (the exact loop order of the original native.rs code)
// ---------------------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn dense_scalar(
    m: usize,
    din: usize,
    dout: usize,
    a1: &[f32],
    w1: &[f32],
    pair: Option<(&[f32], &[f32])>,
    bias: Option<&[f32]>,
    relu: bool,
    out: &mut [f32],
) {
    for i in 0..m {
        let o = &mut out[i * dout..(i + 1) * dout];
        match bias {
            Some(b) => o.copy_from_slice(b),
            None => o.fill(0.0),
        }
        let a1r = &a1[i * din..(i + 1) * din];
        match pair {
            Some((a2, w2)) => {
                let a2r = &a2[i * din..(i + 1) * din];
                for p in 0..din {
                    let (x1, x2) = (a1r[p], a2r[p]);
                    let w1row = &w1[p * dout..(p + 1) * dout];
                    let w2row = &w2[p * dout..(p + 1) * dout];
                    for q in 0..dout {
                        o[q] += x1 * w1row[q] + x2 * w2row[q];
                    }
                }
            }
            None => {
                for p in 0..din {
                    let x1 = a1r[p];
                    let w1row = &w1[p * dout..(p + 1) * dout];
                    for q in 0..dout {
                        o[q] += x1 * w1row[q];
                    }
                }
            }
        }
        if relu {
            for v in o.iter_mut() {
                *v = v.max(0.0);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Blocked implementations
// ---------------------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn dense_blocked(
    m: usize,
    din: usize,
    dout: usize,
    a1: &[f32],
    w1: &[f32],
    pair: Option<(&[f32], &[f32])>,
    bias: Option<&[f32]>,
    relu: bool,
    out: &mut [f32],
) {
    let mut i = 0;
    while i < m {
        let mr = (m - i).min(MR);
        // Full NR-wide column tiles, then a scalar column tail. Per output
        // element the reduction still runs p = 0..din in ascending order
        // from the bias, so every element is bit-identical to the scalar
        // reference.
        let q_full = dout - dout % NR;
        let mut q0 = 0;
        while q0 < q_full {
            let mut acc = [[0f32; NR]; MR];
            for (r, accr) in acc.iter_mut().enumerate().take(mr) {
                for (l, v) in accr.iter_mut().enumerate() {
                    *v = bias.map_or(0.0, |b| b[q0 + l]);
                }
            }
            match pair {
                Some((a2, w2)) => {
                    for p in 0..din {
                        let w1row = &w1[p * dout + q0..p * dout + q0 + NR];
                        let w2row = &w2[p * dout + q0..p * dout + q0 + NR];
                        for (r, accr) in acc.iter_mut().enumerate().take(mr) {
                            let x1 = a1[(i + r) * din + p];
                            let x2 = a2[(i + r) * din + p];
                            for l in 0..NR {
                                accr[l] += x1 * w1row[l] + x2 * w2row[l];
                            }
                        }
                    }
                }
                None => {
                    for p in 0..din {
                        let w1row = &w1[p * dout + q0..p * dout + q0 + NR];
                        for (r, accr) in acc.iter_mut().enumerate().take(mr) {
                            let x1 = a1[(i + r) * din + p];
                            for l in 0..NR {
                                accr[l] += x1 * w1row[l];
                            }
                        }
                    }
                }
            }
            for (r, accr) in acc.iter().enumerate().take(mr) {
                let orow = &mut out[(i + r) * dout + q0..(i + r) * dout + q0 + NR];
                for (o, &v) in orow.iter_mut().zip(accr) {
                    *o = if relu { v.max(0.0) } else { v };
                }
            }
            q0 += NR;
        }
        for q in q_full..dout {
            for r in 0..mr {
                let mut acc = bias.map_or(0.0, |b| b[q]);
                let a1r = &a1[(i + r) * din..(i + r + 1) * din];
                match pair {
                    Some((a2, w2)) => {
                        let a2r = &a2[(i + r) * din..(i + r + 1) * din];
                        for p in 0..din {
                            acc += a1r[p] * w1[p * dout + q] + a2r[p] * w2[p * dout + q];
                        }
                    }
                    None => {
                        for p in 0..din {
                            acc += a1r[p] * w1[p * dout + q];
                        }
                    }
                }
                out[(i + r) * dout + q] = if relu { acc.max(0.0) } else { acc };
            }
        }
        i += mr;
    }
}

/// The transpose turns the per-element dot product into a q-outer saxpy
/// over `din`-contiguous rows of `wt`, which autovectorizes; the q-chunking
/// keeps the hot transposed panel resident in L1 across all `m` rows.
fn gx_blocked(m: usize, din: usize, dout: usize, g: &[f32], w: &[f32], gx: &mut [f32]) {
    // wt[q*din + p] = w[p*dout + q]
    let mut wt = vec![0f32; din * dout];
    for p in 0..din {
        for q in 0..dout {
            wt[q * din + p] = w[p * dout + q];
        }
    }
    // Accumulate into a zeroed temporary so each gx element receives one
    // final `+=` of the complete q-ordered sum — the scalar order.
    let mut tmp = vec![0f32; m * din];
    const QB: usize = 8;
    let mut q0 = 0;
    while q0 < dout {
        let qe = (q0 + QB).min(dout);
        for i in 0..m {
            let trow = &mut tmp[i * din..(i + 1) * din];
            for q in q0..qe {
                let gq = g[i * dout + q];
                let wtrow = &wt[q * din..(q + 1) * din];
                for (t, &wv) in trow.iter_mut().zip(wtrow) {
                    *t += gq * wv;
                }
            }
        }
        q0 = qe;
    }
    for (o, &t) in gx.iter_mut().zip(&tmp) {
        *o += t;
    }
}

fn gw_blocked(m: usize, din: usize, dout: usize, a: &[f32], g: &[f32], gw: &mut [f32]) {
    const IB: usize = 8;
    let mut i0 = 0;
    while i0 < m {
        let ie = (i0 + IB).min(m);
        for p in 0..din {
            let gwrow = &mut gw[p * dout..(p + 1) * dout];
            for i in i0..ie {
                let av = a[i * din + p];
                let grow = &g[i * dout..(i + 1) * dout];
                for (o, &gv) in gwrow.iter_mut().zip(grow) {
                    *o += av * gv;
                }
            }
        }
        i0 = ie;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ramp(len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|i| ((i * 37 + 11) % 97) as f32 / 97.0 * scale - scale / 2.0).collect()
    }

    /// Shapes deliberately not multiples of MR/NR, plus degenerate ones.
    const SHAPES: [(usize, usize, usize); 6] =
        [(1, 1, 1), (3, 5, 7), (4, 8, 8), (9, 13, 17), (2, 16, 9), (7, 6, 24)];

    #[test]
    fn blocked_is_bit_identical_to_scalar() {
        for &(m, din, dout) in &SHAPES {
            let a1 = ramp(m * din, 2.0);
            let a2 = ramp(m * din, 1.5);
            let w1 = ramp(din * dout, 1.0);
            let w2 = ramp(din * dout, 0.7);
            let bias = ramp(dout, 0.3);
            for pair in [None, Some((&a2[..], &w2[..]))] {
                for bias_opt in [None, Some(&bias[..])] {
                    for relu in [false, true] {
                        let mut o_s = vec![0f32; m * dout];
                        let mut o_b = vec![7f32; m * dout]; // junk: must be overwritten
                        dense_bias_act(
                            KernelKind::Scalar,
                            m,
                            din,
                            dout,
                            &a1,
                            &w1,
                            pair,
                            bias_opt,
                            relu,
                            &mut o_s,
                        );
                        dense_bias_act(
                            KernelKind::Blocked,
                            m,
                            din,
                            dout,
                            &a1,
                            &w1,
                            pair,
                            bias_opt,
                            relu,
                            &mut o_b,
                        );
                        assert_eq!(o_s, o_b, "m={m} din={din} dout={dout} relu={relu}");
                    }
                }
            }
        }
    }

    #[test]
    fn gx_blocked_is_bit_identical_to_scalar() {
        for &(m, din, dout) in &SHAPES {
            let g = ramp(m * dout, 2.0);
            let w = ramp(din * dout, 1.0);
            let seed = ramp(m * din, 0.1);
            let (mut gx_s, mut gx_b) = (seed.clone(), seed);
            matmul_gx_acc(KernelKind::Scalar, m, din, dout, &g, &w, &mut gx_s);
            matmul_gx_acc(KernelKind::Blocked, m, din, dout, &g, &w, &mut gx_b);
            assert_eq!(gx_s, gx_b, "m={m} din={din} dout={dout}");
        }
    }

    #[test]
    fn gw_blocked_is_bit_identical_to_scalar() {
        for &(m, din, dout) in &SHAPES {
            let a = ramp(m * din, 2.0);
            let g = ramp(m * dout, 1.0);
            let seed = ramp(din * dout, 0.1);
            let (mut gw_s, mut gw_b) = (seed.clone(), seed);
            matmul_gw_acc(KernelKind::Scalar, m, din, dout, &a, &g, &mut gw_s);
            matmul_gw_acc(KernelKind::Blocked, m, din, dout, &a, &g, &mut gw_b);
            assert_eq!(gw_s, gw_b, "m={m} din={din} dout={dout}");
        }
    }

    #[test]
    fn empty_m_is_a_noop() {
        let w = ramp(4 * 3, 1.0);
        let mut out: Vec<f32> = vec![];
        dense_bias_act(KernelKind::Blocked, 0, 4, 3, &[], &w, None, None, false, &mut out);
        let mut gx: Vec<f32> = vec![];
        matmul_gx_acc(KernelKind::Blocked, 0, 4, 3, &[], &w, &mut gx);
        let mut gw = vec![0f32; 12];
        matmul_gw_acc(KernelKind::Blocked, 0, 4, 3, &[], &[], &mut gw);
        assert!(gw.iter().all(|&v| v == 0.0));
    }
}
