//! PJRT runtime bridge (requires the `pjrt` cargo feature): loads the AOT
//! HLO-text artifacts produced by `python/compile/aot.py` and executes them
//! from the Rust hot path.
//!
//! * [`Manifest`] — parses `artifacts/manifest.json` (shape buckets, layer
//!   dims, fanout) so Rust *reads* the compile-time contract instead of
//!   assuming it.
//! * [`Runtime`] — one PJRT CPU client plus a lazily-compiled executable
//!   cache; exposes typed entry points for layer forward/backward and the
//!   loss head, handling all padding to the static AOT shapes. Implements
//!   [`Backend`], so the trainer uses it interchangeably with
//!   `NativeBackend`.
//!
//! Interchange is HLO **text** (`HloModuleProto::from_text_file`): the
//! crate's xla_extension 0.5.1 rejects jax≥0.5 serialized protos (64-bit
//! instruction ids) but the text parser reassigns ids cleanly.
//!
//! The default build links the in-tree `xla` API stub (compiles anywhere,
//! fails at `Runtime::load` with instructions); swap in the real xla-rs
//! crate to execute artifacts — see README.md "PJRT backend".

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::{ArtifactMeta, Manifest};
use super::tensors::{lit_f32, lit_i32, to_vec_f32};
use super::{Backend, LayerGrads, LossOut};
use crate::model::{GnnKind, LayerParams};
use crate::sampling::NO_NEIGHBOR;

pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Load the manifest and create the PJRT CPU client. Executables are
    /// compiled lazily, on first use, and cached for the process lifetime.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {dir:?} (run `make artifacts`)"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e}"))?;
        Ok(Runtime { client, manifest, dir, cache: Mutex::new(HashMap::new()) })
    }

    /// The model shape the exported artifacts were compiled for.
    pub fn model_config(&self, kind: GnnKind) -> crate::model::ModelConfig {
        crate::model::ModelConfig {
            kind,
            feat_dim: self.manifest.feat_dim,
            hidden: self.manifest.hidden,
            num_classes: self.manifest.num_classes,
            num_layers: self.manifest.layer_dims.len(),
        }
    }

    fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let meta = self
            .manifest
            .by_name(name)
            .ok_or_else(|| anyhow!("artifact `{name}` not in manifest"))?;
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow!("compile {name}: {e}"))?;
        let exe = std::sync::Arc::new(exe);
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Number of executables compiled so far (diagnostics).
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Pick the layer artifact for `m_real` destination rows (the smallest
    /// bucket that fits; see aot.py for why N = M·(K+1) then also fits).
    fn pick_layer(
        &self,
        kind: &str,
        model: GnnKind,
        din: usize,
        dout: usize,
        relu: bool,
        m_real: usize,
        n_real: usize,
    ) -> Result<&ArtifactMeta> {
        let k = self.manifest.kernel_fanout;
        let m_need = m_real.max(n_real.div_ceil(k + 1));
        self.manifest
            .pick_layer(kind, model, din, dout, relu, m_need)
            .ok_or_else(|| {
                anyhow!(
                    "no {kind} artifact for {model:?} {din}x{dout} relu={relu} m>={m_need} \
                     (buckets {:?}; re-run `make artifacts` with larger M_BUCKETS?)",
                    self.manifest.m_buckets
                )
            })
    }

    /// Build the padded (x, idx, mask) literals shared by fwd and bwd.
    ///
    /// `neigh` is `m_real × k_real` with `NO_NEIGHBOR` padding, exactly as
    /// the samplers produce it; entries index the `n_real` mixed rows.
    fn pack_inputs(
        &self,
        meta: &ArtifactMeta,
        x: &[f32],
        din: usize,
        n_real: usize,
        neigh: &[u32],
        m_real: usize,
        k_real: usize,
    ) -> Result<(xla::Literal, xla::Literal, xla::Literal)> {
        let (m, n, k) = (meta.m, meta.n, meta.k);
        if k_real != k {
            bail!("sampled fanout {k_real} != artifact fanout {k}");
        }
        if m_real > m || n_real > n {
            bail!("m_real={m_real} n_real={n_real} exceed bucket m={m} n={n}");
        }
        assert_eq!(x.len(), n_real * din);
        assert_eq!(neigh.len(), m_real * k_real);
        let mut x_pad = vec![0f32; n * din];
        x_pad[..x.len()].copy_from_slice(x);
        let mut idx = vec![0i32; m * k];
        let mut mask = vec![0f32; m * k];
        for r in 0..m_real {
            for c in 0..k_real {
                let v = neigh[r * k_real + c];
                if v != NO_NEIGHBOR {
                    idx[r * k + c] = v as i32;
                    mask[r * k + c] = 1.0;
                }
            }
        }
        Ok((
            lit_f32(&x_pad, &[n as i64, din as i64])?,
            lit_i32(&idx, &[m as i64, k as i64])?,
            lit_f32(&mask, &[m as i64, k as i64])?,
        ))
    }

    fn param_literals(&self, params: &LayerParams) -> Result<Vec<xla::Literal>> {
        params
            .tensors
            .iter()
            .zip(&params.shapes)
            .map(|(t, &(r, c))| {
                if r == 1 {
                    lit_f32(t, &[c as i64])
                } else {
                    lit_f32(t, &[r as i64, c as i64])
                }
            })
            .collect()
    }
}

impl Backend for Runtime {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    /// Execute one GNN layer forward through the bucketed AOT executable.
    ///
    /// Returns the `m_real × dout` hidden rows (padding sliced away).
    fn layer_fwd(
        &self,
        model: GnnKind,
        din: usize,
        dout: usize,
        relu: bool,
        x: &[f32],
        n_real: usize,
        neigh: &[u32],
        m_real: usize,
        k_real: usize,
        params: &LayerParams,
    ) -> Result<Vec<f32>> {
        let meta =
            self.pick_layer("layer_fwd", model, din, dout, relu, m_real, n_real)?.clone();
        let (x_l, idx_l, mask_l) = self.pack_inputs(&meta, x, din, n_real, neigh, m_real, k_real)?;
        let mut args = vec![x_l, idx_l, mask_l];
        args.extend(self.param_literals(params)?);
        let exe = self.executable(&meta.name)?;
        let result = exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| anyhow!("execute {}: {e}", meta.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {}: {e}", meta.name))?;
        let outs = result.to_tuple().map_err(|e| anyhow!("tuple: {e}"))?;
        let full = to_vec_f32(&outs[0])?;
        Ok(full[..m_real * dout].to_vec())
    }

    /// Execute one GNN layer backward (VJP) through the AOT executable.
    fn layer_bwd(
        &self,
        model: GnnKind,
        din: usize,
        dout: usize,
        relu: bool,
        x: &[f32],
        n_real: usize,
        neigh: &[u32],
        m_real: usize,
        k_real: usize,
        g_out: &[f32],
        params: &LayerParams,
    ) -> Result<LayerGrads> {
        let meta =
            self.pick_layer("layer_bwd", model, din, dout, relu, m_real, n_real)?.clone();
        let (x_l, idx_l, mask_l) = self.pack_inputs(&meta, x, din, n_real, neigh, m_real, k_real)?;
        assert_eq!(g_out.len(), m_real * dout);
        let mut g_pad = vec![0f32; meta.m * dout];
        g_pad[..g_out.len()].copy_from_slice(g_out);
        let g_l = lit_f32(&g_pad, &[meta.m as i64, dout as i64])?;
        let mut args = vec![x_l, idx_l, mask_l, g_l];
        args.extend(self.param_literals(params)?);
        let exe = self.executable(&meta.name)?;
        let result = exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| anyhow!("execute {}: {e}", meta.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {}: {e}", meta.name))?;
        let outs = result.to_tuple().map_err(|e| anyhow!("tuple: {e}"))?;
        if outs.len() != 1 + params.tensors.len() {
            bail!("{}: expected {} outputs, got {}", meta.name, 1 + params.tensors.len(), outs.len());
        }
        let g_x_full = to_vec_f32(&outs[0])?;
        let g_x = g_x_full[..n_real * din].to_vec();
        let mut g_params = Vec::with_capacity(params.tensors.len());
        for (i, t) in params.tensors.iter().enumerate() {
            let g = to_vec_f32(&outs[1 + i])?;
            assert_eq!(g.len(), t.len(), "param grad {i} shape mismatch");
            g_params.push(g);
        }
        Ok(LayerGrads { g_x, g_params })
    }

    /// Execute the loss head over `b_real` target rows.
    fn loss(
        &self,
        logits: &[f32],
        labels: &[i32],
        b_real: usize,
        c: usize,
    ) -> Result<(LossOut, Vec<f32>)> {
        let meta = self
            .manifest
            .pick_loss(b_real, c)
            .ok_or_else(|| anyhow!("no loss artifact for b>={b_real} c={c}"))?
            .clone();
        let b = meta.m; // bucket
        assert_eq!(logits.len(), b_real * c);
        assert_eq!(labels.len(), b_real);
        let mut lg = vec![0f32; b * c];
        lg[..logits.len()].copy_from_slice(logits);
        let mut lb = vec![0i32; b];
        lb[..labels.len()].copy_from_slice(labels);
        let mut valid = vec![0f32; b];
        valid[..b_real].fill(1.0);
        let args = vec![
            lit_f32(&lg, &[b as i64, c as i64])?,
            lit_i32(&lb, &[b as i64])?,
            lit_f32(&valid, &[b as i64])?,
        ];
        let exe = self.executable(&meta.name)?;
        let result = exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| anyhow!("execute {}: {e}", meta.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e}"))?;
        let outs = result.to_tuple().map_err(|e| anyhow!("tuple: {e}"))?;
        let loss = to_vec_f32(&outs[0])?[0];
        let g_full = to_vec_f32(&outs[1])?;
        let correct = to_vec_f32(&outs[2])?[0];
        Ok((LossOut { loss, correct }, g_full[..b_real * c].to_vec()))
    }
}
