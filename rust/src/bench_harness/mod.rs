//! Micro-benchmark harness (criterion is unavailable offline): warmup,
//! timed iterations, and robust statistics. Used by `rust/benches/*.rs`
//! (compiled with `harness = false`).

use std::time::Instant;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
    /// Optional throughput denominator (items per iteration).
    pub items_per_iter: Option<f64>,
}

impl BenchStats {
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter.map(|n| n / self.mean_s)
    }

    pub fn report_line(&self) -> String {
        let tp = match self.throughput() {
            Some(t) if t >= 1e6 => format!("  {:>8.2} M/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("  {:>8.2} K/s", t / 1e3),
            Some(t) => format!("  {t:>8.2} /s"),
            None => String::new(),
        };
        format!(
            "{:<42} {:>10} {:>10} {:>10} {:>4} iters{}",
            self.name,
            fmt_dur(self.mean_s),
            fmt_dur(self.median_s),
            fmt_dur(self.p95_s),
            self.iters,
            tp
        )
    }
}

fn fmt_dur(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

/// Runner with a time budget per case.
pub struct Bench {
    warmup_iters: usize,
    min_iters: usize,
    max_iters: usize,
    budget_s: f64,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup_iters: 2, min_iters: 5, max_iters: 200, budget_s: 2.0 }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench { warmup_iters: 1, min_iters: 3, max_iters: 50, budget_s: 0.5 }
    }

    pub fn with_budget(mut self, s: f64) -> Self {
        self.budget_s = s;
        self
    }

    /// Run `f` repeatedly; `items` sets the throughput denominator.
    pub fn run<T>(&self, name: &str, items: Option<f64>, mut f: impl FnMut() -> T) -> BenchStats {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples: Vec<f64> = Vec::new();
        let t_start = Instant::now();
        while samples.len() < self.min_iters
            || (samples.len() < self.max_iters && t_start.elapsed().as_secs_f64() < self.budget_s)
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let stats = BenchStats {
            name: name.to_string(),
            iters: n,
            mean_s: mean,
            median_s: samples[n / 2],
            p95_s: samples[(n as f64 * 0.95) as usize % n.max(1)],
            min_s: samples[0],
            items_per_iter: items,
        };
        println!("{}", stats.report_line());
        stats
    }
}

/// Print a section header in bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
    println!(
        "{:<42} {:>10} {:>10} {:>10}",
        "case", "mean", "median", "p95"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let b = Bench { warmup_iters: 1, min_iters: 3, max_iters: 5, budget_s: 0.05 };
        let s = b.run("noop", Some(100.0), || 1 + 1);
        assert!(s.iters >= 3);
        assert!(s.mean_s >= 0.0);
        assert!(s.throughput().unwrap() > 0.0);
        assert!(s.report_line().contains("noop"));
    }
}
