//! Micro-benchmark harness (criterion is unavailable offline): warmup,
//! timed iterations, and robust statistics. Used by `rust/benches/*.rs`
//! (compiled with `harness = false`).
//!
//! Besides the human-readable report, every bench records its cases into a
//! [`BenchSuite`] and finishes by writing `BENCH_<suite>.json` — the
//! machine-readable output CI's `bench-smoke` job collects and
//! `tools/check_bench_json.rs` validates. The JSON contract (one object
//! per file):
//!
//! ```json
//! {
//!   "suite": "<suite name>",
//!   "git_rev": "<short rev or 'unknown'>",
//!   "cases": [
//!     {"name": "...", "iters": 12, "mean_s": 0.1, "median_s": 0.1,
//!      "p95_s": 0.12, "min_s": 0.09, "throughput_per_s": 1234.5}
//!   ]
//! }
//! ```
//!
//! `throughput_per_s` is `null` for cases without an item count. Derived
//! scalar results (modeled epoch seconds, ratios, byte counts) are
//! recorded via [`BenchSuite::metric`], which stores the value in all four
//! statistics fields with `iters = 1`, so one schema covers every case.

use std::path::PathBuf;
use std::time::Instant;

use crate::util::JsonValue;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
    /// Optional throughput denominator (items per iteration).
    pub items_per_iter: Option<f64>,
}

impl BenchStats {
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter.map(|n| n / self.mean_s)
    }

    /// One JSON case object of the `BENCH_<suite>.json` contract (see the
    /// module docs).
    pub fn to_json(&self) -> JsonValue {
        JsonValue::obj(vec![
            ("name", JsonValue::str(&self.name)),
            ("iters", JsonValue::num(self.iters as f64)),
            ("mean_s", JsonValue::num(self.mean_s)),
            ("median_s", JsonValue::num(self.median_s)),
            ("p95_s", JsonValue::num(self.p95_s)),
            ("min_s", JsonValue::num(self.min_s)),
            (
                "throughput_per_s",
                match self.throughput() {
                    Some(t) => JsonValue::num(t),
                    None => JsonValue::Null,
                },
            ),
        ])
    }

    pub fn report_line(&self) -> String {
        let tp = match self.throughput() {
            Some(t) if t >= 1e6 => format!("  {:>8.2} M/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("  {:>8.2} K/s", t / 1e3),
            Some(t) => format!("  {t:>8.2} /s"),
            None => String::new(),
        };
        format!(
            "{:<42} {:>10} {:>10} {:>10} {:>4} iters{}",
            self.name,
            fmt_dur(self.mean_s),
            fmt_dur(self.median_s),
            fmt_dur(self.p95_s),
            self.iters,
            tp
        )
    }
}

fn fmt_dur(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

/// Runner with a time budget per case.
pub struct Bench {
    warmup_iters: usize,
    min_iters: usize,
    max_iters: usize,
    budget_s: f64,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup_iters: 2, min_iters: 5, max_iters: 200, budget_s: 2.0 }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench { warmup_iters: 1, min_iters: 3, max_iters: 50, budget_s: 0.5 }
    }

    pub fn with_budget(mut self, s: f64) -> Self {
        self.budget_s = s;
        self
    }

    /// Run `f` repeatedly; `items` sets the throughput denominator.
    pub fn run<T>(&self, name: &str, items: Option<f64>, mut f: impl FnMut() -> T) -> BenchStats {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples: Vec<f64> = Vec::new();
        let t_start = Instant::now();
        while samples.len() < self.min_iters
            || (samples.len() < self.max_iters && t_start.elapsed().as_secs_f64() < self.budget_s)
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let stats = BenchStats {
            name: name.to_string(),
            iters: n,
            mean_s: mean,
            median_s: samples[n / 2],
            p95_s: samples[(n as f64 * 0.95) as usize % n.max(1)],
            min_s: samples[0],
            items_per_iter: items,
        };
        println!("{}", stats.report_line());
        stats
    }
}

/// Machine-readable collector for one bench binary: accumulates timed
/// [`BenchStats`] and derived scalar metrics, then writes
/// `BENCH_<suite>.json` next to the human-readable report.
pub struct BenchSuite {
    suite: String,
    cases: Vec<BenchStats>,
}

impl BenchSuite {
    pub fn new(suite: &str) -> Self {
        BenchSuite { suite: suite.to_string(), cases: Vec::new() }
    }

    /// Record a timed case produced by [`Bench::run`].
    pub fn record(&mut self, stats: &BenchStats) {
        self.cases.push(stats.clone());
    }

    /// Record a derived scalar (modeled seconds, a ratio, a byte count):
    /// stored with `iters = 1` and the value in all four statistics
    /// fields, so every case shares one schema. Non-finite values are a
    /// bench bug and panic (CI's bench-smoke job treats a panic as a
    /// failure, which is the point).
    pub fn metric(&mut self, name: &str, value: f64) {
        assert!(value.is_finite(), "bench metric `{name}` is not finite: {value}");
        self.cases.push(BenchStats {
            name: name.to_string(),
            iters: 1,
            mean_s: value,
            median_s: value,
            p95_s: value,
            min_s: value,
            items_per_iter: None,
        });
    }

    pub fn len(&self) -> usize {
        self.cases.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cases.is_empty()
    }

    /// The whole-suite JSON object (see the module docs for the contract).
    pub fn to_json(&self) -> JsonValue {
        JsonValue::obj(vec![
            ("suite", JsonValue::str(&self.suite)),
            ("git_rev", JsonValue::str(git_rev())),
            ("cases", JsonValue::Arr(self.cases.iter().map(BenchStats::to_json).collect())),
        ])
    }

    /// Write `BENCH_<suite>.json` into `GSPLIT_BENCH_JSON_DIR` (default:
    /// the current directory — the workspace root under `cargo bench`).
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let dir = std::env::var("GSPLIT_BENCH_JSON_DIR").unwrap_or_else(|_| ".".to_string());
        let path = PathBuf::from(dir).join(format!("BENCH_{}.json", self.suite));
        std::fs::write(&path, format!("{}\n", self.to_json()))?;
        Ok(path)
    }

    /// Write the JSON report, print where it went, and panic on failure —
    /// the last line of every bench `main`.
    pub fn finish(&self) {
        assert!(!self.is_empty(), "bench suite `{}` recorded no cases", self.suite);
        match self.write() {
            Ok(path) => println!("\n[bench-json] wrote {} ({} cases)", path.display(), self.len()),
            Err(e) => panic!("failed to write BENCH_{}.json: {e}", self.suite),
        }
    }
}

/// Short git revision for bench provenance: `GITHUB_SHA` when CI provides
/// it, else `git rev-parse --short HEAD`, else `"unknown"`.
fn git_rev() -> String {
    if let Ok(sha) = std::env::var("GITHUB_SHA") {
        if !sha.is_empty() {
            return sha.chars().take(12).collect();
        }
    }
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// Print a section header in bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
    println!(
        "{:<42} {:>10} {:>10} {:>10}",
        "case", "mean", "median", "p95"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_to_json_has_the_contract_fields() {
        let s = BenchStats {
            name: "case".into(),
            iters: 7,
            mean_s: 0.5,
            median_s: 0.4,
            p95_s: 0.9,
            min_s: 0.3,
            items_per_iter: Some(100.0),
        };
        let j = s.to_json();
        assert_eq!(j.get("name").unwrap().as_str(), Some("case"));
        assert_eq!(j.get("iters").unwrap().as_u64(), Some(7));
        assert_eq!(j.get("mean_s").unwrap().as_f64(), Some(0.5));
        assert_eq!(j.get("min_s").unwrap().as_f64(), Some(0.3));
        assert_eq!(j.get("throughput_per_s").unwrap().as_f64(), Some(200.0));
        let none = BenchStats { items_per_iter: None, ..s };
        assert_eq!(*none.to_json().get("throughput_per_s").unwrap(), JsonValue::Null);
    }

    #[test]
    fn suite_json_roundtrips_and_degenerate_metrics() {
        let mut suite = BenchSuite::new("unit_test");
        suite.metric("epoch_total_s", 1.25);
        let b = Bench { warmup_iters: 0, min_iters: 1, max_iters: 2, budget_s: 0.01 };
        let s = b.run("noop", None, || 0u8);
        suite.record(&s);
        assert_eq!(suite.len(), 2);
        let text = suite.to_json().to_string();
        let parsed = JsonValue::parse(&text).expect("suite JSON must be valid");
        assert_eq!(parsed.get("suite").unwrap().as_str(), Some("unit_test"));
        assert!(!parsed.get("git_rev").unwrap().as_str().unwrap().is_empty());
        let cases = parsed.get("cases").unwrap().as_arr().unwrap();
        assert_eq!(cases.len(), 2);
        assert_eq!(cases[0].get("iters").unwrap().as_u64(), Some(1));
        assert_eq!(cases[0].get("mean_s").unwrap().as_f64(), Some(1.25));
        assert_eq!(cases[0].get("p95_s").unwrap().as_f64(), Some(1.25));
    }

    #[test]
    #[should_panic(expected = "not finite")]
    fn non_finite_metric_panics() {
        BenchSuite::new("x").metric("bad", f64::INFINITY);
    }

    #[test]
    fn runs_and_reports() {
        let b = Bench { warmup_iters: 1, min_iters: 3, max_iters: 5, budget_s: 0.05 };
        let s = b.run("noop", Some(100.0), || 1 + 1);
        assert!(s.iters >= 3);
        assert!(s.mean_s >= 0.0);
        assert!(s.throughput().unwrap() > 0.0);
        assert!(s.report_line().contains("noop"));
    }
}
