//! Out-of-core feature reader: the disk-backed [`FeatureSource`].
//!
//! [`DiskFeatureStore`] serves feature rows straight from the feature
//! section of a v2 `.gsg` file through a small LRU buffer of fixed-size
//! row chunks, so training a graph whose features don't fit in RAM only
//! ever holds `max_chunks × chunk_rows × dim × 4` bytes of them.
//!
//! Why explicit chunk buffering instead of `mmap`: the crate is fully
//! offline (no libc/`memmap` dependency), and — more importantly — an
//! explicit buffer makes the Host/Disk tier split *observable and
//! deterministic*. Every fetch either hits a resident chunk
//! ([`HostTier::Ram`] — the row was already in host memory) or faults the
//! chunk in from disk ([`HostTier::Disk`]), and because all feature
//! fetches happen on the coordinator thread in batch order (the plan
//! stage gathers, the executors only consume the gathered buffers), the
//! buffer-state evolution — and therefore the per-tier byte accounting —
//! is identical for the serial and pipelined executors. DESIGN.md
//! §Loading describes the resulting four-tier model.
//!
//! The bit-identity contract of [`FeatureSource`] holds trivially: rows
//! are read back verbatim from the file `save_dataset` wrote, so a
//! disk-backed dataset trains bit-identically to the in-RAM source those
//! bytes came from.

use std::fs::File;
use std::io::{Seek, SeekFrom};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::graph::features::{FeatureSource, HostTier};
use crate::graph::io::{read_f32_slice, GsgLayout};
use crate::obs::Phase;
use crate::Vid;

/// Default rows per chunk: 1024 rows × 32-dim f32 = 128 KiB per chunk.
pub const DEFAULT_CHUNK_ROWS: usize = 1024;
/// Default resident chunk count.
pub const DEFAULT_MAX_CHUNKS: usize = 8;

/// Chunk-buffered reader over the feature section of a v2 `.gsg` file.
///
/// All state lives behind one mutex: fetches are serialized, which keeps
/// the LRU evolution (and the Host/Disk accounting derived from it) a
/// pure function of the fetch order.
#[derive(Debug)]
pub struct DiskFeatureStore {
    path: PathBuf,
    n: usize,
    dim: usize,
    feat_off: u64,
    chunk_rows: usize,
    max_chunks: usize,
    state: Mutex<ChunkBuffer>,
}

#[derive(Debug)]
struct ChunkBuffer {
    file: File,
    /// Resident chunks as `(chunk_id, rows)`, LRU order: front = coldest,
    /// back = most recently used. Linear scan — `max_chunks` is single
    /// digits, a map would cost more than it saves.
    chunks: Vec<(usize, Vec<f32>)>,
    chunk_loads: u64,
    disk_bytes: u64,
}

impl DiskFeatureStore {
    /// Open the feature section of a v2 `.gsg` file with the default
    /// buffer geometry. Rejects v1 files (they carry no features).
    pub fn open(path: &Path) -> Result<DiskFeatureStore> {
        let layout = GsgLayout::read(path)?;
        if layout.version < 2 || layout.feat_dim == 0 {
            bail!(
                "{path:?}: v{} .gsg has no feature section — regenerate with `gsplit gen --out` \
                 or `Dataset::write_gsg`",
                layout.version
            );
        }
        let file = File::open(path).with_context(|| format!("open {path:?}"))?;
        Ok(DiskFeatureStore {
            path: path.to_path_buf(),
            n: layout.n,
            dim: layout.feat_dim,
            feat_off: layout.feat_off,
            chunk_rows: DEFAULT_CHUNK_ROWS,
            max_chunks: DEFAULT_MAX_CHUNKS,
            state: Mutex::new(ChunkBuffer {
                file,
                chunks: Vec::new(),
                chunk_loads: 0,
                disk_bytes: 0,
            }),
        })
    }

    /// Replace the buffer geometry (and drop any resident chunks).
    /// `chunk_rows × max_chunks` bounds resident feature rows.
    pub fn with_buffer(mut self, chunk_rows: usize, max_chunks: usize) -> DiskFeatureStore {
        assert!(chunk_rows > 0 && max_chunks > 0, "buffer geometry must be nonzero");
        self.chunk_rows = chunk_rows;
        self.max_chunks = max_chunks;
        self.reset_buffer();
        self
    }

    /// Drop all resident chunks and zero the load counters — the next
    /// fetch of any row is a [`HostTier::Disk`] fault again.
    pub fn reset_buffer(&self) {
        let mut s = self.state.lock().expect("DiskFeatureStore mutex poisoned");
        s.chunks.clear();
        s.chunk_loads = 0;
        s.disk_bytes = 0;
    }

    /// Number of chunk faults (disk reads) since the last reset.
    pub fn chunk_loads(&self) -> u64 {
        self.state.lock().expect("DiskFeatureStore mutex poisoned").chunk_loads
    }

    /// Bytes read from disk since the last reset.
    pub fn disk_bytes_read(&self) -> u64 {
        self.state.lock().expect("DiskFeatureStore mutex poisoned").disk_bytes
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Ensure the chunk holding `v` is resident (faulting it in from disk
    /// if not), run `use_chunk` on it, and report which tier served it.
    fn with_row_chunk(&self, v: Vid, mut use_chunk: impl FnMut(&[f32])) -> HostTier {
        let vu = v as usize;
        assert!(vu < self.n, "vertex {v} out of range for {} feature rows", self.n);
        let chunk_id = vu / self.chunk_rows;
        let row_in_chunk = vu % self.chunk_rows;
        let mut s = self.state.lock().expect("DiskFeatureStore mutex poisoned");
        let pos = s.chunks.iter().position(|(id, _)| *id == chunk_id);
        let tier = match pos {
            Some(i) => {
                // Hit: move to the back (most recently used).
                let entry = s.chunks.remove(i);
                s.chunks.push(entry);
                HostTier::Ram
            }
            None => {
                // Miss: evict the coldest chunk (reusing its allocation)
                // and read the chunk from disk. Faults are rare relative to
                // row fetches, so the tracing + metrics lookups live here,
                // off the hit path.
                let _s = crate::span!(Phase::DiskFetch);
                let mut buf = if s.chunks.len() >= self.max_chunks {
                    s.chunks.remove(0).1
                } else {
                    Vec::new()
                };
                let rows = self.chunk_rows.min(self.n - chunk_id * self.chunk_rows);
                buf.resize(rows * self.dim, 0.0);
                let row0 = (chunk_id as u64) * (self.chunk_rows as u64);
                let off = self.feat_off + row0 * (self.dim as u64) * 4;
                s.file
                    .seek(SeekFrom::Start(off))
                    .unwrap_or_else(|e| panic!("seek chunk {chunk_id} of {:?}: {e}", self.path));
                read_f32_slice(&mut s.file, &mut buf)
                    .unwrap_or_else(|e| panic!("read chunk {chunk_id} of {:?}: {e:#}", self.path));
                s.chunk_loads += 1;
                s.disk_bytes += (buf.len() * 4) as u64;
                let reg = crate::obs::metrics::registry();
                reg.counter("disk_chunk_loads", &[]).inc();
                reg.counter("disk_bytes_read", &[]).add((buf.len() * 4) as u64);
                s.chunks.push((chunk_id, buf));
                HostTier::Disk
            }
        };
        let chunk = &s.chunks.last().expect("chunk just ensured resident").1;
        use_chunk(&chunk[row_in_chunk * self.dim..(row_in_chunk + 1) * self.dim]);
        tier
    }
}

impl FeatureSource for DiskFeatureStore {
    fn dim(&self) -> usize {
        self.dim
    }

    fn len(&self) -> usize {
        self.n
    }

    fn fetch_row(&self, v: Vid, out: &mut [f32]) -> HostTier {
        debug_assert_eq!(out.len(), self.dim);
        self.with_row_chunk(v, |row| out.copy_from_slice(row))
    }

    fn probe_row(&self, v: Vid) -> HostTier {
        // Same buffer-state evolution as fetch_row, no copy.
        self.with_row_chunk(v, |_| {})
    }

    fn reset_host_tiers(&self) {
        self.reset_buffer();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{rmat, save_dataset, save_graph, FeatureStore, GenParams};

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("gsplit_oocr_{}_{name}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{name}.gsg"))
    }

    fn write_fixture(name: &str, n: usize, dim: usize) -> (PathBuf, FeatureStore) {
        let g = rmat(&GenParams { num_vertices: n, num_edges: 4 * n, seed: 11 });
        let feats = FeatureStore::lazy(n, dim, 0xFEA7);
        let path = tmp(name);
        save_dataset(&path, &g, None, &feats).unwrap();
        (path, feats)
    }

    #[test]
    fn rejects_v1_files() {
        let g = rmat(&GenParams { num_vertices: 32, num_edges: 64, seed: 1 });
        let path = tmp("v1_reject");
        save_graph(&g, &path).unwrap();
        let err = DiskFeatureStore::open(&path).unwrap_err();
        assert!(format!("{err:#}").contains("no feature section"));
    }

    #[test]
    fn rows_bit_identical_to_source() {
        let (path, feats) = write_fixture("bits", 300, 7);
        let disk = DiskFeatureStore::open(&path).unwrap().with_buffer(64, 2);
        assert_eq!(FeatureSource::dim(&disk), 7);
        assert_eq!(FeatureSource::len(&disk), 300);
        let mut want = vec![0f32; 7];
        let mut got = vec![0f32; 7];
        // Mixed order so the LRU churns.
        for &v in &[0u32, 299, 150, 1, 64, 63, 299, 0, 200, 100] {
            feats.copy_row(v, &mut want);
            disk.fetch_row(v, &mut got);
            for (w, g) in want.iter().zip(&got) {
                assert_eq!(w.to_bits(), g.to_bits(), "row {v} differs");
            }
        }
    }

    #[test]
    fn tier_classification_tracks_residency() {
        let (path, _) = write_fixture("tiers", 100, 4);
        // 10-row chunks, 2 resident: vertices 0..9 are chunk 0, etc.
        let disk = DiskFeatureStore::open(&path).unwrap().with_buffer(10, 2);
        let mut row = vec![0f32; 4];
        assert_eq!(disk.fetch_row(0, &mut row), HostTier::Disk); // fault chunk 0
        assert_eq!(disk.fetch_row(5, &mut row), HostTier::Ram); // same chunk
        assert_eq!(disk.fetch_row(15, &mut row), HostTier::Disk); // fault chunk 1
        assert_eq!(disk.fetch_row(0, &mut row), HostTier::Ram); // chunk 0 still in
        assert_eq!(disk.fetch_row(25, &mut row), HostTier::Disk); // evicts chunk 1 (LRU)
        assert_eq!(disk.fetch_row(0, &mut row), HostTier::Ram);
        assert_eq!(disk.fetch_row(15, &mut row), HostTier::Disk); // chunk 1 was evicted
        assert_eq!(disk.chunk_loads(), 4);
        // 4 faults × 10 rows × 4 cols × 4 bytes.
        assert_eq!(disk.disk_bytes_read(), 4 * 10 * 4 * 4);
    }

    #[test]
    fn probe_advances_the_same_state_as_fetch() {
        let (path, _) = write_fixture("probe", 100, 4);
        let a = DiskFeatureStore::open(&path).unwrap().with_buffer(10, 2);
        let b = DiskFeatureStore::open(&path).unwrap().with_buffer(10, 2);
        let mut row = vec![0f32; 4];
        for &v in &[0u32, 5, 15, 0, 25, 0, 15, 99, 3] {
            let ta = a.fetch_row(v, &mut row);
            let tb = b.probe_row(v);
            assert_eq!(ta, tb, "fetch and probe disagree at vertex {v}");
        }
        assert_eq!(a.chunk_loads(), b.chunk_loads());
        assert_eq!(a.disk_bytes_read(), b.disk_bytes_read());
    }

    #[test]
    fn reset_makes_the_buffer_cold_again() {
        let (path, _) = write_fixture("reset", 50, 3);
        let disk = DiskFeatureStore::open(&path).unwrap().with_buffer(10, 8);
        let mut row = vec![0f32; 3];
        assert_eq!(disk.fetch_row(7, &mut row), HostTier::Disk);
        assert_eq!(disk.fetch_row(7, &mut row), HostTier::Ram);
        disk.reset_host_tiers();
        assert_eq!(disk.chunk_loads(), 0);
        assert_eq!(disk.fetch_row(7, &mut row), HostTier::Disk);
    }

    #[test]
    fn tail_chunk_is_short() {
        // n = 25, chunk_rows = 10: chunk 2 holds rows 20..24 only.
        let (path, feats) = write_fixture("tail", 25, 5);
        let disk = DiskFeatureStore::open(&path).unwrap().with_buffer(10, 1);
        let mut want = vec![0f32; 5];
        let mut got = vec![0f32; 5];
        assert_eq!(disk.fetch_row(24, &mut got), HostTier::Disk);
        feats.copy_row(24, &mut want);
        assert_eq!(want, got);
        assert_eq!(disk.disk_bytes_read(), 5 * 5 * 4); // 5 rows, not 10
    }

    #[test]
    fn gather_through_the_trait_matches_ram() {
        let (path, feats) = write_fixture("gather", 64, 6);
        let disk = DiskFeatureStore::open(&path).unwrap().with_buffer(8, 2);
        let src: &dyn FeatureSource = &disk;
        let verts = [3u32, 60, 12, 3, 45];
        let mut from_disk = Vec::new();
        let mut from_ram = Vec::new();
        src.gather(&verts, &mut from_disk);
        feats.gather(&verts, &mut from_ram);
        assert_eq!(from_disk.len(), from_ram.len());
        for (d, r) in from_disk.iter().zip(&from_ram) {
            assert_eq!(d.to_bits(), r.to_bits());
        }
    }
}
