//! Dataset presets: scaled stand-ins for the paper's three graphs.
//!
//! | Paper graph | n | m (undirected) | feat | avg deg |
//! |---|---|---|---|---|
//! | Orkut        | 3.1M | 120M | 512 | 77 |
//! | Papers100M   | 111M | 1.6B | 128 | 29 |
//! | Friendster   | 65M  | 1.9B | 128 | 58 |
//!
//! The stand-ins divide vertex/edge counts by a per-dataset scale factor
//! while preserving feature width and average degree; the simulated GPU
//! memory is divided by the same factor (see `devices::HardwarePreset`) so
//! the *cache-fit fraction* — the property that drives the paper's
//! loading-time crossovers — is preserved. Generated graphs are cached on
//! disk under `target/graphs/` because RMAT at papers-s scale takes seconds.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::graph::{
    community_rmat, load_graph, load_labels, save_dataset, save_graph, CsrGraph, DiskFeatureStore,
    FeatureSource, FeatureStore, GenParams, LabelStore,
};
use crate::rng::Pcg32;
use crate::Vid;

/// Which stand-in to materialize.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StandIn {
    /// Orkut / 32: 96k vertices, ~3.7M undirected edges, 512-dim features.
    OrkutS,
    /// Papers100M / 128: 867k vertices, ~12.5M undirected edges, 128-dim.
    PapersS,
    /// Friendster / 128: 508k vertices, ~14.7M undirected edges, 128-dim.
    FriendsterS,
    /// Small graph for unit/integration tests: 8k vertices.
    Tiny,
}

impl StandIn {
    pub fn all_paper() -> [StandIn; 3] {
        [StandIn::OrkutS, StandIn::PapersS, StandIn::FriendsterS]
    }

    pub fn spec(self) -> DatasetSpec {
        match self {
            StandIn::OrkutS => DatasetSpec {
                name: "orkut-s",
                paper_name: "Orkut",
                num_vertices: 96_000,
                num_und_edges: 3_700_000,
                feat_dim: 512,
                scale_divisor: 32.0,
                train_frac: 0.40, // Orkut has no canonical split; SNAP GNN evals train on large fractions
                seed: 0x06B1,
                communities: 192,
                inter_frac: 0.08,
            },
            StandIn::PapersS => DatasetSpec {
                name: "papers-s",
                paper_name: "Papers100M",
                num_vertices: 867_000,
                num_und_edges: 12_500_000,
                feat_dim: 128,
                scale_divisor: 128.0,
                train_frac: 0.011, // OGB papers100M: 1.2M train of 111M ≈ 1.1%
                seed: 0x9A9E,
                communities: 1024,
                inter_frac: 0.05,
            },
            StandIn::FriendsterS => DatasetSpec {
                name: "friendster-s",
                paper_name: "Friendster",
                num_vertices: 508_000,
                num_und_edges: 14_700_000,
                feat_dim: 128,
                scale_divisor: 128.0,
                train_frac: 0.10,
                seed: 0xF12E,
                communities: 512,
                inter_frac: 0.10,
            },
            StandIn::Tiny => DatasetSpec {
                name: "tiny",
                paper_name: "(test)",
                num_vertices: 8_000,
                num_und_edges: 64_000,
                feat_dim: 32,
                scale_divisor: 1.0,
                train_frac: 0.25,
                seed: 0x7111,
                communities: 16,
                inter_frac: 0.10,
            },
        }
    }

    pub fn load(self) -> Result<Dataset> {
        self.spec().materialize()
    }
}

/// Static description of a dataset stand-in.
#[derive(Debug, Clone)]
pub struct DatasetSpec {
    pub name: &'static str,
    pub paper_name: &'static str,
    pub num_vertices: usize,
    pub num_und_edges: usize,
    pub feat_dim: usize,
    /// Factor by which the paper-scale graph was divided; the hardware
    /// preset divides GPU memory by the same factor.
    pub scale_divisor: f64,
    pub train_frac: f64,
    pub seed: u64,
    /// Community structure of the generator: block count and the fraction
    /// of edges crossing blocks (real social/citation graphs are strongly
    /// local — that locality is the premise of offline min-cut
    /// partitioning, so the stand-ins must have it too).
    pub communities: usize,
    pub inter_frac: f64,
}

impl DatasetSpec {
    /// Total input-feature bytes (n × dim × 4).
    pub fn feature_bytes(&self) -> u64 {
        self.num_vertices as u64 * self.feat_dim as u64 * 4
    }

    fn cache_path(&self) -> PathBuf {
        PathBuf::from("target/graphs").join(format!("{}.gsg", self.name))
    }

    /// Generate (or load from the disk cache) the graph plus features and a
    /// train/val split.
    pub fn materialize(&self) -> Result<Dataset> {
        let path = self.cache_path();
        let graph = if path.exists() {
            load_graph(&path)?
        } else {
            let g = community_rmat(
                &GenParams {
                    num_vertices: self.num_vertices,
                    num_edges: self.num_und_edges,
                    seed: self.seed,
                },
                self.communities,
                self.inter_frac,
            );
            std::fs::create_dir_all(path.parent().unwrap())?;
            save_graph(&g, &path)?;
            g
        };
        // Features are lazy/procedural: perf experiments only move bytes.
        let features = FeatureStore::lazy(graph.num_vertices(), self.feat_dim, self.seed ^ 0xFEA7);
        // Labels exist for API completeness on stand-ins (perf experiments
        // ignore them); degree-derived so they're deterministic and free.
        let labels: Vec<u32> =
            (0..graph.num_vertices() as Vid).map(|v| graph.degree(v) % 16).collect();
        let labels = LabelStore::with_split(labels, self.train_frac, self.seed ^ 0x5717);
        Ok(Dataset { spec: self.clone(), graph, features: Arc::new(features), labels })
    }
}

/// A fully materialized dataset.
///
/// `features` is a shared [`FeatureSource`] trait object: the in-RAM
/// [`FeatureStore`] for stand-ins, or a [`DiskFeatureStore`] for
/// out-of-core datasets opened with [`Dataset::open_ooc`]. Everything
/// downstream (plan stage, executors, cache build) goes through the trait,
/// so the two are interchangeable — and, per the trait contract,
/// bit-identical in what they serve.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub spec: DatasetSpec,
    pub graph: CsrGraph,
    pub features: Arc<dyn FeatureSource>,
    pub labels: LabelStore,
}

impl Dataset {
    /// A *learnable* synthetic dataset for end-to-end training: an SBM
    /// community graph with community labels and community-correlated
    /// Gaussian features (a GNN must beat 1/communities accuracy easily).
    ///
    /// `num_classes` must match the AOT-exported head (manifest
    /// `num_classes`); `feat_dim` likewise.
    pub fn sbm_learnable(
        num_vertices: usize,
        num_classes: usize,
        feat_dim: usize,
        noise: f32,
        seed: u64,
    ) -> Dataset {
        let (graph, communities) =
            crate::graph::sbm(num_vertices, num_classes, 8, 1, seed);
        let features = FeatureStore::correlated(&communities, feat_dim, noise, seed ^ 0xFEA7);
        let labels = LabelStore::with_split(communities, 0.5, seed ^ 0x5717);
        Dataset {
            spec: DatasetSpec {
                name: "sbm-learnable",
                paper_name: "(synthetic SBM)",
                num_vertices,
                num_und_edges: graph.num_edges() / 2,
                feat_dim,
                scale_divisor: 1.0,
                train_frac: 0.5,
                seed,
                communities: num_classes,
                inter_frac: 0.1,
            },
            graph,
            features: Arc::new(features),
            labels,
        }
    }

    /// Open a v2 `.gsg` file as an out-of-core dataset: topology and labels
    /// load into RAM (they are a small fraction of feature bytes), features
    /// stay on disk behind a [`DiskFeatureStore`]. Files written without a
    /// labels section get the same degree-derived labels the stand-ins use,
    /// so a round trip through [`Dataset::write_gsg`] → `open_ooc` (with
    /// the stand-in's `train_frac` and split seed `spec.seed ^ 0x5717`)
    /// reproduces the in-RAM dataset exactly.
    pub fn open_ooc(path: &Path, train_frac: f64, split_seed: u64) -> Result<Dataset> {
        let graph = load_graph(path)?;
        let features =
            DiskFeatureStore::open(path).with_context(|| format!("open features of {path:?}"))?;
        let labels = match load_labels(path)? {
            Some(l) => l,
            None => (0..graph.num_vertices() as Vid).map(|v| graph.degree(v) % 16).collect(),
        };
        let labels = LabelStore::with_split(labels, train_frac, split_seed);
        let spec = DatasetSpec {
            name: "ooc",
            paper_name: "(on-disk)",
            num_vertices: graph.num_vertices(),
            num_und_edges: graph.num_edges() / 2,
            feat_dim: features.dim(),
            scale_divisor: 1.0,
            train_frac,
            seed: split_seed,
            communities: 1,
            inter_frac: 0.0,
        };
        Ok(Dataset { spec, graph, features: Arc::new(features), labels })
    }

    /// Write this dataset (topology + labels + features) as a v2 `.gsg`
    /// file — the input `open_ooc` and `gsplit train --graph` consume.
    /// Features are streamed through the [`FeatureSource`] in chunks.
    pub fn write_gsg(&self, path: &Path) -> Result<()> {
        save_dataset(path, &self.graph, Some(&self.labels.labels), &*self.features)
    }

    /// Shuffled copy of the training vertices for one epoch.
    pub fn epoch_targets(&self, epoch_seed: u64) -> Vec<Vid> {
        let mut t = self.labels.train_set.clone();
        let mut rng = Pcg32::new(epoch_seed);
        rng.shuffle(&mut t);
        t
    }

    /// Number of mini-batch iterations in one epoch at the given batch size.
    pub fn iters_per_epoch(&self, batch_size: usize) -> usize {
        self.labels.train_set.len().div_ceil(batch_size)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_materializes() {
        let ds = StandIn::Tiny.load().unwrap();
        assert_eq!(ds.graph.num_vertices(), 8_000);
        assert!(ds.graph.num_edges() > 64_000);
        assert_eq!(ds.features.dim(), 32);
        assert_eq!(ds.labels.train_set.len(), 2_000);
        assert!(ds.iters_per_epoch(512) == 4);
    }

    #[test]
    fn epoch_targets_are_permutations() {
        let ds = StandIn::Tiny.load().unwrap();
        let a = ds.epoch_targets(1);
        let b = ds.epoch_targets(2);
        assert_ne!(a, b);
        let mut sa = a.clone();
        let mut sb = b.clone();
        sa.sort_unstable();
        sb.sort_unstable();
        assert_eq!(sa, sb);
    }

    #[test]
    fn write_gsg_open_ooc_roundtrip_matches_ram() {
        let ds = StandIn::Tiny.load().unwrap();
        let dir = std::env::temp_dir().join(format!("gsplit_ds_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tiny.gsg");
        ds.write_gsg(&path).unwrap();
        let spec = StandIn::Tiny.spec();
        let ooc = Dataset::open_ooc(&path, spec.train_frac, spec.seed ^ 0x5717).unwrap();
        assert_eq!(ooc.graph, ds.graph);
        assert_eq!(ooc.labels.labels, ds.labels.labels);
        assert_eq!(ooc.labels.train_set, ds.labels.train_set);
        assert_eq!(ooc.features.dim(), ds.features.dim());
        let dim = ds.features.dim();
        let mut ram = vec![0f32; dim];
        let mut disk = vec![0f32; dim];
        for v in [0u32, 1, 4_000, 7_999] {
            ds.features.copy_row(v, &mut ram);
            ooc.features.copy_row(v, &mut disk);
            for (r, d) in ram.iter().zip(&disk) {
                assert_eq!(r.to_bits(), d.to_bits(), "row {v} differs");
            }
        }
    }

    #[test]
    fn specs_preserve_paper_ratios() {
        // avg degree within 25% of the paper's graphs.
        for (s, paper_deg) in
            [(StandIn::OrkutS, 77.0), (StandIn::PapersS, 28.8), (StandIn::FriendsterS, 58.5)]
        {
            let spec = s.spec();
            let deg = 2.0 * spec.num_und_edges as f64 / spec.num_vertices as f64;
            assert!(
                (deg - paper_deg).abs() / paper_deg < 0.25,
                "{}: deg {deg} vs paper {paper_deg}",
                spec.name
            );
        }
    }
}
