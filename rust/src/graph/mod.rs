//! Graph substrate: immutable CSR storage, builders, synthetic generators
//! (RMAT power-law, SBM community graphs, Erdős–Rényi), a binary on-disk
//! format, and synthetic feature/label generation.
//!
//! The paper evaluates on Orkut, Papers100M, and Friendster. Those datasets
//! (and hosts able to hold them) are not available here, so `datasets.rs`
//! defines scaled stand-ins that preserve the properties the experiments
//! depend on — average degree, feature width, skew, and cache-fit ratio
//! (see DESIGN.md §3).

mod csr;
mod datasets;
mod features;
mod gen;
mod io;
mod oocr;

pub use csr::{CsrGraph, GraphBuilder};
pub use datasets::{Dataset, DatasetSpec, StandIn};
pub use features::{FeatureSource, FeatureStore, HostTier, LabelStore};
pub use gen::{community_rmat, erdos_renyi, rmat, sbm, GenParams};
pub use io::{load_graph, load_labels, save_dataset, save_graph, GsgLayout};
pub use oocr::DiskFeatureStore;
