//! Synthetic graph generators.
//!
//! * [`rmat`] — R-MAT/Kronecker power-law graphs: degree-skewed social-graph
//!   stand-ins for Orkut / Papers100M / Friendster.
//! * [`sbm`] — stochastic block model: community structure with planted
//!   labels; used by the end-to-end training example where the GNN must
//!   actually learn something.
//! * [`erdos_renyi`] — uniform random graphs for tests and worst cases
//!   (no locality, partitioners can't win).

use crate::graph::{CsrGraph, GraphBuilder};
use crate::rng::Pcg32;
use crate::Vid;

/// Parameters shared by the generators.
#[derive(Debug, Clone)]
pub struct GenParams {
    pub num_vertices: usize,
    pub num_edges: usize,
    pub seed: u64,
}

/// R-MAT generator (Chakrabarti et al. 2004) with the standard Graph500
/// quadrant probabilities (a=0.57, b=0.19, c=0.19, d=0.05) producing a
/// power-law degree distribution similar to large social graphs.
///
/// `num_edges` counts undirected edges before dedup; the returned CSR holds
/// both directions.
pub fn rmat(p: &GenParams) -> CsrGraph {
    rmat_with_probs(p, 0.57, 0.19, 0.19)
}

pub fn rmat_with_probs(p: &GenParams, a: f64, b: f64, c: f64) -> CsrGraph {
    assert!(p.num_vertices > 1);
    let scale = (p.num_vertices as f64).log2().ceil() as u32;
    let n = p.num_vertices as u64;
    let mut rng = Pcg32::new(p.seed);
    let mut builder = GraphBuilder::new(p.num_vertices).symmetric();
    let mut placed = 0usize;
    // Some R-MAT picks fall outside [0, n) when n is not a power of two or
    // are self-loops; retry until we place the requested edge count.
    let mut guard = 0usize;
    let budget = p.num_edges * 20 + 1000;
    while placed < p.num_edges {
        guard += 1;
        assert!(guard < budget, "rmat failed to place edges (degenerate params?)");
        let (mut u, mut v) = (0u64, 0u64);
        for _ in 0..scale {
            let r = rng.next_f64();
            let (bu, bv) = if r < a {
                (0, 0)
            } else if r < a + b {
                (0, 1)
            } else if r < a + b + c {
                (1, 0)
            } else {
                (1, 1)
            };
            u = (u << 1) | bu;
            v = (v << 1) | bv;
        }
        if u >= n || v >= n || u == v {
            continue;
        }
        builder.add_edge(u as Vid, v as Vid);
        placed += 1;
    }
    builder.finish()
}

/// Stochastic block model: `communities` equally-sized blocks; each vertex
/// draws `intra_deg` neighbors inside its block and `inter_deg` outside.
/// Returns the graph and the planted community assignment (used as labels).
pub fn sbm(
    num_vertices: usize,
    communities: usize,
    intra_deg: usize,
    inter_deg: usize,
    seed: u64,
) -> (CsrGraph, Vec<u32>) {
    assert!(communities >= 1 && num_vertices >= communities);
    let mut rng = Pcg32::new(seed);
    let block = num_vertices / communities;
    let assignment: Vec<u32> =
        (0..num_vertices).map(|v| ((v / block).min(communities - 1)) as u32).collect();
    let mut builder = GraphBuilder::new(num_vertices).symmetric();
    for v in 0..num_vertices {
        let comm = assignment[v] as usize;
        let lo = comm * block;
        let hi = if comm == communities - 1 { num_vertices } else { lo + block };
        let span = (hi - lo) as u32;
        for _ in 0..intra_deg {
            let u = lo as u32 + rng.gen_range(span);
            builder.add_edge(v as Vid, u);
        }
        for _ in 0..inter_deg {
            let u = rng.gen_range(num_vertices as u32);
            builder.add_edge(v as Vid, u);
        }
    }
    (builder.finish(), assignment)
}

/// Community-structured power-law graph: the paper's social graphs (Orkut,
/// Friendster) and citation graph (Papers100M) all combine heavy-tailed
/// degrees with strong locality (METIS finds small cuts on them — that is
/// the premise of GSplit's offline partitioning). Plain R-MAT has the
/// degree skew but almost no locality, so stand-ins are generated as R-MAT
/// *within* `communities` blocks plus a fraction `inter_frac` of global
/// R-MAT edges across blocks.
pub fn community_rmat(p: &GenParams, communities: usize, inter_frac: f64) -> CsrGraph {
    assert!(communities >= 1 && p.num_vertices >= communities);
    let block = p.num_vertices / communities;
    let inter_edges = (p.num_edges as f64 * inter_frac) as usize;
    let intra_edges = p.num_edges - inter_edges;
    let mut rng = Pcg32::new(p.seed);
    let mut builder = GraphBuilder::new(p.num_vertices).symmetric();

    // Intra-community edges: R-MAT coordinates within each block, block
    // chosen proportional to size (uniform here).
    let scale = (block as f64).log2().ceil() as u32;
    let mut placed = 0usize;
    let mut guard = 0usize;
    while placed < intra_edges {
        guard += 1;
        assert!(guard < intra_edges * 30 + 1000, "community_rmat stalled");
        let c = rng.gen_range(communities as u32) as usize;
        let lo = c * block;
        let hi = if c == communities - 1 { p.num_vertices } else { lo + block };
        let span = (hi - lo) as u64;
        let (mut u, mut v) = (0u64, 0u64);
        for _ in 0..scale {
            let r = rng.next_f64();
            let (bu, bv) = if r < 0.57 {
                (0, 0)
            } else if r < 0.76 {
                (0, 1)
            } else if r < 0.95 {
                (1, 0)
            } else {
                (1, 1)
            };
            u = (u << 1) | bu;
            v = (v << 1) | bv;
        }
        if u >= span || v >= span || u == v {
            continue;
        }
        builder.add_edge((lo as u64 + u) as Vid, (lo as u64 + v) as Vid);
        placed += 1;
    }
    // Inter-community edges: uniform random endpoints in different blocks.
    let n = p.num_vertices as u32;
    let mut placed = 0usize;
    while placed < inter_edges {
        let u = rng.gen_range(n);
        let v = rng.gen_range(n);
        if u != v && (u as usize) / block != (v as usize) / block {
            builder.add_edge(u, v);
            placed += 1;
        }
    }
    builder.finish()
}

/// Erdős–Rényi G(n, m): m undirected edges sampled uniformly.
pub fn erdos_renyi(p: &GenParams) -> CsrGraph {
    let mut rng = Pcg32::new(p.seed);
    let n = p.num_vertices as u32;
    let mut builder = GraphBuilder::new(p.num_vertices).symmetric();
    let mut placed = 0;
    while placed < p.num_edges {
        let u = rng.gen_range(n);
        let v = rng.gen_range(n);
        if u != v {
            builder.add_edge(u, v);
            placed += 1;
        }
    }
    builder.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmat_basic_shape() {
        let g = rmat(&GenParams { num_vertices: 1 << 10, num_edges: 8 << 10, seed: 1 });
        assert_eq!(g.num_vertices(), 1024);
        // Symmetric + dedup: strictly fewer than 2*m, but most edges survive.
        assert!(g.num_edges() > 8 * 1024, "edges={}", g.num_edges());
        assert!(g.num_edges() <= 16 * 1024);
    }

    #[test]
    fn rmat_is_deterministic() {
        let p = GenParams { num_vertices: 512, num_edges: 2048, seed: 9 };
        assert_eq!(rmat(&p), rmat(&p));
        let p2 = GenParams { seed: 10, ..p };
        assert_ne!(rmat(&p), rmat(&p2));
    }

    #[test]
    fn rmat_is_skewed() {
        // Power-law: max degree should far exceed the average.
        let g = rmat(&GenParams { num_vertices: 1 << 12, num_edges: 16 << 12, seed: 3 });
        assert!(
            (g.max_degree() as f64) > 6.0 * g.avg_degree(),
            "max={} avg={}",
            g.max_degree(),
            g.avg_degree()
        );
    }

    #[test]
    fn sbm_is_community_heavy() {
        let (g, labels) = sbm(2000, 4, 8, 1, 7);
        assert_eq!(labels.len(), 2000);
        // Count intra vs inter community edges.
        let mut intra = 0u64;
        let mut inter = 0u64;
        for v in 0..g.num_vertices() as Vid {
            for &u in g.neighbors(v) {
                if labels[u as usize] == labels[v as usize] {
                    intra += 1;
                } else {
                    inter += 1;
                }
            }
        }
        assert!(intra > 4 * inter, "intra={intra} inter={inter}");
    }

    #[test]
    fn sbm_labels_balanced() {
        let (_, labels) = sbm(1000, 5, 4, 1, 2);
        let mut counts = [0usize; 5];
        for &l in &labels {
            counts[l as usize] += 1;
        }
        assert_eq!(counts.iter().sum::<usize>(), 1000);
        assert!(counts.iter().all(|&c| c == 200), "{counts:?}");
    }

    #[test]
    fn community_rmat_is_local_and_skewed() {
        let g = community_rmat(
            &GenParams { num_vertices: 8192, num_edges: 65536, seed: 4 },
            32,
            0.1,
        );
        assert_eq!(g.num_vertices(), 8192);
        // Locality: ≥ 80% of edges stay within a 256-vertex block.
        let block = 8192 / 32;
        let mut intra = 0u64;
        let mut total = 0u64;
        for v in 0..g.num_vertices() as Vid {
            for &u in g.neighbors(v) {
                total += 1;
                if (u as usize) / block == (v as usize) / block {
                    intra += 1;
                }
            }
        }
        assert!(intra as f64 / total as f64 > 0.8, "intra fraction {}", intra as f64 / total as f64);
        // Skew: power-law-ish max degree.
        assert!((g.max_degree() as f64) > 4.0 * g.avg_degree());
    }

    #[test]
    fn er_shape() {
        let g = erdos_renyi(&GenParams { num_vertices: 500, num_edges: 2000, seed: 4 });
        assert_eq!(g.num_vertices(), 500);
        assert!(g.num_edges() > 3000 && g.num_edges() <= 4000);
    }
}
