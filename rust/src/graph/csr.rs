//! Immutable CSR (compressed sparse row) graph.
//!
//! Sampling reads `neighbors(v)` billions of times per experiment, so the
//! layout is the classic two-array CSR: `offsets: [u64; n+1]` and
//! `adj: [u32; m]`. Graphs are treated as directed adjacency from
//! destination → in-neighbors (GNN aggregation pulls from in-neighbors);
//! generators emit symmetric edges for the undirected social graphs the
//! paper uses.

use crate::{Eid, Vid};

/// An immutable CSR graph.
#[derive(Debug, Clone, PartialEq)]
pub struct CsrGraph {
    offsets: Vec<u64>,
    adj: Vec<Vid>,
}

impl CsrGraph {
    /// Build from raw CSR arrays. Panics if the arrays are inconsistent —
    /// this is an internal constructor; external inputs go through
    /// [`GraphBuilder`] or [`super::load_graph`].
    pub fn from_raw(offsets: Vec<u64>, adj: Vec<Vid>) -> Self {
        assert!(!offsets.is_empty(), "offsets must have n+1 entries");
        assert_eq!(*offsets.last().unwrap() as usize, adj.len(), "offsets/adj mismatch");
        debug_assert!(offsets.windows(2).all(|w| w[0] <= w[1]), "offsets not monotone");
        CsrGraph { offsets, adj }
    }

    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.offsets.len() - 1
    }

    #[inline]
    pub fn num_edges(&self) -> usize {
        self.adj.len()
    }

    #[inline]
    pub fn degree(&self, v: Vid) -> u32 {
        let v = v as usize;
        (self.offsets[v + 1] - self.offsets[v]) as u32
    }

    #[inline]
    pub fn neighbors(&self, v: Vid) -> &[Vid] {
        let v = v as usize;
        &self.adj[self.offsets[v] as usize..self.offsets[v + 1] as usize]
    }

    /// Global edge id of the `i`-th neighbor of `v` (CSR slot index). Used
    /// by pre-sampling to accumulate per-edge visit counts.
    #[inline]
    pub fn edge_id(&self, v: Vid, i: u32) -> Eid {
        self.offsets[v as usize] + i as u64
    }

    pub fn offsets(&self) -> &[u64] {
        &self.offsets
    }

    pub fn adj(&self) -> &[Vid] {
        &self.adj
    }

    pub fn avg_degree(&self) -> f64 {
        if self.num_vertices() == 0 {
            0.0
        } else {
            self.num_edges() as f64 / self.num_vertices() as f64
        }
    }

    pub fn max_degree(&self) -> u32 {
        (0..self.num_vertices() as Vid).map(|v| self.degree(v)).max().unwrap_or(0)
    }

    /// Approximate resident bytes of the topology.
    pub fn topology_bytes(&self) -> u64 {
        (self.offsets.len() * 8 + self.adj.len() * 4) as u64
    }
}

/// Accumulates an edge list and finalizes it into a [`CsrGraph`].
///
/// Deduplicates parallel edges and drops self-loops (matching the cleaning
/// step applied to SNAP social graphs in GNN benchmarks).
#[derive(Debug, Default)]
pub struct GraphBuilder {
    n: usize,
    edges: Vec<(Vid, Vid)>,
    symmetric: bool,
}

impl GraphBuilder {
    pub fn new(num_vertices: usize) -> Self {
        GraphBuilder { n: num_vertices, edges: Vec::new(), symmetric: false }
    }

    /// Mirror every added edge (undirected graph). Social-network datasets
    /// in the paper are undirected.
    pub fn symmetric(mut self) -> Self {
        self.symmetric = true;
        self
    }

    pub fn add_edge(&mut self, u: Vid, v: Vid) {
        debug_assert!((u as usize) < self.n && (v as usize) < self.n);
        if u == v {
            return; // drop self-loops
        }
        self.edges.push((u, v));
        if self.symmetric {
            self.edges.push((v, u));
        }
    }

    pub fn num_pending_edges(&self) -> usize {
        self.edges.len()
    }

    /// Counting-sort by source vertex, dedup, and emit CSR.
    pub fn finish(mut self) -> CsrGraph {
        let n = self.n;
        // Counting sort by (src) then sort each row and dedup. Sorting the
        // full edge list pair-wise is O(m log m); counting sort by src then
        // per-row sorts is faster and allocation-friendlier for big m.
        let mut counts = vec![0u64; n + 1];
        for &(u, _) in &self.edges {
            counts[u as usize + 1] += 1;
        }
        for i in 0..n {
            counts[i + 1] += counts[i];
        }
        let mut adj = vec![0 as Vid; self.edges.len()];
        let mut cursor = counts.clone();
        for &(u, v) in &self.edges {
            let c = &mut cursor[u as usize];
            adj[*c as usize] = v;
            *c += 1;
        }
        self.edges = Vec::new(); // free early
        // Per-row sort + dedup, compacting in place.
        let mut offsets = vec![0u64; n + 1];
        let mut write = 0usize;
        for v in 0..n {
            let (s, e) = (counts[v] as usize, counts[v + 1] as usize);
            let row = &mut adj[s..e];
            row.sort_unstable();
            let mut prev: Option<Vid> = None;
            let row_start = write;
            for i in s..e {
                let x = adj[i];
                if prev != Some(x) {
                    adj[write] = x;
                    write += 1;
                    prev = Some(x);
                }
            }
            offsets[v] = row_start as u64;
            let _ = row_start;
        }
        // offsets[v] currently holds row starts; set final sentinel and fix
        // up into standard prefix form.
        offsets[n] = write as u64;
        adj.truncate(write);
        CsrGraph::from_raw(offsets, adj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> CsrGraph {
        // 0-1, 0-2, 1-3, 2-3 undirected
        let mut b = GraphBuilder::new(4).symmetric();
        b.add_edge(0, 1);
        b.add_edge(0, 2);
        b.add_edge(1, 3);
        b.add_edge(2, 3);
        b.finish()
    }

    #[test]
    fn csr_shape() {
        let g = diamond();
        assert_eq!(g.num_vertices(), 4);
        assert_eq!(g.num_edges(), 8);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.neighbors(3), &[1, 2]);
        assert_eq!(g.degree(1), 2);
        assert_eq!(g.max_degree(), 2);
        assert!((g.avg_degree() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn drops_self_loops_and_duplicates() {
        let mut b = GraphBuilder::new(3);
        b.add_edge(0, 1);
        b.add_edge(0, 1);
        b.add_edge(1, 1);
        b.add_edge(2, 0);
        let g = b.finish();
        assert_eq!(g.neighbors(0), &[1]);
        assert_eq!(g.neighbors(1), &[] as &[Vid]);
        assert_eq!(g.neighbors(2), &[0]);
        assert_eq!(g.num_edges(), 2);
    }

    #[test]
    fn neighbors_sorted() {
        let mut b = GraphBuilder::new(5);
        for v in [4u32, 2, 3, 1] {
            b.add_edge(0, v);
        }
        let g = b.finish();
        assert_eq!(g.neighbors(0), &[1, 2, 3, 4]);
    }

    #[test]
    fn edge_ids_are_csr_slots() {
        let g = diamond();
        assert_eq!(g.edge_id(0, 0), 0);
        assert_eq!(g.edge_id(0, 1), 1);
        assert_eq!(g.edge_id(1, 0), 2);
        assert_eq!(g.edge_id(3, 1), 7);
    }

    #[test]
    fn empty_graph() {
        let g = GraphBuilder::new(3).finish();
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.num_edges(), 0);
        assert_eq!(g.degree(2), 0);
    }
}
