//! Binary on-disk graph format (`.gsg` — "gsplit graph").
//!
//! Two versions, both little endian:
//!
//! **v1** — topology only (what `save_graph` writes; used to cache
//! generated stand-in graphs across runs):
//! ```text
//! magic   u64  = 0x4753504C49545F31 ("GSPLIT_1")
//! n       u64  number of vertices
//! m       u64  number of directed edges
//! offsets (n+1) × u64
//! adj     m × u32
//! ```
//!
//! **v2** — topology + versioned label/feature sections (what
//! `save_dataset` writes; the out-of-core training input):
//! ```text
//! magic    u64  = 0x4753504C49545F32 ("GSPLIT_2")
//! n        u64
//! m        u64
//! feat_dim u64  feature columns per vertex
//! flags    u64  bit 0 = labels section present
//! offsets  (n+1) × u64
//! adj      m × u32
//! labels   n × u32          (iff flags bit 0)
//! features n × feat_dim × f32
//! ```
//! Features come **last** so row `v` has the fixed file offset
//! `feat_off + v × feat_dim × 4` — the property
//! [`DiskFeatureStore`](crate::graph::DiskFeatureStore) relies on to read
//! chunks without an index. `save_dataset` streams feature rows through a
//! bounded chunk buffer, so a 10⁷-vertex graph's features never
//! materialize in RAM.
//!
//! `load_graph` accepts either version (it stops after `adj`) and
//! validates the CSR invariants on load: total file length against the
//! header, monotone offsets starting at 0, and every adjacency entry
//! `< n` — naming the offending index in the error, never panicking.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::graph::{CsrGraph, FeatureSource};
use crate::Vid;

const MAGIC_V1: u64 = 0x4753_504C_4954_5F31;
const MAGIC_V2: u64 = 0x4753_504C_4954_5F32;

/// Flags bit 0: a `labels` section precedes the feature section.
const FLAG_LABELS: u64 = 1;

const HEADER_V1_BYTES: u64 = 3 * 8;
const HEADER_V2_BYTES: u64 = 5 * 8;

/// Parsed `.gsg` header plus the absolute section offsets derived from it.
/// For v1 files the label/feature sections don't exist (`feat_dim == 0`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GsgLayout {
    /// Format version (1 or 2).
    pub version: u32,
    pub n: usize,
    pub m: usize,
    /// Feature columns per vertex (0 for v1 files).
    pub feat_dim: usize,
    pub has_labels: bool,
    /// Byte offset of the labels section (meaningful iff `has_labels`).
    pub labels_off: u64,
    /// Byte offset of the feature section (meaningful iff v2).
    pub feat_off: u64,
}

impl GsgLayout {
    /// Read and validate the header of `path`, including that the file
    /// length matches exactly what the header promises (so truncation is
    /// a descriptive error here, not an EOF deep inside a section read).
    pub fn read(path: &Path) -> Result<GsgLayout> {
        let f = File::open(path).with_context(|| format!("open {path:?}"))?;
        let file_len = f.metadata().with_context(|| format!("stat {path:?}"))?.len();
        let mut r = BufReader::new(f);
        if file_len < HEADER_V1_BYTES {
            bail!(
                "{path:?}: file is {file_len} bytes, shorter than the {HEADER_V1_BYTES}-byte \
                 .gsg header"
            );
        }
        let magic = read_u64(&mut r)?;
        let version = match magic {
            MAGIC_V1 => 1,
            MAGIC_V2 => 2,
            other => bail!("{path:?}: bad magic {other:#x} (not a .gsg graph file)"),
        };
        if version == 2 && file_len < HEADER_V2_BYTES {
            bail!(
                "{path:?}: file is {file_len} bytes, shorter than the {HEADER_V2_BYTES}-byte \
                 v2 .gsg header"
            );
        }
        let n = read_u64(&mut r)?;
        let m = read_u64(&mut r)?;
        let (feat_dim, flags) =
            if version == 2 { (read_u64(&mut r)?, read_u64(&mut r)?) } else { (0, 0) };
        let has_labels = flags & FLAG_LABELS != 0;
        let header = if version == 2 { HEADER_V2_BYTES } else { HEADER_V1_BYTES };

        // Expected length, overflow-checked: a corrupt header must produce
        // an error, never a huge allocation or a wrapped size.
        let sections: Option<u64> = (|| {
            let offsets = n.checked_add(1)?.checked_mul(8)?;
            let adj = m.checked_mul(4)?;
            let labels = if has_labels { n.checked_mul(4)? } else { 0 };
            let feats = n.checked_mul(feat_dim)?.checked_mul(4)?;
            header.checked_add(offsets)?.checked_add(adj)?.checked_add(labels)?.checked_add(feats)
        })();
        let expected = match sections {
            Some(e) => e,
            None => bail!("{path:?}: corrupt header (n={n}, m={m}, feat_dim={feat_dim} overflow)"),
        };
        if file_len != expected {
            bail!(
                "{path:?}: file is {file_len} bytes but the header (n={n}, m={m}, \
                 feat_dim={feat_dim}, labels={has_labels}) requires exactly {expected} — \
                 truncated or corrupt"
            );
        }
        let topo_end = header + (n + 1) * 8 + m * 4;
        let labels_off = topo_end;
        let feat_off = topo_end + if has_labels { n * 4 } else { 0 };
        Ok(GsgLayout {
            version,
            n: n as usize,
            m: m as usize,
            feat_dim: feat_dim as usize,
            has_labels,
            labels_off,
            feat_off,
        })
    }
}

/// Save topology only (v1) — the stand-in graph cache format.
pub fn save_graph(g: &CsrGraph, path: &Path) -> Result<()> {
    let f = File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    w.write_all(&MAGIC_V1.to_le_bytes())?;
    w.write_all(&(g.num_vertices() as u64).to_le_bytes())?;
    w.write_all(&(g.num_edges() as u64).to_le_bytes())?;
    write_topology(&mut w, g)?;
    w.flush()?;
    Ok(())
}

/// Rows per write chunk when streaming the feature section. Large enough
/// to amortize syscalls, small enough that the buffer stays a few MB even
/// at Orkut's 512-dim width.
const SAVE_CHUNK_ROWS: usize = 4096;

/// Save topology + optional labels + features (v2, the out-of-core
/// training input). Feature rows are pulled from `features` and written in
/// [`SAVE_CHUNK_ROWS`]-row chunks, so a lazy/procedural source streams to
/// disk without ever materializing the full matrix in RAM.
pub fn save_dataset(
    path: &Path,
    g: &CsrGraph,
    labels: Option<&[u32]>,
    features: &dyn FeatureSource,
) -> Result<()> {
    let n = g.num_vertices();
    assert_eq!(features.len(), n, "feature rows must cover all vertices");
    if let Some(l) = labels {
        assert_eq!(l.len(), n, "labels must cover all vertices");
    }
    let dim = features.dim();
    let f = File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    w.write_all(&MAGIC_V2.to_le_bytes())?;
    w.write_all(&(n as u64).to_le_bytes())?;
    w.write_all(&(g.num_edges() as u64).to_le_bytes())?;
    w.write_all(&(dim as u64).to_le_bytes())?;
    let flags: u64 = if labels.is_some() { FLAG_LABELS } else { 0 };
    w.write_all(&flags.to_le_bytes())?;
    write_topology(&mut w, g)?;
    if let Some(l) = labels {
        for &x in l {
            w.write_all(&x.to_le_bytes())?;
        }
    }
    let mut chunk = vec![0f32; SAVE_CHUNK_ROWS.min(n.max(1)) * dim];
    for start in (0..n).step_by(SAVE_CHUNK_ROWS.max(1)) {
        let rows = SAVE_CHUNK_ROWS.min(n - start);
        for r in 0..rows {
            features.copy_row((start + r) as Vid, &mut chunk[r * dim..(r + 1) * dim]);
        }
        write_f32_slice(&mut w, &chunk[..rows * dim])?;
    }
    w.flush()?;
    Ok(())
}

fn write_topology(w: &mut impl Write, g: &CsrGraph) -> Result<()> {
    for &o in g.offsets() {
        w.write_all(&o.to_le_bytes())?;
    }
    for &v in g.adj() {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Load the topology of a v1 **or** v2 `.gsg` file, validating the CSR
/// invariants (see the module docs).
pub fn load_graph(path: &Path) -> Result<CsrGraph> {
    let layout = GsgLayout::read(path)?;
    let f = File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut r = BufReader::new(f);
    let header = if layout.version == 2 { HEADER_V2_BYTES } else { HEADER_V1_BYTES };
    io_skip(&mut r, header)?;
    let (n, m) = (layout.n, layout.m);
    let mut offsets = vec![0u64; n + 1];
    read_u64_slice(&mut r, &mut offsets)?;
    let mut adj = vec![0 as Vid; m];
    read_u32_slice(&mut r, &mut adj)?;
    validate_csr(path, n, m, &offsets, &adj)?;
    Ok(CsrGraph::from_raw(offsets, adj))
}

/// Load the labels section of a v2 file; `Ok(None)` if the file carries no
/// labels (v1, or v2 written without them).
pub fn load_labels(path: &Path) -> Result<Option<Vec<u32>>> {
    let layout = GsgLayout::read(path)?;
    if !layout.has_labels {
        return Ok(None);
    }
    let f = File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut r = BufReader::new(f);
    io_skip(&mut r, layout.labels_off)?;
    let mut labels = vec![0u32; layout.n];
    read_u32_slice(&mut r, &mut labels)?;
    Ok(Some(labels))
}

/// The load-time CSR validation (the `.gsg` trust boundary): every index
/// the in-memory [`CsrGraph`] would later use unchecked is range-checked
/// here, with the offending index named.
fn validate_csr(path: &Path, n: usize, m: usize, offsets: &[u64], adj: &[Vid]) -> Result<()> {
    if offsets[0] != 0 {
        bail!("{path:?}: corrupt offsets (offsets[0] = {}, expected 0)", offsets[0]);
    }
    for i in 0..n {
        if offsets[i] > offsets[i + 1] {
            bail!(
                "{path:?}: corrupt offsets (offsets[{i}] = {} > offsets[{}] = {} — not \
                 monotone)",
                offsets[i],
                i + 1,
                offsets[i + 1]
            );
        }
    }
    if offsets[n] != m as u64 {
        bail!("{path:?}: corrupt offsets (last = {}, m = {m})", offsets[n]);
    }
    for (i, &v) in adj.iter().enumerate() {
        if v as usize >= n {
            bail!("{path:?}: corrupt adjacency (adj[{i}] = {v}, out of range for n = {n})");
        }
    }
    Ok(())
}

fn io_skip(r: &mut impl Read, bytes: u64) -> Result<()> {
    std::io::copy(&mut r.take(bytes), &mut std::io::sink())?;
    Ok(())
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_u64_slice(r: &mut impl Read, out: &mut [u64]) -> Result<()> {
    // Bulk read: interpret the output slice as bytes.
    let bytes = unsafe {
        std::slice::from_raw_parts_mut(out.as_mut_ptr() as *mut u8, out.len() * 8)
    };
    r.read_exact(bytes)?;
    if cfg!(target_endian = "big") {
        for x in out.iter_mut() {
            *x = u64::from_le(*x);
        }
    }
    Ok(())
}

fn read_u32_slice(r: &mut impl Read, out: &mut [u32]) -> Result<()> {
    let bytes = unsafe {
        std::slice::from_raw_parts_mut(out.as_mut_ptr() as *mut u8, out.len() * 4)
    };
    r.read_exact(bytes)?;
    if cfg!(target_endian = "big") {
        for x in out.iter_mut() {
            *x = u32::from_le(*x);
        }
    }
    Ok(())
}

pub(crate) fn read_f32_slice(r: &mut impl Read, out: &mut [f32]) -> Result<()> {
    let bytes = unsafe {
        std::slice::from_raw_parts_mut(out.as_mut_ptr() as *mut u8, out.len() * 4)
    };
    r.read_exact(bytes)?;
    if cfg!(target_endian = "big") {
        for x in out.iter_mut() {
            *x = f32::from_bits(u32::from_le(x.to_bits()));
        }
    }
    Ok(())
}

fn write_f32_slice(w: &mut impl Write, data: &[f32]) -> Result<()> {
    if cfg!(target_endian = "big") {
        for &x in data {
            w.write_all(&x.to_le_bytes())?;
        }
    } else {
        let bytes =
            unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
        w.write_all(bytes)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{rmat, FeatureStore, GenParams, GraphBuilder};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("gsplit_io_{}_{name}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{name}.gsg"))
    }

    #[test]
    fn roundtrip() {
        let g = rmat(&GenParams { num_vertices: 256, num_edges: 1024, seed: 12 });
        let path = tmp("roundtrip");
        save_graph(&g, &path).unwrap();
        let g2 = load_graph(&path).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn roundtrip_empty_graph() {
        let g = GraphBuilder::new(0).finish();
        let path = tmp("empty");
        save_graph(&g, &path).unwrap();
        let g2 = load_graph(&path).unwrap();
        assert_eq!(g, g2);
        assert_eq!(g2.num_vertices(), 0);
        assert_eq!(g2.num_edges(), 0);
    }

    #[test]
    fn roundtrip_isolated_vertices() {
        // Vertices with no edges at all: offsets are flat runs.
        let mut b = GraphBuilder::new(10);
        b.add_edge(3, 7);
        let g = b.finish();
        let path = tmp("isolated");
        save_graph(&g, &path).unwrap();
        let g2 = load_graph(&path).unwrap();
        assert_eq!(g, g2);
        assert_eq!(g2.degree(0), 0);
        assert_eq!(g2.degree(3), 1);
    }

    #[test]
    fn roundtrip_max_degree_vertex() {
        // One hub adjacent to every other vertex.
        let n = 64u32;
        let mut b = GraphBuilder::new(n as usize);
        for v in 1..n {
            b.add_edge(0, v);
            b.add_edge(v, 0);
        }
        let g = b.finish();
        let path = tmp("hub");
        save_graph(&g, &path).unwrap();
        let g2 = load_graph(&path).unwrap();
        assert_eq!(g, g2);
        assert_eq!(g2.degree(0) as u32, n - 1);
    }

    #[test]
    fn v2_roundtrip_with_labels_and_features() {
        let g = rmat(&GenParams { num_vertices: 100, num_edges: 400, seed: 5 });
        let feats = FeatureStore::lazy(100, 8, 99);
        let labels: Vec<u32> = (0..100).map(|v| v % 7).collect();
        let path = tmp("v2");
        save_dataset(&path, &g, Some(&labels), &feats).unwrap();
        let layout = GsgLayout::read(&path).unwrap();
        assert_eq!(layout.version, 2);
        assert_eq!((layout.n, layout.m), (100, g.num_edges()));
        assert_eq!(layout.feat_dim, 8);
        assert!(layout.has_labels);
        assert_eq!(load_graph(&path).unwrap(), g);
        assert_eq!(load_labels(&path).unwrap().unwrap(), labels);
    }

    #[test]
    fn v2_without_labels() {
        let g = rmat(&GenParams { num_vertices: 32, num_edges: 64, seed: 6 });
        let feats = FeatureStore::lazy(32, 4, 1);
        let path = tmp("v2_nolabels");
        save_dataset(&path, &g, None, &feats).unwrap();
        assert!(load_labels(&path).unwrap().is_none());
        assert_eq!(load_graph(&path).unwrap(), g);
    }

    #[test]
    fn v1_has_no_labels_section() {
        let g = rmat(&GenParams { num_vertices: 32, num_edges: 64, seed: 6 });
        let path = tmp("v1_nolabels");
        save_graph(&g, &path).unwrap();
        assert!(load_labels(&path).unwrap().is_none());
    }

    // ---- corruption matrix: every case is a descriptive error, never a
    // panic or an OOM-sized allocation ----

    fn expect_err_containing(path: &Path, needle: &str) {
        let err = match load_graph(path) {
            Err(e) => format!("{e:#}"),
            Ok(_) => panic!("corrupt file {path:?} loaded successfully"),
        };
        assert!(err.contains(needle), "error {err:?} should mention {needle:?}");
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmp("bad_magic");
        std::fs::write(&path, [0u8; 64]).unwrap();
        expect_err_containing(&path, "bad magic");
    }

    #[test]
    fn rejects_short_header() {
        let path = tmp("short_header");
        std::fs::write(&path, &MAGIC_V1.to_le_bytes()[..6]).unwrap();
        expect_err_containing(&path, "shorter than");
        // v2 magic + nothing else: long enough for v1's header test but
        // not v2's.
        let mut bytes = MAGIC_V2.to_le_bytes().to_vec();
        bytes.extend_from_slice(&[0u8; 16]);
        std::fs::write(&path, bytes).unwrap();
        expect_err_containing(&path, "shorter than");
    }

    #[test]
    fn rejects_truncated_adj() {
        let g = rmat(&GenParams { num_vertices: 64, num_edges: 128, seed: 1 });
        let path = tmp("trunc");
        save_graph(&g, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 10]).unwrap();
        expect_err_containing(&path, "truncated or corrupt");
    }

    #[test]
    fn rejects_offsets_m_mismatch() {
        // Claim m+8 edges in the header but keep the original offsets:
        // with 8 extra adj entries appended the length check passes and
        // the offsets/m cross-check must catch it.
        let g = rmat(&GenParams { num_vertices: 64, num_edges: 128, seed: 1 });
        let path = tmp("m_mismatch");
        save_graph(&g, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let m = g.num_edges() as u64 + 8;
        bytes[16..24].copy_from_slice(&m.to_le_bytes());
        bytes.extend_from_slice(&[0u8; 32]);
        std::fs::write(&path, bytes).unwrap();
        expect_err_containing(&path, "corrupt offsets");
    }

    #[test]
    fn rejects_insane_header_counts() {
        // n = u64::MAX must be a clean error, not a (n+1)*8 allocation.
        let mut bytes = MAGIC_V1.to_le_bytes().to_vec();
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        bytes.extend_from_slice(&0u64.to_le_bytes());
        let path = tmp("insane");
        std::fs::write(&path, bytes).unwrap();
        expect_err_containing(&path, "overflow");
    }

    /// Write a v1 file with the exact offsets/adj given — for crafting
    /// corrupt CSR payloads that pass the length check.
    fn write_v1_raw(path: &Path, n: u64, m: u64, offsets: &[u64], adj: &[u32]) {
        let mut bytes = MAGIC_V1.to_le_bytes().to_vec();
        bytes.extend_from_slice(&n.to_le_bytes());
        bytes.extend_from_slice(&m.to_le_bytes());
        for &o in offsets {
            bytes.extend_from_slice(&o.to_le_bytes());
        }
        for &v in adj {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(path, bytes).unwrap();
    }

    #[test]
    fn rejects_non_monotone_offsets_naming_index() {
        // offsets[1] = 3 > offsets[2] = 1: decreasing run the old
        // last-offset-only check would have waved through.
        let path = tmp("nonmono");
        write_v1_raw(&path, 4, 4, &[0, 3, 1, 3, 4], &[1, 2, 3, 0]);
        expect_err_containing(&path, "offsets[1] = 3 > offsets[2] = 1");
        expect_err_containing(&path, "monotone");
    }

    #[test]
    fn rejects_nonzero_first_offset() {
        let path = tmp("first_offset");
        write_v1_raw(&path, 4, 4, &[1, 1, 2, 3, 4], &[1, 2, 3, 0]);
        expect_err_containing(&path, "offsets[0] = 1");
    }

    #[test]
    fn rejects_out_of_range_adj_naming_index() {
        // adj[2] = 9 ≥ n = 4 — an index CsrGraph::neighbors would later
        // use to read out of bounds.
        let path = tmp("adj_oob");
        write_v1_raw(&path, 4, 4, &[0, 1, 2, 3, 4], &[1, 2, 9, 0]);
        expect_err_containing(&path, "adj[2] = 9");
    }
}
