//! Binary on-disk graph format (`.gsg` — "gsplit graph").
//!
//! Layout (little endian):
//! ```text
//! magic   u64  = 0x4753504C49545F31 ("GSPLIT_1")
//! n       u64  number of vertices
//! m       u64  number of directed edges
//! offsets (n+1) × u64
//! adj     m × u32
//! ```
//! Used so benches can reuse generated stand-in graphs across runs instead
//! of regenerating them (RMAT at papers-s scale takes a couple of seconds).

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::graph::CsrGraph;
use crate::Vid;

const MAGIC: u64 = 0x4753_504C_4954_5F31;

pub fn save_graph(g: &CsrGraph, path: &Path) -> Result<()> {
    let f = File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    w.write_all(&MAGIC.to_le_bytes())?;
    w.write_all(&(g.num_vertices() as u64).to_le_bytes())?;
    w.write_all(&(g.num_edges() as u64).to_le_bytes())?;
    for &o in g.offsets() {
        w.write_all(&o.to_le_bytes())?;
    }
    for &v in g.adj() {
        w.write_all(&v.to_le_bytes())?;
    }
    w.flush()?;
    Ok(())
}

pub fn load_graph(path: &Path) -> Result<CsrGraph> {
    let f = File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut r = BufReader::new(f);
    let magic = read_u64(&mut r)?;
    if magic != MAGIC {
        bail!("{path:?}: bad magic {magic:#x} (not a .gsg graph file)");
    }
    let n = read_u64(&mut r)? as usize;
    let m = read_u64(&mut r)? as usize;
    let mut offsets = vec![0u64; n + 1];
    read_u64_slice(&mut r, &mut offsets)?;
    let mut adj = vec![0 as Vid; m];
    read_u32_slice(&mut r, &mut adj)?;
    if offsets.last().copied() != Some(m as u64) {
        bail!("{path:?}: corrupt offsets (last={:?}, m={m})", offsets.last());
    }
    Ok(CsrGraph::from_raw(offsets, adj))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_u64_slice(r: &mut impl Read, out: &mut [u64]) -> Result<()> {
    // Bulk read: interpret the output slice as bytes.
    let bytes = unsafe {
        std::slice::from_raw_parts_mut(out.as_mut_ptr() as *mut u8, out.len() * 8)
    };
    r.read_exact(bytes)?;
    if cfg!(target_endian = "big") {
        for x in out.iter_mut() {
            *x = u64::from_le(*x);
        }
    }
    Ok(())
}

fn read_u32_slice(r: &mut impl Read, out: &mut [u32]) -> Result<()> {
    let bytes = unsafe {
        std::slice::from_raw_parts_mut(out.as_mut_ptr() as *mut u8, out.len() * 4)
    };
    r.read_exact(bytes)?;
    if cfg!(target_endian = "big") {
        for x in out.iter_mut() {
            *x = u32::from_le(*x);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{rmat, GenParams};

    #[test]
    fn roundtrip() {
        let g = rmat(&GenParams { num_vertices: 256, num_edges: 1024, seed: 12 });
        let dir = std::env::temp_dir().join("gsplit_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("g.gsg");
        save_graph(&g, &path).unwrap();
        let g2 = load_graph(&path).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("gsplit_io_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.gsg");
        std::fs::write(&path, b"not a graph file at all....").unwrap();
        assert!(load_graph(&path).is_err());
    }

    #[test]
    fn rejects_truncated() {
        let g = rmat(&GenParams { num_vertices: 64, num_edges: 128, seed: 1 });
        let dir = std::env::temp_dir().join("gsplit_io_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trunc.gsg");
        save_graph(&g, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(load_graph(&path).is_err());
    }
}
