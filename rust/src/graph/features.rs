//! Synthetic input features and labels.
//!
//! The performance experiments only need feature *bytes* to exist (loading
//! cost is `count × width × 4B`), but the end-to-end training example needs
//! features that are *learnable*: community-correlated Gaussian mixtures so
//! a GNN can separate the classes.

use crate::rng::{Pcg32, SplitMix64};
use crate::Vid;

/// Which host-side tier actually served a feature row — the split one
/// level *below* the device-side Local/Peer classification of
/// [`FetchSource`](crate::cache::FetchSource) (DESIGN.md §Loading).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HostTier {
    /// Served from host RAM: an in-RAM [`FeatureStore`], or a hit in an
    /// out-of-core reader's chunk buffer.
    Ram,
    /// The row fell through host RAM to disk (a chunk-buffer miss in a
    /// [`DiskFeatureStore`](crate::graph::DiskFeatureStore)).
    Disk,
}

/// Uniform host-side feature access for the plan stage, both executors,
/// and `CacheStore::build`: implemented by the in-RAM [`FeatureStore`] and
/// the out-of-core [`DiskFeatureStore`](crate::graph::DiskFeatureStore).
///
/// The contract every implementation must honor is the repo-wide one: for
/// the same vertex, every source returns the **same f32 bits** — where the
/// bytes live (RAM, chunk buffer, disk) can change the [`HostTier`]
/// accounting, never what the model computes.
pub trait FeatureSource: Send + Sync + std::fmt::Debug {
    /// Feature width (columns per row).
    fn dim(&self) -> usize;

    /// Number of rows (vertices).
    fn len(&self) -> usize;

    /// Copy the feature row of `v` into `out` (length `dim`), reporting
    /// the host tier that served it.
    fn fetch_row(&self, v: Vid, out: &mut [f32]) -> HostTier;

    /// Classify where a fetch of `v` *would have been* served, advancing
    /// the same internal buffer state as [`Self::fetch_row`] but without
    /// copying bytes — the cost-model counting path
    /// (`SplitParallel::account_plan`) uses this.
    fn probe_row(&self, v: Vid) -> HostTier;

    /// Drop any internal tier-classification state (e.g. the out-of-core
    /// chunk buffer). Called after offline bulk reads — cache residency
    /// construction — so online accounting always starts cold and is
    /// independent of how the cache was built. No-op for in-RAM stores.
    fn reset_host_tiers(&self) {}

    /// Copy the feature row of `v` into `out`, ignoring the tier.
    fn copy_row(&self, v: Vid, out: &mut [f32]) {
        self.fetch_row(v, out);
    }

    /// Bytes per feature row.
    fn row_bytes(&self) -> u64 {
        (self.dim() * 4) as u64
    }

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Gather rows for `vertices` into a `[len, dim]` row-major buffer.
    fn gather(&self, vertices: &[Vid], out: &mut Vec<f32>) {
        let dim = self.dim();
        out.resize(vertices.len() * dim, 0.0);
        for (i, &v) in vertices.iter().enumerate() {
            self.copy_row(v, &mut out[i * dim..(i + 1) * dim]);
        }
    }
}

impl FeatureSource for FeatureStore {
    fn dim(&self) -> usize {
        FeatureStore::dim(self)
    }

    fn len(&self) -> usize {
        FeatureStore::len(self)
    }

    fn fetch_row(&self, v: Vid, out: &mut [f32]) -> HostTier {
        FeatureStore::copy_row(self, v, out);
        HostTier::Ram
    }

    fn probe_row(&self, _v: Vid) -> HostTier {
        HostTier::Ram
    }
}

/// Dense row-major f32 feature matrix `[n, dim]`.
///
/// For large perf-only graphs, use [`FeatureStore::lazy`] which synthesizes
/// rows on demand from the vertex id — the engines only hash/copy row bytes,
/// so materializing GBs of synthetic features would be pure waste.
#[derive(Debug, Clone)]
pub enum FeatureStore {
    Dense { dim: usize, data: Vec<f32> },
    /// Procedural features: row `v` is derived from `hash(seed, v)`.
    Lazy { dim: usize, n: usize, seed: u64 },
}

impl FeatureStore {
    pub fn dense(n: usize, dim: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), n * dim);
        FeatureStore::Dense { dim, data }
    }

    pub fn lazy(n: usize, dim: usize, seed: u64) -> Self {
        FeatureStore::Lazy { dim, n, seed }
    }

    /// Gaussian-mixture features correlated with `labels`: class c has mean
    /// direction derived from c; rows get `mean(c) + noise`.
    pub fn correlated(labels: &[u32], dim: usize, noise: f32, seed: u64) -> Self {
        let n = labels.len();
        let mut data = vec![0f32; n * dim];
        let mut rng = Pcg32::new(seed);
        // Per-class mean vectors.
        let num_classes = labels.iter().copied().max().unwrap_or(0) as usize + 1;
        let mut means = vec![0f32; num_classes * dim];
        let mut mrng = Pcg32::new(seed ^ 0xABCD);
        for x in means.iter_mut() {
            *x = mrng.next_gaussian() as f32;
        }
        for (v, &l) in labels.iter().enumerate() {
            let mrow = &means[l as usize * dim..(l as usize + 1) * dim];
            let row = &mut data[v * dim..(v + 1) * dim];
            for (r, m) in row.iter_mut().zip(mrow) {
                *r = *m + noise * rng.next_gaussian() as f32;
            }
        }
        FeatureStore::Dense { dim, data }
    }

    pub fn dim(&self) -> usize {
        match self {
            FeatureStore::Dense { dim, .. } | FeatureStore::Lazy { dim, .. } => *dim,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            FeatureStore::Dense { data, dim } => data.len() / dim.max(&1),
            FeatureStore::Lazy { n, .. } => *n,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn row_bytes(&self) -> u64 {
        (self.dim() * 4) as u64
    }

    /// Copy the feature row of `v` into `out` (length `dim`).
    pub fn copy_row(&self, v: Vid, out: &mut [f32]) {
        let dim = self.dim();
        debug_assert_eq!(out.len(), dim);
        match self {
            FeatureStore::Dense { data, .. } => {
                out.copy_from_slice(&data[v as usize * dim..(v as usize + 1) * dim]);
            }
            FeatureStore::Lazy { seed, .. } => {
                let mut sm = SplitMix64::new(seed ^ (v as u64).wrapping_mul(0x9E3779B97F4A7C15));
                for x in out.iter_mut() {
                    // Cheap uniform in [-1, 1); numerics don't matter here.
                    *x = ((sm.next_u64() >> 40) as f32 / (1u32 << 23) as f32) - 1.0;
                }
            }
        }
    }

    /// Gather rows for `vertices` into a `[len, dim]` row-major buffer.
    pub fn gather(&self, vertices: &[Vid], out: &mut Vec<f32>) {
        let dim = self.dim();
        out.resize(vertices.len() * dim, 0.0);
        for (i, &v) in vertices.iter().enumerate() {
            let dst = &mut out[i * dim..(i + 1) * dim];
            self.copy_row(v, dst);
        }
    }
}

/// Node labels plus train/val split.
#[derive(Debug, Clone)]
pub struct LabelStore {
    pub labels: Vec<u32>,
    pub num_classes: usize,
    pub train_set: Vec<Vid>,
    pub val_set: Vec<Vid>,
}

impl LabelStore {
    /// Split vertices into train/val with the given train fraction.
    pub fn with_split(labels: Vec<u32>, train_frac: f64, seed: u64) -> Self {
        let num_classes = labels.iter().copied().max().unwrap_or(0) as usize + 1;
        let mut ids: Vec<Vid> = (0..labels.len() as Vid).collect();
        let mut rng = Pcg32::new(seed);
        rng.shuffle(&mut ids);
        let cut = (labels.len() as f64 * train_frac) as usize;
        let train_set = ids[..cut].to_vec();
        let val_set = ids[cut..].to_vec();
        LabelStore { labels, num_classes, train_set, val_set }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_roundtrip() {
        let fs = FeatureStore::dense(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let mut row = [0f32; 2];
        fs.copy_row(1, &mut row);
        assert_eq!(row, [3., 4.]);
        let mut out = Vec::new();
        fs.gather(&[2, 0], &mut out);
        assert_eq!(out, vec![5., 6., 1., 2.]);
    }

    #[test]
    fn lazy_rows_deterministic_and_distinct() {
        let fs = FeatureStore::lazy(100, 8, 42);
        let mut a = vec![0f32; 8];
        let mut b = vec![0f32; 8];
        fs.copy_row(7, &mut a);
        fs.copy_row(7, &mut b);
        assert_eq!(a, b);
        fs.copy_row(8, &mut b);
        assert_ne!(a, b);
        assert!(a.iter().all(|x| (-1.0..1.01).contains(x)));
    }

    #[test]
    fn correlated_features_are_separable() {
        // Mean distance between same-class rows should be far below
        // cross-class distance.
        let labels: Vec<u32> = (0..200).map(|i| (i % 2) as u32).collect();
        let fs = FeatureStore::correlated(&labels, 16, 0.1, 5);
        let mut r0 = vec![0f32; 16];
        let mut r2 = vec![0f32; 16];
        let mut r1 = vec![0f32; 16];
        fs.copy_row(0, &mut r0);
        fs.copy_row(2, &mut r2); // same class as 0
        fs.copy_row(1, &mut r1); // other class
        let d = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f32>().sqrt()
        };
        assert!(d(&r0, &r2) < d(&r0, &r1), "same-class rows should be closer");
    }

    #[test]
    fn feature_store_is_a_ram_tier_source() {
        // Through the trait object, an in-RAM store always classifies Ram
        // and returns the same bits as the inherent accessors.
        let fs = FeatureStore::lazy(10, 4, 7);
        let src: &dyn FeatureSource = &fs;
        assert_eq!(src.dim(), 4);
        assert_eq!(src.len(), 10);
        assert_eq!(src.row_bytes(), 16);
        assert!(!src.is_empty());
        let mut a = vec![0f32; 4];
        let mut b = vec![0f32; 4];
        fs.copy_row(3, &mut a);
        assert_eq!(src.fetch_row(3, &mut b), HostTier::Ram);
        assert_eq!(src.probe_row(3), HostTier::Ram);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        let mut g1 = Vec::new();
        let mut g2 = Vec::new();
        fs.gather(&[1, 9, 0], &mut g1);
        src.gather(&[1, 9, 0], &mut g2);
        assert_eq!(g1, g2);
    }

    #[test]
    fn label_split_partitions_vertices() {
        let labels: Vec<u32> = (0..100).map(|i| (i % 4) as u32).collect();
        let ls = LabelStore::with_split(labels, 0.8, 3);
        assert_eq!(ls.num_classes, 4);
        assert_eq!(ls.train_set.len(), 80);
        assert_eq!(ls.val_set.len(), 20);
        let mut all: Vec<Vid> = ls.train_set.iter().chain(&ls.val_set).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
    }
}
