//! Typed metrics registry: named [`Counter`]s and [`Gauge`]s with static
//! label sets, interned in a process-global [`Registry`] (DESIGN.md
//! §Observability).
//!
//! The loading stage, the resident cache, the out-of-core reader, and the
//! counting engines publish here, so the byte tiers and hit/miss rates the
//! repo previously exposed only as struct fields (`LoadStats`,
//! `IterCounters`) are also available as one snapshot-able blob — exported
//! next to the Chrome trace by [`chrome`](super::chrome).
//!
//! Handles are `Arc`s: look one up once (`registry().counter(...)`), keep
//! it, and update it with a single relaxed atomic add on the hot path.
//! Keys are `name{label=value,...}` with labels sorted by key, so the
//! snapshot ordering is deterministic.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::util::JsonValue;

/// Monotonically increasing u64 metric.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins f64 metric (stored as bits in an atomic).
#[derive(Debug)]
pub struct Gauge(AtomicU64);

impl Default for Gauge {
    fn default() -> Self {
        Gauge(AtomicU64::new(0f64.to_bits()))
    }
}

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Build the canonical `name{k=v,...}` key (labels sorted by key).
fn key(name: &str, labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return name.to_string();
    }
    let mut sorted: Vec<&(&str, &str)> = labels.iter().collect();
    sorted.sort_by_key(|(k, _)| *k);
    let mut out = String::with_capacity(name.len() + 16 * sorted.len());
    out.push_str(name);
    out.push('{');
    for (i, (k, v)) in sorted.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(k);
        out.push('=');
        out.push_str(v);
    }
    out.push('}');
    out
}

/// Process-global metric interner. Obtain it via [`registry`].
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
}

static REGISTRY: OnceLock<Registry> = OnceLock::new();

/// The global [`Registry`].
pub fn registry() -> &'static Registry {
    REGISTRY.get_or_init(Registry::default)
}

impl Registry {
    /// Intern (or fetch) the counter `name{labels}`.
    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        let mut map = self.counters.lock().expect("metrics registry poisoned");
        Arc::clone(map.entry(key(name, labels)).or_default())
    }

    /// Intern (or fetch) the gauge `name{labels}`.
    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
        let mut map = self.gauges.lock().expect("metrics registry poisoned");
        Arc::clone(map.entry(key(name, labels)).or_default())
    }

    /// Point-in-time copy of every registered metric.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let counters = self
            .counters
            .lock()
            .expect("metrics registry poisoned")
            .iter()
            .map(|(k, c)| (k.clone(), c.get()))
            .collect();
        let gauges = self
            .gauges
            .lock()
            .expect("metrics registry poisoned")
            .iter()
            .map(|(k, g)| (k.clone(), g.get()))
            .collect();
        MetricsSnapshot { counters, gauges }
    }

    /// Zero every registered metric (handles stay valid) — test/bench
    /// isolation.
    pub fn reset(&self) {
        for c in self.counters.lock().expect("metrics registry poisoned").values() {
            c.0.store(0, Ordering::Relaxed);
        }
        for g in self.gauges.lock().expect("metrics registry poisoned").values() {
            g.set(0.0);
        }
    }
}

/// Point-in-time values of every registered metric, keyed
/// `name{label=value,...}`.
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, f64>,
}

impl MetricsSnapshot {
    /// Counter value by full key, 0 when absent.
    pub fn counter(&self, key: &str) -> u64 {
        self.counters.get(key).copied().unwrap_or(0)
    }

    /// Gauge value by full key, 0.0 when absent.
    pub fn gauge(&self, key: &str) -> f64 {
        self.gauges.get(key).copied().unwrap_or(0.0)
    }

    /// The exported metrics blob (`{"counters": {...}, "gauges": {...}}`).
    pub fn to_json(&self) -> JsonValue {
        let counters = JsonValue::Obj(
            self.counters.iter().map(|(k, v)| (k.clone(), JsonValue::num(*v as f64))).collect(),
        );
        let gauges = JsonValue::Obj(
            self.gauges.iter().map(|(k, v)| (k.clone(), JsonValue::num(*v))).collect(),
        );
        JsonValue::obj(vec![("counters", counters), ("gauges", gauges)])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn key_is_label_order_invariant_and_sorted() {
        assert_eq!(key("m", &[]), "m");
        let fwd = key("m", &[("tier", "local"), ("scope", "train")]);
        let rev = key("m", &[("scope", "train"), ("tier", "local")]);
        assert_eq!(fwd, rev);
        assert_eq!(key("m", &[("b", "2"), ("a", "1")]), "m{a=1,b=2}");
    }

    #[test]
    fn counters_intern_and_accumulate() {
        let reg = registry();
        let a = reg.counter("obs_test_counter", &[("case", "intern")]);
        let b = reg.counter("obs_test_counter", &[("case", "intern")]);
        let before = a.get();
        a.add(3);
        b.inc();
        assert_eq!(a.get(), before + 4, "same key must intern to one counter");
        let snap = reg.snapshot();
        assert_eq!(snap.counter("obs_test_counter{case=intern}"), a.get());
    }

    #[test]
    fn gauges_hold_floats() {
        let reg = registry();
        let g = reg.gauge("obs_test_gauge", &[]);
        g.set(2.5);
        assert_eq!(g.get(), 2.5);
        assert_eq!(reg.snapshot().gauge("obs_test_gauge"), 2.5);
        assert_eq!(reg.snapshot().gauge("missing"), 0.0);
    }

    #[test]
    fn snapshot_json_shape() {
        let reg = registry();
        reg.counter("obs_test_json", &[("k", "v")]).add(7);
        let j = reg.snapshot().to_json();
        let c = j.get("counters").unwrap();
        assert!(c.as_obj().unwrap().contains_key("obs_test_json{k=v}"));
        assert!(j.get("gauges").unwrap().as_obj().is_some());
        // Round-trips through the writer/parser.
        let reparsed = JsonValue::parse(&j.to_string()).unwrap();
        let n = c.as_obj().unwrap().len();
        assert_eq!(reparsed.get("counters").unwrap().as_obj().unwrap().len(), n);
    }
}
