//! Observability: span tracing + the metrics registry (DESIGN.md
//! §Observability).
//!
//! The split-parallel pipeline can *account bytes* (IterCounters,
//! LoadStats) but byte counts cannot show **when** each device was
//! sampling, exchanging, or computing — pipeline bubbles, exchange stalls,
//! and disk-fetch tails are invisible. This module adds the time axis:
//!
//! * a process-global [`Tracer`] recording [`Span`]s into per-thread
//!   buffers. Tracing is a no-op unless enabled (`GSPLIT_TRACE=<path>`,
//!   [`set_enabled`], or `TrainConfig::trace` applied through
//!   `Trainer::with_config`); the disabled hot path is one relaxed atomic
//!   load;
//! * a typed [`metrics`] registry (`Counter` / `Gauge` with static label
//!   sets) that the loading tiers, the cache, and the engines publish
//!   into, so byte accounting is snapshot-able without hand-copying
//!   struct fields;
//! * Chrome trace-event export ([`chrome`]) — one track per worker thread
//!   plus one per simulated device — validated by
//!   `tools/check_trace_json.rs`.
//!
//! # Determinism
//!
//! Recording a span only reads the monotonic clock and appends to a
//! thread-local buffer; it never touches an RNG, a float, or any shared
//! training state. Tracing on/off therefore cannot change a single output
//! bit — `executor_equivalence.rs` and `oocr_equivalence.rs` prove it.
//!
//! # Hot-path cost and memory bounds
//!
//! Each thread appends finished spans to its own `Vec` behind a
//! `RefCell` — no lock, no atomic RMW — and flushes it into a shared,
//! registry-owned buffer when the thread exits (worker threads are
//! scoped, so their spans always outlive them) or when the owning thread
//! calls [`flush_thread`]. Buffers are bounded at [`span_cap`] spans per
//! thread (`GSPLIT_TRACE_CAP` overrides); past the cap new spans are
//! dropped and counted, so a runaway trace costs memory proportional to
//! thread count, never to run length.

pub mod chrome;
pub mod metrics;

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Default per-thread span capacity (~3 MiB of spans per thread).
pub const DEFAULT_SPAN_CAP: usize = 1 << 16;

/// Pipeline phase of a span — the **stable contract** between the
/// instrumentation, the Chrome exporter, `check_trace_json`, and the
/// fig3-style S/L/FB grouping. Renaming a phase is a breaking change to
/// every consumer of `GSPLIT_TRACE` output.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    /// Cooperative sampling (plan stage, the paper's S phase).
    Sample,
    /// Input-feature gather + tier classification (plan stage, L phase).
    Load,
    /// The pipelined coordinator preparing batch *t+1* while the workers
    /// train batch *t* (wraps a `Sample` + `Load` pair).
    SampleAhead,
    /// Pre-forward peer exchange of cache-resident rows.
    LoadExchange,
    /// Per-layer forward all-to-all, serial executor (single materialize
    /// loop — no send/recv split exists there).
    ShuffleFwd,
    /// Forward all-to-all, send half: packing owned rows into chunks.
    ShuffleFwdSend,
    /// Forward all-to-all, recv half: pumping the channel fabric.
    ShuffleFwdRecv,
    /// Per-layer reverse all-to-all, serial executor.
    ShuffleBwd,
    /// Reverse all-to-all, send half: per-device VJP gradient packing.
    ShuffleBwdSend,
    /// Reverse all-to-all, recv half: pump + fixed-order scatter-add.
    ShuffleBwdRecv,
    /// Per-device layer forward kernel.
    ComputeFwd,
    /// Per-device layer backward kernel (VJP).
    ComputeBwd,
    /// Per-device softmax-CE loss head.
    Loss,
    /// Coordinator's fixed-order gradient all-reduce + SGD step.
    GradReduce,
    /// `DiskFeatureStore` chunk fault (the disk tail of the L phase).
    DiskFetch,
    /// Offline `CacheStore::build` bulk read.
    CacheBuild,
    /// One served micro-batch end to end: admission-queue drain through
    /// response fan-out (`crate::serving`).
    ServeBatch,
    /// The split-parallel forward-only inference inside a served
    /// micro-batch (`Trainer::infer`: plan + exchange + compute).
    ServeInfer,
    /// Time inside a `crate::collectives` primitive (all-to-all pump,
    /// fixed-order all-reduce, job broadcast) — nested inside whatever
    /// pipeline phase opened the collective.
    Collective,
}

/// Paper-style grouping of [`Phase`]s into the Figure-3 S/L/FB breakdown.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PhaseGroup {
    /// Sampling (S).
    Sampling,
    /// Loading (L).
    Loading,
    /// Forward/backward compute + exchange (FB).
    Fb,
    /// Offline/one-time work outside the steady-state iteration.
    Offline,
    /// Online inference service work (`gsplit serve`), outside the
    /// training-iteration S/L/FB breakdown.
    Serving,
}

impl Phase {
    /// Every phase, for exhaustive iteration in validators and benches.
    pub const ALL: [Phase; 19] = [
        Phase::Sample,
        Phase::Load,
        Phase::SampleAhead,
        Phase::LoadExchange,
        Phase::ShuffleFwd,
        Phase::ShuffleFwdSend,
        Phase::ShuffleFwdRecv,
        Phase::ShuffleBwd,
        Phase::ShuffleBwdSend,
        Phase::ShuffleBwdRecv,
        Phase::ComputeFwd,
        Phase::ComputeBwd,
        Phase::Loss,
        Phase::GradReduce,
        Phase::DiskFetch,
        Phase::CacheBuild,
        Phase::ServeBatch,
        Phase::ServeInfer,
        Phase::Collective,
    ];

    /// Stable wire name (the Chrome event `cat` field).
    pub fn name(self) -> &'static str {
        match self {
            Phase::Sample => "sample",
            Phase::Load => "load",
            Phase::SampleAhead => "sample_ahead",
            Phase::LoadExchange => "load_exchange",
            Phase::ShuffleFwd => "shuffle_fwd",
            Phase::ShuffleFwdSend => "shuffle_fwd_send",
            Phase::ShuffleFwdRecv => "shuffle_fwd_recv",
            Phase::ShuffleBwd => "shuffle_bwd",
            Phase::ShuffleBwdSend => "shuffle_bwd_send",
            Phase::ShuffleBwdRecv => "shuffle_bwd_recv",
            Phase::ComputeFwd => "compute_fwd",
            Phase::ComputeBwd => "compute_bwd",
            Phase::Loss => "loss",
            Phase::GradReduce => "grad_reduce",
            Phase::DiskFetch => "disk_fetch",
            Phase::CacheBuild => "cache_build",
            Phase::ServeBatch => "serve_batch",
            Phase::ServeInfer => "serve_infer",
            Phase::Collective => "collective",
        }
    }

    /// Inverse of [`Phase::name`].
    pub fn parse(s: &str) -> Option<Phase> {
        Phase::ALL.iter().copied().find(|p| p.name() == s)
    }

    /// Where this phase lands in the paper's S/L/FB breakdown.
    pub fn group(self) -> PhaseGroup {
        match self {
            Phase::Sample | Phase::SampleAhead => PhaseGroup::Sampling,
            Phase::Load | Phase::LoadExchange | Phase::DiskFetch => PhaseGroup::Loading,
            Phase::CacheBuild => PhaseGroup::Offline,
            Phase::ServeBatch | Phase::ServeInfer => PhaseGroup::Serving,
            _ => PhaseGroup::Fb,
        }
    }
}

/// One finished timed interval. `device`, `batch`, and `layer` are `-1`
/// when not applicable; `t0`/`t1` are nanoseconds since the tracer epoch.
#[derive(Debug, Clone, Copy)]
pub struct Span {
    /// Display name (defaults to the phase name).
    pub name: &'static str,
    pub phase: Phase,
    pub device: i32,
    pub batch: i64,
    pub layer: i32,
    pub t0_ns: u64,
    pub t1_ns: u64,
}

impl Span {
    /// Duration in seconds.
    pub fn secs(&self) -> f64 {
        (self.t1_ns.saturating_sub(self.t0_ns)) as f64 * 1e-9
    }
}

/// Registry-owned side of one thread's span buffer: the flush target that
/// outlives the recording thread.
#[derive(Debug)]
pub struct Track {
    label: Mutex<String>,
    buf: Mutex<TrackBuf>,
}

#[derive(Debug, Default)]
struct TrackBuf {
    spans: Vec<Span>,
    dropped: u64,
}

impl Track {
    fn new(label: String) -> Track {
        Track { label: Mutex::new(label), buf: Mutex::new(TrackBuf::default()) }
    }
}

/// A snapshot of one track for export: label, spans, drop count.
#[derive(Debug, Clone)]
pub struct TrackSnapshot {
    pub label: String,
    pub spans: Vec<Span>,
    pub dropped: u64,
}

/// The process-global span recorder. Obtain it via [`tracer`].
pub struct Tracer {
    enabled: AtomicBool,
    epoch: Instant,
    cap: usize,
    /// Output path from `GSPLIT_TRACE`, if the env var enabled tracing.
    env_path: Option<String>,
    tracks: Mutex<Vec<Arc<Track>>>,
}

static TRACER: OnceLock<Tracer> = OnceLock::new();

/// The global [`Tracer`]. First call reads `GSPLIT_TRACE` (enables tracing
/// and remembers the export path) and `GSPLIT_TRACE_CAP`.
pub fn tracer() -> &'static Tracer {
    TRACER.get_or_init(|| {
        let env_path = std::env::var("GSPLIT_TRACE").ok().filter(|s| !s.is_empty());
        let cap = std::env::var("GSPLIT_TRACE_CAP")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&c| c > 0)
            .unwrap_or(DEFAULT_SPAN_CAP);
        Tracer {
            enabled: AtomicBool::new(env_path.is_some()),
            epoch: Instant::now(),
            cap,
            env_path,
            tracks: Mutex::new(Vec::new()),
        }
    })
}

impl Tracer {
    /// Whether spans are being recorded.
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Turn recording on or off (off discards nothing already recorded).
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// The export path `GSPLIT_TRACE` asked for, if any.
    pub fn env_path(&self) -> Option<&str> {
        self.env_path.as_deref()
    }

    /// Per-thread span capacity.
    pub fn span_cap(&self) -> usize {
        self.cap
    }

    /// Nanoseconds since the tracer epoch.
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    fn register(&self, label: String) -> Arc<Track> {
        let track = Arc::new(Track::new(label));
        self.tracks.lock().expect("tracer registry poisoned").push(Arc::clone(&track));
        track
    }

    /// Snapshot every track (flushed spans only — call [`flush_thread`] on
    /// a live thread first; exited threads flush automatically).
    pub fn snapshot(&self) -> Vec<TrackSnapshot> {
        let tracks = self.tracks.lock().expect("tracer registry poisoned");
        tracks
            .iter()
            .map(|t| {
                let label = t.label.lock().expect("track label poisoned").clone();
                let buf = t.buf.lock().expect("track buffer poisoned");
                TrackSnapshot { label, spans: buf.spans.clone(), dropped: buf.dropped }
            })
            .collect()
    }

    /// Drop every recorded span (labels and registration survive), so
    /// benches and tests can isolate runs. Flush the calling thread first.
    pub fn reset(&self) {
        flush_thread();
        let tracks = self.tracks.lock().expect("tracer registry poisoned");
        for t in tracks.iter() {
            let mut buf = t.buf.lock().expect("track buffer poisoned");
            buf.spans.clear();
            buf.dropped = 0;
        }
    }
}

/// Whether the global tracer is recording (one relaxed atomic load).
#[inline]
pub fn enabled() -> bool {
    tracer().enabled()
}

/// Enable or disable the global tracer (`TrainConfig::trace` forwards
/// here when applied).
pub fn set_enabled(on: bool) {
    tracer().set_enabled(on);
}

// Thread-local recording side: a plain Vec push behind a RefCell — no
// lock on the hot path. The shared Arc<Track> exists only so the spans
// survive thread exit (flushed by ThreadBuf::drop).
struct ThreadBuf {
    spans: Vec<Span>,
    dropped: u64,
    shared: Arc<Track>,
}

impl ThreadBuf {
    fn flush(&mut self) {
        if self.spans.is_empty() && self.dropped == 0 {
            return;
        }
        let mut buf = self.shared.buf.lock().expect("track buffer poisoned");
        buf.spans.append(&mut self.spans);
        buf.dropped += self.dropped;
        self.dropped = 0;
    }
}

impl Drop for ThreadBuf {
    fn drop(&mut self) {
        self.flush();
    }
}

thread_local! {
    static THREAD_BUF: RefCell<Option<ThreadBuf>> = const { RefCell::new(None) };
}

fn with_thread_buf(f: impl FnOnce(&mut ThreadBuf)) {
    THREAD_BUF.with(|cell| {
        let mut slot = cell.borrow_mut();
        let buf = slot.get_or_insert_with(|| {
            let label = std::thread::current()
                .name()
                .map(|n| n.to_string())
                .unwrap_or_else(|| format!("thread-{:?}", std::thread::current().id()));
            ThreadBuf { spans: Vec::new(), dropped: 0, shared: tracer().register(label) }
        });
        f(buf);
    });
}

/// Name the current thread's track in the exported trace (idempotent;
/// last label wins). Worker threads call this once at startup. A no-op
/// while tracing is disabled, so untraced runs never grow the registry.
pub fn set_thread_label(label: &str) {
    if !enabled() {
        return;
    }
    with_thread_buf(|buf| {
        *buf.shared.label.lock().expect("track label poisoned") = label.to_string();
    });
}

/// Push the current thread's unflushed spans into the shared registry so
/// [`Tracer::snapshot`] can see them. Threads that exit flush implicitly.
pub fn flush_thread() {
    with_thread_buf(ThreadBuf::flush);
}

fn record(span: Span) {
    let cap = tracer().span_cap();
    with_thread_buf(|buf| {
        if buf.spans.len() < cap {
            buf.spans.push(span);
        } else {
            buf.dropped += 1;
        }
    });
}

/// RAII span: records a [`Span`] from construction to drop. Inert (and
/// nearly free) when tracing is disabled at construction time.
#[must_use = "a TraceGuard records its span when dropped; bind it with `let _g = ...`"]
pub struct TraceGuard {
    /// `None` when tracing was disabled at construction.
    t0_ns: Option<u64>,
    name: &'static str,
    phase: Phase,
    device: i32,
    batch: i64,
    layer: i32,
}

impl TraceGuard {
    /// Override the display name (defaults to the phase name).
    pub fn named(mut self, name: &'static str) -> TraceGuard {
        self.name = name;
        self
    }

    /// Attach a device id.
    pub fn device(mut self, d: usize) -> TraceGuard {
        self.device = d as i32;
        self
    }

    /// Attach a batch index.
    pub fn batch(mut self, b: u64) -> TraceGuard {
        self.batch = b as i64;
        self
    }

    /// Attach a sampled-layer index.
    pub fn layer(mut self, l: usize) -> TraceGuard {
        self.layer = l as i32;
        self
    }
}

impl Drop for TraceGuard {
    fn drop(&mut self) {
        if let Some(t0) = self.t0_ns {
            record(Span {
                name: self.name,
                phase: self.phase,
                device: self.device,
                batch: self.batch,
                layer: self.layer,
                t0_ns: t0,
                t1_ns: tracer().now_ns(),
            });
        }
    }
}

/// Start a span of `phase` on the current thread. Prefer the [`span!`]
/// macro, which also sets the context fields.
#[inline]
pub fn span(phase: Phase) -> TraceGuard {
    let t = tracer();
    TraceGuard {
        t0_ns: if t.enabled() { Some(t.now_ns()) } else { None },
        name: phase.name(),
        phase,
        device: -1,
        batch: -1,
        layer: -1,
    }
}

/// Open an RAII trace span: `span!(Phase::ComputeFwd, device = d, batch =
/// b, layer = l)`. Context fields are optional and order-free; bind the
/// result (`let _g = span!(...)`) so the span closes at scope exit.
#[macro_export]
macro_rules! span {
    ($phase:expr $(, $field:ident = $value:expr)* $(,)?) => {
        $crate::obs::span($phase)$(.$field($value))*
    };
}

/// Serializes unit tests that toggle the process-global tracer, so a
/// concurrently running test cannot observe (or clobber) another test's
/// enabled state.
#[cfg(test)]
pub(crate) fn test_gate() -> std::sync::MutexGuard<'static, ()> {
    static GATE: Mutex<()> = Mutex::new(());
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lock() -> std::sync::MutexGuard<'static, ()> {
        test_gate()
    }

    #[test]
    fn phase_names_roundtrip_and_are_unique() {
        let mut seen = std::collections::HashSet::new();
        for p in Phase::ALL {
            assert!(seen.insert(p.name()), "duplicate phase name {}", p.name());
            assert_eq!(Phase::parse(p.name()), Some(p));
        }
        assert_eq!(Phase::parse("nonsense"), None);
    }

    #[test]
    fn phase_groups_cover_s_l_fb() {
        assert_eq!(Phase::Sample.group(), PhaseGroup::Sampling);
        assert_eq!(Phase::SampleAhead.group(), PhaseGroup::Sampling);
        assert_eq!(Phase::Load.group(), PhaseGroup::Loading);
        assert_eq!(Phase::DiskFetch.group(), PhaseGroup::Loading);
        assert_eq!(Phase::ComputeFwd.group(), PhaseGroup::Fb);
        assert_eq!(Phase::GradReduce.group(), PhaseGroup::Fb);
        assert_eq!(Phase::CacheBuild.group(), PhaseGroup::Offline);
        assert_eq!(Phase::ServeBatch.group(), PhaseGroup::Serving);
        assert_eq!(Phase::ServeInfer.group(), PhaseGroup::Serving);
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let _g = lock();
        let was = enabled();
        set_enabled(false);
        std::thread::scope(|s| {
            s.spawn(|| {
                set_thread_label("obs-disabled-test");
                let _s = span!(Phase::Sample, batch = 3);
            });
        });
        set_enabled(was);
        // Neither the label nor the span may have registered anything.
        let snap = tracer().snapshot();
        assert!(
            snap.iter().all(|t| t.label != "obs-disabled-test"),
            "disabled tracer must not register tracks or record spans"
        );
    }

    #[test]
    fn enabled_tracer_records_nested_spans_in_order() {
        let _g = lock();
        let was = enabled();
        set_enabled(true);
        tracer().reset();
        set_thread_label("obs-test");
        {
            let _outer = span!(Phase::SampleAhead, batch = 7);
            let _inner = span!(Phase::Sample, batch = 7);
        }
        flush_thread();
        set_enabled(was);
        let snap = tracer().snapshot();
        let track = snap
            .iter()
            .find(|t| t.label == "obs-test" && !t.spans.is_empty())
            .expect("test thread track");
        let sample = track.spans.iter().find(|s| s.phase == Phase::Sample).unwrap();
        let ahead = track.spans.iter().find(|s| s.phase == Phase::SampleAhead).unwrap();
        assert_eq!(sample.batch, 7);
        assert!(ahead.t0_ns <= sample.t0_ns, "parent starts first");
        assert!(sample.t1_ns <= ahead.t1_ns, "child ends first");
        assert!(sample.secs() >= 0.0);
    }

    #[test]
    fn span_cap_bounds_memory_and_counts_drops() {
        let _g = lock();
        let was = enabled();
        set_enabled(true);
        tracer().reset();
        let cap = tracer().span_cap();
        // Fill this thread's buffer past the cap on a fresh track.
        std::thread::scope(|s| {
            s.spawn(|| {
                set_thread_label("obs-cap-test");
                for _ in 0..cap + 10 {
                    let _s = span!(Phase::DiskFetch);
                }
            });
        });
        set_enabled(was);
        let snap = tracer().snapshot();
        let track = snap.iter().find(|t| t.label == "obs-cap-test").expect("cap test track");
        assert_eq!(track.spans.len(), cap);
        assert_eq!(track.dropped, 10);
    }

    #[test]
    fn guard_context_builders_set_fields() {
        let _g = lock();
        let was = enabled();
        set_enabled(true);
        tracer().reset();
        std::thread::scope(|s| {
            s.spawn(|| {
                set_thread_label("obs-ctx-test");
                let _s = span!(Phase::ComputeFwd, device = 2, batch = 5, layer = 1)
                    .named("custom");
            });
        });
        set_enabled(was);
        let snap = tracer().snapshot();
        let track = snap.iter().find(|t| t.label == "obs-ctx-test").expect("ctx test track");
        let s = &track.spans[0];
        assert_eq!((s.device, s.batch, s.layer, s.name), (2, 5, 1, "custom"));
        assert!(s.t1_ns >= s.t0_ns);
    }
}
