//! Chrome trace-event export of the recorded spans (DESIGN.md
//! §Observability).
//!
//! The output loads directly in Perfetto (<https://ui.perfetto.dev>) or
//! `chrome://tracing`: a JSON object whose `traceEvents` array holds one
//! complete (`"ph": "X"`) event per recorded [`Span`], grouped into
//!
//! * **pid 1 — "trainer threads"**: one track per recording thread
//!   (coordinator, `worker-0..n`) carrying the spans with no device
//!   attribution (sampling, exchanges, reductions), and
//! * **pid 2 — "devices"**: one track per simulated device carrying the
//!   per-device spans (compute, loss) regardless of which worker thread
//!   ran them — each device is owned by exactly one thread per run, so
//!   the track stays properly nested.
//!
//! The `cat` field is the stable [`Phase`] name; `ts`/`dur` are
//! microseconds since the tracer epoch. Events are globally sorted by
//! `ts` (ties: longer event first), which `tools/check_trace_json.rs`
//! verifies along with per-track nesting. The metrics registry snapshot
//! rides along under the top-level `"metrics"` key, and per-track drop
//! counts under `"otherData"` — both ignored by trace viewers.

use std::collections::BTreeSet;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::JsonValue;

use super::metrics::registry;
use super::{flush_thread, tracer, Span};

/// Trace-event pid of the per-thread tracks.
pub const PID_THREADS: u64 = 1;
/// Trace-event pid of the per-device tracks.
pub const PID_DEVICES: u64 = 2;

/// What an export wrote, for logging.
#[derive(Debug, Clone, Copy)]
pub struct ExportSummary {
    /// Thread tracks with at least one span.
    pub threads: usize,
    /// Distinct devices with at least one span.
    pub devices: usize,
    /// Complete (`"ph": "X"`) events written.
    pub events: usize,
    /// Spans lost to the per-thread ring cap.
    pub dropped: u64,
}

fn metadata(name: &str, pid: u64, tid: u64, value: &str) -> JsonValue {
    JsonValue::obj(vec![
        ("name", JsonValue::str(name)),
        ("ph", JsonValue::str("M")),
        ("pid", JsonValue::num(pid as f64)),
        ("tid", JsonValue::num(tid as f64)),
        ("args", JsonValue::obj(vec![("name", JsonValue::str(value))])),
    ])
}

fn complete_event(span: &Span, pid: u64, tid: u64) -> JsonValue {
    let mut args: Vec<(&str, JsonValue)> = Vec::new();
    if span.device >= 0 {
        args.push(("device", JsonValue::num(span.device as f64)));
    }
    if span.batch >= 0 {
        args.push(("batch", JsonValue::num(span.batch as f64)));
    }
    if span.layer >= 0 {
        args.push(("layer", JsonValue::num(span.layer as f64)));
    }
    JsonValue::obj(vec![
        ("name", JsonValue::str(span.name)),
        ("cat", JsonValue::str(span.phase.name())),
        ("ph", JsonValue::str("X")),
        ("ts", JsonValue::num(span.t0_ns as f64 / 1000.0)),
        ("dur", JsonValue::num(span.t1_ns.saturating_sub(span.t0_ns) as f64 / 1000.0)),
        ("pid", JsonValue::num(pid as f64)),
        ("tid", JsonValue::num(tid as f64)),
        ("args", JsonValue::obj(args)),
    ])
}

/// Build the trace JSON from everything recorded so far (plus the current
/// metrics snapshot). Flushes the calling thread first.
pub fn trace_json() -> (JsonValue, ExportSummary) {
    flush_thread();
    let snap = tracer().snapshot();

    let mut events: Vec<JsonValue> = Vec::new();
    let mut devices: BTreeSet<u64> = BTreeSet::new();
    // (t0, t1, pid, tid, span) — sorted so `ts` is globally monotone and,
    // at equal starts, enclosing spans precede their children.
    let mut timed: Vec<(u64, u64, u64, u64, Span)> = Vec::new();
    let mut threads = 0usize;
    let mut dropped = 0u64;

    for (i, track) in snap.iter().enumerate() {
        dropped += track.dropped;
        if track.spans.is_empty() {
            continue;
        }
        let tid = i as u64;
        threads += 1;
        events.push(metadata("thread_name", PID_THREADS, tid, &track.label));
        for span in &track.spans {
            if span.device >= 0 {
                let dev = span.device as u64;
                devices.insert(dev);
                timed.push((span.t0_ns, span.t1_ns, PID_DEVICES, dev, *span));
            } else {
                timed.push((span.t0_ns, span.t1_ns, PID_THREADS, tid, *span));
            }
        }
    }
    events.push(metadata("process_name", PID_THREADS, 0, "trainer threads"));
    events.push(metadata("process_name", PID_DEVICES, 0, "devices"));
    for &dev in &devices {
        let label = format!("device-{dev}");
        events.push(metadata("thread_name", PID_DEVICES, dev, &label));
    }

    timed.sort_by(|a, b| a.0.cmp(&b.0).then(b.1.cmp(&a.1)));
    let n_events = timed.len();
    for (_, _, pid, tid, span) in &timed {
        events.push(complete_event(span, *pid, *tid));
    }

    let summary = ExportSummary { threads, devices: devices.len(), events: n_events, dropped };
    let json = JsonValue::obj(vec![
        ("traceEvents", JsonValue::Arr(events)),
        ("displayTimeUnit", JsonValue::str("ms")),
        ("metrics", registry().snapshot().to_json()),
        ("otherData", JsonValue::obj(vec![("dropped_spans", JsonValue::num(dropped as f64))])),
    ]);
    (json, summary)
}

/// Export everything recorded so far as Chrome trace-event JSON at `path`.
pub fn export(path: &Path) -> Result<ExportSummary> {
    let (json, summary) = trace_json();
    std::fs::write(path, json.to_string()).with_context(|| format!("write trace {path:?}"))?;
    Ok(summary)
}

#[cfg(test)]
mod tests {
    use super::super::{set_enabled, set_thread_label, Phase};
    use super::*;

    #[test]
    fn exported_trace_is_valid_and_sorted() {
        let _gate = super::super::test_gate();
        let was = super::super::enabled();
        set_enabled(true);
        std::thread::scope(|s| {
            s.spawn(|| {
                set_thread_label("chrome-test");
                let _outer = crate::span!(Phase::SampleAhead, batch = 0);
                let _dev = crate::span!(Phase::ComputeFwd, device = 1, batch = 0, layer = 2);
            });
        });
        set_enabled(was);
        let (json, summary) = trace_json();
        assert!(summary.threads >= 1);
        assert!(summary.devices >= 1);
        assert!(summary.events >= 2);

        let reparsed = JsonValue::parse(&json.to_string()).unwrap();
        let events = reparsed.get("traceEvents").unwrap().as_arr().unwrap();
        let mut last_ts = f64::NEG_INFINITY;
        let mut saw_device_track = false;
        for ev in events {
            match ev.get("ph").unwrap().as_str().unwrap() {
                "M" => {
                    if ev.get("pid").unwrap().as_u64() == Some(PID_DEVICES)
                        && ev.get("name").unwrap().as_str() == Some("thread_name")
                    {
                        saw_device_track = true;
                    }
                }
                "X" => {
                    let ts = ev.get("ts").unwrap().as_f64().unwrap();
                    assert!(ts >= last_ts, "X events must be ts-sorted");
                    last_ts = ts;
                    assert!(ev.get("dur").unwrap().as_f64().unwrap() >= 0.0);
                    let cat = ev.get("cat").unwrap().as_str().unwrap();
                    assert!(Phase::parse(cat).is_some(), "unknown phase {cat}");
                }
                other => panic!("unexpected ph {other}"),
            }
        }
        assert!(saw_device_track, "device span must create a device track");
        assert!(reparsed.get("metrics").unwrap().get("counters").unwrap().as_obj().is_some());
    }
}
