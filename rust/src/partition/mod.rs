//! Offline graph partitioning (paper §5, second stage): weighted
//! min-edge-cut partitioning of the pre-sampled weighted graph `G_w`,
//! producing the global partitioning function `f_G : V → D` used online by
//! the splitter and by the cache placement.
//!
//! Four strategies, matching the paper's §7.3 comparison:
//! * [`Strategy::GSplit`] — pre-sampled vertex **and** edge weights
//!   (the paper's algorithm with probabilistic guarantees).
//! * [`Strategy::Node`]  — pre-sampled vertex weights, unweighted edges.
//! * [`Strategy::Edge`]  — no pre-sampling: balances edges + target
//!   vertices while min-cutting edge count (the common data-parallel
//!   partitioning, e.g. DistDGL).
//! * [`Strategy::Rand`]  — uniform random assignment.

mod metis_like;
mod quality;

pub use metis_like::{multilevel_partition, MultilevelParams};
pub use quality::{evaluate_minibatch, evaluate_partitioning, MiniBatchQuality, PartitionQuality};

use crate::graph::CsrGraph;
use crate::presample::PresampleWeights;
use crate::rng::Pcg32;
use crate::{DeviceId, Vid};

/// Partitioning strategy (paper §7.3 naming).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    GSplit,
    Node,
    Edge,
    Rand,
}

impl Strategy {
    pub fn parse(s: &str) -> anyhow::Result<Strategy> {
        Ok(match s {
            "gsplit" => Strategy::GSplit,
            "node" => Strategy::Node,
            "edge" => Strategy::Edge,
            "rand" | "random" => Strategy::Rand,
            other => anyhow::bail!("unknown partitioner `{other}` (gsplit|node|edge|rand)"),
        })
    }
}

/// The global partitioning function `f_G`: a static vertex → device map.
#[derive(Debug, Clone)]
pub struct Partitioning {
    pub assignment: Vec<DeviceId>,
    pub k: usize,
}

impl Partitioning {
    /// O(1) online lookup — the heart of "embarrassingly parallel
    /// constant-time splitting" (paper §5).
    #[inline]
    pub fn device_of(&self, v: Vid) -> DeviceId {
        self.assignment[v as usize]
    }

    /// Vertices per partition.
    pub fn sizes(&self) -> Vec<usize> {
        let mut sizes = vec![0usize; self.k];
        for &d in &self.assignment {
            sizes[d as usize] += 1;
        }
        sizes
    }
}

/// Balance slack ε of Eq. 2; the conventional METIS default.
pub const DEFAULT_EPSILON: f64 = 0.05;

/// Compute `f_G` for the given strategy.
///
/// * `weights` — pre-sampling counts (used by GSplit/Node; Edge/Rand ignore
///   them).
/// * `train_mask` — Edge additionally balances target (train) vertices, as
///   data-parallel systems do.
pub fn partition_graph(
    g: &CsrGraph,
    weights: &PresampleWeights,
    train_mask: &[bool],
    strategy: Strategy,
    k: usize,
    epsilon: f64,
    seed: u64,
) -> Partitioning {
    assert!(k >= 1 && k <= DeviceId::MAX as usize);
    assert_eq!(train_mask.len(), g.num_vertices());
    if k == 1 {
        return Partitioning { assignment: vec![0; g.num_vertices()], k };
    }
    match strategy {
        Strategy::Rand => {
            let mut rng = Pcg32::new(seed);
            let assignment =
                (0..g.num_vertices()).map(|_| rng.gen_range(k as u32) as DeviceId).collect();
            Partitioning { assignment, k }
        }
        Strategy::GSplit => {
            // Vertex load = k_v, edge weight = k_e (Eq. 2). Vertices never
            // sampled still need a home for caching: give them weight 0 —
            // they cost nothing during training — and edge weight 0 edges
            // are free to cut.
            let vw: Vec<u64> = weights.vertex.clone();
            let ew: Vec<u32> = weights.edge.clone();
            run_multilevel(g, vw, ew, k, epsilon, seed)
        }
        Strategy::Node => {
            let vw: Vec<u64> = weights.vertex.clone();
            let ew: Vec<u32> = vec![1; g.num_edges()];
            run_multilevel(g, vw, ew, k, epsilon, seed)
        }
        Strategy::Edge => {
            // Balance edges + target vertices (DistDGL-style): vertex load
            // = degree + λ·is_train with λ = avg degree, so a target vertex
            // "costs" about as much as an average vertex's edges.
            let lambda = g.avg_degree().ceil() as u64;
            let vw: Vec<u64> = (0..g.num_vertices())
                .map(|v| g.degree(v as Vid) as u64 + if train_mask[v] { lambda } else { 0 })
                .collect();
            let ew: Vec<u32> = vec![1; g.num_edges()];
            run_multilevel(g, vw, ew, k, epsilon, seed)
        }
    }
}

fn run_multilevel(
    g: &CsrGraph,
    vw: Vec<u64>,
    ew: Vec<u32>,
    k: usize,
    epsilon: f64,
    seed: u64,
) -> Partitioning {
    let params = MultilevelParams { k, epsilon, seed, ..Default::default() };
    let assignment = multilevel_partition(g, &vw, &ew, &params);
    Partitioning { assignment, k }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{rmat, sbm, GenParams};

    fn weights_for(g: &CsrGraph) -> PresampleWeights {
        PresampleWeights::uniform(g)
    }

    #[test]
    fn rand_covers_all_partitions() {
        let g = rmat(&GenParams { num_vertices: 4000, num_edges: 16000, seed: 2 });
        let w = weights_for(&g);
        let mask = vec![false; g.num_vertices()];
        let p = partition_graph(&g, &w, &mask, Strategy::Rand, 4, 0.05, 1);
        let sizes = p.sizes();
        assert_eq!(sizes.iter().sum::<usize>(), 4000);
        for s in sizes {
            assert!(s > 800, "random partition badly skewed: {s}");
        }
    }

    #[test]
    fn k1_is_trivial() {
        let g = rmat(&GenParams { num_vertices: 100, num_edges: 400, seed: 3 });
        let w = weights_for(&g);
        let mask = vec![false; 100];
        let p = partition_graph(&g, &w, &mask, Strategy::GSplit, 1, 0.05, 1);
        assert!(p.assignment.iter().all(|&d| d == 0));
    }

    #[test]
    fn edge_strategy_beats_rand_on_communities() {
        // On an SBM graph the min-cut partitioner should cut far fewer
        // edges than random assignment.
        let (g, _) = sbm(4000, 4, 8, 1, 5);
        let w = weights_for(&g);
        let mask = vec![true; g.num_vertices()];
        let rand = partition_graph(&g, &w, &mask, Strategy::Rand, 4, 0.05, 1);
        let edge = partition_graph(&g, &w, &mask, Strategy::Edge, 4, 0.05, 1);
        let cut = |p: &Partitioning| -> u64 {
            let mut c = 0;
            for v in 0..g.num_vertices() as Vid {
                for &u in g.neighbors(v) {
                    if p.device_of(u) != p.device_of(v) {
                        c += 1;
                    }
                }
            }
            c
        };
        let (cr, ce) = (cut(&rand), cut(&edge));
        assert!(
            (ce as f64) < 0.5 * cr as f64,
            "edge cut {ce} should be far below random cut {cr}"
        );
    }

    #[test]
    fn strategies_are_deterministic() {
        let g = rmat(&GenParams { num_vertices: 1000, num_edges: 5000, seed: 9 });
        let w = weights_for(&g);
        let mask = vec![false; 1000];
        for s in [Strategy::GSplit, Strategy::Node, Strategy::Edge, Strategy::Rand] {
            let a = partition_graph(&g, &w, &mask, s, 4, 0.05, 77);
            let b = partition_graph(&g, &w, &mask, s, 4, 0.05, 77);
            assert_eq!(a.assignment, b.assignment, "{s:?} not deterministic");
        }
    }

    #[test]
    fn parse_strategies() {
        assert_eq!(Strategy::parse("gsplit").unwrap(), Strategy::GSplit);
        assert_eq!(Strategy::parse("rand").unwrap(), Strategy::Rand);
        assert!(Strategy::parse("metis??").is_err());
    }
}
