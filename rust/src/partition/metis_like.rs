//! Multilevel weighted min-edge-cut partitioner (METIS-family heuristic,
//! built from scratch — METIS itself is not available offline, and the
//! paper only requires "a heuristic, for example Metis").
//!
//! Three classic phases:
//! 1. **Coarsening** — heavy-edge matching: repeatedly contract a maximal
//!    matching that prefers heavy edges, aggregating vertex and edge
//!    weights, until the graph is small.
//! 2. **Initial partitioning** — greedy graph growing on the coarsest
//!    graph: grow each part from a seed, absorbing the boundary vertex
//!    with the highest connection gain until the part reaches its load
//!    target.
//! 3. **Uncoarsening + refinement** — project the assignment back level by
//!    level and run boundary FM-style refinement: move boundary vertices
//!    to the neighbor part with maximal cut gain, subject to the (1+ε)
//!    balance constraint of Eq. 2.

use crate::graph::CsrGraph;
use crate::rng::Pcg32;
use crate::{DeviceId, Vid};

#[derive(Debug, Clone)]
pub struct MultilevelParams {
    pub k: usize,
    pub epsilon: f64,
    pub seed: u64,
    /// Stop coarsening when the graph has ≤ `coarsen_target_per_part × k`
    /// vertices.
    pub coarsen_target_per_part: usize,
    /// Maximum refinement passes per level.
    pub refine_passes: usize,
}

impl Default for MultilevelParams {
    fn default() -> Self {
        MultilevelParams {
            k: 2,
            epsilon: 0.05,
            seed: 0,
            coarsen_target_per_part: 64,
            refine_passes: 4,
        }
    }
}

/// Internal weighted graph used across coarsening levels (CSR with weights).
struct WGraph {
    offsets: Vec<u64>,
    adj: Vec<Vid>,
    ew: Vec<u64>,
    vw: Vec<u64>,
}

impl WGraph {
    fn n(&self) -> usize {
        self.vw.len()
    }

    #[inline]
    fn neighbors(&self, v: Vid) -> impl Iterator<Item = (Vid, u64)> + '_ {
        let (s, e) = (self.offsets[v as usize] as usize, self.offsets[v as usize + 1] as usize);
        self.adj[s..e].iter().copied().zip(self.ew[s..e].iter().copied())
    }

    fn total_vw(&self) -> u64 {
        self.vw.iter().sum()
    }
}

/// Entry point: returns the per-vertex part assignment.
pub fn multilevel_partition(
    g: &CsrGraph,
    vw: &[u64],
    ew: &[u32],
    params: &MultilevelParams,
) -> Vec<DeviceId> {
    assert_eq!(vw.len(), g.num_vertices());
    assert_eq!(ew.len(), g.num_edges());
    // Level 0: copy of the input. Edge weights get +1 so that structurally
    // present but never-pre-sampled edges still discourage cutting slightly
    // (ties broken toward locality); this matches METIS's behaviour of
    // requiring positive weights.
    let base = WGraph {
        offsets: g.offsets().to_vec(),
        adj: g.adj().to_vec(),
        ew: ew.iter().map(|&w| w as u64 + 1).collect(),
        vw: vw.iter().map(|&w| w + 1).collect(),
    };

    // --- Phase 1: coarsen ---
    let mut levels: Vec<WGraph> = vec![base];
    let mut maps: Vec<Vec<Vid>> = Vec::new(); // fine vertex -> coarse vertex
    let target = params.coarsen_target_per_part * params.k;
    let mut rng = Pcg32::new(params.seed ^ 0xC0A5);
    loop {
        let cur = levels.last().unwrap();
        if cur.n() <= target {
            break;
        }
        let (map, coarse_n) = heavy_edge_matching(cur, &mut rng);
        // Stalled (e.g. star graphs where matching can't shrink much).
        if coarse_n as f64 > cur.n() as f64 * 0.95 {
            break;
        }
        let coarse = contract(cur, &map, coarse_n);
        maps.push(map);
        levels.push(coarse);
    }

    // --- Phase 2: initial partition on the coarsest graph ---
    let coarsest = levels.last().unwrap();
    let mut assign = greedy_growing(coarsest, params, &mut rng);
    refine(coarsest, &mut assign, params);

    // --- Phase 3: uncoarsen + refine ---
    for lvl in (0..maps.len()).rev() {
        let fine = &levels[lvl];
        let map = &maps[lvl];
        let mut fine_assign = vec![0 as DeviceId; fine.n()];
        for v in 0..fine.n() {
            fine_assign[v] = assign[map[v] as usize];
        }
        assign = fine_assign;
        refine(fine, &mut assign, params);
    }
    assign
}

/// Heavy-edge matching: visit vertices in random order; match each
/// unmatched vertex with its unmatched neighbor of maximal edge weight.
/// Returns (fine→coarse map, number of coarse vertices).
fn heavy_edge_matching(g: &WGraph, rng: &mut Pcg32) -> (Vec<Vid>, usize) {
    let n = g.n();
    let mut order: Vec<Vid> = (0..n as Vid).collect();
    rng.shuffle(&mut order);
    let mut mate: Vec<Vid> = vec![Vid::MAX; n];
    for &v in &order {
        if mate[v as usize] != Vid::MAX {
            continue;
        }
        let mut best: Option<(Vid, u64)> = None;
        for (u, w) in g.neighbors(v) {
            if u != v && mate[u as usize] == Vid::MAX && best.map(|(_, bw)| w > bw).unwrap_or(true)
            {
                best = Some((u, w));
            }
        }
        match best {
            Some((u, _)) => {
                mate[v as usize] = u;
                mate[u as usize] = v;
            }
            None => mate[v as usize] = v, // self-matched (stays single)
        }
    }
    // Assign coarse ids.
    let mut map = vec![Vid::MAX; n];
    let mut next = 0 as Vid;
    for v in 0..n as Vid {
        if map[v as usize] != Vid::MAX {
            continue;
        }
        let m = mate[v as usize];
        map[v as usize] = next;
        if m != Vid::MAX && m != v {
            map[m as usize] = next;
        }
        next += 1;
    }
    (map, next as usize)
}

/// Contract matched pairs into a coarse WGraph, summing weights and merging
/// parallel edges.
fn contract(g: &WGraph, map: &[Vid], coarse_n: usize) -> WGraph {
    let mut vw = vec![0u64; coarse_n];
    for v in 0..g.n() {
        vw[map[v] as usize] += g.vw[v];
    }
    // Count coarse degrees (upper bound: sum of member degrees).
    let mut counts = vec![0u64; coarse_n + 1];
    for v in 0..g.n() as Vid {
        let c = map[v as usize] as usize;
        let deg = (g.offsets[v as usize + 1] - g.offsets[v as usize]) as u64;
        counts[c + 1] += deg;
    }
    for i in 0..coarse_n {
        counts[i + 1] += counts[i];
    }
    let total = counts[coarse_n] as usize;
    let mut adj = vec![0 as Vid; total];
    let mut ew = vec![0u64; total];
    let mut cursor = counts.clone();
    for v in 0..g.n() as Vid {
        let cv = map[v as usize];
        for (u, w) in g.neighbors(v) {
            let cu = map[u as usize];
            if cu == cv {
                continue; // contracted edge disappears
            }
            let slot = &mut cursor[cv as usize];
            adj[*slot as usize] = cu;
            ew[*slot as usize] = w;
            *slot += 1;
        }
    }
    // Per-row sort + merge duplicates, then rebuild tight CSR.
    let mut new_offsets = vec![0u64; coarse_n + 1];
    let mut write = 0usize;
    for c in 0..coarse_n {
        let (s, e) = (counts[c] as usize, cursor[c] as usize);
        // sort the row by neighbor id (pair sort over (adj, ew))
        let mut row: Vec<(Vid, u64)> =
            adj[s..e].iter().copied().zip(ew[s..e].iter().copied()).collect();
        row.sort_unstable_by_key(|&(u, _)| u);
        let row_start = write;
        let mut last: Option<Vid> = None;
        for (u, w) in row {
            if last == Some(u) {
                ew[write - 1] += w;
            } else {
                adj[write] = u;
                ew[write] = w;
                write += 1;
                last = Some(u);
            }
        }
        new_offsets[c] = row_start as u64;
    }
    new_offsets[coarse_n] = write as u64;
    adj.truncate(write);
    ew.truncate(write);
    WGraph { offsets: new_offsets, adj, ew, vw }
}

/// Greedy graph growing initial partitioning.
fn greedy_growing(g: &WGraph, params: &MultilevelParams, rng: &mut Pcg32) -> Vec<DeviceId> {
    let n = g.n();
    let k = params.k;
    let total = g.total_vw();
    let target = total as f64 / k as f64;
    let mut assign = vec![DeviceId::MAX; n];
    let mut loads = vec![0u64; k];
    for part in 0..k {
        // Seed: random unassigned vertex.
        let mut seed = None;
        for _ in 0..64 {
            let v = rng.gen_range(n as u32);
            if assign[v as usize] == DeviceId::MAX {
                seed = Some(v);
                break;
            }
        }
        let seed = match seed.or_else(|| {
            (0..n as Vid).find(|&v| assign[v as usize] == DeviceId::MAX)
        }) {
            Some(s) => s,
            None => break,
        };
        // Grow: frontier of candidate vertices with gains = connection
        // weight to this part. Simple binary-heap growing.
        use std::collections::BinaryHeap;
        let mut heap: BinaryHeap<(u64, Vid)> = BinaryHeap::new();
        heap.push((1, seed));
        while loads[part] as f64 <= target && !heap.is_empty() {
            let (_, v) = heap.pop().unwrap();
            if assign[v as usize] != DeviceId::MAX {
                continue;
            }
            assign[v as usize] = part as DeviceId;
            loads[part] += g.vw[v as usize];
            for (u, w) in g.neighbors(v) {
                if assign[u as usize] == DeviceId::MAX {
                    heap.push((w, u));
                }
            }
        }
    }
    // Leftovers: assign to the lightest part.
    for v in 0..n {
        if assign[v] == DeviceId::MAX {
            let lightest =
                (0..k).min_by_key(|&p| loads[p]).expect("k >= 1");
            assign[v] = lightest as DeviceId;
            loads[lightest] += g.vw[v];
        }
    }
    assign
}

/// Boundary FM-style refinement: greedy single-vertex moves that improve
/// the cut while keeping every part ≤ (1+ε)·(total/k).
fn refine(g: &WGraph, assign: &mut [DeviceId], params: &MultilevelParams) {
    let n = g.n();
    let k = params.k;
    let total = g.total_vw();
    let max_load = ((total as f64 / k as f64) * (1.0 + params.epsilon)).ceil() as u64;
    let mut loads = vec![0u64; k];
    for v in 0..n {
        loads[assign[v] as usize] += g.vw[v];
    }
    // conn[p] reused per-vertex: connection weight of v to part p.
    let mut conn = vec![0u64; k];
    for _pass in 0..params.refine_passes {
        let mut moved = 0usize;
        for v in 0..n as Vid {
            let from = assign[v as usize] as usize;
            // Compute connection weights; skip interior vertices fast.
            let mut boundary = false;
            conn.iter_mut().for_each(|c| *c = 0);
            for (u, w) in g.neighbors(v) {
                let pu = assign[u as usize] as usize;
                conn[pu] += w;
                if pu != from {
                    boundary = true;
                }
            }
            if !boundary {
                continue;
            }
            // Best destination by gain = conn[to] - conn[from].
            let mut best: Option<(usize, i64)> = None;
            for to in 0..k {
                if to == from {
                    continue;
                }
                if loads[to] + g.vw[v as usize] > max_load {
                    continue;
                }
                let gain = conn[to] as i64 - conn[from] as i64;
                if gain > 0 && best.map(|(_, bg)| gain > bg).unwrap_or(true) {
                    best = Some((to, gain));
                }
            }
            if let Some((to, _)) = best {
                assign[v as usize] = to as DeviceId;
                loads[from] -= g.vw[v as usize];
                loads[to] += g.vw[v as usize];
                moved += 1;
            }
        }
        if moved == 0 {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{sbm, rmat, GenParams};

    fn cut_of(g: &CsrGraph, ew: &[u32], assign: &[DeviceId]) -> u64 {
        let mut cut = 0u64;
        for v in 0..g.num_vertices() as Vid {
            for (i, &u) in g.neighbors(v).iter().enumerate() {
                if assign[u as usize] != assign[v as usize] {
                    cut += ew[g.edge_id(v, i as u32) as usize] as u64;
                }
            }
        }
        cut
    }

    fn balance_of(vw: &[u64], assign: &[DeviceId], k: usize) -> f64 {
        let mut loads = vec![0u64; k];
        for (v, &p) in assign.iter().enumerate() {
            loads[p as usize] += vw[v] + 1; // +1 matches internal weighting
        }
        let total: u64 = loads.iter().sum();
        let max = *loads.iter().max().unwrap() as f64;
        max / (total as f64 / k as f64)
    }

    #[test]
    fn recovers_sbm_communities() {
        let (g, labels) = sbm(2000, 4, 10, 1, 3);
        let vw = vec![1u64; g.num_vertices()];
        let ew = vec![1u32; g.num_edges()];
        let params = MultilevelParams { k: 4, epsilon: 0.05, seed: 1, ..Default::default() };
        let assign = multilevel_partition(&g, &vw, &ew, &params);
        // The cut should be close to the number of inter-community edges,
        // i.e. far below a random 4-way cut (≈ 75% of edges).
        let cut = cut_of(&g, &ew, &assign);
        let m = g.num_edges() as u64;
        assert!(cut < m / 4, "cut={cut} of m={m}");
        // Most pairs within a community should be co-located.
        let mut agree = 0u64;
        let mut total = 0u64;
        for v in 0..g.num_vertices() {
            for u in 0..100 {
                if labels[v] == labels[u] {
                    total += 1;
                    if assign[v] == assign[u] {
                        agree += 1;
                    }
                }
            }
        }
        assert!(agree as f64 / total as f64 > 0.5, "{agree}/{total}");
    }

    #[test]
    fn respects_balance_constraint() {
        let g = rmat(&GenParams { num_vertices: 3000, num_edges: 15000, seed: 6 });
        let vw = vec![1u64; g.num_vertices()];
        let ew = vec![1u32; g.num_edges()];
        for k in [2, 4, 8] {
            let params = MultilevelParams { k, epsilon: 0.05, seed: 2, ..Default::default() };
            let assign = multilevel_partition(&g, &vw, &ew, &params);
            let bal = balance_of(&vw, &assign, k);
            // Initial growing can overshoot slightly before refinement, so
            // allow modest slack over (1+ε).
            assert!(bal < 1.25, "k={k} balance={bal}");
            // All parts non-empty.
            let mut sizes = vec![0; k];
            for &p in &assign {
                sizes[p as usize] += 1;
            }
            assert!(sizes.iter().all(|&s| s > 0), "k={k} sizes={sizes:?}");
        }
    }

    #[test]
    fn weighted_edges_steer_the_cut() {
        // Two cliques joined by heavy edges within and light across:
        // partitioner must cut the light edges.
        let mut b = crate::graph::GraphBuilder::new(20).symmetric();
        for i in 0..10u32 {
            for j in (i + 1)..10 {
                b.add_edge(i, j);
                b.add_edge(i + 10, j + 10);
            }
        }
        // bridges
        b.add_edge(0, 10);
        b.add_edge(5, 15);
        let g = b.finish();
        let vw = vec![1u64; 20];
        let ew = vec![1u32; g.num_edges()];
        let params = MultilevelParams { k: 2, epsilon: 0.3, seed: 3, ..Default::default() };
        let assign = multilevel_partition(&g, &vw, &ew, &params);
        // Each clique must land in one part.
        for i in 1..10 {
            assert_eq!(assign[i], assign[0], "clique A split");
            assert_eq!(assign[i + 10], assign[10], "clique B split");
        }
        assert_ne!(assign[0], assign[10]);
    }

    #[test]
    fn heavy_vertices_count_toward_balance() {
        // One vertex with huge weight: its part should get few others.
        let g = rmat(&GenParams { num_vertices: 1000, num_edges: 4000, seed: 8 });
        let mut vw = vec![1u64; 1000];
        vw[0] = 400; // ≈ half the total load by itself
        let ew = vec![1u32; g.num_edges()];
        let params = MultilevelParams { k: 2, epsilon: 0.10, seed: 4, ..Default::default() };
        let assign = multilevel_partition(&g, &vw, &ew, &params);
        let part0 = assign[0];
        let light_in_part0 =
            (1..1000).filter(|&v| assign[v] == part0).count();
        assert!(
            light_in_part0 < 700,
            "heavy vertex's part also got {light_in_part0} light vertices"
        );
    }
}
