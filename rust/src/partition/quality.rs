//! Partitioning quality metrics: expected cut, expected load balance, and
//! per-mini-batch realized metrics (the quantities plotted in Figure 5).

use crate::graph::CsrGraph;
use crate::partition::Partitioning;
use crate::presample::PresampleWeights;
use crate::sampling::MiniBatch;
use crate::Vid;

/// Offline (expected) quality of a partitioning under pre-sample weights.
#[derive(Debug, Clone, PartialEq)]
pub struct PartitionQuality {
    /// Σ_{e ∈ C} k_e — the objective of Eq. 2 (∝ E[Y]).
    pub expected_cut: u64,
    /// Σ k_e over all edges (for reporting the cut as a fraction).
    pub total_edge_weight: u64,
    /// L_i = Σ_{v ∈ P_i} k_v.
    pub loads: Vec<u64>,
    /// max_i L_i / (L / k): 1.0 is perfect balance.
    pub imbalance: f64,
}

impl PartitionQuality {
    pub fn cut_fraction(&self) -> f64 {
        if self.total_edge_weight == 0 {
            0.0
        } else {
            self.expected_cut as f64 / self.total_edge_weight as f64
        }
    }
}

/// Evaluate the Eq. 2 objective and constraint for a partitioning.
pub fn evaluate_partitioning(
    g: &CsrGraph,
    w: &PresampleWeights,
    p: &Partitioning,
) -> PartitionQuality {
    let mut expected_cut = 0u64;
    let mut total_edge_weight = 0u64;
    for v in 0..g.num_vertices() as Vid {
        let pv = p.device_of(v);
        for (i, &u) in g.neighbors(v).iter().enumerate() {
            let we = w.edge[g.edge_id(v, i as u32) as usize] as u64;
            total_edge_weight += we;
            if p.device_of(u) != pv {
                expected_cut += we;
            }
        }
    }
    let mut loads = vec![0u64; p.k];
    for v in 0..g.num_vertices() {
        loads[p.assignment[v] as usize] += w.vertex[v];
    }
    let total: u64 = loads.iter().sum();
    let imbalance = if total == 0 {
        1.0
    } else {
        *loads.iter().max().unwrap() as f64 / (total as f64 / p.k as f64)
    };
    PartitionQuality { expected_cut, total_edge_weight, loads, imbalance }
}

/// Realized per-mini-batch metrics (Figure 5): workload imbalance = max
/// edges per split / average, communication = fraction of sampled edges
/// whose endpoints fall in different splits.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MiniBatchQuality {
    pub imbalance: f64,
    pub cross_edge_fraction: f64,
}

/// Measure the realized split quality of a sampled mini-batch under `p`.
pub fn evaluate_minibatch(mb: &MiniBatch, p: &Partitioning) -> MiniBatchQuality {
    let mut edges_per_split = vec![0u64; p.k];
    let mut cross = 0u64;
    let mut total = 0u64;
    for layer in &mb.layers {
        for (i, &d) in layer.dst.iter().enumerate() {
            let pd = p.device_of(d);
            // Edges of d are processed by d's split (its GPU aggregates
            // them), so they count toward that split's load.
            let cnt = layer.neigh_len[i] as u64;
            edges_per_split[pd as usize] += cnt;
            total += cnt;
            for &j in layer.neighbors_of(i) {
                if p.device_of(layer.src[j as usize]) != pd {
                    cross += 1;
                }
            }
        }
    }
    let avg = total as f64 / p.k as f64;
    let max = *edges_per_split.iter().max().unwrap_or(&0) as f64;
    MiniBatchQuality {
        imbalance: if avg > 0.0 { max / avg } else { 1.0 },
        cross_edge_fraction: if total > 0 { cross as f64 / total as f64 } else { 0.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{rmat, GenParams};
    use crate::partition::{partition_graph, Strategy};
    use crate::rng::Pcg32;
    use crate::sampling::Sampler;

    #[test]
    fn expected_cut_zero_for_k1() {
        let g = rmat(&GenParams { num_vertices: 500, num_edges: 2500, seed: 4 });
        let w = PresampleWeights::uniform(&g);
        let mask = vec![false; 500];
        let p = partition_graph(&g, &w, &mask, Strategy::GSplit, 1, 0.05, 1);
        let q = evaluate_partitioning(&g, &w, &p);
        assert_eq!(q.expected_cut, 0);
        assert!((q.imbalance - 1.0).abs() < 1e-9);
        assert_eq!(q.cut_fraction(), 0.0);
    }

    #[test]
    fn rand_cut_fraction_near_three_quarters() {
        // Random 4-way assignment cuts ~75% of edges (the Fig. 5 anchor).
        let g = rmat(&GenParams { num_vertices: 4000, num_edges: 20000, seed: 5 });
        let w = PresampleWeights::uniform(&g);
        let mask = vec![false; 4000];
        let p = partition_graph(&g, &w, &mask, Strategy::Rand, 4, 0.05, 2);
        let q = evaluate_partitioning(&g, &w, &p);
        assert!(
            (q.cut_fraction() - 0.75).abs() < 0.03,
            "cut fraction {}",
            q.cut_fraction()
        );
    }

    #[test]
    fn minibatch_metrics_in_range() {
        let g = rmat(&GenParams { num_vertices: 2000, num_edges: 10000, seed: 6 });
        let w = PresampleWeights::uniform(&g);
        let mask = vec![false; 2000];
        let p = partition_graph(&g, &w, &mask, Strategy::Rand, 4, 0.05, 3);
        let mut s = Sampler::new();
        let mut rng = Pcg32::new(1);
        let targets: Vec<Vid> = (0..256).collect();
        let mb = s.sample(&g, &targets, &[5, 5], &mut rng);
        let q = evaluate_minibatch(&mb, &p);
        assert!(q.imbalance >= 1.0);
        assert!((0.0..=1.0).contains(&q.cross_edge_fraction));
        // Random split of a random graph: expect lots of cross edges.
        assert!(q.cross_edge_fraction > 0.5, "{}", q.cross_edge_fraction);
    }
}
