//! SplitMix64 and PCG32 generators.

/// SplitMix64 (Steele, Lea, Flood 2014). Used for seeding and cheap one-shot
/// hashing of ids into streams.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG32 (XSH-RR 64/32, O'Neill 2014): 64-bit LCG state, 32-bit output with
/// xorshift-high + random rotation. Small state, passes BigCrush, and the
/// `gen_range` path below is the hot instruction sequence of the neighbor
/// sampler.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    const MUL: u64 = 6364136223846793005;

    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(Self::MUL).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` using Lemire's multiply-shift with rejection.
    /// Branch-predictable in the common case; the rejection loop triggers
    /// with probability < 2^-32 for small bounds.
    #[inline]
    pub fn gen_range(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (bound as u64);
        let mut l = m as u32;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (bound as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Standard normal via Box–Muller (used for feature synthesis and
    /// parameter init checks; not on the sampling hot path).
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            let u2 = self.next_f64();
            if u1 > 1e-12 {
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pcg_reference_stream_is_stable() {
        // Golden values pin the implementation: any change to the generator
        // invalidates recorded experiments, so this must never drift.
        let mut r = Pcg32::new(42);
        let got: Vec<u32> = (0..4).map(|_| r.next_u32()).collect();
        let again: Vec<u32> = {
            let mut r = Pcg32::new(42);
            (0..4).map(|_| r.next_u32()).collect()
        };
        assert_eq!(got, again);
        let mut other = Pcg32::new(43);
        assert_ne!(got[0], other.next_u32());
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Pcg32::new(7);
        for bound in [1u32, 2, 3, 10, 1000, u32::MAX] {
            for _ in 0..200 {
                assert!(r.gen_range(bound) < bound);
            }
        }
    }

    #[test]
    fn gen_range_is_roughly_uniform() {
        let mut r = Pcg32::new(123);
        let mut hist = [0u32; 8];
        let n = 80_000;
        for _ in 0..n {
            hist[r.gen_range(8) as usize] += 1;
        }
        let expect = n as f64 / 8.0;
        for h in hist {
            assert!((h as f64 - expect).abs() < expect * 0.05, "hist={hist:?}");
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg32::new(5);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Pcg32::new(99);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "shuffle left identity");
    }
}
