//! Sampling primitives used by the neighbor sampler.

use super::Pcg32;

/// Sample `k` distinct items from `0..n` **without replacement**.
///
/// This matches DGL's default `sample_neighbors(..., replace=False)`
/// semantics used by the paper's "standard neighborhood sampling": if a
/// vertex has ≤ k neighbors, all of them are taken.
///
/// Two regimes:
/// * `k >= n`: take everything (no RNG needed).
/// * `k < n`: Floyd's algorithm — O(k) time, O(k) space, no allocation of
///   the full range. Output order is randomized by construction.
pub fn sample_without_replacement(rng: &mut Pcg32, n: u32, k: u32, out: &mut Vec<u32>) {
    out.clear();
    if n == 0 || k == 0 {
        return;
    }
    if k >= n {
        out.extend(0..n);
        return;
    }
    // Robert Floyd's sampling algorithm. For the small k (fanout 5..25) and
    // small n (vertex degree) in GNN sampling, the linear containment scan
    // beats a hash set by a wide margin.
    for j in (n - k)..n {
        let t = rng.gen_range(j + 1);
        if out.contains(&t) {
            out.push(j);
        } else {
            out.push(t);
        }
    }
}

/// Classic reservoir sampling over an iterator, used by pre-sampling
/// validation and tests (not on the hot path).
pub fn reservoir_sample<T: Copy>(rng: &mut Pcg32, items: impl Iterator<Item = T>, k: usize) -> Vec<T> {
    let mut reservoir: Vec<T> = Vec::with_capacity(k);
    for (i, item) in items.enumerate() {
        if i < k {
            reservoir.push(item);
        } else {
            let j = rng.gen_range(i as u32 + 1) as usize;
            if j < k {
                reservoir[j] = item;
            }
        }
    }
    reservoir
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn without_replacement_distinct_and_in_range() {
        let mut rng = Pcg32::new(11);
        let mut out = Vec::new();
        for n in [1u32, 2, 5, 16, 100] {
            for k in [1u32, 2, 5, 15, 99, 200] {
                sample_without_replacement(&mut rng, n, k, &mut out);
                assert_eq!(out.len() as u32, k.min(n), "n={n} k={k}");
                let mut sorted = out.clone();
                sorted.sort_unstable();
                sorted.dedup();
                assert_eq!(sorted.len(), out.len(), "duplicates for n={n} k={k}");
                assert!(out.iter().all(|&x| x < n));
            }
        }
    }

    #[test]
    fn without_replacement_covers_uniformly() {
        // Each element of 0..n should appear with probability k/n.
        let (n, k, trials) = (20u32, 5u32, 40_000);
        let mut rng = Pcg32::new(77);
        let mut hits = vec![0u32; n as usize];
        let mut out = Vec::new();
        for _ in 0..trials {
            sample_without_replacement(&mut rng, n, k, &mut out);
            for &x in &out {
                hits[x as usize] += 1;
            }
        }
        let expect = trials as f64 * k as f64 / n as f64;
        for (i, h) in hits.iter().enumerate() {
            assert!(
                (*h as f64 - expect).abs() < expect * 0.08,
                "element {i}: {h} vs {expect}"
            );
        }
    }

    #[test]
    fn zero_cases() {
        let mut rng = Pcg32::new(1);
        let mut out = vec![9];
        sample_without_replacement(&mut rng, 0, 3, &mut out);
        assert!(out.is_empty());
        sample_without_replacement(&mut rng, 3, 0, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn reservoir_size_and_membership() {
        let mut rng = Pcg32::new(5);
        let s = reservoir_sample(&mut rng, 0..1000u32, 10);
        assert_eq!(s.len(), 10);
        assert!(s.iter().all(|&x| x < 1000));
    }
}
