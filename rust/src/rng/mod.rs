//! Deterministic pseudo-random number generation and sampling primitives.
//!
//! The `rand` crate family is not available offline, so we implement the two
//! generators the system needs: **SplitMix64** for seeding / stream derivation
//! and **PCG32 (XSH-RR)** as the workhorse generator for neighbor sampling.
//! Both are well-studied, tiny, and fast; determinism across runs is a hard
//! requirement for reproducible experiments (every engine, pre-sampling run,
//! and benchmark takes an explicit seed).

mod pcg;
mod sample;

pub use pcg::{Pcg32, SplitMix64};
pub use sample::{reservoir_sample, sample_without_replacement};

/// Derive a child seed from a base seed and a stream label. Used to give
/// each (epoch, iteration, device, purpose) tuple an independent stream so
/// parallel sampling is deterministic regardless of thread scheduling.
pub fn derive_seed(base: u64, label: &[u64]) -> u64 {
    let mut sm = SplitMix64::new(base);
    let mut acc = sm.next_u64();
    for &l in label {
        // Mix in each label word through a fresh SplitMix state.
        let mut s = SplitMix64::new(acc ^ l.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        acc = s.next_u64();
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derive_is_deterministic_and_label_sensitive() {
        let a = derive_seed(42, &[1, 2, 3]);
        let b = derive_seed(42, &[1, 2, 3]);
        let c = derive_seed(42, &[1, 2, 4]);
        let d = derive_seed(43, &[1, 2, 3]);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_ne!(a, d);
    }

    #[test]
    fn derive_order_sensitive() {
        assert_ne!(derive_seed(7, &[1, 2]), derive_seed(7, &[2, 1]));
    }
}
