//! **§7.3 "Cost of the splitting algorithm"** — wall-clock time of the two
//! offline stages (pre-sampling with 10 epochs, weighted min-cut
//! partitioning) on every graph. These are real measured seconds of this
//! implementation on this machine (the paper reports 19–288 s pre-sampling
//! on 4×RTX3090 and 14–534 s METIS on a 96-thread host; stand-ins are
//! ~32–128× smaller).

#[path = "bench_common.rs"]
mod bench_common;

use bench_common::*;
use gsplit::bench_harness::BenchSuite;
use gsplit::partition::{evaluate_partitioning, partition_graph, Strategy};
use gsplit::presample::{presample, PresampleConfig};
use gsplit::util::{timer::timed, Table};

fn main() {
    let mut suite = BenchSuite::new("offline_cost");
    println!("Offline splitting-algorithm cost (measured wall-clock on this host)\n");
    let epochs = if quick() { 2 } else { 10 };
    let mut t = Table::new(&[
        "Graph",
        "Presample(s)",
        "Partition(s)",
        "Cut frac",
        "Imbalance",
    ])
    .left(0);
    for ds in all_datasets() {
        let (t_pre, w) = timed(|| {
            presample(
                &ds.graph,
                &ds.labels.train_set,
                &PresampleConfig {
                    epochs,
                    batch_size: BATCH,
                    fanouts: vec![FANOUT; LAYERS],
                    seed: SEED,
                },
            )
        });
        let mask = train_mask(&ds);
        let (t_part, part) =
            timed(|| partition_graph(&ds.graph, &w, &mask, Strategy::GSplit, 4, 0.05, SEED));
        let q = evaluate_partitioning(&ds.graph, &w, &part);
        suite.metric(&format!("{}/presample_s", ds.spec.name), t_pre);
        suite.metric(&format!("{}/partition_s", ds.spec.name), t_part);
        suite.metric(&format!("{}/cut_fraction", ds.spec.name), q.cut_fraction());
        t.row(vec![
            ds.spec.paper_name.to_string(),
            format!("{t_pre:.1}"),
            format!("{t_part:.1}"),
            format!("{:.3}", q.cut_fraction()),
            format!("{:.3}", q.imbalance),
        ]);
    }
    t.print();
    println!(
        "\nPaper: presample 19s (Orkut) / 20s (Papers100M) / 288s (Friendster) on 4×RTX3090;\n\
         METIS partition 14s / 78s / 534s on 96 threads. One-time costs, amortized across runs.\n\
         (Pre-sampling epochs = {epochs}; the 10/30/100-epoch sensitivity sweep is in fig6_ablations.)"
    );
    suite.finish();
}
