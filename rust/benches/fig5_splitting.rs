//! **Figure 5** — quality of the splitting algorithm: per-iteration
//! workload imbalance (max edges per split / average) and communication
//! cost (% of mini-batch edges crossing splits) under the four offline
//! partitioning strategies — GSplit (pre-sampled vertex+edge weights),
//! Node (vertex weights only), Edge (unweighted min-cut, degree+target
//! balanced), Rand — on Papers100M.

#[path = "bench_common.rs"]
mod bench_common;

use bench_common::*;
use gsplit::bench_harness::BenchSuite;
use gsplit::graph::StandIn;
use gsplit::partition::{evaluate_minibatch, Strategy};
use gsplit::rng::{derive_seed, Pcg32};
use gsplit::sampling::Sampler;
use gsplit::util::Table;

fn pctl(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    sorted[((sorted.len() - 1) as f64 * q) as usize]
}

fn main() {
    let mut suite = BenchSuite::new("fig5_splitting");
    println!(
        "Figure 5 — splitting quality per mini-batch on Papers100M (4 splits,\n\
         fanout 15, 3 layers, batch 1024): workload imbalance and % cross edges.\n"
    );
    let ds = load_standin(StandIn::PapersS);
    let w = presample_cached(&ds, PRESAMPLE_EPOCHS, FANOUT, LAYERS);
    let fanouts = vec![FANOUT; LAYERS];
    let strategies =
        [Strategy::GSplit, Strategy::Node, Strategy::Edge, Strategy::Rand];

    let mut imb = Table::new(&["Strategy", "imb p10", "imb p50", "imb p90", "mean"]).left(0);
    let mut cross = Table::new(&["Strategy", "cross p10", "cross p50", "cross p90", "mean"]).left(0);

    let targets = ds.epoch_targets(SEED);
    let iters = if quick() { 4 } else { targets.len().div_ceil(BATCH).min(64) };

    for strat in strategies {
        let part = partition_cached(&ds, &w, strat, 4);
        let mut sampler = Sampler::new();
        let (mut imbs, mut crosses) = (Vec::new(), Vec::new());
        for (i, chunk) in targets.chunks(BATCH).take(iters).enumerate() {
            let mut rng = Pcg32::new(derive_seed(SEED, &[i as u64, 0xf15]));
            let mb = sampler.sample(&ds.graph, chunk, &fanouts, &mut rng);
            let q = evaluate_minibatch(&mb, &part);
            imbs.push(q.imbalance);
            crosses.push(q.cross_edge_fraction * 100.0);
        }
        imbs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        crosses.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        suite.metric(&format!("{strat:?}/imbalance_mean"), mean(&imbs));
        suite.metric(&format!("{strat:?}/cross_pct_mean"), mean(&crosses));
        imb.row(vec![
            format!("{strat:?}"),
            format!("{:.2}", pctl(&imbs, 0.1)),
            format!("{:.2}", pctl(&imbs, 0.5)),
            format!("{:.2}", pctl(&imbs, 0.9)),
            format!("{:.2}", mean(&imbs)),
        ]);
        cross.row(vec![
            format!("{strat:?}"),
            format!("{:.1}%", pctl(&crosses, 0.1)),
            format!("{:.1}%", pctl(&crosses, 0.5)),
            format!("{:.1}%", pctl(&crosses, 0.9)),
            format!("{:.1}%", mean(&crosses)),
        ]);
    }
    println!("Workload imbalance (max edges per split / average):");
    imb.print();
    println!("\nCommunication cost (% edges crossing splits):");
    cross.print();
    println!(
        "\nPaper (Fig. 5): Rand ≈ perfectly balanced but ~75% cross edges; Edge cuts well\n\
         but imbalanced; Node ≈ 9% cross; GSplit ≈ 5% cross with near-balanced load."
    );
    suite.finish();
}
