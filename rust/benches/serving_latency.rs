//! **Serving latency** — online-inference tail latency and throughput of
//! `gsplit serve`'s micro-batching service, swept over cache policy ×
//! budget × pipeline worker count under a seeded Zipf request stream
//! (closed loop, so measured latency is queueing + micro-batch wait +
//! split-parallel forward, not arrival-rate fiction).
//!
//! Emits `BENCH_serving.json`: nearest-rank p50/p95/p99 seconds and
//! served requests/s per configuration. Unlike the paper-figure benches
//! these are real wall-clock numbers (the forward actually runs), so the
//! committed baseline tolerance is generous; the stream itself is
//! seed-deterministic (`serving::traffic::request_stream`).

#[path = "bench_common.rs"]
mod bench_common;

use std::sync::Arc;

use bench_common::{partition_cached, presample_cached, smoke, SEED};
use gsplit::bench_harness::BenchSuite;
use gsplit::cache::{CachePolicy, ResidentCache};
use gsplit::devices::Topology;
use gsplit::graph::StandIn;
use gsplit::model::{GnnKind, ModelConfig};
use gsplit::partition::Strategy;
use gsplit::rng::derive_seed;
use gsplit::runtime::NativeBackend;
use gsplit::serving::{self, traffic, ServeConfig};
use gsplit::train::{TrainConfig, Trainer};
use gsplit::util::Table;

const K: usize = 4;
const FANOUT: usize = 5;
const LAYERS: usize = 2;

fn main() {
    let mut suite = BenchSuite::new("serving");
    // Real wall-clock serving on the Tiny stand-in in both modes — the
    // bench measures the service machinery, not graph scale.
    let ds = StandIn::Tiny.load().unwrap();
    let requests = if smoke() { 200 } else { 2000 };
    let cfg = ModelConfig {
        kind: GnnKind::GraphSage,
        feat_dim: ds.features.dim(),
        hidden: 64,
        num_classes: ds.labels.num_classes,
        num_layers: LAYERS,
    };
    let backend = NativeBackend::new();
    let w = presample_cached(&ds, 3, FANOUT, LAYERS);
    let part = partition_cached(&ds, &w, Strategy::GSplit, K);
    let topo = Topology::for_gpus(K, 1.0).unwrap();
    let traffic_cfg = traffic::TrafficConfig {
        requests,
        concurrency: 8,
        skew: 1.0,
        seed: SEED,
        vertices: ds.graph.num_vertices(),
    };
    let serve_seed = derive_seed(SEED, &[0x1F5E]);

    println!(
        "Serving latency — {requests} Zipf(s=1.0) requests, {} closed-loop clients,\n\
         max-batch 32, max-wait 500us, queue 256, on tiny ({} vertices, k={K}).\n",
        traffic_cfg.concurrency,
        ds.graph.num_vertices(),
    );
    let mut table =
        Table::new(&["Policy", "Budget", "Workers", "p50(ms)", "p95(ms)", "p99(ms)", "req/s"])
            .left(0);

    for policy in [CachePolicy::None, CachePolicy::Distributed, CachePolicy::Partitioned] {
        for budget in [64u64, 1024] {
            // An absent cache has no budget axis — sweep it once.
            if policy == CachePolicy::None && budget != 64 {
                continue;
            }
            for workers in [0usize, 2] {
                let mut trainer =
                    Trainer::new(&backend, &cfg, FANOUT, part.clone(), 0.2, SEED).unwrap();
                let cache = (policy != CachePolicy::None).then(|| {
                    Arc::new(ResidentCache::build(
                        policy,
                        &w.vertex,
                        budget,
                        trainer.partitioning(),
                        &topo,
                        &ds.features,
                    ))
                });
                trainer
                    .apply_config(TrainConfig::new().parallel_workers(workers).cache(cache))
                    .unwrap();
                let serve_cfg = ServeConfig {
                    max_batch: 32,
                    max_wait: std::time::Duration::from_micros(500),
                    queue_cap: 256,
                    seed: serve_seed,
                };
                let (res, report) = serving::run(&mut trainer, &ds, serve_cfg, |client| {
                    traffic::run_closed_loop(client, &traffic_cfg)
                })
                .unwrap();
                res.unwrap();
                assert_eq!(report.served, requests as u64);

                let (p50, p95, p99) =
                    (report.percentile(50.0), report.percentile(95.0), report.percentile(99.0));
                let budget_label = if policy == CachePolicy::None { 0 } else { budget };
                let key = format!("{}/b{budget_label}/w{workers}", policy.name());
                suite.metric(&format!("{key}/p50_s"), p50);
                suite.metric(&format!("{key}/p95_s"), p95);
                suite.metric(&format!("{key}/p99_s"), p99);
                suite.metric(&format!("{key}/rps"), report.rps());
                table.row(vec![
                    policy.name().to_string(),
                    budget_label.to_string(),
                    workers.to_string(),
                    format!("{:.3}", p50 * 1e3),
                    format!("{:.3}", p95 * 1e3),
                    format!("{:.3}", p99 * 1e3),
                    format!("{:.0}", report.rps()),
                ]);
            }
        }
    }
    table.print();
    println!(
        "\nExpectation: caching lowers the loading share of each micro-batch\n\
         (partitioned > distributed > none at equal budget), and pipeline\n\
         workers raise throughput at a small per-request latency cost."
    );
    suite.finish();
}
