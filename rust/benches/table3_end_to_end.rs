//! **Table 3** — end-to-end epoch time (S / L / FB / Total, speedup vs
//! GSplit) for DGL, P3*, Quiver, the CAGNET-style 1D full-graph baseline,
//! Edge (GSplit with unweighted min-cut partitioning), and GSplit, on all
//! three graphs × GraphSage and GAT, at the paper's defaults (4 GPUs,
//! fanout 15, 3 layers, hidden 256, batch 1024; the full-graph baseline
//! runs one whole-graph pass per epoch instead of mini-batches).

#[path = "bench_common.rs"]
mod bench_common;

use bench_common::*;
use gsplit::bench_harness::BenchSuite;
use gsplit::devices::Topology;
use gsplit::exec::{DataParallel, Engine, EngineCtx, FullGraph, PushPull, SplitParallel};
use gsplit::model::GnnKind;
use gsplit::partition::Strategy;
use gsplit::util::{fmt_bytes, fmt_secs, Table};

fn main() {
    let mut suite = BenchSuite::new("table3_end_to_end");
    println!(
        "Table 3 — epoch time (modeled seconds on the simulated 4×V100 host).\n\
         S = sampling, L = loading, FB = forward+backward; speedup = Total / GSplit Total.\n"
    );
    let mut table =
        Table::new(&["Graph", "System", "Model", "S", "L", "FB", "Total(s)", "Speedup"]).left(0).left(1).left(2);

    for ds in all_datasets() {
        let topo = || Topology::p3_8xlarge(ds.spec.scale_divisor);
        for kind in [GnnKind::GraphSage, GnnKind::Gat] {
            let ctx = EngineCtx::new(&ds, topo(), kind, HIDDEN, LAYERS, FANOUT);
            let w = presample_cached(&ds, PRESAMPLE_EPOCHS, FANOUT, LAYERS);

            let mut rows: Vec<(String, gsplit::costmodel::PhaseBreakdown)> = Vec::new();
            let mut gsplit_load: Option<(u64, u64, u64)> = None;
            let mut run = |name: &str, engine: &mut dyn Engine, batch: usize, cap: usize| {
                let (c, t) = epoch_time(engine, &ctx, batch, SEED, cap);
                if name == "GSplit" {
                    gsplit_load = Some((
                        c.local_load_bytes.iter().sum(),
                        c.peer_load.total_remote(),
                        c.host_load_bytes.iter().sum(),
                    ));
                }
                rows.push((name.to_string(), t));
            };
            run("DGL", &mut DataParallel::dgl(&ctx), BATCH, iter_cap());
            run("P3*", &mut PushPull::new(&ctx, BATCH), BATCH, iter_cap());
            run("Quiver", &mut DataParallel::quiver(&ctx, &w, BATCH), BATCH, iter_cap());
            // Full-graph training: one whole-graph pass is the epoch. Runs
            // before GSplit — the speedup base is the last row.
            run("FullGraph", &mut FullGraph::new(&ctx), usize::MAX, 1);
            {
                let part = partition_cached(&ds, &w, Strategy::Edge, ctx.k());
                run("Edge", &mut SplitParallel::new(&ctx, part, &w.vertex, BATCH), BATCH, iter_cap());
            }
            {
                let part = partition_cached(&ds, &w, Strategy::GSplit, ctx.k());
                run("GSplit", &mut SplitParallel::new(&ctx, part, &w.vertex, BATCH), BATCH, iter_cap());
            }

            let gsplit_total = rows.last().unwrap().1.total();
            for (name, t) in &rows {
                let sp = if name == "GSplit" {
                    String::new()
                } else {
                    speedup(t.total(), gsplit_total)
                };
                suite.metric(
                    &format!("{}/{}/{name}/total_s", ds.spec.name, kind.name()),
                    t.total(),
                );
                table.row(vec![
                    ds.spec.paper_name.to_string(),
                    name.clone(),
                    kind.name().to_string(),
                    fmt_secs(t.sampling),
                    fmt_secs(t.loading),
                    fmt_secs(t.fb),
                    fmt_secs(t.total()),
                    sp,
                ]);
            }
            table.sep();
            if let Some((local, peer, host)) = gsplit_load {
                println!(
                    "  [{} / {}] GSplit loading split: local {} | peer {} | host {}",
                    ds.spec.paper_name,
                    kind.name(),
                    fmt_bytes(local),
                    fmt_bytes(peer),
                    fmt_bytes(host),
                );
            }
        }
    }
    table.print();
    println!(
        "\nPaper (Table 3) speedups vs GSplit — Orkut: DGL 4.4x/3.6x, P3* 0.8x/1.9x, Quiver 1.1x/1.1x, Edge 1.7x/1.6x;\n\
         Papers100M: DGL 1.4x/1.2x, P3* 2.2x/2.2x, Quiver 1.9x/1.4x, Edge 1.5x/1.4x;\n\
         Friendster: DGL 2.9x/1.7x, P3* 4.1x/3.0x, Quiver 1.6x/1.2x, Edge 1.3x/1.4x (Sage/GAT).\n\
         Expectation on stand-ins: same ordering and crossovers (absolute seconds are scaled by 1/divisor)."
    );
    suite.finish();
}
