//! Hot-path micro-benchmarks (the §Perf targets in DESIGN.md): neighbor
//! sampling rate, online splitting + shuffle-index build rate, vertex-map
//! throughput, partitioner wall time, feature gather bandwidth, and the
//! serial-vs-pipelined real-compute epoch wall-clock (DESIGN.md
//! §Executor).

#[path = "bench_common.rs"]
mod bench_common;

use bench_common::*;
use gsplit::bench_harness::{section, Bench, BenchSuite};
use gsplit::graph::{Dataset, StandIn};
use gsplit::model::{GnnKind, ModelConfig};
use gsplit::partition::{partition_graph, Partitioning, Strategy};
use gsplit::presample::PresampleWeights;
use gsplit::rng::{derive_seed, Pcg32};
use gsplit::runtime::NativeBackend;
use gsplit::sampling::{Sampler, VertexMap};
use gsplit::split::SplitSampler;
use gsplit::train::{train_epoch, ExecMode, PipelineConfig, Trainer};
use gsplit::util::timer::timed;
use gsplit::Vid;

fn main() {
    let mut suite = BenchSuite::new("micro_hotpaths");
    let ds = smoke_standin(StandIn::OrkutS).load().expect("dataset");
    let bench = if quick() { Bench::quick() } else { Bench::default().with_budget(3.0) };
    let fanouts = vec![FANOUT; LAYERS];
    let targets: Vec<Vid> = ds.epoch_targets(SEED).into_iter().take(BATCH).collect();

    // --- single-device mini-batch sampling ---
    section("mini-batch sampling (orkut-s, batch 1024, fanout 15, 3 layers)");
    let mut sampler = Sampler::new();
    let mut seed_ctr = 0u64;
    let mut mb = gsplit::sampling::MiniBatch::default();
    // Measure edges/s: pre-measure edge count of one batch.
    let probe = sampler.sample(&ds.graph, &targets, &fanouts, &mut Pcg32::new(1));
    let edges = probe.total_edges() as f64;
    let s = bench.run("sample_minibatch", Some(edges), || {
        seed_ctr += 1;
        let mut rng = Pcg32::new(derive_seed(SEED, &[seed_ctr]));
        sampler.sample_into(&ds.graph, &targets, &fanouts, &mut rng, &mut mb);
    });
    suite.record(&s);

    // --- cooperative split-parallel sampling (includes online splitting +
    //     shuffle-index construction) ---
    section("split-parallel sampling + shuffle index (4 splits)");
    let w = PresampleWeights::uniform(&ds.graph);
    let mask = vec![false; ds.graph.num_vertices()];
    let part = partition_graph(&ds.graph, &w, &mask, Strategy::Edge, 4, 0.05, SEED);
    let mut ss = SplitSampler::new(4);
    let s = bench.run("split_sample_minibatch", Some(edges), || {
        seed_ctr += 1;
        ss.sample(&ds.graph, &targets, &fanouts, &part, seed_ctr)
    });
    suite.record(&s);

    // --- vertex map ---
    section("VertexMap get_or_insert (1M mixed ops)");
    let keys: Vec<Vid> = {
        let mut rng = Pcg32::new(3);
        (0..1_000_000).map(|_| rng.gen_range(200_000)).collect()
    };
    let mut vm = VertexMap::new();
    let s = bench.run("vertex_map_1M", Some(1e6), || {
        vm.reset(300_000);
        let mut acc = 0u32;
        for &k in &keys {
            acc ^= vm.get_or_insert(k).0;
        }
        acc
    });
    suite.record(&s);

    // --- partitioner ---
    section("multilevel partitioner (orkut-s, k=4)");
    let bench_slow = if quick() { Bench::quick() } else { Bench::default().with_budget(10.0) };
    let s = bench_slow.run("partition_orkut_s", None, || {
        partition_graph(&ds.graph, &w, &mask, Strategy::Edge, 4, 0.05, SEED)
    });
    suite.record(&s);

    // --- feature gather (loading path) ---
    section("feature row gather (orkut-s rows, 512 dims)");
    let inputs: Vec<Vid> = probe.input_vertices().to_vec();
    let mut buf = Vec::new();
    let bytes = inputs.len() as f64 * ds.features.row_bytes() as f64;
    let s = bench.run("gather_input_rows", Some(bytes), || {
        ds.features.gather(&inputs, &mut buf);
        buf.len()
    });
    suite.record(&s);

    // --- threaded pipelined executor: real-compute epoch wall-clock ---
    // Same seeds ⇒ bit-identical numerics (asserted below); the speedup
    // comes from per-device compute parallelism plus the sampling-ahead
    // pipeline stage hiding S+L behind FB.
    section("pipelined executor: serial vs threaded epoch (real compute, k=4, 3 layers)");
    let n_vertices = if quick() { 2048 } else { 8192 };
    let cfg = ModelConfig {
        kind: GnnKind::GraphSage,
        feat_dim: 32,
        hidden: 64,
        num_classes: 8,
        num_layers: 3,
    };
    let tds = Dataset::sbm_learnable(n_vertices, cfg.num_classes, cfg.feat_dim, 0.6, SEED);
    let tpart = Partitioning {
        assignment: (0..n_vertices as u32).map(|v| (v % 4) as u16).collect(),
        k: 4,
    };
    let backend = NativeBackend::new();
    let tbatch = 256usize;
    let mut serial_trainer = Trainer::new(&backend, &cfg, 5, tpart.clone(), 0.2, SEED).unwrap();
    let (t_serial, serial_stats) =
        timed(|| train_epoch(&mut serial_trainer, &tds, tbatch, 0).expect("serial epoch"));
    println!(
        "serial                       {t_serial:>8.3} s/epoch   ({} iterations)",
        serial_stats.len()
    );
    suite.metric("executor/serial_epoch_s", t_serial);
    for workers in [2usize, 4] {
        let mut tr = Trainer::new(&backend, &cfg, 5, tpart.clone(), 0.2, SEED).unwrap();
        tr.set_exec_mode(ExecMode::Pipelined(PipelineConfig::with_workers(workers)));
        let (t, stats) = timed(|| train_epoch(&mut tr, &tds, tbatch, 0).expect("pipelined epoch"));
        assert!(
            serial_stats.iter().zip(&stats).all(|(a, b)| a.loss.to_bits() == b.loss.to_bits()),
            "pipelined executor diverged from serial"
        );
        println!(
            "pipelined --parallel-workers {workers} {t:>8.3} s/epoch   speedup {:.2}x (bit-identical)",
            t_serial / t
        );
        suite.metric(&format!("executor/pipelined_w{workers}_epoch_s"), t);
    }

    // --- cache-aware loading: distributed-policy epoch through the
    // pipelined executor's pre-forward exchange phase, still bit-identical
    // to the uncached serial reference (DESIGN.md §Loading).
    {
        let topo = gsplit::devices::Topology::p3_8xlarge(1.0);
        let ranking: Vec<u64> =
            (0..n_vertices as Vid).map(|v| tds.graph.degree(v) as u64).collect();
        let cache = std::sync::Arc::new(gsplit::cache::ResidentCache::build(
            gsplit::cache::CachePolicy::Distributed,
            &ranking,
            (n_vertices / 8) as u64,
            &tpart,
            &topo,
            &tds.features,
        ));
        let mut tr = Trainer::new(&backend, &cfg, 5, tpart.clone(), 0.2, SEED).unwrap();
        tr.set_cache(Some(cache)).unwrap();
        tr.set_exec_mode(ExecMode::Pipelined(PipelineConfig::with_workers(4)));
        let (t, stats) = timed(|| train_epoch(&mut tr, &tds, tbatch, 0).expect("cached epoch"));
        assert!(
            serial_stats.iter().zip(&stats).all(|(a, b)| a.loss.to_bits() == b.loss.to_bits()),
            "cache-aware pipelined executor diverged from the uncached serial reference"
        );
        let peer: u64 = tr.load_stats().iter().map(|s| s.peer_bytes).sum();
        println!(
            "pipelined + distributed cache {t:>7.3} s/epoch   ({} peer-exchanged, bit-identical)",
            gsplit::util::fmt_bytes(peer)
        );
        suite.metric("executor/pipelined_cached_epoch_s", t);
        suite.metric("executor/cached_peer_bytes", peer as f64);
    }
    suite.finish();
}
