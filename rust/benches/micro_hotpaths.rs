//! Hot-path micro-benchmarks (the §Perf targets in DESIGN.md): neighbor
//! sampling rate, online splitting + shuffle-index build rate, vertex-map
//! throughput, partitioner wall time, feature gather bandwidth, per-kernel
//! GFLOP/s for the blocked/simd compute kernels (DESIGN.md §Perf "Rust
//! kernel blocking"), the end-to-end epoch wall-clock under each
//! `GSPLIT_KERNELS` variant, the serial-vs-pipelined real-compute
//! epoch wall-clock (DESIGN.md §Executor), and the span tracer's
//! disabled-guard cost plus traced-epoch overhead and bit-identity
//! (DESIGN.md §Observability).

#[path = "bench_common.rs"]
mod bench_common;

use bench_common::*;
use gsplit::bench_harness::{section, Bench, BenchSuite};
use gsplit::graph::{Dataset, FeatureSource, StandIn};
use gsplit::model::{GnnKind, ModelConfig};
use gsplit::partition::{partition_graph, Partitioning, Strategy};
use gsplit::presample::PresampleWeights;
use gsplit::rng::{derive_seed, Pcg32};
use gsplit::runtime::kernels::{self, KernelKind};
use gsplit::runtime::NativeBackend;
use gsplit::sampling::{Sampler, VertexMap};
use gsplit::split::SplitSampler;
use gsplit::train::{train_epoch, TrainConfig, Trainer};
use gsplit::util::timer::timed;
use gsplit::Vid;

fn main() {
    let mut suite = BenchSuite::new("micro_hotpaths");
    let ds = load_standin(StandIn::OrkutS);
    let bench = if quick() { Bench::quick() } else { Bench::default().with_budget(3.0) };
    let fanouts = vec![FANOUT; LAYERS];
    let targets: Vec<Vid> = ds.epoch_targets(SEED).into_iter().take(BATCH).collect();

    // --- single-device mini-batch sampling ---
    section("mini-batch sampling (orkut-s, batch 1024, fanout 15, 3 layers)");
    let mut sampler = Sampler::new();
    let mut seed_ctr = 0u64;
    let mut mb = gsplit::sampling::MiniBatch::default();
    // Measure edges/s: pre-measure edge count of one batch.
    let probe = sampler.sample(&ds.graph, &targets, &fanouts, &mut Pcg32::new(1));
    let edges = probe.total_edges() as f64;
    let s = bench.run("sample_minibatch", Some(edges), || {
        seed_ctr += 1;
        let mut rng = Pcg32::new(derive_seed(SEED, &[seed_ctr]));
        sampler.sample_into(&ds.graph, &targets, &fanouts, &mut rng, &mut mb);
    });
    suite.record(&s);

    // --- cooperative split-parallel sampling (includes online splitting +
    //     shuffle-index construction) ---
    section("split-parallel sampling + shuffle index (4 splits)");
    let w = PresampleWeights::uniform(&ds.graph);
    let mask = vec![false; ds.graph.num_vertices()];
    let part = partition_graph(&ds.graph, &w, &mask, Strategy::Edge, 4, 0.05, SEED);
    let mut ss = SplitSampler::new(4);
    let s = bench.run("split_sample_minibatch", Some(edges), || {
        seed_ctr += 1;
        ss.sample(&ds.graph, &targets, &fanouts, &part, seed_ctr)
    });
    suite.record(&s);

    // --- vertex map ---
    section("VertexMap get_or_insert (1M mixed ops)");
    let keys: Vec<Vid> = {
        let mut rng = Pcg32::new(3);
        (0..1_000_000).map(|_| rng.gen_range(200_000)).collect()
    };
    let mut vm = VertexMap::new();
    let s = bench.run("vertex_map_1M", Some(1e6), || {
        vm.reset(300_000);
        let mut acc = 0u32;
        for &k in &keys {
            acc ^= vm.get_or_insert(k).0;
        }
        acc
    });
    suite.record(&s);

    // --- partitioner ---
    section("multilevel partitioner (orkut-s, k=4)");
    let bench_slow = if quick() { Bench::quick() } else { Bench::default().with_budget(10.0) };
    let s = bench_slow.run("partition_orkut_s", None, || {
        partition_graph(&ds.graph, &w, &mask, Strategy::Edge, 4, 0.05, SEED)
    });
    suite.record(&s);

    // --- feature gather (loading path) ---
    section("feature row gather (orkut-s rows, 512 dims)");
    let inputs: Vec<Vid> = probe.input_vertices().to_vec();
    let mut buf = Vec::new();
    let bytes = inputs.len() as f64 * ds.features.row_bytes() as f64;
    let s = bench.run("gather_input_rows", Some(bytes), || {
        ds.features.gather(&inputs, &mut buf);
        buf.len()
    });
    suite.record(&s);

    // --- compute kernels: per-variant GFLOP/s on the hot primitives ---
    // The acceptance bar (ISSUE 6): blocked ≥3× scalar GFLOP/s on the
    // dense-transform kernels. Metric names are stable so
    // check_bench_json --baseline can diff them across PRs.
    let (km, kdin, kdout, kk) = if quick() { (256, 96, 96, 15) } else { (1024, 256, 256, 15) };
    section("compute kernels per variant (dense/gather/attention)");
    let mut krng = Pcg32::new(9);
    let mut fill = |len: usize| -> Vec<f32> {
        (0..len).map(|_| krng.next_f32() - 0.5).collect::<Vec<f32>>()
    };
    let a1 = fill(km * kdin);
    let a2 = fill(km * kdin);
    let w1 = fill(kdin * kdout);
    let w2 = fill(kdin * kdout);
    let kbias = fill(kdout);
    let g_up = fill(km * kdout);
    let z_att = fill(km * kdout);
    let s_src = fill(km);
    let s_dst = fill(km);
    let x_gather = fill(km * kdin);
    drop(fill);
    let mut kneigh = vec![gsplit::sampling::NO_NEIGHBOR; km * kk];
    {
        let mut nrng = Pcg32::new(11);
        for slot in kneigh.iter_mut() {
            if nrng.gen_range(5) != 0 {
                *slot = nrng.gen_range(km as u32);
            }
        }
    }
    let variants: Vec<KernelKind> = KernelKind::all()
        .into_iter()
        .filter(|&kv| {
            let ok = kv != KernelKind::Simd || kernels::simd_available();
            if !ok {
                println!("kernels/*/simd                   skipped (AVX2+FMA unavailable)");
            }
            ok
        })
        .collect();
    // Dual dense transform (the GraphSage forward shape): 4 FLOPs/(i,p,q).
    let flops_dual = 4.0 * (km * kdin * kdout) as f64;
    let mut kout = vec![0f32; km * kdout];
    for &kv in &variants {
        let s = bench.run(&format!("kernels/dense_fwd/{}", kv.name()), Some(flops_dual), || {
            gsplit::runtime::kernels::dense::dense_bias_act(
                kv,
                km,
                kdin,
                kdout,
                &a1,
                &w1,
                Some((&a2, &w2)),
                Some(&kbias),
                true,
                &mut kout,
            );
            kout[0]
        });
        suite.record(&s);
    }
    // Input-side VJP g·Wᵀ and weight-side VJP Aᵀ·g: 2 FLOPs/(i,p,q) each.
    let flops_vjp = 2.0 * (km * kdin * kdout) as f64;
    let mut kgx = vec![0f32; km * kdin];
    for &kv in &variants {
        let s = bench.run(&format!("kernels/dense_gx/{}", kv.name()), Some(flops_vjp), || {
            kgx.fill(0.0);
            gsplit::runtime::kernels::dense::matmul_gx_acc(
                kv, km, kdin, kdout, &g_up, &w1, &mut kgx,
            );
            kgx[0]
        });
        suite.record(&s);
    }
    let mut kgw = vec![0f32; kdin * kdout];
    for &kv in &variants {
        let s = bench.run(&format!("kernels/dense_gw/{}", kv.name()), Some(flops_vjp), || {
            kgw.fill(0.0);
            gsplit::runtime::kernels::dense::matmul_gw_acc(
                kv, km, kdin, kdout, &a1, &g_up, &mut kgw,
            );
            kgw[0]
        });
        suite.record(&s);
    }
    // Gather-mean: ~1 add per (edge, feature); identical numerics across
    // variants, so throughput is the only thing that may differ.
    let flops_gather = (km * kk * kdin) as f64;
    let mut kagg = vec![0f32; km * kdin];
    let mut kden = vec![0f32; km];
    for &kv in &variants {
        let s = bench.run(&format!("kernels/gather_mean/{}", kv.name()), Some(flops_gather), || {
            gsplit::runtime::kernels::gather::gather_mean(
                kv, &x_gather, &kneigh, km, kk, kdin, &mut kagg, &mut kden,
            );
            kagg[0]
        });
        suite.record(&s);
    }
    // One-pass GAT attention forward: ~2 FLOPs per (edge+self, channel).
    let flops_attn = 2.0 * (km * (kk + 1) * kdout) as f64;
    for &kv in &variants {
        let s = bench.run(&format!("kernels/gat_attn/{}", kv.name()), Some(flops_attn), || {
            gsplit::runtime::kernels::attn::attention_fwd(
                kv, &z_att, &s_src, &s_dst, &kneigh, km, kk, kdout, &kbias, true, &mut kout,
            );
            kout[0]
        });
        suite.record(&s);
    }

    // --- threaded pipelined executor: real-compute epoch wall-clock ---
    // Same seeds ⇒ bit-identical numerics (asserted below); the speedup
    // comes from per-device compute parallelism plus the sampling-ahead
    // pipeline stage hiding S+L behind FB.
    section("pipelined executor: serial vs threaded epoch (real compute, k=4, 3 layers)");
    let n_vertices = if quick() { 2048 } else { 8192 };
    let cfg = ModelConfig {
        kind: GnnKind::GraphSage,
        feat_dim: 32,
        hidden: 64,
        num_classes: 8,
        num_layers: 3,
    };
    let tds = Dataset::sbm_learnable(n_vertices, cfg.num_classes, cfg.feat_dim, 0.6, SEED);
    let tpart = Partitioning {
        assignment: (0..n_vertices as u32).map(|v| (v % 4) as u16).collect(),
        k: 4,
    };
    let backend = NativeBackend::new();
    let tbatch = 256usize;
    let mut serial_trainer = Trainer::new(&backend, &cfg, 5, tpart.clone(), 0.2, SEED).unwrap();
    let (t_serial, serial_stats) =
        timed(|| train_epoch(&mut serial_trainer, &tds, tbatch, 0).expect("serial epoch"));
    println!(
        "serial                       {t_serial:>8.3} s/epoch   ({} iterations)",
        serial_stats.len()
    );
    suite.metric("executor/serial_epoch_s", t_serial);
    for workers in [2usize, 4] {
        let mut tr = Trainer::new(&backend, &cfg, 5, tpart.clone(), 0.2, SEED)
            .unwrap()
            .with_config(TrainConfig::new().parallel_workers(workers))
            .unwrap();
        let (t, stats) = timed(|| train_epoch(&mut tr, &tds, tbatch, 0).expect("pipelined epoch"));
        assert!(
            serial_stats.iter().zip(&stats).all(|(a, b)| a.loss.to_bits() == b.loss.to_bits()),
            "pipelined executor diverged from serial"
        );
        println!(
            "pipelined --parallel-workers {workers} {t:>8.3} s/epoch   speedup {:.2}x (bit-identical)",
            t_serial / t
        );
        suite.metric(&format!("executor/pipelined_w{workers}_epoch_s"), t);
    }

    // --- cache-aware loading: distributed-policy epoch through the
    // pipelined executor's pre-forward exchange phase, still bit-identical
    // to the uncached serial reference (DESIGN.md §Loading).
    {
        let topo = gsplit::devices::Topology::p3_8xlarge(1.0);
        let ranking: Vec<u64> =
            (0..n_vertices as Vid).map(|v| tds.graph.degree(v) as u64).collect();
        let cache = std::sync::Arc::new(gsplit::cache::ResidentCache::build(
            gsplit::cache::CachePolicy::Distributed,
            &ranking,
            (n_vertices / 8) as u64,
            &tpart,
            &topo,
            &tds.features,
        ));
        let mut tr = Trainer::new(&backend, &cfg, 5, tpart.clone(), 0.2, SEED)
            .unwrap()
            .with_config(TrainConfig::new().parallel_workers(4).cache(Some(cache)))
            .unwrap();
        let (t, stats) = timed(|| train_epoch(&mut tr, &tds, tbatch, 0).expect("cached epoch"));
        assert!(
            serial_stats.iter().zip(&stats).all(|(a, b)| a.loss.to_bits() == b.loss.to_bits()),
            "cache-aware pipelined executor diverged from the uncached serial reference"
        );
        let peer: u64 = tr.load_stats().iter().map(|s| s.peer_bytes).sum();
        println!(
            "pipelined + distributed cache {t:>7.3} s/epoch   ({} peer-exchanged, bit-identical)",
            gsplit::util::fmt_bytes(peer)
        );
        suite.metric("executor/pipelined_cached_epoch_s", t);
        suite.metric("executor/cached_peer_bytes", peer as f64);
    }

    // --- end-to-end epoch per kernel variant (serial executor) ---
    // The measured scalar→blocked/simd speedup the README quotes; blocked
    // must stay bit-identical to scalar (asserted on the loss bits).
    section("end-to-end epoch per kernel variant (serial, GraphSage)");
    let mut t_scalar = f64::NAN;
    let mut scalar_losses: Vec<u32> = Vec::new();
    for &kv in &variants {
        let kb = NativeBackend::with_kernels(kv);
        let mut tr = Trainer::new(&kb, &cfg, 5, tpart.clone(), 0.2, SEED).unwrap();
        let (t, stats) =
            timed(|| train_epoch(&mut tr, &tds, tbatch, 0).expect("per-kernel epoch"));
        let losses: Vec<u32> = stats.iter().map(|s| s.loss.to_bits()).collect();
        if kv == KernelKind::Scalar {
            t_scalar = t;
            scalar_losses = losses;
            println!("{:<8}                     {t:>8.3} s/epoch", kv.name());
        } else {
            if kv == KernelKind::Blocked {
                assert_eq!(
                    scalar_losses, losses,
                    "blocked epoch diverged bitwise from the scalar oracle"
                );
            }
            println!(
                "{:<8}                     {t:>8.3} s/epoch   speedup {:.2}x vs scalar",
                kv.name(),
                t_scalar / t
            );
            suite.metric(&format!("kernels/epoch_speedup/{}", kv.name()), t_scalar / t);
        }
        suite.metric(&format!("kernels/epoch_s/{}", kv.name()), t);
    }

    // --- observability: disabled-guard cost + traced-epoch overhead ---
    // The disabled span guard must be unmeasurable (one relaxed atomic
    // load), and a fully traced epoch must stay bit-identical to the
    // untraced serial reference and in the same wall-clock ballpark
    // (DESIGN.md §Observability).
    section("span tracing: disabled-guard cost + traced epoch (serial, k=4)");
    assert!(!gsplit::obs::enabled(), "tracing must be off before the disabled-guard bench");
    let s = bench.run("obs/disabled_span_1k", Some(1000.0), || {
        let mut acc = 0u64;
        for i in 0..1000u64 {
            let _s = gsplit::span!(gsplit::obs::Phase::Sample);
            acc = acc.wrapping_add(i);
        }
        acc
    });
    assert!(
        s.mean_s / 1000.0 < 1e-6,
        "disabled span guard must cost well under 1us, measured {:.1} ns",
        s.mean_s / 1000.0 * 1e9
    );
    suite.record(&s);

    let mut tr = Trainer::new(&backend, &cfg, 5, tpart.clone(), 0.2, SEED)
        .unwrap()
        .with_config(TrainConfig::new().trace(true))
        .unwrap();
    gsplit::obs::tracer().reset();
    let (t_traced, traced_stats) =
        timed(|| train_epoch(&mut tr, &tds, tbatch, 0).expect("traced epoch"));
    gsplit::obs::set_enabled(false);
    gsplit::obs::flush_thread();
    let spans: usize = gsplit::obs::tracer().snapshot().iter().map(|t| t.spans.len()).sum();
    assert!(spans > 0, "traced epoch must record spans");
    assert!(
        serial_stats.iter().zip(&traced_stats).all(|(a, b)| a.loss.to_bits() == b.loss.to_bits()),
        "tracing changed the training output — it must not touch a single bit"
    );
    // Generous bound: span recording is a clock read + Vec push, so even a
    // noisy shared runner stays far below 3x.
    assert!(
        t_traced < t_serial * 3.0 + 0.05,
        "traced epoch unreasonably slow: {t_traced:.3}s vs {t_serial:.3}s untraced"
    );
    println!(
        "epoch untraced {t_serial:>8.3} s | traced {t_traced:>8.3} s ({spans} spans, \
         bit-identical)"
    );
    suite.metric("obs/epoch_traced_s", t_traced);
    suite.metric("obs/traced_over_untraced", t_traced / t_serial.max(1e-9));
    gsplit::obs::tracer().reset();
    suite.finish();
}
