//! Hot-path micro-benchmarks (the §Perf targets in DESIGN.md): neighbor
//! sampling rate, online splitting + shuffle-index build rate, vertex-map
//! throughput, partitioner wall time, and feature gather bandwidth.

#[path = "bench_common.rs"]
mod bench_common;

use bench_common::*;
use gsplit::bench_harness::{section, Bench};
use gsplit::graph::StandIn;
use gsplit::partition::{partition_graph, Strategy};
use gsplit::presample::PresampleWeights;
use gsplit::rng::{derive_seed, Pcg32};
use gsplit::sampling::{Sampler, VertexMap};
use gsplit::split::SplitSampler;
use gsplit::Vid;

fn main() {
    let ds = StandIn::OrkutS.load().expect("dataset");
    let bench = if quick() { Bench::quick() } else { Bench::default().with_budget(3.0) };
    let fanouts = vec![FANOUT; LAYERS];
    let targets: Vec<Vid> = ds.epoch_targets(SEED).into_iter().take(BATCH).collect();

    // --- single-device mini-batch sampling ---
    section("mini-batch sampling (orkut-s, batch 1024, fanout 15, 3 layers)");
    let mut sampler = Sampler::new();
    let mut seed_ctr = 0u64;
    let mut mb = gsplit::sampling::MiniBatch::default();
    // Measure edges/s: pre-measure edge count of one batch.
    let probe = sampler.sample(&ds.graph, &targets, &fanouts, &mut Pcg32::new(1));
    let edges = probe.total_edges() as f64;
    bench.run("sample_minibatch", Some(edges), || {
        seed_ctr += 1;
        let mut rng = Pcg32::new(derive_seed(SEED, &[seed_ctr]));
        sampler.sample_into(&ds.graph, &targets, &fanouts, &mut rng, &mut mb);
    });

    // --- cooperative split-parallel sampling (includes online splitting +
    //     shuffle-index construction) ---
    section("split-parallel sampling + shuffle index (4 splits)");
    let w = PresampleWeights::uniform(&ds.graph);
    let mask = vec![false; ds.graph.num_vertices()];
    let part = partition_graph(&ds.graph, &w, &mask, Strategy::Edge, 4, 0.05, SEED);
    let mut ss = SplitSampler::new(4);
    bench.run("split_sample_minibatch", Some(edges), || {
        seed_ctr += 1;
        ss.sample(&ds.graph, &targets, &fanouts, &part, seed_ctr)
    });

    // --- vertex map ---
    section("VertexMap get_or_insert (1M mixed ops)");
    let keys: Vec<Vid> = {
        let mut rng = Pcg32::new(3);
        (0..1_000_000).map(|_| rng.gen_range(200_000)).collect()
    };
    let mut vm = VertexMap::new();
    bench.run("vertex_map_1M", Some(1e6), || {
        vm.reset(300_000);
        let mut acc = 0u32;
        for &k in &keys {
            acc ^= vm.get_or_insert(k).0;
        }
        acc
    });

    // --- partitioner ---
    section("multilevel partitioner (orkut-s, k=4)");
    let bench_slow = if quick() { Bench::quick() } else { Bench::default().with_budget(10.0) };
    bench_slow.run("partition_orkut_s", None, || {
        partition_graph(&ds.graph, &w, &mask, Strategy::Edge, 4, 0.05, SEED)
    });

    // --- feature gather (loading path) ---
    section("feature row gather (orkut-s rows, 512 dims)");
    let inputs: Vec<Vid> = probe.input_vertices().to_vec();
    let mut buf = Vec::new();
    let bytes = inputs.len() as f64 * ds.features.row_bytes() as f64;
    bench.run("gather_input_rows", Some(bytes), || {
        ds.features.gather(&inputs, &mut buf);
        buf.len()
    });
}
