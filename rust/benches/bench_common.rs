//! Shared infrastructure for the paper-reproduction benches.
//!
//! Heavy offline products (pre-sample weights, partitionings) are cached
//! under `target/bench_cache/` so re-running individual benches doesn't
//! repeat minutes of identical offline work. Set `GSPLIT_BENCH_QUICK=1`
//! to cap per-epoch iterations (scaled extrapolation) while iterating.
//!
//! `BENCH_SMOKE=1` (CI's `bench-smoke` job) additionally swaps every
//! paper stand-in for `StandIn::Tiny`: each bench still exercises its full
//! code path and emits its `BENCH_<suite>.json` report, in seconds instead
//! of minutes. Smoke numbers are correctness probes, not measurements.

#![allow(dead_code)]

use std::io::{Read, Write};
use std::path::PathBuf;

use gsplit::costmodel::{iter_time, IterCounters, PhaseBreakdown};
use gsplit::exec::{Engine, EngineCtx, SplitParallel};
use gsplit::graph::{Dataset, StandIn};
use gsplit::partition::{partition_graph, Partitioning, Strategy};
use gsplit::presample::{presample, PresampleConfig, PresampleWeights};
use gsplit::rng::derive_seed;

pub const SEED: u64 = 42;
/// Paper defaults (§7.1).
pub const FANOUT: usize = 15;
pub const LAYERS: usize = 3;
pub const HIDDEN: usize = 256;
pub const BATCH: usize = 1024;
/// Pre-sampling epochs for weights (the paper found 10 sufficient; 3 is
/// indistinguishable at stand-in scale and keeps bench setup fast — the
/// 10/30 sweep itself is in fig6_ablations).
pub const PRESAMPLE_EPOCHS: usize = 3;

/// CI smoke mode: tiny graphs, capped iterations, JSON output still
/// emitted and validated.
pub fn smoke() -> bool {
    std::env::var("BENCH_SMOKE").is_ok()
}

pub fn quick() -> bool {
    smoke() || std::env::var("GSPLIT_BENCH_QUICK").is_ok()
}

/// The stand-ins a bench iterates: the requested paper graphs normally,
/// just `Tiny` under `BENCH_SMOKE=1`.
pub fn smoke_standins(full: &[StandIn]) -> Vec<StandIn> {
    if smoke() {
        vec![StandIn::Tiny]
    } else {
        full.to_vec()
    }
}

/// One stand-in, smoke-aware.
pub fn smoke_standin(full: StandIn) -> StandIn {
    if smoke() {
        StandIn::Tiny
    } else {
        full
    }
}

/// Max iterations actually executed per epoch (rest extrapolated — batches
/// are iid samples of the same distribution, so the per-iteration mean is
/// unbiased). `GSPLIT_BENCH_FULL=1` runs every iteration.
pub fn iter_cap() -> usize {
    if quick() {
        4
    } else if std::env::var("GSPLIT_BENCH_FULL").is_ok() {
        usize::MAX
    } else {
        16
    }
}

fn cache_dir() -> PathBuf {
    let d = PathBuf::from("target/bench_cache");
    std::fs::create_dir_all(&d).ok();
    d
}

pub fn train_mask(ds: &Dataset) -> Vec<bool> {
    let mut m = vec![false; ds.graph.num_vertices()];
    for &t in &ds.labels.train_set {
        m[t as usize] = true;
    }
    m
}

/// Pre-sample weights, disk-cached.
pub fn presample_cached(ds: &Dataset, epochs: usize, fanout: usize, layers: usize) -> PresampleWeights {
    let key = format!("pw_{}_{epochs}_{fanout}_{layers}_{}.bin", ds.spec.name, BATCH);
    let path = cache_dir().join(key);
    if let Ok(mut f) = std::fs::File::open(&path) {
        let mut buf = Vec::new();
        if f.read_to_end(&mut buf).is_ok() {
            if let Some(w) = decode_weights(&buf, ds) {
                return w;
            }
        }
    }
    let w = presample(
        &ds.graph,
        &ds.labels.train_set,
        &PresampleConfig { epochs, batch_size: BATCH, fanouts: vec![fanout; layers], seed: SEED },
    );
    if let Ok(mut f) = std::fs::File::create(&path) {
        f.write_all(&encode_weights(&w)).ok();
    }
    w
}

/// Partitioning, disk-cached.
pub fn partition_cached(
    ds: &Dataset,
    w: &PresampleWeights,
    strategy: Strategy,
    k: usize,
) -> Partitioning {
    let key = format!("part_{}_{strategy:?}_{k}_{}.bin", ds.spec.name, w.epochs);
    let path = cache_dir().join(key);
    if let Ok(buf) = std::fs::read(&path) {
        if buf.len() == ds.graph.num_vertices() * 2 {
            let assignment: Vec<u16> =
                buf.chunks_exact(2).map(|c| u16::from_le_bytes([c[0], c[1]])).collect();
            return Partitioning { assignment, k };
        }
    }
    let p = partition_graph(&ds.graph, w, &train_mask(ds), strategy, k, 0.05, SEED);
    let bytes: Vec<u8> = p.assignment.iter().flat_map(|d| d.to_le_bytes()).collect();
    std::fs::write(&path, bytes).ok();
    p
}

fn encode_weights(w: &PresampleWeights) -> Vec<u8> {
    let mut out = Vec::with_capacity(16 + w.vertex.len() * 8 + w.edge.len() * 4);
    out.extend((w.vertex.len() as u64).to_le_bytes());
    out.extend((w.edge.len() as u64).to_le_bytes());
    out.extend((w.epochs as u64).to_le_bytes());
    for &v in &w.vertex {
        out.extend(v.to_le_bytes());
    }
    for &e in &w.edge {
        out.extend(e.to_le_bytes());
    }
    out
}

fn decode_weights(buf: &[u8], ds: &Dataset) -> Option<PresampleWeights> {
    if buf.len() < 24 {
        return None;
    }
    let nv = u64::from_le_bytes(buf[0..8].try_into().ok()?) as usize;
    let ne = u64::from_le_bytes(buf[8..16].try_into().ok()?) as usize;
    let epochs = u64::from_le_bytes(buf[16..24].try_into().ok()?) as usize;
    if nv != ds.graph.num_vertices()
        || ne != ds.graph.num_edges()
        || buf.len() != 24 + nv * 8 + ne * 4
    {
        return None;
    }
    let vertex = buf[24..24 + nv * 8].chunks_exact(8).map(|c| u64::from_le_bytes(c.try_into().unwrap())).collect();
    let edge = buf[24 + nv * 8..].chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect();
    Some(PresampleWeights { vertex, edge, epochs })
}

/// Run one epoch with an iteration cap; the modeled time is scaled up to
/// the full epoch (counters are NOT scaled — callers using counters should
/// pass `usize::MAX`).
pub fn epoch_time(
    engine: &mut dyn Engine,
    ctx: &EngineCtx,
    batch: usize,
    epoch_seed: u64,
    cap: usize,
) -> (IterCounters, PhaseBreakdown) {
    let targets = ctx.ds.epoch_targets(epoch_seed);
    let total_iters = targets.len().div_ceil(batch).max(1);
    let run_iters = total_iters.min(cap);
    let mut counters = IterCounters::new(ctx.k());
    let mut time = PhaseBreakdown::default();
    for (i, chunk) in targets.chunks(batch).take(run_iters).enumerate() {
        let c = engine.iteration(ctx, chunk, derive_seed(epoch_seed, &[i as u64]));
        time.add(iter_time(&c, &ctx.topo));
        counters.merge(&c);
    }
    let scale = total_iters as f64 / run_iters as f64;
    time.sampling *= scale;
    time.loading *= scale;
    time.fb *= scale;
    (counters, time)
}

/// Build the GSplit engine (presample → partition → engine).
pub fn build_gsplit(ctx: &EngineCtx, strategy: Strategy, batch: usize) -> SplitParallel {
    let w = presample_cached(ctx.ds, PRESAMPLE_EPOCHS, ctx.fanouts[0], ctx.fanouts.len());
    let part = partition_cached(ctx.ds, &w, strategy, ctx.k());
    SplitParallel::new(ctx, part, &w.vertex, batch)
}

/// Load a stand-in, smoke-aware. Under `BENCH_SMOKE=1` the features are
/// additionally served **out-of-core** from a `.gsg` file in the bench
/// cache, so every smoke run also exercises the disk path end to end
/// (DESIGN.md §Loading, disk tier) — bit-identical numerics, by contract.
pub fn load_standin(full: StandIn) -> Dataset {
    let s = smoke_standin(full);
    if smoke() {
        ooc_dataset(s)
    } else {
        s.load().expect("dataset")
    }
}

/// A stand-in served out-of-core: written once to `target/bench_cache/`
/// (tmp + rename, so concurrent benches never read a half-written file)
/// and reopened with a disk-backed feature source. The spec is copied
/// from the in-RAM dataset so offline cache keys and `scale_divisor`
/// stay exactly what the in-RAM path would use.
pub fn ooc_dataset(s: StandIn) -> Dataset {
    let ram = s.load().expect("dataset");
    let path = cache_dir().join(format!("{}_ooc.gsg", ram.spec.name));
    if !path.exists() {
        let tmp = cache_dir().join(format!("{}_ooc.gsg.tmp{}", ram.spec.name, std::process::id()));
        ram.write_gsg(&tmp).expect("write .gsg");
        std::fs::rename(&tmp, &path).expect("publish .gsg");
    }
    let mut ds = Dataset::open_ooc(&path, ram.spec.train_frac, ram.spec.seed ^ 0x5717)
        .expect("open .gsg out-of-core");
    ds.spec = ram.spec.clone();
    ds
}

pub fn all_datasets() -> Vec<Dataset> {
    smoke_standins(&StandIn::all_paper()).iter().map(|&s| load_standin(s)).collect()
}

/// Format a speedup column like the paper ("4.4×"; empty for the baseline).
pub fn speedup(other_total: f64, gsplit_total: f64) -> String {
    format!("{:.1}x", other_total / gsplit_total)
}
