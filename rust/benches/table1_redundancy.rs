//! **Table 1** — redundant computation and data loading of data parallelism:
//! the total edges computed and feature vectors loaded over one epoch when
//! each mini-batch is sampled as 4 micro-batches of size 1024 ("Micro") vs
//! one mini-batch of size 4096 ("Mini").

#[path = "bench_common.rs"]
mod bench_common;

use bench_common::*;
use gsplit::bench_harness::BenchSuite;
use gsplit::rng::{derive_seed, Pcg32};
use gsplit::sampling::Sampler;
use gsplit::util::{fmt_count, Table};
use gsplit::Vid;

fn main() {
    let mut suite = BenchSuite::new("table1_redundancy");
    println!("Table 1 — redundancy of data parallelism (micro 4×1024 vs mini 1×4096)\n");
    let mut table = Table::new(&[
        "Graph", "Edges Micro", "Edges Mini", "Ratio", "Feat Micro", "Feat Mini", "Ratio",
    ])
    .left(0);

    for ds in all_datasets() {
        let fanouts = vec![FANOUT; LAYERS];
        let targets = ds.epoch_targets(SEED);
        let mini_batch = 4 * BATCH;
        let cap = if quick() { 2 } else { usize::MAX };
        let mut sampler = Sampler::new();

        let (mut e_micro, mut e_mini) = (0u64, 0u64);
        let (mut f_micro, mut f_mini) = (0u64, 0u64);
        let total_iters = targets.len().div_ceil(mini_batch).max(1);
        let run_iters = total_iters.min(cap);
        for (i, chunk) in targets.chunks(mini_batch).take(run_iters).enumerate() {
            // Micro: 4 independent micro-batches, one per GPU.
            let micro: Vec<Vec<Vid>> = {
                let mut m = vec![Vec::new(); 4];
                for (j, &t) in chunk.iter().enumerate() {
                    m[j % 4].push(t);
                }
                m
            };
            for (d, mtargets) in micro.iter().enumerate() {
                let mut rng = Pcg32::new(derive_seed(SEED, &[i as u64, d as u64]));
                let mb = sampler.sample(&ds.graph, mtargets, &fanouts, &mut rng);
                e_micro += mb.total_edges();
                f_micro += mb.input_vertices().len() as u64;
            }
            // Mini: the same targets as ONE batch.
            let mut rng = Pcg32::new(derive_seed(SEED, &[i as u64, 0xffff]));
            let mb = sampler.sample(&ds.graph, chunk, &fanouts, &mut rng);
            e_mini += mb.total_edges();
            f_mini += mb.input_vertices().len() as u64;
        }
        let scale = total_iters as f64 / run_iters as f64;
        let s = |x: u64| (x as f64 * scale) as u64;
        suite.metric(
            &format!("{}/edge_ratio", ds.spec.name),
            e_micro as f64 / e_mini as f64,
        );
        suite.metric(
            &format!("{}/feat_ratio", ds.spec.name),
            f_micro as f64 / f_mini as f64,
        );
        table.row(vec![
            ds.spec.paper_name.to_string(),
            fmt_count(s(e_micro)),
            fmt_count(s(e_mini)),
            format!("{:.1}x", e_micro as f64 / e_mini as f64),
            fmt_count(s(f_micro)),
            fmt_count(s(f_mini)),
            format!("{:.1}x", f_micro as f64 / f_mini as f64),
        ]);
    }
    table.print();
    println!(
        "\nPaper (Table 1): Orkut 1.2x/2.5x, Papers100M 1.2x/1.5x, Friendster 1.0x/1.2x\n\
         (compute ratio / loading ratio; stand-ins should land in the same bands)"
    );
    suite.finish();
}
