//! **Figure 6(a)/(b)** — scalability.
//!
//! (a) Single host, 2 / 4 / 8 GPUs (8-GPU host is the NVLink hybrid cube
//!     mesh where not every pair is directly connected — Quiver must
//!     replicate its cache across the two 4-cliques, GSplit need not).
//! (b) Multi-host: 1 / 2 / 4 hosts × 4 GPUs; GSplit = data parallelism
//!     across hosts × split parallelism within each host.

#[path = "bench_common.rs"]
mod bench_common;

use bench_common::*;
use gsplit::bench_harness::BenchSuite;
use gsplit::devices::Topology;
use gsplit::exec::{DataParallel, EngineCtx, SplitParallel};
use gsplit::model::GnnKind;
use gsplit::partition::Strategy;
use gsplit::util::{fmt_secs, Table};

fn main() {
    let mut suite = BenchSuite::new("fig6_scaling");
    let kind = GnnKind::GraphSage;
    println!("Figure 6(a) — single-host scaling (epoch seconds; speedup = system/GSplit)\n");
    let mut ta =
        Table::new(&["Graph", "GPUs", "DGL", "Quiver", "GSplit", "DGL x", "Quiver x"]).left(0);
    for ds in all_datasets() {
        for gpus in [2usize, 4, 8] {
            let topo = Topology::for_gpus(gpus, ds.spec.scale_divisor).unwrap();
            let ctx = EngineCtx::new(&ds, topo, kind, HIDDEN, LAYERS, FANOUT);
            let w = presample_cached(&ds, PRESAMPLE_EPOCHS, FANOUT, LAYERS);
            let t_dgl = epoch_time(&mut DataParallel::dgl(&ctx), &ctx, BATCH, SEED, iter_cap()).1;
            let t_q =
                epoch_time(&mut DataParallel::quiver(&ctx, &w, BATCH), &ctx, BATCH, SEED, iter_cap()).1;
            let part = partition_cached(&ds, &w, Strategy::GSplit, gpus);
            let mut gs = SplitParallel::new(&ctx, part, &w.vertex, BATCH);
            let t_g = epoch_time(&mut gs, &ctx, BATCH, SEED, iter_cap()).1;
            for (sys, t) in [("dgl", &t_dgl), ("quiver", &t_q), ("gsplit", &t_g)] {
                suite.metric(&format!("{}/gpus{gpus}/{sys}/total_s", ds.spec.name), t.total());
            }
            ta.row(vec![
                ds.spec.paper_name.to_string(),
                gpus.to_string(),
                fmt_secs(t_dgl.total()),
                fmt_secs(t_q.total()),
                fmt_secs(t_g.total()),
                speedup(t_dgl.total(), t_g.total()),
                speedup(t_q.total(), t_g.total()),
            ]);
        }
        ta.sep();
    }
    ta.print();

    println!("\nFigure 6(b) — multi-host scaling (hosts × 4 GPUs; GraphSage)\n");
    let mut tb =
        Table::new(&["Graph", "Hosts", "DGL", "Quiver", "GSplit", "DGL x", "Quiver x"]).left(0);
    for ds in all_datasets() {
        for hosts in [1usize, 2, 4] {
            let topo = Topology::multi_host(hosts, ds.spec.scale_divisor);
            let k = topo.num_gpus();
            let ctx = EngineCtx::new(&ds, topo, kind, HIDDEN, LAYERS, FANOUT);
            let w = presample_cached(&ds, PRESAMPLE_EPOCHS, FANOUT, LAYERS);
            let t_dgl = epoch_time(&mut DataParallel::dgl(&ctx), &ctx, BATCH, SEED, iter_cap()).1;
            let t_q =
                epoch_time(&mut DataParallel::quiver(&ctx, &w, BATCH), &ctx, BATCH, SEED, iter_cap()).1;
            let part = partition_cached(&ds, &w, Strategy::GSplit, k);
            let mut gs = SplitParallel::new(&ctx, part, &w.vertex, BATCH);
            let t_g = epoch_time(&mut gs, &ctx, BATCH, SEED, iter_cap()).1;
            for (sys, t) in [("dgl", &t_dgl), ("quiver", &t_q), ("gsplit", &t_g)] {
                suite.metric(&format!("{}/hosts{hosts}/{sys}/total_s", ds.spec.name), t.total());
            }
            tb.row(vec![
                ds.spec.paper_name.to_string(),
                hosts.to_string(),
                fmt_secs(t_dgl.total()),
                fmt_secs(t_q.total()),
                fmt_secs(t_g.total()),
                speedup(t_dgl.total(), t_g.total()),
                speedup(t_q.total(), t_g.total()),
            ]);
        }
        tb.sep();
    }
    tb.print();
    println!(
        "\nPaper: GSplit's speedups grow with GPU count (more redundancy to avoid; no cache\n\
         replication on the 8-GPU cube mesh) and persist across hosts with hybrid parallelism."
    );
    suite.finish();
}
