//! **Figure 3** — epoch time breakdown of existing systems.
//!
//! (a) S / L / FB per epoch for DGL, Quiver, P3*, and the CAGNET-style 1D
//!     full-graph baseline on Orkut and Papers100M with GraphSage and GAT
//!     (the motivation figure: loading dominates DGL; P3* trades loading
//!     for shuffle-heavy FB; full-graph drops S entirely but pays
//!     whole-graph L and shuffle).
//! (b) percentage breakdown for Quiver on Orkut and Papers100M with
//!     GraphSage (loading stays significant even with distributed caching).
//! (+) loading-stage byte split of the **real-compute trainer** under each
//!     cache policy (DESIGN.md §Loading): Local / NVLink-peer / PCIe-host
//!     bytes must be nonzero where the policy predicts them and must sum
//!     to the uncached total — caching re-routes bytes, it never changes
//!     how many rows the model consumes.
//! (+) the same sweep with the dataset served **out-of-core** from a v2
//!     `.gsg` file: cache-miss host rows further split into Host (chunk
//!     buffer) and Disk (fault), the four tiers sum to the same in-RAM
//!     uncached total, and the distributed policy shows all four nonzero.
//! (+) span-trace consistency: a real serial trainer epoch recorded by the
//!     `obs` tracer must yield nonzero S / L / FB span-group totals that
//!     stay inside the measured wall-clock (DESIGN.md §Observability).

#[path = "bench_common.rs"]
mod bench_common;

use std::sync::Arc;

use bench_common::*;
use gsplit::bench_harness::BenchSuite;
use gsplit::cache::{CachePolicy, LoadStats, ResidentCache};
use gsplit::devices::Topology;
use gsplit::exec::{DataParallel, Engine, EngineCtx, FullGraph, PushPull};
use gsplit::graph::{Dataset, StandIn};
use gsplit::model::{GnnKind, ModelConfig};
use gsplit::partition::Partitioning;
use gsplit::runtime::NativeBackend;
use gsplit::train::{train_epoch, TrainConfig, Trainer};
use gsplit::util::{fmt_bytes, fmt_secs, Table};
use gsplit::Vid;

fn main() {
    let mut suite = BenchSuite::new("fig3_breakdown");
    println!(
        "Figure 3(a) — epoch breakdown of DGL / Quiver / P3* / FullGraph (modeled seconds)\n"
    );
    let mut table =
        Table::new(&["Graph", "Model", "System", "S", "L", "FB", "Total(s)", "L %"]).left(0).left(1).left(2);
    let mut quiver_pct: Vec<(String, f64, f64, f64)> = Vec::new();

    for standin in smoke_standins(&[StandIn::OrkutS, StandIn::PapersS]) {
        let ds = load_standin(standin);
        for kind in [GnnKind::GraphSage, GnnKind::Gat] {
            let ctx = EngineCtx::new(
                &ds,
                Topology::p3_8xlarge(ds.spec.scale_divisor),
                kind,
                HIDDEN,
                LAYERS,
                FANOUT,
            );
            let w = presample_cached(&ds, PRESAMPLE_EPOCHS, FANOUT, LAYERS);
            let mut run = |name: &str, e: &mut dyn Engine, batch: usize, cap: usize| {
                let (_, t) = epoch_time(e, &ctx, batch, SEED, cap);
                table.row(vec![
                    ds.spec.paper_name.to_string(),
                    kind.name().to_string(),
                    name.to_string(),
                    fmt_secs(t.sampling),
                    fmt_secs(t.loading),
                    fmt_secs(t.fb),
                    fmt_secs(t.total()),
                    format!("{:.0}%", 100.0 * t.loading / t.total()),
                ]);
                t
            };
            let mut record = |sys: &str, t: gsplit::costmodel::PhaseBreakdown| {
                let base = format!("{}/{}/{sys}", ds.spec.name, kind.name());
                suite.metric(&format!("{base}/loading_s"), t.loading);
                suite.metric(&format!("{base}/total_s"), t.total());
            };
            let td = run("DGL", &mut DataParallel::dgl(&ctx), BATCH, iter_cap());
            let tq = run("Quiver", &mut DataParallel::quiver(&ctx, &w, BATCH), BATCH, iter_cap());
            let tp = run("P3*", &mut PushPull::new(&ctx, BATCH), BATCH, iter_cap());
            // Full-graph training has no mini-batches: one pass is the epoch
            // (S ≈ 0, but L and the shuffle volume cover the whole graph).
            let tf = run("FullGraph", &mut FullGraph::new(&ctx), usize::MAX, 1);
            record("dgl", td);
            record("quiver", tq);
            record("p3", tp);
            record("fullgraph", tf);
            table.sep();
            if kind == GnnKind::GraphSage {
                quiver_pct.push((
                    ds.spec.paper_name.to_string(),
                    tq.sampling / tq.total() * 100.0,
                    tq.loading / tq.total() * 100.0,
                    tq.fb / tq.total() * 100.0,
                ));
            }
        }
    }
    table.print();

    println!("\nFigure 3(b) — Quiver phase percentages (GraphSage)\n");
    let mut t2 = Table::new(&["Graph", "Sampling %", "Loading %", "Training %"]).left(0);
    for (g, s, l, fb) in quiver_pct {
        t2.row(vec![g, format!("{s:.0}%"), format!("{l:.0}%"), format!("{fb:.0}%")]);
    }
    t2.print();
    println!(
        "\nPaper: DGL loading >60% of epoch time; Quiver cuts Orkut loading via NVLink cache\n\
         but Papers100M loading stays high (~30%); P3* has lowest L but highest FB."
    );

    let uncached_total = loading_split_section(&mut suite);
    loading_split_section_ooc(&mut suite, uncached_total);
    trace_consistency_section(&mut suite);
    suite.finish();
}

/// Trace one real serial trainer epoch and check the span-derived S/L/FB
/// phase totals against the measured wall-clock: every group is exercised
/// (nonzero), and — serial spans being disjoint on one thread — their sum
/// never exceeds the wall time.
fn trace_consistency_section(suite: &mut BenchSuite) {
    use gsplit::obs::{flush_thread, set_enabled, tracer, PhaseGroup};
    println!("\nSpan-trace consistency — serial trainer epoch, S/L/FB from recorded spans\n");
    let k = 4usize;
    let n_vertices = if quick() { 2048 } else { 4096 };
    let cfg = ModelConfig {
        kind: GnnKind::GraphSage,
        feat_dim: 32,
        hidden: 32,
        num_classes: 8,
        num_layers: 2,
    };
    let ds = Dataset::sbm_learnable(n_vertices, cfg.num_classes, cfg.feat_dim, 0.6, SEED);
    let part = Partitioning {
        assignment: (0..n_vertices as Vid).map(|v| (v % k as Vid) as u16).collect(),
        k,
    };
    let backend = NativeBackend::new();
    let mut trainer = Trainer::new(&backend, &cfg, 5, part, 0.2, SEED)
        .expect("trainer")
        .with_config(TrainConfig::new().trace(true))
        .expect("trace config");
    tracer().reset();
    let (wall, _) = gsplit::util::timer::timed(|| {
        train_epoch(&mut trainer, &ds, 256, 0).expect("traced epoch")
    });
    flush_thread();
    set_enabled(false);

    let (mut sampling, mut loading, mut fb) = (0f64, 0f64, 0f64);
    let mut n_spans = 0usize;
    for track in tracer().snapshot() {
        for span in &track.spans {
            n_spans += 1;
            match span.phase.group() {
                PhaseGroup::Sampling => sampling += span.secs(),
                PhaseGroup::Loading => loading += span.secs(),
                PhaseGroup::Fb => fb += span.secs(),
                PhaseGroup::Offline | PhaseGroup::Serving => {}
            }
        }
    }
    let total = sampling + loading + fb;
    println!(
        "wall {wall:.3}s | spans {n_spans} | S {sampling:.3}s | L {loading:.3}s | FB {fb:.3}s \
         | covered {:.0}%",
        100.0 * total / wall.max(1e-9)
    );
    assert!(n_spans > 0, "traced epoch recorded no spans");
    assert!(sampling > 0.0, "no sampling-phase span time recorded");
    assert!(loading > 0.0, "no loading-phase span time recorded");
    assert!(fb > 0.0, "no FB-phase span time recorded");
    assert!(
        total <= wall * 1.10,
        "serial spans are disjoint, so S+L+FB ({total:.3}s) cannot exceed the wall ({wall:.3}s)"
    );
    suite.metric("trace/span_total_s", total);
    suite.metric("trace/sampling_frac", sampling / wall.max(1e-9));
    suite.metric("trace/loading_frac", loading / wall.max(1e-9));
    suite.metric("trace/fb_frac", fb / wall.max(1e-9));
    tracer().reset();
}

/// Run the real-compute trainer's cache-aware loading stage under every
/// policy and report (and assert) the Local / Peer / Host byte split.
/// Returns the uncached total byte volume for the out-of-core section to
/// check against.
fn loading_split_section(suite: &mut BenchSuite) -> u64 {
    println!("\nLoading-stage byte split — real-compute trainer, per cache policy\n");
    let k = 4usize;
    let n_vertices = if quick() { 2048 } else { 8192 };
    let cfg = ModelConfig {
        kind: GnnKind::GraphSage,
        feat_dim: 32,
        hidden: 32,
        num_classes: 8,
        num_layers: 2,
    };
    let ds = Dataset::sbm_learnable(n_vertices, cfg.num_classes, cfg.feat_dim, 0.6, SEED);
    let part = Partitioning {
        assignment: (0..n_vertices as Vid).map(|v| (v % k as Vid) as u16).collect(),
        k,
    };
    let topo = Topology::p3_8xlarge(1.0);
    let ranking: Vec<u64> =
        (0..n_vertices as Vid).map(|v| ds.graph.degree(v) as u64).collect();
    // Budget at ~1/8 of the graph per device: enough that Local and Peer
    // hits are common while plenty of rows still miss to host memory.
    let budget = (n_vertices / 8) as u64;
    let backend = NativeBackend::new();
    let batch = 256usize;

    let mut table =
        Table::new(&["Policy", "Local", "Peer (NVLink)", "Host (PCIe)", "Total"]).left(0);
    let mut uncached_total: Option<u64> = None;
    for policy in [CachePolicy::None, CachePolicy::Distributed, CachePolicy::Partitioned] {
        let cache = (policy != CachePolicy::None).then(|| {
            Arc::new(ResidentCache::build(policy, &ranking, budget, &part, &topo, &ds.features))
        });
        let mut trainer = Trainer::new(&backend, &cfg, 5, part.clone(), 0.2, SEED)
            .expect("trainer")
            .with_config(TrainConfig::new().cache(cache))
            .expect("cache fits trainer");
        train_epoch(&mut trainer, &ds, batch, 0).expect("epoch");
        let split = LoadStats::sum(trainer.load_stats());
        table.row(vec![
            policy.name().to_string(),
            fmt_bytes(split.local_bytes),
            fmt_bytes(split.peer_bytes),
            fmt_bytes(split.host_bytes),
            fmt_bytes(split.total()),
        ]);
        for (kind, bytes) in [
            ("local_bytes", split.local_bytes),
            ("peer_bytes", split.peer_bytes),
            ("host_bytes", split.host_bytes),
            ("disk_bytes", split.disk_bytes),
        ] {
            suite.metric(&format!("trainer_load/{}/{kind}", policy.name()), bytes as f64);
        }
        assert_eq!(split.disk_bytes, 0, "the in-RAM source has no disk tier");

        // The acceptance invariants: every policy materializes exactly the
        // uncached byte volume, and the distributed policy produces a
        // nonzero three-way split on the all-NVLink 4-GPU host.
        match uncached_total {
            None => {
                assert_eq!(split.local_bytes + split.peer_bytes, 0, "no cache, no hits");
                uncached_total = Some(split.total());
            }
            Some(total) => assert_eq!(
                split.total(),
                total,
                "{}: Local/Peer/Host split must sum to the uncached total",
                policy.name()
            ),
        }
        if policy == CachePolicy::Distributed {
            assert!(
                split.local_bytes > 0 && split.peer_bytes > 0 && split.host_bytes > 0,
                "distributed policy must produce a nonzero Local/Peer/Host split, got {split:?}"
            );
        }
        if policy == CachePolicy::Partitioned {
            assert_eq!(split.peer_bytes, 0, "owner-consistent cache never fetches from peers");
            assert!(split.local_bytes > 0);
        }
    }
    table.print();
    println!(
        "\nGSplit's partitioned cache serves hits locally (owner-consistent, zero peer\n\
         traffic); Quiver-style distributed caching trades host loads for NVLink pulls."
    );
    uncached_total.expect("the CachePolicy::None pass ran first")
}

/// The same policy sweep with the dataset served out-of-core: features
/// come from a v2 `.gsg` file through a chunk-buffered `DiskFeatureStore`,
/// so the Host leg of the split divides into Host (buffer hit) and Disk
/// (chunk fault) — and the four tiers still sum to the in-RAM uncached
/// total, because the feature source never changes what the model reads.
fn loading_split_section_ooc(suite: &mut BenchSuite, ram_uncached_total: u64) {
    println!("\nLoading-stage byte split — out-of-core dataset (v2 .gsg), per cache policy\n");
    let k = 4usize;
    let n_vertices = if quick() { 2048 } else { 8192 };
    let cfg = ModelConfig {
        kind: GnnKind::GraphSage,
        feat_dim: 32,
        hidden: 32,
        num_classes: 8,
        num_layers: 2,
    };
    // Write the SAME SBM dataset the in-RAM section trained on, then train
    // from disk. Each policy opens a fresh store so the chunk buffer
    // starts cold and the Host/Disk split is reproducible.
    let ram = Dataset::sbm_learnable(n_vertices, cfg.num_classes, cfg.feat_dim, 0.6, SEED);
    let dir = std::env::temp_dir().join(format!("gsplit_fig3_ooc_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp dir");
    let path = dir.join("sbm.gsg");
    ram.write_gsg(&path).expect("write .gsg");
    let part = Partitioning {
        assignment: (0..n_vertices as Vid).map(|v| (v % k as Vid) as u16).collect(),
        k,
    };
    let topo = Topology::p3_8xlarge(1.0);
    let ranking: Vec<u64> = (0..n_vertices as Vid).map(|v| ram.graph.degree(v) as u64).collect();
    let budget = (n_vertices / 8) as u64;
    let backend = NativeBackend::new();
    let batch = 256usize;

    let mut table =
        Table::new(&["Policy", "Local", "Peer (NVLink)", "Host (buffer)", "Disk", "Total"]).left(0);
    for policy in [CachePolicy::None, CachePolicy::Distributed, CachePolicy::Partitioned] {
        // Split-seed derivation matches `sbm_learnable`, so the train/val
        // sets — and therefore every sampled batch — are identical.
        let mut ds = Dataset::open_ooc(&path, 0.5, SEED ^ 0x5717).expect("open .gsg");
        let store = gsplit::graph::DiskFeatureStore::open(&path).expect("open features");
        ds.features = Arc::new(store.with_buffer(256, 8));
        let cache = (policy != CachePolicy::None).then(|| {
            Arc::new(ResidentCache::build(policy, &ranking, budget, &part, &topo, &ds.features))
        });
        let mut trainer = Trainer::new(&backend, &cfg, 5, part.clone(), 0.2, SEED)
            .expect("trainer")
            .with_config(TrainConfig::new().cache(cache))
            .expect("cache fits trainer");
        train_epoch(&mut trainer, &ds, batch, 0).expect("epoch");
        let split = LoadStats::sum(trainer.load_stats());
        table.row(vec![
            policy.name().to_string(),
            fmt_bytes(split.local_bytes),
            fmt_bytes(split.peer_bytes),
            fmt_bytes(split.host_bytes),
            fmt_bytes(split.disk_bytes),
            fmt_bytes(split.total()),
        ]);
        for (kind, bytes) in [
            ("local_bytes", split.local_bytes),
            ("peer_bytes", split.peer_bytes),
            ("host_bytes", split.host_bytes),
            ("disk_bytes", split.disk_bytes),
        ] {
            suite.metric(&format!("trainer_load_ooc/{}/{kind}", policy.name()), bytes as f64);
        }

        // Acceptance invariants (ISSUE 7): a nonzero four-tier split that
        // sums to the in-RAM uncached total.
        assert_eq!(
            split.total(),
            ram_uncached_total,
            "{}: the four-tier split must sum to the in-RAM uncached total",
            policy.name()
        );
        assert!(split.disk_bytes > 0, "{}: cold chunk buffer must fault", policy.name());
        match policy {
            CachePolicy::None => {
                assert_eq!(split.local_bytes + split.peer_bytes, 0, "no cache, no hits")
            }
            CachePolicy::Distributed => assert!(
                split.local_bytes > 0
                    && split.peer_bytes > 0
                    && split.host_bytes > 0
                    && split.disk_bytes > 0,
                "distributed policy must produce a nonzero four-tier split, got {split:?}"
            ),
            CachePolicy::Partitioned => {
                assert_eq!(split.peer_bytes, 0, "owner-consistent cache never fetches from peers")
            }
        }
    }
    table.print();
    println!(
        "\nOut-of-core changes where bytes come FROM, never what the model consumes:\n\
         first touch of a chunk faults from disk, re-touches hit the host buffer."
    );
}
