//! **Figure 3** — epoch time breakdown of existing systems.
//!
//! (a) S / L / FB per epoch for DGL, Quiver, and P3* on Orkut and
//!     Papers100M with GraphSage and GAT (the motivation figure: loading
//!     dominates DGL; P3* trades loading for shuffle-heavy FB).
//! (b) percentage breakdown for Quiver on Orkut and Papers100M with
//!     GraphSage (loading stays significant even with distributed caching).

#[path = "bench_common.rs"]
mod bench_common;

use bench_common::*;
use gsplit::devices::Topology;
use gsplit::exec::{DataParallel, Engine, EngineCtx, PushPull};
use gsplit::graph::StandIn;
use gsplit::model::GnnKind;
use gsplit::util::{fmt_secs, Table};

fn main() {
    println!("Figure 3(a) — epoch breakdown of DGL / Quiver / P3* (modeled seconds)\n");
    let mut table =
        Table::new(&["Graph", "Model", "System", "S", "L", "FB", "Total(s)", "L %"]).left(0).left(1).left(2);
    let mut quiver_pct: Vec<(String, f64, f64, f64)> = Vec::new();

    for standin in [StandIn::OrkutS, StandIn::PapersS] {
        let ds = standin.load().expect("dataset");
        for kind in [GnnKind::GraphSage, GnnKind::Gat] {
            let ctx = EngineCtx::new(
                &ds,
                Topology::p3_8xlarge(ds.spec.scale_divisor),
                kind,
                HIDDEN,
                LAYERS,
                FANOUT,
            );
            let w = presample_cached(&ds, PRESAMPLE_EPOCHS, FANOUT, LAYERS);
            let mut run = |name: &str, e: &mut dyn Engine| {
                let (_, t) = epoch_time(e, &ctx, BATCH, SEED, iter_cap());
                table.row(vec![
                    ds.spec.paper_name.to_string(),
                    kind.name().to_string(),
                    name.to_string(),
                    fmt_secs(t.sampling),
                    fmt_secs(t.loading),
                    fmt_secs(t.fb),
                    fmt_secs(t.total()),
                    format!("{:.0}%", 100.0 * t.loading / t.total()),
                ]);
                t
            };
            run("DGL", &mut DataParallel::dgl(&ctx));
            let tq = run("Quiver", &mut DataParallel::quiver(&ctx, &w, BATCH));
            run("P3*", &mut PushPull::new(&ctx, BATCH));
            table.sep();
            if kind == GnnKind::GraphSage {
                quiver_pct.push((
                    ds.spec.paper_name.to_string(),
                    tq.sampling / tq.total() * 100.0,
                    tq.loading / tq.total() * 100.0,
                    tq.fb / tq.total() * 100.0,
                ));
            }
        }
    }
    table.print();

    println!("\nFigure 3(b) — Quiver phase percentages (GraphSage)\n");
    let mut t2 = Table::new(&["Graph", "Sampling %", "Loading %", "Training %"]).left(0);
    for (g, s, l, fb) in quiver_pct {
        t2.row(vec![g, format!("{s:.0}%"), format!("{l:.0}%"), format!("{fb:.0}%")]);
    }
    t2.print();
    println!(
        "\nPaper: DGL loading >60% of epoch time; Quiver cuts Orkut loading via NVLink cache\n\
         but Papers100M loading stays high (~30%); P3* has lowest L but highest FB."
    );
}
