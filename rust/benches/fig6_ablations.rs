//! **Figure 6(c)/(d)/(e)** — hyper-parameter ablations on Friendster, plus
//! two design-choice ablations beyond the paper's figures:
//!
//! (c) hidden size {64, 128, 256, 512};
//! (d) batch size {1024, 2048, 4096, 8192} at hidden 128;
//! (e) GNN layers {2, 3, 4} at hidden 128 (fanout shrinks with depth to
//!     bound memory, as in the paper);
//! (+) pre-sampling epoch count {2, 10, 30} → splitting quality (§7.3
//!     claim: 10 epochs suffice);
//! (+) cache ranking policy: pre-sample frequency vs degree.

#[path = "bench_common.rs"]
mod bench_common;

use bench_common::*;
use gsplit::bench_harness::BenchSuite;
use gsplit::cache::FeatureCache;
use gsplit::devices::Topology;
use gsplit::exec::{DataParallel, Engine, EngineCtx, PushPull, SplitParallel};
use gsplit::graph::StandIn;
use gsplit::model::GnnKind;
use gsplit::partition::{evaluate_partitioning, Strategy};
use gsplit::util::{fmt_secs, Table};
use gsplit::Vid;

fn run_all(
    ctx: &EngineCtx,
    w: &gsplit::presample::PresampleWeights,
    batch: usize,
) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    let mut run = |name: &str, e: &mut dyn Engine| {
        let t = epoch_time(e, ctx, batch, SEED, iter_cap()).1;
        out.push((name.to_string(), t.total()));
    };
    run("DGL", &mut DataParallel::dgl(ctx));
    run("Quiver", &mut DataParallel::quiver(ctx, w, batch));
    run("P3*", &mut PushPull::new(ctx, batch));
    let part = partition_cached(ctx.ds, w, Strategy::GSplit, ctx.k());
    run("GSplit", &mut SplitParallel::new(ctx, part, &w.vertex, batch));
    out
}

fn main() {
    let mut suite = BenchSuite::new("fig6_ablations");
    let ds = load_standin(StandIn::FriendsterS);
    let topo = || Topology::p3_8xlarge(ds.spec.scale_divisor);
    let w = presample_cached(&ds, PRESAMPLE_EPOCHS, FANOUT, LAYERS);

    for kind in [GnnKind::GraphSage, GnnKind::Gat] {
        println!("Figure 6(c) — hidden size ablation, Friendster, {}\n", kind.name());
        let mut t = Table::new(&["Hidden", "DGL", "Quiver", "P3*", "GSplit", "best-baseline x"]).left(0);
        for hidden in [64usize, 128, 256, 512] {
            let ctx = EngineCtx::new(&ds, topo(), kind, hidden, LAYERS, FANOUT);
            let r = run_all(&ctx, &w, BATCH);
            let g = r.iter().find(|(n, _)| n == "GSplit").unwrap().1;
            suite.metric(&format!("hidden{hidden}/{}/gsplit_total_s", kind.name()), g);
            let best = r.iter().filter(|(n, _)| n != "GSplit").map(|(_, t)| *t).fold(f64::MAX, f64::min);
            t.row(vec![
                hidden.to_string(),
                fmt_secs(r[0].1),
                fmt_secs(r[1].1),
                fmt_secs(r[2].1),
                fmt_secs(g),
                speedup(best, g),
            ]);
        }
        t.print();
        println!();
    }

    println!("Figure 6(d) — batch size ablation, Friendster, hidden 128, GraphSage\n");
    let mut t = Table::new(&["Batch", "DGL", "Quiver", "P3*", "GSplit", "best-baseline x"]).left(0);
    for batch in [1024usize, 2048, 4096, 8192] {
        let ctx = EngineCtx::new(&ds, topo(), GnnKind::GraphSage, 128, LAYERS, FANOUT);
        let r = run_all(&ctx, &w, batch);
        let g = r.iter().find(|(n, _)| n == "GSplit").unwrap().1;
        suite.metric(&format!("batch{batch}/gsplit_total_s"), g);
        let best = r.iter().filter(|(n, _)| n != "GSplit").map(|(_, t)| *t).fold(f64::MAX, f64::min);
        t.row(vec![
            batch.to_string(),
            fmt_secs(r[0].1),
            fmt_secs(r[1].1),
            fmt_secs(r[2].1),
            fmt_secs(g),
            speedup(best, g),
        ]);
    }
    t.print();

    println!("\nFigure 6(e) — #layers ablation, Friendster, hidden 128, fanout capped by depth\n");
    let mut t = Table::new(&["Layers", "Fanout", "DGL", "Quiver", "P3*", "GSplit", "best x"]).left(0);
    for (layers, fanout) in [(2usize, 25usize), (3, 15), (4, 8)] {
        let wl = presample_cached(&ds, PRESAMPLE_EPOCHS, fanout, layers);
        let ctx = EngineCtx::new(&ds, topo(), GnnKind::GraphSage, 128, layers, fanout);
        let r = run_all(&ctx, &wl, BATCH);
        let g = r.iter().find(|(n, _)| n == "GSplit").unwrap().1;
        suite.metric(&format!("layers{layers}/gsplit_total_s"), g);
        let best = r.iter().filter(|(n, _)| n != "GSplit").map(|(_, t)| *t).fold(f64::MAX, f64::min);
        t.row(vec![
            layers.to_string(),
            fanout.to_string(),
            fmt_secs(r[0].1),
            fmt_secs(r[1].1),
            fmt_secs(r[2].1),
            fmt_secs(g),
            speedup(best, g),
        ]);
    }
    t.print();
    println!(
        "\nPaper: GSplit wins at 2–3 layers; at 4 layers the extra shuffles erode the\n\
         advantage for GraphSage (split parallelism only for bottom layers = future work)."
    );

    // --- extra ablation 1: pre-sampling epoch count (§7.3) ---
    println!("\nAblation — pre-sampling epochs vs splitting quality (Papers100M)\n");
    let dsp = load_standin(StandIn::PapersS);
    let mut t = Table::new(&["Presample epochs", "Cut frac", "Imbalance"]).left(0);
    for epochs in [2usize, 10, 30] {
        if quick() && epochs > 10 {
            continue;
        }
        let w = presample_cached(&dsp, epochs, FANOUT, LAYERS);
        let part = partition_cached(&dsp, &w, Strategy::GSplit, 4);
        let q = evaluate_partitioning(&dsp.graph, &w, &part);
        suite.metric(&format!("presample{epochs}/cut_fraction"), q.cut_fraction());
        t.row(vec![
            epochs.to_string(),
            format!("{:.4}", q.cut_fraction()),
            format!("{:.3}", q.imbalance),
        ]);
    }
    t.print();
    println!("Paper: beyond 10 epochs, imbalance moves <2% and cross edges <2–7%.");

    // --- extra ablation 2: cache ranking policy ---
    println!("\nAblation — cache ranking: pre-sample frequency vs degree (Papers100M, GSplit)\n");
    let ctx = EngineCtx::new(&dsp, Topology::p3_8xlarge(dsp.spec.scale_divisor), GnnKind::GraphSage, HIDDEN, LAYERS, FANOUT);
    let w = presample_cached(&dsp, PRESAMPLE_EPOCHS, FANOUT, LAYERS);
    let part = partition_cached(&dsp, &w, Strategy::GSplit, 4);
    let degree_rank: Vec<u64> =
        (0..dsp.graph.num_vertices() as Vid).map(|v| dsp.graph.degree(v) as u64).collect();
    let rows = ctx.cache_rows(BATCH);
    let mut t = Table::new(&["Ranking", "Cache coverage", "Epoch loading (s)"]).left(0);
    for (name, ranking) in [("presample-freq", &w.vertex), ("degree", &degree_rank)] {
        let cache = FeatureCache::partitioned(ranking, rows, &part);
        let coverage = cache.coverage();
        let mut e = SplitParallel::new(&ctx, part.clone(), ranking, BATCH);
        let time = epoch_time(&mut e, &ctx, BATCH, SEED, iter_cap()).1;
        suite.metric(&format!("cache_ranking/{name}/loading_s"), time.loading);
        t.row(vec![
            name.to_string(),
            format!("{:.1}%", coverage * 100.0),
            format!("{:.3}", time.loading),
        ]);
    }
    t.print();
    suite.finish();
}
