//! Property tests for the kernel dispatch contract (DESIGN.md §Perf "Rust
//! kernel blocking"): sweeping kernel variants × shapes — `m`/`din`/`dout`/
//! fanout including non-multiples of the register-block size, isolated
//! vertices, and `k = 0` rows — the `blocked` and `simd` layer paths must
//! match the scalar oracle through the public `Backend` API:
//!
//! * `blocked` **bit-exactly** (its contract preserves each element's
//!   accumulation order),
//! * `simd` within `SIMD_REL_TOL` (FMA + lane-reassociated dots), except
//!   gather-mean, which stays bit-exact under every variant.

use gsplit::model::{GnnKind, LayerParams};
use gsplit::rng::Pcg32;
use gsplit::runtime::kernels::{self, KernelKind, SIMD_REL_TOL};
use gsplit::runtime::{Backend, NativeBackend};
use gsplit::sampling::NO_NEIGHBOR;
use gsplit::testing::for_all_seeds;

fn rand_vec(rng: &mut Pcg32, len: usize, scale: f32) -> Vec<f32> {
    (0..len).map(|_| (rng.next_f32() - 0.5) * scale).collect()
}

/// Random neighbor table with ~25% padded slots; when `m ≥ 2` and `k > 0`,
/// row 1 is fully padded (an isolated vertex) and row 0 repeats a neighbor.
fn rand_neigh(rng: &mut Pcg32, m: usize, k: usize, n: usize) -> Vec<u32> {
    let mut neigh = vec![NO_NEIGHBOR; m * k];
    for i in 0..m {
        if i == 1 {
            continue;
        }
        for j in 0..k {
            if rng.gen_range(4) != 0 {
                neigh[i * k + j] = rng.gen_range(n as u32);
            }
        }
    }
    if m >= 1 && k >= 2 {
        neigh[0] = 0; // self as neighbor
        neigh[1] = 0; // repeated neighbor
    }
    neigh
}

fn rand_params(rng: &mut Pcg32, model: GnnKind, din: usize, dout: usize) -> LayerParams {
    match model {
        GnnKind::GraphSage => LayerParams {
            tensors: vec![
                rand_vec(rng, din * dout, 1.0),
                rand_vec(rng, din * dout, 1.0),
                rand_vec(rng, dout, 0.5),
            ],
            shapes: vec![(din, dout), (din, dout), (1, dout)],
        },
        GnnKind::Gat => LayerParams {
            tensors: vec![
                rand_vec(rng, din * dout, 1.0),
                rand_vec(rng, dout, 0.8),
                rand_vec(rng, dout, 0.8),
                rand_vec(rng, dout, 0.5),
            ],
            shapes: vec![(din, dout), (1, dout), (1, dout), (1, dout)],
        },
    }
}

/// `bit = true` → exact equality; otherwise the documented simd tolerance.
fn assert_close(tag: &str, got: &[f32], want: &[f32], bit: bool) {
    assert_eq!(got.len(), want.len(), "{tag}: length");
    if bit {
        assert_eq!(got, want, "{tag}: expected bit-identical output");
    } else {
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            assert!(
                (g - w).abs() <= SIMD_REL_TOL * (1.0 + w.abs()),
                "{tag}[{i}]: {g} vs oracle {w} exceeds SIMD_REL_TOL"
            );
        }
    }
}

/// The non-scalar variants worth testing on this host, with whether their
/// contract is bit-exact for the dense/attention layer paths.
fn variants() -> Vec<(KernelKind, bool)> {
    let mut v = vec![(KernelKind::Blocked, true)];
    if kernels::simd_available() {
        v.push((KernelKind::Simd, false));
    }
    v
}

#[test]
fn kernel_variants_match_scalar_oracle_across_shapes() {
    // First cases pin adversarial shapes: singleton dims, exact multiples
    // of the 4×8 register tile, non-multiples straddling both tails, k = 0
    // (no neighbor table at all), and a wide-but-short batch.
    const FIXED: [(usize, usize, usize, usize); 7] = [
        (1, 1, 1, 0),
        (1, 8, 8, 1),
        (4, 8, 16, 2),
        (3, 9, 7, 3),
        (5, 13, 24, 4),
        (2, 33, 5, 6),
        (7, 17, 9, 0),
    ];
    let scalar = NativeBackend::with_kernels(KernelKind::Scalar);
    for_all_seeds("kernel-equivalence", 24, |rng, case| {
        let (m, din, dout, k) = if (case as usize) < FIXED.len() {
            FIXED[case as usize]
        } else {
            (
                1 + rng.gen_range(8) as usize,
                1 + rng.gen_range(34) as usize,
                1 + rng.gen_range(34) as usize,
                rng.gen_range(6) as usize,
            )
        };
        let n = m + rng.gen_range(2 * (k as u32) + 3) as usize;
        let x = rand_vec(rng, n * din, 2.0);
        let neigh = rand_neigh(rng, m, k, n);
        let g_out = rand_vec(rng, m * dout, 1.0);
        for model in [GnnKind::GraphSage, GnnKind::Gat] {
            let params = rand_params(rng, model, din, dout);
            for relu in [false, true] {
                let o_s = scalar
                    .layer_fwd(model, din, dout, relu, &x, n, &neigh, m, k, &params)
                    .unwrap();
                let b_s = scalar
                    .layer_bwd(model, din, dout, relu, &x, n, &neigh, m, k, &g_out, &params)
                    .unwrap();
                for (kind, bit) in variants() {
                    let be = NativeBackend::with_kernels(kind);
                    let tag = format!("{model:?}/{}/relu={relu}/m={m},din={din},dout={dout},k={k}",
                        kind.name());
                    let o = be
                        .layer_fwd(model, din, dout, relu, &x, n, &neigh, m, k, &params)
                        .unwrap();
                    assert_close(&format!("{tag}/fwd"), &o, &o_s, bit);
                    let b = be
                        .layer_bwd(model, din, dout, relu, &x, n, &neigh, m, k, &g_out, &params)
                        .unwrap();
                    assert_close(&format!("{tag}/g_x"), &b.g_x, &b_s.g_x, bit);
                    assert_eq!(b.g_params.len(), b_s.g_params.len());
                    for (t, (gp, gp_s)) in b.g_params.iter().zip(&b_s.g_params).enumerate() {
                        assert_close(&format!("{tag}/g_params[{t}]"), gp, gp_s, bit);
                    }
                }
            }
        }
    });
}

#[test]
fn gather_mean_is_bit_exact_under_every_kernel() {
    // The gather-mean contract is stricter: every variant, including simd,
    // is bit-identical (plain adds in slot order + one reciprocal scale).
    for_all_seeds("gather-mean-bit-exact", 16, |rng, _| {
        let m = 1 + rng.gen_range(10) as usize;
        let k = rng.gen_range(7) as usize;
        let din = 1 + rng.gen_range(40) as usize;
        let n = m + rng.gen_range(8) as usize;
        let x = rand_vec(rng, n * din, 2.0);
        let neigh = rand_neigh(rng, m, k, n);
        let mut agg_s = vec![0f32; m * din];
        let mut den_s = vec![0f32; m];
        kernels::gather::gather_mean(
            KernelKind::Scalar, &x, &neigh, m, k, din, &mut agg_s, &mut den_s,
        );
        for kind in [KernelKind::Blocked, KernelKind::Simd] {
            let mut agg = vec![1f32; m * din];
            let mut den = vec![1f32; m];
            kernels::gather::gather_mean(kind, &x, &neigh, m, k, din, &mut agg, &mut den);
            assert_eq!(agg_s, agg, "{} agg", kind.name());
            assert_eq!(den_s, den, "{} denoms", kind.name());
        }
    });
}

#[test]
fn with_kernels_resolves_and_reports() {
    let be = NativeBackend::with_kernels(KernelKind::Blocked);
    assert_eq!(be.kernels(), KernelKind::Blocked);
    let be = NativeBackend::with_kernels(KernelKind::Simd);
    if kernels::simd_available() {
        assert_eq!(be.kernels(), KernelKind::Simd);
    } else {
        assert_eq!(be.kernels(), KernelKind::Blocked);
    }
}
