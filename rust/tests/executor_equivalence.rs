//! Bit-equivalence of the threaded pipelined executor against the serial
//! reference trainer (DESIGN.md §Executor determinism contract): for the
//! same seed, `IterStats` (loss, correct, examples) and the post-epoch
//! `ParamStore` must be **bit-identical** on `StandIn::Tiny` — across
//! seeds, layer counts, worker counts, and under channel backpressure.

use gsplit::graph::{Dataset, StandIn};
use gsplit::model::{GnnKind, ModelConfig, ParamStore};
use gsplit::partition::Partitioning;
use gsplit::runtime::NativeBackend;
use gsplit::train::{train_epoch, ExecMode, IterStats, PipelineConfig, TrainConfig, Trainer};

const FANOUT: usize = 5;
const K: usize = 4;

fn tiny_cfg(num_layers: usize) -> ModelConfig {
    // StandIn::Tiny: 32-dim features, degree-derived labels in 0..16.
    ModelConfig { kind: GnnKind::GraphSage, feat_dim: 32, hidden: 32, num_classes: 16, num_layers }
}

fn modulo_part(ds: &Dataset, k: usize) -> Partitioning {
    Partitioning {
        assignment: (0..ds.graph.num_vertices() as u32).map(|v| (v % k as u32) as u16).collect(),
        k,
    }
}

fn assert_params_bit_identical(a: &ParamStore, b: &ParamStore, what: &str) {
    assert_eq!(a.layers.len(), b.layers.len());
    for (l, (la, lb)) in a.layers.iter().zip(&b.layers).enumerate() {
        assert_eq!(la.tensors.len(), lb.tensors.len());
        for (t, (ta, tb)) in la.tensors.iter().zip(&lb.tensors).enumerate() {
            assert_eq!(ta.len(), tb.len());
            for (i, (x, y)) in ta.iter().zip(tb).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "{what}: param layer {l} tensor {t} elem {i}: {x} != {y}"
                );
            }
        }
    }
}

fn assert_stats_bit_identical(a: &[IterStats], b: &[IterStats], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: iteration counts differ");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.examples, y.examples, "{what}: iter {i} examples");
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "{what}: iter {i} loss {} != {}", x.loss, y.loss);
        assert_eq!(x.correct.to_bits(), y.correct.to_bits(), "{what}: iter {i} correct");
    }
}

/// Train one epoch serially and one epoch with the given pipeline config,
/// from identical initial states, and demand bit-identical outcomes.
fn check_epoch_equivalence(num_layers: usize, seed: u64, pipeline: PipelineConfig, what: &str) {
    let ds = StandIn::Tiny.load().unwrap();
    let cfg = tiny_cfg(num_layers);
    let part = modulo_part(&ds, K);
    let backend = NativeBackend::new();

    let mut serial = Trainer::new(&backend, &cfg, FANOUT, part.clone(), 0.2, seed).unwrap();
    let mut pipelined = Trainer::new(&backend, &cfg, FANOUT, part, 0.2, seed)
        .unwrap()
        .with_config(TrainConfig::new().exec(ExecMode::Pipelined(pipeline)))
        .unwrap();
    assert_params_bit_identical(&serial.params, &pipelined.params, "init");

    let a = train_epoch(&mut serial, &ds, 512, seed).unwrap();
    let b = train_epoch(&mut pipelined, &ds, 512, seed).unwrap();
    assert!(!a.is_empty(), "epoch must contain iterations");
    assert_stats_bit_identical(&a, &b, what);
    assert_params_bit_identical(&serial.params, &pipelined.params, what);
}

#[test]
fn pipelined_epoch_bit_identical_across_worker_counts() {
    // Acceptance matrix: worker counts 1, 2, and k on the 3-layer model.
    for workers in [1usize, 2, K] {
        check_epoch_equivalence(
            3,
            42,
            PipelineConfig::with_workers(workers),
            &format!("3-layer workers={workers}"),
        );
    }
}

#[test]
fn pipelined_epoch_bit_identical_across_layer_counts() {
    for num_layers in [1usize, 2, 3] {
        check_epoch_equivalence(
            num_layers,
            42,
            PipelineConfig::with_workers(2),
            &format!("{num_layers}-layer workers=2"),
        );
    }
}

#[test]
fn pipelined_epoch_bit_identical_across_seeds() {
    for seed in [1u64, 0xC0FFEE] {
        check_epoch_equivalence(
            2,
            seed,
            PipelineConfig::with_workers(2),
            &format!("2-layer seed={seed}"),
        );
    }
}

#[test]
fn backpressure_stress_still_bit_identical() {
    // Single-row chunks through capacity-1 channels: maximal backpressure,
    // workers must interleave sends with receives to make progress — and
    // the results must not change at all.
    let stress = PipelineConfig { workers: 3, channel_cap: 1, chunk_rows: 1 };
    check_epoch_equivalence(2, 9, stress, "backpressure stress");
}

#[test]
fn pipelined_evaluate_matches_serial() {
    let ds = StandIn::Tiny.load().unwrap();
    let cfg = tiny_cfg(3);
    let part = modulo_part(&ds, K);
    let backend = NativeBackend::new();
    let mut serial = Trainer::new(&backend, &cfg, FANOUT, part.clone(), 0.2, 5).unwrap();
    let mut pipelined = Trainer::new(&backend, &cfg, FANOUT, part, 0.2, 5)
        .unwrap()
        .with_config(TrainConfig::new().parallel_workers(K))
        .unwrap();
    let targets = &ds.labels.val_set[..256];
    let a = serial.evaluate(&ds, targets, 77).unwrap();
    let b = pipelined.evaluate(&ds, targets, 77).unwrap();
    assert_eq!(a.examples, b.examples);
    assert_eq!(a.loss.to_bits(), b.loss.to_bits());
    assert_eq!(a.correct.to_bits(), b.correct.to_bits());
    // Forward-only evaluation must not touch parameters.
    assert_params_bit_identical(&serial.params, &pipelined.params, "evaluate");
}

#[test]
fn tracing_changes_no_output_bit() {
    // DESIGN.md §Observability: the span recorder only reads clocks, so
    // enabling it must not move a single output bit under either
    // executor. One traced test per binary — the tracer is
    // process-global and toggling it from parallel tests would race.
    let ds = StandIn::Tiny.load().unwrap();
    let cfg = tiny_cfg(2);
    let part = modulo_part(&ds, K);
    let backend = NativeBackend::new();

    let mut untraced = Trainer::new(&backend, &cfg, FANOUT, part.clone(), 0.2, 11).unwrap();
    let a = train_epoch(&mut untraced, &ds, 512, 11).unwrap();

    let mut serial = Trainer::new(&backend, &cfg, FANOUT, part.clone(), 0.2, 11)
        .unwrap()
        .with_config(TrainConfig::new().trace(true))
        .unwrap();
    let b = train_epoch(&mut serial, &ds, 512, 11).unwrap();

    let mut pipelined = Trainer::new(&backend, &cfg, FANOUT, part, 0.2, 11)
        .unwrap()
        .with_config(TrainConfig::new().parallel_workers(2))
        .unwrap();
    let c = train_epoch(&mut pipelined, &ds, 512, 11).unwrap();
    gsplit::obs::set_enabled(false);

    gsplit::obs::flush_thread();
    let spans: usize = gsplit::obs::tracer().snapshot().iter().map(|t| t.spans.len()).sum();
    assert!(spans > 0, "traced runs must have recorded spans");
    gsplit::obs::tracer().reset();

    assert_stats_bit_identical(&a, &b, "traced serial vs untraced serial");
    assert_stats_bit_identical(&a, &c, "traced pipelined vs untraced serial");
    assert_params_bit_identical(&untraced.params, &serial.params, "traced serial params");
    assert_params_bit_identical(&untraced.params, &pipelined.params, "traced pipelined params");
}

#[test]
fn single_iteration_and_single_device_paths() {
    // k = 1 (self-channel only) and a one-off pipelined train_iteration.
    let ds = StandIn::Tiny.load().unwrap();
    let cfg = tiny_cfg(2);
    let part = modulo_part(&ds, 1);
    let backend = NativeBackend::new();
    let mut serial = Trainer::new(&backend, &cfg, FANOUT, part.clone(), 0.2, 3).unwrap();
    let mut pipelined = Trainer::new(&backend, &cfg, FANOUT, part, 0.2, 3)
        .unwrap()
        .with_config(TrainConfig::new().parallel_workers(2))
        .unwrap();
    let epoch_targets = ds.epoch_targets(0);
    let targets = &epoch_targets[..192];
    let a = serial.train_iteration(&ds, targets, 0).unwrap();
    let b = pipelined.train_iteration(&ds, targets, 0).unwrap();
    assert_eq!(a.loss.to_bits(), b.loss.to_bits());
    assert_eq!(a.correct.to_bits(), b.correct.to_bits());
    assert_eq!(a.examples, b.examples);
    assert_params_bit_identical(&serial.params, &pipelined.params, "k=1 iteration");
}
