//! Whole-pipeline integration tests: presample → partition → split-sample →
//! forward/backward → SGD, across all engines.
//!
//! The numerics run through the default `NativeBackend`, so the entire
//! suite executes on a fresh clone — no artifacts, no Python.

use gsplit::costmodel::iter_time;
use gsplit::exec::{run_epoch, DataParallel, Engine, EngineCtx, PushPull, SplitParallel};
use gsplit::devices::Topology;
use gsplit::graph::{Dataset, GraphBuilder, StandIn};
use gsplit::model::{GnnKind, ModelConfig};
use gsplit::partition::{partition_graph, Partitioning, Strategy};
use gsplit::presample::{presample, PresampleConfig, PresampleWeights};
use gsplit::runtime::NativeBackend;
use gsplit::train::Trainer;
use gsplit::Vid;

/// Per-layer neighbor fanout used by the real-compute tests.
const FANOUT: usize = 5;

fn model_cfg(kind: GnnKind) -> ModelConfig {
    ModelConfig { kind, feat_dim: 32, hidden: 32, num_classes: 8, num_layers: 3 }
}

#[test]
fn split_parallel_training_learns_sbm_communities() {
    let backend = NativeBackend::new();
    let cfg = model_cfg(GnnKind::GraphSage);
    let ds = Dataset::sbm_learnable(4096, cfg.num_classes, cfg.feat_dim, 0.6, 42);
    let w = PresampleWeights::uniform(&ds.graph);
    let mask = vec![false; ds.graph.num_vertices()];
    let part = partition_graph(&ds.graph, &w, &mask, Strategy::Edge, 4, 0.1, 7);
    let mut trainer = Trainer::new(&backend, &cfg, FANOUT, part, 0.2, 11).unwrap();

    let first = trainer
        .train_iteration(&ds, &ds.epoch_targets(0)[..192], 0)
        .unwrap();
    let mut last = first;
    for step in 1..30 {
        let targets = ds.epoch_targets(step as u64);
        last = trainer.train_iteration(&ds, &targets[..192], step as u64).unwrap();
    }
    assert!(
        last.loss < first.loss * 0.8,
        "loss should drop: {} -> {}",
        first.loss,
        last.loss
    );
    // Validation accuracy ≫ random (1/num_classes).
    let val = trainer.evaluate(&ds, &ds.labels.val_set[..192], 999).unwrap();
    assert!(
        val.accuracy() > 2.0 / cfg.num_classes as f32,
        "val accuracy {} vs random {}",
        val.accuracy(),
        1.0 / cfg.num_classes as f32
    );
}

/// With fanout ≥ max degree, neighborhood "sampling" is deterministic
/// (every neighbor taken), so the computed loss must be *identical* no
/// matter how many devices cooperate — the strongest correctness statement
/// about cooperative split-parallel execution + shuffles.
#[test]
fn split_parallel_is_equivalent_to_single_device_when_sampling_is_exhaustive() {
    let backend = NativeBackend::new();
    let cfg = model_cfg(GnnKind::GraphSage);
    let kernel_k = FANOUT;

    // Bounded-degree graph: ring + a few chords, max degree ≤ kernel_k.
    let n = 600usize;
    let mut b = GraphBuilder::new(n).symmetric();
    for v in 0..n {
        b.add_edge(v as Vid, ((v + 1) % n) as Vid);
    }
    for v in (0..n).step_by(7) {
        b.add_edge(v as Vid, ((v + n / 2) % n) as Vid);
    }
    let graph = b.finish();
    assert!(graph.max_degree() as usize <= kernel_k, "need degree ≤ fanout");
    let labels: Vec<u32> = (0..n).map(|v| (v % cfg.num_classes) as u32).collect();
    let features = gsplit::graph::FeatureStore::correlated(&labels, cfg.feat_dim, 0.3, 5);
    let ds = Dataset {
        spec: StandIn::Tiny.spec(),
        graph,
        features: std::sync::Arc::new(features),
        labels: gsplit::graph::LabelStore::with_split(labels, 0.5, 3),
    };

    let targets: Vec<Vid> = (0..128).collect();
    let mut losses = Vec::new();
    for k in [1usize, 2, 4] {
        let part = Partitioning {
            assignment: (0..n).map(|v| (v % k) as u16).collect(),
            k,
        };
        let mut trainer = Trainer::new(&backend, &cfg, kernel_k, part, 0.1, 77).unwrap();
        let stats = trainer.evaluate(&ds, &targets, 1).unwrap();
        losses.push(stats.loss);
    }
    for w in losses.windows(2) {
        assert!(
            (w[0] - w[1]).abs() < 1e-4 * (1.0 + w[0].abs()),
            "split-parallel loss must be k-invariant under exhaustive sampling: {losses:?}"
        );
    }
}

#[test]
fn all_engines_run_an_epoch_and_gsplit_loads_least() {
    let ds = StandIn::Tiny.load().unwrap();
    // Small GPUs: caches can hold only part of the features.
    let topo = Topology::p3_8xlarge(200.0);
    let ctx = EngineCtx::new(&ds, topo, GnnKind::GraphSage, 64, 3, 5);
    let pw = presample(
        &ds.graph,
        &ds.labels.train_set,
        &PresampleConfig { epochs: 2, batch_size: 256, fanouts: vec![5, 5, 5], seed: 1 },
    );
    let mask: Vec<bool> = {
        let mut m = vec![false; ds.graph.num_vertices()];
        for &t in &ds.labels.train_set {
            m[t as usize] = true;
        }
        m
    };
    let part = partition_graph(&ds.graph, &pw, &mask, Strategy::GSplit, 4, 0.1, 2);

    let mut engines: Vec<Box<dyn Engine>> = vec![
        Box::new(DataParallel::dgl(&ctx)),
        Box::new(DataParallel::quiver(&ctx, &pw, 256)),
        Box::new(PushPull::new(&ctx, 256)),
        Box::new(SplitParallel::new(&ctx, part, &pw.vertex, 256)),
    ];
    let mut loads = Vec::new();
    for e in engines.iter_mut() {
        let (counters, time) = run_epoch(e.as_mut(), &ctx, 256, 3);
        assert!(counters.sampled_edges.iter().sum::<u64>() > 0, "{}", e.name());
        assert!(time.total() > 0.0, "{}", e.name());
        loads.push((e.name(), counters.total_load_bytes()));
        let t = iter_time(&counters, &ctx.topo);
        assert!(t.total().is_finite());
    }
    let gsplit_load = loads.iter().find(|(n, _)| *n == "GSplit").unwrap().1;
    for (name, l) in &loads {
        if *name != "GSplit" && *name != "P3*" {
            assert!(
                gsplit_load <= *l,
                "GSplit must load least: gsplit={gsplit_load} {name}={l}"
            );
        }
    }
}

#[test]
fn presample_weighted_partition_beats_edge_on_expected_cut() {
    // The §7.3 story in miniature: GSplit's pre-sampled weights reduce the
    // expected (weight-weighted) cut vs the unweighted Edge partitioner.
    let ds = StandIn::Tiny.load().unwrap();
    let pw = presample(
        &ds.graph,
        &ds.labels.train_set,
        &PresampleConfig { epochs: 3, batch_size: 256, fanouts: vec![5, 5], seed: 9 },
    );
    let mask = vec![false; ds.graph.num_vertices()];
    let gp = partition_graph(&ds.graph, &pw, &mask, Strategy::GSplit, 4, 0.1, 4);
    let rp = partition_graph(&ds.graph, &pw, &mask, Strategy::Rand, 4, 0.1, 4);
    let gq = gsplit::partition::evaluate_partitioning(&ds.graph, &pw, &gp);
    let rq = gsplit::partition::evaluate_partitioning(&ds.graph, &pw, &rp);
    // Robust invariants of the weighted partitioner (the fine-grained
    // GSplit-vs-Edge cut comparison is statistical and lives at real scale
    // in the fig5_splitting bench, where GSplit < Node < Edge ≪ Rand):
    // the expected cut must be far below random assignment, and the
    // expected-load balance must respect the (1+ε) constraint band.
    assert!(
        (gq.expected_cut as f64) < 0.3 * rq.expected_cut as f64,
        "gsplit expected cut {} should be far below random {}",
        gq.expected_cut,
        rq.expected_cut
    );
    assert!(gq.imbalance < 1.3, "imbalance {}", gq.imbalance);
}
