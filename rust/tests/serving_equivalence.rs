//! Serving bit-identity (DESIGN.md §Serving): logits answered by the
//! online service must be **bit-identical** to an offline
//! [`Trainer::infer`] on the same vertices and seed — no matter how
//! requests fall into micro-batches, which cache policy/budget backs the
//! loading stage, how many pipeline workers run the forward, or whether
//! features live in RAM or stream from a v2 `.gsg` on disk.
//!
//! The mechanism under test is per-vertex stateless sampling: each
//! frontier vertex draws from its own stream keyed on
//! `(seed, layer, vertex)`, so its sampled neighborhood — and therefore
//! its logits — cannot depend on which other vertices shared its
//! micro-batch. Request counts are chosen to straddle the flush boundary
//! (1, exactly `max_batch`, `max_batch + 1`).
//!
//! Also pinned here: serving a **label-free** dataset (inference must
//! never touch `ds.labels` — the regression behind `Trainer::infer`),
//! shutdown drain (every admitted request is answered), and zero
//! `max_wait` degrading to per-request batches without deadlock.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use gsplit::cache::{CachePolicy, ResidentCache};
use gsplit::devices::Topology;
use gsplit::graph::{Dataset, DiskFeatureStore, StandIn};
use gsplit::model::{GnnKind, ModelConfig};
use gsplit::partition::Partitioning;
use gsplit::runtime::NativeBackend;
use gsplit::serving::{self, ServeConfig};
use gsplit::train::{TrainConfig, Trainer};
use gsplit::{DeviceId, Vid};

const FANOUT: usize = 5;
const SEED: u64 = 42;
/// The sampling seed every serve run and every offline oracle pins to.
const SERVE_SEED: u64 = 0xA11CE;
const K: usize = 4;
const MAX_BATCH: usize = 8;

fn tiny_cfg(num_layers: usize) -> ModelConfig {
    ModelConfig { kind: GnnKind::GraphSage, feat_dim: 32, hidden: 32, num_classes: 16, num_layers }
}

fn modulo_part(ds: &Dataset, k: usize) -> Partitioning {
    Partitioning {
        assignment: (0..ds.graph.num_vertices() as Vid)
            .map(|v| (v % k as Vid) as DeviceId)
            .collect(),
        k,
    }
}

fn degree_ranking(ds: &Dataset) -> Vec<u64> {
    (0..ds.graph.num_vertices() as Vid).map(|v| ds.graph.degree(v) as u64).collect()
}

/// A trainer for one serving configuration. All trainers share `SEED`, so
/// their freshly initialized parameters are bit-identical — serving never
/// updates them, which keeps every config comparable to the oracle.
fn make_trainer<'b>(
    backend: &'b NativeBackend,
    cfg: &ModelConfig,
    ds: &Dataset,
    workers: usize,
    policy: CachePolicy,
    budget: u64,
) -> Trainer<'b> {
    let part = modulo_part(ds, K);
    let t = Trainer::new(backend, cfg, FANOUT, part.clone(), 0.2, SEED).unwrap();
    let cache = (policy != CachePolicy::None).then(|| {
        let topo = Topology::for_gpus(K, 1.0).unwrap();
        Arc::new(ResidentCache::build(
            policy,
            &degree_ranking(ds),
            budget,
            &part,
            &topo,
            &ds.features,
        ))
    });
    t.with_config(TrainConfig::new().parallel_workers(workers).cache(cache)).unwrap()
}

/// Submit `vids` through the online service and return each response's
/// logits, in submit order.
fn serve(
    trainer: &mut Trainer<'_>,
    ds: &Dataset,
    vids: &[Vid],
    max_batch: usize,
    max_wait: Duration,
) -> Vec<Vec<f32>> {
    let cfg = ServeConfig { max_batch, max_wait, queue_cap: 1024, seed: SERVE_SEED };
    let (rows, report) = serving::run(trainer, ds, cfg, |client| {
        let pending: Vec<_> =
            vids.iter().map(|&v| client.submit(v).expect("admitted")).collect();
        pending
            .into_iter()
            .map(|p| {
                let r = p.wait().expect("answered");
                r.logits
            })
            .collect::<Vec<Vec<f32>>>()
    })
    .unwrap();
    assert_eq!(report.served, vids.len() as u64, "every admitted request is answered");
    rows
}

/// Deterministic distinct request vertices spread over the graph.
fn targets(ds: &Dataset, r: usize) -> Vec<Vid> {
    let n = ds.graph.num_vertices() as Vid;
    let stride = n / r as Vid;
    (0..r as Vid).map(|i| (i * stride.max(97) + 13) % n).collect()
}

fn assert_rows_bit_match(served: &[Vec<f32>], offline: &[f32], c: usize, what: &str) {
    assert_eq!(served.len() * c, offline.len(), "{what}: row count");
    for (i, row) in served.iter().enumerate() {
        assert_eq!(row.len(), c, "{what}: row {i} width");
        for (j, x) in row.iter().enumerate() {
            let y = offline[i * c + j];
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{what}: request {i} class {j}: served {x} != offline {y}"
            );
        }
    }
}

/// The tentpole sweep: request counts straddling the micro-batch boundary
/// × cache policies × budgets × worker counts, each bit-compared to one
/// uncached serial offline oracle.
#[test]
fn served_logits_bit_match_offline_across_configs() {
    let ds = StandIn::Tiny.load().unwrap();
    let cfg = tiny_cfg(2);
    let backend = NativeBackend::new();
    let mut oracle = make_trainer(&backend, &cfg, &ds, 0, CachePolicy::None, 0);
    for r in [1usize, MAX_BATCH, MAX_BATCH + 1] {
        let vids = targets(&ds, r);
        let offline = oracle.infer(&ds, &vids, SERVE_SEED).unwrap();
        for policy in [CachePolicy::None, CachePolicy::Distributed, CachePolicy::Partitioned] {
            for budget in [64u64, 1024] {
                // An absent cache has no budget axis — sweep it once.
                if policy == CachePolicy::None && budget != 64 {
                    continue;
                }
                for workers in [0usize, 1, 2, 4] {
                    let what = format!("r={r}/{}/b{budget}/w{workers}", policy.name());
                    let mut t = make_trainer(&backend, &cfg, &ds, workers, policy, budget);
                    let served =
                        serve(&mut t, &ds, &vids, MAX_BATCH, Duration::from_millis(2));
                    assert_rows_bit_match(&served, &offline, cfg.num_classes, &what);
                }
            }
        }
    }
}

/// Repeat requests for the same vertex are answered identically no matter
/// which micro-batch they land in, and the service dedupes them into one
/// inference row per unique vertex.
#[test]
fn duplicate_requests_get_identical_answers() {
    let ds = StandIn::Tiny.load().unwrap();
    let cfg = tiny_cfg(2);
    let backend = NativeBackend::new();
    let v: Vid = 7;
    let vids = vec![v; MAX_BATCH + 3]; // spans two micro-batches
    let mut t = make_trainer(&backend, &cfg, &ds, 2, CachePolicy::None, 0);
    let served = serve(&mut t, &ds, &vids, MAX_BATCH, Duration::from_millis(2));
    let mut oracle = make_trainer(&backend, &cfg, &ds, 0, CachePolicy::None, 0);
    let offline = oracle.infer(&ds, &[v], SERVE_SEED).unwrap();
    for (i, row) in served.iter().enumerate() {
        assert_rows_bit_match(&[row.clone()], &offline, cfg.num_classes, &format!("dup {i}"));
    }
}

static COUNTER: AtomicUsize = AtomicUsize::new(0);

fn unique_gsg() -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "gsplit_serving_eq_{}_{}",
        std::process::id(),
        COUNTER.fetch_add(1, Ordering::Relaxed)
    ));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join("tiny.gsg")
}

/// RAM vs disk: the same vertices served from a chunk-buffered
/// [`DiskFeatureStore`] answer bit-identically to the in-RAM reference
/// the file was written from.
#[test]
fn served_logits_bit_match_between_ram_and_disk_features() {
    let ram = StandIn::Tiny.load().unwrap();
    let path = unique_gsg();
    ram.write_gsg(&path).unwrap();
    let mut disk = Dataset::open_ooc(&path, ram.spec.train_frac, ram.spec.seed ^ 0x5717).unwrap();
    disk.spec = ram.spec.clone();
    disk.features = Arc::new(DiskFeatureStore::open(&path).unwrap().with_buffer(64, 4));

    let cfg = tiny_cfg(2);
    let backend = NativeBackend::new();
    let vids = targets(&ram, MAX_BATCH + 1);
    let mut oracle = make_trainer(&backend, &cfg, &ram, 0, CachePolicy::None, 0);
    let offline = oracle.infer(&ram, &vids, SERVE_SEED).unwrap();
    for workers in [0usize, 2] {
        for policy in [CachePolicy::None, CachePolicy::Partitioned] {
            let what = format!("disk/{}/w{workers}", policy.name());
            let mut t = make_trainer(&backend, &cfg, &disk, workers, policy, 256);
            let served = serve(&mut t, &disk, &vids, MAX_BATCH, Duration::from_millis(2));
            assert_rows_bit_match(&served, &offline, cfg.num_classes, &what);
        }
    }
    let _ = std::fs::remove_file(&path);
}

/// Regression: inference must never touch `ds.labels`. A dataset with its
/// labels stripped (as a pure serving replica would hold) serves the same
/// bits as the labeled original.
#[test]
fn label_free_dataset_serves_identically() {
    let ds = StandIn::Tiny.load().unwrap();
    let cfg = tiny_cfg(2);
    let backend = NativeBackend::new();
    let vids = targets(&ds, MAX_BATCH + 1);
    let mut oracle = make_trainer(&backend, &cfg, &ds, 0, CachePolicy::None, 0);
    let offline = oracle.infer(&ds, &vids, SERVE_SEED).unwrap();

    let mut stripped = ds;
    stripped.labels.labels = Vec::new();
    stripped.labels.train_set = Vec::new();
    stripped.labels.val_set = Vec::new();

    // Offline label-free inference, serial and pipelined.
    let mut t = make_trainer(&backend, &cfg, &stripped, 0, CachePolicy::None, 0);
    let bare = t.infer(&stripped, &vids, SERVE_SEED).unwrap();
    assert_eq!(offline.len(), bare.len());
    for (i, (x, y)) in offline.iter().zip(&bare).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "label-free offline elem {i}");
    }
    // And through the full service.
    let mut t2 = make_trainer(&backend, &cfg, &stripped, 2, CachePolicy::None, 0);
    let served = serve(&mut t2, &stripped, &vids, MAX_BATCH, Duration::from_millis(2));
    assert_rows_bit_match(&served, &offline, cfg.num_classes, "label-free served");
}

/// Shutdown drain: requests submitted and *not yet awaited* when the
/// client drops are still answered — the loop drains the queue before
/// exiting instead of dropping in-flight work.
#[test]
fn shutdown_drains_in_flight_requests() {
    let ds = StandIn::Tiny.load().unwrap();
    let cfg = tiny_cfg(2);
    let backend = NativeBackend::new();
    let vids = targets(&ds, 5);
    let mut t = make_trainer(&backend, &cfg, &ds, 0, CachePolicy::None, 0);
    let serve_cfg = ServeConfig {
        // A batch that can never fill and an hour-long wait: only the
        // shutdown drain can flush these requests.
        max_batch: 1000,
        max_wait: Duration::from_secs(3600),
        queue_cap: 16,
        seed: SERVE_SEED,
    };
    let (pending, report) = serving::run(&mut t, &ds, serve_cfg, |client| {
        vids.iter().map(|&v| client.submit(v).expect("admitted")).collect::<Vec<_>>()
    })
    .unwrap();
    assert_eq!(report.served, vids.len() as u64, "drain must answer every admitted request");
    assert_eq!(report.batches, 1, "drain flushes the pending batch once");
    for (i, p) in pending.into_iter().enumerate() {
        let r = p.wait().unwrap_or_else(|e| panic!("request {i} dropped on shutdown: {e}"));
        assert_eq!(r.vid, vids[i]);
        assert_eq!(r.logits.len(), cfg.num_classes);
    }
}

/// `max_wait == 0` degrades to one micro-batch per request — and the loop
/// must not deadlock waiting for a batch that can never age.
#[test]
fn zero_wait_serves_per_request_batches_without_deadlock() {
    let ds = StandIn::Tiny.load().unwrap();
    let cfg = tiny_cfg(2);
    let backend = NativeBackend::new();
    let vids = targets(&ds, 6);
    let mut t = make_trainer(&backend, &cfg, &ds, 0, CachePolicy::None, 0);
    let serve_cfg =
        ServeConfig { max_batch: 64, max_wait: Duration::ZERO, queue_cap: 16, seed: SERVE_SEED };
    let (rows, report) = serving::run(&mut t, &ds, serve_cfg, |client| {
        // Closed loop: each wait completes before the next submit, so
        // every request reaches the loop alone and batches stay size 1.
        vids.iter()
            .map(|&v| client.submit(v).expect("admitted").wait().expect("answered").logits)
            .collect::<Vec<_>>()
    })
    .unwrap();
    assert_eq!(report.served, vids.len() as u64);
    assert_eq!(
        report.batches,
        vids.len() as u64,
        "zero max_wait must flush one batch per request"
    );
    let mut oracle = make_trainer(&backend, &cfg, &ds, 0, CachePolicy::None, 0);
    let offline = oracle.infer(&ds, &vids, SERVE_SEED).unwrap();
    assert_rows_bit_match(&rows, &offline, cfg.num_classes, "zero-wait");
}
