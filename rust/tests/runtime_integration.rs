//! Integration tests over the PJRT runtime: load real artifacts, execute
//! them, and verify numerics against the golden values `aot.py` computed
//! in JAX — this pins the whole L1→L2→HLO→PJRT→Rust chain.
//!
//! Requires `make artifacts` (skipped with a message otherwise, so plain
//! `cargo test` without artifacts still passes the pure-Rust suite).

use gsplit::model::{GnnKind, LayerParams, ModelConfig, ParamStore};
use gsplit::runtime::Runtime;
use gsplit::sampling::NO_NEIGHBOR;
use gsplit::util::JsonValue;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        None
    }
}

/// The deterministic "ramp" pattern aot.py uses for goldens:
/// v(i) = ((i*37 + 11) % 97)/97 * scale - scale/2.
fn ramp(len: usize, scale: f32) -> Vec<f32> {
    (0..len).map(|i| ((i * 37 + 11) % 97) as f32 / 97.0 * scale - scale / 2.0).collect()
}

fn golden() -> Option<JsonValue> {
    let dir = artifacts_dir()?;
    let text = std::fs::read_to_string(dir.join("golden.json")).ok()?;
    Some(JsonValue::parse(&text).unwrap())
}

#[test]
fn layer_fwd_matches_jax_golden() {
    let (Some(dir), Some(g)) = (artifacts_dir(), golden()) else { return };
    let rt = Runtime::load(&dir).unwrap();
    let k = rt.manifest.kernel_fanout;
    let (din, dout) = (rt.manifest.feat_dim, rt.manifest.hidden);
    let m_real = g.get("layer").unwrap().get("m_real").unwrap().as_usize().unwrap();

    // Rebuild the exact inputs aot.write_goldens used.
    let n_real = m_real * (k + 1);
    let x = ramp(n_real * din, 2.0);
    let mut neigh = vec![NO_NEIGHBOR; m_real * k];
    for i in 0..m_real {
        for j in 0..k {
            if (i + j) % 4 != 3 {
                neigh[i * k + j] = (m_real + i * k + j) as u32;
            }
        }
    }
    // Param tensors: ramp(0.5) in aot order (w_self, w_neigh, bias).
    let params = LayerParams {
        tensors: vec![ramp(din * dout, 0.5), ramp(din * dout, 0.5), ramp(dout, 0.5)],
        shapes: vec![(din, dout), (din, dout), (1, dout)],
    };
    let out = rt
        .layer_fwd(GnnKind::GraphSage, din, dout, true, &x, n_real, &neigh, m_real, k, &params)
        .unwrap();
    let want: Vec<f64> = g
        .get("layer")
        .unwrap()
        .get("out_rows")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap())
        .collect();
    assert_eq!(out.len(), m_real * dout);
    for (i, (a, b)) in out.iter().zip(&want).enumerate() {
        assert!(
            (*a as f64 - b).abs() < 1e-4 * (1.0 + b.abs()),
            "row value {i}: rust={a} jax={b}"
        );
    }
}

#[test]
fn loss_matches_jax_golden() {
    let (Some(dir), Some(g)) = (artifacts_dir(), golden()) else { return };
    let rt = Runtime::load(&dir).unwrap();
    let c = rt.manifest.num_classes;
    let b = 256usize;
    let logits = ramp(b * c, 4.0);
    let labels: Vec<i32> = (0..b).map(|i| ((i * 7 + 3) % c) as i32).collect();
    // golden used valid = first 16 rows; emulate by passing b_real = 16.
    let b_real = 16;
    let (out, g_logits) = rt.loss(&logits[..b_real * c], &labels[..b_real], b_real, c).unwrap();
    let gl = g.get("loss").unwrap();
    let want_loss = gl.get("loss").unwrap().as_f64().unwrap();
    let want_correct = gl.get("correct").unwrap().as_f64().unwrap();
    assert!((out.loss as f64 - want_loss).abs() < 1e-4, "{} vs {want_loss}", out.loss);
    assert!((out.correct as f64 - want_correct).abs() < 1e-6);
    let want_g: Vec<f64> = gl
        .get("g_logits_head")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap())
        .collect();
    for (a, b) in g_logits[..want_g.len()].iter().zip(&want_g) {
        assert!((*a as f64 - b).abs() < 1e-5, "g_logits {a} vs {b}");
    }
}

#[test]
fn bwd_grads_flow_and_match_finite_difference() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let k = rt.manifest.kernel_fanout;
    let (din, dout) = (rt.manifest.feat_dim, rt.manifest.hidden);
    let cfg = ModelConfig {
        kind: GnnKind::GraphSage,
        feat_dim: din,
        hidden: dout,
        num_classes: 8,
        num_layers: 2,
    };
    let store = ParamStore::init(&cfg, 7);
    let params = &store.layers[0];
    let m_real = 4usize;
    let n_real = m_real * (k + 1);
    let x = ramp(n_real * din, 1.0);
    let mut neigh = vec![NO_NEIGHBOR; m_real * k];
    for i in 0..m_real {
        for j in 0..k.min(3) {
            neigh[i * k + j] = (m_real + i * k + j) as u32;
        }
    }
    // Scalar objective: sum of outputs. g_out = ones.
    let g_out = vec![1f32; m_real * dout];
    let grads = rt
        .layer_bwd(
            GnnKind::GraphSage,
            din,
            dout,
            true,
            &x,
            n_real,
            &neigh,
            m_real,
            k,
            &g_out,
            params,
        )
        .unwrap();
    assert_eq!(grads.g_x.len(), n_real * din);
    assert_eq!(grads.g_params.len(), 3);

    // Finite-difference check on one input coordinate that feeds a real
    // neighbor slot (row m_real = first neighbor of dst 0).
    let probe = m_real * din + 3;
    let f = |x: &[f32]| -> f32 {
        rt.layer_fwd(GnnKind::GraphSage, din, dout, true, x, n_real, &neigh, m_real, k, params)
            .unwrap()
            .iter()
            .sum()
    };
    let eps = 1e-2f32;
    let mut xp = x.clone();
    xp[probe] += eps;
    let mut xm = x.clone();
    xm[probe] -= eps;
    let fd = (f(&xp) - f(&xm)) / (2.0 * eps);
    let an = grads.g_x[probe];
    assert!(
        (fd - an).abs() < 2e-2 * (1.0 + fd.abs()),
        "finite-diff {fd} vs analytic {an}"
    );
}

#[test]
fn bucket_selection_handles_sizes() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let k = rt.manifest.kernel_fanout;
    let (din, dout) = (rt.manifest.feat_dim, rt.manifest.hidden);
    let cfg = ModelConfig {
        kind: GnnKind::GraphSage,
        feat_dim: din,
        hidden: dout,
        num_classes: 8,
        num_layers: 2,
    };
    let store = ParamStore::init(&cfg, 9);
    // m_real = 300 forces the 1024 bucket.
    let m_real = 300usize;
    let n_real = m_real; // no neighbors at all: isolated rows
    let x = ramp(n_real * din, 1.0);
    let neigh = vec![NO_NEIGHBOR; m_real * k];
    let out = rt
        .layer_fwd(
            GnnKind::GraphSage,
            din,
            dout,
            true,
            &x,
            n_real,
            &neigh,
            m_real,
            k,
            &store.layers[0],
        )
        .unwrap();
    assert_eq!(out.len(), m_real * dout);
    // Isolated rows: agg = 0, so out = relu(x_self @ w_self + bias); just
    // check a known-zero case: zero input row → relu(bias).
    // (x row 0 is not zero, so instead verify determinism.)
    let out2 = rt
        .layer_fwd(
            GnnKind::GraphSage,
            din,
            dout,
            true,
            &x,
            n_real,
            &neigh,
            m_real,
            k,
            &store.layers[0],
        )
        .unwrap();
    assert_eq!(out, out2);
}

#[test]
fn gat_artifacts_execute() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let k = rt.manifest.kernel_fanout;
    let (din, dout) = (rt.manifest.feat_dim, rt.manifest.hidden);
    let cfg = ModelConfig {
        kind: GnnKind::Gat,
        feat_dim: din,
        hidden: dout,
        num_classes: 8,
        num_layers: 2,
    };
    let store = ParamStore::init(&cfg, 11);
    let m_real = 8usize;
    let n_real = m_real * 2;
    let x = ramp(n_real * din, 1.0);
    let mut neigh = vec![NO_NEIGHBOR; m_real * k];
    for i in 0..m_real {
        neigh[i * k] = (m_real + i) as u32;
    }
    let out = rt
        .layer_fwd(GnnKind::Gat, din, dout, true, &x, n_real, &neigh, m_real, k, &store.layers[0])
        .unwrap();
    assert_eq!(out.len(), m_real * dout);
    assert!(out.iter().all(|v| v.is_finite() && *v >= 0.0));
    let g_out = vec![0.5f32; m_real * dout];
    let grads = rt
        .layer_bwd(
            GnnKind::Gat,
            din,
            dout,
            true,
            &x,
            n_real,
            &neigh,
            m_real,
            k,
            &g_out,
            &store.layers[0],
        )
        .unwrap();
    assert_eq!(grads.g_params.len(), 4);
    assert!(grads.g_x.iter().any(|v| *v != 0.0), "gradient should flow to inputs");
}
