//! Integration tests over the `Backend` trait with the default
//! `NativeBackend`: golden-value checks against fixtures computed with the
//! JAX references in `python/compile/kernels/ref.py`, gradient flow, and a
//! train-loop smoke test on the Tiny stand-in dataset.
//!
//! Unlike the old PJRT-only suite, nothing here needs `make artifacts` —
//! the whole file runs on a fresh clone. (PJRT-specific golden tests
//! against AOT executables live behind `--features pjrt` and still skip
//! politely when artifacts are absent.)

use gsplit::graph::StandIn;
use gsplit::model::{GnnKind, LayerParams, ModelConfig, ParamStore};
use gsplit::partition::{partition_graph, Strategy};
use gsplit::presample::PresampleWeights;
use gsplit::runtime::{Backend, NativeBackend};
use gsplit::sampling::NO_NEIGHBOR;
use gsplit::train::Trainer;

/// The deterministic "ramp" pattern the AOT golden generator uses:
/// v(i) = ((i*37 + 11) % 97)/97 * scale - scale/2.
fn ramp(len: usize, scale: f32) -> Vec<f32> {
    (0..len).map(|i| ((i * 37 + 11) % 97) as f32 / 97.0 * scale - scale / 2.0).collect()
}

fn backend() -> NativeBackend {
    NativeBackend::new()
}

#[test]
fn layer_fwd_through_trait_object() {
    // Exercise the trait-object path the trainer uses (&dyn Backend).
    let be = backend();
    let b: &dyn Backend = &be;
    assert_eq!(b.name(), "native");
    let x = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
    let eye = vec![1.0, 0.0, 0.0, 1.0];
    let params = LayerParams {
        tensors: vec![eye.clone(), eye, vec![0.5, -0.5]],
        shapes: vec![(2, 2), (2, 2), (1, 2)],
    };
    let out = b
        .layer_fwd(GnnKind::GraphSage, 2, 2, false, &x, 3, &[1, 2], 1, 2, &params)
        .unwrap();
    // Golden: x_self + mean(rows 1,2) + bias = [5.5, 6.5] (ref.py).
    assert!((out[0] - 5.5).abs() < 1e-6 && (out[1] - 6.5).abs() < 1e-6, "{out:?}");
}

#[test]
fn loss_golden_and_gradient_direction() {
    let b = backend();
    // Fixture cross-checked against model.loss_head in JAX: see
    // runtime/native.rs for the derivation.
    let (out, g) = b.loss(&[0.0, 0.0, 2.0, 0.0], &[0, 1], 2, 2).unwrap();
    assert!((out.loss - 1.410038).abs() < 1e-5);
    assert_eq!(out.correct, 1.0);
    // Gradient pushes the true-label logit up (negative gradient entry).
    assert!(g[0] < 0.0 && g[3] < 0.0);
    assert!((g.iter().sum::<f32>()).abs() < 1e-6, "CE logit gradient sums to zero");
}

#[test]
fn bwd_grads_flow_and_match_finite_difference() {
    let rt = backend();
    let k = 5usize;
    let (din, dout) = (16, 8);
    let cfg = ModelConfig {
        kind: GnnKind::GraphSage,
        feat_dim: din,
        hidden: dout,
        num_classes: 8,
        num_layers: 2,
    };
    let store = ParamStore::init(&cfg, 7);
    let params = &store.layers[0];
    let m_real = 4usize;
    let n_real = m_real * (k + 1);
    let x = ramp(n_real * din, 1.0);
    let mut neigh = vec![NO_NEIGHBOR; m_real * k];
    for i in 0..m_real {
        for j in 0..k.min(3) {
            neigh[i * k + j] = (m_real + i * k + j) as u32;
        }
    }
    // Scalar objective: sum of outputs. g_out = ones.
    let g_out = vec![1f32; m_real * dout];
    let grads = rt
        .layer_bwd(
            GnnKind::GraphSage,
            din,
            dout,
            true,
            &x,
            n_real,
            &neigh,
            m_real,
            k,
            &g_out,
            params,
        )
        .unwrap();
    assert_eq!(grads.g_x.len(), n_real * din);
    assert_eq!(grads.g_params.len(), 3);

    // Finite-difference check on one input coordinate that feeds a real
    // neighbor slot (row m_real = first neighbor of dst 0).
    let probe = m_real * din + 3;
    let f = |x: &[f32]| -> f32 {
        rt.layer_fwd(GnnKind::GraphSage, din, dout, true, x, n_real, &neigh, m_real, k, params)
            .unwrap()
            .iter()
            .sum()
    };
    let eps = 1e-2f32;
    let mut xp = x.clone();
    xp[probe] += eps;
    let mut xm = x.clone();
    xm[probe] -= eps;
    let fd = (f(&xp) - f(&xm)) / (2.0 * eps);
    let an = grads.g_x[probe];
    assert!(
        (fd - an).abs() < 2e-2 * (1.0 + fd.abs()),
        "finite-diff {fd} vs analytic {an}"
    );
}

#[test]
fn gat_executes_and_gradients_flow() {
    let rt = backend();
    let k = 5usize;
    let (din, dout) = (16, 8);
    let cfg = ModelConfig {
        kind: GnnKind::Gat,
        feat_dim: din,
        hidden: dout,
        num_classes: 8,
        num_layers: 2,
    };
    let store = ParamStore::init(&cfg, 11);
    let m_real = 8usize;
    let n_real = m_real * 2;
    let x = ramp(n_real * din, 1.0);
    let mut neigh = vec![NO_NEIGHBOR; m_real * k];
    for i in 0..m_real {
        neigh[i * k] = (m_real + i) as u32;
    }
    let out = rt
        .layer_fwd(GnnKind::Gat, din, dout, true, &x, n_real, &neigh, m_real, k, &store.layers[0])
        .unwrap();
    assert_eq!(out.len(), m_real * dout);
    assert!(out.iter().all(|v| v.is_finite() && *v >= 0.0));
    let g_out = vec![0.5f32; m_real * dout];
    let grads = rt
        .layer_bwd(
            GnnKind::Gat,
            din,
            dout,
            true,
            &x,
            n_real,
            &neigh,
            m_real,
            k,
            &g_out,
            &store.layers[0],
        )
        .unwrap();
    assert_eq!(grads.g_params.len(), 4);
    assert!(grads.g_x.iter().any(|v| *v != 0.0), "gradient should flow to inputs");
}

#[test]
fn large_batch_and_isolated_rows_execute() {
    // The PJRT runtime buckets sizes; the native backend must handle any
    // shape directly — including destinations with no neighbors at all.
    let rt = backend();
    let k = 5usize;
    let (din, dout) = (16, 8);
    let cfg = ModelConfig {
        kind: GnnKind::GraphSage,
        feat_dim: din,
        hidden: dout,
        num_classes: 8,
        num_layers: 2,
    };
    let store = ParamStore::init(&cfg, 9);
    let m_real = 300usize;
    let n_real = m_real; // no neighbors at all: isolated rows
    let x = ramp(n_real * din, 1.0);
    let neigh = vec![NO_NEIGHBOR; m_real * k];
    let out = rt
        .layer_fwd(
            GnnKind::GraphSage,
            din,
            dout,
            true,
            &x,
            n_real,
            &neigh,
            m_real,
            k,
            &store.layers[0],
        )
        .unwrap();
    assert_eq!(out.len(), m_real * dout);
    let out2 = rt
        .layer_fwd(
            GnnKind::GraphSage,
            din,
            dout,
            true,
            &x,
            n_real,
            &neigh,
            m_real,
            k,
            &store.layers[0],
        )
        .unwrap();
    assert_eq!(out, out2, "deterministic across calls");
}

/// Train-loop smoke test: five SGD iterations on a fixed mini-batch of the
/// Tiny stand-in must reduce the loss (memorization direction).
#[test]
fn train_loop_smoke_loss_decreases_on_tiny() {
    let ds = StandIn::Tiny.load().unwrap();
    let be = backend();
    let cfg = ModelConfig {
        kind: GnnKind::GraphSage,
        feat_dim: ds.spec.feat_dim,
        hidden: 32,
        num_classes: ds.labels.num_classes,
        num_layers: 3,
    };
    let w = PresampleWeights::uniform(&ds.graph);
    let mask = vec![false; ds.graph.num_vertices()];
    let part = partition_graph(&ds.graph, &w, &mask, Strategy::Edge, 4, 0.1, 5);
    let mut trainer = Trainer::new(&be, &cfg, 5, part, 0.1, 13).unwrap();
    let batch: Vec<_> = ds.labels.train_set[..64].to_vec();
    let mut losses = Vec::new();
    for step in 0..5u64 {
        // Same batch, same sampling seed: pure optimization progress.
        let s = trainer.train_iteration(&ds, &batch, 0).unwrap();
        assert!(s.loss.is_finite(), "step {step}: loss must stay finite");
        losses.push(s.loss);
    }
    assert!(
        losses[4] < losses[0],
        "loss should decrease over 5 iterations on a fixed batch: {losses:?}"
    );
}

// ---------------------------------------------------------------------------
// PJRT golden tests (feature-gated; skip politely without artifacts)
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
mod pjrt_golden {
    use super::*;
    use gsplit::runtime::Runtime;
    use gsplit::util::JsonValue;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            Some(dir)
        } else {
            eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
            None
        }
    }

    fn golden() -> Option<JsonValue> {
        let dir = artifacts_dir()?;
        let text = std::fs::read_to_string(dir.join("golden.json")).ok()?;
        Some(JsonValue::parse(&text).unwrap())
    }

    #[test]
    fn layer_fwd_matches_jax_golden() {
        let (Some(dir), Some(g)) = (artifacts_dir(), golden()) else { return };
        let rt = match Runtime::load(&dir) {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("SKIP: PJRT unavailable ({e})");
                return;
            }
        };
        let k = rt.manifest.kernel_fanout;
        let (din, dout) = (rt.manifest.feat_dim, rt.manifest.hidden);
        let m_real = g.get("layer").unwrap().get("m_real").unwrap().as_usize().unwrap();

        // Rebuild the exact inputs aot.write_goldens used.
        let n_real = m_real * (k + 1);
        let x = ramp(n_real * din, 2.0);
        let mut neigh = vec![NO_NEIGHBOR; m_real * k];
        for i in 0..m_real {
            for j in 0..k {
                if (i + j) % 4 != 3 {
                    neigh[i * k + j] = (m_real + i * k + j) as u32;
                }
            }
        }
        // Param tensors: ramp(0.5) in aot order (w_self, w_neigh, bias).
        let params = LayerParams {
            tensors: vec![ramp(din * dout, 0.5), ramp(din * dout, 0.5), ramp(dout, 0.5)],
            shapes: vec![(din, dout), (din, dout), (1, dout)],
        };
        let out = rt
            .layer_fwd(GnnKind::GraphSage, din, dout, true, &x, n_real, &neigh, m_real, k, &params)
            .unwrap();
        let want: Vec<f64> = g
            .get("layer")
            .unwrap()
            .get("out_rows")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect();
        assert_eq!(out.len(), m_real * dout);
        for (i, (a, b)) in out.iter().zip(&want).enumerate() {
            assert!(
                (*a as f64 - b).abs() < 1e-4 * (1.0 + b.abs()),
                "row value {i}: rust={a} jax={b}"
            );
        }
    }

    #[test]
    fn loss_matches_jax_golden() {
        let (Some(dir), Some(g)) = (artifacts_dir(), golden()) else { return };
        let rt = match Runtime::load(&dir) {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("SKIP: PJRT unavailable ({e})");
                return;
            }
        };
        let c = rt.manifest.num_classes;
        let b = 256usize;
        let logits = ramp(b * c, 4.0);
        let labels: Vec<i32> = (0..b).map(|i| ((i * 7 + 3) % c) as i32).collect();
        // golden used valid = first 16 rows; emulate by passing b_real = 16.
        let b_real = 16;
        let (out, g_logits) =
            rt.loss(&logits[..b_real * c], &labels[..b_real], b_real, c).unwrap();
        let gl = g.get("loss").unwrap();
        let want_loss = gl.get("loss").unwrap().as_f64().unwrap();
        let want_correct = gl.get("correct").unwrap().as_f64().unwrap();
        assert!((out.loss as f64 - want_loss).abs() < 1e-4, "{} vs {want_loss}", out.loss);
        assert!((out.correct as f64 - want_correct).abs() < 1e-6);
        let want_g: Vec<f64> = gl
            .get("g_logits_head")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect();
        for (a, b) in g_logits[..want_g.len()].iter().zip(&want_g) {
            assert!((*a as f64 - b).abs() < 1e-5, "g_logits {a} vs {b}");
        }
    }
}
