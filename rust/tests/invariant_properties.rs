//! Randomized property tests over the coordinator's core invariants
//! (proptest-lite: seeded random cases via `gsplit::testing`).

use gsplit::graph::{rmat, GenParams};
use gsplit::partition::{partition_graph, Partitioning, Strategy};
use gsplit::presample::PresampleWeights;
use gsplit::rng::Pcg32;
use gsplit::sampling::Sampler;
use gsplit::split::SplitSampler;
use gsplit::testing::for_all_seeds;
use gsplit::Vid;

fn random_graph(rng: &mut Pcg32) -> gsplit::graph::CsrGraph {
    let n = 200 + rng.gen_range(2000) as usize;
    let m = n * (2 + rng.gen_range(6) as usize);
    rmat(&GenParams { num_vertices: n, num_edges: m, seed: rng.next_u64() })
}

fn random_targets(rng: &mut Pcg32, n: usize) -> Vec<Vid> {
    let count = 16 + rng.gen_range(128) as usize;
    let mut seen = std::collections::BTreeSet::new();
    while seen.len() < count.min(n) {
        seen.insert(rng.gen_range(n as u32));
    }
    seen.into_iter().collect()
}

#[test]
fn property_split_plan_preserves_sampled_structure() {
    for_all_seeds("split-plan-structure", 12, |rng, _| {
        let g = random_graph(rng);
        let k = 1 + rng.gen_range(7) as usize;
        let part = Partitioning {
            assignment: (0..g.num_vertices())
                .map(|_| rng.gen_range(k as u32) as u16)
                .collect(),
            k,
        };
        let targets = random_targets(rng, g.num_vertices());
        let fanouts = vec![1 + rng.gen_range(8) as usize; 1 + rng.gen_range(3) as usize];
        let mut ss = SplitSampler::new(k);
        let plan = ss.sample(&g, &targets, &fanouts, &part, rng.next_u64());

        // (1) target cover: top dsts partition the targets
        let mut tops: Vec<Vid> =
            plan.layers[0].per_dev.iter().flat_map(|d| d.dst.iter().copied()).collect();
        tops.sort_unstable();
        let mut want = targets.clone();
        want.sort_unstable();
        assert_eq!(tops, want);

        // (2) inputs are globally disjoint
        let mut inputs: Vec<Vid> =
            plan.input_frontier.iter().flat_map(|f| f.iter().copied()).collect();
        let len = inputs.len();
        inputs.sort_unstable();
        inputs.dedup();
        assert_eq!(len, inputs.len(), "redundant input load");

        // (3) ownership: every dst owned by its device, every mixed vertex
        //     present in its owner's rows below
        for (l, layer) in plan.layers.iter().enumerate() {
            for (d, dl) in layer.per_dev.iter().enumerate() {
                for &v in &dl.dst {
                    assert_eq!(part.device_of(v) as usize, d);
                }
                for &v in &dl.mixed_src {
                    let o = part.device_of(v) as usize;
                    assert!(plan.owned_rows(l, o).contains(&v));
                }
            }
            // (4) shuffle bijection
            for (d, dl) in layer.per_dev.iter().enumerate() {
                let mut filled = vec![false; dl.mixed_src.len()];
                for from in 0..k {
                    for (&s, &r) in layer.shuffle.send[from][d]
                        .iter()
                        .zip(&layer.shuffle.recv[d][from])
                    {
                        assert_eq!(
                            plan.owned_rows(l, from)[s as usize],
                            dl.mixed_src[r as usize]
                        );
                        assert!(!filled[r as usize]);
                        filled[r as usize] = true;
                    }
                }
                assert!(filled.iter().all(|&x| x));
            }
        }
    });
}

#[test]
fn property_split_counts_match_single_device_distribution() {
    // Split-parallel sampling with k devices must produce a mini-batch with
    // the same structure *distribution* as single-device sampling: same
    // per-layer destination counts is too strong (different RNG streams),
    // but the frontier growth bound must hold and edges must be real.
    for_all_seeds("split-counts", 10, |rng, _| {
        let g = random_graph(rng);
        let k = 1 + rng.gen_range(4) as usize;
        let part = Partitioning {
            assignment: (0..g.num_vertices())
                .map(|_| rng.gen_range(k as u32) as u16)
                .collect(),
            k,
        };
        let targets = random_targets(rng, g.num_vertices());
        let fanout = 1 + rng.gen_range(6) as usize;
        let mut ss = SplitSampler::new(k);
        let plan = ss.sample(&g, &targets, &[fanout, fanout], &part, rng.next_u64());
        // Frontier growth bound: layer dst count ≤ previous × (fanout+1).
        let mut prev = targets.len() as u64;
        for layer in &plan.layers {
            let dst: u64 = layer.per_dev.iter().map(|d| d.num_dst() as u64).sum();
            assert!(dst <= prev, "dst layer can't exceed mixed rows above");
            let mixed: u64 = layer.per_dev.iter().map(|d| d.mixed_src.len() as u64).sum();
            assert!(mixed <= prev * (fanout as u64 + 1));
            prev = mixed;
        }
        // Edge reality: spot-check up to 100 edges.
        let mut checked = 0;
        'outer: for layer in &plan.layers {
            for dl in &layer.per_dev {
                for i in 0..dl.num_dst() {
                    for &j in dl.neighbors_of(i) {
                        assert!(g.neighbors(dl.dst[i]).contains(&dl.mixed_src[j as usize]));
                        checked += 1;
                        if checked > 100 {
                            break 'outer;
                        }
                    }
                }
            }
        }
    });
}

#[test]
fn property_partitioners_respect_balance_and_cover() {
    for_all_seeds("partition-balance", 8, |rng, _| {
        let g = random_graph(rng);
        let w = PresampleWeights::uniform(&g);
        let mask = vec![false; g.num_vertices()];
        let k = 2 + rng.gen_range(6) as usize;
        for strat in [Strategy::GSplit, Strategy::Node, Strategy::Edge, Strategy::Rand] {
            let p = partition_graph(&g, &w, &mask, strat, k, 0.1, rng.next_u64());
            assert_eq!(p.assignment.len(), g.num_vertices());
            assert!(p.assignment.iter().all(|&d| (d as usize) < k), "{strat:?}");
            let sizes = p.sizes();
            assert_eq!(sizes.iter().sum::<usize>(), g.num_vertices());
            // Each strategy balances its own load measure: vertex counts
            // for GSplit/Node under uniform weights, degree for Edge.
            match strat {
                Strategy::GSplit | Strategy::Node => {
                    let avg = g.num_vertices() as f64 / k as f64;
                    let max = *sizes.iter().max().unwrap() as f64;
                    assert!(max / avg < 1.6, "{strat:?} sizes {sizes:?}");
                }
                Strategy::Edge => {
                    let mut deg = vec![0u64; k];
                    for v in 0..g.num_vertices() {
                        deg[p.assignment[v] as usize] += g.degree(v as Vid) as u64;
                    }
                    let total: u64 = deg.iter().sum();
                    let avg = total as f64 / k as f64;
                    let max = *deg.iter().max().unwrap() as f64;
                    assert!(max / avg < 1.6, "Edge degree loads {deg:?}");
                }
                Strategy::Rand => {}
            }
        }
    });
}

#[test]
fn property_single_device_sampler_equals_split_with_k1() {
    // With one device the cooperative sampler must produce exactly the
    // classic mini-batch: same frontier sets, same edges.
    for_all_seeds("k1-equivalence", 10, |rng, _| {
        let g = random_graph(rng);
        let targets = random_targets(rng, g.num_vertices());
        let fanouts = vec![1 + rng.gen_range(5) as usize; 2];
        let part = Partitioning { assignment: vec![0; g.num_vertices()], k: 1 };
        let seed = rng.next_u64();
        let mut ss = SplitSampler::new(1);
        let plan = ss.sample(&g, &targets, &fanouts, &part, seed);
        // Single-device Sampler with the derived per-device stream:
        let mut s = Sampler::new();
        let mut drng = Pcg32::new(gsplit::rng::derive_seed(seed, &[0]));
        let mb = s.sample(&g, &targets, &fanouts, &mut drng);
        for (l, layer) in mb.layers.iter().enumerate() {
            let dl = &plan.layers[l].per_dev[0];
            assert_eq!(dl.dst, layer.dst, "layer {l} dst");
            assert_eq!(dl.mixed_src, layer.src, "layer {l} src");
            assert_eq!(dl.neigh, layer.neigh, "layer {l} neigh");
        }
        assert_eq!(plan.input_frontier[0], *mb.input_vertices());
    });
}
