//! Out-of-core bit-identity (DESIGN.md §Loading, disk tier): a dataset
//! served from a v2 `.gsg` file through the chunk-buffered
//! [`DiskFeatureStore`] must train **bit-identically** to the in-RAM
//! reference it was written from — for every cache policy × budget ×
//! worker count, under both executors. Mirrors `cache_equivalence.rs`,
//! with two extra contracts on the byte accounting:
//!
//!  1. the serial and pipelined executors agree on the full four-tier
//!     Local/Peer/Host/Disk split (feature fetches happen on the
//!     coordinator in batch order, so the chunk-buffer evolution is
//!     executor-independent), and
//!  2. the four tiers sum to exactly what the uncached in-RAM oracle
//!     loaded from host memory — out-of-core re-routes bytes, it never
//!     changes how many input rows an iteration materializes.
//!
//! Every disk-backed trainer gets its OWN freshly opened dataset: the
//! Host/Disk split is a pure function of the fetch order *from a cold
//! buffer*, so sharing one store across runs would entangle their states.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use gsplit::cache::{CachePolicy, LoadStats, ResidentCache};
use gsplit::devices::Topology;
use gsplit::graph::{Dataset, DiskFeatureStore, FeatureSource, StandIn};
use gsplit::model::{GnnKind, ModelConfig, ParamStore};
use gsplit::partition::Partitioning;
use gsplit::runtime::NativeBackend;
use gsplit::train::{train_epoch, IterStats, TrainConfig, Trainer};
use gsplit::{DeviceId, Vid};

const FANOUT: usize = 5;
const BATCH: usize = 512;
const SEED: u64 = 42;

static COUNTER: AtomicUsize = AtomicUsize::new(0);

/// A unique `.gsg` path per call so parallel tests never share a file.
fn unique_gsg() -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "gsplit_oocr_eq_{}_{}",
        std::process::id(),
        COUNTER.fetch_add(1, Ordering::Relaxed)
    ));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join("tiny.gsg")
}

/// Materialize the in-RAM Tiny stand-in and write it out as a v2 `.gsg`.
fn write_tiny_gsg() -> (PathBuf, Dataset) {
    let ram = StandIn::Tiny.load().unwrap();
    let path = unique_gsg();
    ram.write_gsg(&path).unwrap();
    (path, ram)
}

/// Open a fresh disk-backed view of the written Tiny dataset. The split
/// seed derivation matches `DatasetSpec::materialize`, so the train/val
/// sets are identical to the in-RAM reference; the spec is copied over so
/// engine-side scaling (`scale_divisor`) can't diverge either.
fn open_disk_tiny(path: &Path, ram: &Dataset, chunk_rows: usize, max_chunks: usize) -> Dataset {
    let mut ds =
        Dataset::open_ooc(path, ram.spec.train_frac, ram.spec.seed ^ 0x5717).unwrap();
    ds.spec = ram.spec.clone();
    ds.features =
        Arc::new(DiskFeatureStore::open(path).unwrap().with_buffer(chunk_rows, max_chunks));
    ds
}

fn tiny_cfg(num_layers: usize) -> ModelConfig {
    ModelConfig { kind: GnnKind::GraphSage, feat_dim: 32, hidden: 32, num_classes: 16, num_layers }
}

fn modulo_part(ds: &Dataset, k: usize) -> Partitioning {
    Partitioning {
        assignment: (0..ds.graph.num_vertices() as Vid)
            .map(|v| (v % k as Vid) as DeviceId)
            .collect(),
        k,
    }
}

fn degree_ranking(ds: &Dataset) -> Vec<u64> {
    (0..ds.graph.num_vertices() as Vid).map(|v| ds.graph.degree(v) as u64).collect()
}

fn assert_params_bit_identical(a: &ParamStore, b: &ParamStore, what: &str) {
    for (l, (la, lb)) in a.layers.iter().zip(&b.layers).enumerate() {
        for (t, (ta, tb)) in la.tensors.iter().zip(&lb.tensors).enumerate() {
            for (i, (x, y)) in ta.iter().zip(tb).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "{what}: param layer {l} tensor {t} elem {i}: {x} != {y}"
                );
            }
        }
    }
}

fn assert_stats_bit_identical(a: &[IterStats], b: &[IterStats], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: iteration counts differ");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.examples, y.examples, "{what}: iter {i} examples");
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "{what}: iter {i} loss");
        assert_eq!(x.correct.to_bits(), y.correct.to_bits(), "{what}: iter {i} correct");
    }
}

/// One epoch three ways — uncached in-RAM serial (oracle), disk-backed
/// serial, disk-backed pipelined — all bit-identical. Each disk trainer
/// opens its own store and builds its own cache from it (cache rows are
/// bit-exact copies of the same file bytes, so the caches agree too).
/// Returns the disk run's four-tier split and the oracle's uncached total.
fn check_case(
    topo: &Topology,
    policy: CachePolicy,
    budget: u64,
    workers: usize,
    chunk_rows: usize,
    max_chunks: usize,
    what: &str,
) -> (LoadStats, u64) {
    let (path, ram) = write_tiny_gsg();
    let k = topo.num_gpus();
    let cfg = tiny_cfg(2);
    let part = modulo_part(&ram, k);
    let ranking = degree_ranking(&ram);
    let backend = NativeBackend::new();

    let mut oracle = Trainer::new(&backend, &cfg, FANOUT, part.clone(), 0.2, SEED).unwrap();
    let a = train_epoch(&mut oracle, &ram, BATCH, SEED).unwrap();

    let ds_s = open_disk_tiny(&path, &ram, chunk_rows, max_chunks);
    let cache_s =
        Arc::new(ResidentCache::build(policy, &ranking, budget, &part, topo, &ds_s.features));
    let mut serial = Trainer::new(&backend, &cfg, FANOUT, part.clone(), 0.2, SEED)
        .unwrap()
        .with_config(TrainConfig::new().cache(Some(cache_s)))
        .unwrap();
    let b = train_epoch(&mut serial, &ds_s, BATCH, SEED).unwrap();

    let ds_p = open_disk_tiny(&path, &ram, chunk_rows, max_chunks);
    let cache_p =
        Arc::new(ResidentCache::build(policy, &ranking, budget, &part, topo, &ds_p.features));
    let mut pipelined = Trainer::new(&backend, &cfg, FANOUT, part, 0.2, SEED)
        .unwrap()
        .with_config(TrainConfig::new().cache(Some(cache_p)).parallel_workers(workers))
        .unwrap();
    let c = train_epoch(&mut pipelined, &ds_p, BATCH, SEED).unwrap();

    assert!(!a.is_empty());
    assert_stats_bit_identical(&a, &b, &format!("{what}: disk serial vs RAM oracle"));
    assert_stats_bit_identical(&a, &c, &format!("{what}: disk pipelined vs RAM oracle"));
    assert_params_bit_identical(&oracle.params, &serial.params, what);
    assert_params_bit_identical(&oracle.params, &pipelined.params, what);

    // Four-tier accounting: both disk executors saw the identical split,
    // and Local+Peer+Host+Disk sums to exactly what the oracle loaded.
    let oracle_split = LoadStats::sum(oracle.load_stats());
    assert_eq!(
        oracle_split.local_bytes + oracle_split.peer_bytes + oracle_split.disk_bytes,
        0,
        "{what}: oracle is uncached and in-RAM"
    );
    let serial_split = LoadStats::sum(serial.load_stats());
    let pipelined_split = LoadStats::sum(pipelined.load_stats());
    assert_eq!(serial_split, pipelined_split, "{what}: executors disagree on the byte split");
    assert_eq!(
        serial_split.total(),
        oracle_split.host_bytes,
        "{what}: Local/Peer/Host/Disk split must sum to the uncached total"
    );
    (serial_split, oracle_split.host_bytes)
}

#[test]
fn tracing_changes_no_output_bit_out_of_core() {
    // DESIGN.md §Observability: tracing only reads clocks — a disk-backed
    // epoch must stay bit-identical with the recorder on, and the chunk
    // faults must show up as `DiskFetch` spans. One traced test per
    // binary: the tracer is process-global and toggling it from parallel
    // tests would race.
    let cfg = tiny_cfg(2);
    let backend = NativeBackend::new();
    let (path, ram) = write_tiny_gsg();
    let part = modulo_part(&ram, 4);

    let ds_a = open_disk_tiny(&path, &ram, 256, 4);
    let mut untraced = Trainer::new(&backend, &cfg, FANOUT, part.clone(), 0.2, SEED).unwrap();
    let a = train_epoch(&mut untraced, &ds_a, BATCH, SEED).unwrap();

    let ds_b = open_disk_tiny(&path, &ram, 256, 4);
    let mut traced = Trainer::new(&backend, &cfg, FANOUT, part, 0.2, SEED)
        .unwrap()
        .with_config(TrainConfig::new().trace(true))
        .unwrap();
    let b = train_epoch(&mut traced, &ds_b, BATCH, SEED).unwrap();
    gsplit::obs::set_enabled(false);

    gsplit::obs::flush_thread();
    let snap = gsplit::obs::tracer().snapshot();
    let fetches: usize = snap
        .iter()
        .flat_map(|t| t.spans.iter())
        .filter(|s| s.phase == gsplit::obs::Phase::DiskFetch)
        .count();
    assert!(fetches > 0, "out-of-core run must record DiskFetch spans");
    gsplit::obs::tracer().reset();

    assert_stats_bit_identical(&a, &b, "traced disk serial vs untraced");
    assert_params_bit_identical(&untraced.params, &traced.params, "traced disk params");
}

#[test]
fn every_row_bit_identical_to_the_ram_source() {
    // The foundation of everything else in this file: the disk store
    // returns the exact bytes the lazy in-RAM source generated, for every
    // row, through plenty of LRU churn (1024 resident rows of 8000).
    let (path, ram) = write_tiny_gsg();
    let disk = open_disk_tiny(&path, &ram, 256, 4);
    let dim = ram.features.dim();
    assert_eq!(disk.features.dim(), dim);
    assert_eq!(disk.features.len(), ram.features.len());
    let mut want = vec![0f32; dim];
    let mut got = vec![0f32; dim];
    for v in 0..ram.graph.num_vertices() as Vid {
        ram.features.copy_row(v, &mut want);
        disk.features.fetch_row(v, &mut got);
        for (d, (w, g)) in want.iter().zip(&got).enumerate() {
            assert_eq!(w.to_bits(), g.to_bits(), "row {v} dim {d}: {w} != {g}");
        }
    }
}

#[test]
fn disk_epochs_bit_identical_across_policies_budgets_workers() {
    let topo = Topology::p3_8xlarge(1.0);
    for policy in [CachePolicy::None, CachePolicy::Distributed, CachePolicy::Partitioned] {
        for budget in [64u64, 1024] {
            for workers in [1usize, 2, 4] {
                let what = format!("ooc/{}/budget{budget}/workers{workers}", policy.name());
                let (split, total) = check_case(&topo, policy, budget, workers, 256, 4, &what);
                // The buffer (1024 resident rows of 8000) can never hold
                // the cache misses of an epoch: some fetches MUST fault.
                assert!(split.disk_bytes > 0, "{what}: no disk faults counted");
                match policy {
                    CachePolicy::None => {
                        assert_eq!(split.local_bytes + split.peer_bytes, 0, "{what}");
                        assert_eq!(split.host_bytes + split.disk_bytes, total, "{what}");
                    }
                    CachePolicy::Distributed => {
                        assert!(split.local_bytes > 0, "{what}: no local hits");
                        assert!(split.peer_bytes > 0, "{what}: no peer fetches");
                    }
                    CachePolicy::Partitioned => {
                        assert!(split.local_bytes > 0, "{what}: no local hits");
                        assert_eq!(
                            split.peer_bytes, 0,
                            "{what}: owner-consistent cache never fetches from peers"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn tiny_buffer_stress_stays_bit_identical() {
    // Pathological geometry — 8-row chunks, 2 resident — maximizes LRU
    // churn and the Disk share of the split; numerics must not notice.
    let topo = Topology::p3_8xlarge(1.0);
    let (split, _) =
        check_case(&topo, CachePolicy::Distributed, 256, 3, 8, 2, "ooc/stress/chunk8x2");
    assert!(split.disk_bytes > 0, "stress must fault");
}

#[test]
fn warm_buffer_splits_host_into_ram_and_disk() {
    // 1024-row chunks × 8 resident covers all 8000 rows: after the
    // post-cache-build cold start, the FIRST touch of each chunk faults
    // (Disk) and every later touch hits host memory (Ram) — so both host
    // tiers must be nonzero, and they still sum to the uncached total.
    let topo = Topology::p3_8xlarge(1.0);
    let (split, total) =
        check_case(&topo, CachePolicy::None, 64, 1, 1024, 8, "ooc/warm/chunk1024x8");
    assert!(split.host_bytes > 0, "warm buffer: re-touched rows must count as Ram");
    assert!(split.disk_bytes > 0, "warm buffer: first touches must count as Disk");
    assert_eq!(split.host_bytes + split.disk_bytes, total);
}

#[test]
fn truncated_cube_mesh_exercises_all_four_tiers() {
    // k = 6 cube-mesh truncation (see cache_equivalence.rs): Distributed
    // caching exercises Local, Peer, AND the linkless-copy → Host
    // fallback; with the disk source the Host leg further splits into
    // Ram + Disk — all four tiers nonzero in one bit-identical run.
    let topo = Topology::for_gpus(6, 1.0).unwrap();
    let (split, _) =
        check_case(&topo, CachePolicy::Distributed, 256, 3, 128, 4, "ooc/cube6/distributed");
    assert!(
        split.local_bytes > 0
            && split.peer_bytes > 0
            && split.host_bytes > 0
            && split.disk_bytes > 0,
        "expected all four tiers nonzero, got {split:?}"
    );
}
