//! Full-graph smoke suite (CI job `full-graph-smoke`):
//!
//! 1. The CAGNET-style [`FullGraph`] engine on `StandIn::Tiny` — sampling
//!    phase must be exactly zero (it processes every edge, every layer),
//!    the breakdown must be deterministic, and remote shuffle volume must
//!    vanish at `k = 1`.
//! 2. Full-neighborhood real-compute training: with fanout ≥ the graph's
//!    max degree the sampler keeps *every* neighbor, so each mini-batch
//!    computes exactly the math a full-graph system would for those
//!    targets. Serial vs pipelined executors must then be bit-identical —
//!    the determinism contract holds even at full-graph working-set sizes.

use gsplit::exec::{run_epoch, EngineCtx, FullGraph};
use gsplit::graph::StandIn;
use gsplit::model::{GnnKind, ModelConfig};
use gsplit::partition::Partitioning;
use gsplit::runtime::NativeBackend;
use gsplit::train::{train_epoch, TrainConfig, Trainer};
use gsplit::{devices::Topology, Vid};

#[test]
fn full_graph_engine_has_no_sampling_phase_and_is_deterministic() {
    let ds = StandIn::Tiny.load().unwrap();
    let topo = Topology::for_gpus(4, ds.spec.scale_divisor).unwrap();
    let ctx = EngineCtx::new(&ds, topo, GnnKind::GraphSage, 64, 2, 5);
    let mut engine = FullGraph::new(&ctx);
    // One whole-graph pass per epoch: batch = usize::MAX collapses the
    // epoch targets into a single iteration (matching the benches).
    let (c, t) = run_epoch(&mut engine, &ctx, usize::MAX, 42);

    assert_eq!(c.sampled_edges.iter().sum::<u64>(), 0, "full-graph must not sample");
    assert_eq!(c.sample_comm.total_remote(), 0, "no cooperative-sampling shuffle");
    assert_eq!(t.sampling, 0.0, "S must be exactly zero");
    assert!(t.loading > 0.0, "row-partitioned features still load");
    assert!(t.fb > 0.0, "forward/backward over every edge");
    assert!(c.train_comm.total_remote() > 0, "per-layer activation exchange at k=4");

    // Target- and seed-independent: a different epoch seed permutes the
    // targets, but the full-graph pass covers the same rows and edges.
    let (c2, _) = run_epoch(&mut FullGraph::new(&ctx), &ctx, usize::MAX, 1337);
    assert_eq!(c.fwd_flops, c2.fwd_flops, "FLOPs must not depend on the epoch seed");
    assert_eq!(
        c.train_comm.total_remote(),
        c2.train_comm.total_remote(),
        "shuffle volume must not depend on the epoch seed"
    );
}

#[test]
fn full_graph_engine_single_gpu_has_no_remote_traffic() {
    let ds = StandIn::Tiny.load().unwrap();
    let topo = Topology::single_host(1, false, ds.spec.scale_divisor);
    let ctx = EngineCtx::new(&ds, topo, GnnKind::GraphSage, 64, 2, 5);
    let (c, _) = run_epoch(&mut FullGraph::new(&ctx), &ctx, usize::MAX, 42);
    assert_eq!(c.train_comm.total_remote(), 0, "one GPU owns every row");
    assert!(c.fwd_flops.iter().sum::<u64>() > 0);
}

#[test]
fn exhaustive_fanout_epoch_serial_vs_pipelined_bit_identical() {
    let ds = StandIn::Tiny.load().unwrap();
    let max_degree = (0..ds.graph.num_vertices() as Vid)
        .map(|v| ds.graph.degree(v))
        .max()
        .unwrap_or(0) as usize;
    let fanout = max_degree.max(1);

    let cfg = ModelConfig {
        kind: GnnKind::GraphSage,
        feat_dim: 32,
        hidden: 32,
        num_classes: 16,
        num_layers: 2,
    };
    let part = Partitioning {
        assignment: (0..ds.graph.num_vertices() as Vid).map(|v| (v % 4) as u16).collect(),
        k: 4,
    };
    let backend = NativeBackend::new();

    let mut serial = Trainer::new(&backend, &cfg, fanout, part.clone(), 0.2, 42).unwrap();
    let a = train_epoch(&mut serial, &ds, 1024, 7).unwrap();

    let mut pipelined = Trainer::new(&backend, &cfg, fanout, part, 0.2, 42)
        .unwrap()
        .with_config(TrainConfig::new().parallel_workers(2))
        .unwrap();
    let b = train_epoch(&mut pipelined, &ds, 1024, 7).unwrap();

    assert!(!a.is_empty());
    assert_eq!(a.len(), b.len(), "iteration counts differ");
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(x.examples, y.examples, "iter {i} examples");
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "iter {i} loss {} != {}", x.loss, y.loss);
        assert_eq!(x.correct.to_bits(), y.correct.to_bits(), "iter {i} correct");
    }
}
