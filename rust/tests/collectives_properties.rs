//! Property tests for the `collectives` fabric in isolation — no trainer,
//! no dataset (DESIGN.md §Collectives):
//!
//! * `all_to_all` is a **bijection on rows**: every planned row arrives at
//!   exactly the position the shared plan derives for it, exactly once —
//!   at any worker grouping, channel capacity, or chunk size;
//! * the exchanged buffers are **bit-identical** across worker counts and
//!   `channel_cap ∈ {1, 8}`;
//! * `all_reduce` accumulates in fixed slice order (the serial oracle's
//!   bits, proven with an order-sensitive float sequence);
//! * `broadcast` delivers exactly one copy per receiver, in order;
//! * the shared abort flag breaks a pump whose peer never sends.

use std::sync::atomic::Ordering;
use std::sync::mpsc::sync_channel;
use std::thread;
use std::time::Duration;

use gsplit::collectives::{all_reduce, broadcast, Fabric, OutQueue, RowChunk};

const W: usize = 3; // row width (f32s per row)

/// Deterministic per-link row count (≥1, self-links included).
fn rows_sent(from: usize, to: usize) -> usize {
    (from * 7 + to * 3) % 5 + 1
}

fn recv_rows(k: usize, to: usize) -> usize {
    (0..k).map(|f| rows_sent(f, to)).sum()
}

/// Row offset of the (from → to) block in `to`'s receive buffer — the
/// "shared plan" both sides derive positions from.
fn offset(from: usize, to: usize) -> usize {
    (0..from).map(|f| rows_sent(f, to)).sum()
}

/// The unique value planted at (from → to, row r, column c).
fn value(from: usize, to: usize, r: usize, c: usize) -> f32 {
    (from * 100_000 + to * 10_000 + r * 10 + c) as f32
}

/// Run one all-to-all over `owned_sets` worker groupings and return each
/// device's assembled receive buffer. Panics if any planned position is
/// not written exactly once (the bijection property).
fn run_exchange(
    owned_sets: &[Vec<usize>],
    k: usize,
    channel_cap: usize,
    chunk_rows: usize,
) -> Vec<Vec<f32>> {
    let mut fabric = Fabric::new(k, channel_cap, chunk_rows);
    let mut endpoints: Vec<_> = owned_sets.iter().map(|o| fabric.endpoint(o.clone())).collect();
    let mut out: Vec<Vec<f32>> = vec![Vec::new(); k];
    thread::scope(|s| {
        let handles: Vec<_> = endpoints
            .drain(..)
            .map(|ep| {
                s.spawn(move || {
                    let owned = ep.owned().to_vec();
                    let mut outgoing = Vec::new();
                    for (li, &d) in owned.iter().enumerate() {
                        for to in 0..k {
                            let n = rows_sent(d, to);
                            let q = ep.pack_chunks(n, W, |i, buf| {
                                for c in 0..W {
                                    buf.push(value(d, to, i, c));
                                }
                            });
                            outgoing.push(OutQueue { li, to, q });
                        }
                    }
                    let mut expect: Vec<Vec<usize>> = owned
                        .iter()
                        .map(|&d| (0..k).map(|from| ep.chunks_of(rows_sent(from, d))).collect())
                        .collect();
                    let mut bufs: Vec<Vec<f32>> =
                        owned.iter().map(|&d| vec![f32::NAN; recv_rows(k, d) * W]).collect();
                    let mut fills: Vec<Vec<u32>> =
                        owned.iter().map(|&d| vec![0u32; recv_rows(k, d)]).collect();
                    ep.all_to_all(&mut outgoing, &mut expect, |li, from, chunk: RowChunk| {
                        let d = owned[li];
                        let base = offset(from, d) + chunk.start as usize;
                        let n = chunk.rows.len() / W;
                        for r in 0..n {
                            fills[li][base + r] += 1;
                            bufs[li][(base + r) * W..(base + r + 1) * W]
                                .copy_from_slice(&chunk.rows[r * W..(r + 1) * W]);
                        }
                    })
                    .expect("exchange completes");
                    for (li, f) in fills.iter().enumerate() {
                        assert!(
                            f.iter().all(|&c| c == 1),
                            "device {}: some planned position not written exactly once: {f:?}",
                            owned[li]
                        );
                    }
                    owned.into_iter().zip(bufs).collect::<Vec<_>>()
                })
            })
            .collect();
        for h in handles {
            for (d, buf) in h.join().expect("worker panicked") {
                out[d] = buf;
            }
        }
    });
    out
}

#[test]
fn all_to_all_is_a_bijection_on_rows() {
    let k = 4;
    let owners: Vec<Vec<usize>> = (0..k).map(|d| vec![d]).collect();
    let out = run_exchange(&owners, k, 8, 3);
    // Placement: every planted value landed at exactly the plan-derived
    // position (exactly-once is asserted inside run_exchange).
    for to in 0..k {
        for from in 0..k {
            let base = offset(from, to);
            for r in 0..rows_sent(from, to) {
                for c in 0..W {
                    assert_eq!(
                        out[to][(base + r) * W + c],
                        value(from, to, r, c),
                        "row ({from}->{to})[{r}][{c}] misplaced"
                    );
                }
            }
        }
    }
}

#[test]
fn exchange_bit_identical_across_worker_groupings_and_capacity() {
    let k = 4;
    let per_device: Vec<Vec<usize>> = (0..k).map(|d| vec![d]).collect();
    let reference = run_exchange(&per_device, k, 8, 4);
    let groupings: Vec<Vec<Vec<usize>>> = vec![
        vec![vec![0, 1, 2, 3]],       // one worker owns everything
        vec![vec![0, 2], vec![1, 3]], // two workers, strided
        per_device.clone(),           // one worker per device
    ];
    for owners in &groupings {
        for channel_cap in [1usize, 8] {
            for chunk_rows in [1usize, 5] {
                let got = run_exchange(owners, k, channel_cap, chunk_rows);
                for d in 0..k {
                    let same = reference[d]
                        .iter()
                        .zip(&got[d])
                        .all(|(a, b)| a.to_bits() == b.to_bits());
                    assert!(
                        same,
                        "device {d} differs: owners={owners:?} cap={channel_cap} chunk={chunk_rows}"
                    );
                }
            }
        }
    }
}

#[test]
fn all_reduce_matches_the_serial_accumulation_order_bitwise() {
    // Classic order-sensitive sequence: (1e8 + 1) - 1e8 rounds the 1 away,
    // so left-to-right gives 0.0 while any reordering that pairs the big
    // magnitudes first gives 1.0. The fixed slice order must reproduce the
    // serial oracle's bits exactly.
    let contribs = [
        vec![vec![1e8f32, 0.25]],
        vec![vec![1.0f32, 0.5]],
        vec![vec![-1e8f32, 0.125]],
    ];
    let mut oracle = vec![vec![0f32; 2]];
    for c in &contribs {
        for (a, b) in oracle[0].iter_mut().zip(&c[0]) {
            *a += b;
        }
    }
    assert_eq!(oracle[0][0].to_bits(), 0f32.to_bits(), "sequence must be order-sensitive");

    let refs: Vec<Option<&Vec<Vec<f32>>>> = contribs.iter().map(Some).collect();
    let mut acc = vec![vec![0f32; 2]];
    all_reduce(&mut acc, &refs);
    for (a, o) in acc[0].iter().zip(&oracle[0]) {
        assert_eq!(a.to_bits(), o.to_bits(), "all_reduce diverged from the serial order");
    }

    // A permutation visibly changes the bits — proving the order is load-bearing.
    let permuted: Vec<Option<&Vec<Vec<f32>>>> =
        [&contribs[0], &contribs[2], &contribs[1]].map(Some).to_vec();
    let mut acc_p = vec![vec![0f32; 2]];
    all_reduce(&mut acc_p, &permuted);
    assert_ne!(acc_p[0][0].to_bits(), acc[0][0].to_bits());

    // None entries are skipped without perturbing the order of the rest.
    let with_gaps: Vec<Option<&Vec<Vec<f32>>>> =
        vec![Some(&contribs[0]), None, Some(&contribs[1]), None, Some(&contribs[2])];
    let mut acc_g = vec![vec![0f32; 2]];
    all_reduce(&mut acc_g, &with_gaps);
    for (a, o) in acc_g[0].iter().zip(&oracle[0]) {
        assert_eq!(a.to_bits(), o.to_bits(), "None gaps must not perturb the order");
    }
}

#[test]
fn broadcast_delivers_every_message_exactly_once_per_worker_in_order() {
    let (txs, rxs): (Vec<_>, Vec<_>) = (0..3).map(|_| sync_channel::<u64>(1)).unzip();
    thread::scope(|s| {
        let handles: Vec<_> = rxs
            .into_iter()
            .map(|rx| {
                s.spawn(move || {
                    let mut got = Vec::new();
                    while let Ok(v) = rx.recv() {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        for m in 0..16u64 {
            broadcast(&txs, m).unwrap();
        }
        drop(txs);
        for h in handles {
            assert_eq!(
                h.join().unwrap(),
                (0..16).collect::<Vec<u64>>(),
                "each worker must see every message exactly once, in send order"
            );
        }
    });
}

#[test]
fn abort_flag_breaks_a_stuck_exchange() {
    let mut fabric = Fabric::new(2, 1, 1);
    let abort = fabric.abort_handle();
    let ep = fabric.endpoint(vec![0]);
    // Keep device 1's endpoints alive so the pump spins on an empty
    // channel instead of erroring on disconnect.
    let _peer = fabric.endpoint(vec![1]);
    thread::scope(|s| {
        s.spawn(move || {
            thread::sleep(Duration::from_millis(20));
            abort.store(true, Ordering::Relaxed);
        });
        // Expect one chunk from device 1 that never comes.
        let mut expect = vec![vec![0usize, 1]];
        let err = ep.all_to_all(&mut [], &mut expect, |_, _, _| {}).unwrap_err();
        assert!(err.to_string().contains("aborted"), "{err}");
    });
}
