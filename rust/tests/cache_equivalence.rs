//! Serial-vs-pipelined bit-identity with the cache-aware loading stage
//! enabled (DESIGN.md §Loading): for every cache policy × budget × worker
//! count, one epoch through the pipelined executor (including its
//! pre-forward peer-exchange phase) must match the serial trainer bit for
//! bit — and BOTH must match the uncached serial reference, because
//! cached rows are bit-exact copies of the host rows. Also pins the
//! loading-stage byte accounting: the Local/Peer/Host split always sums
//! to the uncached total.

use std::sync::Arc;

use gsplit::cache::{CachePolicy, LoadStats, ResidentCache};
use gsplit::devices::Topology;
use gsplit::graph::{Dataset, StandIn};
use gsplit::model::{GnnKind, ModelConfig, ParamStore};
use gsplit::partition::Partitioning;
use gsplit::runtime::NativeBackend;
use gsplit::train::{train_epoch, ExecMode, IterStats, PipelineConfig, TrainConfig, Trainer};
use gsplit::{DeviceId, Vid};

const FANOUT: usize = 5;
const BATCH: usize = 512;
const SEED: u64 = 42;

fn tiny_cfg(num_layers: usize) -> ModelConfig {
    ModelConfig { kind: GnnKind::GraphSage, feat_dim: 32, hidden: 32, num_classes: 16, num_layers }
}

fn modulo_part(ds: &Dataset, k: usize) -> Partitioning {
    Partitioning {
        assignment: (0..ds.graph.num_vertices() as Vid)
            .map(|v| (v % k as Vid) as DeviceId)
            .collect(),
        k,
    }
}

fn degree_ranking(ds: &Dataset) -> Vec<u64> {
    (0..ds.graph.num_vertices() as Vid).map(|v| ds.graph.degree(v) as u64).collect()
}

fn assert_params_bit_identical(a: &ParamStore, b: &ParamStore, what: &str) {
    for (l, (la, lb)) in a.layers.iter().zip(&b.layers).enumerate() {
        for (t, (ta, tb)) in la.tensors.iter().zip(&lb.tensors).enumerate() {
            for (i, (x, y)) in ta.iter().zip(tb).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "{what}: param layer {l} tensor {t} elem {i}: {x} != {y}"
                );
            }
        }
    }
}

fn assert_stats_bit_identical(a: &[IterStats], b: &[IterStats], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: iteration counts differ");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.examples, y.examples, "{what}: iter {i} examples");
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "{what}: iter {i} loss");
        assert_eq!(x.correct.to_bits(), y.correct.to_bits(), "{what}: iter {i} correct");
    }
}

/// One epoch three ways — uncached serial (oracle), cached serial, cached
/// pipelined — all bit-identical; returns the cached run's byte split and
/// the oracle's uncached total.
fn check_case(
    topo: &Topology,
    policy: CachePolicy,
    budget: u64,
    workers: usize,
    what: &str,
) -> (LoadStats, u64) {
    let ds = StandIn::Tiny.load().unwrap();
    let k = topo.num_gpus();
    let cfg = tiny_cfg(2);
    let part = modulo_part(&ds, k);
    let backend = NativeBackend::new();
    let cache = Arc::new(ResidentCache::build(
        policy,
        &degree_ranking(&ds),
        budget,
        &part,
        topo,
        &ds.features,
    ));

    let mut oracle = Trainer::new(&backend, &cfg, FANOUT, part.clone(), 0.2, SEED).unwrap();
    let mut serial = Trainer::new(&backend, &cfg, FANOUT, part.clone(), 0.2, SEED)
        .unwrap()
        .with_config(TrainConfig::new().cache(Some(Arc::clone(&cache))))
        .unwrap();
    let mut pipelined = Trainer::new(&backend, &cfg, FANOUT, part, 0.2, SEED)
        .unwrap()
        .with_config(TrainConfig::new().cache(Some(cache)).parallel_workers(workers))
        .unwrap();

    let a = train_epoch(&mut oracle, &ds, BATCH, SEED).unwrap();
    let b = train_epoch(&mut serial, &ds, BATCH, SEED).unwrap();
    let c = train_epoch(&mut pipelined, &ds, BATCH, SEED).unwrap();
    assert!(!a.is_empty());
    assert_stats_bit_identical(&a, &b, &format!("{what}: cached serial vs uncached oracle"));
    assert_stats_bit_identical(&a, &c, &format!("{what}: cached pipelined vs uncached oracle"));
    assert_params_bit_identical(&oracle.params, &serial.params, what);
    assert_params_bit_identical(&oracle.params, &pipelined.params, what);

    // Byte accounting: both cached executors saw the identical split, and
    // it sums to exactly what the oracle loaded from host memory.
    let oracle_split = LoadStats::sum(oracle.load_stats());
    assert_eq!(oracle_split.local_bytes + oracle_split.peer_bytes, 0, "{what}: oracle uncached");
    let serial_split = LoadStats::sum(serial.load_stats());
    let pipelined_split = LoadStats::sum(pipelined.load_stats());
    assert_eq!(serial_split, pipelined_split, "{what}: executors disagree on the byte split");
    assert_eq!(
        serial_split.total(),
        oracle_split.host_bytes,
        "{what}: Local/Peer/Host split must sum to the uncached total"
    );
    (serial_split, oracle_split.host_bytes)
}

#[test]
fn cached_epochs_bit_identical_across_policies_budgets_workers() {
    let topo = Topology::p3_8xlarge(1.0);
    for policy in [CachePolicy::None, CachePolicy::Distributed, CachePolicy::Partitioned] {
        for budget in [64u64, 1024] {
            for workers in [1usize, 2, 4] {
                let what = format!("{}/budget{budget}/workers{workers}", policy.name());
                let (split, total) = check_case(&topo, policy, budget, workers, &what);
                match policy {
                    CachePolicy::None => {
                        assert_eq!(split.local_bytes + split.peer_bytes, 0, "{what}");
                        assert_eq!(split.host_bytes, total, "{what}");
                    }
                    CachePolicy::Distributed => {
                        // All-NVLink 4-GPU host: the single-copy cache is
                        // partitioned, so hits split into Local and Peer.
                        assert!(split.local_bytes > 0, "{what}: no local hits");
                        assert!(split.peer_bytes > 0, "{what}: no peer fetches");
                    }
                    CachePolicy::Partitioned => {
                        assert!(split.local_bytes > 0, "{what}: no local hits");
                        assert_eq!(
                            split.peer_bytes, 0,
                            "{what}: owner-consistent cache never fetches from peers"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn cached_epochs_bit_identical_on_truncated_cube_mesh() {
    // k = 6 cube-mesh truncation: some cached copies sit behind missing
    // NVLinks, so the Distributed policy exercises Local, Peer, AND the
    // linkless-copy → Host fallback in one run — still bit-identical.
    let topo = Topology::for_gpus(6, 1.0).unwrap();
    let (split, _) = check_case(&topo, CachePolicy::Distributed, 256, 3, "cube6/distributed");
    assert!(split.local_bytes > 0 && split.peer_bytes > 0 && split.host_bytes > 0);
    let (split_p, _) = check_case(&topo, CachePolicy::Partitioned, 256, 6, "cube6/partitioned");
    assert_eq!(split_p.peer_bytes, 0);
}

#[test]
fn backpressure_stress_with_peer_exchange() {
    // Single-row chunks through capacity-1 channels while the loading
    // exchange phase is active: maximal backpressure on the same fabric
    // the forward/backward shuffles use.
    let ds = StandIn::Tiny.load().unwrap();
    let topo = Topology::p3_8xlarge(1.0);
    let cfg = tiny_cfg(2);
    let part = modulo_part(&ds, 4);
    let backend = NativeBackend::new();
    let cache = Arc::new(ResidentCache::build(
        CachePolicy::Distributed,
        &degree_ranking(&ds),
        512,
        &part,
        &topo,
        &ds.features,
    ));
    let mut serial = Trainer::new(&backend, &cfg, FANOUT, part.clone(), 0.2, 9)
        .unwrap()
        .with_config(TrainConfig::new().cache(Some(Arc::clone(&cache))))
        .unwrap();
    let stress = ExecMode::Pipelined(PipelineConfig { workers: 3, channel_cap: 1, chunk_rows: 1 });
    let mut stressed = Trainer::new(&backend, &cfg, FANOUT, part, 0.2, 9)
        .unwrap()
        .with_config(TrainConfig::new().cache(Some(cache)).exec(stress))
        .unwrap();
    let a = train_epoch(&mut serial, &ds, BATCH, 9).unwrap();
    let b = train_epoch(&mut stressed, &ds, BATCH, 9).unwrap();
    assert_stats_bit_identical(&a, &b, "backpressure + peer exchange");
    assert_params_bit_identical(&serial.params, &stressed.params, "backpressure + peer exchange");
    assert!(LoadStats::sum(stressed.load_stats()).peer_bytes > 0, "stress must exercise the exchange");
}

#[test]
fn config_rejects_mismatched_cache_device_count() {
    let ds = StandIn::Tiny.load().unwrap();
    let topo = Topology::p3_8xlarge(1.0);
    let part4 = modulo_part(&ds, 4);
    let part2 = modulo_part(&ds, 2);
    let backend = NativeBackend::new();
    let cache = Arc::new(ResidentCache::build(
        CachePolicy::Partitioned,
        &degree_ranking(&ds),
        64,
        &part4,
        &topo,
        &ds.features,
    ));
    let cfg = tiny_cfg(2);
    let trainer = Trainer::new(&backend, &cfg, FANOUT, part2, 0.2, SEED).unwrap();
    let res = trainer.with_config(TrainConfig::new().cache(Some(cache)));
    assert!(res.is_err(), "k mismatch must be rejected");
}

#[test]
#[allow(deprecated)]
fn deprecated_setters_forward_to_the_config_path() {
    // The pre-TrainConfig setters stay as thin shims; this is the one
    // place they are still exercised, pinned against the new surface.
    let ds = StandIn::Tiny.load().unwrap();
    let topo = Topology::p3_8xlarge(1.0);
    let part = modulo_part(&ds, 4);
    let backend = NativeBackend::new();
    let cfg = tiny_cfg(2);
    let cache = Arc::new(ResidentCache::build(
        CachePolicy::Partitioned,
        &degree_ranking(&ds),
        64,
        &part,
        &topo,
        &ds.features,
    ));

    let mut shimmed = Trainer::new(&backend, &cfg, FANOUT, part.clone(), 0.2, SEED).unwrap();
    shimmed.set_cache(Some(Arc::clone(&cache))).unwrap();
    shimmed.set_exec_mode(ExecMode::Pipelined(PipelineConfig::with_workers(2)));
    let configured = Trainer::new(&backend, &cfg, FANOUT, part.clone(), 0.2, SEED)
        .unwrap()
        .with_config(TrainConfig::new().cache(Some(cache)).parallel_workers(2))
        .unwrap();
    assert_eq!(shimmed.exec_mode(), configured.exec_mode());
    assert!(shimmed.cache().is_some() && configured.cache().is_some());

    // with_parallel_workers(0) still means serial, like parallel_workers(0).
    let serial = Trainer::new(&backend, &cfg, FANOUT, part, 0.2, SEED)
        .unwrap()
        .with_parallel_workers(0);
    assert_eq!(serial.exec_mode(), ExecMode::Serial);

    // And the shim path enforces the same cache/k validation.
    let ds2 = StandIn::Tiny.load().unwrap();
    let part2 = modulo_part(&ds2, 2);
    let mut mismatched = Trainer::new(&backend, &cfg, FANOUT, part2, 0.2, SEED).unwrap();
    let bad = Arc::new(ResidentCache::build(
        CachePolicy::Partitioned,
        &degree_ranking(&ds2),
        64,
        &modulo_part(&ds2, 4),
        &topo,
        &ds2.features,
    ));
    assert!(mismatched.set_cache(Some(bad)).is_err(), "shim must reject k mismatch too");
}
