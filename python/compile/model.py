"""L2 — JAX GNN model: GraphSage and GAT layers over the L1 Pallas kernels,
plus the softmax-CE loss head.

Everything here is **build-time only**: ``aot.py`` lowers these functions to
HLO text once; the Rust coordinator executes the artifacts via PJRT and
composes layers with its own shuffles (split parallelism) — exactly the
layer-centric kernel reuse the paper's §6 API argues for.

Conventions shared with the Rust runtime (see rust/src/runtime):
  * the mixed-frontier feature matrix ``x`` has the destination rows first
    (``x[:M]`` are the destinations' own features),
  * neighbor tables are ``[M, K]`` int32 indices into ``x`` with a parallel
    ``[M, K]`` float32 validity mask (0.0 ⇒ padded slot; padded ``idx``
    must still be < N, the runtime uses 0),
  * padded destination rows simply produce garbage outputs that the runtime
    slices away; the loss head additionally takes a per-row validity mask.
"""

import jax
import jax.numpy as jnp

from .kernels import gat_attention, gather_mean

# ---------------------------------------------------------------------------
# Layers
# ---------------------------------------------------------------------------


def sage_layer(params, x, idx, mask, relu):
    """GraphSage layer: h = act(x_self @ W_self + mean(x_nbr) @ W_neigh + b).

    ``params = (w_self [Din,Dout], w_neigh [Din,Dout], bias [Dout])``.
    """
    w_self, w_neigh, bias = params
    m = idx.shape[0]
    agg = gather_mean(x, idx, mask)  # [M, Din] — L1 Pallas kernel
    h = x[:m] @ w_self + agg @ w_neigh + bias
    return jax.nn.relu(h) if relu else h


def gat_layer(params, x, idx, mask, relu):
    """Single-head GAT layer with implicit self edge.

    ``params = (w [Din,Dout], a_src [Dout], a_dst [Dout], bias [Dout])``.
    The projection and attention dot products run in jnp (MXU-friendly);
    the score/softmax/weighted-sum hot loop is the L1 Pallas kernel.
    """
    w, a_src, a_dst, bias = params
    m = idx.shape[0]
    z = x @ w  # [N, Dout]
    s_src = z @ a_src  # [N]
    s_dst = (z @ a_dst)[:m]  # [M]
    h = gat_attention(z, s_src, s_dst, idx, mask) + bias
    return jax.nn.relu(h) if relu else h


def layer_apply(kind, params, x, idx, mask, relu):
    if kind == "sage":
        return sage_layer(params, x, idx, mask, relu)
    if kind == "gat":
        return gat_layer(params, x, idx, mask, relu)
    raise ValueError(f"unknown layer kind {kind!r}")


def layer_bwd(kind, params, x, idx, mask, relu, g_out):
    """VJP of one layer w.r.t. (x, *params) — the per-layer backward the
    split-parallel engine composes with reverse shuffles.

    Returns ``(g_x [N,Din], *g_params)``.
    """
    _, vjp = jax.vjp(lambda xx, *pp: layer_apply(kind, pp, xx, idx, mask, relu), x, *params)
    return vjp(g_out)


# ---------------------------------------------------------------------------
# Loss head
# ---------------------------------------------------------------------------


def loss_head(logits, labels, valid):
    """Masked softmax cross-entropy over target rows.

    Args:
      logits: [B, C] — top-layer outputs for the (padded) target rows.
      labels: [B] int32.
      valid:  [B] float32 — 1.0 for real targets, 0.0 for padding.

    Returns:
      (loss, g_logits, correct): mean CE over valid rows, its gradient
      w.r.t. ``logits``, and the number of correct (valid) predictions.
    """
    denom = jnp.maximum(valid.sum(), 1.0)

    def mean_ce(lg):
        logp = jax.nn.log_softmax(lg, axis=-1)
        ce = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
        return jnp.sum(ce * valid) / denom

    loss, g_logits = jax.value_and_grad(mean_ce)(logits)
    pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    correct = jnp.sum((pred == labels).astype(jnp.float32) * valid)
    return loss, g_logits, correct


# ---------------------------------------------------------------------------
# Whole-minibatch reference (used by tests and the fused single-device path)
# ---------------------------------------------------------------------------


def full_forward(kind, all_params, x_input, layers):
    """Run a whole sampled mini-batch bottom-up on one device.

    ``layers`` is a list of ``(idx, mask, gather)`` from bottom to top,
    where ``gather`` maps the *next* layer's mixed rows into the current
    output rows (what the cross-device shuffle does in split parallelism;
    on one device it's a plain take). The bottom entry's ``gather`` indexes
    into ``x_input`` rows. Returns top-layer logits.
    """
    h = x_input
    num = len(all_params)
    for l, (params, (idx, mask, gather)) in enumerate(zip(all_params, layers)):
        if gather is not None:
            h = h[gather]
        relu = l + 1 < num
        h = layer_apply(kind, params, h, idx, mask, relu)
    return h


def init_params(kind, rng, dims):
    """Xavier-uniform init; ``dims`` = [(din, dout), ...] bottom→top.

    Mirrors ``rust/src/model`` ParamStore layouts (shape-wise; the Rust
    side streams its own deterministic values into the artifacts).
    """
    params = []
    for din, dout in dims:
        rng, k1, k2, k3 = jax.random.split(rng, 4)
        bound = (6.0 / (din + dout)) ** 0.5
        if kind == "sage":
            params.append(
                (
                    jax.random.uniform(k1, (din, dout), minval=-bound, maxval=bound),
                    jax.random.uniform(k2, (din, dout), minval=-bound, maxval=bound),
                    jnp.zeros((dout,)),
                )
            )
        else:
            params.append(
                (
                    jax.random.uniform(k1, (din, dout), minval=-bound, maxval=bound),
                    jax.random.uniform(k2, (dout,), minval=-bound, maxval=bound) * 0.1,
                    jax.random.uniform(k3, (dout,), minval=-bound, maxval=bound) * 0.1,
                    jnp.zeros((dout,)),
                )
            )
    return params
