"""AOT compiler: lowers the L2/L1 functions to HLO **text** artifacts that
the Rust coordinator loads via PJRT (`xla` crate).

Why HLO text and not ``lowered.compile().serialize()`` / serialized protos:
jax ≥ 0.5 emits HloModuleProtos with 64-bit instruction ids which the
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the HLO
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Artifact set (see DESIGN.md §7 "Shapes & padding"): per model kind and
per (din, dout, relu) layer signature, forward and backward executables in
a few destination-row *buckets* M ∈ M_BUCKETS with mixed-frontier capacity
N = M·(K+1) — a sampled layer with M_actual ≤ M always has
N_actual ≤ M_actual·(K+1) ≤ N, so the runtime just picks the smallest
bucket that fits and pads. Plus the loss head per batch bucket, and a
golden-values file the Rust integration tests verify numerics against.

Usage: ``cd python && python -m compile.aot --out ../artifacts``
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model

# ---------------------------------------------------------------------------
# Configuration — must stay in sync with rust/src/runtime (the manifest
# carries all of it, so Rust reads rather than assumes).
# ---------------------------------------------------------------------------

KERNEL_K = 5  # fanout of runtime-executed configs (examples + tests)
M_BUCKETS = [256, 1024, 4096]
LOSS_BUCKETS = [256, 1024]

# (din, dout, relu) bottom→top for the default end-to-end model:
# feat 32 → hidden 64 → hidden 64 → 8 classes.
FEAT_DIM = 32
HIDDEN = 64
NUM_CLASSES = 8
LAYER_DIMS = [
    (FEAT_DIM, HIDDEN, True),
    (HIDDEN, HIDDEN, True),
    (HIDDEN, NUM_CLASSES, False),
]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def param_specs(kind, din, dout):
    if kind == "sage":
        return [f32(din, dout), f32(din, dout), f32(dout)]
    return [f32(din, dout), f32(dout), f32(dout), f32(dout)]


def layer_fwd_fn(kind, relu):
    def fn(x, idx, mask, *params):
        return (model.layer_apply(kind, params, x, idx, mask, relu),)

    return fn


def layer_bwd_fn(kind, relu):
    def fn(x, idx, mask, g_out, *params):
        grads = model.layer_bwd(kind, params, x, idx, mask, relu, g_out)
        return tuple(grads)  # (g_x, *g_params)

    return fn


def loss_fn(logits, labels, valid):
    return model.loss_head(logits, labels, valid)


def lower_artifact(fn, specs):
    # keep_unused: jax DCEs arguments that don't affect outputs (e.g. the
    # bias in a no-relu backward); the Rust runtime passes the full argument
    # list, so the HLO signature must keep every parameter.
    return to_hlo_text(jax.jit(fn, keep_unused=True).lower(*specs))


def build_artifacts(out_dir):
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "version": 1,
        "kernel_fanout": KERNEL_K,
        "m_buckets": M_BUCKETS,
        "loss_buckets": LOSS_BUCKETS,
        "feat_dim": FEAT_DIM,
        "hidden": HIDDEN,
        "num_classes": NUM_CLASSES,
        "layer_dims": [[d, o, r] for (d, o, r) in LAYER_DIMS],
        "artifacts": [],
    }

    def emit(name, fn, specs, meta):
        path = f"{name}.hlo.txt"
        text = lower_artifact(fn, specs)
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(text)
        entry = {"name": name, "file": path}
        entry.update(meta)
        manifest["artifacts"].append(entry)
        print(f"  wrote {path} ({len(text) / 1024:.0f} KiB)")

    k = KERNEL_K
    for kind in ("sage", "gat"):
        for din, dout, relu in LAYER_DIMS:
            for m in M_BUCKETS:
                n = m * (k + 1)
                rtag = "r1" if relu else "r0"
                base = f"{kind}_{din}x{dout}_{rtag}_m{m}"
                common = {
                    "model": kind,
                    "din": din,
                    "dout": dout,
                    "relu": relu,
                    "m": m,
                    "n": n,
                    "k": k,
                }
                emit(
                    f"{base}_fwd",
                    layer_fwd_fn(kind, relu),
                    [f32(n, din), i32(m, k), f32(m, k), *param_specs(kind, din, dout)],
                    {"kind": "layer_fwd", **common},
                )
                emit(
                    f"{base}_bwd",
                    layer_bwd_fn(kind, relu),
                    [
                        f32(n, din),
                        i32(m, k),
                        f32(m, k),
                        f32(m, dout),
                        *param_specs(kind, din, dout),
                    ],
                    {"kind": "layer_bwd", **common},
                )
    for b in LOSS_BUCKETS:
        emit(
            f"loss_b{b}_c{NUM_CLASSES}",
            loss_fn,
            [f32(b, NUM_CLASSES), i32(b), f32(b)],
            {"kind": "loss", "b": b, "c": NUM_CLASSES},
        )

    write_goldens(out_dir, manifest)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"manifest: {len(manifest['artifacts'])} artifacts")


def write_goldens(out_dir, manifest):
    """Deterministic test vectors the Rust runtime verifies against.

    Small shapes (M=8 real rows inside the m=256 bucket) so the JSON stays
    tiny; inputs are simple ramps so Rust can regenerate them exactly.
    """
    k = KERNEL_K
    m_real, din, dout = 8, FEAT_DIM, HIDDEN
    m, n = M_BUCKETS[0], M_BUCKETS[0] * (k + 1)

    def ramp(shape, scale, dtype=np.float32):
        size = int(np.prod(shape))
        # Bounded deterministic pattern, exactly reproducible in Rust:
        # v(i) = ((i * 37 + 11) % 97) / 97 * scale - scale/2
        v = (((np.arange(size) * 37 + 11) % 97) / 97.0 * scale - scale / 2).astype(dtype)
        return v.reshape(shape)

    x = np.zeros((n, din), np.float32)
    x[: m_real * (k + 1)] = ramp((m_real * (k + 1), din), 2.0)
    idx = np.zeros((m, k), np.int32)
    mask = np.zeros((m, k), np.float32)
    for i in range(m_real):
        for j in range(k):
            # neighbors of row i live at rows m_real + i*k + j
            idx[i, j] = m_real + i * k + j
            mask[i, j] = 1.0 if (i + j) % 4 != 3 else 0.0  # some padding
    params = [ramp(s.shape, 0.5) for s in param_specs("sage", din, dout)]
    out = model.layer_apply(
        "sage", tuple(jnp.asarray(p) for p in params), jnp.asarray(x), jnp.asarray(idx), jnp.asarray(mask), True
    )
    out = np.asarray(out)

    # Loss golden.
    b = LOSS_BUCKETS[0]
    logits = ramp((b, NUM_CLASSES), 4.0)
    labels = ((np.arange(b) * 7 + 3) % NUM_CLASSES).astype(np.int32)
    valid = (np.arange(b) < 16).astype(np.float32)
    loss, g_logits, correct = model.loss_head(
        jnp.asarray(logits), jnp.asarray(labels), jnp.asarray(valid)
    )

    golden = {
        "layer": {
            "artifact": f"sage_{din}x{dout}_r1_m{m}_fwd",
            "m_real": m_real,
            "out_rows": out[:m_real].reshape(-1).tolist(),
        },
        "loss": {
            "artifact": f"loss_b{b}_c{NUM_CLASSES}",
            "loss": float(loss),
            "correct": float(correct),
            "g_logits_head": np.asarray(g_logits)[:2].reshape(-1).tolist(),
        },
    }
    with open(os.path.join(out_dir, "golden.json"), "w") as f:
        json.dump(golden, f)
    print("  wrote golden.json")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    build_artifacts(args.out)


if __name__ == "__main__":
    main()
