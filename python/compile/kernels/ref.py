"""Pure-jnp oracles for the Pallas kernels.

These are the "obviously correct" reference implementations. Every Pallas
kernel must match them (pytest + hypothesis sweeps in ``tests/``), and the
backward passes wired through ``jax.custom_vjp`` must match ``jax.grad`` of
these references.
"""

import jax
import jax.numpy as jnp

LEAKY_SLOPE = 0.2  # GAT LeakyReLU slope (Velickovic et al. 2018)


def gather_mean_ref(x, idx, mask):
    """Masked mean aggregation of sampled neighbors.

    Args:
      x:    [N, D] float32 — mixed-frontier feature rows.
      idx:  [M, K] int32   — per-destination neighbor indices into ``x``
                             (padded slots may hold any valid index).
      mask: [M, K] float32 — 1.0 for real neighbors, 0.0 for padding.

    Returns:
      [M, D] float32 — sum(x[idx] * mask) / max(sum(mask), 1) per row.
      Zero-degree rows (all-zero mask) return zeros.
    """
    rows = x[idx]  # [M, K, D]
    s = jnp.sum(rows * mask[..., None], axis=1)
    cnt = jnp.maximum(jnp.sum(mask, axis=1), 1.0)
    return s / cnt[:, None]


def gather_mean_grad_x_ref(idx, mask, g_out, n):
    """Reference gradient of ``gather_mean_ref`` w.r.t. ``x``.

    Each sampled edge (m, k) scatters ``g_out[m] * mask[m,k] / cnt[m]``
    into row ``idx[m, k]``.
    """
    cnt = jnp.maximum(jnp.sum(mask, axis=1), 1.0)
    contrib = (g_out / cnt[:, None])[:, None, :] * mask[..., None]  # [M,K,D]
    gx = jnp.zeros((n, g_out.shape[-1]), g_out.dtype)
    return gx.at[idx].add(contrib)


def gat_attention_ref(z, s_src, s_dst, idx, mask):
    """Single-head GAT aggregation with an implicit self edge.

    Args:
      z:     [N, D] — projected features (x @ W) of the mixed frontier.
      s_src: [N]    — per-source attention term (z @ a_src).
      s_dst: [M]    — per-destination attention term ((z @ a_dst)[:M];
                      destination m *is* mixed row m).
      idx:   [M, K] int32 — neighbor indices into ``z``.
      mask:  [M, K] — 1/0 validity.

    Returns:
      [M, D] — attention-weighted sum over {self} ∪ neighbors, with
      LeakyReLU(0.2) on logits and a masked softmax.
    """
    m = idx.shape[0]
    e_self = s_dst + s_src[:m]  # [M] — self edge score
    e_nb = s_dst[:, None] + s_src[idx]  # [M, K]
    logits = jnp.concatenate([e_self[:, None], e_nb], axis=1)  # [M, K+1]
    logits = jax.nn.leaky_relu(logits, LEAKY_SLOPE)
    full_mask = jnp.concatenate([jnp.ones((m, 1), mask.dtype), mask], axis=1)
    neg = jnp.finfo(logits.dtype).min / 2
    masked = jnp.where(full_mask > 0, logits, neg)
    alpha = jax.nn.softmax(masked, axis=1) * full_mask
    alpha = alpha / jnp.maximum(alpha.sum(axis=1, keepdims=True), 1e-9)
    out = alpha[:, 0:1] * z[:m] + jnp.einsum("mk,mkd->md", alpha[:, 1:], z[idx])
    return out
