"""L1 — Pallas kernels for the GNN compute hot-spots.

``gather_mean``   — GraphSage masked mean aggregation (Pallas fwd + Pallas
                    scatter-add bwd via custom_vjp).
``gat_attention`` — single-head GAT attention aggregation (Pallas fwd,
                    recompute jnp bwd via custom_vjp).
``ref``           — pure-jnp oracles both kernels are tested against.
"""

from .gat_attn import gat_attention
from .gather_mean import gather_mean, scatter_mean_grad
from . import ref

__all__ = ["gather_mean", "scatter_mean_grad", "gat_attention", "ref"]
