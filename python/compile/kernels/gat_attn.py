"""Pallas kernel: single-head GAT attention aggregation.

Forward (Pallas): per destination-row block, score the self edge and the K
sampled neighbor edges (LeakyReLU of additive attention terms), apply a
masked softmax, and accumulate the attention-weighted sum of projected
neighbor rows. The dense projection ``z = x @ W`` and the attention dot
products ``z @ a_src``, ``z @ a_dst`` stay in jnp so XLA schedules them on
the MXU (DESIGN.md §Hardware-Adaptation).

Backward: recompute-based ``custom_vjp`` in jnp against the reference
aggregation — attention softmax gradients are cheap relative to the
projection matmuls, and this keeps the VJP exactly consistent with the
oracle (verified in pytest against ``jax.grad`` of ``ref``).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

BLOCK_M = 128


def _gat_kernel(z_ref, zdst_ref, ssrc_ref, sself_ref, sdst_ref, idx_ref, mask_ref, o_ref):
    z = z_ref[...]  # (N, D) projected sources
    z_dst = zdst_ref[...]  # (BM, D) destinations' own rows
    s_src = ssrc_ref[...]  # (N,)
    s_self = sself_ref[...]  # (BM,) src-term of the self edge
    s_dst = sdst_ref[...]  # (BM,) dst-term
    idx = idx_ref[...]  # (BM, K)
    mask = mask_ref[...]  # (BM, K)

    e_self = s_dst + s_self  # (BM,)
    e_nb = s_dst[:, None] + s_src[idx]  # (BM, K)
    logits = jnp.concatenate([e_self[:, None], e_nb], axis=1)  # (BM, K+1)
    logits = jnp.where(logits > 0, logits, ref.LEAKY_SLOPE * logits)
    full_mask = jnp.concatenate([jnp.ones_like(e_self)[:, None], mask], axis=1)
    neg = jnp.finfo(logits.dtype).min / 2
    masked = jnp.where(full_mask > 0, logits, neg)
    mx = jnp.max(masked, axis=1, keepdims=True)
    w = jnp.exp(masked - mx) * full_mask
    alpha = w / jnp.maximum(jnp.sum(w, axis=1, keepdims=True), 1e-9)
    nbr = z[idx]  # (BM, K, D)
    out = alpha[:, 0:1] * z_dst + jnp.sum(alpha[:, 1:, None] * nbr, axis=1)
    o_ref[...] = out


def _pad_rows(a, m_pad):
    if a.shape[0] == m_pad:
        return a
    pad = [(0, m_pad - a.shape[0])] + [(0, 0)] * (a.ndim - 1)
    return jnp.pad(a, pad)


def _gat_fwd_impl(z, s_src, s_dst, idx, mask):
    m, k = idx.shape
    n, d = z.shape
    bm = min(BLOCK_M, m) if m > 0 else 1
    m_pad = ((m + bm - 1) // bm) * bm
    idx_p = _pad_rows(idx, m_pad)
    mask_p = _pad_rows(mask, m_pad)
    sdst_p = _pad_rows(s_dst, m_pad)
    zdst_p = _pad_rows(z[:m], m_pad)
    sself_p = _pad_rows(s_src[:m], m_pad)
    out = pl.pallas_call(
        _gat_kernel,
        grid=(m_pad // bm,),
        in_specs=[
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, d), z.dtype),
        interpret=True,
    )(z, zdst_p, s_src, sself_p, sdst_p, idx_p, mask_p)
    return out[:m]


@jax.custom_vjp
def gat_attention(z, s_src, s_dst, idx, mask):
    """Attention-weighted aggregation; see ``ref.gat_attention_ref``.

    Differentiable w.r.t. ``z``, ``s_src``, ``s_dst``.
    """
    return _gat_fwd_impl(z, s_src, s_dst, idx, mask)


def _vjp_fwd(z, s_src, s_dst, idx, mask):
    return _gat_fwd_impl(z, s_src, s_dst, idx, mask), (z, s_src, s_dst, idx, mask)


def _vjp_bwd(res, g_out):
    z, s_src, s_dst, idx, mask = res
    # Recompute-based VJP through the jnp oracle (numerically identical to
    # the Pallas forward; asserted in tests).
    _, vjp = jax.vjp(lambda zz, ss, sd: ref.gat_attention_ref(zz, ss, sd, idx, mask), z, s_src, s_dst)
    gz, gs_src, gs_dst = vjp(g_out)
    return gz, gs_src, gs_dst, None, None


gat_attention.defvjp(_vjp_fwd, _vjp_bwd)
