"""Pallas kernel: masked gather-mean aggregation (GraphSage hot spot).

Forward: for each destination row, gather its K sampled neighbor rows from
the mixed-frontier feature matrix and average the valid ones. Backward:
scatter-add of the output gradient back to the gathered rows — also a
Pallas kernel — wired together with ``jax.custom_vjp``.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles destination
rows in blocks of ``BLOCK_M``; each grid step keeps one ``(BLOCK_M, K)``
index tile, one mask tile, and one ``(BLOCK_M, D)`` output tile in VMEM and
gathers from the source matrix (resident here; streamed from HBM on a real
TPU — the BlockSpec index map is where the paper's thread-block schedule
lives). ``interpret=True`` everywhere: the CPU PJRT client cannot execute
Mosaic custom-calls, and interpret-mode lowering produces plain HLO that
both pytest and the Rust runtime execute (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_M = 128


def _fwd_kernel(x_ref, idx_ref, mask_ref, o_ref):
    x = x_ref[...]  # (N, D) source rows
    idx = idx_ref[...]  # (BM, K)
    mask = mask_ref[...]  # (BM, K)
    rows = x[idx]  # (BM, K, D) gather
    s = jnp.sum(rows * mask[..., None], axis=1)
    cnt = jnp.maximum(jnp.sum(mask, axis=1), 1.0)
    o_ref[...] = s / cnt[:, None]


def _bwd_kernel(idx_ref, mask_ref, g_ref, o_ref):
    # The output block is the full (N, D) gradient, revisited by every grid
    # step; initialize once, then scatter-add each step's contribution.
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    idx = idx_ref[...]
    mask = mask_ref[...]
    g = g_ref[...]  # (BM, D)
    cnt = jnp.maximum(jnp.sum(mask, axis=1), 1.0)
    contrib = (g / cnt[:, None])[:, None, :] * mask[..., None]  # (BM, K, D)
    o_ref[...] = o_ref[...].at[idx].add(contrib)


def _pad_rows(a, m_pad):
    if a.shape[0] == m_pad:
        return a
    pad = [(0, m_pad - a.shape[0])] + [(0, 0)] * (a.ndim - 1)
    return jnp.pad(a, pad)


def _gather_mean_fwd_impl(x, idx, mask):
    m, k = idx.shape
    n, d = x.shape
    bm = min(BLOCK_M, m) if m > 0 else 1
    m_pad = ((m + bm - 1) // bm) * bm
    idx_p = _pad_rows(idx, m_pad)
    mask_p = _pad_rows(mask, m_pad)
    out = pl.pallas_call(
        _fwd_kernel,
        grid=(m_pad // bm,),
        in_specs=[
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, d), x.dtype),
        interpret=True,
    )(x, idx_p, mask_p)
    return out[:m]


def scatter_mean_grad(idx, mask, g_out, n):
    """Pallas backward: scatter-add gradient to the N source rows."""
    m, k = idx.shape
    d = g_out.shape[-1]
    bm = min(BLOCK_M, m) if m > 0 else 1
    m_pad = ((m + bm - 1) // bm) * bm
    idx_p = _pad_rows(idx, m_pad)
    mask_p = _pad_rows(mask, m_pad)
    g_p = _pad_rows(g_out, m_pad)
    return pl.pallas_call(
        _bwd_kernel,
        grid=(m_pad // bm,),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((n, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), g_out.dtype),
        interpret=True,
    )(idx_p, mask_p, g_p)


@jax.custom_vjp
def gather_mean(x, idx, mask):
    """Masked mean over gathered neighbor rows; see ``ref.gather_mean_ref``.

    Differentiable w.r.t. ``x`` (Pallas scatter-add backward); ``idx`` and
    ``mask`` are treated as constants.
    """
    return _gather_mean_fwd_impl(x, idx, mask)


def _vjp_fwd(x, idx, mask):
    return _gather_mean_fwd_impl(x, idx, mask), (idx, mask, x.shape[0])


def _vjp_bwd(res, g_out):
    idx, mask, n = res
    gx = scatter_mean_grad(idx, mask, g_out, n)
    return gx, None, None


gather_mean.defvjp(_vjp_fwd, _vjp_bwd)


@functools.partial(jax.jit, static_argnums=())
def gather_mean_jit(x, idx, mask):
    return gather_mean(x, idx, mask)
