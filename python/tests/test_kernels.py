"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py), including
hypothesis sweeps over shapes and the custom_vjp backward passes vs
jax.grad of the references."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gat_attention, gather_mean, ref, scatter_mean_grad

jax.config.update("jax_platform_name", "cpu")


def rand_case(seed, n, m, k, d):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d)).astype(np.float32)
    idx = rng.integers(0, n, (m, k)).astype(np.int32)
    mask = (rng.random((m, k)) > 0.25).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(idx), jnp.asarray(mask)


class TestGatherMean:
    def test_matches_ref_basic(self):
        x, idx, mask = rand_case(0, n=64, m=32, k=5, d=16)
        got = gather_mean(x, idx, mask)
        want = ref.gather_mean_ref(x, idx, mask)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_zero_degree_rows_are_zero(self):
        x, idx, mask = rand_case(1, n=32, m=8, k=4, d=8)
        mask = mask.at[3].set(0.0)
        got = gather_mean(x, idx, mask)
        np.testing.assert_allclose(got[3], np.zeros(8), atol=1e-6)

    def test_full_mask_is_plain_mean(self):
        x, idx, _ = rand_case(2, n=32, m=16, k=4, d=8)
        mask = jnp.ones((16, 4), jnp.float32)
        got = gather_mean(x, idx, mask)
        want = x[idx].mean(axis=1)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(2, 200),
        m=st.integers(1, 300),
        k=st.integers(1, 12),
        d=st.integers(1, 40),
        seed=st.integers(0, 10_000),
    )
    def test_matches_ref_sweep(self, n, m, k, d, seed):
        x, idx, mask = rand_case(seed, n=n, m=m, k=k, d=d)
        got = gather_mean(x, idx, mask)
        want = ref.gather_mean_ref(x, idx, mask)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_grad_matches_ref_grad(self):
        x, idx, mask = rand_case(3, n=48, m=24, k=5, d=12)

        def via_kernel(xx):
            return (gather_mean(xx, idx, mask) ** 2).sum()

        def via_ref(xx):
            return (ref.gather_mean_ref(xx, idx, mask) ** 2).sum()

        gk = jax.grad(via_kernel)(x)
        gr = jax.grad(via_ref)(x)
        np.testing.assert_allclose(gk, gr, rtol=1e-4, atol=1e-5)

    def test_scatter_bwd_matches_ref(self):
        x, idx, mask = rand_case(4, n=40, m=16, k=6, d=8)
        g = jnp.asarray(np.random.default_rng(5).standard_normal((16, 8)).astype(np.float32))
        got = scatter_mean_grad(idx, mask, g, 40)
        want = ref.gather_mean_grad_x_ref(idx, mask, g, 40)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    @settings(max_examples=15, deadline=None)
    @given(
        n=st.integers(2, 100),
        m=st.integers(1, 150),
        k=st.integers(1, 8),
        d=st.integers(1, 24),
        seed=st.integers(0, 10_000),
    )
    def test_grad_sweep(self, n, m, k, d, seed):
        x, idx, mask = rand_case(seed, n=n, m=m, k=k, d=d)
        g = jnp.asarray(
            np.random.default_rng(seed + 1).standard_normal((m, d)).astype(np.float32)
        )
        got = scatter_mean_grad(idx, mask, g, n)
        want = ref.gather_mean_grad_x_ref(idx, mask, g, n)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_duplicate_indices_accumulate(self):
        # All neighbors point at row 0: gradient should pile up there.
        n, m, k, d = 8, 4, 3, 2
        x = jnp.ones((n, d), jnp.float32)
        idx = jnp.zeros((m, k), jnp.int32)
        mask = jnp.ones((m, k), jnp.float32)
        g = jnp.ones((m, d), jnp.float32)
        gx = scatter_mean_grad(idx, mask, g, n)
        # every row contributes 1/k per slot, k slots, m rows → m total
        np.testing.assert_allclose(gx[0], np.full(d, float(m)), rtol=1e-5)
        np.testing.assert_allclose(gx[1:], np.zeros((n - 1, d)), atol=1e-7)


def rand_gat_case(seed, n, m, k, d):
    rng = np.random.default_rng(seed)
    z = rng.standard_normal((n, d)).astype(np.float32)
    s_src = rng.standard_normal(n).astype(np.float32)
    s_dst = rng.standard_normal(m).astype(np.float32)
    idx = rng.integers(0, n, (m, k)).astype(np.int32)
    mask = (rng.random((m, k)) > 0.3).astype(np.float32)
    return tuple(jnp.asarray(a) for a in (z, s_src, s_dst, idx, mask))


class TestGatAttention:
    def test_matches_ref_basic(self):
        z, s_src, s_dst, idx, mask = rand_gat_case(0, n=64, m=32, k=5, d=16)
        got = gat_attention(z, s_src, s_dst, idx, mask)
        want = ref.gat_attention_ref(z, s_src, s_dst, idx, mask)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_isolated_vertex_keeps_self(self):
        # All neighbors masked out ⇒ attention collapses onto the self edge.
        z, s_src, s_dst, idx, _ = rand_gat_case(1, n=16, m=4, k=3, d=8)
        mask = jnp.zeros((4, 3), jnp.float32)
        got = gat_attention(z, s_src, s_dst, idx, mask)
        np.testing.assert_allclose(got, z[:4], rtol=1e-5, atol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(
        n=st.integers(2, 150),
        m=st.integers(1, 200),
        k=st.integers(1, 10),
        d=st.integers(1, 32),
        seed=st.integers(0, 10_000),
    )
    def test_matches_ref_sweep(self, n, m, k, d, seed):
        m = min(m, n)  # dst rows are a prefix of the mixed rows
        z, s_src, s_dst, idx, mask = rand_gat_case(seed, n=n, m=m, k=k, d=d)
        got = gat_attention(z, s_src, s_dst, idx, mask)
        want = ref.gat_attention_ref(z, s_src, s_dst, idx, mask)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_attention_weights_sum_to_one_effect(self):
        # With identical z rows the output must equal that row regardless
        # of attention weights (softmax is a convex combination).
        n, m, k, d = 20, 6, 4, 5
        z = jnp.tile(jnp.arange(d, dtype=jnp.float32)[None, :], (n, 1))
        s_src = jnp.linspace(-1, 1, n)
        s_dst = jnp.linspace(1, -1, m)
        idx = jnp.asarray(np.random.default_rng(2).integers(0, n, (m, k)), jnp.int32)
        mask = jnp.ones((m, k), jnp.float32)
        got = gat_attention(z, s_src, s_dst, idx, mask)
        np.testing.assert_allclose(got, z[:m], rtol=1e-5, atol=1e-5)

    def test_grad_matches_ref(self):
        z, s_src, s_dst, idx, mask = rand_gat_case(3, n=40, m=16, k=5, d=8)

        def via_kernel(zz, ss, sd):
            return (gat_attention(zz, ss, sd, idx, mask) ** 2).sum()

        def via_ref(zz, ss, sd):
            return (ref.gat_attention_ref(zz, ss, sd, idx, mask) ** 2).sum()

        gk = jax.grad(via_kernel, argnums=(0, 1, 2))(z, s_src, s_dst)
        gr = jax.grad(via_ref, argnums=(0, 1, 2))(z, s_src, s_dst)
        for a, b in zip(gk, gr):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


class TestJitAndLowering:
    def test_kernels_jit_cleanly(self):
        x, idx, mask = rand_case(7, n=32, m=16, k=4, d=8)
        jit_fn = jax.jit(gather_mean)
        np.testing.assert_allclose(
            jit_fn(x, idx, mask), gather_mean(x, idx, mask), rtol=1e-6
        )

    def test_gather_mean_lowers_to_hlo_text(self):
        from compile.aot import to_hlo_text

        spec_x = jax.ShapeDtypeStruct((60, 8), jnp.float32)
        spec_i = jax.ShapeDtypeStruct((10, 4), jnp.int32)
        spec_m = jax.ShapeDtypeStruct((10, 4), jnp.float32)
        lowered = jax.jit(lambda *a: (gather_mean(*a),)).lower(spec_x, spec_i, spec_m)
        text = to_hlo_text(lowered)
        assert "HloModule" in text
        assert "ROOT" in text
