"""AOT export contract tests: manifest consistency, HLO parameter counts
(keep_unused must hold every argument), and golden-file regeneration
determinism. These run against the checked-in aot module without writing
to the real artifacts/ directory."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


def test_param_specs_match_model_layouts():
    sage = aot.param_specs("sage", 8, 4)
    assert [s.shape for s in sage] == [(8, 4), (8, 4), (4,)]
    gat = aot.param_specs("gat", 8, 4)
    assert [s.shape for s in gat] == [(8, 4), (4,), (4,), (4,)]


def test_layer_fwd_lowering_keeps_all_parameters():
    # The no-relu backward famously DCEs the bias without keep_unused; the
    # HLO entry signature must keep every runtime-supplied argument.
    k, m, n, din, dout = 5, 256, 1536, 64, 8
    specs = [
        aot.f32(n, din),
        aot.i32(m, k),
        aot.f32(m, k),
        aot.f32(m, dout),
        *aot.param_specs("sage", din, dout),
    ]
    text = aot.lower_artifact(aot.layer_bwd_fn("sage", False), specs)
    entry = text[text.index("ENTRY") :]
    n_params = entry.count("parameter(")
    assert n_params == len(specs), f"expected {len(specs)} params, HLO has {n_params}"


def test_bucket_capacity_invariant():
    # N = M·(K+1) guarantees any layer with m_real ≤ M fits (n_real ≤ N).
    k = aot.KERNEL_K
    for m in aot.M_BUCKETS:
        n = m * (k + 1)
        # worst case mixed size for m destinations:
        assert m * (k + 1) <= n


def test_full_export_writes_consistent_manifest(tmp_path):
    # Monkeypatch the config to a tiny set so the test stays fast.
    old = (aot.M_BUCKETS, aot.LOSS_BUCKETS, aot.LAYER_DIMS)
    aot.M_BUCKETS, aot.LOSS_BUCKETS = [256], [256]
    aot.LAYER_DIMS = [(aot.FEAT_DIM, aot.HIDDEN, True), (aot.HIDDEN, aot.NUM_CLASSES, False)]
    try:
        out = str(tmp_path / "arts")
        aot.build_artifacts(out)
        manifest = json.load(open(os.path.join(out, "manifest.json")))
        assert manifest["version"] == 1
        names = {a["name"] for a in manifest["artifacts"]}
        # 2 models × 2 dims × 1 bucket × (fwd+bwd) + 1 loss = 9
        assert len(names) == 9
        for a in manifest["artifacts"]:
            path = os.path.join(out, a["file"])
            assert os.path.exists(path), a["file"]
            text = open(path).read()
            assert text.startswith("HloModule"), a["file"]
        golden = json.load(open(os.path.join(out, "golden.json")))
        assert "layer" in golden and "loss" in golden
        assert len(golden["layer"]["out_rows"]) == golden["layer"]["m_real"] * aot.HIDDEN
    finally:
        aot.M_BUCKETS, aot.LOSS_BUCKETS, aot.LAYER_DIMS = old


def test_loss_head_golden_math():
    # Cross-check the golden loss values written by write_goldens against a
    # hand computation on the same ramp inputs.
    import numpy as np

    b, c = 4, aot.NUM_CLASSES
    logits = jnp.asarray(np.arange(b * c, dtype=np.float32).reshape(b, c) / 7.0)
    labels = jnp.asarray(np.array([1, 0, 3, 2], dtype=np.int32))
    valid = jnp.asarray(np.array([1.0, 1.0, 0.0, 1.0], dtype=np.float32))
    loss, g, correct = model.loss_head(logits, labels, valid)
    logp = jax.nn.log_softmax(logits, axis=-1)
    want = -(logp[0, 1] + logp[1, 0] + logp[3, 2]) / 3.0
    assert abs(float(loss) - float(want)) < 1e-6
    assert float(jnp.abs(g[2]).sum()) < 1e-8  # masked row: no gradient
