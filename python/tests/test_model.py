"""L2 correctness: layer shapes, per-layer backward vs autodiff of the whole
stack, loss head semantics, and the full_forward composition that mirrors
what the Rust split-parallel engine does with shuffles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

jax.config.update("jax_platform_name", "cpu")


def make_layer_case(kind, seed, n=40, m=16, k=4, din=12, dout=8):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n, din)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, n, (m, k)).astype(np.int32))
    mask = jnp.asarray((rng.random((m, k)) > 0.2).astype(np.float32))
    params = model.init_params(kind, jax.random.PRNGKey(seed), [(din, dout)])[0]
    return params, x, idx, mask


@pytest.mark.parametrize("kind", ["sage", "gat"])
class TestLayer:
    def test_output_shape(self, kind):
        params, x, idx, mask = make_layer_case(kind, 0)
        h = model.layer_apply(kind, params, x, idx, mask, True)
        assert h.shape == (16, 8)
        assert bool(jnp.all(h >= 0)), "relu output must be non-negative"

    def test_no_relu_variant(self, kind):
        params, x, idx, mask = make_layer_case(kind, 1)
        h = model.layer_apply(kind, params, x, idx, mask, False)
        assert bool(jnp.any(h < 0)), "non-relu layer should produce negatives"

    def test_bwd_matches_autodiff(self, kind):
        params, x, idx, mask = make_layer_case(kind, 2)
        g_out = jnp.asarray(
            np.random.default_rng(3).standard_normal((16, 8)).astype(np.float32)
        )
        grads = model.layer_bwd(kind, params, x, idx, mask, True, g_out)

        def scalar(xx, *pp):
            h = model.layer_apply(kind, pp, xx, idx, mask, True)
            return jnp.sum(h * g_out)

        expect = jax.grad(scalar, argnums=tuple(range(1 + len(params))))(x, *params)
        assert len(grads) == len(expect)
        for a, b in zip(grads, expect):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)

    def test_padded_rows_do_not_affect_valid_rows(self, kind):
        # Doubling M with garbage rows must not change the first rows.
        params, x, idx, mask = make_layer_case(kind, 4)
        h1 = model.layer_apply(kind, params, x, idx, mask, True)
        idx2 = jnp.concatenate([idx, jnp.zeros_like(idx)], axis=0)
        mask2 = jnp.concatenate([mask, jnp.zeros_like(mask)], axis=0)
        # mixed rows must cover the new dst rows: extend x by zeros
        x2 = jnp.concatenate([x[:16], jnp.zeros((16, x.shape[1])), x[16:]], axis=0)
        # remap idx2 entries ≥ 16 (they shifted by 16)
        idx2 = jnp.where(idx2 >= 16, idx2 + 16, idx2)
        h2 = model.layer_apply(kind, params, x2, idx2, mask2, True)
        np.testing.assert_allclose(h1, h2[:16], rtol=1e-4, atol=1e-5)


class TestLossHead:
    def test_loss_value_and_grad(self):
        logits = jnp.asarray([[2.0, 0.0], [0.0, 3.0], [9.0, 9.0]])
        labels = jnp.asarray([0, 1, 0], jnp.int32)
        valid = jnp.asarray([1.0, 1.0, 0.0])
        loss, g, correct = model.loss_head(logits, labels, valid)
        # manual: -log softmax picks
        p0 = np.exp(2.0) / (np.exp(2.0) + 1.0)
        p1 = np.exp(3.0) / (np.exp(3.0) + 1.0)
        want = -(np.log(p0) + np.log(p1)) / 2
        np.testing.assert_allclose(float(loss), want, rtol=1e-5)
        # padded row contributes no gradient
        np.testing.assert_allclose(g[2], np.zeros(2), atol=1e-7)
        assert float(correct) == 2.0

    def test_correct_counts_only_valid(self):
        logits = jnp.asarray([[5.0, 0.0]] * 4)
        labels = jnp.asarray([0, 0, 1, 0], jnp.int32)
        valid = jnp.asarray([1.0, 1.0, 1.0, 0.0])
        _, _, correct = model.loss_head(logits, labels, valid)
        assert float(correct) == 2.0

    def test_grad_is_softmax_minus_onehot_scaled(self):
        rng = np.random.default_rng(0)
        logits = jnp.asarray(rng.standard_normal((6, 4)).astype(np.float32))
        labels = jnp.asarray(rng.integers(0, 4, 6).astype(np.int32))
        valid = jnp.ones(6)
        _, g, _ = model.loss_head(logits, labels, valid)
        sm = jax.nn.softmax(logits, axis=-1)
        onehot = jax.nn.one_hot(labels, 4)
        np.testing.assert_allclose(g, (sm - onehot) / 6.0, rtol=1e-4, atol=1e-5)


class TestFullForward:
    def test_two_layer_composition_matches_manual(self):
        # Build a tiny 2-layer mini-batch by hand and check full_forward
        # against manually chained layer_apply + gather.
        kind = "sage"
        rng = np.random.default_rng(1)
        n_input, m1, m0, k = 30, 10, 4, 3
        x_in = jnp.asarray(rng.standard_normal((n_input, 6)).astype(np.float32))
        idx1 = jnp.asarray(rng.integers(0, n_input, (m1, k)).astype(np.int32))
        mask1 = jnp.ones((m1, k), jnp.float32)
        # top layer consumes a mixed frontier of 12 rows gathered from the
        # m1 bottom outputs
        gather_top = jnp.asarray(rng.integers(0, m1, (12,)).astype(np.int32))
        idx0 = jnp.asarray(rng.integers(0, 12, (m0, k)).astype(np.int32))
        mask0 = jnp.ones((m0, k), jnp.float32)
        params = model.init_params(
            kind, jax.random.PRNGKey(0), [(6, 5), (5, 2)]
        )
        logits = model.full_forward(
            kind,
            params,
            x_in,
            [(idx1, mask1, None), (idx0, mask0, gather_top)],
        )
        h1 = model.layer_apply(kind, params[0], x_in, idx1, mask1, True)
        h_mixed = h1[gather_top]
        want = model.layer_apply(kind, params[1], h_mixed, idx0, mask0, False)
        np.testing.assert_allclose(logits, want, rtol=1e-5, atol=1e-6)

    def test_training_reduces_loss_on_separable_data(self):
        # Miniature end-to-end sanity: one Sage layer + loss head learns a
        # linearly separable 2-class problem on a fixed "mini-batch".
        rng = np.random.default_rng(7)
        n, m, k, din = 64, 32, 4, 8
        labels_np = (np.arange(m) % 2).astype(np.int32)
        x = rng.standard_normal((n, din)).astype(np.float32)
        x[:m, 0] = labels_np * 4.0 - 2.0  # self feature carries the class
        x = jnp.asarray(x)
        idx = jnp.asarray(rng.integers(0, n, (m, k)).astype(np.int32))
        mask = jnp.ones((m, k), jnp.float32)
        labels = jnp.asarray(labels_np)
        valid = jnp.ones(m)
        params = model.init_params("sage", jax.random.PRNGKey(3), [(din, 2)])[0]

        def loss_of(pp):
            h = model.layer_apply("sage", pp, x, idx, mask, False)
            loss, _, _ = model.loss_head(h, labels, valid)
            return loss

        l0 = float(loss_of(params))
        for _ in range(60):
            g = jax.grad(loss_of)(params)
            params = tuple(p - 0.5 * gp for p, gp in zip(params, g))
        l1 = float(loss_of(params))
        assert l1 < l0 * 0.5, f"loss did not drop: {l0} -> {l1}"
