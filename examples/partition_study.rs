//! Partitioning study: compares the four offline strategies (GSplit /
//! Node / Edge / Rand) on a dataset — expected cut, expected balance, and
//! realized per-mini-batch splitting quality (the §7.3 / Figure 5 story
//! as a runnable example).
//!
//! Run: `cargo run --release --example partition_study -- --dataset tiny`

use anyhow::Result;
use gsplit::cli::Args;
use gsplit::config::parse_dataset;
use gsplit::opts;
use gsplit::partition::{
    evaluate_minibatch, evaluate_partitioning, partition_graph, Strategy,
};
use gsplit::presample::{presample, PresampleConfig};
use gsplit::rng::{derive_seed, Pcg32};
use gsplit::sampling::Sampler;
use gsplit::util::{timer::timed, Table};

fn main() -> Result<()> {
    let spec = opts![
        ("dataset", true, "orkut-s|papers-s|friendster-s|tiny (default tiny)"),
        ("parts", true, "number of splits (default 4)"),
        ("batch", true, "mini-batch size (default 1024)"),
        ("fanout", true, "fanout (default 15)"),
        ("layers", true, "layers (default 3)"),
        ("presample-epochs", true, "pre-sampling epochs (default 5)"),
        ("iters", true, "mini-batches to evaluate (default 16)"),
    ];
    let a = Args::from_env(spec, "compare offline partitioning strategies")?;
    let ds = parse_dataset(&a.get_str("dataset", "tiny"))?.load()?;
    let k = a.get_usize("parts", 4)?;
    let batch = a.get_usize("batch", 1024)?;
    let fanout = a.get_usize("fanout", 15)?;
    let layers = a.get_usize("layers", 3)?;
    let iters = a.get_usize("iters", 16)?;
    let seed = 42u64;

    let (t_pre, pw) = timed(|| {
        presample(
            &ds.graph,
            &ds.labels.train_set,
            &PresampleConfig {
                epochs: a.get_usize("presample-epochs", 5).unwrap(),
                batch_size: batch,
                fanouts: vec![fanout; layers],
                seed,
            },
        )
    });
    println!(
        "dataset {} ({} vertices, {} edges); presample {t_pre:.1}s\n",
        ds.spec.name,
        ds.graph.num_vertices(),
        ds.graph.num_edges()
    );

    let mask: Vec<bool> = {
        let mut m = vec![false; ds.graph.num_vertices()];
        for &t in &ds.labels.train_set {
            m[t as usize] = true;
        }
        m
    };

    let mut table = Table::new(&[
        "Strategy",
        "Partition(s)",
        "E[cut] frac",
        "E[imbalance]",
        "mb cross %",
        "mb imbalance",
    ])
    .left(0);
    for strat in [Strategy::GSplit, Strategy::Node, Strategy::Edge, Strategy::Rand] {
        let (t_part, part) =
            timed(|| partition_graph(&ds.graph, &pw, &mask, strat, k, 0.05, seed));
        let q = evaluate_partitioning(&ds.graph, &pw, &part);
        // Realized mini-batch quality over a few iterations.
        let mut sampler = Sampler::new();
        let targets = ds.epoch_targets(seed);
        let (mut cross, mut imb) = (0.0, 0.0);
        let mut n = 0;
        for (i, chunk) in targets.chunks(batch).take(iters).enumerate() {
            let mut rng = Pcg32::new(derive_seed(seed, &[i as u64]));
            let mb = sampler.sample(&ds.graph, chunk, &vec![fanout; layers], &mut rng);
            let mq = evaluate_minibatch(&mb, &part);
            cross += mq.cross_edge_fraction * 100.0;
            imb += mq.imbalance;
            n += 1;
        }
        table.row(vec![
            format!("{strat:?}"),
            format!("{t_part:.1}"),
            format!("{:.3}", q.cut_fraction()),
            format!("{:.3}", q.imbalance),
            format!("{:.1}%", cross / n as f64),
            format!("{:.3}", imb / n as f64),
        ]);
    }
    table.print();
    println!(
        "\nGSplit should dominate: lowest realized cross-edge % at near-balanced load\n\
         (Rand balances best but shuffles ~75% of edges; Edge cuts well but can be imbalanced)."
    );
    Ok(())
}
