//! Multi-host hybrid parallelism (paper §7.4 / Figure 6b): data parallelism
//! across hosts × split parallelism within each host, compared against
//! data-parallel baselines on the same simulated cluster.
//!
//! Run: `cargo run --release --example multihost_sim -- --dataset papers-s`

use anyhow::Result;
use gsplit::cli::Args;
use gsplit::config::parse_dataset;
use gsplit::devices::Topology;
use gsplit::exec::{run_epoch, DataParallel, EngineCtx, SplitParallel};
use gsplit::model::GnnKind;
use gsplit::opts;
use gsplit::partition::{partition_graph, Strategy};
use gsplit::presample::{presample, PresampleConfig};
use gsplit::util::{fmt_secs, Table};

fn main() -> Result<()> {
    let spec = opts![
        ("dataset", true, "dataset (default tiny)"),
        ("batch", true, "batch size (default 1024)"),
        ("fanout", true, "fanout (default 15)"),
    ];
    let a = Args::from_env(spec, "multi-host hybrid parallelism simulation")?;
    let ds = parse_dataset(&a.get_str("dataset", "tiny"))?.load()?;
    let batch = a.get_usize("batch", 1024)?;
    let fanout = a.get_usize("fanout", 15)?;
    let seed = 42;

    println!(
        "Multi-host scaling on {} (hosts × 4 GPUs; epoch seconds, modeled)\n",
        ds.spec.name
    );
    let mut table =
        Table::new(&["Hosts", "GPUs", "DGL", "Quiver", "GSplit(hybrid)", "vs DGL", "vs Quiver"])
            .left(0);
    for hosts in [1usize, 2, 4] {
        let topo = Topology::multi_host(hosts, ds.spec.scale_divisor);
        let k = topo.num_gpus();
        let ctx = EngineCtx::new(&ds, topo, GnnKind::GraphSage, 256, 3, fanout);
        let pw = presample(
            &ds.graph,
            &ds.labels.train_set,
            &PresampleConfig { epochs: 2, batch_size: batch, fanouts: ctx.fanouts.clone(), seed },
        );
        let mask = vec![false; ds.graph.num_vertices()];
        let part = partition_graph(&ds.graph, &pw, &mask, Strategy::GSplit, k, 0.05, seed);

        let (_, t_dgl) = run_epoch(&mut DataParallel::dgl(&ctx), &ctx, batch, seed);
        let (_, t_q) = run_epoch(&mut DataParallel::quiver(&ctx, &pw, batch), &ctx, batch, seed);
        let mut gs = SplitParallel::new(&ctx, part, &pw.vertex, batch);
        let (_, t_g) = run_epoch(&mut gs, &ctx, batch, seed);
        table.row(vec![
            hosts.to_string(),
            k.to_string(),
            fmt_secs(t_dgl.total()),
            fmt_secs(t_q.total()),
            fmt_secs(t_g.total()),
            format!("{:.1}x", t_dgl.total() / t_g.total()),
            format!("{:.1}x", t_q.total() / t_g.total()),
        ]);
    }
    table.print();
    println!(
        "\nGSplit avoids cross-host feature traffic entirely: hosts exchange only\n\
         gradients, while split-parallel shuffles stay on intra-host NVLink."
    );
    Ok(())
}
