//! **End-to-end validation driver** (paper §7 end-to-end story): train a
//! 3-layer GraphSage on a synthetic SBM community graph with the
//! split-parallel engine and real compute — cooperative sampling,
//! per-layer hidden shuffles, per-layer VJP backward with reverse
//! shuffles, gradient all-reduce, SGD — and log the loss curve plus
//! validation accuracy.
//!
//! Uses the pure-Rust `NativeBackend`, so it runs on a fresh clone with no
//! artifacts; build with `--features pjrt` and swap the backend to drive
//! the AOT executables instead.
//!
//! Pass `--parallel-workers N` to run the threaded pipelined executor
//! (one worker pool per epoch, sampling-ahead overlap; bit-identical to
//! serial for the same seed — see DESIGN.md §Executor).
//!
//! Pass `--cache-policy distributed|partitioned` (with `--cache-budget`
//! rows per GPU) to serve input features from per-GPU resident caches —
//! numerics are unchanged, the final loading byte split shows where bytes
//! came from (DESIGN.md §Loading).
//!
//! Run: `cargo run --release --example train_sage -- --iters 300`
//!  or: `cargo run --release --example train_sage -- --parallel-workers 4`
//!  or: `cargo run --release --example train_sage -- --cache-policy partitioned`

use std::sync::Arc;

use anyhow::Result;
use gsplit::cache::{CachePolicy, LoadStats, ResidentCache};
use gsplit::cli::Args;
use gsplit::devices::Topology;
use gsplit::graph::Dataset;
use gsplit::model::{GnnKind, ModelConfig};
use gsplit::opts;
use gsplit::partition::{partition_graph, Strategy};
use gsplit::presample::{presample, PresampleConfig};
use gsplit::runtime::NativeBackend;
use gsplit::train::{train_epoch, ExecMode, TrainConfig, Trainer};
use gsplit::util::timer::timed;

fn main() -> Result<()> {
    let spec = opts![
        ("iters", true, "training iterations, rounded up to whole epochs (default 300)"),
        ("batch", true, "mini-batch size (default 256)"),
        ("gpus", true, "simulated GPUs (default 4)"),
        ("vertices", true, "graph size (default 32768)"),
        ("hidden", true, "hidden dim (default 64)"),
        ("classes", true, "SBM communities = classes (default 8)"),
        ("fanout", true, "neighbor fanout (default 5)"),
        ("lr", true, "learning rate (default 0.25)"),
        ("seed", true, "seed (default 42)"),
        ("parallel-workers", true, "pipelined-executor worker threads (0 = serial, default 0)"),
        ("cache-policy", true, "feature cache: none|distributed|partitioned (default none)"),
        ("cache-budget", true, "cached feature rows per simulated GPU (default 4096)"),
    ];
    let a = Args::from_env(spec, "end-to-end split-parallel GraphSage training")?;
    let iters = a.get_usize("iters", 300)?;
    let batch = a.get_usize("batch", 256)?;
    let k = a.get_usize("gpus", 4)?;
    let seed = a.get_u64("seed", 42)?;
    let fanout = a.get_usize("fanout", 5)?;

    let backend = NativeBackend::new();
    let cfg = ModelConfig {
        kind: GnnKind::GraphSage,
        feat_dim: 32,
        hidden: a.get_usize("hidden", 64)?,
        num_classes: a.get_usize("classes", 8)?,
        num_layers: 3,
    };
    let ds = Dataset::sbm_learnable(
        a.get_usize("vertices", 32768)?,
        cfg.num_classes,
        cfg.feat_dim,
        0.6,
        seed,
    );
    println!(
        "# SBM graph: {} vertices, {} edges, {} classes; model {}-layer GraphSage ({}→{}→{})",
        ds.graph.num_vertices(),
        ds.graph.num_edges(),
        cfg.num_classes,
        cfg.num_layers,
        cfg.feat_dim,
        cfg.hidden,
        cfg.num_classes
    );

    // Offline stage of the splitting algorithm.
    let fanouts = vec![fanout; cfg.num_layers];
    let (t_pre, pw) = timed(|| {
        presample(
            &ds.graph,
            &ds.labels.train_set,
            &PresampleConfig { epochs: 3, batch_size: batch, fanouts, seed },
        )
    });
    let mask = vec![false; ds.graph.num_vertices()];
    let (t_part, part) =
        timed(|| partition_graph(&ds.graph, &pw, &mask, Strategy::GSplit, k, 0.05, seed));
    println!("# offline: presample {t_pre:.1}s, partition {t_part:.1}s, k={k}");

    let workers = a.get_usize("parallel-workers", 0)?;
    let mut trainer =
        Trainer::new(&backend, &cfg, fanout, part, a.get_f64("lr", 0.25)? as f32, seed)?;

    // Optional cache-aware loading stage, ranked by pre-sampling
    // frequency (DESIGN.md §Loading). Numerics are identical at any
    // policy/budget; only the loading byte split below changes.
    let policy = CachePolicy::parse(&a.get_str("cache-policy", "none"))?;
    let mut resident = None;
    if policy != CachePolicy::None {
        let budget = a.get_u64("cache-budget", 4096)?;
        let topo = Topology::for_gpus(k, 1.0)?;
        let cache = Arc::new(ResidentCache::build(
            policy,
            &pw.vertex,
            budget,
            trainer.partitioning(),
            &topo,
            &ds.features,
        ));
        println!(
            "# cache: {} | {budget} rows/GPU | coverage {:.1}%",
            policy.name(),
            cache.placement().coverage() * 100.0
        );
        resident = Some(cache);
    }
    trainer.apply_config(TrainConfig::new().parallel_workers(workers).cache(resident))?;

    match trainer.exec_mode() {
        ExecMode::Serial => println!("# executor: serial"),
        ExecMode::Pipelined(p) => {
            println!("# executor: pipelined, {} workers (sampling-ahead overlap)", p.workers)
        }
    }
    println!("step,loss,batch_acc");
    let t0 = std::time::Instant::now();
    let mut step = 0usize;
    let mut epoch = 0u64;
    #[allow(unused_assignments)]
    let mut last_loss = f32::NAN;
    // Whole epochs through `train_epoch`, so the pipelined executor can
    // overlap batch t+1's sampling with batch t's compute; every executed
    // iteration is counted, so --iters rounds up to an epoch boundary and
    // the reported it/s stays honest.
    while step < iters {
        for s in train_epoch(&mut trainer, &ds, batch, epoch)? {
            step += 1;
            last_loss = s.loss;
            if step % 10 == 0 || step == 1 {
                println!("{step},{:.4},{:.4}", s.loss, s.accuracy());
            }
        }
        epoch += 1;
    }
    let elapsed = t0.elapsed().as_secs_f64();

    // Validation over a few batches.
    let mut correct = 0f32;
    let mut total = 0usize;
    for (i, chunk) in ds.labels.val_set.chunks(batch).take(8).enumerate() {
        let s = trainer.evaluate(&ds, chunk, 0xDEAD + i as u64)?;
        correct += s.correct;
        total += s.examples;
    }
    let val_acc = correct / total.max(1) as f32;
    println!(
        "# {step} iterations in {elapsed:.1}s ({:.2} it/s); final loss {last_loss:.4}",
        step as f64 / elapsed
    );
    println!(
        "# validation accuracy {:.4} over {} examples (random baseline {:.4})",
        val_acc,
        total,
        1.0 / cfg.num_classes as f32
    );
    let split = LoadStats::sum(trainer.load_stats());
    println!(
        "# loading: local {} | peer(nvlink) {} | host(pcie) {} | disk {}",
        gsplit::util::fmt_bytes(split.local_bytes),
        gsplit::util::fmt_bytes(split.peer_bytes),
        gsplit::util::fmt_bytes(split.host_bytes),
        gsplit::util::fmt_bytes(split.disk_bytes),
    );
    if val_acc < 2.0 / cfg.num_classes as f32 {
        anyhow::bail!("training failed to beat the random baseline");
    }
    println!("# OK");
    Ok(())
}
