//! Quickstart: the whole GSplit pipeline on a small graph in ~a minute.
//!
//! 1. generate a community graph,
//! 2. pre-sample to weight vertices/edges (offline stage 1),
//! 3. weighted min-cut partition → global splitting function f_G (stage 2),
//! 4. cooperatively sample + split one mini-batch online,
//! 5. run one real split-parallel training iteration through the
//!    AOT-compiled (JAX/Pallas → HLO → PJRT) executables.
//!
//! Run: `cargo run --release --example quickstart`

use anyhow::Result;
use gsplit::graph::Dataset;
use gsplit::model::{GnnKind, ModelConfig};
use gsplit::partition::{evaluate_partitioning, partition_graph, Strategy};
use gsplit::presample::{presample, PresampleConfig};
use gsplit::runtime::Runtime;
use gsplit::split::SplitSampler;
use gsplit::train::Trainer;
use gsplit::util::fmt_count;

fn main() -> Result<()> {
    // --- load the AOT artifacts (build once with `make artifacts`) ---
    let rt = Runtime::load("artifacts")?;
    let cfg = ModelConfig {
        kind: GnnKind::GraphSage,
        feat_dim: rt.manifest.feat_dim,
        hidden: rt.manifest.hidden,
        num_classes: rt.manifest.num_classes,
        num_layers: rt.manifest.layer_dims.len(),
    };
    println!("model: 3-layer GraphSage {}→{}→{} classes", cfg.feat_dim, cfg.hidden, cfg.num_classes);

    // --- a small learnable dataset ---
    let ds = Dataset::sbm_learnable(8192, cfg.num_classes, cfg.feat_dim, 0.6, 7);
    println!(
        "graph: {} vertices, {} edges, {} train targets",
        fmt_count(ds.graph.num_vertices() as u64),
        fmt_count(ds.graph.num_edges() as u64),
        fmt_count(ds.labels.train_set.len() as u64)
    );

    // --- offline: pre-sample + weighted min-cut partition (4 splits) ---
    let fanouts = vec![rt.manifest.kernel_fanout; cfg.num_layers];
    let pw = presample(
        &ds.graph,
        &ds.labels.train_set,
        &PresampleConfig { epochs: 3, batch_size: 256, fanouts: fanouts.clone(), seed: 7 },
    );
    let mask = vec![false; ds.graph.num_vertices()];
    let part = partition_graph(&ds.graph, &pw, &mask, Strategy::GSplit, 4, 0.05, 7);
    let q = evaluate_partitioning(&ds.graph, &pw, &part);
    println!(
        "partitioning: expected cut fraction {:.3}, load imbalance {:.3}",
        q.cut_fraction(),
        q.imbalance
    );

    // --- online: split one mini-batch and inspect the plan ---
    let targets = &ds.epoch_targets(0)[..256];
    let mut ss = SplitSampler::new(4);
    let plan = ss.sample(&ds.graph, targets, &fanouts, &part, 1);
    println!(
        "split plan: {} layers, {} total sampled edges, {} non-overlapping input rows",
        plan.layers.len(),
        fmt_count(plan.total_edges()),
        fmt_count(plan.total_inputs())
    );
    for (i, layer) in plan.layers.iter().enumerate() {
        println!(
            "  layer {i}: dst per split {:?}, remote shuffle rows {}",
            layer.per_dev.iter().map(|d| d.num_dst()).collect::<Vec<_>>(),
            layer.shuffle.remote_rows()
        );
    }

    // --- one real training iteration through PJRT ---
    let mut trainer = Trainer::new(&rt, &cfg, part, 0.2, 7)?;
    let stats = trainer.train_iteration(&ds, targets, 0)?;
    println!(
        "one split-parallel training iteration: loss {:.4}, batch accuracy {:.3}",
        stats.loss,
        stats.accuracy()
    );
    println!("OK — see examples/train_sage.rs for full training runs.");
    Ok(())
}
