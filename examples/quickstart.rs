//! Quickstart: the whole GSplit pipeline on a small graph in ~a minute.
//!
//! 1. generate a community graph,
//! 2. pre-sample to weight vertices/edges (offline stage 1),
//! 3. weighted min-cut partition → global splitting function f_G (stage 2),
//! 4. cooperatively sample + split one mini-batch online,
//! 5. train for a few split-parallel iterations with real compute through
//!    the pure-Rust `NativeBackend` (no artifacts or Python required).
//!
//! Run: `cargo run --release --example quickstart`

use anyhow::{ensure, Result};
use gsplit::graph::Dataset;
use gsplit::model::{GnnKind, ModelConfig};
use gsplit::partition::{evaluate_partitioning, partition_graph, Strategy};
use gsplit::presample::{presample, PresampleConfig};
use gsplit::runtime::{Backend, NativeBackend};
use gsplit::split::SplitSampler;
use gsplit::train::Trainer;
use gsplit::util::fmt_count;

fn main() -> Result<()> {
    // --- the numeric backend and model shape ---
    let backend = NativeBackend::new();
    let fanout = 5usize;
    let cfg = ModelConfig {
        kind: GnnKind::GraphSage,
        feat_dim: 32,
        hidden: 64,
        num_classes: 8,
        num_layers: 3,
    };
    println!(
        "model: {}-layer GraphSage {}→{}→{} classes ({} backend)",
        cfg.num_layers,
        cfg.feat_dim,
        cfg.hidden,
        cfg.num_classes,
        backend.name()
    );

    // --- a small learnable dataset ---
    let ds = Dataset::sbm_learnable(8192, cfg.num_classes, cfg.feat_dim, 0.6, 7);
    println!(
        "graph: {} vertices, {} edges, {} train targets",
        fmt_count(ds.graph.num_vertices() as u64),
        fmt_count(ds.graph.num_edges() as u64),
        fmt_count(ds.labels.train_set.len() as u64)
    );

    // --- offline: pre-sample + weighted min-cut partition (4 splits) ---
    let fanouts = vec![fanout; cfg.num_layers];
    let pw = presample(
        &ds.graph,
        &ds.labels.train_set,
        &PresampleConfig { epochs: 3, batch_size: 256, fanouts: fanouts.clone(), seed: 7 },
    );
    let mask = vec![false; ds.graph.num_vertices()];
    let part = partition_graph(&ds.graph, &pw, &mask, Strategy::GSplit, 4, 0.05, 7);
    let q = evaluate_partitioning(&ds.graph, &pw, &part);
    println!(
        "partitioning: expected cut fraction {:.3}, load imbalance {:.3}",
        q.cut_fraction(),
        q.imbalance
    );

    // --- online: split one mini-batch and inspect the plan ---
    let targets = &ds.epoch_targets(0)[..256];
    let mut ss = SplitSampler::new(4);
    let plan = ss.sample(&ds.graph, targets, &fanouts, &part, 1);
    println!(
        "split plan: {} layers, {} total sampled edges, {} non-overlapping input rows",
        plan.layers.len(),
        fmt_count(plan.total_edges()),
        fmt_count(plan.total_inputs())
    );
    for (i, layer) in plan.layers.iter().enumerate() {
        println!(
            "  layer {i}: dst per split {:?}, remote shuffle rows {}",
            layer.per_dev.iter().map(|d| d.num_dst()).collect::<Vec<_>>(),
            layer.shuffle.remote_rows()
        );
    }

    // --- a few real split-parallel training iterations ---
    let mut trainer = Trainer::new(&backend, &cfg, fanout, part, 0.2, 7)?;
    println!("training (cooperative split-parallel, 4 simulated GPUs):");
    let mut first = f32::NAN;
    let mut last = f32::NAN;
    for step in 0..10u64 {
        let targets = &ds.epoch_targets(step)[..256];
        let stats = trainer.train_iteration(&ds, targets, step)?;
        if step == 0 {
            first = stats.loss;
        }
        last = stats.loss;
        println!("  step {step}: loss {:.4}, batch accuracy {:.3}", stats.loss, stats.accuracy());
    }
    ensure!(
        last < first,
        "training loss should decrease over 10 steps ({first:.4} -> {last:.4})"
    );
    println!("loss {first:.4} -> {last:.4}: decreasing ✓");
    println!("OK — see examples/train_sage.rs for full training runs.");
    Ok(())
}
