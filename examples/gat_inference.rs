//! GAT through the same layer-centric API (paper §6): the point of
//! GSplit's split/shuffle abstraction is that attention models reuse the
//! exact same single-device kernels as GraphSage — here we run
//! split-parallel **GAT** evaluation and training through the `Backend`
//! trait (native attention forward/backward) and report per-batch latency
//! and loss.
//!
//! Run: `cargo run --release --example gat_inference`

use anyhow::Result;
use gsplit::graph::Dataset;
use gsplit::model::{GnnKind, ModelConfig};
use gsplit::partition::{partition_graph, Strategy};
use gsplit::presample::PresampleWeights;
use gsplit::runtime::NativeBackend;
use gsplit::train::Trainer;
use gsplit::util::Table;

fn main() -> Result<()> {
    let backend = NativeBackend::new();
    let fanout = 5usize;
    let cfg = ModelConfig {
        kind: GnnKind::Gat,
        feat_dim: 32,
        hidden: 32,
        num_classes: 8,
        num_layers: 3,
    };
    let ds = Dataset::sbm_learnable(16384, cfg.num_classes, cfg.feat_dim, 0.5, 3);
    let w = PresampleWeights::uniform(&ds.graph);
    let mask = vec![false; ds.graph.num_vertices()];
    let part = partition_graph(&ds.graph, &w, &mask, Strategy::Edge, 4, 0.05, 3);
    let mut trainer = Trainer::new(&backend, &cfg, fanout, part, 0.1, 3)?;

    println!(
        "split-parallel GAT ({} layers, hidden {}) — batched evaluation\n",
        cfg.num_layers, cfg.hidden
    );
    let mut table = Table::new(&["Batch", "Loss", "Acc", "Latency (ms)"]).left(0);
    for (i, &batch) in [64usize, 128, 256].iter().enumerate() {
        let targets = &ds.epoch_targets(i as u64)[..batch];
        let t0 = std::time::Instant::now();
        let stats = trainer.evaluate(&ds, targets, i as u64)?;
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        table.row(vec![
            batch.to_string(),
            format!("{:.4}", stats.loss),
            format!("{:.3}", stats.accuracy()),
            format!("{ms:.1}"),
        ]);
    }
    table.print();

    // A few training steps to show GAT backward works through the same
    // split/shuffle machinery (attention softmax + LeakyReLU VJP).
    let before = trainer.evaluate(&ds, &ds.epoch_targets(99)[..256], 99)?;
    for step in 0..20 {
        let targets = ds.epoch_targets(step as u64);
        trainer.train_iteration(&ds, &targets[..256], step as u64)?;
    }
    let after = trainer.evaluate(&ds, &ds.epoch_targets(99)[..256], 99)?;
    println!(
        "\n20 GAT training steps: loss {:.4} → {:.4} (attention trains end-to-end)",
        before.loss, after.loss
    );
    Ok(())
}
